"""repro: GBATC (guaranteed block autoencoder with tensor correlations) as a
production-grade JAX training/inference framework.

Layers:
  repro.codec     — public codec API: bytes-in/bytes-out GBATC container
                    (fit/compress to a self-describing blob, standalone
                    decompress with no fitted state)
  repro.core      — the paper's contribution (GBA / GBATC / GAE / SZ baseline)
  repro.nn        — minimal functional module system (params as pytrees)
  repro.data      — synthetic S3D surrogate + token pipelines
  repro.models    — the 10 assigned LM architectures
  repro.parallel  — sharding rules, gradient compression
  repro.train     — optimizer, train loop, checkpointing, fault tolerance
  repro.serve     — prefill/decode serving with (quantized) KV caches
  repro.kernels   — Pallas TPU kernels (+ pure-jnp oracles)
  repro.launch    — production mesh, multi-pod dry-run, drivers
"""

__version__ = "1.0.0"
