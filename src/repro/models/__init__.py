from repro.models.registry import build_model, ARCH_REGISTRY  # noqa: F401
