"""Decoder-only transformer LM: dense GQA, MoE, and VLM (M-RoPE) variants.

Design (MaxText-style, pure JAX):
  * parameters are definition trees (repro.nn.module.Param) carrying logical
    sharding axes; the parallel layer maps them to the mesh;
  * layer stacks run under ``jax.lax.scan`` over parameters stacked on a
    leading "layers" axis (keeps HLO size O(1) in depth — required to compile
    80-layer models quickly) with configurable remat;
  * attention uses the chunked online-softmax path for long sequences (the
    Pallas flash kernel is the TPU hot path, see repro/kernels);
  * MoE uses sort-based capacity dispatch (gather -> stacked-expert einsum ->
    scatter-add), which shards experts over the "model" mesh axis (EP) and
    turns token exchange into XLA all-to-alls.

Embedding table is sharded on d_model (gather stays collective-free and the
table fits per-device); the LM head is vocab-sharded with a sharded-logits
cross entropy.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import common
from repro.nn import layers as L
from repro.nn.module import Param, init_tree, pspec_tree, spec_tree


# --------------------------------------------------------------------------
# Param-def helpers
# --------------------------------------------------------------------------
def _stack_defs(defs, n: int, axis_name: str = "layers"):
    """Add a leading stacked-layer dim to every Param in the tree."""

    def stack(p: Param) -> Param:
        base = p.initializer

        def init(key, shape, dtype):
            keys = jax.random.split(key, shape[0])
            return jax.vmap(lambda k: base(k, p.shape, dtype))(keys)

        return Param((n,) + p.shape, p.dtype, init, (axis_name,) + p.axes)

    if isinstance(defs, Param):
        return stack(defs)
    return {k: _stack_defs(v, n, axis_name) for k, v in defs.items()}


def _norm_defs(cfg: ArchConfig, dim: Optional[int] = None):
    dim = dim or cfg.d_model
    d = {"scale": Param((dim,), jnp.float32, "ones", (None,))}
    if cfg.norm == "layer":
        d["bias"] = Param((dim,), jnp.float32, "zeros", (None,))
    return d


def _apply_norm(cfg: ArchConfig, p, x, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    if cfg.norm == "layer":
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:
        ms = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(ms + eps) * p["scale"]
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# Attention sub-module
# --------------------------------------------------------------------------
def _attn_defs(cfg: ArchConfig):
    dm, hd = cfg.d_model, cfg.head_dim
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    dt = cfg.dtype
    d = {
        "wq": Param((dm, nh * hd), dt, "fan_in", ("embed", "heads")),
        "wk": Param((dm, nkv * hd), dt, "fan_in", ("embed", "kv_heads")),
        "wv": Param((dm, nkv * hd), dt, "fan_in", ("embed", "kv_heads")),
        "wo": Param((nh * hd, dm), dt, "fan_in", ("heads", "embed")),
    }
    if cfg.qkv_bias:
        d["bq"] = Param((nh * hd,), dt, "zeros", ("heads",))
        d["bk"] = Param((nkv * hd,), dt, "zeros", ("kv_heads",))
        d["bv"] = Param((nkv * hd,), dt, "zeros", ("kv_heads",))
    return d


def _project_qkv(cfg: ArchConfig, p, x):
    b, t, _ = x.shape
    hd = cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, t, cfg.n_heads, hd)
    k = k.reshape(b, t, cfg.n_kv_heads, hd)
    v = v.reshape(b, t, cfg.n_kv_heads, hd)
    return q, k, v


def _rope_qk(cfg: ArchConfig, q, k, positions):
    if cfg.rope_theta <= 0:
        return q, k
    if cfg.mrope_sections:
        q = common.apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = common.apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = common.apply_rope(q, positions, cfg.rope_theta, cfg.rope_frac)
        k = common.apply_rope(k, positions, cfg.rope_theta, cfg.rope_frac)
    return q, k


def _attn_forward(cfg: ArchConfig, p, x, positions, *, causal=True):
    b, t, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x)
    q, k = _rope_qk(cfg, q, k, positions)
    o = common.attention(q, k, v, causal=causal, window=cfg.window)
    return o.reshape(b, t, -1) @ p["wo"], (k, v)


def _quant_kv(x):
    """int8 symmetric per-(token, head) quantization — the paper's
    quantization stage applied to serving state (entropy stage dropped on
    the random-access hot path, DESIGN.md §Deviations)."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.abs(x32).max(-1, keepdims=True), 1e-30) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -128, 127).astype(jnp.int8)
    return q, scale


def _attn_decode_quant(cfg: ArchConfig, p, x, positions, kq, vq, ks, vs,
                       cache_len):
    """Single-token decode against an int8 KV cache (dequant fused into the
    attention reads — HBM traffic is the int8 payload, half of bf16)."""
    b = x.shape[0]
    q, k, v = _project_qkv(cfg, p, x)
    q, k = _rope_qk(cfg, q, k, positions)
    k_new_q, k_new_s = _quant_kv(k)
    v_new_q, v_new_s = _quant_kv(v)
    kq = jax.lax.dynamic_update_slice_in_dim(kq, k_new_q, cache_len, axis=1)
    vq = jax.lax.dynamic_update_slice_in_dim(vq, v_new_q, cache_len, axis=1)
    ks = jax.lax.dynamic_update_slice_in_dim(ks, k_new_s, cache_len, axis=1)
    vs = jax.lax.dynamic_update_slice_in_dim(vs, v_new_s, cache_len, axis=1)
    k_deq = (kq.astype(jnp.float32) * ks).astype(cfg.dtype)
    v_deq = (vq.astype(jnp.float32) * vs).astype(cfg.dtype)
    o = common.decode_attention(q, k_deq, v_deq, cache_len + 1,
                                window=cfg.window)
    return o.reshape(b, 1, -1) @ p["wo"], (kq, vq, ks, vs)


def _attn_decode(cfg: ArchConfig, p, x, positions, k_cache, v_cache, cache_len):
    """x: (B, 1, D); returns (out, new_k, new_v) with cache updated at
    position cache_len."""
    b = x.shape[0]
    q, k, v = _project_qkv(cfg, p, x)
    q, k = _rope_qk(cfg, q, k, positions)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, cache_len, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, cache_len, axis=1)
    o = common.decode_attention(
        q, k_cache, v_cache, cache_len + 1, window=cfg.window
    )
    return o.reshape(b, 1, -1) @ p["wo"], k_cache, v_cache


# --------------------------------------------------------------------------
# FFN sub-modules
# --------------------------------------------------------------------------
def _ffn_defs(cfg: ArchConfig):
    dm, df, dt = cfg.d_model, cfg.d_ff, cfg.dtype
    return {
        "wg": Param((dm, df), dt, "fan_in", ("embed", "mlp")),
        "wu": Param((dm, df), dt, "fan_in", ("embed", "mlp")),
        "wd": Param((df, dm), dt, "fan_in", ("mlp", "embed")),
    }


def _ffn_forward(p, x):
    return (jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])) @ p["wd"]


def _moe_defs(cfg: ArchConfig):
    dm, df, e, dt = cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.dtype
    return {
        "router": Param((dm, e), jnp.float32, "fan_in", ("embed", None)),
        "wg": Param((e, dm, df), dt, "fan_in", ("expert", "embed", "mlp")),
        "wu": Param((e, dm, df), dt, "fan_in", ("expert", "embed", "mlp")),
        "wd": Param((e, df, dm), dt, "fan_in", ("expert", "mlp", "embed")),
    }


def moe_capacity(cfg: ArchConfig, n_tokens: int) -> int:
    cap = int(np.ceil(n_tokens * cfg.moe_top_k * cfg.capacity_factor / cfg.n_experts))
    return max(8, -(-cap // 8) * 8)  # round up to 8 for TPU-friendly shapes


def _moe_forward(cfg: ArchConfig, p, x):
    """Sort-based capacity-constrained top-k dispatch.

    Returns (y, aux_loss). Shapes: x (B, T, D) -> assignments (B*T*k,), expert
    buffers (E, C, D) sharded on E (EP); gather/scatter lower to all-to-alls
    under pjit.
    """
    b, t, d = x.shape
    e, k = cfg.n_experts, cfg.moe_top_k
    n = b * t
    xf = x.reshape(n, d)
    logits = (xf.astype(jnp.float32)) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, k)  # (N, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch-style)
    me = probs.mean(axis=0)  # (E,)
    ce = jnp.zeros((e,), jnp.float32).at[top_i.reshape(-1)].add(1.0) / (n * k)
    aux = cfg.router_aux_coef * e * jnp.sum(me * ce)

    flat_e = top_i.reshape(-1)  # (N*k,)
    flat_tok = jnp.repeat(jnp.arange(n), k)
    flat_w = top_w.reshape(-1)
    order = jnp.argsort(flat_e)
    se, st, sw = flat_e[order], flat_tok[order], flat_w[order]

    cap = moe_capacity(cfg, n)
    counts = jnp.zeros((e,), jnp.int32).at[se].add(1)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(se.size) - starts[se]
    keep = pos_in_e < cap
    slot = jnp.where(keep, se * cap + pos_in_e, e * cap)  # overflow -> scratch

    buf = jnp.zeros((e * cap + 1, d), x.dtype)
    buf = buf.at[slot].set(xf[st])
    h = buf[: e * cap].reshape(e, cap, d)
    if cfg.constrain_acts:
        # §Perf lever: pin expert buffers to EP layout so SPMD doesn't
        # replicate the dispatch across the model axis
        h = common.constrain(h, "model", None, None)

    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, p["wg"]))
    u = jnp.einsum("ecd,edf->ecf", h, p["wu"])
    o = jnp.einsum("ecf,efd->ecd", g * u, p["wd"])  # (E, C, D)
    if cfg.constrain_acts:
        o = common.constrain(o, "model", None, None)

    of = o.reshape(e * cap, d)
    contrib = of[jnp.minimum(slot, e * cap - 1)] * (sw * keep)[:, None].astype(x.dtype)
    y = jnp.zeros((n, d), x.dtype).at[st].add(contrib)
    return y.reshape(b, t, d), aux


# --------------------------------------------------------------------------
# The model
# --------------------------------------------------------------------------
class DecoderLM:
    """Covers dense (llama/qwen/yi/stablelm), MoE (qwen3-moe/dbrx) and VLM
    (qwen2-vl) families."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    # ---- definitions ---------------------------------------------------
    def _layer_defs(self):
        cfg = self.cfg
        d = {
            "ln1": _norm_defs(cfg),
            "attn": _attn_defs(cfg),
            "ln2": _norm_defs(cfg),
        }
        d["ffn"] = _moe_defs(cfg) if cfg.n_experts else _ffn_defs(cfg)
        return d

    @property
    def defs(self):
        cfg = self.cfg
        d: dict[str, Any] = {
            "embed": Param(
                (cfg.vocab, cfg.d_model), cfg.dtype, "normal_0.02",
                (None, "embed_shard"),
            ),
            "lm_head": Param(
                (cfg.d_model, cfg.vocab), cfg.dtype, "fan_in", ("embed", "vocab"),
            ),
            "ln_f": _norm_defs(cfg),
            "layers": _stack_defs(self._layer_defs(), cfg.n_layers),
        }
        if cfg.is_vlm:
            d["patch_proj"] = Param(
                (cfg.d_patch, cfg.d_model), cfg.dtype, "fan_in", (None, "embed"),
            )
        return d

    def init(self, key):
        return init_tree(self.defs, key)

    def specs(self):
        return spec_tree(self.defs)

    def pspecs(self, rules):
        return pspec_tree(self.defs, rules)

    # ---- blocks ----------------------------------------------------------
    def _block(self, p, x, positions):
        cfg = self.cfg
        h, _ = _attn_forward(cfg, p["attn"], _apply_norm(cfg, p["ln1"], x), positions)
        x = x + h
        normed = _apply_norm(cfg, p["ln2"], x)
        if cfg.n_experts:
            f, aux = _moe_forward(cfg, p["ffn"], normed)
        else:
            f, aux = _ffn_forward(p["ffn"], normed), jnp.zeros((), jnp.float32)
        return x + f, aux

    def _remat_block(self):
        cfg = self.cfg
        if cfg.remat == "none":
            return self._block
        policy = (
            jax.checkpoint_policies.nothing_saveable
            if cfg.remat == "full"
            else jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
        return jax.checkpoint(self._block, policy=policy)

    def _constrain(self, x):
        if self.cfg.constrain_acts:
            return common.constrain(x, self.cfg.constrain_acts, None, None)
        return x

    def _stack(self, params, x, positions):
        cfg = self.cfg
        block = self._remat_block()
        x = self._constrain(x)
        if cfg.scan_layers:
            def body(carry, layer_p):
                x, aux = carry
                x, a = block(layer_p, x, positions)
                return (self._constrain(x), aux + a), None

            (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                       params["layers"])
        else:
            aux = jnp.zeros((), jnp.float32)
            for i in range(cfg.n_layers):
                layer_p = jax.tree.map(lambda l: l[i], params["layers"])
                x, a = block(layer_p, x, positions)
                aux = aux + a
        return x, aux

    # ---- input assembly --------------------------------------------------
    def _embed_tokens(self, params, tokens):
        return jnp.take(params["embed"], tokens, axis=0)

    def _assemble(self, params, batch):
        """Returns (x, positions, text_start). For VLM, patch embeddings are
        prepended and M-RoPE position streams are built (t/h/w)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b, t = tokens.shape
        x = self._embed_tokens(params, tokens)
        if not cfg.is_vlm:
            pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
            return x, pos, 0
        patches = batch["patches"]  # (B, Np, d_patch)
        npatch = patches.shape[1]
        px = patches.astype(cfg.dtype) @ params["patch_proj"]
        x = jnp.concatenate([px, x], axis=1)
        # M-RoPE positions: patches form a sqrt grid at t=0; text advances t.
        side = max(1, int(np.sqrt(npatch)))
        grid_h = (np.arange(npatch) // side).astype(np.int32)
        grid_w = (np.arange(npatch) % side).astype(np.int32)
        text_pos = np.arange(t, dtype=np.int32) + int(grid_h.max()) + 1
        pos_t = np.concatenate([np.zeros(npatch, np.int32), text_pos])
        pos_h = np.concatenate([grid_h, text_pos])
        pos_w = np.concatenate([grid_w, text_pos])
        pos = jnp.broadcast_to(
            jnp.stack([jnp.asarray(pos_t), jnp.asarray(pos_h), jnp.asarray(pos_w)])[
                :, None, :
            ],
            (3, b, npatch + t),
        )
        return x, pos, npatch

    # ---- public API --------------------------------------------------------
    def loss(self, params, batch):
        """Next-token CE (+ MoE aux). batch: tokens (B,T), labels (B,T)
        [+ patches for VLM]."""
        cfg = self.cfg
        x, pos, text_start = self._assemble(params, batch)
        x, aux = self._stack(params, x, pos)
        x = _apply_norm(cfg, params["ln_f"], x)
        if text_start:
            x = x[:, text_start:]
        logits = x @ params["lm_head"]
        return common.cross_entropy(logits, batch["labels"]) + aux

    def prefill(self, params, batch, max_len: Optional[int] = None):
        """Full-sequence forward producing KV caches + last-position logits.

        ``max_len`` sizes the cache (room for decode_step growth); defaults
        to sequence length + 64."""
        cfg = self.cfg
        x, pos, text_start = self._assemble(params, batch)
        caches_k, caches_v = [], []

        def block_with_cache(p, x):
            h, (k, v) = _attn_forward(
                cfg, p["attn"], _apply_norm(cfg, p["ln1"], x), pos
            )
            x = x + h
            normed = _apply_norm(cfg, p["ln2"], x)
            if cfg.n_experts:
                f, _ = _moe_forward(cfg, p["ffn"], normed)
            else:
                f = _ffn_forward(p["ffn"], normed)
            return x + f, (k, v)

        if cfg.scan_layers:
            def body(x, layer_p):
                x, kv = block_with_cache(layer_p, x)
                return x, kv

            x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
        else:
            ks_l, vs_l = [], []
            for i in range(cfg.n_layers):
                layer_p = jax.tree.map(lambda l: l[i], params["layers"])
                x, (k, v) = block_with_cache(layer_p, x)
                ks_l.append(k)
                vs_l.append(v)
            ks, vs = jnp.stack(ks_l), jnp.stack(vs_l)

        x = _apply_norm(cfg, params["ln_f"], x)
        logits = x[:, -1:] @ params["lm_head"]
        t_total = x.shape[1]
        max_len = max_len or t_total + 64
        pad = max_len - t_total
        if pad > 0:
            ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        cache = {
            "k": ks,
            "v": vs,
            "len": jnp.asarray(t_total, jnp.int32),
        }
        if cfg.mrope_sections:
            # M-RoPE: the *position* stream advances past the max grid index,
            # not past the raw cache length.
            cache["pos_next"] = pos[0, 0, -1] + 1
        return logits, cache

    def decode_step(self, params, cache, tokens):
        """One token for every sequence. tokens: (B, 1)."""
        cfg = self.cfg
        b = tokens.shape[0]
        x = self._embed_tokens(params, tokens)
        clen = cache["len"]
        if cfg.mrope_sections:
            p_next = cache.get("pos_next", clen)
            pos = jnp.broadcast_to(p_next[None, None], (3, b, 1)).astype(jnp.int32)
        else:
            pos = jnp.broadcast_to(clen[None], (b, 1)).astype(jnp.int32)

        if cfg.kv_quant:
            def body_q(x, layer_in):
                layer_p, kq, vq, ks_, vs_ = layer_in
                h, new_kv = _attn_decode_quant(
                    cfg, layer_p["attn"], _apply_norm(cfg, layer_p["ln1"], x),
                    pos, kq, vq, ks_, vs_, clen,
                )
                x = x + h
                normed = _apply_norm(cfg, layer_p["ln2"], x)
                if cfg.n_experts:
                    f, _ = _moe_forward(cfg, layer_p["ffn"], normed)
                else:
                    f = _ffn_forward(layer_p["ffn"], normed)
                return x + f, new_kv

            x, (kq, vq, ks_, vs_) = jax.lax.scan(
                body_q, x,
                (params["layers"], cache["k_q"], cache["v_q"],
                 cache["k_s"], cache["v_s"]))
            x = _apply_norm(cfg, params["ln_f"], x)
            logits = x @ params["lm_head"]
            new_cache = {"k_q": kq, "v_q": vq, "k_s": ks_, "v_s": vs_,
                         "len": clen + 1}
            if cfg.mrope_sections:
                new_cache["pos_next"] = cache.get("pos_next", clen) + 1
            return logits, new_cache

        def body(x, layer_in):
            layer_p, k_cache, v_cache = layer_in
            h, k_new, v_new = _attn_decode(
                cfg, layer_p["attn"], _apply_norm(cfg, layer_p["ln1"], x),
                pos, k_cache, v_cache, clen,
            )
            x = x + h
            normed = _apply_norm(cfg, layer_p["ln2"], x)
            if cfg.n_experts:
                f, _ = _moe_forward(cfg, layer_p["ffn"], normed)
            else:
                f = _ffn_forward(layer_p["ffn"], normed)
            return x + f, (k_new, v_new)

        if cfg.scan_layers:
            x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
        else:
            ks_l, vs_l = [], []
            for i in range(cfg.n_layers):
                layer_p = jax.tree.map(lambda l: l[i], params["layers"])
                x, (k, v) = body(x, (layer_p, cache["k"][i], cache["v"][i]))
                ks_l.append(k)
                vs_l.append(v)
            ks, vs = jnp.stack(ks_l), jnp.stack(vs_l)

        x = _apply_norm(cfg, params["ln_f"], x)
        logits = x @ params["lm_head"]
        new_cache = {"k": ks, "v": vs, "len": clen + 1}
        if cfg.mrope_sections:
            new_cache["pos_next"] = cache.get("pos_next", clen) + 1
        return logits, new_cache

    # ---- cache specs (dry-run stand-ins) ---------------------------------
    def cache_specs(self, batch: int, max_len: int):
        cfg = self.cfg
        kv_shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
        if cfg.kv_quant:
            s_shape = kv_shape[:-1] + (1,)
            out = {
                "k_q": jax.ShapeDtypeStruct(kv_shape, jnp.int8),
                "v_q": jax.ShapeDtypeStruct(kv_shape, jnp.int8),
                "k_s": jax.ShapeDtypeStruct(s_shape, jnp.float32),
                "v_s": jax.ShapeDtypeStruct(s_shape, jnp.float32),
                "len": jax.ShapeDtypeStruct((), jnp.int32),
            }
        else:
            out = {
                "k": jax.ShapeDtypeStruct(kv_shape, cfg.dtype),
                "v": jax.ShapeDtypeStruct(kv_shape, cfg.dtype),
                "len": jax.ShapeDtypeStruct((), jnp.int32),
            }
        if cfg.mrope_sections:
            out["pos_next"] = jax.ShapeDtypeStruct((), jnp.int32)
        return out
