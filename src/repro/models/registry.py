"""Architecture registry: config -> model instance + dry-run input specs.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input of the given cell — weak-type-correct, shardable, and never
allocating device memory (the multi-pod dry-run contract). ``make_batch``
materializes small real batches for CPU smoke tests/examples.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, SHAPES, ShapeSpec
from repro.models.rglru import RecurrentGemma
from repro.models.rwkv6 import RWKV6
from repro.models.transformer import DecoderLM
from repro.models.whisper import Whisper

ARCH_REGISTRY = {
    "dense": DecoderLM,
    "moe": DecoderLM,
    "vlm": DecoderLM,
    "ssm": RWKV6,
    "hybrid": RecurrentGemma,
    "audio": Whisper,
}


def build_model(cfg: ArchConfig):
    return ARCH_REGISTRY[cfg.family](cfg)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def input_specs(cfg: ArchConfig, shape: ShapeSpec | str) -> dict[str, Any]:
    """Dry-run inputs for (arch, shape) — see DESIGN.md for cell semantics.

    train  : {tokens, labels [, frames/patches]}
    prefill: {tokens [, frames/patches]}
    decode : {tokens (B,1)} — KV/state cache specs come from
             ``model.cache_specs`` (see launch/dryrun.py).
    """
    if isinstance(shape, str):
        shape = SHAPES[shape]
    b, t = shape.global_batch, shape.seq_len
    itok = jnp.int32
    specs: dict[str, Any] = {}
    if shape.kind == "train":
        specs["tokens"] = _sds((b, t), itok)
        specs["labels"] = _sds((b, t), itok)
    elif shape.kind == "prefill":
        specs["tokens"] = _sds((b, t), itok)
    else:  # decode
        specs["tokens"] = _sds((b, 1), itok)
    if cfg.is_encdec and shape.kind != "decode":
        specs["frames"] = _sds((b, cfg.n_audio_ctx, cfg.d_model), cfg.dtype)
    if cfg.is_vlm and shape.kind != "decode":
        specs["patches"] = _sds((b, cfg.n_patches, cfg.d_patch), jnp.float32)
    return specs


def make_batch(cfg: ArchConfig, *, batch: int, seq: int, kind: str = "train",
               seed: int = 0) -> dict[str, Any]:
    """Small concrete batch for smoke tests — mirrors input_specs."""
    rng = np.random.default_rng(seed)
    out: dict[str, Any] = {}
    if kind == "decode":
        out["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (batch, 1)), jnp.int32)
    else:
        out["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (batch, seq)), jnp.int32)
        if kind == "train":
            out["labels"] = jnp.asarray(
                rng.integers(0, cfg.vocab, (batch, seq)), jnp.int32)
    if cfg.is_encdec and kind != "decode":
        out["frames"] = jnp.asarray(
            rng.normal(size=(batch, cfg.n_audio_ctx, cfg.d_model)), cfg.dtype)
    if cfg.is_vlm and kind != "decode":
        out["patches"] = jnp.asarray(
            rng.normal(size=(batch, cfg.n_patches, cfg.d_patch)), jnp.float32)
    return out
