"""Whisper encoder-decoder backbone [arXiv:2212.04356].

The conv frontend is a stub per the assignment: ``input_specs`` provides
precomputed mel-frame embeddings (B, n_audio_ctx, d_model) — everything after
the two stride-2 convs. Encoder: bidirectional pre-LN MHA with sinusoidal
positions. Decoder: causal self-attention + cross-attention to the encoder
output, learned positions.

train  : CE over decoder tokens given frames.
prefill: encode frames, run decoder prompt, build self-attn KV cache and the
         (static) cross-attn KV.
decode : single-token step against both caches.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import common
from repro.models.transformer import (
    _apply_norm,
    _norm_defs,
    _stack_defs,
)
from repro.nn.module import Param, init_tree, pspec_tree, spec_tree


def _mha_defs(cfg: ArchConfig):
    dm, hd, nh = cfg.d_model, cfg.head_dim, cfg.n_heads
    dt = cfg.dtype
    return {
        "wq": Param((dm, nh * hd), dt, "fan_in", ("embed", "heads")),
        "wk": Param((dm, nh * hd), dt, "fan_in", ("embed", "heads")),
        "wv": Param((dm, nh * hd), dt, "fan_in", ("embed", "heads")),
        "wo": Param((nh * hd, dm), dt, "fan_in", ("heads", "embed")),
        "bq": Param((nh * hd,), dt, "zeros", ("heads",)),
        "bv": Param((nh * hd,), dt, "zeros", ("heads",)),
        "bo": Param((dm,), dt, "zeros", (None,)),
    }


def _mha_project(cfg, p, xq, xkv):
    b, tq, _ = xq.shape
    tk = xkv.shape[1]
    nh, hd = cfg.n_heads, cfg.head_dim
    q = (xq @ p["wq"] + p["bq"]).reshape(b, tq, nh, hd)
    k = (xkv @ p["wk"]).reshape(b, tk, nh, hd)
    v = (xkv @ p["wv"] + p["bv"]).reshape(b, tk, nh, hd)
    return q, k, v


def _mha(cfg, p, xq, xkv, causal):
    b, tq, _ = xq.shape
    q, k, v = _mha_project(cfg, p, xq, xkv)
    o = common.attention(q, k, v, causal=causal)
    return o.reshape(b, tq, -1) @ p["wo"] + p["bo"], (k, v)


def _ffn_defs(cfg: ArchConfig):
    dm, df, dt = cfg.d_model, cfg.d_ff, cfg.dtype
    return {
        "w1": Param((dm, df), dt, "fan_in", ("embed", "mlp")),
        "b1": Param((df,), dt, "zeros", ("mlp",)),
        "w2": Param((df, dm), dt, "fan_in", ("mlp", "embed")),
        "b2": Param((dm,), dt, "zeros", (None,)),
    }


def _ffn(p, x):
    return jax.nn.gelu(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]


class Whisper:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    # ---- defs -----------------------------------------------------------
    def _enc_layer_defs(self):
        cfg = self.cfg
        return {
            "ln1": _norm_defs(cfg), "attn": _mha_defs(cfg),
            "ln2": _norm_defs(cfg), "ffn": _ffn_defs(cfg),
        }

    def _dec_layer_defs(self):
        cfg = self.cfg
        return {
            "ln1": _norm_defs(cfg), "self_attn": _mha_defs(cfg),
            "ln2": _norm_defs(cfg), "cross_attn": _mha_defs(cfg),
            "ln3": _norm_defs(cfg), "ffn": _ffn_defs(cfg),
        }

    @property
    def defs(self):
        cfg = self.cfg
        return {
            "embed": Param((cfg.vocab, cfg.d_model), cfg.dtype, "normal_0.02",
                           (None, "embed_shard")),
            # sized to cover the decode_32k cell (learned positions)
            "pos_dec": Param((32768 + 1024, cfg.d_model), cfg.dtype,
                             "normal_0.02", (None, None)),
            "enc_layers": _stack_defs(self._enc_layer_defs(), cfg.n_encoder_layers),
            "dec_layers": _stack_defs(self._dec_layer_defs(), cfg.n_layers),
            "ln_enc": _norm_defs(cfg),
            "ln_dec": _norm_defs(cfg),
        }

    def init(self, key):
        return init_tree(self.defs, key)

    def specs(self):
        return spec_tree(self.defs)

    def pspecs(self, rules):
        return pspec_tree(self.defs, rules)

    # ---- encoder ----------------------------------------------------------
    def encode(self, params, frames):
        """frames: (B, n_audio_ctx, d_model) stub embeddings."""
        cfg = self.cfg
        t = frames.shape[1]
        pos = jnp.asarray(common.sinusoidal_positions(t, cfg.d_model), cfg.dtype)
        x = frames.astype(cfg.dtype) + pos[None]

        def body(x, p):
            h, _ = _mha(cfg, p["attn"], _apply_norm(cfg, p["ln1"], x),
                        _apply_norm(cfg, p["ln1"], x), causal=False)
            x = x + h
            x = x + _ffn(p["ffn"], _apply_norm(cfg, p["ln2"], x))
            return x, None

        x, _ = jax.lax.scan(body, x, params["enc_layers"])
        return _apply_norm(cfg, params["ln_enc"], x)

    # ---- decoder ------------------------------------------------------------
    def _dec_block(self, p, x, enc, pos_offset=0):
        cfg = self.cfg
        h, self_kv = _mha(cfg, p["self_attn"], _apply_norm(cfg, p["ln1"], x),
                          _apply_norm(cfg, p["ln1"], x), causal=True)
        x = x + h
        h, cross_kv = _mha(cfg, p["cross_attn"], _apply_norm(cfg, p["ln2"], x),
                           enc, causal=False)
        x = x + h
        x = x + _ffn(p["ffn"], _apply_norm(cfg, p["ln3"], x))
        return x, (self_kv, cross_kv)

    def _decode_tokens(self, params, tokens, enc):
        cfg = self.cfg
        b, t = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0)
        x = x + params["pos_dec"][:t][None]

        block = self._dec_block
        if cfg.remat != "none":
            block = jax.checkpoint(
                block, policy=jax.checkpoint_policies.nothing_saveable)

        def body(x, p):
            x, _ = block(p, x, enc)
            return x, None

        x, _ = jax.lax.scan(body, x, params["dec_layers"])
        x = _apply_norm(cfg, params["ln_dec"], x)
        # tied output head (whisper ties embed <-> logits)
        return x @ params["embed"].T

    # ---- public ----------------------------------------------------------------
    def loss(self, params, batch):
        """batch: frames (B, n_ctx, d_model), tokens (B,T), labels (B,T)."""
        enc = self.encode(params, batch["frames"])
        logits = self._decode_tokens(params, batch["tokens"], enc)
        return common.cross_entropy(logits, batch["labels"])

    def prefill(self, params, batch, max_len=None):
        cfg = self.cfg
        enc = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        b, t = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0)
        x = x + params["pos_dec"][:t][None]

        def body(x, p):
            x, (self_kv, cross_kv) = self._dec_block(p, x, enc)
            return x, (self_kv, cross_kv)

        x, ((ks, vs), (cks, cvs)) = jax.lax.scan(body, x, params["dec_layers"])
        x = _apply_norm(cfg, params["ln_dec"], x)
        logits = x[:, -1:] @ params["embed"].T
        max_len = max_len or t + 64
        pad = max_len - t
        if pad > 0:
            ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        cache = {
            "k": ks, "v": vs, "ck": cks, "cv": cvs,
            "len": jnp.asarray(t, jnp.int32),
        }
        return logits, cache

    def decode_step(self, params, cache, tokens):
        cfg = self.cfg
        b = tokens.shape[0]
        clen = cache["len"]
        x = jnp.take(params["embed"], tokens, axis=0)
        pos_vec = jax.lax.dynamic_slice_in_dim(params["pos_dec"], clen, 1, axis=0)
        x = x + pos_vec[None]  # (1,1,D) -> broadcast over batch

        def body(x, inp):
            p, k_c, v_c, ck, cv = inp
            normed = _apply_norm(cfg, p["ln1"], x)
            q, k, v = _mha_project(cfg, p["self_attn"], normed, normed)
            k_c = jax.lax.dynamic_update_slice_in_dim(k_c, k, clen, axis=1)
            v_c = jax.lax.dynamic_update_slice_in_dim(v_c, v, clen, axis=1)
            o = common.decode_attention(q, k_c, v_c, clen + 1)
            x = x + o.reshape(b, 1, -1) @ p["self_attn"]["wo"] + p["self_attn"]["bo"]
            # cross attention against the precomputed encoder KV
            normed = _apply_norm(cfg, p["ln2"], x)
            nh, hd = cfg.n_heads, cfg.head_dim
            q = (normed @ p["cross_attn"]["wq"] + p["cross_attn"]["bq"]).reshape(
                b, 1, nh, hd)
            o = common.decode_attention(q, ck, cv, ck.shape[1])
            x = x + o.reshape(b, 1, -1) @ p["cross_attn"]["wo"] + p["cross_attn"]["bo"]
            x = x + _ffn(p["ffn"], _apply_norm(cfg, p["ln3"], x))
            return x, (k_c, v_c)

        x, (new_k, new_v) = jax.lax.scan(
            body, x,
            (params["dec_layers"], cache["k"], cache["v"], cache["ck"], cache["cv"]),
        )
        x = _apply_norm(cfg, params["ln_dec"], x)
        logits = x @ params["embed"].T
        return logits, {
            "k": new_k, "v": new_v, "ck": cache["ck"], "cv": cache["cv"],
            "len": clen + 1,
        }

    def cache_specs(self, batch: int, max_len: int):
        cfg = self.cfg
        l, nh, hd = cfg.n_layers, cfg.n_heads, cfg.head_dim
        return {
            "k": jax.ShapeDtypeStruct((l, batch, max_len, nh, hd), cfg.dtype),
            "v": jax.ShapeDtypeStruct((l, batch, max_len, nh, hd), cfg.dtype),
            "ck": jax.ShapeDtypeStruct((l, batch, cfg.n_audio_ctx, nh, hd), cfg.dtype),
            "cv": jax.ShapeDtypeStruct((l, batch, cfg.n_audio_ctx, nh, hd), cfg.dtype),
            "len": jax.ShapeDtypeStruct((), jnp.int32),
        }
