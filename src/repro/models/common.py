"""Shared model machinery: rotary embeddings (standard / partial / M-RoPE),
memory-efficient chunked attention (online softmax, GQA, causal + sliding
window), and small helpers.

Attention never materializes the full (T x T) score matrix: queries are
processed in chunks under ``jax.lax.scan`` with running (max, sum, acc)
statistics — the XLA-level equivalent of FlashAttention. The Pallas kernel in
``repro.kernels.flash_attention`` is the TPU hot path; this module is the
portable path used by CPU smoke tests and the multi-pod dry-run (Pallas does
not lower on the CPU backend), selected via ``use_kernels`` in the model
configs.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


# --------------------------------------------------------------------------
# Rotary position embeddings
# --------------------------------------------------------------------------
def rope_freqs(d_head: int, theta: float, rope_frac: float = 1.0) -> jax.Array:
    """Inverse frequencies for the rotated sub-dimension (d_rot = d*frac)."""
    d_rot = int(d_head * rope_frac)
    d_rot -= d_rot % 2
    return 1.0 / (
        theta ** (jnp.arange(0, d_rot, 2, dtype=jnp.float32) / d_rot)
    )


def apply_rope(
    x: jax.Array,  # (B, T, H, D)
    positions: jax.Array,  # (B, T) int32
    theta: float,
    rope_frac: float = 1.0,
) -> jax.Array:
    inv = rope_freqs(x.shape[-1], theta, rope_frac)
    ang = positions[..., None].astype(jnp.float32) * inv  # (B, T, D_rot/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    d_rot = 2 * inv.shape[0]
    x_rot, x_pass = x[..., :d_rot], x[..., d_rot:]
    x1, x2 = jnp.split(x_rot, 2, axis=-1)
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)
    return jnp.concatenate([out, x_pass], axis=-1) if x_pass.shape[-1] else out


def apply_mrope(
    x: jax.Array,  # (B, T, H, D)
    positions: jax.Array,  # (3, B, T) — temporal / height / width streams
    theta: float,
    sections: tuple[int, int, int],
) -> jax.Array:
    """Qwen2-VL multimodal RoPE: the head dim's rotary halves are split into
    3 sections, each rotated by its own position stream (t/h/w). For pure
    text, all three streams are equal and M-RoPE reduces to RoPE."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)  # (d/2,)
    # section s covers inv-freq slots [off_s, off_s + sections[s])
    sec = np.asarray(sections)
    assert sec.sum() == d // 2, (sections, d)
    sec_id = jnp.asarray(np.repeat(np.arange(3), sec))  # (d/2,)
    ang_all = positions[..., None].astype(jnp.float32) * inv  # (3, B, T, d/2)
    idx = jnp.broadcast_to(
        sec_id[None, None, None, :], (1,) + ang_all.shape[1:]
    )
    ang = jnp.take_along_axis(ang_all, idx, axis=0)[0]  # (B, T, d/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> np.ndarray:
    pos = np.arange(n)[:, None]
    dim = np.arange(0, d, 2)[None, :]
    ang = pos / (10000 ** (dim / d))
    out = np.zeros((n, d), np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return out


# --------------------------------------------------------------------------
# Attention
# --------------------------------------------------------------------------
def repeat_kv(x: jax.Array, n_rep: int) -> jax.Array:
    """(B, T, Hkv, D) -> (B, T, Hkv*n_rep, D)."""
    if n_rep == 1:
        return x
    b, t, h, d = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, t, h, n_rep, d)).reshape(
        b, t, h * n_rep, d
    )


def _direct_attention(q, k, v, *, causal, window, q_offset):
    b, tq, h, d = q.shape
    tk = k.shape[1]
    scale = 1.0 / np.sqrt(d)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    qpos = q_offset + jnp.arange(tq)[:, None]
    kpos = jnp.arange(tk)[None, :]
    mask = jnp.ones((tq, tk), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def _chunked_attention(q, k, v, *, causal, window, q_offset, q_chunk, k_chunk):
    """Online-softmax attention: scan over k-chunks inside a scan over
    q-chunks. Peak live memory O(q_chunk * k_chunk) per head."""
    b, tq, h, d = q.shape
    tk = k.shape[1]
    scale = 1.0 / np.sqrt(d)
    n_q = -(-tq // q_chunk)
    pad_q = n_q * q_chunk - tq
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    n_k = -(-tk // k_chunk)
    pad_k = n_k * k_chunk - tk
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))

    qs = q.reshape(b, n_q, q_chunk, h, d).transpose(1, 0, 3, 2, 4)  # (nq,B,H,qc,d)
    ks = k.reshape(b, n_k, k_chunk, h, d).transpose(1, 0, 3, 2, 4)
    vs = v.reshape(b, n_k, k_chunk, h, d).transpose(1, 0, 3, 2, 4)

    def q_body(_, qi_qc):
        qi, qc = qi_qc
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def k_body(carry, ki_kc_vc):
            ki, kc, vc = ki_kc_vc
            m, l, acc = carry
            k_pos = ki * k_chunk + jnp.arange(k_chunk)
            s = jnp.einsum("bhqd,bhkd->bhqk", qc, kc).astype(jnp.float32) * scale
            mask = k_pos[None, :] < tk  # k padding
            if causal:
                mask &= k_pos[None, :] <= q_pos[:, None]
            if window > 0:
                mask &= k_pos[None, :] > q_pos[:, None] - window
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(vc.dtype), vc
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((b, h, q_chunk), NEG_INF, jnp.float32),
            jnp.zeros((b, h, q_chunk), jnp.float32),
            jnp.zeros((b, h, q_chunk, d), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(
            k_body, init, (jnp.arange(n_k), ks, vs)
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_body, None, (jnp.arange(n_q), qs))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, n_q * q_chunk, h, d)
    return out[:, :tq]


def attention(
    q: jax.Array,  # (B, Tq, H, D)
    k: jax.Array,  # (B, Tk, Hkv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: jax.Array | int = 0,
    q_chunk: int = 512,
    k_chunk: int = 1024,
) -> jax.Array:
    """GQA attention; memory-efficient path for long sequences."""
    n_rep = q.shape[2] // k.shape[2]
    k = repeat_kv(k, n_rep)
    v = repeat_kv(v, n_rep)
    tq, tk = q.shape[1], k.shape[1]
    if tq * tk <= 4096 * 4096 and tq <= 4096:
        return _direct_attention(q, k, v, causal=causal, window=window,
                                 q_offset=q_offset)
    return _chunked_attention(q, k, v, causal=causal, window=window,
                              q_offset=q_offset, q_chunk=q_chunk, k_chunk=k_chunk)


def decode_attention(
    q: jax.Array,  # (B, 1, H, D)
    k_cache: jax.Array,  # (B, L, Hkv, D)
    v_cache: jax.Array,
    cache_len: jax.Array,  # scalar int — number of valid cache positions
    *,
    window: int = 0,
) -> jax.Array:
    """Single-token decode against a (possibly longer-than-valid) KV cache."""
    n_rep = q.shape[2] // k_cache.shape[2]
    k = repeat_kv(k_cache, n_rep)
    v = repeat_kv(v_cache, n_rep)
    d = q.shape[-1]
    scale = 1.0 / np.sqrt(d)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    kpos = jnp.arange(k.shape[1])
    mask = kpos < cache_len
    if window > 0:
        mask &= kpos > cache_len - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


def constrain(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint that no-ops outside a mesh context — the
    hillclimb lever for pinning activation layouts (EXPERIMENTS.md §Perf)."""
    try:
        from jax.sharding import PartitionSpec

        return jax.lax.with_sharding_constraint(x, PartitionSpec(*spec))
    except Exception:  # noqa: BLE001  # repro: allow[typed-errors] — no mesh (smoke tests) -> identity
        return x


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token CE. logits (B, T, V) possibly vocab-sharded."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)
