"""Patch-token block attention encoder/decoder (the second GBATC family).

The paper group's follow-up (arxiv 2409.05357) replaces the conv block
autoencoder with attention for better rate at the same bound; this module
is that encoder/decoder pair over the *same* block instances the conv AE
consumes: an (NB, S, bt, ph, pw) block flattens to ``S * bt`` patch
tokens of dimension ``ph * pw`` (one token per species per frame of the
block), a dense projection + sinusoidal positions lifts them to
``d_model``, ``depth`` pre-norm non-causal transformer blocks (multi-head
attention + SwiGLU FFN, the :mod:`repro.models.transformer` idioms) mix
them, and one FC maps the flattened token grid to the shared 36-dim
latent. The decoder mirrors exactly (its own projection, blocks, and
un-embed), so the codec stores decoder-side parameters only, like the
conv family.

Everything downstream is family-agnostic: ``fit`` trains through the same
compiled :class:`repro.train.train_loop.MiniBatchTrainer`, the unchanged
``GuaranteeEngine`` bounds whatever this decoder reconstructs, and the
fused decode builder in :mod:`repro.codec.families` consumes the same
``decode(params, z) -> (NB, S, bt, ph, pw)`` contract. ``attn_impl``
selects the attention path: ``"direct"`` (default) runs
:func:`repro.models.common.attention`; ``"flash"`` routes through the
Pallas :func:`repro.kernels.flash_attention.flash_attention` kernel
(interpret mode off-TPU), retained bit-comparable for accelerator runs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import common
from repro.nn.module import Param, init_tree
from repro.train import train_loop


@dataclasses.dataclass(frozen=True)
class BlockAttentionConfig:
    n_species: int
    block: tuple[int, int, int]  # (bt, ph, pw)
    latent: int = 36
    d_model: int = 32
    n_heads: int = 2
    depth: int = 1
    mlp_hidden: int = 64
    dtype: Any = jnp.float32
    attn_impl: str = "direct"  # "direct" | "flash" (Pallas kernel)

    def __post_init__(self):
        if self.d_model % self.n_heads:
            raise ValueError(
                f"d_model {self.d_model} not divisible by n_heads "
                f"{self.n_heads}"
            )

    @property
    def n_tokens(self) -> int:
        return self.n_species * self.block[0]

    @property
    def token_dim(self) -> int:
        return self.block[1] * self.block[2]

    @property
    def arch(self) -> tuple[int, int, int, int]:
        """The wire arch words (see ``codec.families``): the four u16
        fields that, with geometry/latent, fully rebuild this config."""
        return (self.d_model, self.n_heads, self.depth, self.mlp_hidden)


def _attn_defs(cfg: BlockAttentionConfig):
    dm, dt = cfg.d_model, cfg.dtype
    return {
        "wq": Param((dm, dm), dt, "fan_in", ("embed", "heads")),
        "wk": Param((dm, dm), dt, "fan_in", ("embed", "heads")),
        "wv": Param((dm, dm), dt, "fan_in", ("embed", "heads")),
        "wo": Param((dm, dm), dt, "fan_in", ("heads", "embed")),
    }


def _ffn_defs(cfg: BlockAttentionConfig):
    dm, df, dt = cfg.d_model, cfg.mlp_hidden, cfg.dtype
    return {
        "wg": Param((dm, df), dt, "fan_in", ("embed", "mlp")),
        "wu": Param((dm, df), dt, "fan_in", ("embed", "mlp")),
        "wd": Param((df, dm), dt, "fan_in", ("mlp", "embed")),
    }


def _norm_defs(cfg: BlockAttentionConfig):
    return {"scale": Param((cfg.d_model,), jnp.float32, "ones", (None,))}


def _block_defs(cfg: BlockAttentionConfig):
    return {
        "ln1": _norm_defs(cfg),
        "attn": _attn_defs(cfg),
        "ln2": _norm_defs(cfg),
        "ffn": _ffn_defs(cfg),
    }


def _rms_norm(p, x, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(ms + eps) * p["scale"]).astype(x.dtype)


class BlockAttentionAE:
    """Encoder/decoder over (NB, S, bt, ph, pw) blocks; same contract as
    :class:`repro.core.autoencoder.BlockAutoencoder` (``encode``,
    ``decode``, ``defs`` with ``enc``/``dec`` key prefixes, ``init``)."""

    def __init__(self, cfg: BlockAttentionConfig):
        self.cfg = cfg
        # fixed (not learned) positions: the token grid is static per
        # structural config, so they need no bytes on the wire
        self._pos = jnp.asarray(
            common.sinusoidal_positions(cfg.n_tokens, cfg.d_model)
        )
        self._trainers: dict[tuple, train_loop.MiniBatchTrainer] = {}

    # ---- definition tree ------------------------------------------------
    @property
    def defs(self):
        cfg = self.cfg
        dm, td, nt = cfg.d_model, cfg.token_dim, cfg.n_tokens
        d: dict = {
            "enc_proj": {"w": Param((td, dm), cfg.dtype, "fan_in"),
                         "b": Param((dm,), cfg.dtype, "zeros")},
            "enc_head": {"w": Param((nt * dm, cfg.latent), cfg.dtype,
                                    "fan_in"),
                         "b": Param((cfg.latent,), cfg.dtype, "zeros")},
            "enc_norm": _norm_defs(cfg),
            "dec_proj": {"w": Param((cfg.latent, nt * dm), cfg.dtype,
                                    "fan_in"),
                         "b": Param((nt * dm,), cfg.dtype, "zeros")},
            "dec_head": {"w": Param((dm, td), cfg.dtype, "fan_in"),
                         "b": Param((td,), cfg.dtype, "zeros")},
            "dec_norm": _norm_defs(cfg),
        }
        for i in range(cfg.depth):
            d[f"enc_block{i}"] = _block_defs(cfg)
            d[f"dec_block{i}"] = _block_defs(cfg)
        return d

    def init(self, key):
        return init_tree(self.defs, key)

    # ---- forward ---------------------------------------------------------
    def _tokens(self, x):
        # (NB, S, bt, ph, pw) -> (NB, S*bt, ph*pw) patch tokens
        nb = x.shape[0]
        return x.reshape(nb, self.cfg.n_tokens, self.cfg.token_dim)

    def _attention(self, p, x):
        cfg = self.cfg
        b, t, _ = x.shape
        hd = cfg.d_model // cfg.n_heads
        q = (x @ p["wq"]).reshape(b, t, cfg.n_heads, hd)
        k = (x @ p["wk"]).reshape(b, t, cfg.n_heads, hd)
        v = (x @ p["wv"]).reshape(b, t, cfg.n_heads, hd)
        if cfg.attn_impl == "flash":
            from repro.kernels import flash_attention as fa

            o = fa.flash_attention(
                q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                v.transpose(0, 2, 1, 3), causal=False,
                interpret=jax.default_backend() != "tpu",
            ).transpose(0, 2, 1, 3)
        else:
            o = common.attention(q, k, v, causal=False)
        return o.reshape(b, t, -1) @ p["wo"]

    def _block(self, p, x):
        x = x + self._attention(p["attn"], _rms_norm(p["ln1"], x))
        h = _rms_norm(p["ln2"], x)
        return x + (jax.nn.silu(h @ p["ffn"]["wg"])
                    * (h @ p["ffn"]["wu"])) @ p["ffn"]["wd"]

    def encode(self, params, x):
        cfg = self.cfg
        h = self._tokens(x) @ params["enc_proj"]["w"] \
            + params["enc_proj"]["b"] + self._pos
        for i in range(cfg.depth):
            h = self._block(params[f"enc_block{i}"], h)
        h = _rms_norm(params["enc_norm"], h)
        h = h.reshape(h.shape[0], -1)
        return h @ params["enc_head"]["w"] + params["enc_head"]["b"]

    def decode(self, params, z):
        cfg = self.cfg
        s, (bt, ph, pw) = cfg.n_species, cfg.block
        h = z @ params["dec_proj"]["w"] + params["dec_proj"]["b"]
        h = h.reshape(-1, cfg.n_tokens, cfg.d_model) + self._pos
        for i in range(cfg.depth):
            h = self._block(params[f"dec_block{i}"], h)
        h = _rms_norm(params["dec_norm"], h)
        h = h @ params["dec_head"]["w"] + params["dec_head"]["b"]
        return h.reshape(-1, s, bt, ph, pw)

    def __call__(self, params, x):
        return self.decode(params, self.encode(params, x))

    def decoder_param_bytes(self, params) -> int:
        dec = {k: v for k, v in params.items() if k.startswith("dec")}
        return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(dec))


def _loss(model: BlockAttentionAE):
    def loss_fn(p, batch):
        rec = model(p, batch)
        return jnp.mean(jnp.square(rec - batch))

    return loss_fn


def fit(
    model: BlockAttentionAE,
    blocks: np.ndarray,
    *,
    steps: int = 400,
    batch_size: int = 64,
    lr: float = 1e-3,
    seed: int = 0,
    log_every: int = 0,
    mode: Optional[str] = None,
    mesh=None,
) -> tuple[Any, np.ndarray]:
    """Train with AdamW on MSE through the compiled mini-batch engine —
    the exact :func:`repro.core.autoencoder.fit` contract, so the
    pipeline's family handle can call either interchangeably. Returns
    (params, loss_history); the engine is cached on the model, so
    refitting never re-traces."""
    params = model.init(jax.random.PRNGKey(seed))
    key = (lr, steps, mode)
    trainer = model._trainers.get(key)
    if trainer is None:
        trainer = train_loop.MiniBatchTrainer(
            _loss(model),
            train_loop.adamw_cfg(lr, steps),
            mode=mode,
            log_fn=lambda t, loss: print(f"[attn] step {t} loss {loss:.3e}"),
        )
        model._trainers[key] = trainer
    return trainer.fit(
        params, (blocks,), steps=steps, batch_size=batch_size, seed=seed,
        log_every=log_every, mesh=mesh,
    )
