"""RecurrentGemma / Griffin [arXiv:2402.19427] hybrid model.

26 residual blocks, pattern (recurrent, recurrent, attention) — attention
every 3rd block (local sliding-window MQA, window 2048). Recurrent block:
two input branches (GeLU gate | conv1d(4) -> RG-LRU), elementwise product,
output projection. RG-LRU:

  r_t = sigmoid(W_a x_t + b_a)          # recurrence gate
  i_t = sigmoid(W_x x_t + b_x)          # input gate
  a_t = exp(-c * softplus(L) * r_t)     # data-dependent decay, c = 8
  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The diagonal linear recurrence is evaluated with ``jax.lax.associative_scan``
(log-depth parallel prefix) in sequence mode — this is what keeps the
long_500k cell sub-quadratic and scan-parallel — and as a single fused step
in decode mode. The MLP is GeGLU.

Layer stacking: scan over 8 stacked (rec, rec, attn) periods + an unrolled
(rec, rec) tail = 26 blocks.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import common
from repro.models.transformer import (
    _apply_norm,
    _attn_defs,
    _attn_forward,
    _norm_defs,
    _project_qkv,
    _rope_qk,
    _stack_defs,
)
from repro.nn.module import Param, init_tree, pspec_tree, spec_tree

_C = 8.0  # Griffin's fixed decay sharpness


def _lru_init(key, shape, dtype):
    # Lambda initialized so a = sigma(L)^c spreads over (0.9, 0.999)
    u = jax.random.uniform(key, shape, jnp.float32, 0.9, 0.999)
    a = u ** (1.0 / _C)
    return jnp.log(a / (1.0 - a)).astype(dtype)


def _rec_defs(cfg: ArchConfig):
    d, w, dt = cfg.d_model, cfg.rglru_width or cfg.d_model, cfg.dtype
    cw = cfg.conv1d_width
    return {
        "w_gate": Param((d, w), dt, "fan_in", ("embed", "mlp")),
        "w_in": Param((d, w), dt, "fan_in", ("embed", "mlp")),
        "conv_w": Param((cw, w), dt, "fan_in", (None, "mlp")),
        "conv_b": Param((w,), dt, "zeros", ("mlp",)),
        "lru_lambda": Param((w,), jnp.float32, _lru_init, ("mlp",)),
        "wa": Param((w, w), dt, "fan_in", ("mlp", None)),
        "ba": Param((w,), jnp.float32, "zeros", ("mlp",)),
        "wx": Param((w, w), dt, "fan_in", ("mlp", None)),
        "bx": Param((w,), jnp.float32, "zeros", ("mlp",)),
        "w_out": Param((w, d), dt, "fan_in", ("mlp", "embed")),
    }


def _mlp_defs(cfg: ArchConfig):
    d, f, dt = cfg.d_model, cfg.d_ff, cfg.dtype
    return {
        "wg": Param((d, f), dt, "fan_in", ("embed", "mlp")),
        "wu": Param((d, f), dt, "fan_in", ("embed", "mlp")),
        "wd": Param((f, d), dt, "fan_in", ("mlp", "embed")),
    }


def _geglu(p, x):
    return (jax.nn.gelu(x @ p["wg"]) * (x @ p["wu"])) @ p["wd"]


def _rglru_seq(p, x, h0):
    """x: (B, T, W) gated input; h0: (B, W). Associative scan over time."""
    r = jax.nn.sigmoid((x @ p["wa"]).astype(jnp.float32) + p["ba"])
    i = jax.nn.sigmoid((x @ p["wx"]).astype(jnp.float32) + p["bx"])
    log_a = -_C * jax.nn.softplus(p["lru_lambda"]) * r  # (B,T,W) <= 0
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-12)) * i * x.astype(jnp.float32)

    # h_t = a_t h_{t-1} + g_t  -> parallel prefix over (a, g)
    def combine(lhs, rhs):
        a_l, g_l = lhs
        a_r, g_r = rhs
        return a_l * a_r, g_l * a_r + g_r

    a_seq, g_seq = jax.lax.associative_scan(combine, (a, gated), axis=1)
    h = g_seq + a_seq * h0[:, None, :]
    return h.astype(x.dtype), h[:, -1, :]


def _conv1d_seq(p, x, tail):
    """Causal depthwise conv, width cw. tail: (B, cw-1, W) left context."""
    cw = p["conv_w"].shape[0]
    xx = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    out = sum(
        xx[:, i : i + x.shape[1], :] * p["conv_w"][i] for i in range(cw)
    )
    return out + p["conv_b"], xx[:, -(cw - 1) :, :]


def _rec_block_seq(p, x, state):
    """state: {h: (B,W), conv: (B,cw-1,W)}."""
    gate = jax.nn.gelu(x @ p["w_gate"])
    u = x @ p["w_in"]
    u, conv_tail = _conv1d_seq(p, u, state["conv"])
    h, h_last = _rglru_seq(p, u, state["h"])
    out = (gate * h) @ p["w_out"]
    return out, {"h": h_last.astype(jnp.float32), "conv": conv_tail}


def _rec_block_step(p, x, state):
    """Single-token decode step. x: (B, 1, D)."""
    gate = jax.nn.gelu(x @ p["w_gate"])
    u = x @ p["w_in"]
    cw = p["conv_w"].shape[0]
    xx = jnp.concatenate([state["conv"].astype(x.dtype), u], axis=1)  # (B,cw,W)
    u = sum(xx[:, i : i + 1, :] * p["conv_w"][i] for i in range(cw)) + p["conv_b"]
    r = jax.nn.sigmoid((u @ p["wa"]).astype(jnp.float32) + p["ba"])
    i = jax.nn.sigmoid((u @ p["wx"]).astype(jnp.float32) + p["bx"])
    a = jnp.exp(-_C * jax.nn.softplus(p["lru_lambda"]) * r)
    h = a[:, 0] * state["h"] + (
        jnp.sqrt(jnp.clip(1.0 - a * a, 1e-12)) * i * u.astype(jnp.float32)
    )[:, 0]
    out = (gate * h[:, None, :].astype(x.dtype)) @ p["w_out"]
    return out, {"h": h, "conv": xx[:, 1:, :]}


class RecurrentGemma:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        assert cfg.attn_period == 3
        self.n_periods = cfg.n_layers // 3  # full (rec, rec, attn) periods
        self.n_tail = cfg.n_layers - 3 * self.n_periods  # trailing rec blocks

    # ---- defs ---------------------------------------------------------
    def _period_defs(self):
        cfg = self.cfg
        return {
            "ln_r1": _norm_defs(cfg),
            "rec1": _rec_defs(cfg),
            "ln_m1": _norm_defs(cfg),
            "mlp1": _mlp_defs(cfg),
            "ln_r2": _norm_defs(cfg),
            "rec2": _rec_defs(cfg),
            "ln_m2": _norm_defs(cfg),
            "mlp2": _mlp_defs(cfg),
            "ln_a": _norm_defs(cfg),
            "attn": _attn_defs(cfg),
            "ln_m3": _norm_defs(cfg),
            "mlp3": _mlp_defs(cfg),
        }

    def _tail_defs(self):
        cfg = self.cfg
        d = {}
        for i in range(self.n_tail):
            d[f"ln_r{i}"] = _norm_defs(cfg)
            d[f"rec{i}"] = _rec_defs(cfg)
            d[f"ln_m{i}"] = _norm_defs(cfg)
            d[f"mlp{i}"] = _mlp_defs(cfg)
        return d

    @property
    def defs(self):
        cfg = self.cfg
        d: dict[str, Any] = {
            "embed": Param((cfg.vocab, cfg.d_model), cfg.dtype, "normal_0.02",
                           (None, "embed_shard")),
            "ln_f": _norm_defs(cfg),
            "lm_head": Param((cfg.d_model, cfg.vocab), cfg.dtype, "fan_in",
                             ("embed", "vocab")),
            "periods": _stack_defs(self._period_defs(), self.n_periods),
        }
        if self.n_tail:
            d["tail"] = self._tail_defs()
        return d

    def init(self, key):
        return init_tree(self.defs, key)

    def specs(self):
        return spec_tree(self.defs)

    def pspecs(self, rules):
        return pspec_tree(self.defs, rules)

    # ---- state --------------------------------------------------------
    def _zero_rec_state(self, b):
        cfg = self.cfg
        w = cfg.rglru_width or cfg.d_model
        return {
            "h": jnp.zeros((b, w), jnp.float32),
            "conv": jnp.zeros((b, cfg.conv1d_width - 1, w), cfg.dtype),
        }

    # ---- sequence mode (train / prefill) --------------------------------
    def _period_seq(self, p, x, positions, st, collect_kv):
        cfg = self.cfg
        h, st1 = _rec_block_seq(p["rec1"], _apply_norm(cfg, p["ln_r1"], x), st["r1"])
        x = x + h
        x = x + _geglu(p["mlp1"], _apply_norm(cfg, p["ln_m1"], x))
        h, st2 = _rec_block_seq(p["rec2"], _apply_norm(cfg, p["ln_r2"], x), st["r2"])
        x = x + h
        x = x + _geglu(p["mlp2"], _apply_norm(cfg, p["ln_m2"], x))
        h, kv = _attn_forward(cfg, p["attn"], _apply_norm(cfg, p["ln_a"], x),
                              positions)
        x = x + h
        x = x + _geglu(p["mlp3"], _apply_norm(cfg, p["ln_m3"], x))
        new_st = {"r1": st1, "r2": st2}
        return x, new_st, (kv if collect_kv else None)

    def _stack_seq(self, params, x, positions, collect_kv=False):
        cfg = self.cfg
        b = x.shape[0]
        period = self._period_seq
        if cfg.remat != "none":
            period = jax.checkpoint(
                period, policy=jax.checkpoint_policies.nothing_saveable,
                static_argnums=(4,),
            )
        st0 = jax.tree.map(
            lambda z: jnp.broadcast_to(z, (self.n_periods,) + z.shape),
            {"r1": self._zero_rec_state(b), "r2": self._zero_rec_state(b)},
        )

        def body(x, inp):
            p, st = inp
            x, _, kv = period(p, x, positions, st, collect_kv)
            return x, kv

        x, kvs = jax.lax.scan(body, x, (params["periods"], st0))
        for i in range(self.n_tail):
            tp = params["tail"]
            h, _ = _rec_block_seq(
                tp[f"rec{i}"], _apply_norm(cfg, tp[f"ln_r{i}"], x),
                self._zero_rec_state(b),
            )
            x = x + h
            x = x + _geglu(tp[f"mlp{i}"], _apply_norm(cfg, tp[f"ln_m{i}"], x))
        return x, kvs

    # ---- public -----------------------------------------------------------
    def loss(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        b, t = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0)
        pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
        x, _ = self._stack_seq(params, x, pos)
        x = _apply_norm(cfg, params["ln_f"], x)
        logits = x @ params["lm_head"]
        return common.cross_entropy(logits, batch["labels"])

    def prefill(self, params, batch, max_len=None):
        """Prefill keeping only the last `window` KV entries + rec states.
        (max_len ignored — the KV ring buffer is window-bounded.)"""
        del max_len
        cfg = self.cfg
        tokens = batch["tokens"]
        b, t = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0)
        pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))

        # sequence pass, collecting rec states + windowed KV
        st0 = {"r1": self._zero_rec_state(b), "r2": self._zero_rec_state(b)}
        sts, kvs_k, kvs_v, tail_sts = [], [], [], {}
        xcur = x
        win = cfg.window
        for i in range(self.n_periods):
            p = jax.tree.map(lambda l: l[i], params["periods"])
            xcur, st, kv = self._period_seq(p, xcur, pos, st0, True)
            sts.append(st)
            k, v = kv
            if t >= win:
                # ring-buffer alignment: position p lives at slot p % window
                k_w = jnp.roll(k[:, -win:], t % win, axis=1)
                v_w = jnp.roll(v[:, -win:], t % win, axis=1)
            else:
                k_w = jnp.pad(k, ((0, 0), (0, win - t), (0, 0), (0, 0)))
                v_w = jnp.pad(v, ((0, 0), (0, win - t), (0, 0), (0, 0)))
            kvs_k.append(k_w)
            kvs_v.append(v_w)
        for i in range(self.n_tail):
            tp = params["tail"]
            h, st = _rec_block_seq(
                tp[f"rec{i}"], _apply_norm(cfg, tp[f"ln_r{i}"], xcur),
                self._zero_rec_state(b),
            )
            xcur = xcur + h
            xcur = xcur + _geglu(tp[f"mlp{i}"], _apply_norm(cfg, tp[f"ln_m{i}"], xcur))
            tail_sts[f"t{i}"] = st
        xcur = _apply_norm(cfg, params["ln_f"], xcur)
        logits = xcur[:, -1:] @ params["lm_head"]
        cache = {
            "periods": jax.tree.map(lambda *z: jnp.stack(z), *sts),
            "tail": tail_sts,
            "k": jnp.stack(kvs_k),
            "v": jnp.stack(kvs_v),
            "len": jnp.asarray(t, jnp.int32),
        }
        return logits, cache

    def decode_step(self, params, cache, tokens):
        cfg = self.cfg
        b = tokens.shape[0]
        x = jnp.take(params["embed"], tokens, axis=0)
        clen = cache["len"]
        pos = jnp.broadcast_to(clen[None], (b, 1)).astype(jnp.int32)
        # ring-buffer write position within the window cache
        wpos = jnp.mod(clen, cfg.window)

        def period_step(x, inp):
            p, st, k_cache, v_cache = inp
            h, st1 = _rec_block_step(p["rec1"], _apply_norm(cfg, p["ln_r1"], x),
                                     st["r1"])
            x = x + h
            x = x + _geglu(p["mlp1"], _apply_norm(cfg, p["ln_m1"], x))
            h, st2 = _rec_block_step(p["rec2"], _apply_norm(cfg, p["ln_r2"], x),
                                     st["r2"])
            x = x + h
            x = x + _geglu(p["mlp2"], _apply_norm(cfg, p["ln_m2"], x))
            # local attention against the ring buffer
            from repro.models.transformer import _project_qkv, _rope_qk

            q, k, v = _project_qkv(
                cfg, p["attn"], _apply_norm(cfg, p["ln_a"], x)
            )
            q, k = _rope_qk(cfg, q, k, pos)
            k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, wpos, axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, wpos, axis=1)
            valid = jnp.minimum(clen + 1, cfg.window)
            o = common.decode_attention(q, k_cache, v_cache, valid)
            x = x + o.reshape(b, 1, -1) @ p["attn"]["wo"]
            x = x + _geglu(p["mlp3"], _apply_norm(cfg, p["ln_m3"], x))
            return x, ({"r1": st1, "r2": st2}, k_cache, v_cache)

        x, (new_sts, new_k, new_v) = jax.lax.scan(
            period_step, x,
            (params["periods"], cache["periods"], cache["k"], cache["v"]),
        )
        new_tail = {}
        for i in range(self.n_tail):
            tp = params["tail"]
            h, st = _rec_block_step(
                tp[f"rec{i}"], _apply_norm(cfg, tp[f"ln_r{i}"], x),
                cache["tail"][f"t{i}"],
            )
            x = x + h
            x = x + _geglu(tp[f"mlp{i}"], _apply_norm(cfg, tp[f"ln_m{i}"], x))
            new_tail[f"t{i}"] = st
        x = _apply_norm(cfg, params["ln_f"], x)
        logits = x @ params["lm_head"]
        return logits, {
            "periods": new_sts, "tail": new_tail,
            "k": new_k, "v": new_v, "len": clen + 1,
        }

    def cache_specs(self, batch: int, max_len: int):
        """KV is window-bounded; recurrent state O(1) — the long_500k story."""
        cfg = self.cfg
        w = cfg.rglru_width or cfg.d_model
        npd = self.n_periods
        win = min(cfg.window, max_len)
        rec = {
            "h": jax.ShapeDtypeStruct((npd, batch, w), jnp.float32),
            "conv": jax.ShapeDtypeStruct(
                (npd, batch, cfg.conv1d_width - 1, w), cfg.dtype),
        }
        tail_rec = {
            "h": jax.ShapeDtypeStruct((batch, w), jnp.float32),
            "conv": jax.ShapeDtypeStruct(
                (batch, cfg.conv1d_width - 1, w), cfg.dtype),
        }
        return {
            "periods": {"r1": rec, "r2": dict(rec)},
            "tail": {f"t{i}": dict(tail_rec) for i in range(self.n_tail)},
            "k": jax.ShapeDtypeStruct(
                (npd, batch, win, cfg.n_kv_heads, cfg.head_dim), cfg.dtype),
            "v": jax.ShapeDtypeStruct(
                (npd, batch, win, cfg.n_kv_heads, cfg.head_dim), cfg.dtype),
            "len": jax.ShapeDtypeStruct((), jnp.int32),
        }
