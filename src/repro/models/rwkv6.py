"""RWKV-6 "Finch" [arXiv:2404.05892] — attention-free LM with data-dependent
per-channel decay.

Per layer:
  TimeMix: token-shift with data-dependent lerp (ddlerp, LoRA-parameterized),
    per-channel decay w_t = exp(-exp(w0 + LoRA_w)), bonus u ("time_faaaa");
    per head (dim N): o_t = r_t^T (S_{t-1} + (u*k_t) v_t^T),
                      S_t = diag(w_t) S_{t-1} + k_t v_t^T;
    GroupNorm over heads, SiLU(g) gate, output projection.
  ChannelMix: token-shift, k = relu(W_k x)^2, out = sigmoid(W_r x) * (W_v k).

Training path runs the recurrence with ``jax.lax.scan`` over time carrying
(B, H, N, N) state (the Pallas chunked kernel is the TPU hot path — see
repro/kernels/rwkv6_scan.py). Decode carries the state explicitly: O(1) per
token, which is what makes the long_500k cell runnable.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import common
from repro.nn.module import Param, init_tree, pspec_tree, spec_tree
from repro.models.transformer import _stack_defs


def _time_mix_defs(cfg: ArchConfig):
    d, dt = cfg.d_model, cfg.dtype
    lm, ld = cfg.rwkv_lora_mix, cfg.rwkv_lora_decay
    nh = d // cfg.rwkv_head_dim
    return {
        "mu_base": Param((d,), jnp.float32, "zeros", (None,)),
        # ddlerp LoRA: 5 channels (w,k,v,r,g) share A, per-channel B
        "lora_a": Param((d, 5 * lm), dt, "fan_in", ("embed", None)),
        "lora_b": Param((5, lm, d), dt, "zeros", (None, None, "embed")),
        "mu_wkvrg": Param((5, d), jnp.float32, "zeros", (None, None)),
        "decay_base": Param((d,), jnp.float32, "zeros", (None,)),
        "decay_a": Param((d, ld), dt, "fan_in", ("embed", None)),
        "decay_b": Param((ld, d), dt, "zeros", (None, "embed")),
        "bonus": Param((nh, cfg.rwkv_head_dim), jnp.float32, "zeros", ("heads", None)),
        "wr": Param((d, d), dt, "fan_in", ("embed", "heads")),
        "wk": Param((d, d), dt, "fan_in", ("embed", "heads")),
        "wv": Param((d, d), dt, "fan_in", ("embed", "heads")),
        "wg": Param((d, d), dt, "fan_in", ("embed", "heads")),
        "wo": Param((d, d), dt, "fan_in", ("heads", "embed")),
        "gn_scale": Param((d,), jnp.float32, "ones", (None,)),
        "gn_bias": Param((d,), jnp.float32, "zeros", (None,)),
    }


def _channel_mix_defs(cfg: ArchConfig):
    d, f, dt = cfg.d_model, cfg.d_ff, cfg.dtype
    return {
        "mu_k": Param((d,), jnp.float32, "zeros", (None,)),
        "mu_r": Param((d,), jnp.float32, "zeros", (None,)),
        "wk": Param((d, f), dt, "fan_in", ("embed", "mlp")),
        "wv": Param((f, d), dt, "fan_in", ("mlp", "embed")),
        "wr": Param((d, d), dt, "fan_in", ("embed", None)),
    }


def _ln_defs(d):
    return {
        "scale": Param((d,), jnp.float32, "ones", (None,)),
        "bias": Param((d,), jnp.float32, "zeros", (None,)),
    }


def _layer_norm(p, x, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = x32.mean(-1, keepdims=True)
    var = jnp.square(x32 - mu).mean(-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]).astype(
        x.dtype
    )


def _group_norm(scale, bias, x, nh, eps=1e-5):
    """LayerNorm per head over the flattened (H*N) feature dim."""
    b, t, d = x.shape
    xh = x.reshape(b, t, nh, d // nh).astype(jnp.float32)
    mu = xh.mean(-1, keepdims=True)
    var = jnp.square(xh - mu).mean(-1, keepdims=True)
    xh = (xh - mu) * jax.lax.rsqrt(var + eps)
    return (xh.reshape(b, t, d) * scale + bias).astype(x.dtype)


def _token_shift(x, last):
    """Shifted sequence: position t sees x_{t-1}; position 0 sees `last`."""
    return jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)


def _ddlerp(p, x, xs):
    """Data-dependent lerp producing the 5 mixed inputs (w,k,v,r,g)."""
    delta = (xs - x).astype(jnp.float32)
    x_base = x.astype(jnp.float32) + delta * p["mu_base"]
    lora = jnp.tanh(x_base.astype(x.dtype) @ p["lora_a"])  # (B,T,5*lm)
    b, t, _ = x.shape
    lora = lora.reshape(b, t, 5, -1)
    adj = jnp.einsum("btcl,cld->btcd", lora, p["lora_b"]).astype(jnp.float32)
    mix = p["mu_wkvrg"][None, None] + adj  # (B,T,5,D)
    out = x.astype(jnp.float32)[:, :, None, :] + delta[:, :, None, :] * mix
    return [out[:, :, i, :].astype(x.dtype) for i in range(5)]


def wkv6_scan(r, k, v, w, u):
    """Reference WKV6 recurrence via lax.scan over time.

    r,k,v,w: (B, T, H, N); u: (H, N). Returns (out (B,T,H,N), final state
    (B,H,N,N)). State S maps k-space -> v-space.
    """
    b, t, h, n = r.shape

    def step(s, inp):
        rt, kt, vt, wt = inp  # (B,H,N)
        kv = kt[..., :, None] * vt[..., None, :]  # (B,H,N,N)
        out = jnp.einsum("bhi,bhij->bhj", rt, s + u[None, :, :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, out

    s0 = jnp.zeros((b, h, n, n), jnp.float32)
    xs = (
        r.transpose(1, 0, 2, 3).astype(jnp.float32),
        k.transpose(1, 0, 2, 3).astype(jnp.float32),
        v.transpose(1, 0, 2, 3).astype(jnp.float32),
        w.transpose(1, 0, 2, 3).astype(jnp.float32),
    )
    s, outs = jax.lax.scan(step, s0, xs)
    return outs.transpose(1, 0, 2, 3), s


class RWKV6:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        assert cfg.d_model % cfg.rwkv_head_dim == 0
        self.n_heads = cfg.d_model // cfg.rwkv_head_dim

    def _layer_defs(self):
        cfg = self.cfg
        return {
            "ln1": _ln_defs(cfg.d_model),
            "tm": _time_mix_defs(cfg),
            "ln2": _ln_defs(cfg.d_model),
            "cm": _channel_mix_defs(cfg),
        }

    @property
    def defs(self):
        cfg = self.cfg
        return {
            "embed": Param((cfg.vocab, cfg.d_model), cfg.dtype, "normal_0.02",
                           (None, "embed_shard")),
            "ln_in": _ln_defs(cfg.d_model),
            "ln_f": _ln_defs(cfg.d_model),
            "lm_head": Param((cfg.d_model, cfg.vocab), cfg.dtype, "fan_in",
                             ("embed", "vocab")),
            "layers": _stack_defs(self._layer_defs(), cfg.n_layers),
        }

    def init(self, key):
        return init_tree(self.defs, key)

    def specs(self):
        return spec_tree(self.defs)

    def pspecs(self, rules):
        return pspec_tree(self.defs, rules)

    # ---- time mix ---------------------------------------------------------
    def _time_mix_seq(self, p, x, last_x, state):
        """Sequence form. x: (B,T,D); last_x: (B,D); state: (B,H,N,N)."""
        cfg = self.cfg
        b, t, d = x.shape
        nh, hn = self.n_heads, cfg.rwkv_head_dim
        xs = _token_shift(x, last_x)
        xw, xk, xv, xr, xg = _ddlerp(p, x, xs)
        decay_adj = jnp.tanh(xw @ p["decay_a"]) @ p["decay_b"]
        w = jnp.exp(-jnp.exp(
            (p["decay_base"] + decay_adj.astype(jnp.float32)).clip(-18.0, 6.0)
        ))  # (B,T,D) in (0,1)
        r = (xr @ p["wr"]).reshape(b, t, nh, hn)
        k = (xk @ p["wk"]).reshape(b, t, nh, hn)
        v = (xv @ p["wv"]).reshape(b, t, nh, hn)
        g = jax.nn.silu(xg @ p["wg"])
        wh = w.reshape(b, t, nh, hn)
        if state is None:
            state = jnp.zeros((b, nh, hn, hn), jnp.float32)
        out, state = self._wkv(r, k, v, wh, p["bonus"].astype(jnp.float32), state)
        out = _group_norm(p["gn_scale"], p["gn_bias"],
                          out.reshape(b, t, d).astype(x.dtype), nh)
        return (out * g) @ p["wo"], x[:, -1, :], state

    def _wkv(self, r, k, v, w, u, s0):
        b, t, h, n = r.shape

        def step(s, inp):
            rt, kt, vt, wt = inp
            kv = kt[..., :, None] * vt[..., None, :]
            out = jnp.einsum("bhi,bhij->bhj", rt, s + u[None, :, :, None] * kv)
            s = wt[..., :, None] * s + kv
            return s, out

        xs = tuple(
            a.transpose(1, 0, 2, 3).astype(jnp.float32) for a in (r, k, v, w)
        )
        s, outs = jax.lax.scan(step, s0, xs)
        return outs.transpose(1, 0, 2, 3), s

    # ---- channel mix -------------------------------------------------------
    def _channel_mix(self, p, x, last_x):
        xs = _token_shift(x, last_x)
        delta = (xs - x).astype(jnp.float32)
        xk = (x.astype(jnp.float32) + delta * p["mu_k"]).astype(x.dtype)
        xr = (x.astype(jnp.float32) + delta * p["mu_r"]).astype(x.dtype)
        k = jnp.square(jax.nn.relu(xk @ p["wk"]))
        return jax.nn.sigmoid(xr @ p["wr"]) * (k @ p["wv"]), x[:, -1, :]

    # ---- full model ---------------------------------------------------------
    def _block_seq(self, p, x, state):
        """state: dict(tm_x (B,D), cm_x (B,D), s (B,H,N,N))."""
        h, tm_x, s = self._time_mix_seq(
            p["tm"], _layer_norm(p["ln1"], x), state["tm_x"], state["s"]
        )
        x = x + h
        h, cm_x = self._channel_mix(p["cm"], _layer_norm(p["ln2"], x), state["cm_x"])
        x = x + h
        return x, {"tm_x": tm_x, "cm_x": cm_x, "s": s}

    def _zero_state(self, b):
        cfg = self.cfg
        return {
            "tm_x": jnp.zeros((b, cfg.d_model), cfg.dtype),
            "cm_x": jnp.zeros((b, cfg.d_model), cfg.dtype),
            "s": jnp.zeros((b, self.n_heads, cfg.rwkv_head_dim, cfg.rwkv_head_dim),
                           jnp.float32),
        }

    def _stack(self, params, x, states=None, collect=False):
        cfg = self.cfg
        b = x.shape[0]
        block = self._block_seq
        if cfg.remat != "none":
            block = jax.checkpoint(block,
                                   policy=jax.checkpoint_policies.nothing_saveable)
        if states is None:
            states = jax.tree.map(
                lambda z: jnp.broadcast_to(z, (cfg.n_layers,) + z.shape),
                self._zero_state(b),
            )
        if cfg.scan_layers:
            def body(x, inp):
                layer_p, st = inp
                x, st_new = block(layer_p, x, st)
                return x, st_new

            x, new_states = jax.lax.scan(body, x, (params["layers"], states))
        else:
            outs = []
            for i in range(cfg.n_layers):
                layer_p = jax.tree.map(lambda l: l[i], params["layers"])
                st = jax.tree.map(lambda s: s[i], states)
                x, st_new = block(layer_p, x, st)
                outs.append(st_new)
            new_states = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        return x, new_states

    def loss(self, params, batch):
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
        x = _layer_norm(params["ln_in"], x)
        x, _ = self._stack(params, x)
        x = _layer_norm(params["ln_f"], x)
        logits = x @ params["lm_head"]
        return common.cross_entropy(logits, batch["labels"])

    def prefill(self, params, batch, max_len=None):
        del max_len  # recurrent state is O(1); nothing to size
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
        x = _layer_norm(params["ln_in"], x)
        x, states = self._stack(params, x)
        x = _layer_norm(params["ln_f"], x)
        logits = x[:, -1:] @ params["lm_head"]
        states["len"] = jnp.asarray(batch["tokens"].shape[1], jnp.int32)
        return logits, states

    def decode_step(self, params, state, tokens):
        """tokens (B,1); state from prefill (or cache_specs zeros)."""
        clen = state["len"]
        inner = {k: state[k] for k in ("tm_x", "cm_x", "s")}
        x = jnp.take(params["embed"], tokens, axis=0)
        x = _layer_norm(params["ln_in"], x)
        x, new_states = self._stack(params, x, states=inner)
        x = _layer_norm(params["ln_f"], x)
        logits = x @ params["lm_head"]
        new_states["len"] = clen + 1
        return logits, new_states

    def cache_specs(self, batch: int, max_len: int):
        """Recurrent state is O(1) in sequence length — the whole point."""
        cfg = self.cfg
        l = cfg.n_layers
        return {
            "tm_x": jax.ShapeDtypeStruct((l, batch, cfg.d_model), cfg.dtype),
            "cm_x": jax.ShapeDtypeStruct((l, batch, cfg.d_model), cfg.dtype),
            "s": jax.ShapeDtypeStruct(
                (l, batch, self.n_heads, cfg.rwkv_head_dim, cfg.rwkv_head_dim),
                jnp.float32,
            ),
            "len": jax.ShapeDtypeStruct((), jnp.int32),
        }
