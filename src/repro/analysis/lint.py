"""AST lint tier: run the repo-specific rules over a package tree.

:func:`lint_tree` walks every ``*.py`` under a package root, parses it
once, runs the per-file rules (:data:`repro.analysis.rules.FILE_RULES`)
and the cross-file rules (reference-pairing needs the whole tree plus
the test corpus), and filters findings through the inline
``# repro: allow[rule]`` suppressions. Baseline filtering is the
caller's job (:mod:`repro.analysis.__main__`) so tests can assert on raw
rule output.
"""

from __future__ import annotations

import ast
import os
from dataclasses import dataclass, field

from repro.analysis import rules as rules_pkg
from repro.analysis.findings import Finding, scan_suppressions


@dataclass
class LintResult:
    findings: list = field(default_factory=list)
    suppressed: list = field(default_factory=list)
    files_scanned: int = 0
    parse_errors: list = field(default_factory=list)


def _iter_sources(root):
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = sorted(
            d for d in dirnames if d != "__pycache__"
        )
        for name in sorted(filenames):
            if name.endswith(".py"):
                full = os.path.join(dirpath, name)
                rel = os.path.relpath(full, root).replace(os.sep, "/")
                yield rel, full


def lint_tree(src_root, tests_root=None, *,
              file_rules=rules_pkg.FILE_RULES,
              tree_rules=rules_pkg.TREE_RULES) -> LintResult:
    """Lint the package at *src_root*; rel paths in findings are relative
    to it (e.g. ``codec/decode.py``). *tests_root* feeds the cross-file
    reference-pairing rule; ``None`` skips tree rules entirely (fixture
    runs)."""
    result = LintResult()
    parsed = []  # (relpath, tree, suppressions)
    for rel, full in _iter_sources(src_root):
        with open(full, encoding="utf-8") as fh:
            source = fh.read()
        result.files_scanned += 1
        try:
            tree = ast.parse(source, filename=full)
        except SyntaxError as e:
            result.parse_errors.append(
                Finding("parse-error", rel, e.lineno or 0, str(e))
            )
            continue
        supp = scan_suppressions(source)
        parsed.append((rel, tree, supp))
        for rule in file_rules:
            for f in rule.check_file(rel, tree, source):
                (result.suppressed if supp.allows(f.rule, f.line)
                 else result.findings).append(f)

    if tests_root is not None and tree_rules:
        test_sources = []
        for _, full in _iter_sources(tests_root):
            with open(full, encoding="utf-8") as fh:
                test_sources.append(fh.read())
        supp_by_path = {rel: supp for rel, _, supp in parsed}
        files = [(rel, tree) for rel, tree, _ in parsed]
        for rule in tree_rules:
            for f in rule.check_tree(files, test_sources):
                supp = supp_by_path.get(f.path)
                (result.suppressed if supp and supp.allows(f.rule, f.line)
                 else result.findings).append(f)

    result.findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return result
