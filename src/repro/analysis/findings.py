"""Structured findings, inline suppression tags, and the baseline file.

Every analyzer in :mod:`repro.analysis` reports through one currency — a
:class:`Finding` naming the rule that fired, where, and why. Two escape
hatches keep the gate honest without blocking deliberate exceptions:

* an inline ``# repro: allow[rule]`` tag on the offending line (or
  ``# repro: allow-file[rule]`` anywhere in the file for a file-wide
  waiver) suppresses at the source, next to a comment saying why;
* a checked-in baseline (``analysis/baseline.json``) grandfathers
  findings by ``(rule, path, detail)`` — line numbers are deliberately
  ignored so unrelated edits above a baselined site don't resurrect it.

The CLI exits nonzero on any finding that is neither tagged nor
baselined. Stale baseline entries (nothing matches them any more) are
reported as warnings, not failures, so fixes don't require a lockstep
baseline edit.
"""

from __future__ import annotations

import json
import re
from dataclasses import dataclass, field

_ALLOW_LINE = re.compile(r"#\s*repro:\s*allow\[([a-z0-9_,-]+)\]")
_ALLOW_FILE = re.compile(r"#\s*repro:\s*allow-file\[([a-z0-9_,-]+)\]")


@dataclass(frozen=True)
class Finding:
    """One invariant violation: which rule, where, and what it saw."""

    rule: str
    path: str
    line: int
    detail: str

    def key(self) -> tuple[str, str, str]:
        """Baseline identity — line numbers intentionally excluded."""
        return (self.rule, self.path, self.detail)

    def __str__(self) -> str:  # CLI display form
        return f"{self.path}:{self.line}: [{self.rule}] {self.detail}"


@dataclass
class Suppressions:
    """Inline allow tags scanned from one source file."""

    line_rules: dict[int, frozenset] = field(default_factory=dict)
    file_rules: frozenset = frozenset()

    def allows(self, rule: str, line: int) -> bool:
        if rule in self.file_rules or "*" in self.file_rules:
            return True
        rules = self.line_rules.get(line, frozenset())
        return rule in rules or "*" in rules


def scan_suppressions(source: str) -> Suppressions:
    """Collect ``# repro: allow[...]`` / ``allow-file[...]`` tags.

    A line tag covers its own physical line; rule names may be
    comma-separated (``allow[wire-centralization,typed-errors]``).
    """
    line_rules: dict[int, frozenset] = {}
    file_rules: set = set()
    for lineno, text in enumerate(source.splitlines(), start=1):
        m = _ALLOW_FILE.search(text)
        if m:
            file_rules.update(r.strip() for r in m.group(1).split(","))
            continue
        m = _ALLOW_LINE.search(text)
        if m:
            rules = frozenset(r.strip() for r in m.group(1).split(","))
            line_rules[lineno] = line_rules.get(lineno, frozenset()) | rules
    return Suppressions(line_rules=line_rules, file_rules=frozenset(file_rules))


def load_baseline(path) -> list[dict]:
    """Read a baseline file -> list of {rule, path, detail} records."""
    try:
        with open(path, encoding="utf-8") as fh:
            data = json.load(fh)
    except FileNotFoundError:
        return []
    if not isinstance(data, dict) or not isinstance(data.get("findings"), list):
        raise ValueError(f"malformed baseline file {path!r}")
    return data["findings"]


def save_baseline(path, findings) -> None:
    records = sorted(
        ({"rule": f.rule, "path": f.path, "detail": f.detail} for f in findings),
        key=lambda r: (r["rule"], r["path"], r["detail"]),
    )
    with open(path, "w", encoding="utf-8") as fh:
        json.dump({"findings": records}, fh, indent=2, sort_keys=True)
        fh.write("\n")


def apply_baseline(findings, baseline_records):
    """Split findings into (new, baselined) and report stale entries.

    Returns ``(new, baselined, stale)`` where *stale* is the subset of
    baseline records matching no current finding.
    """
    keys = {(r["rule"], r["path"], r["detail"]) for r in baseline_records}
    new, baselined = [], []
    matched: set = set()
    for f in findings:
        if f.key() in keys:
            baselined.append(f)
            matched.add(f.key())
        else:
            new.append(f)
    stale = [r for r in baseline_records
             if (r["rule"], r["path"], r["detail"]) not in matched]
    return new, baselined, stale
