"""Trace-time program auditor: jaxpr walks over the registered hot paths.

The codec's throughput story rests on properties no AST lint can see —
they only exist after tracing. This tier builds each registered hot
program at a tiny representative shape, traces it, and walks the
resulting (closed) jaxprs:

* **fp64 promotion**: any equation producing a float64 output is a
  finding unless the program is allowlisted (the GBATC guarantee kernels
  legitimately compute their error bounds in f64 under interpret mode);
* **host callbacks**: ``debug_callback``/``pure_callback``/
  ``io_callback`` equations are findings everywhere except the trainer's
  ``log_every`` path, which may contain ``debug_callback`` only;
* **d2h transfers**: ``device_put``/``infeed``/``outfeed`` mid-program;
* **large folded constants**: a closed-over ndarray constant > 1 MiB
  means tracing captured data that should have been an argument;
* **undonated carries**: the trainer programs must donate
  ``(params, state)`` — checked via the ``tf.aliasing_output`` /
  ``jax.buffer_donor`` markers in the lowered StableHLO text;
* **collectives where expected**: the mesh DP trainer program must
  contain cross-device collectives (psum/all_gather/...) exactly when
  the mesh spans more than one device — a 1-device mesh program with
  collectives would break the P=1 bit-identity gate, a multi-device one
  without them silently trains on per-shard gradients. The per-shard
  guarantee kernels must stay collective-free (shards are independent
  by construction). Runs under ``REPRO_HOST_DEVICES=8`` CI, both sides
  of the expectation are exercised;
* **retrace counting**: each cached program must trace exactly once
  across representative call patterns (two ``fit`` calls per mode —
  including the mesh DP mode and the sharded guarantee engine's chunk
  dispatches — and repeated fused decode) — asserted with a tracing
  counter and ``jit``'s ``_cache_size``.

Setup guard: the audit requires the default f32 world — it refuses to
run (and reports) if ``jax_enable_x64`` is globally enabled, and
verifies the flag is still off afterwards (the repo only ever enables
x64 in *scoped* ``jax.experimental.enable_x64`` contexts).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import numpy as np

from repro.analysis.findings import Finding

RULE = "jaxpr-audit"

_CALLBACK_PRIMS = frozenset({
    "debug_callback", "pure_callback", "io_callback", "callback",
})
_TRANSFER_PRIMS = frozenset({"device_put", "infeed", "outfeed"})
# prefix-matched: shard_map/jit lowerings have spelled these psum /
# psum_invariant / all_gather(_invariant) across jax versions
_COLLECTIVE_PREFIXES = (
    "psum", "pmean", "all_gather", "all_reduce", "reduce_scatter",
    "all_to_all", "ppermute",
)
_LARGE_CONST_BYTES = 1 << 20
# single-device lowering resolves donation to tf.aliasing_output at
# lowering time; multi-device (mesh) lowering defers aliasing to compile
# and marks the donated inputs jax.buffer_donor instead — either proves
# the carries are donated
_DONATION_MARKERS = ("tf.aliasing_output", "jax.buffer_donor")


@dataclasses.dataclass
class ProgramStats:
    """What the walk saw in one program."""

    n_eqns: int = 0
    callbacks: dict = dataclasses.field(default_factory=dict)
    transfers: int = 0
    f64_eqns: int = 0
    const_bytes: int = 0
    collectives: int = 0
    donated: Optional[bool] = None


@dataclasses.dataclass
class AuditReport:
    findings: list = dataclasses.field(default_factory=list)
    programs: dict = dataclasses.field(default_factory=dict)
    wall_clock_s: float = 0.0


def _walk_jaxpr(jaxpr, stats: ProgramStats) -> None:
    """Recursively walk a Jaxpr's equations, descending into sub-jaxprs
    carried in equation params (scan/cond/pjit bodies, pallas grids)."""
    for eqn in jaxpr.eqns:
        stats.n_eqns += 1
        name = eqn.primitive.name
        if name in _CALLBACK_PRIMS:
            stats.callbacks[name] = stats.callbacks.get(name, 0) + 1
        if name in _TRANSFER_PRIMS:
            stats.transfers += 1
        if name.startswith(_COLLECTIVE_PREFIXES):
            stats.collectives += 1
        for var in eqn.outvars:
            aval = getattr(var, "aval", None)
            dtype = getattr(aval, "dtype", None)
            if dtype is not None and dtype == np.float64:
                stats.f64_eqns += 1
                break
        for param in eqn.params.values():
            for sub in _sub_jaxprs(param):
                _walk_jaxpr(sub, stats)


def _sub_jaxprs(param):
    items = param if isinstance(param, (list, tuple)) else [param]
    for item in items:
        inner = getattr(item, "jaxpr", None)
        if inner is not None and hasattr(inner, "eqns"):
            yield inner  # ClosedJaxpr -> Jaxpr
        elif hasattr(item, "eqns"):
            yield item


def _const_bytes(closed) -> int:
    total = 0
    for c in getattr(closed, "consts", ()):
        if hasattr(c, "nbytes"):
            total += int(c.nbytes)
    return total


@dataclasses.dataclass
class ProgramSpec:
    """One registered hot program.

    ``build()`` returns ``(fn, args)`` traced as ``fn(*args)``.
    ``lowered()`` (optional) returns StableHLO text for the donation
    check. ``allow_f64`` exempts the program from the fp64-promotion
    finding; ``allow_debug_callback`` permits ``debug_callback`` (the
    sanctioned ``log_every`` primitive) but nothing else.
    """

    name: str
    build: Callable[[], tuple]
    lowered: Optional[Callable[[], str]] = None
    allow_f64: bool = False
    allow_debug_callback: bool = False
    check_donation: bool = False
    # True: cross-device collectives REQUIRED; False: collectives
    # FORBIDDEN; None: not checked
    expect_collectives: Optional[bool] = None


def _audit_program(spec: ProgramSpec, report: AuditReport) -> None:
    import jax

    fn, args = spec.build()
    closed = jax.make_jaxpr(fn)(*args)
    stats = ProgramStats()
    _walk_jaxpr(closed.jaxpr, stats)
    stats.const_bytes = _const_bytes(closed)
    report.programs[spec.name] = stats
    here = "analysis/jaxpr_audit.py"

    for prim, count in sorted(stats.callbacks.items()):
        if prim == "debug_callback" and spec.allow_debug_callback:
            continue
        report.findings.append(Finding(
            RULE, here, 0,
            f"program {spec.name!r} contains {count}x host callback "
            f"{prim!r}",
        ))
    if stats.transfers:
        report.findings.append(Finding(
            RULE, here, 0,
            f"program {spec.name!r} contains {stats.transfers} mid-program "
            f"device transfer(s)",
        ))
    if stats.f64_eqns and not spec.allow_f64:
        report.findings.append(Finding(
            RULE, here, 0,
            f"program {spec.name!r} promotes to float64 in "
            f"{stats.f64_eqns} equation(s) outside the guarantee-math "
            f"allowlist",
        ))
    if stats.const_bytes > _LARGE_CONST_BYTES:
        report.findings.append(Finding(
            RULE, here, 0,
            f"program {spec.name!r} folds {stats.const_bytes} bytes of "
            f"constants into the trace (> {_LARGE_CONST_BYTES})",
        ))
    if spec.expect_collectives is True and stats.collectives == 0:
        report.findings.append(Finding(
            RULE, here, 0,
            f"program {spec.name!r} contains no cross-device collectives "
            f"but the mesh spans multiple devices — shards would train "
            f"on unexchanged gradients",
        ))
    if spec.expect_collectives is False and stats.collectives:
        report.findings.append(Finding(
            RULE, here, 0,
            f"program {spec.name!r} contains {stats.collectives} "
            f"collective(s) but must be device-independent",
        ))
    if spec.check_donation and spec.lowered is not None:
        text = spec.lowered()
        stats.donated = any(m in text for m in _DONATION_MARKERS)
        if not stats.donated:
            report.findings.append(Finding(
                RULE, here, 0,
                f"program {spec.name!r} does not donate its carries "
                f"(none of {_DONATION_MARKERS} in lowered text)",
            ))


# --------------------------------------------------------------------------
# registered hot programs


def _tiny_trainer():
    """A MiniBatchTrainer over the real BlockAutoencoder loss at a tiny
    shape, with a tracing counter wrapped around the loss."""
    import jax

    from repro.core import autoencoder as ae
    from repro.train import train_loop

    model = ae.BlockAutoencoder(ae.AEConfig(
        n_species=2, block=(2, 4, 4), latent=8, conv_channels=(4,),
    ))
    params = model.init(jax.random.PRNGKey(0))
    base_loss = ae._ae_loss(model)
    traces = {"n": 0}

    def loss_fn(p, batch):
        traces["n"] += 1
        return base_loss(p, batch)

    blocks = np.asarray(
        jax.random.normal(jax.random.PRNGKey(1), (32, 2, 2, 4, 4)),
        dtype=np.float32,
    )
    return model, params, blocks, loss_fn, traces, train_loop


def _program_specs() -> list:
    import jax

    from repro.core import correction
    from repro.train import train_loop

    model, params, blocks, loss_fn, _, _ = _tiny_trainer()
    ocfg = train_loop.adamw_cfg(1e-3, 8)

    specs = []

    # trainer stream step (per-step dispatch mode)
    tr_stream = train_loop.MiniBatchTrainer(loss_fn, ocfg, mode="stream")
    from repro.train import optimizer as opt

    state = opt.init_state(params)
    idx = np.zeros(8, dtype=np.int32)
    step = tr_stream._stream_step()
    specs.append(ProgramSpec(
        name="trainer_stream_step",
        build=lambda: (step, (params, state, idx, blocks)),
        lowered=lambda: step.lower(params, state, idx, blocks).as_text(),
        check_donation=True,
    ))

    # trainer scan program, hot configuration: log_every=0 -> zero
    # callbacks of any kind
    tr_scan = train_loop.MiniBatchTrainer(loss_fn, ocfg, mode="scan")
    run0 = tr_scan._scan_program(8, 32, 8, 0)
    bkey = train_loop.batch_key(0)
    specs.append(ProgramSpec(
        name="trainer_scan_hot",
        build=lambda: (run0, (params, state, bkey, blocks)),
        lowered=lambda: run0.lower(params, state, bkey, blocks).as_text(),
        check_donation=True,
    ))

    # trainer scan program with log_every: debug_callback only
    run_log = tr_scan._scan_program(8, 32, 8, 4)
    specs.append(ProgramSpec(
        name="trainer_scan_log_every",
        build=lambda: (run_log, (params, state, bkey, blocks)),
        allow_debug_callback=True,
    ))

    # mesh DP trainer programs: collectives present exactly when the mesh
    # spans >1 device (REPRO_HOST_DEVICES=8 CI exercises the multi-device
    # side), carries donated, no mid-program transfers. The quantized
    # variant trades the psum for all_gather of int8 payload + scales.
    from repro.parallel import mesh_fit

    mesh = mesh_fit.host_mesh()
    n_p = mesh_fit.mesh_size(mesh)
    tr_mesh = train_loop.MiniBatchTrainer(loss_fn, ocfg, mode="scan")
    run_mesh = tr_mesh._mesh_program(8, 32, 8, 0, mesh, False, 1)
    specs.append(ProgramSpec(
        name="trainer_mesh_dp",
        build=lambda: (run_mesh, (params, state, bkey, blocks)),
        lowered=lambda: run_mesh.lower(params, state, bkey, blocks).as_text(),
        check_donation=True,
        expect_collectives=(n_p > 1),
    ))
    run_mesh_q = tr_mesh._mesh_program(8, 32, 8, 0, mesh, True, 1)
    specs.append(ProgramSpec(
        name="trainer_mesh_dp_quantized",
        build=lambda: (run_mesh_q, (params, state, bkey, blocks)),
        lowered=lambda: run_mesh_q.lower(
            params, state, bkey, blocks).as_text(),
        check_donation=True,
        expect_collectives=(n_p > 1),
    ))

    # fused decode, with and without the correction network
    from repro.codec import runtime as rt_mod

    lat32 = np.zeros((16, model.cfg.latent), dtype=np.float32)
    fused_plain = rt_mod.make_fused_decode(model, None)
    specs.append(ProgramSpec(
        name="fused_decode",
        build=lambda: (fused_plain, (params, None, lat32)),
    ))
    corr_net = correction.TensorCorrectionNetwork(
        correction.CorrectionConfig(n_species=model.cfg.n_species)
    )
    corr_params = corr_net.init(jax.random.PRNGKey(2))
    fused_corr = rt_mod.make_fused_decode(model, corr_net)
    specs.append(ProgramSpec(
        name="fused_decode_corrected",
        build=lambda: (fused_corr, (params, corr_params, lat32)),
    ))

    # attention-family fused decode: the registry seam must hold the same
    # contract (no callbacks, no d2h) for the second family
    from repro.models import block_attention as ba

    attn_model = ba.BlockAttentionAE(ba.BlockAttentionConfig(
        n_species=model.cfg.n_species, block=(2, 4, 4),
        latent=model.cfg.latent, d_model=8, n_heads=2, depth=1,
        mlp_hidden=16,
    ))
    attn_params = attn_model.init(jax.random.PRNGKey(3))
    fused_attn = rt_mod.make_fused_decode(attn_model, None)
    specs.append(ProgramSpec(
        name="fused_decode_attention",
        build=lambda: (fused_attn, (attn_params, None, lat32)),
    ))

    # GBATC Pallas kernels (interpret mode — the correctness path on CPU);
    # guarantee math legitimately runs f64 here
    from functools import partial

    from repro.kernels import gbatc_project as gk

    s, nb, d = 2, 8, 32
    residual = np.zeros((s, nb, d), dtype=np.float64)
    basis = np.tile(np.eye(d, dtype=np.float64), (s, 1, 1))
    specs.append(ProgramSpec(
        name="gbatc_project_batched",
        build=lambda: (partial(gk.gbatc_project_batched, interpret=True),
                       (residual, basis)),
        allow_f64=True,
    ))
    coeffs = np.zeros((s, nb, d), dtype=np.float64)
    specs.append(ProgramSpec(
        name="gbatc_correct_batched",
        build=lambda: (partial(gk.gbatc_correct_batched, interpret=True),
                       (residual, coeffs, basis)),
        allow_f64=True,
    ))
    rank = np.zeros((s, nb, d), dtype=np.int32)
    m = np.zeros((s, nb), dtype=np.int32)
    specs.append(ProgramSpec(
        name="gbatc_select_accumulate",
        build=lambda: (partial(gk.gbatc_select_accumulate, interpret=True),
                       (residual, coeffs, rank, m, basis)),
        allow_f64=True,
    ))

    # the sharded guarantee engine's per-shard programs: the same batched
    # kernels at a species/row chunk shape — they must stay collective-free
    # (shards are independent; their concatenated outputs ARE the batched
    # result, which is what makes the sharded container byte-identical)
    specs.append(ProgramSpec(
        name="gbatc_project_shard",
        build=lambda: (partial(gk.gbatc_project_batched, interpret=True),
                       (residual[:1], basis[:1])),
        allow_f64=True,
        expect_collectives=False,
    ))
    specs.append(ProgramSpec(
        name="gbatc_select_accumulate_shard",
        build=lambda: (partial(gk.gbatc_select_accumulate, interpret=True),
                       (residual[:1], coeffs[:1], rank[:1], m[:1],
                        basis[:1])),
        allow_f64=True,
        expect_collectives=False,
    ))
    return specs


def _audit_retrace(report: AuditReport) -> None:
    """Each cached program traces exactly once across representative call
    patterns: two same-shape ``fit`` calls per mode must trace the loss
    once per distinct program, and the jit caches must hold one entry."""
    here = "analysis/jaxpr_audit.py"
    model, params, blocks, loss_fn, traces, train_loop = _tiny_trainer()
    ocfg = train_loop.adamw_cfg(1e-3, 4)

    for mode, expected in (("stream", 1), ("scan", 1)):
        traces["n"] = 0
        tr = train_loop.MiniBatchTrainer(loss_fn, ocfg, mode=mode)
        tr.fit(params, (blocks,), steps=4, batch_size=8, seed=0)
        tr.fit(params, (blocks,), steps=4, batch_size=8, seed=1)
        if traces["n"] != expected:
            report.findings.append(Finding(
                RULE, here, 0,
                f"trainer mode {mode!r} traced the loss {traces['n']}x "
                f"across two same-shape fits (expected {expected})",
            ))
        for key, prog in tr._programs.items():
            size = getattr(prog, "_cache_size", lambda: None)()
            if size is not None and size != 1:
                report.findings.append(Finding(
                    RULE, here, 0,
                    f"trainer mode {mode!r} program {key!r} holds "
                    f"{size} cache entries after two same-shape fits",
                ))

    # mesh DP trainer: two same-shape mesh fits trace the loss once and
    # every cached mesh program holds one jit entry (retrace-exactly-once
    # per mesh shape — a second mesh would legitimately add a program)
    from repro.parallel import mesh_fit

    mesh = mesh_fit.host_mesh()
    traces["n"] = 0
    tr = train_loop.MiniBatchTrainer(loss_fn, ocfg, mode="scan")
    tr.fit(params, (blocks,), steps=4, batch_size=8, seed=0, mesh=mesh)
    tr.fit(params, (blocks,), steps=4, batch_size=8, seed=1, mesh=mesh)
    if traces["n"] != 1:
        report.findings.append(Finding(
            RULE, here, 0,
            f"mesh trainer traced the loss {traces['n']}x across two "
            f"same-shape mesh fits (expected 1)",
        ))
    for key, prog in tr._programs.items():
        size = getattr(prog, "_cache_size", lambda: None)()
        if size is not None and size != 1:
            report.findings.append(Finding(
                RULE, here, 0,
                f"mesh trainer program {key!r} holds {size} cache "
                f"entries after two same-shape fits",
            ))

    # sharded guarantee engine: chunk dispatches across two prepare/select
    # rounds re-use one traced program per kernel per device (balanced
    # chunking keeps every chunk the same shape; jit caches one executable
    # per distinct committed device, so round-robin staging legitimately
    # holds min(n_shards, n_devices) entries)
    eng = mesh_fit.ShardedGuaranteeEngine(n_shards=2)
    expected = min(eng._n_shards, len(eng._devices))
    rng = np.random.default_rng(0)
    x = rng.standard_normal((2, 8, 32))
    x_rec = (x + 0.01 * rng.standard_normal((2, 8, 32))).astype(np.float32)
    for tau in (0.01, 0.02):
        prep = eng.prepare(x, x_rec)
        eng.select(prep, tau)
    for jit_name in ("_project_jit", "_correct_jit"):
        prog = getattr(eng, jit_name)
        size = getattr(prog, "_cache_size", lambda: None)()
        if size is not None and size != expected:
            report.findings.append(Finding(
                RULE, here, 0,
                f"sharded guarantee engine {jit_name} holds {size} cache "
                f"entries after two chunked prepare/select rounds "
                f"(expected {expected})",
            ))

    # fused decode: repeated calls on one runtime re-use one executable
    import jax

    from repro.codec import runtime as rt_mod

    fused = jax.jit(rt_mod.make_fused_decode(model, None))
    lat32 = np.zeros((16, model.cfg.latent), dtype=np.float32)
    fused(params, None, lat32)
    fused(params, None, lat32)
    size = fused._cache_size()
    if size != 1:
        report.findings.append(Finding(
            RULE, here, 0,
            f"fused decode holds {size} jit cache entries after repeated "
            f"same-shape calls (expected 1)",
        ))


def audit() -> AuditReport:
    """Run the full trace-time audit; returns findings + per-program stats."""
    import jax

    report = AuditReport()
    t0 = time.perf_counter()
    here = "analysis/jaxpr_audit.py"

    # x64 guard: the audit is only meaningful in the default f32 world
    if jax.config.jax_enable_x64:
        report.findings.append(Finding(
            RULE, here, 0,
            "jax_enable_x64 is globally on — the repo must only enable "
            "x64 in scoped contexts; audit aborted",
        ))
        report.wall_clock_s = time.perf_counter() - t0
        return report

    for spec in _program_specs():
        _audit_program(spec, report)
    _audit_retrace(report)

    if jax.config.jax_enable_x64:
        report.findings.append(Finding(
            RULE, here, 0,
            "an audited program globally enabled jax_enable_x64 and "
            "leaked it past its scope",
        ))
    report.wall_clock_s = time.perf_counter() - t0
    return report
