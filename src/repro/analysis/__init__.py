"""Invariant checker for the GBATC codec: lint, trace audit, wire schema.

Three analyzer tiers over one findings currency
(:class:`~repro.analysis.findings.Finding`):

1. **AST lint** (:mod:`repro.analysis.lint` + :mod:`repro.analysis.rules`)
   — repo-specific rules over ``src/repro``: decode-path purity, wire
   centralization, typed-error discipline, determinism hygiene,
   reference pairing.
2. **Trace-time audit** (:mod:`repro.analysis.jaxpr_audit`) — traces the
   registered hot programs and walks their jaxprs: fp64 promotion, host
   callbacks, mid-program transfers, undonated carries, folded
   constants, retrace counting.
3. **Wire-schema conformance** (:mod:`repro.analysis.wire_schema`) — a
   declarative restatement of container v1–v4 diffed against the live
   pack/parse constants; also owns the fault-region label vocabulary
   (:class:`~repro.analysis.wire_schema.RegionKind`).

Run as a tier-1 gate::

    PYTHONPATH=src python -m repro.analysis && PYTHONPATH=src pytest -x -q

Suppress a deliberate violation inline (``# repro: allow[rule]`` /
``# repro: allow-file[rule]``) or grandfather it in
``src/repro/analysis/baseline.json``; the CLI exits nonzero on any new
finding. See ROADMAP "Codebase invariants" for the rule catalog.
"""

from repro.analysis.findings import Finding, Suppressions, scan_suppressions
from repro.analysis.lint import LintResult, lint_tree
from repro.analysis.wire_schema import (
    GUARANTEE_PARTS,
    RegionKind,
    check_conformance,
)

__all__ = [
    "Finding",
    "GUARANTEE_PARTS",
    "LintResult",
    "RegionKind",
    "Suppressions",
    "check_conformance",
    "lint_tree",
    "scan_suppressions",
]
