"""CLI: ``python -m repro.analysis`` — run all three analyzer tiers.

Exit status 0 iff no non-baselined finding. Options:

* ``--root DIR``      package root to lint (default: the installed
  ``src/repro`` tree this module lives in)
* ``--tests DIR``     tests root for the reference-pairing rule
  (default: ``<repo>/tests`` when resolvable, else skipped)
* ``--baseline PATH`` baseline file (default: ``analysis/baseline.json``)
* ``--no-audit``      skip the (slower) jaxpr audit tier
* ``--json PATH``     dump a machine-readable report
* ``--write-baseline`` rewrite the baseline to the current finding set
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

# REPRO_HOST_DEVICES=N forces an N-way host-platform device mesh, so the
# audit's mesh-trainer / sharded-engine specs run multi-device on CPU.
# Must be applied before the analyzer imports (which import jax); the
# repo-root conftest.py carries the identical hook for pytest.
_n_dev = os.environ.get("REPRO_HOST_DEVICES", "")
if _n_dev.isdigit() and int(_n_dev) > 1 and \
        "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={int(_n_dev)}"
    ).strip()

from repro.analysis import jaxpr_audit, wire_schema
from repro.analysis.findings import apply_baseline, load_baseline, save_baseline
from repro.analysis.lint import lint_tree

_PKG_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _default_tests_root() -> str | None:
    # src/repro -> repo root -> tests
    repo = os.path.dirname(os.path.dirname(_PKG_ROOT))
    tests = os.path.join(repo, "tests")
    return tests if os.path.isdir(tests) else None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--root", default=_PKG_ROOT)
    ap.add_argument("--tests", default=None)
    ap.add_argument("--baseline", default=None)
    ap.add_argument("--no-audit", action="store_true")
    ap.add_argument("--json", dest="json_path", default=None)
    ap.add_argument("--write-baseline", action="store_true")
    args = ap.parse_args(argv)

    t0 = time.perf_counter()
    tests_root = args.tests or _default_tests_root()
    baseline_path = args.baseline or os.path.join(
        args.root, "analysis", "baseline.json"
    )

    result = lint_tree(args.root, tests_root)
    findings = list(result.findings) + list(result.parse_errors)
    lint_s = time.perf_counter() - t0

    findings += wire_schema.check_conformance()

    audit_report = None
    if not args.no_audit:
        audit_report = jaxpr_audit.audit()
        findings += audit_report.findings

    baseline = load_baseline(baseline_path) if os.path.exists(
        baseline_path
    ) else []
    new, baselined, stale = apply_baseline(findings, baseline)

    if args.write_baseline:
        save_baseline(baseline_path, findings)
        print(f"baseline written: {len(findings)} finding(s) -> "
              f"{baseline_path}")
        return 0

    rule_counts: dict[str, int] = {}
    for f in findings:
        rule_counts[f.rule] = rule_counts.get(f.rule, 0) + 1

    for f in new:
        print(str(f))
    for f in baselined:
        print(f"baselined: {f}")
    for r in stale:
        print(f"warning: stale baseline entry "
              f"[{r['rule']}] {r['path']}: {r['detail']}")

    total_s = time.perf_counter() - t0
    print(
        f"repro.analysis: {result.files_scanned} files, "
        f"{len(findings)} finding(s) "
        f"({len(new)} new, {len(baselined)} baselined, "
        f"{len(result.suppressed)} suppressed inline), "
        f"lint {lint_s:.2f}s, total {total_s:.2f}s"
        + ("" if args.no_audit else
           f", audit {audit_report.wall_clock_s:.2f}s")
    )

    if args.json_path:
        payload = {
            "files_scanned": result.files_scanned,
            "rule_counts": rule_counts,
            "new": [f.__dict__ for f in new],
            "baselined": [f.__dict__ for f in baselined],
            "suppressed_inline": len(result.suppressed),
            "stale_baseline": stale,
            "lint_wall_clock_s": lint_s,
            "audit_wall_clock_s": (
                None if audit_report is None else audit_report.wall_clock_s
            ),
            "total_wall_clock_s": total_s,
        }
        with open(args.json_path, "w", encoding="utf-8") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)

    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
