"""reference-pairing: every retained ``*_ref`` twin is test-gated.

The repo's optimization discipline (ROADMAP): every fused/compiled path
retains its pre-change reference implementation, and a test pins parity
between the two. A ``*_reference``/``*_ref`` function no test ever
touches is a parity gate that silently stopped gating — the fused path
can drift and nothing fails.

Cross-file pass: collect every function definition in ``src/repro``
whose name ends in ``_reference`` or ``_ref`` and require the name to
occur (as a whole word) somewhere under ``tests/``. Pallas kernel
*parameters* conventionally named ``*_ref`` are not definitions and are
not collected.
"""

from __future__ import annotations

import ast
import re

from repro.analysis.findings import Finding

RULE = "reference-pairing"

_SUFFIXES = ("_reference", "_ref")


def reference_defs(files) -> list[tuple[str, int, str]]:
    """(relpath, line, name) of every ``*_ref(erence)`` def in *files*,
    given as (relpath, tree) pairs."""
    out = []
    for relpath, tree in files:
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if node.name.endswith(_SUFFIXES):
                    out.append((relpath, node.lineno, node.name))
    return out


def check_tree(files, test_sources) -> list[Finding]:
    """*files*: (relpath, tree) pairs for the package; *test_sources*:
    iterable of test-file text."""
    corpus = "\n".join(test_sources)
    out = []
    for relpath, line, name in reference_defs(files):
        if not re.search(rf"\b{re.escape(name)}\b", corpus):
            out.append(Finding(
                RULE, relpath, line,
                f"reference symbol {name!r} is not exercised by any test "
                f"under tests/",
            ))
    return out
