"""determinism: no unseeded randomness, no wall-clock in cache keys.

GBATC's bit-identity gates (fused vs reference decode, v3/v4 byte
identity) only mean something if every run is reproducible. Two families
of ambient nondeterminism are banned:

* **Unseeded randomness** (everywhere in ``src/repro``): the stdlib
  ``random`` module (always implicitly process-seeded), the legacy
  ``np.random.*`` global-state API (``seed``/``rand``/``randn``/
  ``randint``/``random``/``normal``/``uniform``/``choice``/``shuffle``/
  ``permutation``), and zero-argument ``default_rng()`` (OS-entropy
  seeded). Seeded ``np.random.default_rng(seed)`` and
  ``jax.random.PRNGKey`` are the sanctioned sources.
* **Wall-clock in codec/core/parallel state** (``codec/``, ``core/``,
  ``parallel/``): ``time.time``/``perf_counter``/``monotonic`` and
  ``datetime.now``/``utcnow`` — a timestamp reaching a cache key or a
  wire byte makes identical inputs produce different artifacts, and the
  mesh-sharded fit/compress programs (``parallel/``) carry the same
  bit-identity gates as the single-device paths. Benchmark and launch
  code may time things freely.
"""

from __future__ import annotations

import ast

from repro.analysis.findings import Finding

RULE = "determinism"

_NP_RANDOM_LEGACY = frozenset({
    "seed", "rand", "randn", "randint", "random", "normal", "uniform",
    "choice", "shuffle", "permutation", "random_sample", "standard_normal",
})
_CLOCK_SCOPES = ("codec/", "core/", "parallel/")
_TIME_FUNCS = frozenset({"time", "perf_counter", "monotonic"})
_DATETIME_FUNCS = frozenset({"now", "utcnow", "today"})


def _dotted(node) -> list[str]:
    """Attribute chain -> name parts, e.g. np.random.rand -> [np,random,rand]."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return parts[::-1]
    return []


def check_file(relpath: str, tree: ast.AST, source: str) -> list[Finding]:
    out = []
    in_clock_scope = relpath.startswith(_CLOCK_SCOPES)
    for node in ast.walk(tree):
        if isinstance(node, (ast.Import,)):
            for alias in node.names:
                if alias.name == "random":
                    out.append(Finding(
                        RULE, relpath, node.lineno,
                        "stdlib random imported (process-seeded global "
                        "state); use np.random.default_rng(seed)",
                    ))
        elif isinstance(node, ast.ImportFrom):
            if node.module == "random":
                out.append(Finding(
                    RULE, relpath, node.lineno,
                    "stdlib random imported (process-seeded global "
                    "state); use np.random.default_rng(seed)",
                ))
        elif isinstance(node, ast.Call):
            parts = _dotted(node.func)
            if len(parts) >= 2 and parts[-2] == "random" \
                    and parts[-1] in _NP_RANDOM_LEGACY \
                    and parts[0] in ("np", "numpy"):
                out.append(Finding(
                    RULE, relpath, node.lineno,
                    f"legacy global-state np.random.{parts[-1]}; use a "
                    f"seeded Generator",
                ))
            elif parts and parts[-1] == "default_rng" and not node.args \
                    and not node.keywords:
                out.append(Finding(
                    RULE, relpath, node.lineno,
                    "default_rng() without a seed draws OS entropy",
                ))
            elif in_clock_scope and len(parts) == 2:
                mod, fn = parts
                if (mod == "time" and fn in _TIME_FUNCS) or (
                        mod == "datetime" and fn in _DATETIME_FUNCS):
                    out.append(Finding(
                        RULE, relpath, node.lineno,
                        f"wall-clock {mod}.{fn}() in codec/core — "
                        f"timestamps must not reach cache keys or wire "
                        f"bytes",
                    ))
    return out
