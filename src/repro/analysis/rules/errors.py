"""typed-errors: wire failures are structured; handlers don't swallow.

The codec's error contract (ROADMAP, PR 4/6): anything wrong with
container bytes surfaces as :class:`ContainerFormatError` carrying
``stream=``/``offset=``/``unit=`` so callers (and the salvage decoder)
can quarantine precisely. Two checks enforce it:

**Repo-wide handler discipline** — a bare ``except:`` or a broad
``except Exception/BaseException`` is a finding *unless* the handler
body re-raises (``raise`` anywhere in the handler: the convert-and-raise
idiom is the sanctioned use of broad catches). Deliberate swallowing
sites carry ``# repro: allow[typed-errors]`` with a reason.

**Parse-path raise discipline** — inside the wire-parsing modules, in
parse scopes (``__init__`` of ``*Reader``/``*Directory``/``*Latents``
classes; functions named ``_unpack*``, ``_decode*``, ``verify_*``,
``from_*``):

* every ``raise ContainerFormatError(...)`` must pass at least one of
  ``stream=``/``offset=``/``unit=`` — an unlocated wire error defeats
  salvage;
* every other raise must be a bare re-raise — an untyped exception
  escaping a parse path bypasses the structured contract.

``core/gae.py`` is deliberately outside the parse-path scope: its
``from_parts`` raises unlocated ``ContainerFormatError`` by design and
``runtime._species_guarantee`` adds the stream/unit framing upstream.
"""

from __future__ import annotations

import ast
import fnmatch

from repro.analysis.findings import Finding

RULE = "typed-errors"

#: Modules whose parse scopes must speak ContainerFormatError.
PARSE_MODULES = frozenset({
    "codec/format.py",
    "codec/runtime.py",
    "codec/latents.py",
    "codec/partial.py",
    "codec/integrity.py",
    "core/container.py",
})

_PARSE_FUNC_PATTERNS = ("_unpack*", "_decode*", "verify_*", "from_*")
_PARSE_CLASS_SUFFIXES = ("Reader", "Directory", "Latents")
_STRUCTURED_KWARGS = frozenset({"stream", "offset", "unit"})
_BROAD = frozenset({"Exception", "BaseException"})


def _is_broad(handler: ast.ExceptHandler) -> bool:
    t = handler.type
    if t is None:
        return True
    names = t.elts if isinstance(t, ast.Tuple) else [t]
    for n in names:
        if isinstance(n, ast.Name) and n.id in _BROAD:
            return True
    return False


def _reraises(handler: ast.ExceptHandler) -> bool:
    return any(isinstance(n, ast.Raise) for n in ast.walk(handler))


def _is_parse_scope(func: ast.AST, cls_name: str | None) -> bool:
    name = func.name
    if name == "__init__" and cls_name is not None:
        return cls_name.endswith(_PARSE_CLASS_SUFFIXES)
    return any(fnmatch.fnmatch(name, p) for p in _PARSE_FUNC_PATTERNS)


def _check_raise(node: ast.Raise, relpath: str, scope: str) -> Finding | None:
    if node.exc is None:  # bare re-raise: propagating a typed error
        return None
    exc = node.exc
    fn = exc.func if isinstance(exc, ast.Call) else exc
    name = fn.id if isinstance(fn, ast.Name) else (
        fn.attr if isinstance(fn, ast.Attribute) else None
    )
    if name != "ContainerFormatError":
        return Finding(
            RULE, relpath, node.lineno,
            f"parse scope {scope!r} raises {name or 'a computed exception'}"
            f" instead of ContainerFormatError",
        )
    kwargs = {k.arg for k in exc.keywords} if isinstance(exc, ast.Call) else set()
    if not kwargs & _STRUCTURED_KWARGS:
        return Finding(
            RULE, relpath, node.lineno,
            f"ContainerFormatError in parse scope {scope!r} lacks "
            f"stream=/offset=/unit=",
        )
    return None


def check_file(relpath: str, tree: ast.AST, source: str) -> list[Finding]:
    out = []
    # repo-wide: broad handlers that swallow
    for node in ast.walk(tree):
        if isinstance(node, ast.ExceptHandler):
            if _is_broad(node) and not _reraises(node):
                what = "bare except" if node.type is None else (
                    "broad except swallowing"
                )
                out.append(Finding(
                    RULE, relpath, node.lineno,
                    f"{what} without re-raise",
                ))

    if relpath not in PARSE_MODULES:
        return out

    # parse-path raise discipline, scoped to named parse functions
    def visit(node, cls_name):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.ClassDef):
                visit(child, child.name)
            elif isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if _is_parse_scope(child, cls_name):
                    for sub in ast.walk(child):
                        if isinstance(sub, ast.Raise):
                            f = _check_raise(sub, relpath, child.name)
                            if f is not None:
                                out.append(f)
                else:
                    visit(child, None)

    visit(tree, None)
    return out
