"""decode-purity: decode derives structure from the blob alone.

Everything under ``codec/`` and ``serve/`` must reconstruct purely from
container bytes — never from ambient pipeline configuration or the
process environment. A decode that silently consulted
``default_config()`` or an env var would produce blobs that only decode
on the machine (or config) that wrote them, breaking the paper's
self-describing-container contract; the decode service serves whatever
blobs are registered with it, so the same contract covers the serving
layer wholesale.

Since the encoder-family refactor the rule is structural, not just
symbolic: the codec packages the family-owned
:class:`~repro.codec.families.StructuralConfig` unpacked from the blob,
so **no import of** ``repro.core.pipeline`` — the encode-side
orchestration module — is permitted anywhere under the scope, at any
nesting level. (``repro.codec.__getattr__`` re-exports ``GBATCCodec``
through ``importlib`` by module-name string precisely so the seam stays
visible to this check: an AST import of the pipeline under ``codec/``
is always a regression.)

Flags, inside the scoped trees:

* any ``import``/``from ... import`` of ``repro.core.pipeline`` (the
  ambient-config symbols ``GBATCPipeline`` / ``default_config`` keep
  their dedicated message; any other alias flags the module import
  itself);
* ``os.environ`` / ``os.getenv`` / ``os.environb`` reads;
* calling ``PipelineConfig()`` with no arguments — a fresh
  default-valued config is ambient state by construction; the decode
  path must rebuild its config from the meta stream.
"""

from __future__ import annotations

import ast

from repro.analysis.findings import Finding

RULE = "decode-purity"

SCOPE_PREFIXES = ("codec/", "serve/")

_BANNED_MODULE = "core.pipeline"
_BANNED_IMPORTS = frozenset({"GBATCPipeline", "default_config"})
_ENV_ATTRS = frozenset({"environ", "environb", "getenv"})


def in_scope(relpath: str) -> bool:
    return relpath.startswith(SCOPE_PREFIXES)


def _is_pipeline_module(dotted: str | None) -> bool:
    return dotted is not None and (
        dotted == _BANNED_MODULE or dotted.endswith("." + _BANNED_MODULE)
    )


def check_file(relpath: str, tree: ast.AST, source: str) -> list[Finding]:
    if not in_scope(relpath):
        return []
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom):
            from_pipeline = _is_pipeline_module(node.module)
            for alias in node.names:
                if alias.name in _BANNED_IMPORTS:
                    # the historical, sharper message wins per alias
                    out.append(Finding(
                        RULE, relpath, node.lineno,
                        f"decode path imports ambient-config symbol "
                        f"{alias.name!r}",
                    ))
                elif from_pipeline or (
                    # `from repro.core import pipeline` spells the same
                    # dependency with the module as the alias
                    alias.name == "pipeline"
                    and node.module is not None
                    and node.module.split(".")[-1] == "core"
                ):
                    out.append(Finding(
                        RULE, relpath, node.lineno,
                        f"decode path imports the encode-side pipeline "
                        f"module ({node.module}.{alias.name}); structure "
                        f"must come from the blob's StructuralConfig",
                    ))
        elif isinstance(node, ast.Import):
            for alias in node.names:
                if _is_pipeline_module(alias.name):
                    out.append(Finding(
                        RULE, relpath, node.lineno,
                        f"decode path imports the encode-side pipeline "
                        f"module ({alias.name}); structure must come "
                        f"from the blob's StructuralConfig",
                    ))
        elif isinstance(node, ast.Attribute):
            if (isinstance(node.value, ast.Name)
                    and node.value.id == "os"
                    and node.attr in _ENV_ATTRS):
                out.append(Finding(
                    RULE, relpath, node.lineno,
                    f"decode path reads process environment via "
                    f"os.{node.attr}",
                ))
        elif isinstance(node, ast.Call):
            fn = node.func
            name = fn.id if isinstance(fn, ast.Name) else (
                fn.attr if isinstance(fn, ast.Attribute) else None
            )
            if (name == "PipelineConfig" and not node.args
                    and not node.keywords):
                out.append(Finding(
                    RULE, relpath, node.lineno,
                    "decode path constructs a default PipelineConfig(); "
                    "config must come from the meta stream",
                ))
    return out
