"""decode-purity: decode derives structure from the blob alone.

The decode path (``codec/decode.py``, ``codec/runtime.py``,
``codec/partial.py``, ``codec/latents.py``, ``codec/cache.py``, and the
whole serving layer ``serve/``) must reconstruct purely from container
bytes — never from ambient pipeline configuration or the process
environment. A decode that silently consulted ``default_config()`` or an
env var would produce blobs that only decode on the machine (or config)
that wrote them, breaking the paper's self-describing-container
contract; the decode service serves whatever blobs are registered with
it, so the same contract covers everything under ``serve/``.

Flags, inside the decode-path modules only:

* ``os.environ`` / ``os.getenv`` / ``os.environb`` reads;
* importing ``GBATCPipeline`` or ``default_config`` (the encode-side
  ambient config constructors);
* calling ``PipelineConfig()`` with no arguments — a fresh
  default-valued config is ambient state by construction; the decode
  path must rebuild its config from the meta stream.
"""

from __future__ import annotations

import ast

from repro.analysis.findings import Finding

RULE = "decode-purity"

SCOPE = frozenset({
    "codec/decode.py",
    "codec/runtime.py",
    "codec/partial.py",
    "codec/latents.py",
    "codec/cache.py",
})
# the serving layer is decode path wholesale: every module under serve/
SCOPE_PREFIXES = ("serve/",)

_BANNED_IMPORTS = frozenset({"GBATCPipeline", "default_config"})
_ENV_ATTRS = frozenset({"environ", "environb", "getenv"})


def in_scope(relpath: str) -> bool:
    return relpath in SCOPE or relpath.startswith(SCOPE_PREFIXES)


def check_file(relpath: str, tree: ast.AST, source: str) -> list[Finding]:
    if not in_scope(relpath):
        return []
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom):
            for alias in node.names:
                if alias.name in _BANNED_IMPORTS:
                    out.append(Finding(
                        RULE, relpath, node.lineno,
                        f"decode path imports ambient-config symbol "
                        f"{alias.name!r}",
                    ))
        elif isinstance(node, ast.Attribute):
            if (isinstance(node.value, ast.Name)
                    and node.value.id == "os"
                    and node.attr in _ENV_ATTRS):
                out.append(Finding(
                    RULE, relpath, node.lineno,
                    f"decode path reads process environment via "
                    f"os.{node.attr}",
                ))
        elif isinstance(node, ast.Call):
            fn = node.func
            name = fn.id if isinstance(fn, ast.Name) else (
                fn.attr if isinstance(fn, ast.Attribute) else None
            )
            if (name == "PipelineConfig" and not node.args
                    and not node.keywords):
                out.append(Finding(
                    RULE, relpath, node.lineno,
                    "decode path constructs a default PipelineConfig(); "
                    "config must come from the meta stream",
                ))
    return out
