"""Rule registry for the AST lint tier.

Each rule module exposes ``RULE`` (its name, used in findings, inline
``# repro: allow[rule]`` tags, and the baseline) and ``check_file(relpath,
tree, source)`` returning a list of findings for one module.
:mod:`repro.analysis.rules.pairing` is the one cross-file rule and
instead exposes ``check_tree(src_root, tests_root)``.
"""

from __future__ import annotations

from repro.analysis.rules import determinism, errors, pairing, purity, wire

#: Per-file rules, in report order.
FILE_RULES = (purity, wire, errors, determinism)

#: Cross-file rules (run once over the whole tree).
TREE_RULES = (pairing,)

ALL_RULE_NAMES = tuple(
    [r.RULE for r in FILE_RULES] + [r.RULE for r in TREE_RULES]
)
