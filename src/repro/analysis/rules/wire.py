"""wire-centralization: byte layouts live in format.py / container.py.

Every on-disk byte layout of the GBATC container belongs in
``codec/format.py`` (stream formats) or ``core/container.py`` (outer
framing). A ``struct.pack`` or a 4-byte magic literal anywhere else is a
second, uncoordinated wire site — exactly the kind that
:mod:`repro.analysis.wire_schema` cannot conformance-check and that
drifts silently on the next format bump.

Flags, everywhere outside the two wire modules:

* calls into :mod:`struct` (``pack``/``unpack``/``unpack_from``/
  ``iter_unpack``/``calcsize``/``Struct``) — referencing
  ``struct.error`` in an ``except`` clause is fine and not flagged;
* 4-byte uppercase ASCII bytes literals shaped like stream magics
  (``b"GBTC"``, ``b"LAT3"``, ...).

Deliberate secondary wire owners (e.g. the Huffman stream format in
``core/entropy.py``) carry ``# repro: allow-file[wire-centralization]``
with a comment saying why.
"""

from __future__ import annotations

import ast
import re

from repro.analysis.findings import Finding

RULE = "wire-centralization"

#: The only modules allowed to speak wire bytes.
WIRE_MODULES = frozenset({"codec/format.py", "core/container.py"})

_STRUCT_CALLS = frozenset({
    "pack", "pack_into", "unpack", "unpack_from", "iter_unpack",
    "calcsize", "Struct",
})
_MAGIC = re.compile(rb"^[A-Z][A-Z0-9]{3}$")


def check_file(relpath: str, tree: ast.AST, source: str) -> list[Finding]:
    if relpath in WIRE_MODULES:
        return []
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            fn = node.func
            if (isinstance(fn, ast.Attribute)
                    and isinstance(fn.value, ast.Name)
                    and fn.value.id == "struct"
                    and fn.attr in _STRUCT_CALLS):
                out.append(Finding(
                    RULE, relpath, node.lineno,
                    f"struct.{fn.attr} outside the wire modules "
                    f"(codec/format.py, core/container.py)",
                ))
        elif isinstance(node, ast.Constant) and isinstance(node.value, bytes):
            if _MAGIC.match(node.value):
                out.append(Finding(
                    RULE, relpath, node.lineno,
                    f"magic-shaped bytes literal {node.value!r} outside "
                    f"the wire modules",
                ))
    return out
