"""Wire-schema conformance: a declarative restatement of container v1–v5
cross-checked against the live pack/parse constants.

The container's byte layout is implemented twice on purpose:

* ``codec/format.py`` / ``core/container.py`` hold the *executable*
  layout — the ``struct.Struct`` objects the codec packs and parses
  with;
* this module holds a *declarative* restatement — magics, record format
  strings, record sizes, and the per-version stream sets, written out
  literally from the format documentation.

:func:`check_conformance` diffs the two. A format edit that bumps a
record without updating the docs-level schema (or vice versa — edits the
schema and forgets a version) fails statically, before any blob is ever
round-tripped. The schema also owns :class:`RegionKind`, the enum of
fault-addressable region label templates shared with
:mod:`repro.testing.faults`, so the fault harness and the schema cannot
drift on unit names.
"""

from __future__ import annotations

# repro: allow-file[wire-centralization] — this module IS the declarative
# restatement of the wire layout; duplicating the magics and record
# formats here (to diff against the live ones) is its entire purpose.

import enum
import struct

from repro.analysis.findings import Finding

RULE = "wire-schema"


class RegionKind(enum.Enum):
    """Fault-addressable region label templates (format-string values).

    One member per region species the v4 digests (and the fault harness)
    address. ``label(...)`` renders the concrete label; the members are
    the single source of truth for the strings the harness, the
    integrity sweep tests, and the schema all match on.
    """

    HEADER = "header"
    STREAM = "stream:{name}"
    META_FAMILY = "meta:family"
    LATENT_HEAD = "latent:head"
    LATENT_SHARD = "latent:shard{unit}"
    GUARANTEE_DIR = "guarantee:dir"
    GUARANTEE_SPECIES_PART = "guarantee:s{unit}:{part}"
    BLOB = "blob"

    def label(self, **kw) -> str:
        return self.value.format(**kw)


#: CRC-chain order of one species' guarantee extent (PR 4/6 contract);
#: faults.py iterates parts in exactly this order.
GUARANTEE_PARTS = ("coeff", "index", "basis")


# --------------------------------------------------------------------------
# Declarative layout. Each record: (name, struct format, size in bytes).
# These are deliberately literal — restating them from the format docs is
# the point; do not "fix" a mismatch by importing from format.py.

OUTER_MAGIC = b"GBTC"
LAT3_MAGIC = b"LAT3"
ITG_MAGIC = b"ITG1"

OUTER_RECORDS = (
    ("outer_head", "<4sHH", 8),     # magic, version, n_streams
    ("outer_len", "<Q", 8),         # per-entry stream payload length
)

STREAM_RECORDS = (
    ("meta5_family", "<B", 1),      # v5: encoder-family tag prefixing meta
    ("meta_head", "<BBHHHHH", 12),  # flags, dtype, latent, bt, ph, pw, n_arch
    ("meta_shape", "<IIIId", 24),   # S, T, H, W, latent_bin
    ("gdir_head", "<I", 4),         # species count
    ("gdir_rec", "<ddIIQQQ", 48),   # tau, eb, rank, nb, coeff/index/basis len
    ("lat3_head", "<4sIIQI", 24),   # magic, n_shards, shard_rows, rows, cols
    ("lat3_cb", "<I", 4),           # codebook symbol count
    ("lat3_len", "<Q", 8),          # per-shard payload byte length
    ("itg_head", "<4sH", 6),        # magic, n_streams
    ("itg_crc", "<I", 4),           # one CRC32 digest
    ("itg_units", "<III", 12),      # region_len, region_crc, n_units
)

#: version -> (base streams, adds guarantee dir?, per-species streams?,
#: integrity?). Expressed as an explicit table, one row per version.
VERSIONS = (1, 2, 3, 4, 5)

#: declarative restatement of the registered encoder families and their
#: v5 meta-stream wire tags (compare ``repro.codec.families.registered``);
#: written out literally from the format docs, on purpose — an
#: unregistered tag or a registry/schema drift must fail statically.
FAMILY_TAGS = (
    ("conv", 1),
    ("attention", 2),
)


def expected_stream_set(version: int, n_species: int,
                        has_correction: bool) -> frozenset:
    """Schema-side restatement of the per-version stream sets (compare
    :func:`repro.codec.format.expected_stream_set`)."""
    if version not in VERSIONS:
        raise ValueError(f"unknown container version {version}")
    names = {"meta", "latent", "decoder"}
    if has_correction:
        names.add("correction")
    if version == 1:
        names.update(f"guarantee{s}" for s in range(n_species))
    else:
        names.add("guarantee")
    if version >= 4:
        names.add("integrity")
    return frozenset(names)


# --------------------------------------------------------------------------
# Conformance


def _live_records():
    """(name, live Struct) pairs mirroring the declarative tables."""
    from repro.codec import format as wire
    from repro.core import container as container_format

    return {
        "outer_head": container_format._HEAD,
        "outer_len": container_format._LEN,
        "meta5_family": wire._META_FAMILY,
        "meta_head": wire._META_HEAD,
        "meta_shape": wire._META_SHAPE,
        "gdir_head": wire._GDIR_HEAD,
        "gdir_rec": wire._GDIR_REC,
        "lat3_head": wire._LAT3_HEAD,
        "lat3_cb": wire._LAT3_CB,
        "lat3_len": wire._LAT3_LEN,
        "itg_head": wire._ITG_HEAD,
        "itg_crc": wire._ITG_CRC,
        "itg_units": wire._ITG_UNITS,
    }


def check_conformance() -> list:
    """Cross-check the declarative schema against the implementation.

    Covers all four container versions: outer framing, every stream
    record layout, the magics, the supported-version tuple, and the
    per-version stream sets (schema table vs
    ``format.expected_stream_set`` over representative shapes). Returns
    findings; empty means conformant.
    """
    from repro.codec import format as wire
    from repro.core import container as container_format

    here = "analysis/wire_schema.py"
    out = []

    def finding(detail):
        out.append(Finding(RULE, here, 0, detail))

    # magics
    if container_format.MAGIC != OUTER_MAGIC:
        finding(f"outer magic: schema {OUTER_MAGIC!r} != "
                f"live {container_format.MAGIC!r}")
    if wire._LAT3_MAGIC != LAT3_MAGIC:
        finding(f"latent v3 magic: schema {LAT3_MAGIC!r} != "
                f"live {wire._LAT3_MAGIC!r}")
    if wire._ITG_MAGIC != ITG_MAGIC:
        finding(f"integrity magic: schema {ITG_MAGIC!r} != "
                f"live {wire._ITG_MAGIC!r}")

    # version table
    if tuple(container_format.SUPPORTED_VERSIONS) != VERSIONS:
        finding(f"supported versions: schema {VERSIONS} != "
                f"live {tuple(container_format.SUPPORTED_VERSIONS)}")

    # record layouts: declared format string and size must both match the
    # live Struct (size is re-derived independently as a typo check)
    live = _live_records()
    for name, fmt, size in OUTER_RECORDS + STREAM_RECORDS:
        if struct.calcsize(fmt) != size:
            finding(f"record {name}: declared size {size} does not match "
                    f"its own format {fmt!r} ({struct.calcsize(fmt)})")
        st = live.get(name)
        if st is None:
            finding(f"record {name}: no live Struct mapped")
            continue
        if st.format != fmt:
            finding(f"record {name}: schema format {fmt!r} != "
                    f"live {st.format!r}")
        if st.size != size:
            finding(f"record {name}: schema size {size} != live {st.size}")

    # per-version stream sets, exercised over representative shapes for
    # every supported version
    for version in VERSIONS:
        for n_species, has_corr in ((1, False), (3, True), (4, False)):
            want = expected_stream_set(version, n_species, has_corr)
            got = wire.expected_stream_set(version, n_species, has_corr)
            if got != want:
                finding(
                    f"stream set v{version} (S={n_species}, "
                    f"corr={has_corr}): schema {sorted(want)} != "
                    f"live {sorted(got)}"
                )

    # region-label vocabulary: the fault harness must build labels from
    # RegionKind templates (checked by construction — faults.py imports
    # them — but the part order is wire-visible via the CRC chain)
    if GUARANTEE_PARTS != ("coeff", "index", "basis"):
        finding("guarantee part order drifted from the v4 CRC chain")

    # encoder-family tags: the schema's literal table vs the live
    # registry — a family registered without a schema row (or a tag
    # renumbering) is wire drift, caught before any v5 blob exists
    from repro.codec import families

    live_families = families.registered()
    if FAMILY_TAGS != live_families:
        finding(f"family tags: schema {FAMILY_TAGS} != "
                f"registry {live_families}")
    for name, tag in FAMILY_TAGS:
        fam = families.by_tag(tag)
        if fam is None or fam.name != name:
            finding(f"family tag {tag} ({name!r}) does not resolve through "
                    f"families.by_tag")
    if families.by_tag(0) is not None:
        finding("family tag 0 is reserved as invalid but resolves")

    return out
