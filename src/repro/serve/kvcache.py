"""Quantized KV cache (the paper's quantize+entropy idea on the decode path).

KV blocks are stored int8 with per-(token, head) scales — the entropy stage
is deliberately dropped on the hot path (decode needs random access; noted in
DESIGN.md §Deviations). At kv=8 heads, 32k context, batch 128 this is the
difference between 2.7 GB and 0.7 GB per device of cache — often the
enabling factor for batch size, which is the real serving roofline lever.

Seed template, retained as the record of where the codec's serving-side
cache design came from: the byte-budgeted multi-tier decode cache
(:mod:`repro.codec.cache`) generalizes this module's memory-as-the-
roofline framing to the decode service's head/shard/guarantee tiers.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class QuantizedKVCache:
    """int8 KV storage with fp32 scales; drop-in for the dense cache dict."""

    k_q: jax.Array  # (L, B, T, H, D) int8
    v_q: jax.Array
    k_scale: jax.Array  # (L, B, T, H, 1) fp32
    v_scale: jax.Array
    length: jax.Array  # scalar int32

    @classmethod
    def create(cls, n_layers, batch, max_len, n_kv, d_head):
        shape = (n_layers, batch, max_len, n_kv, d_head)
        sshape = (n_layers, batch, max_len, n_kv, 1)
        return cls(
            k_q=jnp.zeros(shape, jnp.int8),
            v_q=jnp.zeros(shape, jnp.int8),
            k_scale=jnp.zeros(sshape, jnp.float32),
            v_scale=jnp.zeros(sshape, jnp.float32),
            length=jnp.zeros((), jnp.int32),
        )

    @staticmethod
    def _quant(x):
        scale = jnp.maximum(jnp.abs(x).max(-1, keepdims=True), 1e-30) / 127.0
        q = jnp.clip(jnp.round(x / scale), -128, 127).astype(jnp.int8)
        return q, scale.astype(jnp.float32)

    def append(self, k_new, v_new):
        """k_new/v_new: (L, B, 1, H, D) at position self.length."""
        kq, ks = self._quant(k_new.astype(jnp.float32))
        vq, vs = self._quant(v_new.astype(jnp.float32))
        pos = self.length
        return QuantizedKVCache(
            k_q=jax.lax.dynamic_update_slice_in_dim(self.k_q, kq, pos, axis=2),
            v_q=jax.lax.dynamic_update_slice_in_dim(self.v_q, vq, pos, axis=2),
            k_scale=jax.lax.dynamic_update_slice_in_dim(
                self.k_scale, ks, pos, axis=2),
            v_scale=jax.lax.dynamic_update_slice_in_dim(
                self.v_scale, vs, pos, axis=2),
            length=pos + 1,
        )

    def dequant_layer(self, layer: int, dtype=jnp.bfloat16):
        k = (self.k_q[layer].astype(jnp.float32) * self.k_scale[layer]).astype(dtype)
        v = (self.v_q[layer].astype(jnp.float32) * self.v_scale[layer]).astype(dtype)
        return k, v

    def max_abs_error_bound(self):
        """Per-element |x - deq(q)| <= scale/2 — the KV analogue of the
        paper's quantization bound."""
        return self.k_scale.max() / 2.0, self.v_scale.max() / 2.0


jax.tree_util.register_pytree_node(
    QuantizedKVCache,
    lambda c: ((c.k_q, c.v_q, c.k_scale, c.v_scale, c.length), None),
    lambda _, leaves: QuantizedKVCache(*leaves),
)
