"""Serving layer: concurrent query workloads over compressed fields.

:mod:`repro.serve.decode_service` is the codec-native path — a
continuous-batched selective-decode server over GBATC container blobs
(see its module docstring for the scheduler design and bit-identity
contract). :mod:`repro.serve.serve_loop` and :mod:`repro.serve.kvcache`
are the retained seed LM-serving templates the scheduler and the decode
cache were modeled on.
"""

from repro.serve.decode_service import DecodeService, ServeStats

__all__ = ["DecodeService", "ServeStats"]
