"""Batched serving loop: prefill + greedy decode with continuous batching.

Single-controller logic; the jit'd prefill/decode steps are the same
functions the dry-run lowers for the decode_* cells.

Seed template, retained as the record of the scheduler idiom the codec's
decode service (:mod:`repro.serve.decode_service`) is modeled on: one
controller thread, batched jitted dispatches, stats counted at the loop.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class ServeStats:
    prefill_tokens: int = 0
    decode_tokens: int = 0
    steps: int = 0


class Server:
    def __init__(self, model, params, *, max_len: int = 512):
        self.model = model
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, max_len=max_len)
        )
        self._decode = jax.jit(model.decode_step)
        self.stats = ServeStats()

    def generate(self, batch: dict[str, Any], n_new: int,
                 greedy: bool = True, seed: int = 0) -> np.ndarray:
        """Returns (B, n_new) generated token ids."""
        logits, cache = self._prefill(self.params, batch)
        self.stats.prefill_tokens += int(np.prod(batch["tokens"].shape))
        b = batch["tokens"].shape[0]
        out = np.zeros((b, n_new), np.int32)
        key = jax.random.PRNGKey(seed)
        tok = self._pick(logits, greedy, key)
        for i in range(n_new):
            out[:, i] = np.asarray(tok[:, 0])
            logits, cache = self._decode(self.params, cache, tok)
            key, sub = jax.random.split(key)
            tok = self._pick(logits, greedy, sub)
            self.stats.decode_tokens += b
            self.stats.steps += 1
        return out

    @staticmethod
    def _pick(logits, greedy, key):
        if greedy:
            return jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        probs = jax.nn.softmax(logits[:, -1].astype(jnp.float32), axis=-1)
        return jax.random.categorical(key, jnp.log(probs))[:, None].astype(
            jnp.int32)
