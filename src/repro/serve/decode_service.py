"""Decode service: continuous-batched selective decode over GBATC blobs.

The paper's consumers are analysts issuing many small queries — one
species, one time window — against hot compressed fields. The per-request
machinery (:class:`repro.codec.PartialDecoder`) makes each query cheap;
this module makes the *aggregate workload* fast: a single-controller
scheduler thread drains in-flight requests from a queue and coalesces the
ones that can share work into one fused batched dispatch, scattering
per-request slices back out — each bitwise equal to the serial
``PartialDecoder`` answer.

Continuous batching, concretely (one scheduler *tick*):

1. drain up to ``max_batch`` queued requests (the queue refills while a
   tick runs, so under concurrent load batches form naturally — no
   explicit batching window, no wall-clock);
2. handle salvage-mode and unknown-blob requests individually (salvage
   decodes through its own quarantining path and must never share state
   with clean decodes);
3. group the rest by **blob**: requests on one blob share a parsed head
   and hence a decode-runtime structural signature (same geometry, same
   jitted programs). Requests on *different* blobs are never fused even
   when their runtime signature matches — their decoder parameters
   differ, so a shared dispatch could not be bitwise the serial answer;
4. per group: plan every request (:func:`repro.codec.partial.plan_slice`
   — a malformed request fails alone), dedup identical plans (duplicates
   share one computation), merge overlapping/adjacent block-row windows,
   and run ONE fused NN decode per merged row interval
   (row-wise slice transparency makes slicing the union bitwise equal to
   decoding each window separately);
5. per (b0, b1) window subgroup: entropy-decode + correction-replay the
   **species union** once (species-axis batch independence makes each
   species' corrected rows independent of its batch-mates), then hand
   each request its species positions and finalize its exact slice.

Error isolation: a request that hits a
:class:`~repro.core.container.ContainerFormatError` mid-batch gets the
structured error on its own future — batch-mates fall back to
per-request processing and still succeed (matching serial semantics,
including the corrupt blob's head eviction). All decode state the
service shares across threads lives in the multi-tier decode cache
(:mod:`repro.codec.cache`); ``repro.codec.cache_stats()`` observes it.

Provenance: the scheduler is modeled on the seed LM serving template
(``repro.serve.serve_loop.Server`` — single-controller continuous
batching over jitted steps, stats counted at the loop; its quantized KV
cache sibling ``repro.serve.kvcache`` seeded the byte-budgeted cache
design). Those modules are retained as the template record; this module
is the codec-native serving path.

Usage::

    with DecodeService() as svc:
        svc.register("run42", blob)
        fut = svc.submit("run42", species=3, time_range=(4, 12))
        field = fut.result()          # == PartialDecoder(blob).decode(...)
        field2 = svc.decode("run42", species=[1, 3])   # blocking helper
        print(svc.stats.as_dict(), codec.cache_stats())

Everything the service serves derives from registered blob bytes alone —
no environment reads, no pipeline-config imports (machine-checked by the
``repro.analysis`` decode-purity rule, which covers ``serve/``).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from concurrent.futures import Future
from typing import Any, Optional

import numpy as np

from repro.codec.partial import (
    SlicePlan,
    finalize_slice,
    plan_slice,
    replay_slice,
)
from repro.codec.runtime import (
    _cached_head,
    _evict_head,
    _fused_vecs,
    _latents32,
)
from repro.core.container import ContainerFormatError

_STOP = object()  # queue sentinel: drains behind in-flight requests


@dataclasses.dataclass
class ServeStats:
    """Scheduler-side counters (mutated only by the scheduler thread).

    ``coalesced`` counts requests that shared a fused dispatch with at
    least one other request; ``deduped`` counts requests answered from a
    batch-mate's identical computation without any work of their own.
    ``dispatches`` is the number of fused NN decodes actually launched —
    the batching win is ``requests`` growing faster than ``dispatches``.
    """

    requests: int = 0
    completed: int = 0
    errors: int = 0
    salvaged: int = 0
    ticks: int = 0
    dispatches: int = 0
    coalesced: int = 0
    deduped: int = 0
    fallbacks: int = 0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class _Pending:
    """One queued request: its identity plus the future to resolve."""

    blob_id: str
    species: Any
    time_range: Any
    on_error: str
    future: Future


def _merge_intervals(spans: "list[tuple[int, int]]") \
        -> "list[tuple[int, int]]":
    """Merge overlapping/adjacent half-open [b0, b1) row intervals."""
    merged: "list[list[int]]" = []
    for b0, b1 in sorted(spans):
        if merged and b0 <= merged[-1][1]:
            merged[-1][1] = max(merged[-1][1], b1)
        else:
            merged.append([b0, b1])
    return [(b0, b1) for b0, b1 in merged]


class DecodeService:
    """Continuous-batched selective-decode server over registered blobs.

    ``submit`` enqueues a request and returns a
    :class:`concurrent.futures.Future`; the scheduler thread resolves it
    with the decoded slice (or the structured error the serial path
    would raise). ``decode`` is the blocking convenience wrapper. The
    service is a context manager — entering starts the scheduler,
    exiting stops it after draining in-flight requests.
    """

    def __init__(self, *, max_batch: int = 32):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.max_batch = max_batch
        self.stats = ServeStats()
        self._blobs: "dict[str, bytes]" = {}
        self._blobs_lock = threading.Lock()
        self._queue: "queue.SimpleQueue" = queue.SimpleQueue()
        self._thread: Optional[threading.Thread] = None
        self._lifecycle = threading.Lock()
        self._stopped = False

    # -- blob registry ----------------------------------------------------
    def register(self, blob_id: str, blob: bytes) -> str:
        """Register container bytes under ``blob_id`` (parsed lazily, on
        first request, through the shared head cache)."""
        with self._blobs_lock:
            self._blobs[blob_id] = bytes(blob)
        return blob_id

    def unregister(self, blob_id: str) -> None:
        with self._blobs_lock:
            self._blobs.pop(blob_id, None)

    def blob_ids(self) -> "list[str]":
        with self._blobs_lock:
            return sorted(self._blobs)

    # -- lifecycle --------------------------------------------------------
    def start(self) -> "DecodeService":
        with self._lifecycle:
            if self._stopped:
                raise RuntimeError("DecodeService already stopped")
            if self._thread is None:
                self._thread = threading.Thread(
                    target=self._run, name="decode-service", daemon=True
                )
                self._thread.start()
        return self

    def stop(self) -> None:
        """Stop after draining everything already submitted."""
        with self._lifecycle:
            if self._stopped:
                return
            self._stopped = True
            thread = self._thread
        self._queue.put(_STOP)
        if thread is not None:
            thread.join()

    def __enter__(self) -> "DecodeService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- request entry points ---------------------------------------------
    def submit(self, blob_id: str, species=None, time_range=None,
               on_error: str = "raise") -> Future:
        """Enqueue one selective-decode request; resolves to exactly what
        ``PartialDecoder(blob).decode(species, time_range, on_error)``
        returns (or raises)."""
        if on_error not in ("raise", "salvage"):
            raise ValueError(
                f"on_error must be 'raise' or 'salvage', got {on_error!r}"
            )
        with self._lifecycle:
            if self._stopped:
                raise RuntimeError("DecodeService already stopped")
            if self._thread is None:
                raise RuntimeError(
                    "DecodeService not started (use start() or a with-block)"
                )
        fut: Future = Future()
        self._queue.put(_Pending(blob_id, species, time_range,
                                 on_error, fut))
        return fut

    def decode(self, blob_id: str, species=None, time_range=None,
               on_error: str = "raise"):
        """Blocking ``submit(...).result()``."""
        return self.submit(blob_id, species, time_range, on_error).result()

    # -- scheduler --------------------------------------------------------
    def _run(self) -> None:
        while True:
            first = self._queue.get()
            if first is _STOP:
                return
            batch = [first]
            stop = False
            while len(batch) < self.max_batch:
                try:
                    item = self._queue.get_nowait()
                except queue.Empty:
                    break
                if item is _STOP:
                    # drained mid-batch: process the batch, then exit
                    stop = True
                    break
                batch.append(item)
            self._tick(batch)
            if stop:
                return

    def _tick(self, batch: "list[_Pending]") -> None:
        self.stats.ticks += 1
        self.stats.requests += len(batch)
        groups: "dict[str, list[_Pending]]" = {}
        for req in batch:
            with self._blobs_lock:
                blob = self._blobs.get(req.blob_id)
            if blob is None:
                self._fail(req, KeyError(
                    f"unknown blob_id {req.blob_id!r} (register it first)"
                ))
            elif req.on_error == "salvage":
                self._serve_salvage(req, blob)
            else:
                groups.setdefault(req.blob_id, []).append(req)
        for blob_id, reqs in groups.items():
            with self._blobs_lock:
                blob = self._blobs[blob_id]
            self._serve_group(blob, reqs)

    # -- per-request paths ------------------------------------------------
    def _fail(self, req: _Pending, exc: BaseException) -> None:
        self.stats.errors += 1
        req.future.set_exception(exc)

    def _finish(self, req: _Pending, result) -> None:
        self.stats.completed += 1
        req.future.set_result(result)

    def _serve_salvage(self, req: _Pending, blob: bytes) -> None:
        """Salvage decodes run isolated: the quarantining path parses its
        own head and never reads or writes the shared clean-decode cache,
        so a corrupt blob cannot poison batch-mates through it."""
        from repro.codec.integrity import salvage_decompress

        try:
            result = salvage_decompress(
                blob, species=req.species, time_range=req.time_range
            )
        except (ContainerFormatError, ValueError) as e:
            self._fail(req, e)
            return
        self.stats.salvaged += 1
        self._finish(req, result)

    def _serve_serial(self, head, blob: bytes, req: _Pending,
                      plan: Optional[SlicePlan] = None) -> None:
        """Per-request fallback: the serial PartialDecoder path, used when
        a batched stage raised so healthy batch-mates get individually
        retried and the corrupt request fails alone."""
        self.stats.fallbacks += 1
        try:
            if plan is None:
                plan = plan_slice(head, req.species, req.time_range)
            lat32 = _latents32(
                head.latents.rows(plan.b0, plan.b1), head.latent_bin
            )
            vecs = _fused_vecs(
                head.runtime, head.ae_params, head.corr_params, lat32
            )
            import jax.numpy as jnp

            vecs_sel = jnp.asarray(vecs)[np.asarray(plan.idx)]
            vecs_sel = replay_slice(
                head, plan.idx, (plan.b0, plan.b1), vecs_sel
            )
            self._finish(req, finalize_slice(head, plan, vecs_sel))
        except ContainerFormatError as e:
            _evict_head(blob)  # serial decode() semantics
            self._fail(req, e)
        except ValueError as e:
            self._fail(req, e)

    # -- the batched path -------------------------------------------------
    def _serve_group(self, blob: bytes, reqs: "list[_Pending]") -> None:
        """Serve one blob's requests from shared fused dispatches."""
        try:
            head = _cached_head(blob)
        except ContainerFormatError as e:
            # the head itself is bad: every request on this blob raises,
            # exactly as each serial decode would
            for req in reqs:
                self._fail(req, e)
            return
        plans: "dict[tuple, SlicePlan]" = {}
        takers: "dict[tuple, list[_Pending]]" = {}
        for req in reqs:
            try:
                plan = plan_slice(head, req.species, req.time_range)
            except ValueError as e:
                self._fail(req, e)  # malformed request fails alone
                continue
            if plan.key in plans:
                self.stats.deduped += 1
            plans[plan.key] = plan
            takers.setdefault(plan.key, []).append(req)
        if not plans:
            return
        distinct = list(plans.values())
        for B0, B1 in _merge_intervals(
            [(p.b0, p.b1) for p in distinct]
        ):
            members = [p for p in distinct if p.b0 >= B0 and p.b1 <= B1]
            try:
                lat32 = _latents32(
                    head.latents.rows(B0, B1), head.latent_bin
                )
                vecs_dev = _fused_vecs(
                    head.runtime, head.ae_params, head.corr_params, lat32
                )
            except ContainerFormatError:
                # a latent shard in the union is corrupt — per-request
                # retries touch only each request's own rows, so only
                # requests whose window covers the bad shard raise
                for plan in members:
                    for req in takers[plan.key]:
                        self._serve_serial(head, blob, req, plan)
                continue
            self.stats.dispatches += 1
            self._scatter(head, blob, vecs_dev, (B0, B1), members, takers)

    def _scatter(self, head, blob: bytes, vecs_dev, span, members, takers):
        """Replay the species union once per (b0, b1) window subgroup,
        then finalize each plan from its positions of the union."""
        import jax.numpy as jnp

        B0, _ = span
        windows: "dict[tuple[int, int], list[SlicePlan]]" = {}
        for plan in members:
            windows.setdefault((plan.b0, plan.b1), []).append(plan)
        vecs_all = jnp.asarray(vecs_dev)
        for (b0, b1), window_plans in windows.items():
            n_riders = sum(len(takers[p.key]) for p in window_plans)
            if n_riders > 1:
                self.stats.coalesced += n_riders
            union = sorted({s for p in window_plans for s in p.idx})
            pos = {s: i for i, s in enumerate(union)}
            vecs_u = vecs_all[np.asarray(union)][:, b0 - B0 : b1 - B0]
            try:
                vecs_u = replay_slice(head, union, (b0, b1), vecs_u)
            except ContainerFormatError:
                # one species' guarantee stream is corrupt — retries
                # decode each request's own species so healthy requests
                # coalesced with the corrupt one still succeed
                for plan in window_plans:
                    for req in takers[plan.key]:
                        self._serve_serial(head, blob, req, plan)
                continue
            vecs_u = jnp.asarray(vecs_u)
            for plan in window_plans:
                sel = np.asarray([pos[s] for s in plan.idx])
                try:
                    out = finalize_slice(head, plan, vecs_u[sel])
                except ContainerFormatError as e:
                    _evict_head(blob)
                    for req in takers[plan.key]:
                        self._fail(req, e)
                    continue
                for req in takers[plan.key]:
                    self._finish(req, out)
