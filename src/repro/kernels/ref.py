"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each ``<name>`` kernel in this package must match ``ref.<name>_ref`` across
the shape/dtype sweeps in tests/test_kernels.py (interpret mode on CPU,
compiled mode on TPU).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """q: (B, H, Tq, D); k, v: (B, H, Tk, D) (heads already expanded).
    Returns (B, H, Tq, D)."""
    b, h, tq, d = q.shape
    tk = k.shape[2]
    scale = 1.0 / np.sqrt(d)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(tq)[:, None]
    kpos = jnp.arange(tk)[None, :]
    mask = jnp.ones((tq, tk), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def rwkv6_scan_ref(r, k, v, w, u, s0=None):
    """WKV6 recurrence. r,k,v,w: (B, T, H, N); u: (H, N).
    out_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T);  S_t = diag(w_t) S_{t-1} + k_t v_t^T.
    Returns (out (B,T,H,N), S_T (B,H,N,N))."""
    b, t, h, n = r.shape
    if s0 is None:
        s0 = jnp.zeros((b, h, n, n), jnp.float32)

    def step(s, inp):
        rt, kt, vt, wt = inp
        kv = kt[..., :, None] * vt[..., None, :]
        out = jnp.einsum("bhi,bhij->bhj", rt, s + u[None, :, :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, out

    xs = tuple(a.transpose(1, 0, 2, 3).astype(jnp.float32) for a in (r, k, v, w))
    s, outs = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return outs.transpose(1, 0, 2, 3), s


def rglru_scan_ref(a, b, h0=None):
    """Diagonal linear recurrence h_t = a_t * h_{t-1} + b_t.
    a, b: (B, T, W); h0: (B, W). Returns (h (B,T,W), h_T (B,W))."""
    bb, t, w = a.shape
    if h0 is None:
        h0 = jnp.zeros((bb, w), jnp.float32)

    def step(h, inp):
        at, bt = inp
        h = at * h + bt
        return h, h

    xs = (a.transpose(1, 0, 2).astype(jnp.float32),
          b.transpose(1, 0, 2).astype(jnp.float32))
    hT, hs = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return hs.transpose(1, 0, 2), hT


def block_quant_ref(x, n_bits=8, block=64):
    """Per-block symmetric quantize -> dequantize along the last axis.
    x: (..., K) with K % block == 0. Returns (dequantized, scales)."""
    *lead, kdim = x.shape
    xb = x.reshape(*lead, kdim // block, block).astype(jnp.float32)
    qmax = float(2 ** (n_bits - 1) - 1)
    scale = jnp.maximum(jnp.max(jnp.abs(xb), axis=-1, keepdims=True), 1e-30) / qmax
    q = jnp.clip(jnp.round(xb / scale), -qmax - 1, qmax)
    out = (q * scale).reshape(x.shape).astype(x.dtype)
    return out, scale[..., 0]


def gbatc_project_ref(residual, basis):
    """PCA projection c = R @ U. residual: (NB, D); basis: (D, D)."""
    return residual.astype(jnp.float32) @ basis.astype(jnp.float32)


def gbatc_correct_ref(x_rec, coeffs, mask, basis):
    """x^G = x^R + (c * mask) @ U^T."""
    return x_rec.astype(jnp.float32) + (
        coeffs.astype(jnp.float32) * mask.astype(jnp.float32)
    ) @ basis.astype(jnp.float32).T


def gbatc_project_batched_ref(residual, basis):
    """Per-species c_s = R_s @ U_s. residual: (S, NB, D); basis: (S, D, D)."""
    return jnp.einsum("snd,sdk->snk", residual, basis)


def gbatc_correct_batched_ref(x_rec, coeffs, basis):
    """Per-species x^G_s = x^R_s + C_s @ U_s^T (coeffs already masked)."""
    return x_rec + jnp.einsum("snk,sdk->snd", coeffs, basis)


def gbatc_select_accumulate_ref(x_rec, coeff_vals, rank, m, basis):
    """Fused masked select-and-accumulate: keep rank < m, then correct."""
    keep = (rank < m[..., None]).astype(coeff_vals.dtype)
    return gbatc_correct_batched_ref(x_rec, coeff_vals * keep, basis)
