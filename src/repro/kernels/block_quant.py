"""Pallas fused block quantize->dequantize (TPU target; interpret-validated).

The GBATC pipeline quantizes latents/coefficients (host entropy coding
follows); the serving path quantizes KV blocks; gradient compression
quantizes bucket blocks. All three share this bandwidth-bound primitive:
per-block symmetric scale + round + clamp + dequant in one VMEM pass (a
single HBM round-trip instead of three).

Grid tiles the leading axis; each program handles a (rows, K) tile and its
K/block sub-blocks entirely in registers/VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bq_kernel(x_ref, out_ref, scale_ref, *, n_bits, block):
    x = x_ref[...].astype(jnp.float32)  # (rows, K)
    rows, k = x.shape
    xb = x.reshape(rows, k // block, block)
    qmax = float(2 ** (n_bits - 1) - 1)
    scale = jnp.maximum(jnp.max(jnp.abs(xb), axis=-1, keepdims=True), 1e-30) / qmax
    q = jnp.clip(jnp.round(xb / scale), -qmax - 1.0, qmax)
    out_ref[...] = (q * scale).reshape(rows, k).astype(out_ref.dtype)
    scale_ref[...] = scale[..., 0]


def block_quant(
    x: jax.Array,  # (..., K), K % block == 0
    *,
    n_bits: int = 8,
    block: int = 64,
    rows_per_tile: int = 256,
    interpret: bool = False,
):
    """Returns (dequantized x, per-block scales (..., K/block))."""
    orig_shape = x.shape
    k = orig_shape[-1]
    assert k % block == 0, (k, block)
    rows = int(x.size // k)
    xr = x.reshape(rows, k)
    rt = min(rows_per_tile, rows)
    pad = -rows % rt
    if pad:
        xr = jnp.pad(xr, ((0, pad), (0, 0)))
    rp = rows + pad

    out, scale = pl.pallas_call(
        functools.partial(_bq_kernel, n_bits=n_bits, block=block),
        grid=(rp // rt,),
        in_specs=[pl.BlockSpec((rt, k), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((rt, k), lambda i: (i, 0)),
            pl.BlockSpec((rt, k // block), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rp, k), x.dtype),
            jax.ShapeDtypeStruct((rp, k // block), jnp.float32),
        ],
        interpret=interpret,
    )(xr)
    out = out[:rows].reshape(orig_shape)
    scale = scale[:rows].reshape(orig_shape[:-1] + (k // block,))
    return out, scale
