"""Pallas chunked RG-LRU scan (TPU target; validated with interpret=True).

Diagonal linear recurrence h_t = a_t * h_{t-1} + b_t (Griffin's RG-LRU after
gating), per channel. Within a chunk of C steps with la = log a (<= 0),
cum_t = sum_{j<=t} la_j:

  h_t = e^{cum_t} h_0 + sum_{s<=t} e^{cum_t - cum_s} b_s

All exponents are pairwise differences <= 0 -> unconditionally stable.
Grid = (B, W/bw); chunks walked sequentially with the (bw,) carry; the (C, C)
pairwise weight tensor per channel block stays in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rglru_kernel(la_ref, b_ref, h0_ref, h_ref, hT_ref, *, chunk, t):
    n_chunks = t // chunk
    tri = (
        jax.lax.iota(jnp.int32, chunk)[:, None]
        >= jax.lax.iota(jnp.int32, chunk)[None, :]
    )

    def body(ci, h0):
        # length-1 dslice on the lead dim: a bare int index does not
        # discharge under interpret mode on current JAX
        sl = (pl.dslice(0, 1), pl.dslice(ci * chunk, chunk), slice(None))
        la = pl.load(la_ref, sl)[0].astype(jnp.float32)  # (C, bw)
        bb = pl.load(b_ref, sl)[0].astype(jnp.float32)
        cum = jnp.cumsum(la, axis=0)
        # pairwise decay weights e^{cum_t - cum_s} for s <= t
        pair = cum[:, None, :] - cum[None, :, :] + la[None, :, :] * 0.0
        # note: sum_{j=s+1..t} la_j = cum_t - cum_s
        w = jnp.where(tri[:, :, None], jnp.exp(pair), 0.0)  # (C, C, bw)
        h = jnp.exp(cum) * h0[None, :] + jnp.einsum("tsw,sw->tw", w, bb)
        pl.store(h_ref, sl, h[None].astype(h_ref.dtype))
        return h[-1]

    hT = jax.lax.fori_loop(0, n_chunks, body, h0_ref[0].astype(jnp.float32))
    hT_ref[0] = hT.astype(hT_ref.dtype)


def rglru_scan(
    a: jax.Array,  # (B, T, W) decay in (0, 1]
    b: jax.Array,  # (B, T, W) input term
    h0: jax.Array | None = None,  # (B, W)
    *,
    chunk: int = 64,
    block_w: int = 128,
    interpret: bool = False,
):
    """Returns (h (B, T, W), h_T (B, W))."""
    bb_, t, w = a.shape
    if h0 is None:
        h0 = jnp.zeros((bb_, w), jnp.float32)
    pad_t = -t % chunk
    if pad_t:
        a = jnp.pad(a, ((0, 0), (0, pad_t), (0, 0)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad_t), (0, 0)))
    pad_w = -w % block_w
    if pad_w:
        a = jnp.pad(a, ((0, 0), (0, 0), (0, pad_w)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, 0), (0, pad_w)))
        h0 = jnp.pad(h0, ((0, 0), (0, pad_w)))
    tp, wp = t + pad_t, w + pad_w
    bw = min(block_w, wp)

    la = jnp.log(jnp.clip(a.astype(jnp.float32), 1e-37, 1.0))
    h, hT = pl.pallas_call(
        functools.partial(_rglru_kernel, chunk=chunk, t=tp),
        grid=(bb_, wp // bw),
        in_specs=[
            pl.BlockSpec((1, tp, bw), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, tp, bw), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, bw), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, tp, bw), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, bw), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bb_, tp, wp), a.dtype),
            jax.ShapeDtypeStruct((bb_, wp), jnp.float32),
        ],
        interpret=interpret,
    )(la, b, h0)
    return h[:, :t, :w], hT[:, :w]
