"""Pallas GBATC residual-projection kernels (TPU target; interpret-validated).

The guarantee post-process is dominated by two tall-skinny GEMMs over
millions of D=80 blocks per species:

  project: C   = R @ U            (coefficients, eq. 1)
  correct: x^G = x^R + (C.mask) @ U^T   (eq. 2)

TPU adaptation: D=80 is padded to 128 (MXU lane width) by the wrapper; U
(128x128 fp32 = 64 KiB) is VMEM-resident and reused across all row tiles —
the kernel is then purely bandwidth-bound on R, which is the roofline
optimum for this shape.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _project_kernel(r_ref, u_ref, c_ref):
    r = r_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    c_ref[...] = jax.lax.dot_general(
        r, u, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(c_ref.dtype)


def _correct_kernel(x_ref, c_ref, m_ref, u_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    cm = c_ref[...].astype(jnp.float32) * m_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    o_ref[...] = (
        x + jax.lax.dot_general(
            cm, u, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
    ).astype(o_ref.dtype)


def _pad_to(x, target, axis):
    pad = target - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def gbatc_project(
    residual: jax.Array,  # (NB, D)
    basis: jax.Array,  # (D, D) orthonormal columns
    *,
    rows_per_tile: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """c = R @ U, blocked over rows; returns (NB, D) fp32."""
    nb, d = residual.shape
    dp = max(128, -(-d // 128) * 128)
    r = _pad_to(_pad_to(residual, dp, 1), -(-nb // rows_per_tile) * rows_per_tile, 0)
    u = _pad_to(_pad_to(basis, dp, 0), dp, 1)
    rp = r.shape[0]
    rt = min(rows_per_tile, rp)

    c = pl.pallas_call(
        _project_kernel,
        grid=(rp // rt,),
        in_specs=[
            pl.BlockSpec((rt, dp), lambda i: (i, 0)),
            pl.BlockSpec((dp, dp), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rt, dp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, dp), jnp.float32),
        interpret=interpret,
    )(r, u)
    return c[:nb, :d]


def gbatc_correct(
    x_rec: jax.Array,  # (NB, D)
    coeffs: jax.Array,  # (NB, D)
    mask: jax.Array,  # (NB, D) 0/1 keep mask
    basis: jax.Array,  # (D, D)
    *,
    rows_per_tile: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """x^G = x^R + (coeffs * mask) @ U^T."""
    nb, d = x_rec.shape
    dp = max(128, -(-d // 128) * 128)
    rp = -(-nb // rows_per_tile) * rows_per_tile
    x = _pad_to(_pad_to(x_rec, dp, 1), rp, 0)
    c = _pad_to(_pad_to(coeffs, dp, 1), rp, 0)
    m = _pad_to(_pad_to(mask, dp, 1), rp, 0)
    u = _pad_to(_pad_to(basis, dp, 0), dp, 1)
    rt = min(rows_per_tile, rp)

    out = pl.pallas_call(
        _correct_kernel,
        grid=(rp // rt,),
        in_specs=[
            pl.BlockSpec((rt, dp), lambda i: (i, 0)),
            pl.BlockSpec((rt, dp), lambda i: (i, 0)),
            pl.BlockSpec((rt, dp), lambda i: (i, 0)),
            pl.BlockSpec((dp, dp), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rt, dp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, dp), jnp.float32),
        interpret=interpret,
    )(x, c, m, u)
    return out[:nb, :d]
