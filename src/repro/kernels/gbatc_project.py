"""Pallas GBATC residual-projection kernels (TPU target; interpret-validated).

The guarantee post-process is dominated by two tall-skinny GEMMs over
millions of D=80 blocks per species:

  project: C   = R @ U            (coefficients, eq. 1)
  correct: x^G = x^R + (C.mask) @ U^T   (eq. 2)

TPU adaptation: D=80 is padded to 128 (MXU lane width); U (128x128 fp32 =
64 KiB) is VMEM-resident and reused across all row tiles — the kernels are
then purely bandwidth-bound on R, which is the roofline optimum for this
shape.

Two tiers of API:

* 2D single-species (``gbatc_project`` / ``gbatc_correct``) — the original
  kernels, kept for checkpoint compression and as the simplest contract.
* 3D batched-over-species (``*_batched``) — one dispatch for the whole
  (S, NB, D) problem with a per-species basis stack (S, D, D). The grid is
  (species tiles, row tiles); on CPU interpret mode the guarantee engine
  collapses it to a single step (species_per_tile=S, rows_per_tile=NB) so
  the interpreter overhead is paid once per call.

``gbatc_select_accumulate`` fuses Algorithm 1's masked select-and-accumulate:
given quantized coefficient values, each element's rank in the per-block
energy order, and the per-block cut M, it forms the keep mask in-registers
and applies the correction GEMM without ever materialising the masked
coefficient tensor in HBM.

All kernels compute in the dtype of their inputs (fp32 on the MXU path;
fp64 under interpret mode, where the guarantee engine needs bit-stable
quantization against the fp64 numpy oracle).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pad_to(x, target, axis):
    pad = target - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def _lane(d: int, interpret: bool, lane: int | None) -> int:
    """Padded feature width: MXU lane width on TPU, sublane-aligned under
    interpret (where any shape works and padding only wastes flops)."""
    if lane is None:
        lane = 128 if not interpret else 8
    return max(lane, _round_up(d, lane))


# ---------------------------------------------------------------------------
# 2D single-species kernels (original contract)
# ---------------------------------------------------------------------------


def _project_kernel(r_ref, u_ref, c_ref):
    r = r_ref[...]
    u = u_ref[...]
    c_ref[...] = jax.lax.dot_general(
        r, u, (((1,), (0,)), ((), ())), preferred_element_type=c_ref.dtype
    ).astype(c_ref.dtype)


def _correct_kernel(x_ref, c_ref, m_ref, u_ref, o_ref):
    x = x_ref[...]
    cm = c_ref[...] * m_ref[...].astype(c_ref.dtype)
    u = u_ref[...]
    o_ref[...] = (
        x + jax.lax.dot_general(
            cm, u, (((1,), (1,)), ((), ())), preferred_element_type=o_ref.dtype
        ).astype(o_ref.dtype)
    )


def gbatc_project(
    residual: jax.Array,  # (NB, D)
    basis: jax.Array,  # (D, D) orthonormal columns
    *,
    rows_per_tile: int = 512,
    interpret: bool = False,
    lane: int | None = None,
) -> jax.Array:
    """c = R @ U, blocked over rows; returns (NB, D) in the input dtype."""
    nb, d = residual.shape
    dtype = jnp.result_type(residual.dtype, basis.dtype)
    dp = _lane(d, interpret, lane)
    r = _pad_to(_pad_to(residual.astype(dtype), dp, 1),
                _round_up(nb, rows_per_tile), 0)
    u = _pad_to(_pad_to(basis.astype(dtype), dp, 0), dp, 1)
    rp = r.shape[0]
    rt = min(rows_per_tile, rp)

    c = pl.pallas_call(
        _project_kernel,
        grid=(rp // rt,),
        in_specs=[
            pl.BlockSpec((rt, dp), lambda i: (i, 0)),
            pl.BlockSpec((dp, dp), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rt, dp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, dp), dtype),
        interpret=interpret,
    )(r, u)
    return c[:nb, :d]


def gbatc_correct(
    x_rec: jax.Array,  # (NB, D)
    coeffs: jax.Array,  # (NB, D)
    mask: jax.Array,  # (NB, D) 0/1 keep mask
    basis: jax.Array,  # (D, D)
    *,
    rows_per_tile: int = 512,
    interpret: bool = False,
    lane: int | None = None,
) -> jax.Array:
    """x^G = x^R + (coeffs * mask) @ U^T."""
    nb, d = x_rec.shape
    dtype = jnp.result_type(x_rec.dtype, coeffs.dtype, basis.dtype)
    dp = _lane(d, interpret, lane)
    rp = _round_up(nb, rows_per_tile)
    x = _pad_to(_pad_to(x_rec.astype(dtype), dp, 1), rp, 0)
    c = _pad_to(_pad_to(coeffs.astype(dtype), dp, 1), rp, 0)
    m = _pad_to(_pad_to(mask, dp, 1), rp, 0)
    u = _pad_to(_pad_to(basis.astype(dtype), dp, 0), dp, 1)
    rt = min(rows_per_tile, rp)

    out = pl.pallas_call(
        _correct_kernel,
        grid=(rp // rt,),
        in_specs=[
            pl.BlockSpec((rt, dp), lambda i: (i, 0)),
            pl.BlockSpec((rt, dp), lambda i: (i, 0)),
            pl.BlockSpec((rt, dp), lambda i: (i, 0)),
            pl.BlockSpec((dp, dp), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rt, dp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, dp), dtype),
        interpret=interpret,
    )(x, c, m, u)
    return out[:nb, :d]


# ---------------------------------------------------------------------------
# Batched-over-species kernels: one dispatch for (S, NB, D)
# ---------------------------------------------------------------------------

_BATCH_DIMS = (((2,), (1,)), ((0,), (0,)))  # (s,n,d) @ (s,d,k) -> (s,n,k)
_BATCH_DIMS_T = (((2,), (2,)), ((0,), (0,)))  # (s,n,k) @ (s,d,k) -> (s,n,d)


def _project_batched_kernel(r_ref, u_ref, c_ref):
    c_ref[...] = jax.lax.dot_general(
        r_ref[...], u_ref[...], _BATCH_DIMS, preferred_element_type=c_ref.dtype
    ).astype(c_ref.dtype)


def _correct_batched_kernel(x_ref, c_ref, u_ref, o_ref):
    o_ref[...] = x_ref[...] + jax.lax.dot_general(
        c_ref[...], u_ref[...], _BATCH_DIMS_T, preferred_element_type=o_ref.dtype
    ).astype(o_ref.dtype)


def _select_accumulate_kernel(x_ref, c_ref, rank_ref, m_ref, u_ref, o_ref):
    keep = rank_ref[...] < m_ref[...][..., None]
    cm = c_ref[...] * keep.astype(c_ref.dtype)
    o_ref[...] = x_ref[...] + jax.lax.dot_general(
        cm, u_ref[...], _BATCH_DIMS_T, preferred_element_type=o_ref.dtype
    ).astype(o_ref.dtype)


def _batched_tiles(s, nb, species_per_tile, rows_per_tile):
    spt = s if species_per_tile is None else min(species_per_tile, s)
    rpt = nb if rows_per_tile is None else min(rows_per_tile, nb)
    return spt, rpt, _round_up(s, spt), _round_up(nb, rpt)


def gbatc_project_batched(
    residual: jax.Array,  # (S, NB, D)
    basis: jax.Array,  # (S, D, D) per-species orthonormal columns
    *,
    species_per_tile: int | None = None,
    rows_per_tile: int | None = None,
    interpret: bool = False,
    lane: int | None = None,
) -> jax.Array:
    """Per-species c_s = R_s @ U_s in one dispatch; returns (S, NB, D)."""
    s, nb, d = residual.shape
    dtype = jnp.result_type(residual.dtype, basis.dtype)
    dp = _lane(d, interpret, lane)
    spt, rpt, sp, rp = _batched_tiles(s, nb, species_per_tile, rows_per_tile)
    r = _pad_to(_pad_to(_pad_to(residual.astype(dtype), dp, 2), rp, 1), sp, 0)
    u = _pad_to(_pad_to(_pad_to(basis.astype(dtype), dp, 1), dp, 2), sp, 0)

    c = pl.pallas_call(
        _project_batched_kernel,
        grid=(sp // spt, rp // rpt),
        in_specs=[
            pl.BlockSpec((spt, rpt, dp), lambda i, j: (i, j, 0)),
            pl.BlockSpec((spt, dp, dp), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((spt, rpt, dp), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((sp, rp, dp), dtype),
        interpret=interpret,
    )(r, u)
    return c[:s, :nb, :d]


def gbatc_correct_batched(
    x_rec: jax.Array,  # (S, NB, D)
    coeffs: jax.Array,  # (S, NB, D) — already masked/dequantized
    basis: jax.Array,  # (S, D, D)
    *,
    species_per_tile: int | None = None,
    rows_per_tile: int | None = None,
    interpret: bool = False,
    lane: int | None = None,
) -> jax.Array:
    """Per-species x^G_s = x^R_s + C_s @ U_s^T in one dispatch."""
    s, nb, d = x_rec.shape
    dtype = jnp.result_type(x_rec.dtype, coeffs.dtype, basis.dtype)
    dp = _lane(d, interpret, lane)
    spt, rpt, sp, rp = _batched_tiles(s, nb, species_per_tile, rows_per_tile)
    x = _pad_to(_pad_to(_pad_to(x_rec.astype(dtype), dp, 2), rp, 1), sp, 0)
    c = _pad_to(_pad_to(_pad_to(coeffs.astype(dtype), dp, 2), rp, 1), sp, 0)
    u = _pad_to(_pad_to(_pad_to(basis.astype(dtype), dp, 1), dp, 2), sp, 0)

    out = pl.pallas_call(
        _correct_batched_kernel,
        grid=(sp // spt, rp // rpt),
        in_specs=[
            pl.BlockSpec((spt, rpt, dp), lambda i, j: (i, j, 0)),
            pl.BlockSpec((spt, rpt, dp), lambda i, j: (i, j, 0)),
            pl.BlockSpec((spt, dp, dp), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((spt, rpt, dp), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((sp, rp, dp), dtype),
        interpret=interpret,
    )(x, c, u)
    return out[:s, :nb, :d]


def gbatc_select_accumulate(
    x_rec: jax.Array,  # (S, NB, D)
    coeff_vals: jax.Array,  # (S, NB, D) dequantized coefficient values
    rank: jax.Array,  # (S, NB, D) int32 energy-order rank of each element
    m: jax.Array,  # (S, NB) int32 per-block cut: keep rank < m
    basis: jax.Array,  # (S, D, D)
    *,
    species_per_tile: int | None = None,
    rows_per_tile: int | None = None,
    interpret: bool = False,
    lane: int | None = None,
) -> jax.Array:
    """Fused Algorithm-1 tail: x^G = x^R + (C * [rank < m]) @ U^T.

    The keep mask never leaves registers/VMEM — this is the "masked
    select-and-accumulate" of the guarantee engine's decode-free hot path.
    """
    s, nb, d = x_rec.shape
    dtype = jnp.result_type(x_rec.dtype, coeff_vals.dtype, basis.dtype)
    dp = _lane(d, interpret, lane)
    spt, rpt, sp, rp = _batched_tiles(s, nb, species_per_tile, rows_per_tile)
    x = _pad_to(_pad_to(_pad_to(x_rec.astype(dtype), dp, 2), rp, 1), sp, 0)
    c = _pad_to(_pad_to(_pad_to(coeff_vals.astype(dtype), dp, 2), rp, 1), sp, 0)
    # pad ranks with a sentinel above any valid cut so padded lanes drop out
    rk = jnp.pad(
        rank.astype(jnp.int32),
        [(0, sp - s), (0, rp - nb), (0, dp - d)],
        constant_values=jnp.iinfo(jnp.int32).max,
    )
    mm = _pad_to(m.astype(jnp.int32), rp, 1)
    mm = _pad_to(mm, sp, 0)
    u = _pad_to(_pad_to(_pad_to(basis.astype(dtype), dp, 1), dp, 2), sp, 0)

    out = pl.pallas_call(
        _select_accumulate_kernel,
        grid=(sp // spt, rp // rpt),
        in_specs=[
            pl.BlockSpec((spt, rpt, dp), lambda i, j: (i, j, 0)),
            pl.BlockSpec((spt, rpt, dp), lambda i, j: (i, j, 0)),
            pl.BlockSpec((spt, rpt, dp), lambda i, j: (i, j, 0)),
            pl.BlockSpec((spt, rpt), lambda i, j: (i, j)),
            pl.BlockSpec((spt, dp, dp), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((spt, rpt, dp), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((sp, rp, dp), dtype),
        interpret=interpret,
    )(x, c, rk, mm, u)
    return out[:s, :nb, :d]
