"""Pallas flash attention (TPU target; validated with interpret=True on CPU).

TPU adaptation of the FlashAttention-2 schedule:
  * grid = (batch*heads, q_blocks); each program owns one (Bq, D) query tile
    resident in VMEM and streams K/V tiles, keeping running (max, sum, acc)
    statistics in fp32 — no (Tq, Tk) score matrix ever touches HBM;
  * tiles are MXU-aligned: Bq/Bk multiples of 128 on the lane axis (D is
    padded to 128 by the wrapper when needed), fp32 accumulation, bf16 I/O;
  * causal + sliding-window masks are computed from the tile coordinates, and
    fully-masked K tiles are skipped by bounding the inner loop
    (``hi = min(q_block_end, kv_len)`` under causality);
  * K/V are staged per (batch*head) as full-length VMEM blocks — fine for the
    Tk*D*4 bytes <= VMEM/2 regime the tests sweep (up to 8k*128); beyond
    that, the BlockSpec pipeline would stream K/V tiles from HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, bq, bk, causal, window, tk):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)  # (bq, d)
    d = q.shape[-1]
    scale = 1.0 / np.sqrt(d)
    q_pos = qi * bq + jax.lax.iota(jnp.int32, bq)

    n_k = tk // bk
    if causal:
        # K tiles strictly above the diagonal band contribute nothing.
        hi = jnp.minimum(n_k, ((qi + 1) * bq + bk - 1) // bk)
    else:
        hi = n_k

    def body(ki, carry):
        m, l, acc = carry
        # leading dim indexed with a length-1 dslice: a bare int index does
        # not discharge under interpret mode on current JAX
        k = pl.load(k_ref, (pl.dslice(0, 1), pl.dslice(ki * bk, bk), slice(None)))[
            0
        ].astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(0, 1), pl.dslice(ki * bk, bk), slice(None)))[
            0
        ].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)
        k_pos = ki * bk + jax.lax.iota(jnp.int32, bk)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window > 0:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=1)
        acc_new = acc * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        return m_new, l_new, acc_new

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, d), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, hi, body, (m0, l0, acc0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # (B, H, Tq, D)
    k: jax.Array,  # (B, H, Tk, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, h, tq, d = q.shape
    tk = k.shape[2]
    bq = min(block_q, tq)
    bk = min(block_k, tk)
    # pad sequence lengths to tile multiples (wrapper strips afterwards)
    pq = -tq % bq
    pk = -tk % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        # padded K positions must never win the max: rely on causal/window
        # masks plus an explicit length mask via NEG_INF scores from zero
        # keys; zero keys give score 0 which IS attendable -> mask by pos.
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    tq_p, tk_p = tq + pq, tk + pk

    qr = q.reshape(b * h, tq_p, d)
    kr = k.reshape(b * h, tk_p, d)
    vr = v.reshape(b * h, tk_p, d)

    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, causal=causal,
        window=window if window > 0 else (0 if causal else _len_window(tk, pk)),
        tk=tk_p,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * h, tq_p // bq),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi: (bh, qi, 0)),
            pl.BlockSpec((1, tk_p, d), lambda bh, qi: (bh, 0, 0)),
            pl.BlockSpec((1, tk_p, d), lambda bh, qi: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, tq_p, d), q.dtype),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, tq_p, d)[:, :, :tq]


def _len_window(tk: int, pk: int) -> int:
    """Non-causal + padded K: emulate a validity mask with a window that
    excludes the padded tail (window counts back from the *query* position,
    so for bidirectional use we instead rely on no padding: assert)."""
    if pk:
        raise NotImplementedError(
            "non-causal flash path requires Tk % block_k == 0"
        )
    return 0
