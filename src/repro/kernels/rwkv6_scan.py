"""Pallas chunked WKV6 scan (TPU target; validated with interpret=True).

TPU adaptation of the RWKV-6 recurrence (the reference CUDA kernel is a
per-timestep serial loop; on TPU we use the *chunked matrix form* so the MXU
does the work):

Within a chunk of C tokens (per head, head dim N), with per-channel decays
w_t in (0,1] and logs lw_t = log w_t <= 0, cum_t = sum_{j<=t} lw_j:

  out_t = r_t diag(exp(cum_{t-1})) S_0                      (state term)
        + sum_{s<t} [sum_i r_t[i] e^{cum_{t-1}[i]-cum_s[i]} k_s[i]] v_s
        + (sum_i r_t[i] u[i] k_t[i]) v_t                    (bonus diagonal)
  S_C   = diag(exp(cum_C)) S_0 + sum_s diag(e^{cum_C-cum_s}) k_s v_s^T

Every exponent is <= 0 (pairwise differences along the decay), so the chunked
form is *unconditionally* stable — no division by vanishing cumulative decay
(the failure mode of the naive k/P formulation).

Grid = (B*H,); each program walks its chunks sequentially carrying the (N, N)
fp32 state in the fori_loop carry (VMEM-resident); parallelism comes from the
B*H grid axis and the MXU within chunks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wkv6_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref, o_ref, sT_ref,
                 *, chunk, t):
    u = u_ref[0].astype(jnp.float32)  # (N,)
    n_chunks = t // chunk
    tri = (
        jax.lax.iota(jnp.int32, chunk)[:, None]
        > jax.lax.iota(jnp.int32, chunk)[None, :]
    )

    def body(ci, s):
        # length-1 dslice on the lead dim: a bare int index does not
        # discharge under interpret mode on current JAX
        sl = (pl.dslice(0, 1), pl.dslice(ci * chunk, chunk), slice(None))
        r = pl.load(r_ref, sl)[0].astype(jnp.float32)  # (C, N)
        k = pl.load(k_ref, sl)[0].astype(jnp.float32)
        v = pl.load(v_ref, sl)[0].astype(jnp.float32)
        lw = pl.load(lw_ref, sl)[0].astype(jnp.float32)
        cum = jnp.cumsum(lw, axis=0)  # inclusive prefix
        cum_prev = cum - lw  # exclusive prefix (cum_{t-1})

        # state term: (r * e^{cum_prev}) @ S
        out = jax.lax.dot_general(
            r * jnp.exp(cum_prev), s, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (C, N_v)
        # intra-chunk pairwise-decay scores (all exponents <= 0 where used)
        pair = cum_prev[:, None, :] - cum[None, :, :]  # (C, C, N)
        weights = jnp.where(tri[:, :, None], jnp.exp(pair), 0.0)
        scores = jnp.einsum("ti,tsi,si->ts", r, weights, k)
        diag = jnp.sum(r * u[None, :] * k, axis=1)  # (C,) bonus term
        out = out + jax.lax.dot_general(
            scores, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) + diag[:, None] * v
        pl.store(o_ref, sl, out[None].astype(o_ref.dtype))

        # chunk-boundary state update (exponents <= 0)
        k_w = k * jnp.exp(cum[-1][None, :] - cum)  # (C, N)
        s_new = jnp.exp(cum[-1])[:, None] * s + jax.lax.dot_general(
            k_w, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        return s_new

    sT = jax.lax.fori_loop(0, n_chunks, body, s0_ref[0].astype(jnp.float32))
    sT_ref[0] = sT.astype(sT_ref.dtype)


def rwkv6_scan(
    r: jax.Array,  # (B, T, H, N)
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,  # decays in (0, 1]
    u: jax.Array,  # (H, N)
    s0: jax.Array | None = None,  # (B, H, N, N)
    *,
    chunk: int = 32,
    interpret: bool = False,
):
    """Returns (out (B, T, H, N), final state (B, H, N, N))."""
    b, t, h, n = r.shape
    if s0 is None:
        s0 = jnp.zeros((b, h, n, n), jnp.float32)
    pad = -t % chunk
    if pad:
        zeros = ((0, 0), (0, pad), (0, 0), (0, 0))
        r, k, v = (jnp.pad(a, zeros) for a in (r, k, v))
        w = jnp.pad(w, zeros, constant_values=1.0)
    tp = t + pad

    def to_bh(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, tp, n)

    lw = jnp.log(jnp.clip(w.astype(jnp.float32), 1e-37, 1.0))
    rr, kk, vv, lww = to_bh(r), to_bh(k), to_bh(v), to_bh(lw)
    uu = jnp.tile(u.astype(jnp.float32), (b, 1)).reshape(b * h, n)
    ss = s0.reshape(b * h, n, n)

    seq_spec = pl.BlockSpec((1, tp, n), lambda i: (i, 0, 0))
    out, sT = pl.pallas_call(
        functools.partial(_wkv6_kernel, chunk=chunk, t=tp),
        grid=(b * h,),
        in_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((1, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n, n), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            seq_spec,
            pl.BlockSpec((1, n, n), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, tp, n), r.dtype),
            jax.ShapeDtypeStruct((b * h, n, n), jnp.float32),
        ],
        interpret=interpret,
    )(rr, kk, vv, lww, uu, ss)
    out = out.reshape(b, h, tp, n).transpose(0, 2, 1, 3)[:, :t]
    return out, sT.reshape(b, h, n, n)
