"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU (kernel body executes in Python for
validation) and False on TPU (compiled). Models select the kernel path via
``ArchConfig.use_kernels``.
"""

from __future__ import annotations

import functools

import jax

from repro.kernels.block_quant import block_quant
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gbatc_project import gbatc_correct, gbatc_project
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.rwkv6_scan import rwkv6_scan


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k"))
def flash_attention_op(q, k, v, *, causal=True, window=0, block_q=128,
                       block_k=128):
    return flash_attention(
        q, k, v, causal=causal, window=window, block_q=block_q,
        block_k=block_k, interpret=_default_interpret(),
    )


@functools.partial(jax.jit, static_argnames=("chunk",))
def rwkv6_scan_op(r, k, v, w, u, s0=None, *, chunk=32):
    return rwkv6_scan(r, k, v, w, u, s0, chunk=chunk,
                      interpret=_default_interpret())


@functools.partial(jax.jit, static_argnames=("chunk", "block_w"))
def rglru_scan_op(a, b, h0=None, *, chunk=64, block_w=128):
    return rglru_scan(a, b, h0, chunk=chunk, block_w=block_w,
                      interpret=_default_interpret())


@functools.partial(jax.jit, static_argnames=("n_bits", "block",
                                             "rows_per_tile"))
def block_quant_op(x, *, n_bits=8, block=64, rows_per_tile=256):
    return block_quant(x, n_bits=n_bits, block=block,
                       rows_per_tile=rows_per_tile,
                       interpret=_default_interpret())


@functools.partial(jax.jit, static_argnames=("rows_per_tile",))
def gbatc_project_op(residual, basis, *, rows_per_tile=512):
    return gbatc_project(residual, basis, rows_per_tile=rows_per_tile,
                         interpret=_default_interpret())


@functools.partial(jax.jit, static_argnames=("rows_per_tile",))
def gbatc_correct_op(x_rec, coeffs, mask, basis, *, rows_per_tile=512):
    return gbatc_correct(x_rec, coeffs, mask, basis,
                         rows_per_tile=rows_per_tile,
                         interpret=_default_interpret())
