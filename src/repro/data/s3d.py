"""Synthetic S3D HCCI surrogate (paper §III dataset stand-in).

The real dataset — 640x640 grid, 50 time steps (t = 1.5..2.0 ms), 58-species
reduced n-heptane mechanism — is not distributable, so the reproduction runs
on a calibrated surrogate that preserves exactly the structure GBATC exploits
and SZ competes on:

* smooth spatial fields with turbulent-like spectra (k^-beta Gaussian random
  fields) advected over time -> strong spatiotemporal correlation;
* an ignition progress variable with spatially varying delay -> moving sharp
  fronts and exponential species growth/decay (the paper's "values may
  increase or decrease exponentially");
* species constructed as nonlinear responses of a handful of latent fields
  (mixture fraction, progress, strain, temperature) with random per-species
  parameters -> low intrinsic dimensionality but high *linear* rank (the
  paper reports rank 46/58 for NRMSE 1e-3), majors O(1e-1) and minors down to
  O(1e-8) with mid-ignition bumps.

`generate` returns the (S, T, H, W) mass-fraction array plus the temperature
field used by the QoI surrogate.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class S3DConfig:
    n_species: int = 58
    n_time: int = 50
    height: int = 640
    width: int = 640
    seed: int = 0
    # spectral slope of the random fields (3D turbulence-like)
    beta: float = 3.0
    # fraction of species treated as majors (smooth, O(1) mass fraction)
    major_frac: float = 0.15

    def scaled(self, *, n_species=16, n_time=24, height=80, width=80) -> "S3DConfig":
        return dataclasses.replace(
            self, n_species=n_species, n_time=n_time, height=height, width=width
        )


PAPER_CONFIG = S3DConfig()
# Test/CI-scale config: divisible by the paper block geometry (4, 5, 4).
SMALL_CONFIG = S3DConfig(n_species=16, n_time=24, height=80, width=80, seed=0)


def _grf(rng: np.random.Generator, h: int, w: int, beta: float) -> np.ndarray:
    """Gaussian random field with k^-beta spectrum, unit std."""
    kx = np.fft.fftfreq(h)[:, None]
    ky = np.fft.fftfreq(w)[None, :]
    k = np.sqrt(kx**2 + ky**2)
    k[0, 0] = 1.0
    amp = k ** (-beta / 2.0)
    amp[0, 0] = 0.0
    noise = rng.normal(size=(h, w)) + 1j * rng.normal(size=(h, w))
    field = np.fft.ifft2(noise * amp).real
    field -= field.mean()
    std = field.std()
    return field / (std if std > 0 else 1.0)


def _advect(field: np.ndarray, shift_y: float, shift_x: float) -> np.ndarray:
    """Periodic sub-pixel advection via Fourier phase shift."""
    h, w = field.shape
    fy = np.fft.fftfreq(h)[:, None]
    fx = np.fft.fftfreq(w)[None, :]
    phase = np.exp(-2j * np.pi * (fy * shift_y + fx * shift_x))
    return np.fft.ifft2(np.fft.fft2(field) * phase).real


def _base_fields(cfg: S3DConfig) -> dict[str, np.ndarray]:
    """Time-independent latent fields + drift — all of ``generate``'s rng
    consumption, in the exact draw order, so any frame subset derived from
    these is bit-identical to the corresponding slice of a full run."""
    rng = np.random.default_rng(cfg.seed)
    h, w = cfg.height, cfg.width
    mixture = _grf(rng, h, w, cfg.beta)  # mixture fraction Z
    strain = _grf(rng, h, w, cfg.beta)  # local strain proxy
    modulation = _grf(rng, h, w, cfg.beta - 0.5)  # extra rank-raising mode
    # spatially varying ignition delay in [0.25, 0.75] of the window,
    # correlated with mixture and strain (rich/strained pockets ignite late)
    delay = 0.5 + 0.12 * mixture + 0.08 * strain
    width_ign = 0.06 * (1.0 + 0.3 * np.tanh(modulation))
    drift = rng.normal(scale=0.8, size=(2,))
    return {
        "mixture": mixture, "strain": strain, "modulation": modulation,
        "delay": delay, "width_ign": width_ign, "drift": drift,
    }


def _frame_fields(cfg: S3DConfig, base: dict, t0: int, t1: int):
    """(progress, mix, strain, mod) advected fields for frames [t0, t1).

    Every frame is an independent function of the base fields and its own
    time value, so a window is bitwise the slice of the full series.
    """
    h, w = cfg.height, cfg.width
    drift = base["drift"]
    times = np.linspace(0.0, 1.0, cfg.n_time)[t0:t1]
    t = len(times)
    progress = np.empty((t, h, w), dtype=np.float64)
    mix_t = np.empty((t, h, w), dtype=np.float64)
    strain_t = np.empty((t, h, w), dtype=np.float64)
    mod_t = np.empty((t, h, w), dtype=np.float64)
    for i, tt in enumerate(times):
        mix_t[i] = _advect(base["mixture"], drift[0] * tt * h * 0.02,
                           drift[1] * tt * w * 0.02)
        strain_t[i] = _advect(base["strain"], -drift[1] * tt * h * 0.015,
                              drift[0] * tt * w * 0.015)
        mod_t[i] = _advect(base["modulation"], drift[0] * tt * h * 0.01,
                           -drift[0] * tt * w * 0.02)
        progress[i] = 1.0 / (1.0 + np.exp(-(tt - base["delay"])
                                          / base["width_ign"]))
    return progress, mix_t, strain_t, mod_t


def _species_responses(cfg: S3DConfig, progress, mix_t, strain_t, mod_t
                       ) -> np.ndarray:
    """Per-species nonlinear responses over the given frames (elementwise
    in time, so chunked evaluation is bitwise equal to full)."""
    s = cfg.n_species
    n_major = max(2, int(round(cfg.major_frac * s)))
    species = np.empty((s, *progress.shape), dtype=np.float32)
    c = progress
    z = mix_t
    st = strain_t
    md = mod_t
    for j in range(s):
        rj = np.random.default_rng(cfg.seed * 1000 + 17 + j)
        if j == 0:  # fuel: consumed through ignition
            y = 0.06 * (1.0 - c) * (1.0 + 0.25 * z)
        elif j == 1:  # oxidizer
            y = 0.22 * (1.0 - 0.85 * c) * (1.0 - 0.1 * z)
        elif j < n_major:  # products (CO2/H2O/CO-like): grow with progress
            a = rj.uniform(0.02, 0.12)
            y = a * c * (1.0 + 0.2 * np.tanh(z + 0.3 * md))
        else:  # minors: exponential bumps around a per-species progress point
            logamp = rj.uniform(-8.0, -2.5)  # spans O(1e-8)..O(1e-3) peaks
            c0 = rj.uniform(0.15, 0.9)
            sig = rj.uniform(0.05, 0.25)
            sens = rj.uniform(1.0, 4.0)
            y = (10.0**logamp) * np.exp(
                -(((c - c0) / sig) ** 2) + sens * 0.3 * z + 0.2 * st
            )
        species[j] = y.astype(np.float32)
    return species


def generate(cfg: S3DConfig) -> dict[str, np.ndarray]:
    base = _base_fields(cfg)
    progress, mix_t, strain_t, mod_t = _frame_fields(cfg, base, 0, cfg.n_time)
    temperature = 900.0 + 1400.0 * progress + 40.0 * mix_t  # K
    species = _species_responses(cfg, progress, mix_t, strain_t, mod_t)
    return {
        "species": species,  # (S, T, H, W) float32 mass fractions
        "temperature": temperature.astype(np.float32),  # (T, H, W)
        "progress": progress.astype(np.float32),
    }


def generate_species_window(cfg: S3DConfig, t0: int, t1: int,
                            base: dict | None = None) -> np.ndarray:
    """Species mass fractions for frames ``[t0, t1)`` only.

    Bitwise equal to ``generate(cfg)["species"][:, t0:t1]`` while
    materializing just the window's frames (plus the (H, W) base fields) —
    the streaming producer behind :class:`S3DChunkLoader`. ``base``
    reuses precomputed :func:`_base_fields` across windows.
    """
    if not 0 <= t0 < t1 <= cfg.n_time:
        raise ValueError(
            f"frame window ({t0}, {t1}) outside [0, {cfg.n_time})"
        )
    if base is None:
        base = _base_fields(cfg)
    return _species_responses(cfg, *_frame_fields(cfg, base, t0, t1))


class S3DChunkLoader:
    """Re-iterable time-chunked view of the surrogate's species field.

    Feeds ``GBATCCodec.fit_stream`` / ``GBATCPipeline.fit_stream``: each
    ``chunks()`` pass yields consecutive ``(S, chunk_frames, H, W)``
    arrays (ragged tail allowed) that concatenate — bitwise — to
    ``generate(cfg)["species"]``, without the full field ever existing in
    memory. The time-independent base fields are computed once per loader;
    per-chunk cost is the window's frames only.
    """

    def __init__(self, cfg: S3DConfig, chunk_frames: int):
        if chunk_frames < 1:
            raise ValueError(f"chunk_frames must be >= 1, got {chunk_frames}")
        self.cfg = cfg
        self.chunk_frames = int(chunk_frames)
        self._base = _base_fields(cfg)

    @property
    def shape(self) -> tuple[int, int, int, int]:
        cfg = self.cfg
        return (cfg.n_species, cfg.n_time, cfg.height, cfg.width)

    @property
    def n_chunks(self) -> int:
        return -(-self.cfg.n_time // self.chunk_frames)

    def chunks(self):
        for t0 in range(0, self.cfg.n_time, self.chunk_frames):
            t1 = min(t0 + self.chunk_frames, self.cfg.n_time)
            yield generate_species_window(self.cfg, t0, t1, base=self._base)
