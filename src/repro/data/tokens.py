"""Deterministic, step-indexed synthetic token pipeline.

Batches are a pure function of (seed, step, shard) — exactly the property
fault-tolerant training needs: replaying a step after restore consumes the
identical batch, and elastic rescaling re-partitions deterministically.

The stream is a order-2 Markov chain over the vocab (so small models have
signal to learn, unlike uniform noise).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipelineConfig:
    vocab: int
    batch: int
    seq_len: int
    seed: int = 0
    n_shards: int = 1
    shard: int = 0


class TokenPipeline:
    def __init__(self, cfg: TokenPipelineConfig):
        assert cfg.batch % cfg.n_shards == 0
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        k = min(cfg.vocab, 64)
        self._proj = rng.integers(0, cfg.vocab, size=(k, k))

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        local = cfg.batch // cfg.n_shards
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 97 + cfg.shard
        )
        k = self._proj.shape[0]
        toks = np.empty((local, cfg.seq_len + 1), np.int64)
        toks[:, 0] = rng.integers(0, k, local)
        toks[:, 1] = rng.integers(0, k, local)
        noise = rng.random((local, cfg.seq_len + 1))
        for t in range(2, cfg.seq_len + 1):
            nxt = self._proj[toks[:, t - 1] % k, toks[:, t - 2] % k] % k
            rand = rng.integers(0, cfg.vocab, local)
            toks[:, t] = np.where(noise[:, t] < 0.1, rand, nxt)
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }
