"""Architecture + shape configuration schema.

One ``<arch>.py`` per assigned architecture lives in this package; each
exports ``CONFIG`` built from :class:`ArchConfig`. ``get_config(name)``
resolves by module name (``--arch`` flag of the launchers).

Input-shape cells (assigned): every LM arch pairs with
  train_4k     seq 4096,   global batch 256  (training step)
  prefill_32k  seq 32768,  global batch 32   (inference prefill)
  decode_32k   seq 32768,  global batch 128  (single-token decode w/ KV cache)
  long_500k    seq 524288, global batch 1    (long-context decode; only
               sub-quadratic archs — see DESIGN.md §Shape skips)
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0  # 0 -> d_model // n_heads

    # attention
    qkv_bias: bool = False
    rope_theta: float = 1_000_000.0
    rope_frac: float = 1.0
    window: int = 0  # sliding-window size (0 = full)
    norm: str = "rms"  # "rms" | "layer"
    mrope_sections: tuple[int, int, int] = ()

    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # encoder-decoder (whisper)
    n_encoder_layers: int = 0
    n_audio_ctx: int = 1500

    # hybrid (recurrentgemma / griffin)
    attn_period: int = 0  # every `attn_period`-th block is attention
    rglru_width: int = 0
    conv1d_width: int = 4

    # rwkv6
    rwkv_head_dim: int = 64
    rwkv_lora_mix: int = 32
    rwkv_lora_decay: int = 64

    # vlm stub frontend
    n_patches: int = 0
    d_patch: int = 1176

    dtype: Any = jnp.bfloat16
    sub_quadratic: bool = False  # eligible for long_500k
    is_encdec: bool = False
    is_vlm: bool = False

    # execution knobs (hillclimbing levers)
    scan_layers: bool = True
    remat: str = "full"  # "none" | "full" | "dots"
    use_kernels: bool = False  # Pallas path (TPU); False = portable XLA path
    # §Perf levers (see EXPERIMENTS.md):
    constrain_acts: tuple = ()  # e.g. ("data",) — pin activations P(dp,None,None)
    kv_quant: bool = False  # int8 KV cache on the decode path (paper technique)
    kv_shard_heads_padded: bool = False  # force head-sharded KV (pad to TP)

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def shapes(self) -> list[str]:
        out = ["train_4k", "prefill_32k", "decode_32k"]
        if self.sub_quadratic:
            out.append("long_500k")
        return out

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        d_model = 64
        n_heads = max(2, min(4, self.n_heads))
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        if self.n_kv_heads == self.n_heads:
            n_kv = n_heads  # preserve MHA-ness (stablelm)
        kw: dict[str, Any] = dict(
            n_layers=self.n_layers and max(2, min(3, self.n_layers)),
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            d_head=16,
            d_ff=96 if not self.n_experts else 32,
            vocab=256,
            window=min(self.window, 16) if self.window else 0,
            dtype=jnp.float32,
            remat="none",
        )
        if self.n_experts:
            # generous capacity: smoke runs feed a handful of tokens through
            # randomly-initialized routers, where capacity drops are near
            # certain and would make prefill vs decode-step outputs diverge
            # by design rather than by bug
            kw.update(n_experts=min(8, self.n_experts),
                      moe_top_k=min(2, self.moe_top_k),
                      capacity_factor=8.0)
        if self.is_encdec:
            kw.update(n_encoder_layers=2, n_audio_ctx=8)
        if self.attn_period:
            kw.update(attn_period=3, n_layers=3, rglru_width=d_model)
        if self.family == "ssm":
            kw.update(rwkv_head_dim=16, rwkv_lora_mix=8, rwkv_lora_decay=8)
        if self.is_vlm:
            kw.update(n_patches=4, d_patch=12, mrope_sections=(4, 2, 2))
        return self.replace(**kw)


_REGISTRY = [
    "qwen2_vl_7b",
    "whisper_base",
    "rwkv6_7b",
    "llama3_2_1b",
    "qwen2_72b",
    "yi_9b",
    "stablelm_3b",
    "recurrentgemma_2b",
    "qwen3_moe_30b_a3b",
    "dbrx_132b",
]


def list_configs() -> list[str]:
    return list(_REGISTRY)


def get_config(name: str) -> ArchConfig:
    mod_name = name.replace("-", "_").replace(".", "_")
    if mod_name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {_REGISTRY}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG
