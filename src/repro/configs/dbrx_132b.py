"""DBRX-132B [hf:databricks/dbrx-base]: 40L, d_model 6144, 48H GQA kv=8
(head_dim 128), fine-grained MoE with 16 experts top-4, per-expert d_ff
10752, vocab 100352, LayerNorm."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab=100352,
    d_head=128,
    norm="layer",
    rope_theta=500_000.0,
    n_experts=16,
    moe_top_k=4,
)
