"""Whisper-base [arXiv:2212.04356]: encoder-decoder, 6+6 layers, d_model 512,
8 MHA heads, d_ff 2048, vocab 51865. The conv frontend is a stub —
``input_specs`` provides precomputed mel-frame embeddings (B, 1500, 512).
LayerNorm (pre-LN), sinusoidal encoder positions, learned decoder positions.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,  # decoder layers
    n_encoder_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=51865,
    norm="layer",
    rope_theta=0.0,  # no rotary — absolute positions
    n_audio_ctx=1500,
    is_encdec=True,
)
