"""RecurrentGemma-2B (Griffin) [arXiv:2402.19427]: 26 residual blocks with
RG-LRU recurrence + local sliding-window MQA in a 2:1 pattern (rec, rec, attn
— attention every 3rd block), d_model 2560, 10H kv=1 (head_dim 256), GeGLU
d_ff 7680, vocab 256000, window 2048. Sub-quadratic -> runs long_500k.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab=256000,
    d_head=256,
    window=2048,
    attn_period=3,
    rglru_width=2560,
    conv1d_width=4,
    rope_theta=10_000.0,
    sub_quadratic=True,
)
