"""StableLM-3B [hf:stabilityai/stablelm-2 family]: 32L, d_model 2560,
32H MHA (kv=32), d_ff 6912, vocab 50304, LayerNorm, partial rotary (25%)."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=6912,
    vocab=50304,
    d_head=80,
    norm="layer",
    rope_theta=10_000.0,
    rope_frac=0.25,
)
