"""Qwen2-VL-7B language backbone [arXiv:2409.12191].

M-RoPE (temporal/height/width rotary sections 16/24/24 over head_dim 128),
QKV bias, GQA kv=4. The vision frontend is a stub per the assignment:
``input_specs`` provides precomputed patch embeddings (B, n_patches, d_patch)
that the model projects and prepends to the text sequence.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab=152064,
    d_head=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),
    is_vlm=True,
    n_patches=256,
    d_patch=1176,
)
