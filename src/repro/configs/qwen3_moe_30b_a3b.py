"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B]: 48L, d_model 2048, 32H GQA kv=4
(head_dim 128), MoE with 128 experts top-8, per-expert SwiGLU d_ff 768,
vocab 151936."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=768,
    vocab=151936,
    d_head=128,
    rope_theta=1_000_000.0,
    n_experts=128,
    moe_top_k=8,
)
