"""RWKV-6 (Finch) 7B [arXiv:2404.05892]: attention-free, data-dependent decay
linear recurrence. 32 layers, d_model 4096 (64 heads of 64), channel-mix
d_ff 14336, vocab 65536. Sub-quadratic -> runs the long_500k cell.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,  # d_model / rwkv_head_dim
    n_kv_heads=64,
    d_ff=14336,
    vocab=65536,
    rwkv_head_dim=64,
    rwkv_lora_mix=32,
    rwkv_lora_decay=64,
    sub_quadratic=True,
)
