from repro.nn.module import (  # noqa: F401
    Param,
    init_tree,
    spec_tree,
    pspec_tree,
    param_count,
    param_bytes,
    logical_to_pspec,
)
from repro.nn.layers import (  # noqa: F401
    dense,
    embedding,
    conv3d,
    conv3d_transpose,
    layer_norm,
    rms_norm,
    leaky_relu,
)
