"""Stateless layer builders.

Each builder returns a :class:`Layer` — ``defs`` (Param tree) + ``apply``
(pure function of (params, inputs)). Composition happens in plain Python;
parameters stay ordinary pytrees so pjit/shard_map see through everything.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn.module import Param, fan_in_init


@dataclasses.dataclass(frozen=True)
class Layer:
    defs: Any
    apply: Callable


def leaky_relu(x, negative_slope: float = 0.2):
    return jnp.where(x >= 0, x, negative_slope * x)


def dense(
    in_dim: int,
    out_dim: int,
    *,
    use_bias: bool = True,
    dtype=jnp.float32,
    axes: tuple[Optional[str], Optional[str]] = (None, None),
    init: str | Callable = "fan_in",
) -> Layer:
    defs = {"w": Param((in_dim, out_dim), dtype, init, axes)}
    if use_bias:
        defs["b"] = Param((out_dim,), dtype, "zeros", (axes[1],))

    def apply(params, x):
        y = x @ params["w"]
        if use_bias:
            y = y + params["b"]
        return y

    return Layer(defs, apply)


def embedding(
    vocab: int,
    dim: int,
    *,
    dtype=jnp.float32,
    axes: tuple[Optional[str], Optional[str]] = ("vocab", "embed"),
) -> Layer:
    defs = {"table": Param((vocab, dim), dtype, "normal_0.02", axes)}

    def apply(params, ids):
        return jnp.take(params["table"], ids, axis=0)

    return Layer(defs, apply)


def layer_norm(dim: int, *, dtype=jnp.float32, eps: float = 1e-5) -> Layer:
    defs = {
        "scale": Param((dim,), dtype, "ones", (None,)),
        "bias": Param((dim,), dtype, "zeros", (None,)),
    }

    def apply(params, x):
        x32 = x.astype(jnp.float32)
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + eps)
        return (y * params["scale"] + params["bias"]).astype(x.dtype)

    return Layer(defs, apply)


def rms_norm(dim: int, *, dtype=jnp.float32, eps: float = 1e-6) -> Layer:
    defs = {"scale": Param((dim,), dtype, "ones", (None,))}

    def apply(params, x):
        x32 = x.astype(jnp.float32)
        ms = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        return (x32 * jax.lax.rsqrt(ms + eps) * params["scale"]).astype(x.dtype)

    return Layer(defs, apply)


def _conv_kernel_init(kernel_shape):
    # fan_in = prod(spatial) * in_channels  (kernel layout: (D,H,W,in,out))
    fan_in = int(np.prod(kernel_shape[:-1]))
    std = 1.0 / np.sqrt(fan_in)

    def init(key, shape, dtype):
        return (std * jax.random.normal(key, shape, jnp.float32)).astype(dtype)

    return init


def _fast_conv_applicable(kernel, stride, padding) -> bool:
    return (
        padding == "SAME"
        and tuple(stride) == (1, 1, 1)
        and all(k % 2 == 1 for k in kernel)
    )


def _decomposed_conv3d(x, w, kernel):
    """Stride-1 SAME 3D conv as a depth-shifted sum of 2D convolutions.

    XLA:CPU lowers 3D (transposed) convolutions — and especially their
    gradients — through a slow generic path, while 2D NHWC f32 convolutions
    hit the tuned Eigen spatial kernels. A kd x kh x kw stride-1 SAME conv
    is exactly the sum over the kd depth taps of a 2D SAME conv with that
    tap's kh x kw kernel, the depth axis folded into the batch and the tap
    outputs depth-shifted. Equal to ``conv_general_dilated`` up to the
    reassociation of the depth-tap sum (ulp-level on f32; asserted in the
    unit suite) and ~3x faster on CPU for this repo's block shapes.
    """
    n, d, h, ww, ci = x.shape
    kd, kh, kw = kernel
    co = w.shape[-1]
    dn2 = jax.lax.conv_dimension_numbers(
        (1, 1, 1, ci), (kh, kw, ci, co), ("NHWC", "HWIO", "NHWC")
    )
    # every tap convolves the SAME (un-shifted, contiguous) input view and
    # the depth shift moves to the tap *outputs* — shifting the (usually
    # narrower) CO-channel tensors instead of copying strided CI-channel
    # input slices; zero-padded shifts reproduce the SAME-conv boundary
    xs = x.reshape(n * d, h, ww, ci)
    half = kd // 2
    y = None
    for dz in range(kd):
        c = jax.lax.conv_general_dilated(
            xs, w[dz], (1, 1), "SAME", dimension_numbers=dn2
        ).reshape(n, d, h, ww, co)
        s = half - dz
        if s > 0:
            c = jnp.pad(c, ((0, 0), (s, 0), (0, 0), (0, 0), (0, 0)))[:, :d]
        elif s < 0:
            c = jnp.pad(c, ((0, 0), (0, -s), (0, 0), (0, 0), (0, 0)))[:, -d:]
        y = c if y is None else y + c
    return y


def conv3d(
    in_ch: int,
    out_ch: int,
    kernel: tuple[int, int, int],
    *,
    stride: tuple[int, int, int] = (1, 1, 1),
    padding: str = "SAME",
    use_bias: bool = True,
    dtype=jnp.float32,
    impl: str = "2d",
) -> Layer:
    """3D convolution. ``impl="2d"`` (default) uses the depth-decomposed
    2D-conv formulation where it applies (stride 1, SAME, odd kernel) and
    falls back to the XLA 3D convolution otherwise; ``impl="xla"`` always
    uses the XLA convolution (retained as the numerics/perf reference)."""
    if impl not in ("2d", "xla"):
        raise ValueError(f"unknown conv impl {impl!r}")
    kshape = kernel + (in_ch, out_ch)
    defs = {"w": Param(kshape, dtype, _conv_kernel_init(kshape), (None,) * 5)}
    if use_bias:
        defs["b"] = Param((out_ch,), dtype, "zeros", (None,))

    dn = jax.lax.conv_dimension_numbers(
        (1, 1, 1, 1, in_ch), kshape, ("NDHWC", "DHWIO", "NDHWC")
    )
    use_fast = impl == "2d" and _fast_conv_applicable(kernel, stride, padding)

    def apply(params, x):
        # x: (N, D, H, W, C)
        if use_fast:
            y = _decomposed_conv3d(x, params["w"], kernel)
        else:
            y = jax.lax.conv_general_dilated(
                x, params["w"], window_strides=stride, padding=padding,
                dimension_numbers=dn,
            )
        if use_bias:
            y = y + params["b"]
        return y

    return Layer(defs, apply)


def conv3d_transpose(
    in_ch: int,
    out_ch: int,
    kernel: tuple[int, int, int],
    *,
    stride: tuple[int, int, int] = (1, 1, 1),
    padding: str = "SAME",
    use_bias: bool = True,
    dtype=jnp.float32,
    impl: str = "2d",
) -> Layer:
    """Transposed 3D convolution. With stride 1, SAME padding, and an odd
    kernel, ``lax.conv_transpose`` degenerates to the plain convolution with
    the same (unflipped) DHWIO kernel — its adjusted padding is exactly the
    SAME padding — so the default impl reuses :func:`_decomposed_conv3d`."""
    if impl not in ("2d", "xla"):
        raise ValueError(f"unknown conv impl {impl!r}")
    kshape = kernel + (in_ch, out_ch)
    defs = {"w": Param(kshape, dtype, _conv_kernel_init(kshape), (None,) * 5)}
    if use_bias:
        defs["b"] = Param((out_ch,), dtype, "zeros", (None,))

    dn = jax.lax.conv_dimension_numbers(
        (1, 1, 1, 1, in_ch), kshape, ("NDHWC", "DHWIO", "NDHWC")
    )
    use_fast = impl == "2d" and _fast_conv_applicable(kernel, stride, padding)

    def apply(params, x):
        if use_fast:
            y = _decomposed_conv3d(x, params["w"], kernel)
        else:
            y = jax.lax.conv_transpose(
                x, params["w"], strides=stride, padding=padding,
                dimension_numbers=dn,
            )
        if use_bias:
            y = y + params["b"]
        return y

    return Layer(defs, apply)
