"""Minimal functional parameter system.

Design goals (framework-scale, no flax/optax available):

* A model is described by a nested-dict *definition tree* whose leaves are
  :class:`Param` — shape, dtype, initializer, and **logical axis names**.
* ``init_tree(defs, key)`` materializes real arrays (deterministic per path).
* ``spec_tree(defs)`` produces ``jax.ShapeDtypeStruct`` leaves — this is what
  the multi-pod dry-run consumes (no device allocation, ever).
* ``pspec_tree(defs, rules)`` produces ``PartitionSpec`` leaves from the
  logical axes through a rules table — the single source of truth for
  DP/TP/SP/EP placement, MaxText-style.

Keeping definition, materialization, and sharding in one structure is what
lets every (architecture x shape x mesh) cell lower without touching device
memory.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec


Initializer = Callable[[jax.Array, Sequence[int], Any], jax.Array]


def _normal_init(stddev: float) -> Initializer:
    def init(key, shape, dtype):
        return (stddev * jax.random.normal(key, shape, jnp.float32)).astype(dtype)

    return init


def _zeros_init(key, shape, dtype):
    del key
    return jnp.zeros(shape, dtype)


def _ones_init(key, shape, dtype):
    del key
    return jnp.ones(shape, dtype)


def fan_in_init(axis: int = 0) -> Initializer:
    """LeCun-normal over the given fan-in axis product (default: all but last)."""

    def init(key, shape, dtype):
        if len(shape) <= 1:
            fan_in = max(1, shape[0] if shape else 1)
        else:
            fan_in = int(np.prod(shape[:-1]))
        std = 1.0 / np.sqrt(fan_in)
        return (std * jax.random.normal(key, shape, jnp.float32)).astype(dtype)

    return init


INITS: dict[str, Initializer] = {
    "zeros": _zeros_init,
    "ones": _ones_init,
    "fan_in": fan_in_init(),
    "normal_0.02": _normal_init(0.02),
}


@dataclasses.dataclass(frozen=True)
class Param:
    """A parameter leaf: shape + dtype + init + logical axes.

    ``axes`` names one logical axis per dim (or None for replicated dims);
    the parallel layer maps logical names -> mesh axes via a rules table.
    """

    shape: tuple[int, ...]
    dtype: Any = jnp.float32
    init: str | Initializer = "fan_in"
    axes: tuple[Optional[str], ...] = ()

    def __post_init__(self):
        if self.axes and len(self.axes) != len(self.shape):
            raise ValueError(
                f"axes {self.axes} rank mismatch with shape {self.shape}"
            )

    @property
    def initializer(self) -> Initializer:
        if callable(self.init):
            return self.init
        return INITS[self.init]


def _is_param(x) -> bool:
    return isinstance(x, Param)


def _walk(defs, path=()):  # yields (path, Param)
    if _is_param(defs):
        yield path, defs
        return
    if isinstance(defs, Mapping):
        for k in sorted(defs):
            yield from _walk(defs[k], path + (str(k),))
        return
    raise TypeError(f"definition tree leaf of type {type(defs)} at {path}")


def _map_params(defs, fn):
    if _is_param(defs):
        return fn(defs)
    return {k: _map_params(v, fn) for k, v in defs.items()}


def _path_key(key: jax.Array, path: tuple[str, ...]) -> jax.Array:
    # Deterministic per-path fold-in; stable across process restarts.
    digest = hashlib.sha256("/".join(path).encode()).digest()
    fold = int.from_bytes(digest[:4], "little")
    return jax.random.fold_in(key, fold)


def init_tree(defs, key: jax.Array):
    """Materialize a definition tree into real arrays (deterministic)."""

    def materialize_at(path, p: Param):
        return p.initializer(_path_key(key, path), p.shape, p.dtype)

    def rec(node, path):
        if _is_param(node):
            return materialize_at(path, node)
        return {k: rec(v, path + (str(k),)) for k, v in node.items()}

    return rec(defs, ())


def spec_tree(defs):
    """ShapeDtypeStruct tree — the dry-run's no-allocation param stand-in."""
    return _map_params(defs, lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype))


def logical_to_pspec(
    axes: Sequence[Optional[str]], rules: Mapping[str, Any]
) -> PartitionSpec:
    """Map logical axis names to mesh axes through ``rules``.

    A rule value may be None (replicate), a mesh-axis name, or a tuple of
    mesh-axis names (product sharding, e.g. fsdp over ("pod", "data")).
    Guards against using one mesh axis twice in a single spec (illegal in
    XLA SPMD) by dropping the second occurrence.
    """
    used: set[str] = set()
    out = []
    for name in axes:
        assignment = rules.get(name) if name is not None else None
        if assignment is None:
            out.append(None)
            continue
        entries = assignment if isinstance(assignment, tuple) else (assignment,)
        kept = tuple(a for a in entries if a not in used)
        used.update(kept)
        if not kept:
            out.append(None)
        elif len(kept) == 1:
            out.append(kept[0])
        else:
            out.append(kept)
    return PartitionSpec(*out)


def pspec_tree(defs, rules: Mapping[str, Any]):
    """PartitionSpec tree mirroring the definition tree."""
    return _map_params(defs, lambda p: logical_to_pspec(p.axes, rules))


def param_count(defs) -> int:
    return sum(int(np.prod(p.shape)) for _, p in _walk(defs))


def param_bytes(defs) -> int:
    return sum(
        int(np.prod(p.shape)) * jnp.dtype(p.dtype).itemsize for _, p in _walk(defs)
    )
