"""Test-support utilities shipped with the package (deterministic fault
injection for container blobs; see :mod:`repro.testing.faults`)."""
