"""Deterministic fault injection for container blobs.

The v4 integrity contract — *detected or harmless, never a silent wrong
decode* — is only worth shipping if it is exercised by corruption the
codec did not choose. This module is that adversary: it maps a blob into
addressable :class:`Region`\\ s (the outer header, every stream, and on
v2+/v3+ the fine-grained random-access units the digests cover — each
latent shard's chain, each species' guarantee extent, the directory
heads) and mutates them with seeded, reproducible faults.

Every injector is pure: it returns a **new** blob plus a :class:`Fault`
record naming exactly what it did (kind, region, byte/bit), so a failing
sweep case replays from its seed alone. The harness addresses corruption
the same way the decoder reports it (``stream``/``unit``), which lets
property tests assert not just *that* corruption was detected but that
the error indicts the right unit.

Usage::

    regions = blob_regions(blob)
    inj = FaultInjector(seed=0)
    bad, fault = inj.flip_bit(blob, regions[3])
    # ... assert decompress(bad) raises naming fault.stream/fault.unit,
    #     or decodes bitwise-equal to clean (header padding etc.)
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.analysis.wire_schema import GUARANTEE_PARTS, RegionKind
from repro.codec import format as wire
from repro.core import container as container_format
from repro.core.container import ContainerReader


@dataclasses.dataclass(frozen=True)
class Region:
    """A blob-absolute half-open byte extent ``[lo, hi)`` a fault can
    target, labeled with the decoder's own vocabulary: ``stream`` and
    ``unit`` match the :class:`~repro.core.container.ContainerFormatError`
    fields a decode of the corrupted region should carry."""

    label: str
    lo: int
    hi: int
    stream: Optional[str] = None
    unit: Optional[int] = None

    def __len__(self) -> int:
        return self.hi - self.lo


@dataclasses.dataclass(frozen=True)
class Fault:
    """One injected corruption: which region, what was done, where."""

    kind: str          # "flip_bit" | "zero_run" | "splice" | "truncate"
    region: Region
    offset: int        # blob-absolute byte offset of the mutation start
    detail: str        # human-readable specifics (bit index, run length…)


def blob_regions(blob: bytes, *, fine: bool = True) -> list:
    """Map a container blob into fault-addressable :class:`Region`\\ s.

    Always includes the outer header (magic + version + stream table) and
    one region per stream payload. With ``fine=True`` (default), streams
    with internal random-access structure are additionally split into the
    units the v4 digests cover:

    * ``meta`` (v5+): the one-byte encoder-family tag prefixing the
      stream (a flipped tag must fail as provable meta corruption, never
      decode through the wrong family);
    * ``latent`` (v3+): the head (framing + codebook + shard table) and
      each shard's chain payload (``unit=k``);
    * ``guarantee`` (v2+): the species directory and each species' spans
      (coeff+index+basis, as one region per contiguous span, ``unit=s``).

    The coarse whole-stream regions are kept alongside the fine ones, so
    a sweep can target either granularity.
    """
    blob = bytes(blob)
    r = ContainerReader(blob)
    regions = [Region(RegionKind.HEADER.label(), 0, r.header_bytes)]
    for name in r.names:
        lo, hi = r.stream_extent(name)
        regions.append(
            Region(RegionKind.STREAM.label(name=name), lo, hi, stream=name)
        )
    if not fine:
        return regions
    if r.version >= container_format.FORMAT_VERSION_FAMILY:
        lo, _ = r.stream_extent("meta")
        regions.append(Region(
            RegionKind.META_FAMILY.label(), lo, lo + wire._META_FAMILY.size,
            stream="meta",
        ))
    if r.version >= container_format.FORMAT_VERSION_SHARDED:
        lo, _ = r.stream_extent("latent")
        d = wire.LatentShardDirectory(r["latent"])
        regions.append(Region(
            RegionKind.LATENT_HEAD.label(), lo, lo + d.header_bytes,
            stream="latent",
        ))
        for k in range(d.n_shards):
            slo, shi = d.shard_extent(k)
            regions.append(Region(
                RegionKind.LATENT_SHARD.label(unit=k), lo + slo, lo + shi,
                stream="latent", unit=k,
            ))
    if r.version >= container_format.FORMAT_VERSION_SELECTIVE:
        lo, _ = r.stream_extent("guarantee")
        g = wire.GuaranteeDirectory(r["guarantee"])
        regions.append(Region(
            RegionKind.GUARANTEE_DIR.label(), lo, lo + g.dir_bytes,
            stream="guarantee",
        ))
        for s in range(g.n_species):
            for part, (plo, phi) in zip(
                GUARANTEE_PARTS, g.species_spans(s)
            ):
                regions.append(Region(
                    RegionKind.GUARANTEE_SPECIES_PART.label(unit=s, part=part),
                    lo + plo, lo + phi,
                    stream="guarantee", unit=s,
                ))
    return [reg for reg in regions if len(reg) > 0]


class FaultInjector:
    """Seeded source of reproducible blob corruptions.

    All mutation draws come from one ``numpy`` generator, so a sweep's
    entire fault sequence replays from ``seed`` alone; every injector
    returns ``(mutated_blob, fault_record)`` and never touches its input.
    """

    def __init__(self, seed: int = 0):
        self._rng = np.random.default_rng(seed)

    def _offset(self, region: Region) -> int:
        return int(self._rng.integers(region.lo, region.hi))

    def flip_bit(self, blob: bytes, region: Region,
                 offset: Optional[int] = None,
                 bit: Optional[int] = None) -> tuple:
        """XOR one bit inside ``region`` (random byte/bit unless given)."""
        buf = bytearray(blob)
        off = self._offset(region) if offset is None else int(offset)
        b = int(self._rng.integers(0, 8)) if bit is None else int(bit)
        buf[off] ^= 1 << b
        return bytes(buf), Fault(
            "flip_bit", region, off, f"bit {b} of byte {off}"
        )

    def zero_run(self, blob: bytes, region: Region,
                 length: int = 8) -> tuple:
        """Overwrite a run of ``length`` bytes in ``region`` with zeros
        (clipped to the region; a no-op run re-rolls is NOT attempted —
        zeroing already-zero bytes is a legitimately harmless fault)."""
        buf = bytearray(blob)
        off = self._offset(region)
        hi = min(off + max(1, int(length)), region.hi)
        buf[off:hi] = bytes(hi - off)
        return bytes(buf), Fault(
            "zero_run", region, off, f"{hi - off} bytes zeroed at {off}"
        )

    def splice(self, blob: bytes, dst: Region, src: Region) -> tuple:
        """Copy ``src``'s leading bytes over ``dst``'s (clipped to the
        shorter) — models a mis-seeked read stitching valid-looking bytes
        from the wrong unit, the corruption CRCs exist to catch and
        length checks cannot."""
        buf = bytearray(blob)
        n = min(len(dst), len(src))
        buf[dst.lo : dst.lo + n] = blob[src.lo : src.lo + n]
        return bytes(buf), Fault(
            "splice", dst, dst.lo, f"{n} bytes from {src.label} ({src.lo})"
        )

    def truncate(self, blob: bytes, n: Optional[int] = None) -> tuple:
        """Drop the last ``n`` bytes (random ``1..len//4`` if omitted) —
        the torn-write / short-read case the atomic file path prevents
        and the structural parse must still catch when handed one."""
        if n is None:
            n = int(self._rng.integers(1, max(2, len(blob) // 4)))
        n = max(1, min(int(n), len(blob) - 1))
        whole = Region(RegionKind.BLOB.label(), 0, len(blob))
        return bytes(blob[:-n]), Fault(
            "truncate", whole, len(blob) - n, f"last {n} bytes dropped"
        )
