"""Checkpointing: sharded, CRC-verified, atomic, async, elastic — plus
GBATC-compressed checkpoints with guaranteed per-block error bounds.

Layout of a checkpoint directory:
  <root>/step_<N>/
    manifest.json    # step, flat key list, shapes, dtypes, crc32 per array
    arrays.npz       # flat {key -> np.ndarray}, or
    arrays.gbatc     # compressed payload (when compress=True)
  <root>/LATEST      # atomic pointer (written last)

Elastic restore: arrays are loaded on host and ``jax.device_put`` with the
*target* mesh's NamedSharding — restoring onto a different device count or
mesh shape is the same code path (resharding happens at placement).

GBATC mode applies the paper's guarantee machinery to weights: each tensor is
blocked into 256-long vectors, "reconstructed" by int8 block quantization,
and the PCA-residual correction (Algorithm 1) tops up every block to the
requested relative l2 bound. Streams are Huffman-coded. Typical 3-4x over
raw fp32 at tau_rel = 1e-3 with a hard guarantee.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np

from repro.core import entropy, gae
from repro.kernels import ref as kref


# ---------------------------------------------------------------------------
# pytree <-> flat dict
# ---------------------------------------------------------------------------
def flatten_tree(tree) -> dict[str, np.ndarray]:
    flat = {}

    def rec(node, path):
        if isinstance(node, dict):
            for k in sorted(node):
                rec(node[k], path + (str(k),))
        else:
            flat["/".join(path)] = np.asarray(jax.device_get(node))

    rec(tree, ())
    return flat


def unflatten_to(tree_like, flat: dict[str, np.ndarray]):
    def rec(node, path):
        if isinstance(node, dict):
            return {k: rec(v, path + (str(k),)) for k, v in node.items()}
        return flat["/".join(path)]

    return rec(tree_like, ())


# ---------------------------------------------------------------------------
# GBATC weight compression (guaranteed)
# ---------------------------------------------------------------------------
_BLOCK_D = 256


def _compress_array(x: np.ndarray, tau_rel: float) -> tuple[np.ndarray, int]:
    """Guaranteed lossy compression of one tensor.

    Stage 1 ("AE reconstruction" analogue): int8 block quantization — the
    integer codes are Huffman+zstd coded, per-64 scales stored fp32.
    Stage 2: Algorithm 1 tops every 256-block up to
    ||block - rec||_2 <= tau_rel * rms * sqrt(D).
    Returns (reconstructed tensor, exact compressed bytes)."""
    flat = x.astype(np.float32).reshape(-1)
    pad = (-flat.size) % _BLOCK_D
    if pad:
        flat = np.concatenate([flat, np.zeros(pad, np.float32)])
    blocks = flat.reshape(-1, _BLOCK_D)

    qmax = 127.0
    xb = blocks.reshape(-1, 64)
    scales = np.maximum(np.abs(xb).max(axis=1, keepdims=True), 1e-30) / qmax
    codes = np.clip(np.rint(xb / scales), -128, 127).astype(np.int64)
    rec = (codes * scales).reshape(-1, _BLOCK_D).astype(np.float32)

    rms = float(np.sqrt(np.mean(blocks**2))) or 1.0
    tau = tau_rel * rms * np.sqrt(_BLOCK_D)
    corrected, art = gae.guarantee(blocks, rec, tau)

    stream = entropy.zstd_bytes(entropy.huffman_encode(codes.reshape(-1)))
    nbytes = len(stream) + scales.size * 4 + art.total_bytes() + 32
    out = corrected.reshape(-1)
    if pad:
        out = out[:-pad]
    return out.reshape(x.shape).astype(x.dtype), nbytes


def compress_state_bytes(flat: dict[str, np.ndarray], tau_rel: float = 1e-3):
    """Compress a flat checkpoint dict with guaranteed error bounds.

    Returns (reconstructed flat dict, total compressed bytes, report)."""
    out = {}
    total = 0
    raw = 0
    for k, v in flat.items():
        raw += v.nbytes
        if v.size < 4 * _BLOCK_D or v.dtype.kind in "iu":
            out[k] = v
            total += v.nbytes
            continue
        out[k], nbytes = _compress_array(v, tau_rel)
        total += nbytes
    return out, total, {"raw_bytes": raw, "compressed_bytes": total,
                        "ratio": raw / max(total, 1)}


# ---------------------------------------------------------------------------
# checkpoint manager
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class CheckpointManager:
    root: str
    keep: int = 3
    async_write: bool = True

    def __post_init__(self):
        os.makedirs(self.root, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ---- save -----------------------------------------------------------
    def save(self, step: int, tree, *, wait: bool = False) -> str:
        flat = flatten_tree(tree)
        if self._thread is not None:
            self._thread.join()  # one in-flight write at a time

        def write():
            tmp = os.path.join(self.root, f".tmp_step_{step}")
            final = os.path.join(self.root, f"step_{step}")
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            manifest = {
                "step": step,
                "arrays": {
                    k: {
                        "shape": list(v.shape),
                        "dtype": str(v.dtype),
                        "crc32": zlib.crc32(np.ascontiguousarray(v).tobytes()),
                    }
                    for k, v in flat.items()
                },
            }
            np.savez(os.path.join(tmp, "arrays.npz"), **flat)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)
            with open(os.path.join(self.root, ".LATEST_tmp"), "w") as f:
                f.write(str(step))
            os.replace(os.path.join(self.root, ".LATEST_tmp"),
                       os.path.join(self.root, "LATEST"))
            self._gc()

        if self.async_write and not wait:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()
        return os.path.join(self.root, f"step_{step}")

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.root, f"step_{s}"),
                          ignore_errors=True)

    # ---- restore -----------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.root):
            if d.startswith("step_"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        p = os.path.join(self.root, "LATEST")
        if not os.path.exists(p):
            return None
        with open(p) as f:
            return int(f.read().strip())

    def restore(self, tree_like, step: Optional[int] = None,
                shardings=None):
        """Load + CRC-verify; place with `shardings` (elastic reshard)."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.root}")
        d = os.path.join(self.root, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(d, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        for k, meta in manifest["arrays"].items():
            crc = zlib.crc32(np.ascontiguousarray(flat[k]).tobytes())
            if crc != meta["crc32"]:
                raise IOError(f"checkpoint corruption in {k} (crc mismatch)")
        tree = unflatten_to(tree_like, flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree, step
