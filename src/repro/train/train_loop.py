"""Train/serve step factories and the mini-batch SGD throughput engine.

``make_train_step`` closes over (model, optimizer config, compression config)
and returns a pure function
    (params, opt_state, batch) -> (params, opt_state, metrics)
including forward, backward, (optional) gradient compression with error
feedback, and the AdamW update — the *whole* production step, so
cost_analysis sees everything.

:class:`MiniBatchTrainer` is the compiled training engine behind
``autoencoder.fit`` and ``correction.fit`` (the codec's two hot training
loops). Design points:

* **Device-resident**: the dataset is transferred once; batches are gathered
  on device from indices drawn with ``jax.random`` inside the compiled
  program — no host RNG, no host fancy-indexing, no per-step transfers.
* **Two execution modes over one step definition.** ``"scan"`` compiles the
  whole run as a ``lax.scan`` over steps with donated (params, opt state)
  carries — one dispatch per fit, the accelerator path. ``"stream"``
  dispatches the same jitted step per iteration with donated carries and
  *no host sync* (losses are stacked on device and fetched once at the
  end) — on CPU backends XLA runs while-loop bodies single-threaded, so
  streaming keeps intra-op parallelism and wins there; ``mode=None``
  selects by backend. Both modes draw identical batch indices
  (:func:`batch_indices`), so their loss trajectories agree step for step.
* **Compiled once, reused forever**: programs are cached per (steps,
  batch, n, log_every) on the trainer, and trainers are cached by their
  owners (model instances / pipelines) — refitting never re-traces, where
  the seed rebuilt and recompiled its step closure on every ``fit`` call.
* ``log_every`` installs a host callback (``jax.debug.callback`` under
  scan, a host fetch under stream) **only when asked** — the hot path has
  zero host round-trips.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel import gradient_compression as gc
from repro.train import optimizer as opt


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: opt.AdamWConfig = dataclasses.field(default_factory=opt.AdamWConfig)
    compression: Optional[gc.CompressionConfig] = None
    # microbatch accumulation (1 = none); batch axis must divide
    grad_accum: int = 1


def init_train_state(model, params, train_cfg: TrainConfig) -> dict[str, Any]:
    state: dict[str, Any] = {"opt": opt.init_state(params)}
    if train_cfg.compression and train_cfg.compression.enabled:
        state["residuals"] = gc.init_residuals(params)
    return state


def make_train_step(model, train_cfg: TrainConfig):
    ocfg = train_cfg.optimizer
    ccfg = train_cfg.compression

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def one_grad(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def train_step(params, state, batch):
        if train_cfg.grad_accum > 1:
            # Unrolled accumulation: bounded live activations (the microbatch
            # is the remat unit) and exact cost_analysis accounting (a scan
            # here would be counted once by HloCostAnalysis).
            n = train_cfg.grad_accum
            microbatches = jax.tree.map(
                lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch
            )
            loss = jnp.zeros((), jnp.float32)
            grads = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            for i in range(n):
                mb = jax.tree.map(lambda x: x[i], microbatches)
                l_i, g_i = one_grad(params, mb)
                loss = loss + l_i / n
                grads = jax.tree.map(lambda a, g: a + g / n, grads, g_i)
        else:
            loss, grads = one_grad(params, batch)

        new_state = dict(state)
        if ccfg and ccfg.enabled:
            grads, new_state["residuals"] = gc.compress_tree(
                grads, state["residuals"], ccfg)
        params, new_state["opt"], om = opt.update(ocfg, grads, state["opt"], params)
        metrics = {"loss": loss, **om}
        return params, new_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# mini-batch SGD engine (the codec trainer hot loop)
# ---------------------------------------------------------------------------

_BATCH_SALT = 0x5CA1AB1E  # folds the batch stream away from init/model keys


def adamw_cfg(lr: float, steps: int) -> opt.AdamWConfig:
    """The engine's AdamW recipe (cosine schedule over the step budget,
    short warmup) — one definition shared by every trainer that rides
    :class:`MiniBatchTrainer`."""
    return opt.AdamWConfig(
        lr=lr, total_steps=steps, warmup_steps=min(20, steps // 10)
    )


def batch_key(seed: int) -> jax.Array:
    """Base key of the batch-index stream for a given fit seed."""
    return jax.random.fold_in(jax.random.PRNGKey(seed), _BATCH_SALT)


def batch_indices(bkey: jax.Array, step, n: int, batch_size: int) -> jax.Array:
    """Indices of mini-batch ``step`` — the single source of truth for the
    batch stream, shared by every engine mode (and the retained reference
    trainers), so loss trajectories are comparable across them."""
    return jax.random.randint(
        jax.random.fold_in(bkey, step), (batch_size,), 0, n
    )


def all_batch_indices(seed: int, steps: int, n: int, batch_size: int):
    """(steps, batch_size) index matrix, e.g. for host-looped trainers."""
    fn = jax.jit(
        lambda bkey: jax.vmap(
            lambda t: batch_indices(bkey, t, n, batch_size)
        )(jnp.arange(steps)),
        static_argnums=(),
    )
    return np.asarray(fn(batch_key(seed)))


class MiniBatchTrainer:
    """Compiled mini-batch SGD over ``loss_fn(params, *batch_arrays)``.

    ``data`` passed to :meth:`fit` is a tuple of arrays sharing the leading
    (instance) axis; each step gathers the same random rows from all of
    them. Optimizer is AdamW (:mod:`repro.train.optimizer`) configured by
    ``ocfg``; note ``ocfg.total_steps`` drives the cosine schedule, so a
    trainer is specific to its step budget.
    """

    def __init__(
        self,
        loss_fn: Callable,
        ocfg: opt.AdamWConfig,
        *,
        mode: Optional[str] = None,
        log_fn: Optional[Callable[[int, float], None]] = None,
    ):
        if mode not in (None, "scan", "stream"):
            raise ValueError(f"unknown trainer mode {mode!r}")
        if mode is None:
            # XLA:CPU runs while-loop bodies single-threaded; streaming
            # per-step dispatch keeps intra-op parallelism there, while
            # accelerators want the single fused scan program
            mode = "stream" if jax.default_backend() == "cpu" else "scan"
        self.mode = mode
        self._loss_fn = loss_fn
        self._ocfg = ocfg
        self._log_fn = log_fn or (
            lambda t, loss: print(f"[fit] step {t} loss {loss:.3e}")
        )
        self._programs: dict[tuple, Any] = {}

    # -- shared step definition ----------------------------------------
    def _step(self, params, state, batch):
        loss, grads = jax.value_and_grad(self._loss_fn)(params, *batch)
        params, state, _ = opt.update(self._ocfg, grads, state, params)
        return params, state, loss

    # -- compiled programs (cached per shape signature) ------------------
    def _scan_program(self, steps: int, n: int, bs: int, log_every: int):
        key = ("scan", steps, n, bs, log_every)
        prog = self._programs.get(key)
        if prog is not None:
            return prog

        @partial(jax.jit, donate_argnums=(0, 1))
        def run(params, state, bkey, *data):
            def body(carry, t):
                params, state = carry
                idx = batch_indices(bkey, t, n, bs)
                batch = tuple(a[idx] for a in data)
                params, state, loss = self._step(params, state, batch)
                if log_every:
                    jax.debug.callback(self._maybe_log, t, loss,
                                       np.int64(log_every))
                return (params, state), loss

            (params, state), losses = jax.lax.scan(
                body, (params, state), jnp.arange(steps)
            )
            return params, state, losses

        self._programs[key] = run
        return run

    def _maybe_log(self, t, loss, log_every):
        if int(t) % int(log_every) == 0:
            self._log_fn(int(t), float(loss))

    def _stream_step(self):
        key = ("stream-step",)
        prog = self._programs.get(key)
        if prog is None:
            @partial(jax.jit, donate_argnums=(0, 1))
            def prog(params, state, idx, *data):
                batch = tuple(a[idx] for a in data)
                return self._step(params, state, batch)

            self._programs[key] = prog
        return prog

    def _index_program(self, steps: int, n: int, bs: int):
        key = ("indices", steps, n, bs)
        prog = self._programs.get(key)
        if prog is None:
            prog = jax.jit(
                lambda bkey: jax.vmap(
                    lambda t: batch_indices(bkey, t, n, bs)
                )(jnp.arange(steps))
            )
            self._programs[key] = prog
        return prog

    def _mesh_program(self, steps: int, n: int, bs: int, log_every: int,
                      mesh, quantized: bool, n_data: int):
        from repro.parallel import mesh_fit

        key = ("mesh-scan", steps, n, bs, log_every, bool(quantized),
               n_data, mesh_fit.mesh_cache_key(mesh))
        prog = self._programs.get(key)
        if prog is None:
            prog = mesh_fit.dp_scan_program(
                self, steps, n, bs, log_every, mesh, quantized, n_data
            )
            self._programs[key] = prog
        return prog

    # -- the public entry ------------------------------------------------
    def fit(
        self,
        params,
        data,
        *,
        steps: int,
        batch_size: int,
        seed: int,
        log_every: int = 0,
        mesh=None,
        quantized_exchange: bool = False,
    ):
        """Run ``steps`` of SGD from ``params``; returns (params, losses).

        ``losses`` is a host float32 array of shape (steps,), fetched in one
        transfer after the run (no per-step sync).

        ``mesh`` switches to the data-parallel program
        (:func:`repro.parallel.mesh_fit.dp_scan_program`): rows are sharded
        over the mesh's ``"data"`` axis, each shard draws its local batch
        through the same :func:`batch_indices` law, and gradients are
        exchanged as ``psum/P`` — int8-quantized with error-bounded block
        scales when ``quantized_exchange`` is set (a no-op on a 1-device
        mesh, where the program is bit-identical to ``mode="scan"``).
        Global rows/batch are trimmed/rounded to multiples of the mesh
        size.
        """
        if mesh is not None:
            return self._fit_mesh(
                params, data, steps=steps, batch_size=batch_size, seed=seed,
                log_every=log_every, mesh=mesh,
                quantized_exchange=quantized_exchange,
            )
        data = tuple(jnp.asarray(a) for a in data)
        n = int(data[0].shape[0])
        bs = min(batch_size, n)
        bkey = batch_key(seed)
        state = opt.init_state(params)
        # the programs donate (params, state); copy so a caller-held params
        # tree is never invalidated by the donation
        params = jax.tree.map(jnp.array, params)
        if steps == 0:
            return params, np.zeros(0, dtype=np.float32)

        if self.mode == "scan":
            run = self._scan_program(steps, n, bs, log_every)
            params, state, losses = run(params, state, bkey, *data)
            return params, np.asarray(jax.device_get(losses))

        step = self._stream_step()
        idxs = self._index_program(steps, n, bs)(bkey)
        losses = []
        for t in range(steps):
            params, state, loss = step(params, state, idxs[t], *data)
            losses.append(loss)
            if log_every and t % log_every == 0:
                self._log_fn(t, float(loss))  # the only host sync, opt-in
        losses = np.asarray(jax.device_get(jnp.stack(losses)))
        return params, losses

    def _fit_mesh(self, params, data, *, steps, batch_size, seed, log_every,
                  mesh, quantized_exchange):
        from repro.parallel import mesh_fit

        n_p = mesh_fit.mesh_size(mesh)
        data = tuple(jnp.asarray(a) for a in data)
        n = int(data[0].shape[0])
        if n_p > 1:
            n = (n // n_p) * n_p  # equal per-shard row counts
            if n == 0:
                raise ValueError(
                    f"{int(data[0].shape[0])} rows cannot shard over "
                    f"{n_p} devices"
                )
            data = tuple(a[:n] for a in data)
        bs = min(batch_size, n)
        if n_p > 1:
            bs = max((bs // n_p) * n_p, n_p)
        bkey = batch_key(seed)
        state = opt.init_state(params)
        # copy both carries replicated over the mesh: the copies are
        # donation-safe (device_put alone can alias an already-committed
        # array, letting donation delete the caller's buffers) AND already
        # laid out as the program's input sharding, so donation is honored
        # rather than dropped on reshard
        rep = mesh_fit.replicated(mesh)
        copy = lambda t: jax.tree.map(
            lambda a: jax.device_put(jnp.copy(a), rep), t)
        params = copy(params)
        state = copy(state)
        if steps == 0:
            return params, np.zeros(0, dtype=np.float32)
        data = tuple(
            jax.device_put(a, mesh_fit.data_sharding(mesh)) for a in data
        )
        run = self._mesh_program(steps, n, bs, log_every, mesh,
                                 quantized_exchange, len(data))
        params, state, losses = run(params, state, bkey, *data)
        return params, np.asarray(jax.device_get(losses))


def make_prefill_step(model):
    def prefill_step(params, batch):
        return model.prefill(params, batch)

    return prefill_step


def make_serve_step(model):
    def serve_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    return serve_step
