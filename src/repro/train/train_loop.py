"""Train/serve step factories — the functions the dry-run lowers and the
examples execute.

``make_train_step`` closes over (model, optimizer config, compression config)
and returns a pure function
    (params, opt_state, batch) -> (params, opt_state, metrics)
including forward, backward, (optional) gradient compression with error
feedback, and the AdamW update — the *whole* production step, so
cost_analysis sees everything.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.parallel import gradient_compression as gc
from repro.train import optimizer as opt


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: opt.AdamWConfig = dataclasses.field(default_factory=opt.AdamWConfig)
    compression: Optional[gc.CompressionConfig] = None
    # microbatch accumulation (1 = none); batch axis must divide
    grad_accum: int = 1


def init_train_state(model, params, train_cfg: TrainConfig) -> dict[str, Any]:
    state: dict[str, Any] = {"opt": opt.init_state(params)}
    if train_cfg.compression and train_cfg.compression.enabled:
        state["residuals"] = gc.init_residuals(params)
    return state


def make_train_step(model, train_cfg: TrainConfig):
    ocfg = train_cfg.optimizer
    ccfg = train_cfg.compression

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def one_grad(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def train_step(params, state, batch):
        if train_cfg.grad_accum > 1:
            # Unrolled accumulation: bounded live activations (the microbatch
            # is the remat unit) and exact cost_analysis accounting (a scan
            # here would be counted once by HloCostAnalysis).
            n = train_cfg.grad_accum
            microbatches = jax.tree.map(
                lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch
            )
            loss = jnp.zeros((), jnp.float32)
            grads = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            for i in range(n):
                mb = jax.tree.map(lambda x: x[i], microbatches)
                l_i, g_i = one_grad(params, mb)
                loss = loss + l_i / n
                grads = jax.tree.map(lambda a, g: a + g / n, grads, g_i)
        else:
            loss, grads = one_grad(params, batch)

        new_state = dict(state)
        if ccfg and ccfg.enabled:
            grads, new_state["residuals"] = gc.compress_tree(
                grads, state["residuals"], ccfg)
        params, new_state["opt"], om = opt.update(ocfg, grads, state["opt"], params)
        metrics = {"loss": loss, **om}
        return params, new_state, metrics

    return train_step


def make_prefill_step(model):
    def prefill_step(params, batch):
        return model.prefill(params, batch)

    return prefill_step


def make_serve_step(model):
    def serve_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    return serve_step
