"""Fault tolerance: step watchdog / straggler detection + checkpoint-restart.

At 1000+ nodes the two dominant failure modes are (a) hard node loss — handled
by checkpoint/restart with elastic resharding (see checkpoint.py) — and
(b) stragglers — handled by per-step timing against a robust running median.

``run_with_recovery`` is the single-controller loop the train driver uses:
it executes steps, checkpoints every N, and on *any* step exception restores
the latest checkpoint and replays — exactly-once semantics come from the
data pipeline being step-indexed (repro.data.tokens), so a replayed step
consumes identical batches.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import numpy as np


class StepFailure(RuntimeError):
    """Raised by injected failures in tests; real deployments surface XLA
    device errors the same way."""


def retry_with_backoff(
    fn: Callable[[], Any],
    *,
    max_retries: int = 3,
    backoff: float = 0.1,
    retry_on: tuple = (OSError, IOError, StepFailure),
    sleep: Callable[[float], None] = time.sleep,
    on_retry: Optional[Callable[[int, BaseException], None]] = None,
) -> Any:
    """Run ``fn()``; on a retryable exception restart it, up to
    ``max_retries`` times, sleeping ``backoff * 2**attempt`` between
    tries.

    The checkpoint-restart idiom of :func:`run_with_recovery` scaled
    down to a single restartable unit: ``fn`` must be a pure restart —
    re-running it from the top must be equivalent to a clean first run
    (the streaming-fit passes qualify: each is a pure function of a
    re-iterable loader). Exceptions outside ``retry_on`` (shape errors,
    validation) propagate immediately — only transient faults retry.
    """
    attempt = 0
    while True:
        try:
            return fn()
        except retry_on as e:
            if attempt >= max_retries:
                raise
            if on_retry is not None:
                on_retry(attempt, e)
            if backoff > 0:
                sleep(backoff * (2 ** attempt))
            attempt += 1


@dataclasses.dataclass
class Watchdog:
    """Flags steps slower than `threshold` x running median."""

    threshold: float = 3.0
    window: int = 32

    def __post_init__(self):
        self._times: list[float] = []
        self.straggler_steps: list[int] = []

    def observe(self, step: int, seconds: float) -> bool:
        """Returns True if this step is a straggler."""
        is_straggler = False
        if len(self._times) >= 5:
            med = float(np.median(self._times[-self.window:]))
            is_straggler = seconds > self.threshold * med
        if is_straggler:
            self.straggler_steps.append(step)
        self._times.append(seconds)
        return is_straggler

    @property
    def median(self) -> float:
        return float(np.median(self._times)) if self._times else 0.0


def run_with_recovery(
    *,
    step_fn: Callable[[int, Any], Any],  # (step, state) -> state
    init_state: Any,
    n_steps: int,
    ckpt,  # CheckpointManager
    save_every: int = 10,
    max_restarts: int = 3,
    watchdog: Optional[Watchdog] = None,
    on_straggler: Optional[Callable[[int], None]] = None,
    state_to_tree: Callable[[Any], Any] = lambda s: s,
    tree_to_state: Callable[[Any, Any], Any] = lambda tmpl, t: t,
) -> tuple[Any, dict]:
    """Run n_steps with checkpoint-restart. Returns (state, report)."""
    state = init_state
    step = 0
    restarts = 0
    # resume if a checkpoint exists
    latest = ckpt.latest_step()
    if latest is not None:
        tree, got = ckpt.restore(state_to_tree(init_state))
        state = tree_to_state(init_state, tree)
        step = got + 1

    while step < n_steps:
        try:
            t0 = time.perf_counter()
            state = step_fn(step, state)
            dt = time.perf_counter() - t0
            if watchdog is not None and watchdog.observe(step, dt):
                if on_straggler is not None:
                    on_straggler(step)
            if step % save_every == 0:
                ckpt.save(step, state_to_tree(state))
            step += 1
        except StepFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
            latest = ckpt.latest_step()
            if latest is None:
                state = init_state
                step = 0
                continue
            tree, got = ckpt.restore(state_to_tree(init_state))
            state = tree_to_state(init_state, tree)
            step = got + 1
    ckpt.wait()
    return state, {
        "restarts": restarts,
        "stragglers": list(watchdog.straggler_steps) if watchdog else [],
        "final_step": step,
    }
