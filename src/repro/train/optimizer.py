"""AdamW (+ cosine schedule, global-norm clipping) over raw pytrees.

No optax in this environment; the implementation is deliberately tree-pure so
the same code runs (a) on a laptop for the AE/correction nets and (b) under
pjit with ZeRO-sharded moment states for the LM zoo — the states mirror the
parameter pytree, so sharding rules transfer leaf-for-leaf (see
``repro.parallel.sharding.optimizer_pspecs``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: Optional[float] = 1.0
    # warmup + cosine decay (steps); lr constant if total_steps == 0
    warmup_steps: int = 0
    total_steps: int = 0
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to min_lr_frac * lr."""
    step = step.astype(jnp.float32)
    if cfg.total_steps <= 0:
        return jnp.asarray(cfg.lr, jnp.float32)
    warm = jnp.minimum(1.0, step / jnp.maximum(1.0, cfg.warmup_steps))
    frac = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(1.0, cfg.total_steps - cfg.warmup_steps),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    decayed = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * decayed


def init_state(params) -> dict[str, Any]:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return {
        "m": zeros,
        "v": jax.tree.map(jnp.copy, zeros),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), norm


def update(cfg: AdamWConfig, grads, state, params):
    """One AdamW step. Returns (new_params, new_state, metrics).

    Pure pytree -> pytree: (params, state) round-trip with identical
    structure and dtypes, so the pair is a valid ``lax.scan`` carry (and a
    donatable argument) for the compiled training engine in
    :mod:`repro.train.train_loop`.
    """
    step = state["step"] + 1
    if cfg.grad_clip is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    lr = schedule(cfg, step)

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = cfg.b1 * m + (1.0 - cfg.b1) * g32
        v_new = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g32)
        mhat = m_new / b1c
        vhat = v_new / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m_new, v_new

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params, new_m, new_v = jax.tree.transpose(
        jax.tree.structure(params), jax.tree.structure((0, 0, 0)), out
    )
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
