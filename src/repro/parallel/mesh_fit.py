"""Mesh-sharded fit/compress: DP trainer programs, a species/block-row
sharded guarantee engine, and the streaming sharded ingest buffer.

The paper's production fields (full species sets, hundreds of timesteps)
exceed a single accelerator's memory, so the fit/compress path gains a
``("data",)`` mesh dimension in three places:

* **Data-parallel trainer** — :func:`dp_scan_program` builds the
  ``MiniBatchTrainer`` mesh mode: one ``jit(shard_map(lax.scan(...)))``
  program with batch-row-sharded device data, psum'd gradients, and
  donated ``(params, opt_state)`` carries. Every shard draws its local
  mini-batch through the *same* :func:`~repro.train.train_loop.
  batch_indices` law (shard ``i`` folds its axis index into the batch
  key), so on a 1-device mesh the traced program is op-for-op the
  existing scan program and the loss trajectory and final params are
  **bit-identical** to the single-device trainer — the gate tier-1
  asserts. On ``P > 1`` devices the trajectory is the valid DP-SGD one
  (global batch = P local batches, gradients exchanged as
  ``psum / P``), which reduction order makes close to but not bitwise
  the 1-device run. The gradient exchange optionally routes through
  :func:`repro.parallel.gradient_compression.quantized_psum` (int8
  payload + fp32 block scales on the wire); :func:`dp_wire_report`
  accounts the per-step wire bytes either way from the static leaf
  shapes.

* **Sharded guarantee engine** — :class:`ShardedGuaranteeEngine`
  overrides the :class:`~repro.core.gae.GuaranteeEngine` dispatch seam:
  each batched Pallas dispatch (projection, masked select-accumulate,
  correction replay) is split into contiguous per-shard programs over
  the species axis (and over block rows when shards outnumber species),
  placed one per device, and the fetched results concatenated. The
  kernels are per-species and per-block-row pure, so the concatenated
  CSR artifacts — and therefore the serialized container — are
  **byte-identical** to the single-device engine's (asserted in tier-1
  and before any benchmark number). Prepared tensors stay on host and
  are chunk-staged per dispatch, so no single device ever holds the
  full (S, NB, D) problem.

* **Streaming sharded ingest** — :class:`ShardedBlockStore` is the
  mesh-aware ``fit_stream`` landing buffer: each two-pass ingest chunk
  is normalized and blocked on host (one chunk at a time) and written
  straight into a row-sharded device array via a donated
  ``dynamic_update_slice`` program. The full normalized field is never
  materialized on host, and each device holds only ``NB / P`` block
  rows — a field larger than one device's memory fits. (The *compress*
  stage still builds host-numpy mirrors for the guarantee math — the
  out-of-core constraint this buffer removes is device memory, and the
  ingest/fit host peak, which the allocation-tracking test pins to one
  chunk.)

CPU CI validates everything on a forced host mesh
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``; tier-1 honors
``REPRO_HOST_DEVICES`` via the root conftest). The structural wins —
one program per shard, no host gathers mid-fit, donated carries — are
the accelerator-dominant terms, same argument as the compiled trainer.

Demo: ``python -m repro.parallel.mesh_fit`` (run with forced host
devices) prints the per-device ingest memory high-water against the
single-device total; quickstart step 9 drives it.
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import gae

#: the one mesh axis this module shards over (batch rows / species rows)
DATA_AXIS = "data"


# ---------------------------------------------------------------------------
# mesh plumbing
# ---------------------------------------------------------------------------
def host_mesh(n_devices: Optional[int] = None) -> Mesh:
    """A 1-D ``("data",)`` mesh over the first ``n_devices`` devices
    (default: all). On CPU CI the device count comes from
    ``--xla_force_host_platform_device_count``."""
    devs = jax.devices()
    n = len(devs) if n_devices is None else int(n_devices)
    if not 1 <= n <= len(devs):
        raise ValueError(
            f"host_mesh wants {n} devices but {len(devs)} are available"
        )
    return Mesh(np.array(devs[:n]), (DATA_AXIS,))


def mesh_size(mesh: Mesh) -> int:
    return int(np.prod(mesh.devices.shape))


def data_sharding(mesh: Mesh) -> NamedSharding:
    """Rows (leading axis) split over the data axis."""
    return NamedSharding(mesh, P(DATA_AXIS))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def mesh_cache_key(mesh: Mesh) -> tuple:
    """Hashable identity for program caches: the device ids, in order."""
    return tuple(int(d.id) for d in mesh.devices.flat)


def shard_rows(array, mesh: Mesh):
    """Place an array row-sharded over the mesh (no-op if already so)."""
    return jax.device_put(jnp.asarray(array), data_sharding(mesh))


# ---------------------------------------------------------------------------
# (1) data-parallel MiniBatchTrainer program
# ---------------------------------------------------------------------------
def dp_scan_program(trainer, steps: int, n: int, bs: int, log_every: int,
                    mesh: Mesh, quantized: bool, n_data: int = 1):
    """Build the trainer's mesh program: ``jit(shard_map(scan(step)))``.

    ``n`` and ``bs`` are the *global* row/batch counts, both divisible by
    the mesh size (the trainer trims). Each shard samples its local batch
    via ``batch_indices`` over its own ``n/P`` rows under a per-shard key
    (``fold_in(bkey, axis_index)``); gradients are exchanged as
    ``psum/P`` (== the global-batch mean for equal shards) and the logged
    loss is the pmean. All branches on the mesh size are *trace-time*:
    the 1-device program contains no collectives and is op-for-op the
    single-device scan program — that is what makes the P=1 bit-identity
    gate hold by construction rather than by luck.
    """
    from repro.parallel import gradient_compression as gc
    from repro.train import optimizer as opt
    from repro.train.train_loop import batch_indices

    n_p = mesh_size(mesh)
    if n % n_p or bs % n_p:
        raise ValueError(
            f"global rows {n} and batch {bs} must divide the mesh size {n_p}"
        )
    n_local, bs_local = n // n_p, bs // n_p

    def _log_shard0(sidx, t, loss, log_every):
        if int(sidx) == 0:
            trainer._maybe_log(t, loss, log_every)

    def run_body(params, state, bkey, *data):
        if n_p > 1:
            skey = jax.random.fold_in(bkey, jax.lax.axis_index(DATA_AXIS))
        else:
            skey = bkey  # the exact single-device batch stream

        def body(carry, t):
            params, state = carry
            idx = batch_indices(skey, t, n_local, bs_local)
            batch = tuple(a[idx] for a in data)
            if n_p == 1:
                # trace the existing step verbatim: P=1 stays bit-identical
                params, state, loss = trainer._step(params, state, batch)
            else:
                loss, grads = jax.value_and_grad(trainer._loss_fn)(
                    params, *batch
                )
                if quantized:
                    grads = jax.tree.map(
                        lambda g: gc.quantized_psum(g, DATA_AXIS), grads
                    )
                else:
                    grads = jax.lax.psum(grads, DATA_AXIS)
                grads = jax.tree.map(lambda g: g / n_p, grads)
                loss = jax.lax.pmean(loss, DATA_AXIS)
                params, state, _ = opt.update(
                    trainer._ocfg, grads, state, params
                )
            if log_every:
                if n_p == 1:
                    jax.debug.callback(trainer._maybe_log, t, loss,
                                       np.int64(log_every))
                else:
                    # post-pmean loss is replicated; only shard 0 prints
                    jax.debug.callback(
                        _log_shard0, jax.lax.axis_index(DATA_AXIS),
                        t, loss, np.int64(log_every),
                    )
            return (params, state), loss

        (params, state), losses = jax.lax.scan(
            body, (params, state), jnp.arange(steps)
        )
        return params, state, losses

    return jax.jit(
        shard_map(
            run_body, mesh=mesh,
            in_specs=(P(), P(), P()) + (P(DATA_AXIS),) * n_data,
            out_specs=(P(), P(), P()),
            check_rep=False,
        ),
        donate_argnums=(0, 1),
    )


def dp_wire_report(params, n_devices: int, *, n_bits: int = 8,
                   block: int = 64) -> dict:
    """Per-step gradient-exchange wire bytes, from static leaf shapes.

    The quantized exchange all-gathers each device's full quantized
    gradient (int payload + one fp32 scale per ``block`` values), so a
    device receives ``(P-1) * (q + s)`` bytes per step; the fp32
    baseline is a ring all-reduce at ``2 * (P-1)/P * 4n`` bytes per
    device. Static accounting — the traced program moves exactly these
    payloads, there is nothing dynamic to measure.
    """
    q_bytes = scale_bytes = f32_bytes = 0
    for leaf in jax.tree.leaves(params):
        size = int(np.prod(np.shape(leaf))) if np.shape(leaf) else 1
        blocks = -(-size // block)
        q_bytes += blocks * block * n_bits // 8
        scale_bytes += blocks * 4
        f32_bytes += size * 4
    p = max(int(n_devices), 1)
    quant = (q_bytes + scale_bytes) * (p - 1)
    fp32 = 2 * f32_bytes * (p - 1) // p
    return {
        "n_devices": p,
        "n_bits": n_bits,
        "block": block,
        "grad_fp32_bytes": f32_bytes,
        "quantized_bytes_per_step": quant,
        "fp32_bytes_per_step": fp32,
        "wire_ratio": (fp32 / quant) if quant else float("inf"),
    }


# ---------------------------------------------------------------------------
# (2) species/block-row sharded guarantee engine
# ---------------------------------------------------------------------------
#: per-kernel dispatch plan: for every positional arg and every output,
#: ``(species_axis, row_axis)`` — ``None`` replicates (basis, scalars).
#: ``x64`` mirrors the base engine's enable_x64 scopes: projection and
#: selection math are fp64, correction/replay inputs are fp32/int32.
_KERNEL_PLANS = {
    "project": dict(
        args=((0, 1), (0, None)), outs=((0, 1),), x64=True),
    "select": dict(
        args=((0, 1), (0, 1), (0, 1), (0, 1), (0, 1), (0, None), None, None),
        outs=((0, 1), (0, 1), (0, 1), (0, 1)), x64=True),
    "correct": dict(
        args=((0, 1), (0, 1), (0, 1), (0, 1), (0, None)),
        outs=((0, 1),), x64=False),
    "apply": dict(
        args=((0, 1), (0, 1), (0, None)), outs=((0, 1),), x64=False),
}


def _split_points(total: int, parts: int) -> list[int]:
    """Balanced contiguous split boundaries (deterministic)."""
    return [(total * i) // parts for i in range(parts + 1)]


def _chunk_plan(s: int, nb: int, n_shards: int) -> list[tuple]:
    """(s0, s1, r0, r1) extents: species-major, rows split only when
    shards outnumber species. Concatenating per-chunk results restores
    the batched layout exactly (contiguous, in order)."""
    n_s = max(1, min(s, n_shards))
    n_r = max(1, min(n_shards // n_s, nb))
    sb = _split_points(s, n_s)
    rb = _split_points(nb, n_r)
    return [
        (sb[i], sb[i + 1], rb[j], rb[j + 1])
        for i in range(n_s)
        for j in range(n_r)
        if sb[i + 1] > sb[i] and rb[j + 1] > rb[j]
    ]


class ShardedGuaranteeEngine(gae.GuaranteeEngine):
    """GuaranteeEngine whose batched kernel dispatches run one program
    per shard over species (and block rows), placed round-robin on the
    mesh devices.

    The GBATC kernels are per-species and per-block-row pure (projection
    is a per-species GEMM; selection cumsums/argmaxes run within a block
    row; correction is a per-row masked GEMM), so splitting the batch
    into contiguous chunks and concatenating the fetched results is
    bitwise identical to the single batched dispatch — the CSR
    artifacts, and therefore the serialized container, match the
    default engine **byte for byte** (asserted in tier-1).

    Prepared tensors are staged on *host* (the ``_stage`` seam), so no
    device ever holds the full (S, NB, D) problem: each dispatch
    uploads only its shard's chunk to its device. All chunk programs
    are dispatched asynchronously before any result is fetched.

    ``n_shards`` decouples chunk count from device count (defaults to
    the device count) — CI uses it to exercise the chunked path on one
    device, where bitwise identity is just as binding.
    """

    def __init__(self, mesh: Optional[Mesh] = None,
                 devices: Optional[Sequence] = None,
                 n_shards: Optional[int] = None, **kw):
        if devices is None:
            devices = (list(mesh.devices.flat) if mesh is not None
                       else jax.devices())
        self._devices = list(devices)
        self._n_shards = int(n_shards) if n_shards else len(self._devices)
        if self._n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {self._n_shards}")
        super().__init__(**kw)

    # prepared tensors stay host-resident; dispatch stages per-shard chunks
    def _stage(self, arr):
        return np.asarray(arr)

    def _dispatch(self, kernel: str, *args):
        from jax.experimental import enable_x64

        plan = _KERNEL_PLANS[kernel]
        fn = getattr(self, f"_{kernel}_jit")
        # problem extents from the first fully-chunked arg (S, NB, ...)
        lead = next(
            a for a, ax in zip(args, plan["args"])
            if ax is not None and ax[1] is not None
        )
        s, nb = int(lead.shape[0]), int(lead.shape[1])
        chunks = _chunk_plan(s, nb, self._n_shards)

        def stage_and_call():
            pending = []
            for i, (s0, s1, r0, r1) in enumerate(chunks):
                dev = self._devices[i % len(self._devices)]
                chunk_args = []
                for arg, ax in zip(args, plan["args"]):
                    if ax is None:
                        chunk_args.append(arg)
                        continue
                    s_ax, r_ax = ax
                    sl = arg[s0:s1]
                    if r_ax is not None:
                        sl = sl[:, r0:r1]
                    chunk_args.append(
                        jax.device_put(np.ascontiguousarray(sl), dev)
                    )
                pending.append(fn(*chunk_args))  # async: one program/shard
            return pending

        if plan["x64"]:
            with enable_x64():
                pending = stage_and_call()
                fetched = [jax.tree.map(np.asarray, out) for out in pending]
        else:
            pending = stage_and_call()
            fetched = [jax.tree.map(np.asarray, out) for out in pending]

        return self._concat(fetched, chunks, plan["outs"])

    @staticmethod
    def _concat(fetched, chunks, out_axes):
        """Reassemble per-chunk results: rows within a species group
        first (axis 1), then species groups (axis 0)."""
        single = len(out_axes) == 1
        outs = []
        for k, ax in enumerate(out_axes):
            parts = [f if single else f[k] for f in fetched]
            by_species: dict[int, list] = {}
            for (s0, _s1, _r0, _r1), p in zip(chunks, parts):
                by_species.setdefault(s0, []).append(p)
            groups = [
                rows[0] if len(rows) == 1 else np.concatenate(rows, axis=1)
                for _s0, rows in sorted(by_species.items())
            ]
            outs.append(
                groups[0] if len(groups) == 1
                else np.concatenate(groups, axis=0)
            )
        return outs[0] if single else tuple(outs)


# ---------------------------------------------------------------------------
# (3) streaming sharded ingest buffer (mesh-aware fit_stream)
# ---------------------------------------------------------------------------
class ShardedBlockStore:
    """Row-sharded device landing buffer for two-pass streaming ingest.

    ``append`` writes one chunk's blocks into the next rows of a
    ``("data",)``-sharded (NB, S, bt, ph, pw) device array via a donated
    ``dynamic_update_slice`` program — the host touches one chunk at a
    time and the full normalized field only ever exists sharded across
    the mesh. Row counts must divide the mesh size so every device owns
    an equal contiguous row range (raise early, not mid-ingest).

    The update program is cached per chunk shape (uniform chunking
    traces once; a ragged tail traces one extra program), and the row
    cursor is a traced scalar, so appends never retrace per chunk.
    """

    def __init__(self, nb: int, tail_shape: tuple, mesh: Mesh,
                 dtype=jnp.float32):
        n_p = mesh_size(mesh)
        if nb % n_p:
            raise ValueError(
                f"streamed block count {nb} does not divide the mesh size "
                f"{n_p}; choose a chunking/geometry with NB % P == 0"
            )
        self.nb = int(nb)
        self.mesh = mesh
        self._sharding = data_sharding(mesh)
        self._rows = 0
        self._buf = jax.device_put(
            jnp.zeros((self.nb, *tail_shape), dtype), self._sharding
        )
        self._programs: dict[tuple, object] = {}

    def _update_program(self, part_shape: tuple):
        prog = self._programs.get(part_shape)
        if prog is None:
            @partial(jax.jit, donate_argnums=(0,),
                     out_shardings=self._sharding)
            def prog(buf, part, row):
                start = (row,) + (jnp.int32(0),) * (buf.ndim - 1)
                return jax.lax.dynamic_update_slice(buf, part, start)

            self._programs[part_shape] = prog
        return prog

    def append(self, part: np.ndarray) -> None:
        part = jnp.asarray(np.ascontiguousarray(part), dtype=self._buf.dtype)
        if self._rows + part.shape[0] > self.nb:
            raise ValueError(
                f"append overflows the store: {self._rows} + "
                f"{part.shape[0]} > {self.nb} rows"
            )
        self._buf = self._update_program(part.shape)(
            self._buf, part, jnp.int32(self._rows)
        )
        self._rows += int(part.shape[0])

    def finish(self):
        """The filled sharded array; raises if rows are missing."""
        if self._rows != self.nb:
            raise ValueError(
                f"store holds {self._rows} of {self.nb} block rows"
            )
        return self._buf

    def per_device_bytes(self) -> dict[int, int]:
        """Resident bytes per device id — the ingest memory high-water."""
        out: dict[int, int] = {}
        for shard in self._buf.addressable_shards:
            did = int(shard.device.id)
            out[did] = out.get(did, 0) + int(shard.data.nbytes)
        return out


# ---------------------------------------------------------------------------
# demo: per-device ingest memory vs single device (quickstart step 9)
# ---------------------------------------------------------------------------
def _demo() -> None:  # pragma: no cover - exercised via quickstart/driver
    from repro.core.pipeline import GBATCPipeline, PipelineConfig
    from repro.data import s3d

    mesh = host_mesh()
    n_p = mesh_size(mesh)
    cfg = PipelineConfig(conv_channels=(8, 16), ae_steps=40, corr_steps=20,
                         batch_size=32)
    scfg = s3d.S3DConfig(n_species=2, n_time=16, height=40, width=40, seed=0)
    loader = s3d.S3DChunkLoader(scfg, chunk_frames=4)
    pipe = GBATCPipeline(cfg, n_species=2, mesh=mesh)
    pipe.fit_stream(loader)
    store_bytes = {
        int(sh.device.id): int(sh.data.nbytes)
        for sh in pipe._blocks.addressable_shards
    }
    total = int(pipe._blocks.nbytes)
    peak = max(store_bytes.values())
    print(f"mesh fit on {n_p} host device(s): normalized field "
          f"{total} bytes total, per-device ingest high-water "
          f"{peak} bytes ({peak / total:.0%} of single-device)")
    rep = pipe.compress(target_nrmse=1e-3)
    print(f"sharded compress: mean NRMSE {rep.mean_nrmse:.2e} "
          f"(target 1e-3), CR {rep.compression_ratio:.1f}x")


if __name__ == "__main__":  # pragma: no cover
    _demo()
