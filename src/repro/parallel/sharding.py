"""Sharding rules: logical axes -> mesh axes (the DP/TP/SP/EP map).

Mesh axes: ("pod", "data", "model") multi-pod or ("data", "model") single-pod.
  * DP: batch over (pod, data) — 32 groups on the production mesh;
  * TP: heads / d_ff / vocab / experts over "model" (Megatron-style);
  * EP: MoE expert axis over "model" (token exchange = XLA all-to-all);
  * ZeRO-1: optimizer moments additionally sharded over the DP axes on the
    first divisible replicated dim;
  * KV caches: heads over "model" when divisible, else cache length
    (context-parallel decode).

GSPMD handles non-divisible shardings by padding, but padding heads wastes
MXU cycles — rules prefer exactly-divisible axes and fall back to
replication; see EXPERIMENTS.md §Perf for measured effects.
"""

from __future__ import annotations

from typing import Any, Mapping, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_axes_for(mesh: Mesh, batch: int) -> tuple[str, ...]:
    """DP axes whose product divides `batch` (else replicate — e.g. the
    inherently single-stream long_500k cell with global_batch=1)."""
    axes = dp_axes(mesh)
    n = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    if batch % max(n, 1) == 0:
        return axes
    if "data" in axes and batch % mesh.shape["data"] == 0:
        return ("data",)
    return ()


def tp_size(mesh: Mesh) -> int:
    return mesh.shape["model"]


def dp_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in dp_axes(mesh)]))


def make_rules(cfg: ArchConfig, mesh: Mesh) -> dict[str, Any]:
    tp = tp_size(mesh)
    hd_total = cfg.n_heads * cfg.head_dim
    kv_total = cfg.n_kv_heads * cfg.head_dim
    return {
        # activations' d_model stays replicated on the weight side
        "embed": None,
        # embedding table is sharded on d_model (collective-free gather)
        "embed_shard": "model" if cfg.d_model % tp == 0 else None,
        "heads": "model" if hd_total % tp == 0 else None,
        "kv_heads": "model" if kv_total % tp == 0 else None,
        "mlp": "model" if cfg.d_ff % tp == 0 or cfg.n_experts else "model",
        "expert": "model" if (cfg.n_experts and cfg.n_experts % tp == 0) else None,
        "vocab": "model" if cfg.vocab % tp == 0 else "model",
        "layers": None,
    }


def param_pspecs(model, cfg: ArchConfig, mesh: Mesh):
    return model.pspecs(make_rules(cfg, mesh))


def param_shardings(model, cfg: ArchConfig, mesh: Mesh):
    return jax.tree.map(
        lambda ps: NamedSharding(mesh, ps), param_pspecs(model, cfg, mesh)
    )


# ---------------------------------------------------------------------------
# batches
# ---------------------------------------------------------------------------
def batch_pspecs(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh):
    dp = dp_axes_for(mesh, shape.global_batch)
    specs: dict[str, P] = {"tokens": P(dp, None)}
    if shape.kind == "train":
        specs["labels"] = P(dp, None)
    if cfg.is_encdec and shape.kind != "decode":
        specs["frames"] = P(dp, None, None)
    if cfg.is_vlm and shape.kind != "decode":
        specs["patches"] = P(dp, None, None)
    return specs


def batch_shardings(cfg, shape, mesh):
    return {
        k: NamedSharding(mesh, v) for k, v in batch_pspecs(cfg, shape, mesh).items()
    }


# ---------------------------------------------------------------------------
# optimizer state (ZeRO-1)
# ---------------------------------------------------------------------------
def zero_pspec(spec: jax.ShapeDtypeStruct, pspec: P, mesh: Mesh) -> P:
    """Shard the first replicated, divisible dim of a moment tensor over the
    DP axes (ZeRO-1). Scalars and already-fully-sharded leaves pass through."""
    dims = list(pspec) + [None] * (len(spec.shape) - len(pspec))
    dp = dp_axes(mesh)
    dp_n = dp_size(mesh)
    used = {a for d in dims if d is not None
            for a in (d if isinstance(d, tuple) else (d,))}
    if any(a in used for a in dp):
        return pspec
    for i, (dim, assignment) in enumerate(zip(spec.shape, dims)):
        if assignment is None and dim % dp_n == 0 and dim > 0:
            dims[i] = dp if len(dp) > 1 else dp[0]
            return P(*dims)
    return pspec


def optimizer_pspecs(model, cfg: ArchConfig, mesh: Mesh, zero: bool = True):
    """Pspecs tree mirroring opt.init_state(params): {m, v, step}."""
    pspecs = param_pspecs(model, cfg, mesh)
    specs = model.specs()
    if zero:
        moments = jax.tree.map(
            lambda s, ps: zero_pspec(s, ps, mesh), specs, pspecs
        )
    else:
        moments = pspecs
    return {"m": moments, "v": jax.tree.map(lambda x: x, moments), "step": P()}


# ---------------------------------------------------------------------------
# KV / recurrent-state caches
# ---------------------------------------------------------------------------
def cache_pspecs(model, cfg: ArchConfig, mesh: Mesh, batch: int = 0):
    """Pspecs tree mirroring model.cache_specs(batch, max_len)."""
    dp = dp_axes_for(mesh, batch) if batch else dp_axes(mesh)
    tp = tp_size(mesh)

    def kv_spec():
        # (L, B, T, Hkv, D): heads if divisible else context-parallel length
        if cfg.n_kv_heads % tp == 0 or cfg.kv_shard_heads_padded:
            return P(None, dp, None, "model", None)
        return P(None, dp, "model", None, None)

    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        if cfg.kv_quant:
            out = {"k_q": kv_spec(), "v_q": kv_spec(),
                   "k_s": kv_spec(), "v_s": kv_spec(), "len": P()}
        else:
            out = {"k": kv_spec(), "v": kv_spec(), "len": P()}
        if cfg.mrope_sections:
            out["pos_next"] = P()
        return out
    if fam == "audio":
        # cross-attn KV: n_audio_ctx (1500) divides nothing — replicate over
        # model (73 MB/device at decode_32k, measured in EXPERIMENTS.md)
        cross = P(None, dp, None, None, None)
        return {"k": kv_spec(), "v": kv_spec(), "ck": cross,
                "cv": cross, "len": P()}
    if fam == "ssm":
        return {
            "tm_x": P(None, dp, "model" if cfg.d_model % tp == 0 else None),
            "cm_x": P(None, dp, "model" if cfg.d_model % tp == 0 else None),
            # (L, B, H, N, N): heads over model (64 % 16 == 0)
            "s": P(None, dp, "model", None, None),
            "len": P(),
        }
    if fam == "hybrid":
        w_ok = (cfg.rglru_width or cfg.d_model) % tp == 0
        rec = {
            "h": P(None, dp, "model" if w_ok else None),
            "conv": P(None, dp, None, "model" if w_ok else None),
        }
        tail_rec = {
            "h": P(dp, "model" if w_ok else None),
            "conv": P(dp, None, "model" if w_ok else None),
        }
        n_tail = cfg.n_layers - 3 * (cfg.n_layers // 3)
        return {
            "periods": {"r1": rec, "r2": dict(rec)},
            "tail": {f"t{i}": dict(tail_rec) for i in range(n_tail)},
            # MQA kv=1: shard window length over model
            "k": P(None, dp, "model", None, None),
            "v": P(None, dp, "model", None, None),
            "len": P(),
        }
    raise KeyError(fam)


def logits_pspec(cfg: ArchConfig, mesh: Mesh) -> P:
    return P(dp_axes(mesh), None, "model" if cfg.vocab % tp_size(mesh) == 0 else None)
