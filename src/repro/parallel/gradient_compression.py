"""Error-bounded gradient compression with error feedback.

The paper's residual machinery applied *temporally*: each step, the gradient
plus the carried quantization residual is block-quantized (int8/int4 with
per-block scales — the same primitive as repro/kernels/block_quant); the
quantization error is fed back into the next step's residual, so the method
is unbiased over time (EF-SGD family) and the per-step l-inf error is bounded
by scale/2 per block.

Three integration modes:
  * ``compress_tree`` — post-allreduce quantization inside the jit'd train
    step (models the numerics; SPMD collectives unchanged);
  * ``quantized_psum`` — the per-shard exchange body (quantize, all-gather
    int payload + scales, dequant-sum locally), callable *inside* an
    enclosing ``shard_map`` — this is what the mesh DP trainer
    (``parallel/mesh_fit.py``) routes its gradient exchange through when
    ``quantized_exchange=True``;
  * ``quantized_all_reduce`` — standalone ``shard_map(quantized_psum)``:
    the actual 4x wire saving for DP gradient exchange, validated in tests
    on an 8-device CPU mesh.

Wire accounting for the DP exchange lives in
``mesh_fit.dp_wire_report`` (static, from the gradient leaf shapes).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    n_bits: int = 8
    block: int = 64
    enabled: bool = True


def _quant_dequant(x: jax.Array, n_bits: int, block: int):
    """Per-block symmetric quantize->dequantize on a flattened tensor."""
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    xb = flat.reshape(-1, block)
    qmax = float(2 ** (n_bits - 1) - 1)
    scale = jnp.maximum(jnp.max(jnp.abs(xb), axis=-1, keepdims=True), 1e-30) / qmax
    q = jnp.clip(jnp.round(xb / scale), -qmax - 1, qmax)
    out = (q * scale).reshape(-1)[: x.size].reshape(x.shape)
    return out.astype(x.dtype)


def init_residuals(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_tree(grads, residuals, cfg: CompressionConfig):
    """Returns (compressed_grads, new_residuals). Error feedback:
    g_hat = Q(g + r);  r' = (g + r) - g_hat."""
    if not cfg.enabled:
        return grads, residuals

    def one(g, r):
        total = g.astype(jnp.float32) + r
        g_hat = _quant_dequant(total, cfg.n_bits, cfg.block)
        return g_hat.astype(g.dtype), total - g_hat.astype(jnp.float32)

    flat_g, tree = jax.tree.flatten(grads)
    flat_r = tree.flatten_up_to(residuals)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (tree.unflatten([o[0] for o in outs]),
            tree.unflatten([o[1] for o in outs]))


def quantized_psum(local: jax.Array, axis: str = "data",
                   n_bits: int = 8, block: int = 64) -> jax.Array:
    """Quantized psum of a per-shard value, inside an enclosing shard_map.

    The shard quantizes its local tensor (int payload + one fp32 scale per
    ``block`` values), all-gathers the quantized payloads over ``axis``,
    and sums the dequantized contributions locally — the wire carries
    int8 + scales instead of fp32. Every shard returns the same full sum,
    so this is a drop-in for ``jax.lax.psum`` (up to quantization error;
    the mesh DP trainer's convergence test covers the numerics).
    """
    qmax = float(2 ** (n_bits - 1) - 1)
    flat = local.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    xb = flat.reshape(-1, block)
    scale = jnp.maximum(jnp.max(jnp.abs(xb), -1, keepdims=True), 1e-30) / qmax
    q = jnp.clip(jnp.round(xb / scale), -qmax - 1, qmax).astype(jnp.int8)
    q_all = jax.lax.all_gather(q, axis)  # (P, nb, block) int8 on the wire
    s_all = jax.lax.all_gather(scale, axis)
    total = jnp.sum(q_all.astype(jnp.float32) * s_all, axis=0)
    return total.reshape(-1)[: local.size].reshape(local.shape).astype(
        local.dtype)


def quantized_all_reduce(x: jax.Array, mesh: Mesh, axis: str = "data",
                         n_bits: int = 8, block: int = 64) -> jax.Array:
    """All-reduce over `axis` with int8 wire format.

    Each device quantizes its local shard (int + fp32 scales), all-gathers
    the quantized payload, and sums dequantized contributions locally.
    Wire volume: n*(P-1)/P bytes int8 + scales vs 2*n*(P-1)/P * 4 bytes for
    a ring all-reduce in fp32 -> ~8x reduction at 8 bits.
    """
    from jax.experimental.shard_map import shard_map

    inner = functools.partial(quantized_psum, axis=axis, n_bits=n_bits,
                              block=block)
    # input sharded on dim 0 over `axis`; every shard returns the full sum
    return shard_map(
        inner, mesh=mesh, in_specs=P(axis), out_specs=P(axis),
        check_rep=False,
    )(x)
