"""Encoder-family registry: the pluggable seam of the GBATC codec core.

The paper's pipeline is architecture-agnostic by construction — the
guarantee engine bounds *whatever* reconstruction the decoder produces —
so the codec core dispatches every model-shaped decision through this
registry instead of hard-wiring the conv block autoencoder. Each
:class:`EncoderFamily` owns:

* its **wire identity** — a one-byte family tag carried in the container
  v5 ``meta`` stream (below v5 the family is implicitly ``"conv"``);
* its **arch words** — the family-specific u16 fields riding in the meta
  stream's arch slot (conv: the conv channel widths; attention:
  ``(d_model, n_heads, depth, mlp_hidden)``) plus their validation;
* **model construction** from a :class:`StructuralConfig` (everything
  the decode side needs travels in the blob — no ambient pipeline
  state), the training entry point, the decode-side parameter defs, and
  the fused-decode builder.

:class:`StructuralConfig` is the family-owned structural config the
decode path runs on: :func:`structural` normalizes any config-shaped
object (a ``PipelineConfig``, an artifact's unpacked config, another
``StructuralConfig``) into it, so ``runtime._runtime`` keys and builds
decode runtimes from blob-derivable facts alone — two families sharing
geometry/latent can never alias a runtime (the family name is part of
the key and of the config's equality).
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Any, Callable, Optional

if TYPE_CHECKING:  # annotation-only: the core package's __init__ imports
    from repro.core import blocking  # the pipeline, which imports us


# ---------------------------------------------------------------------------
# family-owned structural config (what the decode path runs on)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class StructuralConfig:
    """Structure the blob alone determines: enough to rebuild the decode
    runtime, nothing more (no training hyperparameters, no ambient
    state). ``arch`` is the family's wire arch tuple."""

    family: str
    geometry: blocking.BlockGeometry
    latent: int
    arch: tuple[int, ...]
    use_correction: bool
    param_dtype_bytes: int

    @property
    def conv_channels(self) -> tuple[int, ...]:
        """Conv-family alias for ``arch`` (the historical field name;
        artifact consumers read ``artifact.cfg.conv_channels``)."""
        return self.arch


def structural(cfg: Any) -> StructuralConfig:
    """Normalize any config-shaped object into a :class:`StructuralConfig`.

    Duck-typed: accepts a ``StructuralConfig`` (returned as-is), a
    ``repro.core.pipeline.PipelineConfig`` (its optional ``family`` /
    ``arch`` fields resolve through the registry; a conv config's arch
    defaults to its ``conv_channels``), or anything exposing the same
    attributes. The result is the *identity* the runtime cache keys on.
    """
    if isinstance(cfg, StructuralConfig):
        return cfg
    fam = get(getattr(cfg, "family", None) or "conv")
    return StructuralConfig(
        family=fam.name,
        geometry=cfg.geometry,
        latent=int(cfg.latent),
        arch=fam.arch_of(cfg),
        use_correction=bool(cfg.use_correction),
        param_dtype_bytes=int(cfg.param_dtype_bytes),
    )


# ---------------------------------------------------------------------------
# fused decode builder (shared across families; families may override)
# ---------------------------------------------------------------------------
def make_fused_decode(model, corr_net):
    """Traceable latents -> corrected (S, NB, D) block vectors.

    The whole NN decode — family decoder, pointwise tensor correction, and
    the blocks->vectors layout change — as one function of device arrays,
    so a single jit dispatch replaces chunked host round-trips. All
    reshuffles are pure transposes; per-element arithmetic is identical to
    the staged path (bit-identity asserted in tests and the benchmark).
    Any model exposing ``cfg.n_species`` and ``decode(params, z) ->
    (NB, S, bt, ph, pw)`` composes — both registered families do.
    """
    s = model.cfg.n_species

    def fused(dec_params, corr_params, lat):
        x = model.decode(dec_params, lat)  # (NB, S, bt, ph, pw)
        nb = x.shape[0]
        if corr_net is not None:
            vec = x.reshape(nb, s, -1).transpose(0, 2, 1).reshape(-1, s)
            vec = corr_net(corr_params, vec)
            x = vec.reshape(nb, -1, s).transpose(0, 2, 1).reshape(x.shape)
        return x.reshape(nb, s, -1).transpose(1, 0, 2)  # (S, NB, D)

    return fused


def _decoder_defs(model) -> dict:
    """Decode-side parameter defs: the ``dec``-prefixed subtree, the
    single source for what travels in the ``decoder`` stream."""
    return {k: v for k, v in model.defs.items() if k.startswith("dec")}


# ---------------------------------------------------------------------------
# the registry
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class EncoderFamily:
    """One pluggable encoder/decoder family.

    ``tag`` is the family's wire identity (container v5 meta stream; 0 is
    reserved as invalid). ``arch_of`` extracts the family's arch words
    from a config-shaped object; ``validate_arch`` returns an error
    string for arch words that cannot configure a model (the wire layer
    turns it into a ``ContainerFormatError`` with meta coordinates).
    """

    name: str
    tag: int
    build_model: Callable[[StructuralConfig, int, str], Any]
    fit: Callable[..., tuple]
    arch_of: Callable[[Any], tuple]
    validate_arch: Callable[[tuple], Optional[str]]
    decoder_defs: Callable[[Any], dict] = _decoder_defs
    make_fused: Callable[[Any, Any], Any] = make_fused_decode


def _conv_build(scfg: StructuralConfig, n_species: int,
                backend: str = "2d"):
    from repro.core import autoencoder as ae

    geom = scfg.geometry
    return ae.BlockAutoencoder(ae.AEConfig(
        n_species=n_species,
        block=(geom.bt, geom.ph, geom.pw),
        latent=scfg.latent,
        conv_channels=scfg.arch,
        conv_impl=backend,
    ))


def _conv_fit(model, blocks, **kw):
    from repro.core import autoencoder as ae

    return ae.fit(model, blocks, **kw)


def _conv_arch_of(cfg: Any) -> tuple:
    arch = getattr(cfg, "arch", None)
    if arch is None:
        arch = cfg.conv_channels
    return tuple(int(c) for c in arch)


def _conv_validate(arch: tuple) -> Optional[str]:
    return None  # any positive widths configure a conv stack


#: default attention arch words (d_model, n_heads, depth, mlp_hidden) —
#: sized for the paper's 2-core CI surrogate; override via
#: ``PipelineConfig(family="attention", arch=...)``
DEFAULT_ATTENTION_ARCH = (32, 2, 1, 64)


def _attention_build(scfg: StructuralConfig, n_species: int,
                     backend: str = "2d"):
    from repro.models import block_attention as ba

    del backend  # one attention path serves both runtime twins
    geom = scfg.geometry
    dm, nh, depth, mlp = scfg.arch
    return ba.BlockAttentionAE(ba.BlockAttentionConfig(
        n_species=n_species,
        block=(geom.bt, geom.ph, geom.pw),
        latent=scfg.latent,
        d_model=dm, n_heads=nh, depth=depth, mlp_hidden=mlp,
    ))


def _attention_fit(model, blocks, **kw):
    from repro.models import block_attention as ba

    return ba.fit(model, blocks, **kw)


def _attention_arch_of(cfg: Any) -> tuple:
    arch = getattr(cfg, "arch", None)
    if arch is None:
        arch = DEFAULT_ATTENTION_ARCH
    arch = tuple(int(c) for c in arch)
    err = _attention_validate(arch)
    if err:
        raise ValueError(f"bad attention arch {arch}: {err}")
    return arch


def _attention_validate(arch: tuple) -> Optional[str]:
    if len(arch) != 4:
        return (f"attention arch carries {len(arch)} words, expected 4 "
                f"(d_model, n_heads, depth, mlp_hidden)")
    dm, nh, _, _ = arch
    if dm % nh:
        return f"d_model {dm} not divisible by n_heads {nh}"
    return None


CONV = EncoderFamily(
    name="conv", tag=1,
    build_model=_conv_build, fit=_conv_fit,
    arch_of=_conv_arch_of, validate_arch=_conv_validate,
)
ATTENTION = EncoderFamily(
    name="attention", tag=2,
    build_model=_attention_build, fit=_attention_fit,
    arch_of=_attention_arch_of, validate_arch=_attention_validate,
)

FAMILIES: dict[str, EncoderFamily] = {f.name: f for f in (CONV, ATTENTION)}
_BY_TAG: dict[int, EncoderFamily] = {f.tag: f for f in FAMILIES.values()}
assert len(_BY_TAG) == len(FAMILIES) and 0 not in _BY_TAG, \
    "family tags must be unique and nonzero"


def get(name: str) -> EncoderFamily:
    """Family handle by name; raises ``ValueError`` on unknown names
    (caller-supplied config — not wire data, which goes via ``by_tag``)."""
    try:
        return FAMILIES[name]
    except KeyError:
        raise ValueError(
            f"unknown encoder family {name!r} "
            f"(registered: {sorted(FAMILIES)})"
        ) from None


def by_tag(tag: int) -> Optional[EncoderFamily]:
    """Family handle by wire tag, ``None`` when unregistered — the wire
    layer raises the structured ``ContainerFormatError``."""
    return _BY_TAG.get(tag)


def registered() -> tuple[tuple[str, int], ...]:
    """(name, tag) pairs, sorted by tag — what the wire-schema
    conformance pass cross-checks its declarative family table against."""
    return tuple(sorted(((f.name, f.tag) for f in FAMILIES.values()),
                        key=lambda p: p[1]))
