"""Latent stores: uniform block-row access over per-version latent layouts.

Container v1/v2 carry ONE sequential Huffman chain (any row requires the
full walk, so it decodes whole at head parse); v3 carries independent
per-shard chains under a shared codebook, decoded lazily — a block-row
window touches only its covering shards — which is what makes a window
query O(window) in latent entropy work.
"""

from __future__ import annotations

import struct

import numpy as np

from repro.codec import format as wire
from repro.core import entropy
from repro.core.container import ContainerFormatError


class _ChainLatents:
    """v1/v2 ``latent`` stream: ONE sequential Huffman chain.

    Decoded whole at head parse (any row requires the full chain walk, and
    eager decode keeps the historical corruption-error surface); row access
    is then a slice.
    """

    def __init__(self, stream: bytes, nb: int, n_lat: int,
                 table_cache: entropy.DecodeTableCache, huffman=None):
        try:
            if huffman is None:
                q = entropy.huffman_decode(stream, table_cache=table_cache)
            else:
                q = huffman(stream)
        except (ValueError, struct.error) as e:
            # struct.error: a truncated Huffman header (not a ValueError)
            raise ContainerFormatError(
                f"corrupt latent stream: {e}", stream="latent"
            ) from e
        if q.size != nb * n_lat:
            raise ContainerFormatError(
                f"latent stream decodes to {q.size} symbols, "
                f"expected {nb * n_lat}",
                stream="latent",
            )
        self._q = q.reshape(nb, n_lat)
        self._nbytes = len(stream)

    def full(self) -> np.ndarray:
        return self._q

    def rows(self, b0: int, b1: int) -> np.ndarray:
        return self._q[b0:b1]

    def salvage_rows(self, b0: int, b1: int):
        # a chain store only exists if the whole chain decoded at
        # construction; there is no per-unit quarantine below v3
        return self._q[b0:b1], []

    def bytes_parsed(self, b0: int, b1: int) -> int:
        # a sequential chain walks whole regardless of the window
        return self._nbytes

    def entropy_bytes(self, b0: int, b1: int) -> int:
        return self._nbytes


class _ShardedLatents:
    """v3+ ``latent`` stream: independent per-shard chains, shared codebook.

    Shards entropy-decode lazily — a block-row window touches only the
    covering shards — in one lockstep multi-chain walk, and memoize either
    locally on the store or (once :meth:`attach_cache` binds the store to
    a cached head) in the shared byte-budgeted shard tier, keyed under the
    head's token: repeated window queries pay entropy once per shard,
    eviction just means a deterministic re-decode. A corrupt shard raises
    :class:`ContainerFormatError` naming it and never poisons siblings.

    ``integrity`` (container v4) supplies per-shard CRC32 digests: every
    shard's chain payload is digest-checked immediately before its first
    entropy decode — so a flipped payload bit that would still walk to a
    plausible symbol count is *detected*, not silently decoded — and the
    check is paid exactly once per shard (memoized with the decode).
    """

    def __init__(self, directory: wire.LatentShardDirectory, nb: int,
                 n_lat: int, table_cache: entropy.DecodeTableCache,
                 reference: bool = False, integrity=None):
        if directory.n_rows != nb or directory.n_cols != n_lat:
            raise ContainerFormatError(
                f"latent shard stream covers ({directory.n_rows}, "
                f"{directory.n_cols}) latents, meta stream declares "
                f"({nb}, {n_lat})",
                stream="latent",
            )
        if (integrity is not None
                and len(integrity.shard_crcs) != directory.n_shards):
            raise ContainerFormatError(
                f"integrity stream carries {len(integrity.shard_crcs)} "
                f"shard digests, latent stream has {directory.n_shards} "
                f"shards",
                stream="integrity",
            )
        self._dir = directory
        self._n_lat = n_lat
        self._cache = None if reference else table_cache
        self._shards: dict[int, np.ndarray] = {}
        self._full: "np.ndarray | None" = None
        self._reference = reference
        self._integrity = integrity
        # shared shard tier (set by runtime._attach_cache when this store's
        # head is admitted to the decode cache); until then — and for
        # reference / salvage / fresh-parse stores forever — the local
        # dicts above memoize instead
        self._tier = None
        self._token = None

    def attach_cache(self, tier, token) -> None:
        """Bind the store to the shared shard tier under ``token``
        (migrating anything already decoded through the local memos)."""
        for k, arr in list(self._shards.items()):
            tier.put((token, k), arr, arr.nbytes)
        self._shards.clear()
        if self._full is not None:
            tier.put((token, "full"), self._full, self._full.nbytes)
            self._full = None
        self._tier = tier
        self._token = token

    # -- memo indirection: shared tier when attached, local dicts before --
    def _shard_get(self, k: int):
        if self._tier is not None:
            return self._tier.get((self._token, k))
        return self._shards.get(k)

    def _shard_put(self, k: int, arr: np.ndarray) -> None:
        if self._tier is not None:
            self._tier.put((self._token, k), arr, arr.nbytes)
        else:
            self._shards[k] = arr

    def _full_peek(self):
        if self._tier is not None:
            return self._tier.peek((self._token, "full"))
        return self._full

    def _verify(self, k: int) -> None:
        if self._integrity is not None:
            self._integrity.verify_shard(k, self._dir.shard_payload(k))

    def _decode_one(self, k: int) -> np.ndarray:
        d = self._dir
        self._verify(k)
        try:
            if self._reference:
                # true pre-change cost profile: per-call tables and the
                # retained per-code-bit window pass, per shard
                return entropy.huffman_decode_payload_ref(
                    d.shard_payload(k), d.shard_count(k),
                    d.symbols, d.lengths,
                )
            return entropy.huffman_decode_payload(
                d.shard_payload(k), d.shard_count(k), d.symbols, d.lengths,
                table_cache=self._cache,
            )
        except ValueError as e:
            raise ContainerFormatError(
                f"latent shard {k}: {e}", stream="latent", unit=k,
                offset=d.shard_extent(k)[0],
            ) from e

    def _shape(self, k: int, arr: np.ndarray) -> np.ndarray:
        r0, r1 = self._dir.shard_row_extent(k)
        return arr.reshape(r1 - r0, self._n_lat)

    def _gather(self, k0: int, k1: int) -> "list[np.ndarray]":
        """Shards ``[k0, k1)`` as LOCAL references: each shard is looked up
        in the memo, decoded on miss, and *held* — so an eviction racing
        this window (another thread filling the tier) can never drop an
        array out from under the caller mid-assembly."""
        got: "dict[int, np.ndarray]" = {}
        for k in range(k0, k1):
            arr = self._shard_get(k)
            if arr is not None:
                got[k] = arr
        missing = [k for k in range(k0, k1) if k not in got]
        d = self._dir
        if missing and not self._reference and len(missing) > 1:
            for k in missing:
                self._verify(k)
            try:
                arrs = entropy.huffman_decode_payloads(
                    [d.shard_payload(k) for k in missing],
                    [d.shard_count(k) for k in missing],
                    d.symbols, d.lengths, table_cache=self._cache,
                )
            except ValueError:
                pass  # per-shard walk below names the culprit
            else:
                for k, arr in zip(missing, arrs):
                    got[k] = self._shape(k, arr)
                    self._shard_put(k, got[k])
                missing = []
        # shard-by-shard: store each healthy shard as it decodes, so a
        # corrupt sibling raising (named) never discards finished work
        for k in missing:
            got[k] = self._shape(k, self._decode_one(k))
            self._shard_put(k, got[k])
        return [got[k] for k in range(k0, k1)]

    def salvage_rows(self, b0: int, b1: int):
        """Block rows ``[b0, b1)`` with corrupt shards quarantined.

        Decodes each covering shard independently (digest-checked when the
        container carries integrity digests); a shard that fails fills its
        rows with zeros instead of raising. Returns ``(rows, bad)`` where
        ``bad`` lists ``(shard, row_lo, row_hi, error)`` for every
        quarantined shard's intersection with the window — the caller must
        mask those rows out of any decoded output.
        """
        full = self._full_peek()
        if full is not None:  # every shard already decoded clean
            return full[b0:b1], []
        k0, k1 = self._dir.shards_for_rows(b0, b1)
        parts = []
        bad = []
        for k in range(k0, k1):
            r0, r1 = self._dir.shard_row_extent(k)
            arr = self._shard_get(k)
            if arr is None:
                try:
                    arr = self._shape(k, self._decode_one(k))
                except ContainerFormatError as e:
                    bad.append((k, max(r0, b0), min(r1, b1), e))
                    parts.append(np.zeros((r1 - r0, self._n_lat), np.int64))
                    continue
                self._shard_put(k, arr)
            parts.append(arr)
        base = self._dir.shard_row_extent(k0)[0]
        rows = np.concatenate(parts, axis=0)[b0 - base : b1 - base]
        return rows, bad

    def rows(self, b0: int, b1: int) -> np.ndarray:
        full = self._full_peek()
        if full is not None:  # fully assembled: slices are views
            return full[b0:b1]
        k0, k1 = self._dir.shards_for_rows(b0, b1)
        base = self._dir.shard_row_extent(k0)[0]
        out = np.concatenate(self._gather(k0, k1), axis=0)
        return out[b0 - base : b1 - base]

    def full(self) -> np.ndarray:
        # memoized: repeat full decodes through a cached head must not pay
        # an O(NB * latent) re-concatenation per query. The per-shard
        # arrays are dropped once assembled — rows() serves views of the
        # full array from then on, so keeping both would double the
        # decoded-latent bytes the cache pins. (Tier-attached stores may
        # see the full array evicted under byte pressure; re-assembly is
        # deterministic, so that is a cost, never a correctness event.)
        full = self._full_peek()
        if full is None:
            full = self.rows(0, self._dir.n_rows)
            if self._tier is not None:
                self._tier.put((self._token, "full"), full, full.nbytes)
                for k in range(self._dir.n_shards):
                    self._tier.discard((self._token, k))
            else:
                self._full = full
                self._shards.clear()
        return full

    def bytes_parsed(self, b0: int, b1: int) -> int:
        """Stream bytes a window decode touches: head + covering chains."""
        return self._dir.header_bytes + self._dir.window_payload_bytes(b0, b1)

    def entropy_bytes(self, b0: int, b1: int) -> int:
        """Chain bytes a window decode entropy-decodes (the O(window) term)."""
        return self._dir.window_payload_bytes(b0, b1)


