"""Multi-tier decode cache: blob head -> latent shard -> guarantee tiers.

The PR-5 head memo was a module-global 4-entry ``OrderedDict`` with no
byte accounting, no stats, and unbounded per-head shard/artifact memos
pinned underneath it — fine for one caller, wrong for a decode service
where many clients hammer a fleet of blobs. This module replaces it with
a small cache engine shared by every decode entry point:

* :class:`CacheTier` — a thread-safe LRU bounded by a **byte budget**
  (and optionally an entry count), with admission control (an entry
  larger than the whole budget is rejected, not thrashed through) and
  hit/miss/insert/eviction/rejection counters.
* :class:`DecodeCache` — the three named tiers the decode path uses:

  ===========  ============================================  ==========
  tier         key -> value                                  unit bytes
  ===========  ============================================  ==========
  ``head``     blob content -> parsed ``_DecodedHead``       blob size
  ``shard``    (head token, shard) -> decoded latent rows    array bytes
  ``guarantee``  (head token, species) -> guarantee artifact   stream bytes
  ===========  ============================================  ==========

  Sub-tier keys carry a per-head *token* (allocated at head parse), so
  two byte-different blobs can never alias an entry even if their shard
  contents agree positionally; evicting a head cascades to its shard and
  guarantee entries (they would otherwise be unreachable pins).

Values re-derive deterministically from the blob bytes, so eviction is
always safe: a re-decoded shard or artifact is bitwise the evicted one.
No wall-clock anywhere — recency is pure access order, keeping cache
state reproducible for the bit-identity gates.

:func:`repro.codec.cache_stats` surfaces the counters;
``repro.codec.configure_decode_cache`` re-budgets the tiers (dropping
current contents); ``clear_decode_cache`` empties every tier (plus the
per-runtime Huffman decode-table memos, see
:func:`repro.codec.runtime.clear_decode_cache`).
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Callable, Optional


class TierStats:
    """Counter block for one tier (plain ints; snapshot via ``as_dict``)."""

    __slots__ = ("hits", "misses", "insertions", "evictions", "rejections")

    def __init__(self):
        self.hits = 0
        self.misses = 0
        self.insertions = 0
        self.evictions = 0
        self.rejections = 0

    def as_dict(self) -> dict:
        total = self.hits + self.misses
        return {
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": (self.hits / total) if total else 0.0,
            "insertions": self.insertions,
            "evictions": self.evictions,
            "rejections": self.rejections,
        }


class CacheTier:
    """Byte-budgeted LRU with admission control and counters.

    ``get`` moves a hit to most-recent; ``put`` evicts least-recent
    entries until the new entry fits inside ``capacity_bytes`` (and
    ``max_entries``, when set). An entry whose cost alone exceeds the
    byte budget is *rejected* — admitting it would evict the whole tier
    for a value too big to ever be joined by a second one. Thread-safe;
    no wall clock (recency is access order only, so cache behaviour is
    a deterministic function of the access sequence).
    """

    def __init__(self, name: str, capacity_bytes: int,
                 max_entries: Optional[int] = None):
        if capacity_bytes < 0:
            raise ValueError(f"capacity_bytes must be >= 0, got "
                             f"{capacity_bytes}")
        self.name = name
        self.capacity_bytes = int(capacity_bytes)
        self.max_entries = max_entries
        self.stats = TierStats()
        self._lock = threading.Lock()
        self._entries: "OrderedDict[Any, tuple[Any, int]]" = OrderedDict()
        self._bytes = 0
        # eviction hook: called OUTSIDE the lock with (key, value) of every
        # evicted entry (DecodeCache cascades head evictions through it)
        self.on_evict: Optional[Callable[[Any, Any], None]] = None

    # -- core ops ---------------------------------------------------------
    def get(self, key):
        with self._lock:
            hit = self._entries.get(key)
            if hit is None:
                self.stats.misses += 1
                return None
            self._entries.move_to_end(key)
            self.stats.hits += 1
            return hit[0]

    def peek(self, key):
        """Like ``get`` but uncounted: internal probes that are not logical
        lookups (e.g. ``rows`` probing for an already-assembled full latent
        array) refresh recency without skewing the hit/miss counters."""
        with self._lock:
            hit = self._entries.get(key)
            if hit is None:
                return None
            self._entries.move_to_end(key)
            return hit[0]

    def put(self, key, value, nbytes: int) -> bool:
        """Insert (or refresh) ``key``; returns False on admission reject."""
        nbytes = int(nbytes)
        evicted = []
        with self._lock:
            if nbytes > self.capacity_bytes:
                self.stats.rejections += 1
                return False
            old = self._entries.pop(key, None)
            if old is not None:
                self._bytes -= old[1]
            while self._entries and (
                self._bytes + nbytes > self.capacity_bytes
                or (self.max_entries is not None
                    and len(self._entries) >= self.max_entries)
            ):
                k, (v, b) = self._entries.popitem(last=False)
                self._bytes -= b
                self.stats.evictions += 1
                evicted.append((k, v))
            self._entries[key] = (value, nbytes)
            self._bytes += nbytes
            self.stats.insertions += 1
        if self.on_evict is not None:
            for k, v in evicted:
                self.on_evict(k, v)
        return True

    def discard(self, key) -> bool:
        """Drop one entry (no eviction counter — caller-driven removal)."""
        with self._lock:
            old = self._entries.pop(key, None)
            if old is not None:
                self._bytes -= old[1]
        if old is not None and self.on_evict is not None:
            self.on_evict(key, old[0])
        return old is not None

    def discard_group(self, token) -> int:
        """Drop every entry whose key is a tuple starting with ``token``
        (the cascade path for a head's shard/guarantee entries)."""
        with self._lock:
            doomed = [k for k in self._entries
                      if isinstance(k, tuple) and k and k[0] == token]
            for k in doomed:
                self._bytes -= self._entries.pop(k)[1]
        return len(doomed)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._bytes = 0

    # -- introspection ----------------------------------------------------
    def __contains__(self, key) -> bool:
        with self._lock:
            return key in self._entries

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def keys(self) -> list:
        with self._lock:
            return list(self._entries)

    @property
    def nbytes(self) -> int:
        with self._lock:
            return self._bytes

    def as_dict(self) -> dict:
        with self._lock:
            entries, nbytes = len(self._entries), self._bytes
        d = self.stats.as_dict()
        d.update(entries=entries, bytes=nbytes,
                 capacity_bytes=self.capacity_bytes)
        if self.max_entries is not None:
            d["max_entries"] = self.max_entries
        return d


# defaults sized for a serving box holding a handful of hot blobs: heads
# pin their blob bytes (+ parsed params), shards pin decoded int64 rows
# (the dominant term), artifacts pin entropy-decoded guarantee streams
DEFAULT_HEAD_BYTES = 256 * 1024 * 1024
DEFAULT_SHARD_BYTES = 512 * 1024 * 1024
DEFAULT_GUARANTEE_BYTES = 256 * 1024 * 1024
# the PR-5 head memo kept at most 4 parsed heads; the entry bound stays
# as a belt alongside the new byte budget
DEFAULT_HEAD_ENTRIES = 4


class DecodeCache:
    """The decode path's three tiers, with head-eviction cascade."""

    def __init__(self, head_bytes: int = DEFAULT_HEAD_BYTES,
                 shard_bytes: int = DEFAULT_SHARD_BYTES,
                 guarantee_bytes: int = DEFAULT_GUARANTEE_BYTES,
                 head_entries: Optional[int] = DEFAULT_HEAD_ENTRIES):
        self.heads = CacheTier("head", head_bytes, max_entries=head_entries)
        self.shards = CacheTier("shard", shard_bytes)
        self.guarantees = CacheTier("guarantee", guarantee_bytes)
        self.heads.on_evict = self._cascade

    def _cascade(self, key, head) -> None:
        token = getattr(head, "token", None)
        if token is not None:
            self.shards.discard_group(token)
            self.guarantees.discard_group(token)

    def clear(self) -> None:
        for tier in (self.heads, self.shards, self.guarantees):
            tier.clear()

    def stats(self) -> dict:
        return {t.name: t.as_dict()
                for t in (self.heads, self.shards, self.guarantees)}
