"""GBATC container schemas: the wire layout layer of :mod:`repro.codec`.

Everything byte-layout lives here — the fixed ``meta`` struct, the
combined (container v2+) ``guarantee`` stream's CSR-of-CSR directory, the
time-sharded (container v3) ``latent`` stream, and the measured byte
accounting (:func:`stream_breakdown`). No model state, no jax: parsing a
directory slices bytes and validates framing, nothing more, which is what
lets the runtime/partial layers address any species or time shard without
touching sibling payloads.

Container v3's ``latent`` stream::

    magic "LAT3" | n_shards u32 | shard_rows u32 | n_rows u64 | n_cols u32
    codebook: k u32 | symbols k x i64 | code lengths k x u1
    shard table: n_shards x payload_len u64
    shard payloads, concatenated

The time axis is partitioned into fixed block-row shards (``shard_rows``
rows each, ragged tail allowed); every shard payload is an independently
decodable Huffman chain over ``rows * n_cols`` quantized latents under
the ONE shared codebook stored in the stream head — mirroring the
guarantee directory, every shard's byte extent follows from the table by
prefix sums, so a time-window decode entropy-decodes only the shards
covering the window (the O(window) latent path).

Container v4's ``integrity`` stream (appended to the v3 stream set)::

    magic "ITG1" | n_streams u16
    per sibling stream, table order: name_len u8 | name (ascii) | crc u32
    latent units:    head_len u32 | head_crc u32 | n_shards  u32 | n_shards  x crc u32
    guarantee units: dir_len  u32 | dir_crc  u32 | n_species u32 | n_species x crc u32
    outer_crc u32
    self_crc  u32

All digests are CRC32 (which detects *every* single-bit flip within a
region). The whole-stream digests cover each sibling stream's full
payload; the unit digests match the random-access units — the latent
stream's head region (framing + codebook + shard table, whose length is
stored explicitly so verification never depends on possibly-corrupt
framing), each shard's chain payload, the guarantee stream's directory
region, and each species' byte extent (its coeff/index/basis payloads,
CRC-chained in that order) — so :class:`~repro.codec.PartialDecoder`
verifies exactly the bytes a selection reads and no more. ``outer_crc``
digests the *outer* container header + stream table (computable before
the integrity payload exists because the table stores only this stream's
length); ``self_crc`` digests every preceding integrity byte, so a flip
inside the integrity stream itself is detected rather than mistaken for
payload corruption.
"""

from __future__ import annotations

import os
import struct
import zlib
from concurrent.futures import ThreadPoolExecutor
from typing import Optional

import numpy as np

from repro.codec import families
from repro.core import blocking, entropy
from repro.core import container as container_format
from repro.core.container import ContainerFormatError, ContainerReader

_FLAG_CORRECTION = 1

# flags, param_dtype_bytes, latent, bt, ph, pw, n_arch
_META_HEAD = struct.Struct("<BBHHHHH")
_META_SHAPE = struct.Struct("<IIIId")  # S, T, H, W, latent_bin
# container v5 prefixes the legacy meta body with ONE family-tag byte
# (see repro.codec.families); a conv-family v5 meta body is therefore
# byte-identical to the v4 meta of the same fit
_META_FAMILY = struct.Struct("<B")


def expected_stream_set(version: int, n_species: int,
                        has_correction: bool) -> frozenset:
    """The exact stream-name set a well-formed container of *version*
    carries. Strictness contract (PR 4): every stream must be accounted
    for by purpose — decode rejects blobs with stray or absent streams,
    and :mod:`repro.analysis.wire_schema` conformance-checks this table
    against its own declarative layout description."""
    names = {"meta", "latent", "decoder"}
    if has_correction:
        names.add("correction")
    if version >= container_format.FORMAT_VERSION_SELECTIVE:
        names.add("guarantee")
    else:
        names.update(f"guarantee{sidx}" for sidx in range(n_species))
    if version >= container_format.FORMAT_VERSION_INTEGRITY:
        names.add("integrity")
    return frozenset(names)


# ---------------------------------------------------------------------------
# meta stream
# ---------------------------------------------------------------------------
def _pack_meta(artifact, version: int = container_format.FORMAT_VERSION
               ) -> bytes:
    scfg = families.structural(artifact.cfg)
    fam = families.get(scfg.family)
    geom = scfg.geometry
    if (version < container_format.FORMAT_VERSION_FAMILY
            and fam.name != "conv"):
        raise ValueError(
            f"container v{version} predates encoder families: only the "
            f"conv family is representable (artifact is {fam.name!r}; "
            f"use version {container_format.FORMAT_VERSION_FAMILY}+)"
        )
    flags = _FLAG_CORRECTION if artifact.corr_params is not None else 0
    u16_fields = {
        "latent": scfg.latent,
        "bt": geom.bt,
        "ph": geom.ph,
        "pw": geom.pw,
        **{f"arch[{i}]": c for i, c in enumerate(scfg.arch)},
    }
    bad = {k: v for k, v in u16_fields.items() if not 0 < v <= 0xFFFF}
    if bad:
        raise ValueError(f"meta fields not representable as u16: {bad}")
    parts = []
    if version >= container_format.FORMAT_VERSION_FAMILY:
        parts.append(_META_FAMILY.pack(fam.tag))
    parts += [
        _META_HEAD.pack(
            flags,
            scfg.param_dtype_bytes,
            scfg.latent,
            geom.bt,
            geom.ph,
            geom.pw,
            len(scfg.arch),
        ),
        np.asarray(scfg.arch, dtype="<u2").tobytes(),
        _META_SHAPE.pack(*artifact.shape, artifact.latent_bin),
        np.ascontiguousarray(artifact.norm_min.astype("<f4")).tobytes(),
        np.ascontiguousarray(artifact.norm_range.astype("<f4")).tobytes(),
    ]
    return b"".join(parts)


def _unpack_meta(buf: bytes,
                 version: int = container_format.FORMAT_VERSION):
    base = 0
    fam = families.CONV  # below v5 the family is implicit
    if version >= container_format.FORMAT_VERSION_FAMILY:
        if len(buf) < _META_FAMILY.size:
            raise ContainerFormatError("meta stream truncated", stream="meta")
        (tag,) = _META_FAMILY.unpack_from(buf, 0)
        fam = families.by_tag(tag)
        if fam is None:
            raise ContainerFormatError(
                f"unknown encoder family tag {tag} "
                f"(registered: {families.registered()})",
                stream="meta", offset=0,
            )
        base = _META_FAMILY.size
    if len(buf) < base + _META_HEAD.size:
        raise ContainerFormatError("meta stream truncated", stream="meta")
    flags, pdb, latent, bt, ph, pw, n_arch = _META_HEAD.unpack_from(buf, base)
    if flags & ~_FLAG_CORRECTION:
        # unknown flag bits mean a newer writer (or corruption) — refuse
        # rather than decode under old-flag semantics
        raise ContainerFormatError(
            f"unknown meta flags 0x{flags:02x}", stream="meta", offset=base
        )
    off = base + _META_HEAD.size
    if len(buf) < off + 2 * n_arch + _META_SHAPE.size:
        raise ContainerFormatError("meta stream truncated", stream="meta")
    arch = tuple(
        int(c) for c in np.frombuffer(buf, dtype="<u2", count=n_arch, offset=off)
    )
    off += 2 * n_arch
    s, t, h, w, latent_bin = _META_SHAPE.unpack_from(buf, off)
    off += _META_SHAPE.size
    if len(buf) != off + 8 * s:
        raise ContainerFormatError(
            f"meta stream is {len(buf)} bytes, expected {off + 8 * s} "
            f"for {s} species",
            stream="meta",
        )
    if pdb not in (2, 4):
        raise ContainerFormatError(
            f"bad param dtype byte {pdb} (expected 2 or 4)", stream="meta"
        )
    if min(bt, ph, pw, latent, n_arch, s, t, h, w) < 1 or min(arch) < 1:
        raise ContainerFormatError(
            f"meta stream carries degenerate structure: geometry "
            f"({bt},{ph},{pw}), latent {latent}, arch {arch}, shape "
            f"({s},{t},{h},{w})",
            stream="meta",
        )
    arch_err = fam.validate_arch(arch)
    if arch_err:
        raise ContainerFormatError(
            f"meta stream carries bad {fam.name} arch: {arch_err}",
            stream="meta",
        )
    norm_min = np.frombuffer(buf, dtype="<f4", count=s, offset=off).copy()
    norm_range = np.frombuffer(buf, dtype="<f4", count=s, offset=off + 4 * s).copy()
    if not (np.isfinite(latent_bin) and latent_bin > 0):
        raise ContainerFormatError(
            f"bad latent bin {latent_bin!r}", stream="meta"
        )
    if not (
        np.isfinite(norm_min).all()
        and np.isfinite(norm_range).all()
        and (norm_range > 0).all()
    ):
        raise ContainerFormatError(
            "non-finite or non-positive normalization", stream="meta"
        )
    cfg = families.StructuralConfig(
        family=fam.name,
        geometry=blocking.BlockGeometry(bt=bt, ph=ph, pw=pw),
        latent=latent,
        arch=arch,
        use_correction=bool(flags & _FLAG_CORRECTION),
        param_dtype_bytes=pdb,
    )
    return cfg, (s, t, h, w), float(latent_bin), norm_min, norm_range


# ---------------------------------------------------------------------------
# combined guarantee stream (container v2+): CSR-of-CSR over species
# ---------------------------------------------------------------------------
_GDIR_HEAD = struct.Struct("<I")  # species count
# per species: tau f64, coeff_bin f64, D u32, n_store u32,
#              coeff_len u64, index_len u64, basis_len u64
_GDIR_REC = struct.Struct("<ddIIQQQ")


def pack_guarantee_stream(arts) -> bytes:
    """Pack all species' guarantee artifacts into ONE combined stream.

    Layout: ``S u32 | S x directory record | coeff payloads | index
    payloads | basis payloads`` — the outer offset table (directory) over
    species plus type-grouped sub-streams. Per-species framing collapses
    from a nested container (~60 bytes of magic/table per species) to one
    fixed 48-byte record, and every species' byte extents follow from the
    directory by prefix sums, so a reader can slice one species without
    parsing any sibling payload.
    """
    parts = [_GDIR_HEAD.pack(len(arts))]
    coeffs: list[bytes] = []
    indexes: list[bytes] = []
    bases: list[bytes] = []
    for g in arts:
        c, i, b = g.wire_parts()
        parts.append(
            _GDIR_REC.pack(g.tau, g.coeff_bin, *g.basis.shape,
                           len(c), len(i), len(b))
        )
        coeffs.append(c)
        indexes.append(i)
        bases.append(b)
    return b"".join(parts + coeffs + indexes + bases)


class GuaranteeDirectory:
    """Parsed directory of a combined ``guarantee`` stream (container v2+).

    Holds the per-species metadata and byte extents; payload access is
    pure slicing — no sibling species' stream is ever parsed to reach
    another's. Raises :class:`ContainerFormatError` when the directory
    and the payload bytes disagree.
    """

    def __init__(self, payload: bytes):
        payload = bytes(payload)
        if len(payload) < _GDIR_HEAD.size:
            raise ContainerFormatError(
                "guarantee stream truncated: no species directory",
                stream="guarantee", offset=0,
            )
        (s,) = _GDIR_HEAD.unpack_from(payload, 0)
        dir_end = _GDIR_HEAD.size + s * _GDIR_REC.size
        if len(payload) < dir_end:
            raise ContainerFormatError(
                f"guarantee directory truncated: {len(payload)} bytes "
                f"cannot hold {s} species records",
                stream="guarantee", offset=0,
            )
        recs = list(_GDIR_REC.iter_unpack(payload[_GDIR_HEAD.size:dir_end]))
        self._meta = [(r[0], r[1], r[2], r[3]) for r in recs]
        coeff_lens = [r[4] for r in recs]
        index_lens = [r[5] for r in recs]
        basis_lens = [r[6] for r in recs]
        # per-type payload offsets by prefix sum (python ints: a corrupt
        # u64 length must overflow into a clean mismatch, not wrap)
        off = dir_end
        self._extents: list[list[tuple[int, int]]] = []
        for lens in (coeff_lens, index_lens, basis_lens):
            spans = []
            for ln in lens:
                spans.append((off, off + ln))
                off += ln
            self._extents.append(spans)
        if off != len(payload):
            raise ContainerFormatError(
                f"guarantee stream is {len(payload)} bytes but its "
                f"directory declares {off}",
                stream="guarantee", offset=min(off, len(payload)),
            )
        self.dir_bytes = dir_end
        self.coeff_total = sum(coeff_lens)
        self.index_total = sum(index_lens)
        self.basis_total = sum(basis_lens)
        self._payload = payload

    @property
    def n_species(self) -> int:
        return len(self._meta)

    def _slice(self, kind: int, sidx: int) -> bytes:
        lo, hi = self._extents[kind][sidx]
        return self._payload[lo:hi]

    def coeff_stream(self, sidx: int) -> bytes:
        return self._slice(0, sidx)

    def coeff_len(self, sidx: int) -> int:
        lo, hi = self._extents[0][sidx]
        return hi - lo

    def species_parts(self, sidx: int):
        """(tau, coeff_bin, d, n_store, coeff, index, basis) for one species."""
        return (*self._meta[sidx], self._slice(0, sidx),
                self._slice(1, sidx), self._slice(2, sidx))

    def species_extent_bytes(self, sidx: int) -> int:
        """Payload bytes one species' decode touches (coeff+index+basis)."""
        return sum(hi - lo for lo, hi in
                   (self._extents[k][sidx] for k in range(3)))

    def species_spans(self, sidx: int) -> tuple[tuple[int, int], ...]:
        """Payload-relative (lo, hi) byte spans of one species' coeff,
        index, and basis payloads — the unit a v4 species digest covers
        (CRC-chained in this order) and the fault harness addresses."""
        return tuple(self._extents[k][sidx] for k in range(3))


# ---------------------------------------------------------------------------
# time-sharded latent stream (container v3)
# ---------------------------------------------------------------------------
_LAT3_MAGIC = b"LAT3"
_LAT3_HEAD = struct.Struct("<4sIIQI")  # magic, n_shards, shard_rows, n_rows, n_cols
_LAT3_CB = struct.Struct("<I")  # codebook symbol count
_LAT3_LEN = struct.Struct("<Q")  # per-shard payload byte length

#: default shard granularity: one time block-group (``bt`` frames) per
#: shard — the finest window a block-row decode can address anyway; the
#: per-shard cost is one u64 table entry plus sub-byte chain padding.
DEFAULT_SHARD_TGROUPS = 1

_POOL: Optional[ThreadPoolExecutor] = None


def _pool() -> ThreadPoolExecutor:
    """Shared workers for per-shard entropy packing (numpy releases the
    GIL on the vectorized pack passes, so shards genuinely overlap)."""
    global _POOL
    if _POOL is None:
        _POOL = ThreadPoolExecutor(max_workers=min(os.cpu_count() or 1, 8))
    return _POOL


def pack_latent_stream(
    latent_q, shard_rows: int, *, parallel: Optional[bool] = None
) -> bytes:
    """Pack quantized latents as the v3 time-sharded segmented stream.

    One canonical codebook is built over ALL latents and stored once;
    each shard of ``shard_rows`` block rows (ragged tail allowed) packs
    its own independent Huffman chain under it, so any shard decodes
    without touching the others. Shard chains are independent by
    construction, so they encode in parallel on the shared worker pool
    (``parallel=None`` decides by size; the output bytes are identical
    either way — each shard's payload is a pure function of its rows).

    ``latent_q`` is one (NB, latent) array, or — from a sharded fit — a
    *sequence of per-shard row blocks* sharing the column count. The
    parts path never concatenates the full matrix on host: the codebook
    merges per-part symbol counts (:func:`entropy.huffman_codebook_parts`)
    and each Huffman chain assembles only its own shard's rows, so the
    emitted bytes are identical to packing the concatenated array.
    """
    if hasattr(latent_q, "ndim"):  # one (NB, latent) array (np or device)
        latent_q = np.ascontiguousarray(np.asarray(latent_q, dtype=np.int64))
        if latent_q.ndim != 2 or latent_q.size == 0:
            raise ValueError(
                f"latent_q must be a non-empty (NB, latent) array, "
                f"got shape {latent_q.shape}"
            )
        parts = [latent_q]
    else:
        parts = [np.ascontiguousarray(np.asarray(p, dtype=np.int64))
                 for p in latent_q]
        if not parts or any(p.ndim != 2 or p.shape[0] == 0 for p in parts):
            raise ValueError(
                "latent_q parts must be non-empty 2-D row blocks, got "
                f"shapes {[getattr(p, 'shape', None) for p in parts]}"
            )
        if len({p.shape[1] for p in parts}) != 1:
            raise ValueError(
                "latent_q parts disagree on the latent width: "
                f"{sorted({p.shape[1] for p in parts})}"
            )
    bounds = []
    row = 0
    for p in parts:
        bounds.append((row, row + p.shape[0]))
        row += p.shape[0]
    nb, n_cols = row, parts[0].shape[1]
    if nb == 0 or n_cols == 0:
        raise ValueError("latent_q must cover at least one row and column")
    shard_rows = int(min(max(int(shard_rows), 1), nb))
    if len(parts) == 1:
        symbols, lengths = entropy.huffman_codebook(parts[0])
    else:
        symbols, lengths = entropy.huffman_codebook_parts(parts)
    # canonical codes are shard-invariant: build the (python-loop) table
    # once here rather than once per shard inside the workers
    codes = entropy._canonical_codes(lengths)
    extents = [(r0, min(r0 + shard_rows, nb))
               for r0 in range(0, nb, shard_rows)]

    def rows_for(ext):
        r0, r1 = ext
        picked = [
            p[max(r0, p0) - p0:min(r1, p1) - p0]
            for (p0, p1), p in zip(bounds, parts)
            if max(r0, p0) < min(r1, p1)
        ]
        # O(shard) concat only when a chain crosses a part boundary
        return picked[0] if len(picked) == 1 else np.concatenate(picked)

    def pack(ext):
        return entropy.huffman_payload(rows_for(ext), symbols, lengths, codes)

    total_size = nb * n_cols
    if parallel is None:
        parallel = len(extents) > 1 and total_size >= (1 << 15)
    if parallel and len(extents) > 1:
        payloads = list(_pool().map(pack, extents))
    else:
        payloads = [pack(e) for e in extents]
    parts = [
        _LAT3_HEAD.pack(_LAT3_MAGIC, len(extents), shard_rows, nb, n_cols),
        _LAT3_CB.pack(len(symbols)),
        symbols.astype("<i8").tobytes(),
        lengths.astype("<u1").tobytes(),
    ]
    parts.extend(_LAT3_LEN.pack(len(p)) for p in payloads)
    return b"".join(parts + payloads)


class LatentShardDirectory:
    """Parsed head of a v3 ``latent`` stream: codebook + shard extents.

    Parsing touches only the fixed head — no entropy decode happens here;
    shard payload access is pure slicing, and which shards a block-row
    window needs is arithmetic on the directory alone.
    """

    def __init__(self, payload: bytes):
        payload = bytes(payload)
        if len(payload) < _LAT3_HEAD.size + _LAT3_CB.size:
            raise ContainerFormatError(
                "latent shard stream truncated", stream="latent", offset=0
            )
        magic, n_shards, shard_rows, n_rows, n_cols = \
            _LAT3_HEAD.unpack_from(payload, 0)
        if magic != _LAT3_MAGIC:
            raise ContainerFormatError(
                f"bad latent shard magic {magic!r} (expected {_LAT3_MAGIC!r})",
                stream="latent", offset=0,
            )
        if min(n_shards, shard_rows, n_rows, n_cols) < 1:
            raise ContainerFormatError(
                f"degenerate latent shard geometry: {n_shards} shards of "
                f"{shard_rows} rows for ({n_rows}, {n_cols}) latents",
                stream="latent", offset=0,
            )
        if n_shards != -(-n_rows // shard_rows):
            raise ContainerFormatError(
                f"latent shard directory declares {n_shards} shards but "
                f"{n_rows} rows / {shard_rows} per shard needs "
                f"{-(-n_rows // shard_rows)}",
                stream="latent", offset=0,
            )
        off = _LAT3_HEAD.size
        (k,) = _LAT3_CB.unpack_from(payload, off)
        off += _LAT3_CB.size
        table_end = off + 9 * k + _LAT3_LEN.size * n_shards
        if k < 1 or len(payload) < table_end:
            raise ContainerFormatError(
                f"latent shard stream truncated: {len(payload)} bytes "
                f"cannot hold a {k}-symbol codebook + {n_shards} records",
                stream="latent", offset=0,
            )
        self.symbols = np.frombuffer(
            payload, dtype="<i8", count=k, offset=off
        ).astype(np.int64)
        off += 8 * k
        self.lengths = np.frombuffer(
            payload, dtype="<u1", count=k, offset=off
        ).astype(np.int64)
        off += k
        if not ((self.lengths >= 1) & (self.lengths <= 32)).all():
            raise ContainerFormatError(
                "latent codebook carries bad code lengths",
                stream="latent", offset=0,
            )
        lens = [
            _LAT3_LEN.unpack_from(payload, off + i * _LAT3_LEN.size)[0]
            for i in range(n_shards)
        ]
        off += _LAT3_LEN.size * n_shards
        self.header_bytes = off  # framing + codebook + shard table
        self._extents: list[tuple[int, int]] = []
        for ln in lens:  # python ints: corrupt u64 must mismatch, not wrap
            self._extents.append((off, off + ln))
            off += ln
        if off != len(payload):
            raise ContainerFormatError(
                f"latent shard stream is {len(payload)} bytes but its "
                f"directory declares {off}",
                stream="latent", offset=min(off, len(payload)),
            )
        self.n_shards = n_shards
        self.shard_rows = shard_rows
        self.n_rows = n_rows
        self.n_cols = n_cols
        self.payload_total = sum(lens)
        self._payload = payload

    def shard_payload(self, k: int) -> bytes:
        lo, hi = self._extents[k]
        return self._payload[lo:hi]

    def shard_payload_len(self, k: int) -> int:
        lo, hi = self._extents[k]
        return hi - lo

    def shard_extent(self, k: int) -> tuple[int, int]:
        """Payload-relative (lo, hi) byte span of shard ``k``'s chain —
        the unit a v4 shard digest covers and the fault harness addresses."""
        return self._extents[k]

    def shard_row_extent(self, k: int) -> tuple[int, int]:
        r0 = k * self.shard_rows
        return r0, min(r0 + self.shard_rows, self.n_rows)

    def shard_count(self, k: int) -> int:
        r0, r1 = self.shard_row_extent(k)
        return (r1 - r0) * self.n_cols

    def shards_for_rows(self, b0: int, b1: int) -> tuple[int, int]:
        """Half-open shard range covering block rows ``[b0, b1)``."""
        if not 0 <= b0 < b1 <= self.n_rows:
            raise ValueError(
                f"block-row window ({b0}, {b1}) outside [0, {self.n_rows})"
            )
        return b0 // self.shard_rows, -(-b1 // self.shard_rows)

    def window_payload_bytes(self, b0: int, b1: int) -> int:
        """Chain payload bytes a ``[b0, b1)`` row decode entropy-decodes."""
        k0, k1 = self.shards_for_rows(b0, b1)
        return sum(self.shard_payload_len(k) for k in range(k0, k1))


# ---------------------------------------------------------------------------
# integrity stream (container v4): CRC32 digests per stream + per unit
# ---------------------------------------------------------------------------
_ITG_MAGIC = b"ITG1"
_ITG_HEAD = struct.Struct("<4sH")  # magic, n_streams
_ITG_CRC = struct.Struct("<I")
_ITG_UNITS = struct.Struct("<III")  # region_len, region_crc, n_units


def _chained_crc(payload: bytes, spans) -> int:
    """CRC32 chained across (possibly non-contiguous) payload spans."""
    crc = 0
    for lo, hi in spans:
        crc = zlib.crc32(payload[lo:hi], crc)
    return crc


def pack_integrity_stream(streams: "list[tuple[str, bytes]]") -> bytes:
    """Pack the v4 ``integrity`` stream over the sibling ``streams``
    (every (name, payload) pair of the container *except* integrity
    itself, in table order). The ``outer_crc`` field is left zero —
    :func:`finalize_integrity_stream` patches it once the outer header
    is known (the header depends only on this payload's length, which
    the patch preserves)."""
    by_name = dict(streams)
    parts = [_ITG_HEAD.pack(_ITG_MAGIC, len(streams))]
    for name, payload in streams:
        enc = name.encode("ascii")
        parts.append(struct.pack("<B", len(enc)))
        parts.append(enc)
        parts.append(_ITG_CRC.pack(zlib.crc32(payload)))
    lat_payload = by_name["latent"]
    lat = LatentShardDirectory(lat_payload)
    parts.append(_ITG_UNITS.pack(
        lat.header_bytes,
        zlib.crc32(lat_payload[: lat.header_bytes]),
        lat.n_shards,
    ))
    parts.extend(
        _ITG_CRC.pack(zlib.crc32(lat.shard_payload(k)))
        for k in range(lat.n_shards)
    )
    g_payload = by_name["guarantee"]
    gdir = GuaranteeDirectory(g_payload)
    parts.append(_ITG_UNITS.pack(
        gdir.dir_bytes,
        zlib.crc32(g_payload[: gdir.dir_bytes]),
        gdir.n_species,
    ))
    parts.extend(
        _ITG_CRC.pack(_chained_crc(g_payload, gdir.species_spans(sidx)))
        for sidx in range(gdir.n_species)
    )
    parts.append(_ITG_CRC.pack(0))  # outer_crc placeholder
    body = b"".join(parts)
    return body + _ITG_CRC.pack(zlib.crc32(body))


def finalize_integrity_stream(payload: bytes, outer_header: bytes) -> bytes:
    """Patch ``outer_crc`` with the digest of the outer container header
    + stream table, and recompute ``self_crc`` accordingly. Length is
    unchanged, so the header the caller packed stays exact."""
    body = payload[: -2 * _ITG_CRC.size] + _ITG_CRC.pack(
        zlib.crc32(outer_header)
    )
    return body + _ITG_CRC.pack(zlib.crc32(body))


class IntegrityDirectory:
    """Parsed (and self-verified) v4 ``integrity`` stream.

    Construction runs the self-check first — ``self_crc`` over every
    preceding byte — so a flip *inside* the integrity stream is reported
    against the integrity stream itself, never misattributed to a sibling
    payload. All ``verify_*`` methods raise :class:`ContainerFormatError`
    with structured context (stream, offset, unit) on mismatch and are
    no-ops on success.
    """

    def __init__(self, payload: bytes):
        payload = bytes(payload)

        def bad(msg: str, off: int = 0):
            raise ContainerFormatError(msg, stream="integrity", offset=off)

        floor = _ITG_HEAD.size + 2 * _ITG_CRC.size + 2 * _ITG_UNITS.size
        if len(payload) < floor:
            bad(f"integrity stream truncated: {len(payload)} bytes")
        magic, n_streams = _ITG_HEAD.unpack_from(payload, 0)
        if magic != _ITG_MAGIC:
            bad(f"bad integrity magic {magic!r} (expected {_ITG_MAGIC!r})")
        (self_crc,) = _ITG_CRC.unpack_from(payload, len(payload) - _ITG_CRC.size)
        if zlib.crc32(payload[: -_ITG_CRC.size]) != self_crc:
            bad("integrity stream fails its own digest",
                len(payload) - _ITG_CRC.size)
        off = _ITG_HEAD.size
        self.stream_crcs: dict[str, int] = {}
        for _ in range(n_streams):
            if off + 1 > len(payload):
                bad("integrity stream table truncated", off)
            (name_len,) = struct.unpack_from("<B", payload, off)
            off += 1
            if off + name_len + _ITG_CRC.size > len(payload):
                bad("integrity stream table truncated", off)
            name = payload[off : off + name_len].decode("ascii")
            off += name_len
            (crc,) = _ITG_CRC.unpack_from(payload, off)
            off += _ITG_CRC.size
            self.stream_crcs[name] = crc
        if off + 2 * _ITG_UNITS.size + 2 * _ITG_CRC.size > len(payload):
            bad("integrity unit sections truncated", off)
        self.latent_head_len, self.latent_head_crc, n_shards = \
            _ITG_UNITS.unpack_from(payload, off)
        off += _ITG_UNITS.size
        if off + n_shards * _ITG_CRC.size > len(payload):
            bad("integrity shard digests truncated", off)
        self.shard_crcs = [
            _ITG_CRC.unpack_from(payload, off + k * _ITG_CRC.size)[0]
            for k in range(n_shards)
        ]
        off += n_shards * _ITG_CRC.size
        if off + _ITG_UNITS.size > len(payload):
            bad("integrity unit sections truncated", off)
        self.gdir_len, self.gdir_crc, n_species = \
            _ITG_UNITS.unpack_from(payload, off)
        off += _ITG_UNITS.size
        tail = off + n_species * _ITG_CRC.size + 2 * _ITG_CRC.size
        if tail != len(payload):
            bad(f"integrity stream is {len(payload)} bytes but its "
                f"sections declare {tail}", off)
        self.species_crcs = [
            _ITG_CRC.unpack_from(payload, off + s * _ITG_CRC.size)[0]
            for s in range(n_species)
        ]
        off += n_species * _ITG_CRC.size
        (self.outer_crc,) = _ITG_CRC.unpack_from(payload, off)

    def verify_outer(self, blob: bytes, header_bytes: int) -> None:
        """Digest-check the outer container header + stream table."""
        if zlib.crc32(bytes(blob[:header_bytes])) != self.outer_crc:
            raise ContainerFormatError(
                "container header fails its integrity digest", offset=0
            )

    def verify_stream(self, name: str, payload: bytes) -> None:
        """Digest-check one sibling stream's whole payload."""
        want = self.stream_crcs.get(name)
        if want is None:
            raise ContainerFormatError(
                f"integrity stream carries no digest for {name!r}",
                stream="integrity",
            )
        if zlib.crc32(payload) != want:
            raise ContainerFormatError(
                f"stream {name!r} fails its integrity digest",
                stream=name, offset=0,
            )

    def verify_latent_head(self, payload: bytes) -> None:
        """Digest-check the latent stream's head region (framing +
        codebook + shard table) using the *stored* region length, so the
        check never depends on possibly-corrupt framing fields."""
        n = self.latent_head_len
        if n > len(payload) or zlib.crc32(payload[:n]) != self.latent_head_crc:
            raise ContainerFormatError(
                "latent stream head fails its integrity digest",
                stream="latent", offset=0,
            )

    def verify_shard(self, k: int, chain_payload: bytes) -> None:
        """Digest-check one latent shard's chain payload."""
        if not 0 <= k < len(self.shard_crcs):
            raise ContainerFormatError(
                f"integrity stream carries {len(self.shard_crcs)} shard "
                f"digests, shard {k} requested",
                stream="integrity", unit=k,
            )
        if zlib.crc32(chain_payload) != self.shard_crcs[k]:
            raise ContainerFormatError(
                f"latent shard {k}: fails its integrity digest",
                stream="latent", unit=k,
            )

    def verify_gdir(self, payload: bytes) -> None:
        """Digest-check the guarantee stream's directory region using the
        stored region length."""
        n = self.gdir_len
        if n > len(payload) or zlib.crc32(payload[:n]) != self.gdir_crc:
            raise ContainerFormatError(
                "guarantee directory fails its integrity digest",
                stream="guarantee", offset=0,
            )

    def verify_species(self, sidx: int, payload: bytes, spans) -> None:
        """Digest-check one species' guarantee byte extent (its coeff,
        index, and basis spans of the combined stream, CRC-chained)."""
        if not 0 <= sidx < len(self.species_crcs):
            raise ContainerFormatError(
                f"integrity stream carries {len(self.species_crcs)} species "
                f"digests, species {sidx} requested",
                stream="integrity", unit=sidx,
            )
        if _chained_crc(payload, spans) != self.species_crcs[sidx]:
            raise ContainerFormatError(
                f"guarantee stream {sidx}: fails its integrity digest",
                stream="guarantee", unit=sidx,
                offset=spans[0][0] if spans else None,
            )


# ---------------------------------------------------------------------------
# measured byte accounting
# ---------------------------------------------------------------------------
def stream_breakdown(blob: bytes) -> dict:
    """Byte breakdown as a view over the container's measured stream lengths.

    ``latent/decoder/correction/coeff/index/basis`` are payload bytes;
    ``meta`` is everything else that is really on the wire — the outer
    header + stream table, the meta stream, and per-version framing (v1
    nested guarantee containers, the v2+ guarantee directory, the v3
    latent shard head: codebook + shard table, the v4 integrity stream)
    — so the parts always sum to ``len(blob)`` exactly.
    """
    r = ContainerReader(blob)
    sizes = r.stream_sizes()
    coeff = index = basis = 0
    if r.version >= container_format.FORMAT_VERSION_SELECTIVE:
        if "guarantee" in r:
            gdir = GuaranteeDirectory(r["guarantee"])
            coeff, index, basis = (
                gdir.coeff_total, gdir.index_total, gdir.basis_total
            )
    else:
        for name in sizes:
            if name.startswith("guarantee"):
                sub = ContainerReader(r[name]).stream_sizes()
                coeff += sub.get("coeff", 0)
                index += sub.get("index", 0)
                basis += sub.get("basis", 0)
    latent = sizes.get("latent", 0)
    if r.version >= container_format.FORMAT_VERSION_SHARDED and "latent" in r:
        # chain payloads count as latent data; the shard head (codebook +
        # extents table) is framing and lands in the meta bucket below
        latent = LatentShardDirectory(r["latent"]).payload_total
    out = {
        "latent": latent,
        "decoder": sizes.get("decoder", 0),
        "correction": sizes.get("correction", 0),
        "coeff": coeff,
        "index": index,
        "basis": basis,
    }
    out["meta"] = r.total_bytes - sum(out.values())
    out["total"] = r.total_bytes
    return out
