"""Selective decode: random access by species / time window.

:class:`PartialDecoder` serves (species, window) slices of one container
blob, parsing only the header plus the requested streams. On a v3
container both the guarantee streams *and* the latent stream are
random-access — a time window entropy-decodes only the latent shards
covering it — so a window query is O(window) end to end. Every slice is
bitwise equal to slicing the full decode.

The slice pipeline is exposed in stages — :func:`plan_slice` (normalize
a request to its block-row window), :func:`replay_slice` (guarantee
decode + correction replay over selected species), and
:func:`finalize_slice` (blocks -> field, denormalize, window trim) — so
the decode service (:mod:`repro.serve.decode_service`) can run the
middle stages once over a *union* of coalesced requests and finalize
each request from its slice of the shared result, bit-identically to
the serial path below.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.codec.runtime import (
    _cached_head,
    _decode_species_guarantees,
    _evict_head,
    _fused_vecs,
    _gdir,
    _latents32,
)
from repro.core import blocking, entropy, gae
from repro.core import container as container_format
from repro.core.container import ContainerFormatError, ContainerReader


def _normalize_species(species, s: int) -> tuple[list, bool]:
    """Selection -> (index list, squeeze-species-axis?)."""
    if species is None:
        return list(range(s)), False
    if isinstance(species, (int, np.integer)):
        species, squeeze = [int(species)], True
    else:
        species, squeeze = [int(x) for x in species], False
    if not species:
        raise ValueError("empty species selection")
    idx = []
    for x in species:
        if not -s <= x < s:
            raise ValueError(
                f"species index {x} out of range for {s} species"
            )
        idx.append(x % s)
    if len(set(idx)) != len(idx):
        raise ValueError(f"duplicate species in selection {species}")
    return idx, squeeze


def _normalize_time_range(time_range, t: int) -> tuple[int, int]:
    if time_range is None:
        return 0, t
    t0, t1 = (int(time_range[0]), int(time_range[1]))
    if not 0 <= t0 < t1 <= t:
        raise ValueError(
            f"time_range {time_range!r} is not a half-open window "
            f"inside [0, {t})"
        )
    return t0, t1


def _window_rows(head, t0: int, t1: int) -> tuple[int, int, int, int]:
    """Frame window -> (tg0, tg1, b0, b1): covering time block-groups and
    their contiguous block-row range (the block index is time-major)."""
    geom = head.cfg.geometry
    _, _, h, w = head.shape
    per_frame = (h // geom.ph) * (w // geom.pw)
    tg0, tg1 = t0 // geom.bt, -(-t1 // geom.bt)
    return tg0, tg1, tg0 * per_frame, tg1 * per_frame


# an empty coefficient stream is exactly the self-describing Huffman
# header; any stream with >= 1 symbol is strictly longer (header grows by
# 9 bytes per codebook symbol before any payload bit)
_EMPTY_HUFFMAN_LEN = len(entropy.huffman_encode(np.zeros(0, np.int64)))


def _any_corrections(head) -> bool:
    """Does ANY species of the artifact carry stored corrections?

    The full decode runs the correction-replay kernel over all species
    whenever any one of them has corrections — so the selective path must
    gate its replay on the same artifact-wide bit (not just the selected
    species') to stay byte-identical to slicing the full decode. Decided
    at the wire level without entropy-decoding anything: a species is
    empty iff its coefficient stream is the bare Huffman header. Memoized
    on the head (under its lock — concurrent decode threads share cached
    heads) — the v1 recompute would copy every species' payload per
    query.
    """
    with head.lock:
        if head.any_corrections is not None:
            return head.any_corrections
        if head.version >= container_format.FORMAT_VERSION_SELECTIVE:
            gdir = _gdir(head)
            result = any(
                gdir.coeff_len(sidx) > _EMPTY_HUFFMAN_LEN
                for sidx in range(gdir.n_species)
            )
        else:
            result = False
            for sidx in range(head.shape[0]):
                try:
                    sizes = ContainerReader(
                        head.reader[f"guarantee{sidx}"]
                    ).stream_sizes()
                except ContainerFormatError:
                    # corrupt sibling: the full decode raises on this
                    # blob, so there is no full-decode output to match —
                    # skip it here and let the selected species' own
                    # parse decide
                    continue
                if sizes.get("coeff", 0) > _EMPTY_HUFFMAN_LEN:
                    result = True
                    break
        head.any_corrections = result
        return result


@dataclasses.dataclass(frozen=True)
class SlicePlan:
    """A normalized (species, window) request, resolved to block rows.

    Pure function of (head geometry, request) — no decode work happens at
    planning, so the service can plan every queued request, group plans
    that share latent rows, and batch the expensive stages over unions.
    """

    idx: "tuple[int, ...]"   # normalized species indices (unique)
    squeeze: bool            # single-int selection: squeeze species axis
    t0: int                  # frame window [t0, t1)
    t1: int
    tg0: int                 # covering time block-groups [tg0, tg1)
    tg1: int
    b0: int                  # covering block rows [b0, b1) (time-major)
    b1: int

    @property
    def key(self) -> tuple:
        """Result identity: requests with equal keys (against one head)
        decode to identical outputs and can share one computation."""
        return (self.idx, self.squeeze, self.t0, self.t1)


def plan_slice(head, species, time_range) -> SlicePlan:
    """Normalize a selective-decode request against ``head``.

    Raises ``ValueError`` for malformed selections (out-of-range species,
    duplicate species, inverted windows) — before any decode work."""
    s, t = head.shape[0], head.shape[1]
    idx, squeeze = _normalize_species(species, s)
    t0, t1 = _normalize_time_range(time_range, t)
    tg0, tg1, b0, b1 = _window_rows(head, t0, t1)
    return SlicePlan(idx=tuple(idx), squeeze=squeeze, t0=t0, t1=t1,
                     tg0=tg0, tg1=tg1, b0=b0, b1=b1)


def replay_slice(head, idx, block_range, vecs_sel):
    """Guarantee decode + correction replay for species ``idx`` over
    block rows ``block_range`` of ``vecs_sel`` (device array, species
    axis already selected down to ``idx``'s order).

    The replay is gated on the artifact-wide corrections bit, not the
    selection's: the full decode replays (x + C@U^T, C possibly
    all-zero) over every species whenever any species has corrections,
    and a selective output must be byte-identical to its slice.
    Species-batch independence makes each species' result independent of
    which others ride in the batch — this is what lets the service
    replay a coalesced species *union* once and hand each request its
    positions of the result.
    """
    idx = list(idx)
    b0, b1 = block_range
    # entropy-decodes on host while any dispatched device work runs
    arts = _decode_species_guarantees(head, idx)
    if not _any_corrections(head):
        return vecs_sel
    import jax.numpy as jnp

    geom = head.cfg.geometry
    engine = gae.default_engine()
    dense, basis = engine.dense_corrections(
        arts, (len(idx), b1 - b0, geom.block_size), block_range=(b0, b1)
    )
    return engine.apply_device(
        vecs_sel, jnp.asarray(dense), jnp.asarray(basis)
    )


def finalize_slice(head, plan: SlicePlan, vecs_sel) -> np.ndarray:
    """Corrected block vectors -> the request's field slice: reassemble
    blocks over the plan's window, denormalize with the selected species'
    ranges, trim block-group padding to the exact frame window."""
    geom = head.cfg.geometry
    _, _, h, w = head.shape
    sel = np.asarray(plan.idx)
    rec_blocks = blocking.vectors_as_blocks(np.asarray(vecs_sel), geom)
    sub_shape = (len(plan.idx), (plan.tg1 - plan.tg0) * geom.bt, h, w)
    rec_normed = blocking.from_blocks(rec_blocks, sub_shape, geom)
    out = (
        rec_normed * head.norm_range[sel][:, None, None, None]
        + head.norm_min[sel][:, None, None, None]
    ).astype(np.float32)
    out = out[:, plan.t0 - plan.tg0 * geom.bt
              : plan.t1 - plan.tg0 * geom.bt]
    return out[0] if plan.squeeze else out


class PartialDecoder:
    """Random-access decoder over one GBATC container blob.

    Parses the container head exactly once — served from the shared
    content-keyed head cache, so even constructing a fresh decoder on a
    recently seen blob is cheap — then serves species/time-window slices
    on demand:

    * only the **requested species'** guarantee streams are parsed and
      entropy-decoded (lockstep-batched when several are requested at
      once, memoized across ``decode`` calls);
    * the fused NN decode runs on only the **block rows covering the
      requested time window** (species cannot shrink this stage — the AE
      decodes the species stack jointly per block);
    * on a **v3 (time-sharded) container** only the latent shards
      covering the window entropy-decode (decoded shards memoize), so the
      latent cost is O(window) rather than O(T); v1/v2 carry one
      sequential chain and decode it whole, once;
    * only the requested species' corrections replay through the batched
      Pallas kernel, scattered from the CSR extents of the window alone.

    Every slice is bitwise equal to slicing the corresponding full
    decode. Works on v1/v2/v3/v4 containers; on v4 each latent shard and
    species guarantee extent digest-checks (CRC32) immediately before its
    first decode, so a slice verifies exactly the bytes it reads. A
    corrupt species or latent shard stream raises
    :class:`ContainerFormatError` naming it (structured: stream/unit),
    and does not poison siblings requested in later calls.
    """

    def __init__(self, blob: bytes):
        self._head = _cached_head(blob)

    @property
    def shape(self) -> tuple[int, int, int, int]:
        """(S, T, H, W) of the encoded field."""
        return self._head.shape

    @property
    def n_species(self) -> int:
        return self._head.shape[0]

    @property
    def version(self) -> int:
        return self._head.version

    def bytes_parsed(self, species=None, time_range=None) -> int:
        """Container bytes a ``decode(species=..., time_range=...)`` call
        touches.

        Counts the outer header/table, the selection-independent head
        streams (meta, decoder, correction, and on v4 the integrity
        stream — parsed whole at head decode), the latent extent the
        window walks (v3+: shard head + covering shard chains; v1/v2:
        the whole sequential chain regardless of the window), the
        guarantee directory, and the selected species' coeff/index/basis
        extents. With no selection this equals ``len(blob)`` on a v2+
        container — every byte is then accounted to a purpose.
        """
        head = self._head
        idx, _ = _normalize_species(species, head.shape[0])
        t0, t1 = _normalize_time_range(time_range, head.shape[1])
        _, _, b0, b1 = _window_rows(head, t0, t1)
        sizes = head.reader.stream_sizes()
        n = (
            head.reader.header_bytes
            + sizes["meta"]
            + head.latents.bytes_parsed(b0, b1)
            + sizes["decoder"]
            + sizes.get("correction", 0)
            + sizes.get("integrity", 0)
        )
        if head.version >= container_format.FORMAT_VERSION_SELECTIVE:
            gdir = _gdir(head)
            n += gdir.dir_bytes
            n += sum(gdir.species_extent_bytes(s) for s in idx)
        else:
            n += sum(sizes[f"guarantee{s}"] for s in idx)
        return n

    def latent_bytes_parsed(self, time_range=None) -> int:
        """Latent chain bytes a window decode entropy-decodes — the term
        container v3 makes O(window): only the shards covering the window
        walk, where v1/v2's single sequential chain always walks whole."""
        head = self._head
        t0, t1 = _normalize_time_range(time_range, head.shape[1])
        _, _, b0, b1 = _window_rows(head, t0, t1)
        return head.latents.entropy_bytes(b0, b1)

    def decode(self, species=None, time_range=None,
               on_error: str = "raise"):
        """Decode a (species, time-window) slice of the stored field.

        Returns ``(len(species), t1 - t0, H, W)`` float32 (the species
        axis squeezed when ``species`` is a single integer), bitwise equal
        to the same slice of the full decode.

        On a v4 container the slice verifies exactly what it reads — the
        covering latent shards and the selected species' guarantee
        extents digest-check before decode, unread units pay nothing.
        ``on_error="salvage"`` quarantines corrupt units instead of
        raising and returns ``(field, DecodeReport)`` (see
        :func:`repro.codec.integrity.salvage_decompress`); a raise-mode
        failure evicts this blob's shared cached head (healthy units
        already decoded through *this* decoder instance remain usable).
        """
        if on_error not in ("raise", "salvage"):
            raise ValueError(
                f"on_error must be 'raise' or 'salvage', got {on_error!r}"
            )
        if on_error == "salvage":
            from repro.codec.integrity import salvage_decompress

            return salvage_decompress(
                self._head.blob, species=species, time_range=time_range
            )
        try:
            return self._decode(species, time_range)
        except ContainerFormatError:
            _evict_head(self._head.blob)
            raise

    def _decode(self, species, time_range) -> np.ndarray:
        head = self._head
        plan = plan_slice(head, species, time_range)

        # fused NN decode over the window's block rows only (async
        # dispatch; rows are independent, so the slice is bit-transparent).
        # v3: only the latent shards covering [b0, b1) entropy-decode.
        lat32 = _latents32(
            head.latents.rows(plan.b0, plan.b1), head.latent_bin
        )
        vecs_dev = _fused_vecs(
            head.runtime, head.ae_params, head.corr_params, lat32
        )

        import jax.numpy as jnp

        # selection queues on device; the replay stage's guarantee
        # entropy decode then runs on host while the device computes
        vecs_sel = jnp.asarray(vecs_dev)[np.asarray(plan.idx)]
        vecs_sel = replay_slice(
            head, plan.idx, (plan.b0, plan.b1), vecs_sel
        )
        return finalize_slice(head, plan, vecs_sel)
