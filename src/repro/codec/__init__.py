"""Public codec API: GBATC as *bytes in, bytes out* (the paper's claim, made
literal).

The paper reports two-orders-of-magnitude reduction; this package is where
the repo actually produces those bytes. :class:`GBATCCodec` wraps the
fit/compress orchestration and returns a **self-describing container blob**;
module-level :func:`decompress` reconstructs the field from the blob alone —
no fitted pipeline, no original data, no config object. A fresh process can
decode a container because everything the decoder needs travels in it:

==============  ====================================================
stream          payload
==============  ====================================================
``meta``        geometry, encoder structure, shape, latent bin,
                per-species normalization (min/range) — fixed-layout
                struct. On v5 (default) a one-byte **encoder-family
                tag** prefixes it (see :mod:`repro.codec.families`:
                conv=1, attention=2), selecting which family's decoder
                the arch words configure; below v5 the family is
                implicitly conv
``latent``      (v3+) time-sharded segmented stream: ONE shared
                Huffman codebook + a byte-extent directory over fixed
                block-row shards, each an independently decodable chain
                — a time window entropy-decodes only its covering
                shards. (v1/v2, still read/written) one sequential
                Huffman chain over all latents.
``decoder``     AE decoder parameters, packed fp32/fp16 little-endian
                in deterministic (sorted-path) leaf order
``correction``  tensor-correction network parameters (GBATC only)
``guarantee``   (v2+) ONE combined CSR-of-CSR stream for all species:
                a fixed-layout directory (per species: tau, coeff bin,
                basis dims, byte lengths of its coeff/index/basis
                payloads) followed by the type-grouped payloads.
``guarantee<s>``  (v1, still read) per-species
                :class:`~repro.core.gae.GuaranteeArtifact` as a nested
                container.
``integrity``   (v4, default) CRC32 digests over everything else: the
                outer header, every sibling stream whole, and every
                random-access unit (each latent shard's chain, each
                species' guarantee byte-extent) — self-checked first,
                so a corrupt digest table indicts itself, never the
                data. Verification is lazy and memoized with decode:
                a window query digest-checks exactly what it reads.
==============  ====================================================

Selective decode: ``decompress(blob, species=..., time_range=...)`` (or a
reusable :class:`PartialDecoder`) parses only the header plus the
requested streams; on a v3+ container a time-window query is **O(window)
end to end** — latent shards, guarantee streams, and the fused NN decode
all touch only the window. Every slice is bitwise equal to slicing the
full decode; v1–v4 blobs decode through the same entry points unchanged
(implicitly conv-family), and a conv-family v5 decode equals the v4
decode of the same fit byte for byte.

Robustness: decoding raises a structured
:class:`~repro.core.container.ContainerFormatError` (``.stream`` /
``.unit`` / ``.offset``) on provable corruption, and
``decompress(blob, on_error="salvage")`` instead quarantines the corrupt
units, decodes everything that still verifies (bitwise equal to the
clean decode), NaN-fills the rest, and returns ``(field,
DecodeReport)``. :func:`write`/:func:`read` are the atomic
(tmp+fsync+rename) file pair; :func:`verify_blob` digest-checks a v4
blob end to end without decoding it.

The package layers the codec by responsibility:

* :mod:`repro.codec.families` — the encoder-family registry: per-family
  wire tag, arch words, model construction, decode-side param defs, and
  the fused-decode builder (conv + block attention);
* :mod:`repro.codec.format` — wire schemas: meta struct (family tag on
  v5), guarantee directory, v3 latent shard directory, measured
  ``stream_breakdown``;
* :mod:`repro.codec.params` — parameter-tree leaf packing;
* :mod:`repro.codec.artifact` — :class:`CompressedArtifact`, the fitted
  in-memory compression with its memoized wire streams;
* :mod:`repro.codec.encode` — the fit-side planner (artifact -> streams,
  parallel shard packing);
* :mod:`repro.codec.cache` — the multi-tier byte-budgeted LRU engine
  (head / latent-shard / guarantee tiers, admission, stats);
* :mod:`repro.codec.runtime` — cached decode runtimes (models, jitted
  fused decode, Huffman tables), container-head parsing with the
  content-keyed head cache, lazy per-shard latent stores;
* :mod:`repro.codec.decode` — full-field decode entry points, fused hot
  path and the retained bit-identity reference orchestration;
* :mod:`repro.codec.partial` — :class:`PartialDecoder` and slicing;
* :mod:`repro.codec.integrity` — blob verification and the salvage
  decode path (:func:`salvage_decompress`, :class:`DecodeReport`).

Byte accounting is a *view over the container's stream table*
(:func:`stream_breakdown`), so ``breakdown["total"] == len(blob)`` holds
exactly. Decoding state is cached in a multi-tier, byte-budgeted decode
cache (:mod:`repro.codec.cache`): parsed heads, decoded latent shards,
and guarantee artifacts each live in their own LRU tier, so repeated
``decompress`` calls never re-trace and repeated queries on one blob
never re-parse. :func:`cache_stats` surfaces per-tier hit/miss/eviction
counters (plus the Huffman decode-table memos),
:func:`configure_decode_cache` re-budgets the tiers, and
:func:`clear_decode_cache` drops every tier. The decode service
(:mod:`repro.serve.decode_service`) serves concurrent selective-decode
requests on top of this cache, coalescing compatible requests into
batched dispatches.

``GBATCPipeline.compress/decompress`` remain as thin compatibility wrappers
over this package (see :mod:`repro.core.pipeline`).
"""

from repro.codec import families
from repro.codec.artifact import CompressedArtifact
from repro.codec.decode import (
    decode_artifact,
    decode_artifact_reference,
    decompress,
    decompress_reference,
    reconstruct,
    reconstruct_reference,
)
from repro.codec.encode import encode, read, write
from repro.codec.format import (
    _GDIR_HEAD,
    _GDIR_REC,
    DEFAULT_SHARD_TGROUPS,
    GuaranteeDirectory,
    LatentShardDirectory,
    pack_guarantee_stream,
    pack_latent_stream,
    stream_breakdown,
)
from repro.codec.params import (
    pack_artifact_params,
    pack_params,
    unpack_params,
)
from repro.codec.integrity import (
    DecodeReport,
    IntegrityFailure,
    SpeciesReport,
    salvage_decompress,
    verify_blob,
)
from repro.codec.partial import PartialDecoder
from repro.codec.runtime import (
    _fused_vecs,
    _runtime,
    _runtime_reference,
    cache_stats,
    clear_decode_cache,
    configure_decode_cache,
    make_fused_decode,
)
from repro.core.container import ContainerFormatError


def __getattr__(name: str):
    # GBATCCodec owns a fit, so it lives with the orchestration layer in
    # repro.core.pipeline; resolved lazily (PEP 562) so nothing under
    # codec/ imports the pipeline at module scope (decode-purity
    # invariant — repro.analysis enforces it statically).
    if name == "GBATCCodec":
        import importlib

        return importlib.import_module("repro.core.pipeline").GBATCCodec
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "GBATCCodec",
    "CompressedArtifact",
    "families",
    "ContainerFormatError",
    "DecodeReport",
    "GuaranteeDirectory",
    "IntegrityFailure",
    "LatentShardDirectory",
    "PartialDecoder",
    "SpeciesReport",
    "DEFAULT_SHARD_TGROUPS",
    "cache_stats",
    "clear_decode_cache",
    "configure_decode_cache",
    "encode",
    "read",
    "salvage_decompress",
    "verify_blob",
    "write",
    "pack_guarantee_stream",
    "pack_latent_stream",
    "pack_params",
    "unpack_params",
    "pack_artifact_params",
    "decode_artifact",
    "decode_artifact_reference",
    "decompress",
    "decompress_reference",
    "reconstruct",
    "reconstruct_reference",
    "make_fused_decode",
    "stream_breakdown",
]
