"""Cached decode runtimes + container-head parsing for :mod:`repro.codec`.

Two caches make repeated decoding cheap without any codec instance state:

* **runtime cache** — model instances, jitted callables (including the
  fused decode program), and Huffman decode tables, keyed by structural
  signature; a fresh ``decompress`` call on a structurally familiar blob
  never re-traces.
* **head cache** — fully parsed container heads (meta, latent store,
  network parameters, guarantee directory/artifact memos), keyed by blob
  content with a bounded LRU: repeated window queries against the same
  blob skip the parse, the parameter unpack, and every already-decoded
  latent shard / guarantee stream. Distinct blobs can never alias — the
  key compares by content, not object id.

The latent stream is abstracted as a *store*: container v1/v2 carry one
sequential Huffman chain (decoded whole, as any row needs the full walk),
v3 carries independent per-shard chains under a shared codebook, decoded
lazily and only for the block rows a query touches.
"""

from __future__ import annotations

import dataclasses
import struct
from collections import OrderedDict
from typing import Any, Optional

import numpy as np

from repro.codec import format as wire
from repro.codec.latents import _ChainLatents, _ShardedLatents
from repro.codec.params import _decoder_defs, unpack_params
from repro.core import autoencoder as ae
from repro.core import correction, entropy, gae
from repro.core import container as container_format
from repro.core.container import ContainerFormatError, ContainerReader
from repro.core.pipeline import PipelineConfig
from repro.core.quantization import dequantize


# ---------------------------------------------------------------------------
# decode runtime (cached per structural signature; never re-traces)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _DecodeRuntime:
    model: ae.BlockAutoencoder
    corr_net: Optional[correction.TensorCorrectionNetwork]
    jit_decode: Any
    jit_corr: Any
    # fused device-resident hot path: dequantized latents -> AE decode ->
    # pointwise correction -> (S, NB, D) block vectors, one dispatch
    jit_fused: Any
    # per-runtime Huffman decode-table memo (codebooks repeat across calls)
    table_cache: entropy.DecodeTableCache


_RUNTIMES: dict[tuple, _DecodeRuntime] = {}
_RUNTIMES_REF: dict[tuple, _DecodeRuntime] = {}
_RUNTIMES_MAX = 8


def _runtime_key(cfg: PipelineConfig, n_species: int, has_corr: bool) -> tuple:
    geom = cfg.geometry
    return (
        n_species,
        (geom.bt, geom.ph, geom.pw),
        cfg.latent,
        tuple(cfg.conv_channels),
        has_corr,
    )


def make_fused_decode(model: ae.BlockAutoencoder,
                      corr_net: Optional[correction.TensorCorrectionNetwork]):
    """Traceable latents -> corrected (S, NB, D) block vectors.

    The whole NN decode — AE decoder, pointwise tensor correction, and the
    blocks->vectors layout change — as one function of device arrays, so a
    single jit dispatch replaces chunked host round-trips. All reshuffles
    are pure transposes; per-element arithmetic is identical to the staged
    path (bit-identity asserted in tests and the benchmark).
    """
    s = model.cfg.n_species

    def fused(dec_params, corr_params, lat):
        x = model.decode(dec_params, lat)  # (NB, S, bt, ph, pw)
        nb = x.shape[0]
        if corr_net is not None:
            vec = x.reshape(nb, s, -1).transpose(0, 2, 1).reshape(-1, s)
            vec = corr_net(corr_params, vec)
            x = vec.reshape(nb, -1, s).transpose(0, 2, 1).reshape(x.shape)
        return x.reshape(nb, s, -1).transpose(1, 0, 2)  # (S, NB, D)

    return fused


def _build_runtime(cfg: PipelineConfig, n_species: int, has_corr: bool,
                   conv_impl: str) -> _DecodeRuntime:
    import jax

    geom = cfg.geometry
    model = ae.BlockAutoencoder(
        ae.AEConfig(
            n_species=n_species,
            block=(geom.bt, geom.ph, geom.pw),
            latent=cfg.latent,
            conv_channels=cfg.conv_channels,
            conv_impl=conv_impl,
        )
    )
    corr_net = (
        correction.TensorCorrectionNetwork(
            correction.CorrectionConfig(n_species=n_species)
        )
        if has_corr
        else None
    )
    return _DecodeRuntime(
        model=model,
        corr_net=corr_net,
        jit_decode=jax.jit(model.decode),
        jit_corr=jax.jit(corr_net.__call__) if corr_net is not None else None,
        jit_fused=jax.jit(make_fused_decode(model, corr_net)),
        table_cache=entropy.DecodeTableCache(),
    )


def _cached_runtime(cache: dict, cfg: PipelineConfig, n_species: int,
                    has_corr: bool, conv_impl: str) -> _DecodeRuntime:
    key = _runtime_key(cfg, n_species, has_corr)
    hit = cache.get(key)
    if hit is not None:
        return hit
    rt = _build_runtime(cfg, n_species, has_corr, conv_impl)
    while len(cache) >= _RUNTIMES_MAX:
        cache.pop(next(iter(cache)))
    cache[key] = rt
    return rt


def _runtime(cfg: PipelineConfig, n_species: int,
             has_corr: bool) -> _DecodeRuntime:
    return _cached_runtime(_RUNTIMES, cfg, n_species, has_corr, "2d")


def _runtime_reference(cfg: PipelineConfig, n_species: int,
                       has_corr: bool) -> _DecodeRuntime:
    """Runtime for the retained pre-change decode path: XLA conv impl,
    staged host-chunked orchestration (see ``reconstruct_reference``)."""
    return _cached_runtime(_RUNTIMES_REF, cfg, n_species, has_corr, "xla")


# ---------------------------------------------------------------------------
# container-head parsing
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _DecodedHead:
    """Everything the NN decode needs, parsed before guarantee streams."""

    reader: ContainerReader
    blob: bytes
    cfg: PipelineConfig
    shape: tuple[int, int, int, int]
    nb: int
    latent_bin: float
    norm_min: np.ndarray
    norm_range: np.ndarray
    latents: Any  # _ChainLatents | _ShardedLatents
    latent_stream: Optional[bytes]  # v1/v2 single chain (None for v3)
    ae_params: Any
    corr_params: Any
    runtime: _DecodeRuntime
    version: int = container_format.FORMAT_VERSION
    # parsed + self-verified v4 integrity digests (None below v4): head
    # regions were digest-checked during the head parse; lazily read units
    # (latent shards, species guarantee extents, the guarantee directory)
    # digest-check on first access through this handle
    integrity: Optional[wire.IntegrityDirectory] = None
    # lazily parsed combined guarantee directory (see _gdir)
    gdir: Optional[wire.GuaranteeDirectory] = None
    # memoized artifact-wide "any species has corrections" bit (a pure
    # function of the blob; see partial._any_corrections)
    any_corrections: Optional[bool] = None
    # per-species guarantee artifacts already decoded from this blob
    arts_memo: dict = dataclasses.field(default_factory=dict)


def _decode_head(blob: bytes, *, huffman=None,
                 check_integrity: bool = True) -> _DecodedHead:
    """Parse/validate the container head: meta, stream set, latents,
    network parameters — everything except the guarantee streams, so the
    fused NN decode can be dispatched while those entropy-decode.
    ``huffman`` overrides the latent decoder (reference path).

    On a v4 container the integrity stream is parsed (and self-verified)
    first, then every region this parse consumes is digest-checked
    *before* its bytes are interpreted: the outer header/table, the meta
    stream, the latent stream's head region, and the decoder/correction
    parameter streams. Lazily read units (latent shards, guarantee
    directory and species extents) digest-check on first access.
    ``check_integrity=False`` skips all digest work (salvage uses it to
    decode structurally when the integrity stream itself is corrupt);
    v1–v3 containers carry no digests and parse exactly as before."""
    r = ContainerReader(blob)
    integ = None
    if (check_integrity
            and r.version >= container_format.FORMAT_VERSION_INTEGRITY):
        integ = wire.IntegrityDirectory(r["integrity"])
        integ.verify_outer(r._blob, r.header_bytes)
        integ.verify_stream("meta", r["meta"])
    cfg, shape, latent_bin, norm_min, norm_range = wire._unpack_meta(r["meta"])
    if cfg.use_correction != ("correction" in r):
        # a flipped correction flag must not silently decode without the
        # shipped network (or with a phantom one)
        raise ContainerFormatError(
            f"meta correction flag is {cfg.use_correction} but the "
            f"container {'carries' if 'correction' in r else 'lacks'} a "
            f"correction stream",
            stream="meta",
        )
    s, t, h, w = shape
    geom = cfg.geometry
    if t % geom.bt or h % geom.ph or w % geom.pw:
        raise ContainerFormatError(
            f"shape {shape} not divisible by block geometry "
            f"({geom.bt}, {geom.ph}, {geom.pw})",
            stream="meta",
        )
    nb = (t // geom.bt) * (h // geom.ph) * (w // geom.pw)

    expected_streams = wire.expected_stream_set(
        r.version, s, cfg.use_correction
    )
    if set(r.names) != expected_streams:
        # strictness: every stream must be accounted for by purpose — no
        # stray payloads hiding in the blob, no silently absent streams.
        # Name the first offending stream so the error locates itself.
        odd = sorted(set(r.names) ^ expected_streams)[0]
        raise ContainerFormatError(
            f"unexpected stream set {sorted(r.names)} "
            f"(expected {sorted(expected_streams)})",
            stream=odd,
        )

    # the runtime cache is the single construction site for the decode
    # models — decode_artifact and reconstruct cannot drift apart
    rt = _runtime(cfg, s, cfg.use_correction)
    latent_stream: Optional[bytes] = r["latent"]
    if r.version >= container_format.FORMAT_VERSION_SHARDED:
        if integ is not None:
            # the head region digest-checks against its *stored* length
            # before any framing field is interpreted
            integ.verify_latent_head(latent_stream)
        latents = _ShardedLatents(
            wire.LatentShardDirectory(latent_stream), nb, cfg.latent,
            rt.table_cache, reference=huffman is not None, integrity=integ,
        )
        latent_stream = None  # not the single-chain wire form
    else:
        latents = _ChainLatents(
            latent_stream, nb, cfg.latent, rt.table_cache, huffman=huffman
        )

    def _params(name: str, defs):
        if integ is not None:
            integ.verify_stream(name, r[name])
        try:
            return unpack_params(r[name], defs, cfg.param_dtype_bytes)
        except ContainerFormatError as e:
            raise ContainerFormatError(
                f"{name} stream: {e}", stream=name, offset=e.offset
            ) from e

    ae_params = _params("decoder", _decoder_defs(rt.model))
    corr_params = None
    if cfg.use_correction:
        corr_params = _params("correction", rt.corr_net.defs)
    return _DecodedHead(
        reader=r, blob=bytes(blob), cfg=cfg, shape=shape, nb=nb,
        latent_bin=latent_bin, norm_min=norm_min, norm_range=norm_range,
        latents=latents, latent_stream=latent_stream,
        ae_params=ae_params, corr_params=corr_params, runtime=rt,
        version=r.version, integrity=integ,
    )


_HEADS: "OrderedDict[bytes, _DecodedHead]" = OrderedDict()
_HEADS_MAX = 4


def _cached_head(blob: bytes) -> _DecodedHead:
    """Content-keyed LRU over parsed heads (bounded at ``_HEADS_MAX``).

    Repeated ``decompress``/window queries on the same blob skip the head
    parse, the parameter unpack, and every latent shard or guarantee
    stream already entropy-decoded through this head. The key is the blob
    *bytes* themselves — content equality, so byte-different blobs can
    never share an entry — and CPython caches a bytes object's hash, so a
    caller re-presenting the same object pays O(1) per query rather than
    re-hashing the container (the entry pins the blob anyway).
    """
    key = bytes(blob)
    hit = _HEADS.get(key)
    if hit is not None:
        _HEADS.move_to_end(key)
        return hit
    head = _decode_head(key)
    while len(_HEADS) >= _HEADS_MAX:
        _HEADS.popitem(last=False)
    _HEADS[key] = head
    return head


def clear_decode_cache() -> None:
    """Drop memoized parsed heads (benchmarks use this to time cold
    decodes; also frees the latents/params the cached heads pin)."""
    _HEADS.clear()


def _evict_head(blob: bytes) -> None:
    """Drop ONE blob's cached head. Raise-mode decodes call this when
    corruption surfaces *after* the head parse (a bad latent shard or
    guarantee stream discovered lazily): the head must not stay serveable
    as if the blob were clean, and salvage must never be answered from —
    or write into — the clean-head cache."""
    _HEADS.pop(bytes(blob), None)


# ---------------------------------------------------------------------------
# guarantee stream decode (either layout), per species
# ---------------------------------------------------------------------------
def _gdir(head: _DecodedHead) -> wire.GuaranteeDirectory:
    """Parse (once) the combined guarantee stream's directory (v2+).

    On v4 the directory region digest-checks (against its stored length)
    before any record is interpreted."""
    if head.gdir is None:
        payload = head.reader["guarantee"]
        if head.integrity is not None:
            head.integrity.verify_gdir(payload)
        gdir = wire.GuaranteeDirectory(payload)
        if gdir.n_species != head.shape[0]:
            raise ContainerFormatError(
                f"guarantee directory covers {gdir.n_species} species, "
                f"meta stream declares {head.shape[0]}",
                stream="guarantee",
            )
        if (head.integrity is not None
                and len(head.integrity.species_crcs) != gdir.n_species):
            raise ContainerFormatError(
                f"integrity stream carries "
                f"{len(head.integrity.species_crcs)} species digests, "
                f"guarantee directory has {gdir.n_species}",
                stream="integrity",
            )
        head.gdir = gdir
    return head.gdir


def _coeff_streams(head: _DecodedHead, indices) -> "Optional[list[bytes]]":
    """Selected species' coefficient payloads, sliced without parsing any
    sibling payload; ``None`` when the per-species framing cannot be
    pre-parsed (the per-species path then surfaces the canonical error)."""
    if head.version >= container_format.FORMAT_VERSION_SELECTIVE:
        gdir = _gdir(head)
        return [gdir.coeff_stream(sidx) for sidx in indices]
    try:
        return [
            ContainerReader(head.reader[f"guarantee{sidx}"])["coeff"]
            for sidx in indices
        ]
    except (ContainerFormatError, KeyError):
        return None


def _species_guarantee(
    head: _DecodedHead, sidx: int, *, huffman=None, coeff_q=None
) -> gae.GuaranteeArtifact:
    """Parse + validate ONE species' guarantee artifact (either layout).

    Touches only that species' streams, so a corrupt sibling cannot poison
    it; errors carry the species index (structured: ``stream``/``unit``).
    On v4 the species' guarantee byte extent digest-checks before any of
    it is parsed. ``coeff_q`` injects pre-decoded coefficient symbols
    from the batched lockstep walk."""
    cache = head.runtime.table_cache
    selective = head.version >= container_format.FORMAT_VERSION_SELECTIVE
    sname = "guarantee" if selective else f"guarantee{sidx}"
    try:
        if selective:
            gdir = _gdir(head)
            if head.integrity is not None:
                head.integrity.verify_species(
                    sidx, head.reader["guarantee"], gdir.species_spans(sidx)
                )
            tau, coeff_bin, d, n_store, coeff, index, basis = \
                gdir.species_parts(sidx)
            g = gae.GuaranteeArtifact.from_parts(
                tau, coeff_bin, d, n_store, coeff, index, basis,
                table_cache=cache, huffman=huffman, coeff_q=coeff_q,
            )
        else:
            if coeff_q is not None:
                huffman = lambda _blob, _out=coeff_q: _out  # noqa: E731
            g = gae.GuaranteeArtifact.from_bytes(
                head.reader[sname],
                table_cache=cache, huffman=huffman,
            )
    except ContainerFormatError as e:
        if e.unit == sidx and e.stream == sname:
            raise  # already canonically framed (a failed species digest)
        raise ContainerFormatError(
            f"guarantee stream {sidx}: {e}",
            stream=sname, unit=sidx, offset=e.offset,
        ) from e
    if g.n_blocks != head.nb:
        raise ContainerFormatError(
            f"guarantee stream {sidx} covers {g.n_blocks} blocks, "
            f"expected {head.nb}",
            stream=sname, unit=sidx,
        )
    if g.basis.shape[0] != head.cfg.geometry.block_size:
        raise ContainerFormatError(
            f"guarantee stream {sidx} basis has dimension "
            f"{g.basis.shape[0]}, expected block size "
            f"{head.cfg.geometry.block_size}",
            stream=sname, unit=sidx,
        )
    return g


def _decode_species_guarantees(
    head: _DecodedHead, indices: "list[int]", *, huffman=None
) -> list:
    """Entropy-decode the guarantee streams of ``indices`` only.

    The selected coefficient streams decode in one lockstep chunk-parallel
    chain walk (:func:`entropy.huffman_decode_many`) with codebook tables
    served from the runtime cache; per-species parsing/validation then
    consumes the pre-decoded symbols. Successful artifacts memoize on the
    head (cached heads serve repeated queries without re-walking). When
    the batch walk cannot read a stream, every species re-parses
    individually so the canonical per-species ContainerFormatError
    surfaces (and healthy siblings are still decodable)."""
    memo = head.arts_memo if huffman is None else {}
    todo = [s for s in indices if s not in memo]
    if todo:
        coeffs: "Optional[list]" = None
        if huffman is None and len(todo) > 1:
            streams = _coeff_streams(head, todo)
            if streams is not None:
                try:
                    coeffs = entropy.huffman_decode_many(
                        streams, table_cache=head.runtime.table_cache
                    )
                except (ValueError, struct.error):
                    coeffs = None  # per-species path raises canonically
        for k, sidx in enumerate(todo):
            memo[sidx] = _species_guarantee(
                head, sidx, huffman=huffman,
                coeff_q=None if coeffs is None else coeffs[k],
            )
    return [memo[s] for s in indices]


def _decode_guarantees(head: _DecodedHead, *, huffman=None) -> list:
    """Entropy-decode every species' guarantee stream (full decode)."""
    return _decode_species_guarantees(
        head, list(range(head.shape[0])), huffman=huffman
    )


# ---------------------------------------------------------------------------
# fused NN decode over latents
# ---------------------------------------------------------------------------
def _latents32(latent_q: np.ndarray, latent_bin: float) -> np.ndarray:
    """f64 dequantize then one f32 round — exactly the cast the staged path
    performs when the f64 latents enter the jitted decoder."""
    return dequantize(latent_q, latent_bin).astype(np.float32)


_FUSED_CHUNK = 4096  # blocks per fused-decode dispatch: bounds peak
# activation memory at paper scale (the quick surrogates fit in one chunk)
# without re-tracing — the tail chunk is padded to the fixed shape


def _fused_vecs(rt: _DecodeRuntime, ae_params, corr_params,
                lat32: np.ndarray):
    """Run the fused NN decode over fixed-size block chunks.

    Dispatches are asynchronous, so callers can overlap host work with the
    whole chunk sequence; results are concatenated on device. Chunking is
    row-wise and therefore bit-transparent.
    """
    import jax.numpy as jnp

    n = lat32.shape[0]
    if n <= _FUSED_CHUNK:
        return rt.jit_fused(ae_params, corr_params, lat32)
    outs = []
    for i in range(0, n, _FUSED_CHUNK):
        chunk = lat32[i : i + _FUSED_CHUNK]
        pad = _FUSED_CHUNK - chunk.shape[0]
        if pad:
            chunk = np.concatenate(
                [chunk, np.repeat(chunk[-1:], pad, axis=0)]
            )
        out = rt.jit_fused(ae_params, corr_params, chunk)
        outs.append(out[:, : out.shape[1] - pad] if pad else out)
    return jnp.concatenate(outs, axis=1)  # (S, NB, D) along blocks
