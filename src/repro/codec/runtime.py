"""Cached decode runtimes + container-head parsing for :mod:`repro.codec`.

Two caches make repeated decoding cheap without any codec instance state:

* **runtime cache** — model instances, jitted callables (including the
  fused decode program), and Huffman decode tables, keyed by structural
  signature; a fresh ``decompress`` call on a structurally familiar blob
  never re-traces.
* **head cache** — fully parsed container heads (meta, latent store,
  network parameters, guarantee directory/artifact memos), keyed by blob
  content with a bounded LRU: repeated window queries against the same
  blob skip the parse, the parameter unpack, and every already-decoded
  latent shard / guarantee stream. Distinct blobs can never alias — the
  key compares by content, not object id.

The latent stream is abstracted as a *store*: container v1/v2 carry one
sequential Huffman chain (decoded whole, as any row needs the full walk),
v3 carries independent per-shard chains under a shared codebook, decoded
lazily and only for the block rows a query touches.
"""

from __future__ import annotations

import dataclasses
import itertools
import struct
import threading
from typing import Any, Optional

import numpy as np

from repro.codec import cache as tier_cache
from repro.codec import families
from repro.codec import format as wire
from repro.codec.families import make_fused_decode  # noqa: F401  (canonical home moved; re-exported for the public codec API)
from repro.codec.latents import _ChainLatents, _ShardedLatents
from repro.codec.params import unpack_params
from repro.core import correction, entropy, gae
from repro.core import container as container_format
from repro.core.container import ContainerFormatError, ContainerReader
from repro.core.quantization import dequantize


# ---------------------------------------------------------------------------
# decode runtime (cached per structural signature; never re-traces)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _DecodeRuntime:
    family: families.EncoderFamily
    model: Any
    corr_net: Optional[correction.TensorCorrectionNetwork]
    jit_decode: Any
    jit_corr: Any
    # fused device-resident hot path: dequantized latents -> AE decode ->
    # pointwise correction -> (S, NB, D) block vectors, one dispatch
    jit_fused: Any
    # per-runtime Huffman decode-table memo (codebooks repeat across calls)
    table_cache: entropy.DecodeTableCache


_RUNTIMES: dict[tuple, _DecodeRuntime] = {}
_RUNTIMES_REF: dict[tuple, _DecodeRuntime] = {}
_RUNTIMES_MAX = 8
# the decode service issues concurrent decodes: runtime construction and
# eviction must not interleave (a half-built runtime must never be
# observable, and two threads racing a miss must agree on ONE runtime —
# the cache is also an identity cache, `rt is rt` matters to jit reuse)
_RUNTIMES_LOCK = threading.RLock()


def _runtime_key(cfg: Any, n_species: int, has_corr: bool) -> tuple:
    """Structural signature a decode runtime is cached under.

    ``cfg`` is anything :func:`families.structural` accepts; the family
    name leads the key, so two families sharing geometry/latent/arch can
    never alias one runtime (or each other's jitted programs)."""
    scfg = families.structural(cfg)
    geom = scfg.geometry
    return (
        scfg.family,
        n_species,
        (geom.bt, geom.ph, geom.pw),
        scfg.latent,
        tuple(scfg.arch),
        has_corr,
    )


def _build_runtime(scfg: families.StructuralConfig, n_species: int,
                   has_corr: bool, backend: str) -> _DecodeRuntime:
    import jax

    fam = families.get(scfg.family)
    model = fam.build_model(scfg, n_species, backend)
    corr_net = (
        correction.TensorCorrectionNetwork(
            correction.CorrectionConfig(n_species=n_species)
        )
        if has_corr
        else None
    )
    return _DecodeRuntime(
        family=fam,
        model=model,
        corr_net=corr_net,
        jit_decode=jax.jit(model.decode),
        jit_corr=jax.jit(corr_net.__call__) if corr_net is not None else None,
        jit_fused=jax.jit(fam.make_fused(model, corr_net)),
        table_cache=entropy.DecodeTableCache(),
    )


def _cached_runtime(cache: dict, cfg: Any, n_species: int,
                    has_corr: bool, backend: str) -> _DecodeRuntime:
    scfg = families.structural(cfg)
    key = _runtime_key(scfg, n_species, has_corr)
    with _RUNTIMES_LOCK:
        hit = cache.get(key)
        if hit is not None:
            return hit
        rt = _build_runtime(scfg, n_species, has_corr, backend)
        while len(cache) >= _RUNTIMES_MAX:
            cache.pop(next(iter(cache)))
        cache[key] = rt
        return rt


def _runtime(cfg: Any, n_species: int,
             has_corr: bool) -> _DecodeRuntime:
    return _cached_runtime(_RUNTIMES, cfg, n_species, has_corr, "2d")


def _runtime_reference(cfg: Any, n_species: int,
                       has_corr: bool) -> _DecodeRuntime:
    """Runtime for the retained pre-change decode path: XLA conv impl,
    staged host-chunked orchestration (see ``reconstruct_reference``)."""
    return _cached_runtime(_RUNTIMES_REF, cfg, n_species, has_corr, "xla")


# ---------------------------------------------------------------------------
# container-head parsing
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _DecodedHead:
    """Everything the NN decode needs, parsed before guarantee streams."""

    reader: ContainerReader
    blob: bytes
    cfg: families.StructuralConfig
    shape: tuple[int, int, int, int]
    nb: int
    latent_bin: float
    norm_min: np.ndarray
    norm_range: np.ndarray
    latents: Any  # _ChainLatents | _ShardedLatents
    latent_stream: Optional[bytes]  # v1/v2 single chain (None for v3)
    ae_params: Any
    corr_params: Any
    runtime: _DecodeRuntime
    version: int = container_format.FORMAT_VERSION
    # parsed + self-verified v4 integrity digests (None below v4): head
    # regions were digest-checked during the head parse; lazily read units
    # (latent shards, species guarantee extents, the guarantee directory)
    # digest-check on first access through this handle
    integrity: Optional[wire.IntegrityDirectory] = None
    # lazily parsed combined guarantee directory (see _gdir)
    gdir: Optional[wire.GuaranteeDirectory] = None
    # memoized artifact-wide "any species has corrections" bit (a pure
    # function of the blob; see partial._any_corrections)
    any_corrections: Optional[bool] = None
    # per-species guarantee artifacts already decoded from this blob —
    # the local memo for uncached heads (fresh parses, salvage); cached
    # heads migrate into the shared guarantee tier (see _attach_cache)
    arts_memo: dict = dataclasses.field(default_factory=dict)
    # unique per-parse token: the shard/guarantee tier key prefix (content
    # alone must not alias entries across re-parses of one blob, and a
    # head eviction cascades by token)
    token: int = dataclasses.field(default_factory=lambda: next(_TOKENS))
    # the shared DecodeCache once this head is admitted to the head tier
    # (None for fresh/salvage parses — those stay cache-isolated)
    cache: Optional[tier_cache.DecodeCache] = None
    # guards the lazy single-assignment memos (gdir, any_corrections)
    # against concurrent decode threads; reentrant because the
    # any_corrections probe holds it across a _gdir call
    lock: threading.RLock = dataclasses.field(
        default_factory=threading.RLock, repr=False
    )


_TOKENS = itertools.count()


def _artifact_nbytes(art) -> int:
    """Resident cost of a decoded guarantee artifact (array bytes)."""
    return int(
        art.basis.nbytes + art.coeff_q.nbytes
        + art.index_offsets.nbytes + art.index_flat.nbytes
    )


def _memo_art_get(head: _DecodedHead, sidx: int):
    if head.cache is not None:
        return head.cache.guarantees.get((head.token, sidx))
    return head.arts_memo.get(sidx)


def _memo_art_put(head: _DecodedHead, sidx: int, art) -> None:
    if head.cache is not None:
        head.cache.guarantees.put(
            (head.token, sidx), art, _artifact_nbytes(art)
        )
    else:
        head.arts_memo[sidx] = art


def _decode_head(blob: bytes, *, huffman=None,
                 check_integrity: bool = True) -> _DecodedHead:
    """Parse/validate the container head: meta, stream set, latents,
    network parameters — everything except the guarantee streams, so the
    fused NN decode can be dispatched while those entropy-decode.
    ``huffman`` overrides the latent decoder (reference path).

    On a v4 container the integrity stream is parsed (and self-verified)
    first, then every region this parse consumes is digest-checked
    *before* its bytes are interpreted: the outer header/table, the meta
    stream, the latent stream's head region, and the decoder/correction
    parameter streams. Lazily read units (latent shards, guarantee
    directory and species extents) digest-check on first access.
    ``check_integrity=False`` skips all digest work (salvage uses it to
    decode structurally when the integrity stream itself is corrupt);
    v1–v3 containers carry no digests and parse exactly as before."""
    r = ContainerReader(blob)
    integ = None
    if (check_integrity
            and r.version >= container_format.FORMAT_VERSION_INTEGRITY):
        integ = wire.IntegrityDirectory(r["integrity"])
        integ.verify_outer(r._blob, r.header_bytes)
        integ.verify_stream("meta", r["meta"])
    cfg, shape, latent_bin, norm_min, norm_range = wire._unpack_meta(
        r["meta"], version=r.version
    )
    if cfg.use_correction != ("correction" in r):
        # a flipped correction flag must not silently decode without the
        # shipped network (or with a phantom one)
        raise ContainerFormatError(
            f"meta correction flag is {cfg.use_correction} but the "
            f"container {'carries' if 'correction' in r else 'lacks'} a "
            f"correction stream",
            stream="meta",
        )
    s, t, h, w = shape
    geom = cfg.geometry
    if t % geom.bt or h % geom.ph or w % geom.pw:
        raise ContainerFormatError(
            f"shape {shape} not divisible by block geometry "
            f"({geom.bt}, {geom.ph}, {geom.pw})",
            stream="meta",
        )
    nb = (t // geom.bt) * (h // geom.ph) * (w // geom.pw)

    expected_streams = wire.expected_stream_set(
        r.version, s, cfg.use_correction
    )
    if set(r.names) != expected_streams:
        # strictness: every stream must be accounted for by purpose — no
        # stray payloads hiding in the blob, no silently absent streams.
        # Name the first offending stream so the error locates itself.
        odd = sorted(set(r.names) ^ expected_streams)[0]
        raise ContainerFormatError(
            f"unexpected stream set {sorted(r.names)} "
            f"(expected {sorted(expected_streams)})",
            stream=odd,
        )

    # the runtime cache is the single construction site for the decode
    # models — decode_artifact and reconstruct cannot drift apart
    rt = _runtime(cfg, s, cfg.use_correction)
    latent_stream: Optional[bytes] = r["latent"]
    if r.version >= container_format.FORMAT_VERSION_SHARDED:
        if integ is not None:
            # the head region digest-checks against its *stored* length
            # before any framing field is interpreted
            integ.verify_latent_head(latent_stream)
        latents = _ShardedLatents(
            wire.LatentShardDirectory(latent_stream), nb, cfg.latent,
            rt.table_cache, reference=huffman is not None, integrity=integ,
        )
        latent_stream = None  # not the single-chain wire form
    else:
        latents = _ChainLatents(
            latent_stream, nb, cfg.latent, rt.table_cache, huffman=huffman
        )

    def _params(name: str, defs):
        if integ is not None:
            integ.verify_stream(name, r[name])
        try:
            return unpack_params(r[name], defs, cfg.param_dtype_bytes)
        except ContainerFormatError as e:
            raise ContainerFormatError(
                f"{name} stream: {e}", stream=name, offset=e.offset
            ) from e

    ae_params = _params("decoder", rt.family.decoder_defs(rt.model))
    corr_params = None
    if cfg.use_correction:
        corr_params = _params("correction", rt.corr_net.defs)
    return _DecodedHead(
        reader=r, blob=bytes(blob), cfg=cfg, shape=shape, nb=nb,
        latent_bin=latent_bin, norm_min=norm_min, norm_range=norm_range,
        latents=latents, latent_stream=latent_stream,
        ae_params=ae_params, corr_params=corr_params, runtime=rt,
        version=r.version, integrity=integ,
    )


# the shared multi-tier decode cache: head / latent-shard / guarantee
# tiers with byte budgets, LRU eviction, and stats (see codec/cache.py);
# _HEADS aliases the head tier — the PR-5 name the suite pins eviction
# and isolation behaviour against
_CACHE = tier_cache.DecodeCache()
_HEADS = _CACHE.heads
_HEADS_MAX = tier_cache.DEFAULT_HEAD_ENTRIES
# serializes head *parses* per blob so N concurrent first queries on one
# blob pay one parse, not N (decode work after the parse runs unlocked)
_HEADS_PARSE_LOCK = threading.Lock()
_HEADS_PARSING: dict[bytes, threading.Event] = {}


def _attach_cache(head: _DecodedHead) -> None:
    """Admit a head's sub-memos to the shared tiers (migrating anything
    already decoded through the local memos)."""
    head.cache = _CACHE
    for sidx, art in list(head.arts_memo.items()):
        _CACHE.guarantees.put(
            (head.token, sidx), art, _artifact_nbytes(art)
        )
    head.arts_memo.clear()
    attach = getattr(head.latents, "attach_cache", None)
    if attach is not None:
        attach(_CACHE.shards, head.token)


def _cached_head(blob: bytes) -> _DecodedHead:
    """Content-keyed head tier of the shared decode cache.

    Repeated ``decompress``/window queries on the same blob skip the head
    parse, the parameter unpack, and every latent shard or guarantee
    stream already entropy-decoded through this head. The key is the blob
    *bytes* themselves — content equality, so byte-different blobs can
    never share an entry — and CPython caches a bytes object's hash, so a
    caller re-presenting the same object pays O(1) per query rather than
    re-hashing the container (the entry pins the blob anyway). Entry cost
    is the blob size (the head pins its blob); decoded latent shards and
    guarantee artifacts are accounted in their own tiers and cascade out
    when the head evicts. Concurrent first queries on one blob coalesce
    onto a single parse.
    """
    key = bytes(blob)
    while True:
        hit = _CACHE.heads.get(key)
        if hit is not None:
            return hit
        with _HEADS_PARSE_LOCK:
            # re-check under the lock: the parser that beat us published
            hit = _CACHE.heads.get(key)
            if hit is not None:
                return hit
            waiter = _HEADS_PARSING.get(key)
            if waiter is None:
                _HEADS_PARSING[key] = threading.Event()
                break  # we are the parser
        waiter.wait()
    try:
        head = _decode_head(key)
        _attach_cache(head)
        _CACHE.heads.put(key, head, len(key))
        return head
    finally:
        with _HEADS_PARSE_LOCK:
            _HEADS_PARSING.pop(key).set()


def configure_decode_cache(*, head_bytes: Optional[int] = None,
                           shard_bytes: Optional[int] = None,
                           guarantee_bytes: Optional[int] = None,
                           head_entries: Optional[int] = None) -> None:
    """Re-budget the decode cache tiers (contents are dropped — a budget
    change invalidates every admission decision already made). ``None``
    keeps a tier's current budget; the head tier's entry bound can be
    lifted entirely with ``head_entries=0``."""
    global _HEADS_MAX
    if head_bytes is not None:
        _CACHE.heads.capacity_bytes = int(head_bytes)
    if head_entries is not None:
        _CACHE.heads.max_entries = int(head_entries) or None
        _HEADS_MAX = _CACHE.heads.max_entries or (1 << 62)
    if shard_bytes is not None:
        _CACHE.shards.capacity_bytes = int(shard_bytes)
    if guarantee_bytes is not None:
        _CACHE.guarantees.capacity_bytes = int(guarantee_bytes)
    clear_decode_cache()


def cache_stats() -> dict:
    """Hit/miss/eviction counters + occupancy for every decode cache
    tier, plus the per-runtime Huffman decode-table memos (aggregated
    over the cached decode runtimes)."""
    stats = _CACHE.stats()
    with _RUNTIMES_LOCK:
        runtimes = list(_RUNTIMES.values()) + list(_RUNTIMES_REF.values())
    hits = misses = entries = 0
    for rt in runtimes:
        d = rt.table_cache.stats()
        hits += d["hits"]
        misses += d["misses"]
        entries += d["entries"]
    total = hits + misses
    stats["decode_table"] = {
        "hits": hits,
        "misses": misses,
        "hit_rate": (hits / total) if total else 0.0,
        "entries": entries,
    }
    return stats


def clear_decode_cache() -> None:
    """Drop every decode-cache tier: memoized parsed heads (and with
    them the latent shards / guarantee artifacts their tiers hold), plus
    the Huffman decode-table memos on the cached decode runtimes.
    Benchmarks use this to time genuinely cold decodes."""
    _CACHE.clear()
    with _RUNTIMES_LOCK:
        runtimes = list(_RUNTIMES.values()) + list(_RUNTIMES_REF.values())
    for rt in runtimes:
        rt.table_cache.clear()


def _evict_head(blob: bytes) -> None:
    """Drop ONE blob's cached head. Raise-mode decodes call this when
    corruption surfaces *after* the head parse (a bad latent shard or
    guarantee stream discovered lazily): the head must not stay serveable
    as if the blob were clean, and salvage must never be answered from —
    or write into — the clean-head cache. Cascades to the head's shard
    and guarantee tier entries."""
    _CACHE.heads.discard(bytes(blob))


# ---------------------------------------------------------------------------
# guarantee stream decode (either layout), per species
# ---------------------------------------------------------------------------
def _gdir(head: _DecodedHead) -> wire.GuaranteeDirectory:
    """Parse (once) the combined guarantee stream's directory (v2+).

    On v4 the directory region digest-checks (against its stored length)
    before any record is interpreted. Concurrent callers serialize on the
    head lock so the directory parses exactly once."""
    with head.lock:
        if head.gdir is None:
            payload = head.reader["guarantee"]
            if head.integrity is not None:
                head.integrity.verify_gdir(payload)
            gdir = wire.GuaranteeDirectory(payload)
            if gdir.n_species != head.shape[0]:
                raise ContainerFormatError(
                    f"guarantee directory covers {gdir.n_species} species, "
                    f"meta stream declares {head.shape[0]}",
                    stream="guarantee",
                )
            if (head.integrity is not None
                    and len(head.integrity.species_crcs) != gdir.n_species):
                raise ContainerFormatError(
                    f"integrity stream carries "
                    f"{len(head.integrity.species_crcs)} species digests, "
                    f"guarantee directory has {gdir.n_species}",
                    stream="integrity",
                )
            head.gdir = gdir
        return head.gdir


def _coeff_streams(head: _DecodedHead, indices) -> "Optional[list[bytes]]":
    """Selected species' coefficient payloads, sliced without parsing any
    sibling payload; ``None`` when the per-species framing cannot be
    pre-parsed (the per-species path then surfaces the canonical error)."""
    if head.version >= container_format.FORMAT_VERSION_SELECTIVE:
        gdir = _gdir(head)
        return [gdir.coeff_stream(sidx) for sidx in indices]
    try:
        return [
            ContainerReader(head.reader[f"guarantee{sidx}"])["coeff"]
            for sidx in indices
        ]
    except (ContainerFormatError, KeyError):
        return None


def _species_guarantee(
    head: _DecodedHead, sidx: int, *, huffman=None, coeff_q=None
) -> gae.GuaranteeArtifact:
    """Parse + validate ONE species' guarantee artifact (either layout).

    Touches only that species' streams, so a corrupt sibling cannot poison
    it; errors carry the species index (structured: ``stream``/``unit``).
    On v4 the species' guarantee byte extent digest-checks before any of
    it is parsed. ``coeff_q`` injects pre-decoded coefficient symbols
    from the batched lockstep walk."""
    cache = head.runtime.table_cache
    selective = head.version >= container_format.FORMAT_VERSION_SELECTIVE
    sname = "guarantee" if selective else f"guarantee{sidx}"
    try:
        if selective:
            gdir = _gdir(head)
            if head.integrity is not None:
                head.integrity.verify_species(
                    sidx, head.reader["guarantee"], gdir.species_spans(sidx)
                )
            tau, coeff_bin, d, n_store, coeff, index, basis = \
                gdir.species_parts(sidx)
            g = gae.GuaranteeArtifact.from_parts(
                tau, coeff_bin, d, n_store, coeff, index, basis,
                table_cache=cache, huffman=huffman, coeff_q=coeff_q,
            )
        else:
            if coeff_q is not None:
                huffman = lambda _blob, _out=coeff_q: _out  # noqa: E731
            g = gae.GuaranteeArtifact.from_bytes(
                head.reader[sname],
                table_cache=cache, huffman=huffman,
            )
    except ContainerFormatError as e:
        if e.unit == sidx and e.stream == sname:
            raise  # already canonically framed (a failed species digest)
        raise ContainerFormatError(
            f"guarantee stream {sidx}: {e}",
            stream=sname, unit=sidx, offset=e.offset,
        ) from e
    if g.n_blocks != head.nb:
        raise ContainerFormatError(
            f"guarantee stream {sidx} covers {g.n_blocks} blocks, "
            f"expected {head.nb}",
            stream=sname, unit=sidx,
        )
    if g.basis.shape[0] != head.cfg.geometry.block_size:
        raise ContainerFormatError(
            f"guarantee stream {sidx} basis has dimension "
            f"{g.basis.shape[0]}, expected block size "
            f"{head.cfg.geometry.block_size}",
            stream=sname, unit=sidx,
        )
    return g


def _decode_species_guarantees(
    head: _DecodedHead, indices: "list[int]", *, huffman=None
) -> list:
    """Entropy-decode the guarantee streams of ``indices`` only.

    The selected coefficient streams decode in one lockstep chunk-parallel
    chain walk (:func:`entropy.huffman_decode_many`) with codebook tables
    served from the runtime cache; per-species parsing/validation then
    consumes the pre-decoded symbols. Successful artifacts land in the
    guarantee cache tier keyed under the head's token (cached heads serve
    repeated queries without re-walking; a custom ``huffman`` bypasses
    the shared tier entirely). When the batch walk cannot read a stream,
    every species re-parses individually so the canonical per-species
    ContainerFormatError surfaces (and healthy siblings are still
    decodable)."""
    shared = huffman is None
    got: dict = {}
    if shared:
        for s in indices:
            art = _memo_art_get(head, s)
            if art is not None:
                got[s] = art
    todo = [s for s in indices if s not in got]
    if todo:
        coeffs: "Optional[list]" = None
        if shared and len(todo) > 1:
            streams = _coeff_streams(head, todo)
            if streams is not None:
                try:
                    coeffs = entropy.huffman_decode_many(
                        streams, table_cache=head.runtime.table_cache
                    )
                except (ValueError, struct.error):
                    coeffs = None  # per-species path raises canonically
        for k, sidx in enumerate(todo):
            art = _species_guarantee(
                head, sidx, huffman=huffman,
                coeff_q=None if coeffs is None else coeffs[k],
            )
            got[sidx] = art  # local ref: immune to immediate eviction
            if shared:
                _memo_art_put(head, sidx, art)
    return [got[s] for s in indices]


def _decode_guarantees(head: _DecodedHead, *, huffman=None) -> list:
    """Entropy-decode every species' guarantee stream (full decode)."""
    return _decode_species_guarantees(
        head, list(range(head.shape[0])), huffman=huffman
    )


# ---------------------------------------------------------------------------
# fused NN decode over latents
# ---------------------------------------------------------------------------
def _latents32(latent_q: np.ndarray, latent_bin: float) -> np.ndarray:
    """f64 dequantize then one f32 round — exactly the cast the staged path
    performs when the f64 latents enter the jitted decoder."""
    return dequantize(latent_q, latent_bin).astype(np.float32)


_FUSED_CHUNK = 4096  # blocks per fused-decode dispatch: bounds peak
# activation memory at paper scale (the quick surrogates fit in one chunk)
# without re-tracing — the tail chunk is padded to the fixed shape


def _fused_vecs(rt: _DecodeRuntime, ae_params, corr_params,
                lat32: np.ndarray):
    """Run the fused NN decode over fixed-size block chunks.

    Dispatches are asynchronous, so callers can overlap host work with the
    whole chunk sequence; results are concatenated on device. Chunking is
    row-wise and therefore bit-transparent.
    """
    import jax.numpy as jnp

    n = lat32.shape[0]
    if n <= _FUSED_CHUNK:
        return rt.jit_fused(ae_params, corr_params, lat32)
    outs = []
    for i in range(0, n, _FUSED_CHUNK):
        chunk = lat32[i : i + _FUSED_CHUNK]
        pad = _FUSED_CHUNK - chunk.shape[0]
        if pad:
            chunk = np.concatenate(
                [chunk, np.repeat(chunk[-1:], pad, axis=0)]
            )
        out = rt.jit_fused(ae_params, corr_params, chunk)
        outs.append(out[:, : out.shape[1] - pad] if pad else out)
    return jnp.concatenate(outs, axis=1)  # (S, NB, D) along blocks
