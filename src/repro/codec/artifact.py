"""The fitted-compression artifact: the codec's in-memory unit of work.

:class:`CompressedArtifact` is everything one fitted compression produced
— quantized latents, decode-side parameters, per-species guarantee
artifacts, normalization, shape, and the structural config — plus the
memoized wire streams a ``target_nrmse`` sweep shares across blobs. It
lives under :mod:`repro.codec` (not the pipeline) because it *is* the
wire object: ``to_bytes``/``from_bytes`` are its container round-trip,
``byte_breakdown`` its measured stream accounting. The fit-side
orchestration that produces artifacts stays in
:mod:`repro.core.pipeline`, which re-exports this class for
compatibility.

``cfg`` is any config-shaped object the family registry's
:func:`repro.codec.families.structural` normalizer accepts (a
``PipelineConfig``, a ``StructuralConfig`` unpacked from a blob, ...);
the codec never reads training hyperparameters from it.

Module-level imports here stay clear of ``repro.core`` — the core
package's ``__init__`` imports the pipeline, which imports this module,
so anything heavier than stdlib/numpy at import time would be a cycle.
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Any, Optional

import numpy as np

if TYPE_CHECKING:  # annotation-only; avoids the core-package cycle
    from repro.core import gae


@dataclasses.dataclass
class CompressedArtifact:
    latent_q: np.ndarray  # (NB, latent) int64
    latent_bin: float
    ae_params: Any
    corr_params: Optional[Any]
    species_guarantees: "list[gae.GuaranteeArtifact]"
    norm_min: np.ndarray  # (S,)
    norm_range: np.ndarray  # (S,)
    shape: tuple[int, int, int, int]
    cfg: Any
    # memoized wire streams (immutable once built): the Huffman'd latent
    # payload, pre-packed (decoder, correction) parameter streams shared
    # across a sweep's artifacts, and the full serialized container
    _latent_blob: Optional[bytes] = dataclasses.field(
        default=None, repr=False, compare=False
    )
    _param_streams: Optional[tuple] = dataclasses.field(
        default=None, repr=False, compare=False
    )
    _wire: Optional[bytes] = dataclasses.field(
        default=None, repr=False, compare=False
    )
    # shared latent wire memo: a target_nrmse sweep emits many artifacts
    # off one fitted model with bit-identical latents, so the pipeline
    # hands every artifact of a sweep key the same dict and the entropy
    # pack (single chain or sharded) is paid once per layout, not per blob
    _latent_memo: Optional[dict] = dataclasses.field(
        default=None, repr=False, compare=False
    )

    def latent_blob(self) -> bytes:
        """Single sequential Huffman chain (the v1/v2 ``latent`` stream)."""
        if self._latent_blob is None:
            memo = self._latent_memo
            hit = memo.get("chain") if memo is not None else None
            if hit is None:
                from repro.core import entropy

                hit = entropy.huffman_encode(self.latent_q)
                if memo is not None:
                    memo["chain"] = hit
            self._latent_blob = hit
        return self._latent_blob

    def sharded_latent_stream(self, shard_rows: int) -> bytes:
        """Time-sharded segmented stream (the v3+ ``latent`` stream),
        memoized per shard size across a sweep's artifacts."""
        memo = self._latent_memo
        # the packer clamps shard_rows to the row count, so clamp the key
        # too: every oversized request is the same single-shard stream
        shard_rows = min(max(int(shard_rows), 1), self.latent_q.shape[0])
        key = ("sharded", shard_rows)
        if memo is not None and key in memo:
            return memo[key]
        from repro import codec

        stream = codec.pack_latent_stream(self.latent_q, shard_rows)
        if memo is not None:
            memo[key] = stream
        return stream

    def to_bytes(self) -> bytes:
        """Serialize to the self-describing container (see repro.codec)."""
        if self._wire is None:
            from repro import codec

            self._wire = codec.encode(self)
        return self._wire

    @classmethod
    def from_bytes(cls, blob: bytes) -> "CompressedArtifact":
        """Rebuild an artifact from container bytes (repro.codec wire format)."""
        from repro import codec

        return codec.decode_artifact(blob)

    def byte_breakdown(
        self, model: Optional[Any] = None, corr_net: Optional[Any] = None
    ) -> dict:
        """Measured per-stream byte accounting of the serialized container.

        A view over the container's stream table — every entry is the real
        on-wire length and ``breakdown["total"] == len(self.to_bytes())``
        exactly. ``model``/``corr_net`` are accepted for backward
        compatibility but unused: the container carries the parameter
        streams itself.
        """
        del model, corr_net
        from repro import codec

        return codec.stream_breakdown(self.to_bytes())


def _batched(fn, params, arrays, batch: int = 512):
    """Apply an already-jitted (params, x) callable over leading-axis chunks.

    Chunk shapes are kept fixed: a ragged last chunk is padded (edge-row
    repeat) to the full batch size and the padding sliced off the result.
    The seed dispatched the remainder at its own shape, re-tracing and
    re-compiling the callable once per distinct tail length — the
    trace-count regression test pins this to one trace per leading shape.
    """
    import jax.numpy as jnp

    n = arrays.shape[0]
    if n <= batch:
        return np.asarray(fn(params, jnp.asarray(arrays)))
    outs = []
    for i in range(0, n, batch):
        chunk = arrays[i : i + batch]
        pad = batch - chunk.shape[0]
        if pad:
            chunk = np.concatenate(
                [np.asarray(chunk),
                 np.repeat(np.asarray(chunk[-1:]), pad, axis=0)]
            )
        out = np.asarray(fn(params, jnp.asarray(chunk)))
        outs.append(out[: batch - pad] if pad else out)
    return np.concatenate(outs, axis=0)
