"""Full-field decode paths: blob/artifact -> (S, T, H, W) float32.

The hot path (:func:`decompress`) is device-resident: the container head
(meta, latents, parameters) parses first — served from the content-keyed
head cache on repeat blobs — and one fused jit (dequantized latents → AE
decoder → pointwise correction → (S, NB, D) vectors) is dispatched
asynchronously; the per-species guarantee streams entropy-decode while
the NN decode runs, and a single batched Pallas replay applies the
corrections. The pre-throughput-engine orchestration is retained as
:func:`reconstruct_reference` / :func:`decompress_reference` — the fused
path must match it **bit for bit** (asserted in tests and gating
``benchmarks/bench_throughput.py``).
"""

from __future__ import annotations

import numpy as np

from repro.codec.runtime import (
    _cached_head,
    _decode_guarantees,
    _decode_head,
    _evict_head,
    _fused_vecs,
    _latents32,
    _runtime,
    _runtime_reference,
)
from repro.core.container import ContainerFormatError
from repro.core import blocking, correction, entropy, gae
from repro.codec.artifact import CompressedArtifact, _batched
from repro.core.quantization import dequantize


def _finish_artifact(head, *, huffman=None) -> CompressedArtifact:
    return CompressedArtifact(
        latent_q=head.latents.full(),
        latent_bin=head.latent_bin,
        ae_params=head.ae_params,
        corr_params=head.corr_params,
        species_guarantees=_decode_guarantees(head, huffman=huffman),
        norm_min=head.norm_min,
        norm_range=head.norm_range,
        shape=head.shape,
        cfg=head.cfg,
        _latent_blob=head.latent_stream,
        _wire=head.blob,
    )


def decode_artifact(blob: bytes) -> CompressedArtifact:
    """Rebuild a :class:`CompressedArtifact` from a container blob alone.

    The returned artifact carries only what the wire format does: the AE
    *decoder* parameters (the encoder never ships), the correction network
    if present, and the per-species guarantee streams (entropy-decoded
    species-parallel, decode tables memoized per codebook). Always parses
    fresh — deserialize timing stays honest; the head cache serves
    :func:`decompress` and :class:`~repro.codec.PartialDecoder`.
    """
    return _finish_artifact(_decode_head(blob))


def decode_artifact_reference(blob: bytes) -> CompressedArtifact:
    """Pre-change deserialize, retained as the throughput baseline:
    sequential per-species guarantee decode with per-call table builds and
    the reference per-code-bit window pass. Bitwise the same artifact as
    :func:`decode_artifact`."""
    return _finish_artifact(
        _decode_head(blob, huffman=entropy.huffman_decode_ref),
        huffman=entropy.huffman_decode_ref,
    )


def _finalize_field(corrected: np.ndarray, artifact: CompressedArtifact
                    ) -> np.ndarray:
    """(S, NB, D) corrected vectors -> denormalized (S, T, H, W) field.

    Host numpy in both the fused and the reference path: the multiply/add
    stays un-fused (no FMA contraction), keeping the two paths bit-identical.
    """
    geom = artifact.cfg.geometry
    rec_blocks = blocking.vectors_as_blocks(corrected, geom)
    rec_normed = blocking.from_blocks(rec_blocks, artifact.shape, geom)
    return (
        rec_normed * artifact.norm_range[:, None, None, None]
        + artifact.norm_min[:, None, None, None]
    ).astype(np.float32)


def _apply_guarantees_and_finalize(vecs_dev, artifact: CompressedArtifact
                                   ) -> np.ndarray:
    """Post-dispatch tail of the fused decode: batched guarantee replay on
    the (possibly still in-flight) NN-decoded vectors, then host
    finalization. The single implementation behind both ``reconstruct``
    and ``decompress``."""
    import jax.numpy as jnp

    engine = gae.default_engine()
    arts = artifact.species_guarantees
    if any(a.coeff_q.size for a in arts):
        s, nb, d = vecs_dev.shape
        # host-side CSR scatter overlaps the in-flight async NN decode
        dense, basis = engine.dense_corrections(arts, (s, nb, d))
        vecs_dev = engine.apply_device(
            vecs_dev, jnp.asarray(dense), jnp.asarray(basis)
        )
    return _finalize_field(np.asarray(vecs_dev), artifact)


def _fused_reconstruct(rt, artifact: CompressedArtifact) -> np.ndarray:
    """The device-resident decode hot path (see :func:`decompress`)."""
    vecs_dev = _fused_vecs(
        rt, artifact.ae_params, artifact.corr_params,
        _latents32(artifact.latent_q, artifact.latent_bin),
    )
    return _apply_guarantees_and_finalize(vecs_dev, artifact)


def reconstruct(artifact: CompressedArtifact) -> np.ndarray:
    """Decode an in-memory artifact to the full (S, T, H, W) field.

    Derives every structural decision — geometry, AE shape, whether the
    tensor-correction network runs — from the artifact itself, never from
    ambient pipeline state (the seed's config-shadowing hazard). Runs the
    fused device-resident hot path; :func:`reconstruct_reference` retains
    the staged pre-change orchestration as the bit-identity oracle.
    """
    cfg = artifact.cfg
    has_corr = artifact.corr_params is not None
    rt = _runtime(cfg, len(artifact.norm_min), has_corr)
    return _fused_reconstruct(rt, artifact)


def reconstruct_reference(artifact: CompressedArtifact,
                          conv_impl: str = "2d") -> np.ndarray:
    """The seed's decode *orchestration*, retained as baseline and oracle:
    host-chunked ``_batched`` stages with a numpy round-trip between
    dequantize, decoder, correction, and guarantee replay.

    With the default ``conv_impl="2d"`` the staged path shares the fused
    path's layer implementations, and ``reconstruct`` must match it **bit
    for bit** — the gate asserted by the test suite and by
    ``benchmarks/bench_throughput.py`` before any number is reported (it
    proves the hot-path reorganization is semantically transparent).
    ``conv_impl="xla"`` additionally retains the seed's convolution
    lowering — the true pre-change cost profile used as the benchmark's
    timing baseline; its output differs from the 2d formulation only by
    float-summation reassociation inside the convolutions (ulp-level,
    bound-checked in the benchmark)."""
    cfg = artifact.cfg
    has_corr = artifact.corr_params is not None
    builder = _runtime if conv_impl == "2d" else _runtime_reference
    rt = builder(cfg, len(artifact.norm_min), has_corr)
    lat = dequantize(artifact.latent_q, artifact.latent_bin)
    x_rec = _batched(rt.jit_decode, artifact.ae_params, lat)
    if has_corr:
        vecs = correction.blocks_to_pointwise(x_rec)
        fixed = _batched(rt.jit_corr, artifact.corr_params, vecs, batch=1 << 16)
        x_rec = correction.pointwise_to_blocks(fixed, x_rec)
    vecs_rec = blocking.blocks_as_vectors(x_rec)
    corrected = gae.apply_correction_batched(
        vecs_rec, artifact.species_guarantees
    )
    return _finalize_field(corrected, artifact)


def decompress(blob: bytes, *, species=None, time_range=None,
               on_error: str = "raise"):
    """Standalone decode: container bytes -> (S, T, H, W) float32 field.

    Needs no codec instance and no fitted model — everything is
    reconstructed from the blob (the acceptance contract for the wire
    format). Raises :class:`ContainerFormatError` on malformed input.

    ``species`` (an index or a sequence of indices) and/or ``time_range``
    (a half-open ``(t0, t1)`` frame window) select a slice to decode
    randomly-accessed: only the requested guarantee streams are parsed and
    entropy-decoded, the fused NN decode covers only the block rows of the
    window — and on a v3+ (time-sharded) container only the latent shards
    covering the window entropy-decode, making a window query O(window)
    end to end — with the result bitwise equal to slicing a full decode:
    ``decompress(b, species=s, time_range=(t0, t1))
    == decompress(b)[s, t0:t1]``. An integer ``species`` drops the species
    axis, like numpy indexing.

    On a v4 container every byte the decode reads is digest-checked
    (CRC32) before it is interpreted; a mismatch raises
    :class:`ContainerFormatError` with structured context (stream,
    offset, unit). ``on_error="salvage"`` switches to degraded-but-honest
    decoding: corrupt species/latent shards are quarantined instead of
    aborting, everything verifiable decodes (bitwise equal to the clean
    decode), damaged regions come back NaN, and the call returns a
    ``(field, DecodeReport)`` tuple — see
    :func:`repro.codec.integrity.salvage_decompress`.

    Parsed container heads are served from a content-keyed bounded cache,
    so repeated (window) queries on one blob skip the head parse and every
    already-decoded stream; :func:`repro.codec.clear_decode_cache` drops
    the memo (benchmarks use it to time cold decodes). A raise-mode
    decode that hits corruption evicts the blob's cached head, and
    salvage never touches the cache — a salvaged parse can never be
    served later as a clean head.
    """
    if on_error not in ("raise", "salvage"):
        raise ValueError(
            f"on_error must be 'raise' or 'salvage', got {on_error!r}"
        )
    if on_error == "salvage":
        from repro.codec.integrity import salvage_decompress

        return salvage_decompress(
            blob, species=species, time_range=time_range
        )
    if species is not None or time_range is not None:
        from repro.codec.partial import PartialDecoder

        return PartialDecoder(blob).decode(
            species=species, time_range=time_range
        )
    head = _cached_head(blob)
    try:
        vecs_dev = _fused_vecs(
            head.runtime, head.ae_params, head.corr_params,
            _latents32(head.latents.full(), head.latent_bin),
        )
        # the guarantee streams entropy-decode while the dispatched NN runs
        artifact = _finish_artifact(head)
        return _apply_guarantees_and_finalize(vecs_dev, artifact)
    except ContainerFormatError:
        # corruption discovered after the head parse (lazy shard/species
        # digest or entropy failure): drop the poisoned cached head
        _evict_head(blob)
        raise


def decompress_reference(blob: bytes, conv_impl: str = "2d") -> np.ndarray:
    """Retained pre-change standalone decode: sequential per-species
    deserialize with per-call Huffman table builds, then the staged
    host-chunked reconstruct. With the default ``conv_impl="2d"`` this is
    the fused path's bit-identity oracle; with ``"xla"`` it is the seed's
    full cost profile (the throughput benchmark's timing baseline)."""
    return reconstruct_reference(decode_artifact_reference(blob), conv_impl)
