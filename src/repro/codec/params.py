"""Parameter-tree wire packing: raw little-endian leaves, deterministic order.

The decoder / correction networks travel as *bare parameter values*: the
tree structure is fully derivable from the pipeline config, so the stream
length is exactly the byte count the paper's accounting charges for the
networks — no per-leaf framing.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.container import ContainerFormatError
from repro.core.quantization import param_storage_dtype
from repro.nn import module as nn_module


def _sorted_leaves(tree):
    """Depth-first leaves of a nested-dict pytree, keys sorted at every level
    (the same order as :func:`repro.nn.module._walk` over the defs tree)."""
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _sorted_leaves(tree[k])
    else:
        yield tree


def pack_params(tree, param_dtype_bytes: int) -> bytes:
    """Concatenate pytree leaves as raw storage-dtype bytes, no framing."""
    dtype = param_storage_dtype(param_dtype_bytes)
    return b"".join(
        np.ascontiguousarray(np.asarray(leaf)).astype(dtype).tobytes()
        for leaf in _sorted_leaves(tree)
    )


def unpack_params(buf: bytes, defs, param_dtype_bytes: int):
    """Inverse of :func:`pack_params` given the matching definition tree."""
    dtype = param_storage_dtype(param_dtype_bytes)
    walk = list(nn_module._walk(defs))
    expected = sum(
        int(np.prod(p.shape)) * dtype.itemsize for _, p in walk
    )
    if len(buf) != expected:
        raise ContainerFormatError(
            f"parameter stream is {len(buf)} bytes, expected {expected}"
        )
    out: dict = {}
    off = 0
    for path, p in walk:
        n = int(np.prod(p.shape))
        leaf = (
            np.frombuffer(buf, dtype=dtype, count=n, offset=off)
            .astype(np.float32)
            .reshape(p.shape)
        )
        off += n * dtype.itemsize
        node = out
        for key in path[:-1]:
            node = node.setdefault(key, {})
        node[path[-1]] = leaf
    return out


def _decoder_defs(model):
    # family-agnostic: every registered family keys decode-side defs with
    # a "dec" prefix (see repro.codec.families._decoder_defs, the
    # registry-side twin the runtime dispatches through)
    return {k: v for k, v in model.defs.items() if k.startswith("dec")}


def pack_artifact_params(
    ae_params, corr_params, param_dtype_bytes: int
) -> tuple[bytes, Optional[bytes]]:
    """Packed (decoder, correction) wire streams — the single source for
    the decoder-key filter and tuple layout (correction is None when the
    artifact carries no correction network)."""
    dec = {k: v for k, v in ae_params.items() if k.startswith("dec")}
    return (
        pack_params(dec, param_dtype_bytes),
        pack_params(corr_params, param_dtype_bytes)
        if corr_params is not None
        else None,
    )
