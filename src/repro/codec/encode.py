"""Encode planner + codec facade: pipeline artifacts -> container bytes.

:func:`encode` maps a fitted :class:`CompressedArtifact` onto the wire
streams of the requested container version — v4 (default) is v3 plus an
``integrity`` stream of CRC32 digests (per stream + per random-access
unit + the outer header), v3 shards the latent stream along time and
packs the per-shard chains in parallel, v2 writes the single-chain
selective layout, v1 the original per-species nested guarantee
containers. All four stay writable so round-trip and back-compat gates
can cover every version; a v4 full decode is bitwise equal to the v3
decode of the same fit (the digests change no payload byte).

:func:`write`/:func:`read` are the file-level pair: an atomic
tmp+fsync+rename publish (the ``train/checkpoint.py`` idiom), so a
crash mid-write can never leave a half-blob that parses, and a
digest-verifying read.
"""

from __future__ import annotations

import os
import tempfile
from typing import Optional

import numpy as np

from repro.codec import format as wire
from repro.codec.decode import decompress as _decompress
from repro.codec.params import pack_artifact_params
from repro.core import container as container_format
from repro.core.container import ContainerWriter
from repro.core.pipeline import (
    CompressedArtifact,
    CompressionReport,
    GBATCPipeline,
    PipelineConfig,
)


def encode(artifact: CompressedArtifact,
           version: int = container_format.FORMAT_VERSION_INTEGRITY,
           *, shard_tgroups: Optional[int] = None) -> bytes:
    """Serialize a :class:`CompressedArtifact` into a container blob.

    ``version`` selects the layout: 4 (default) writes the time-sharded
    latent stream + combined guarantee stream + integrity digests; 3 the
    same without digests; 2 the single-chain latent + combined
    guarantee; 1 the original per-species nested containers (all
    retained byte-stable so back-compat round-trips stay testable).
    ``shard_tgroups`` (v3+) sets the shard size in time block-groups
    (``bt`` frames each); the default of
    ``format.DEFAULT_SHARD_TGROUPS`` gives the finest window a block-row
    decode can address. Oversized values clamp to one shard.
    """
    cfg = artifact.cfg
    if version not in container_format.SUPPORTED_VERSIONS:
        raise ValueError(f"unknown container version {version}")
    if (shard_tgroups is not None
            and version < container_format.FORMAT_VERSION_SHARDED):
        raise ValueError(
            f"shard_tgroups applies to container v"
            f"{container_format.FORMAT_VERSION_SHARDED}+ only"
        )
    w = ContainerWriter(version=version)
    w.add("meta", wire._pack_meta(artifact))
    if version >= container_format.FORMAT_VERSION_SHARDED:
        geom = cfg.geometry
        _, _, h, wd = artifact.shape
        per_frame = (h // geom.ph) * (wd // geom.pw)
        tg = wire.DEFAULT_SHARD_TGROUPS if shard_tgroups is None \
            else int(shard_tgroups)
        if tg < 1:
            raise ValueError(f"shard_tgroups must be >= 1, got {tg}")
        # through the artifact so a sweep's blobs share one packed stream
        w.add("latent", artifact.sharded_latent_stream(tg * per_frame))
    else:
        w.add("latent", artifact.latent_blob())
    packed = artifact._param_streams
    if packed is None:
        packed = pack_artifact_params(
            artifact.ae_params, artifact.corr_params, cfg.param_dtype_bytes
        )
    w.add("decoder", packed[0])
    if artifact.corr_params is not None:
        w.add("correction", packed[1])
    if version >= container_format.FORMAT_VERSION_SELECTIVE:
        w.add("guarantee",
              wire.pack_guarantee_stream(artifact.species_guarantees))
    else:
        for sidx, g in enumerate(artifact.species_guarantees):
            w.add(f"guarantee{sidx}", g.to_bytes())
    if version >= container_format.FORMAT_VERSION_INTEGRITY:
        # two-pass outer digest: the integrity payload's LENGTH is fixed
        # before its content (it depends only on stream count/names and
        # unit counts), so the exact outer header+table bytes — integrity
        # entry included — are known before outer_crc is patched in
        streams = list(w._streams)
        integ = wire.pack_integrity_stream(streams)
        header = container_format.pack_header(
            version,
            [(n, len(p)) for n, p in streams] + [("integrity", len(integ))],
        )
        w.add("integrity", wire.finalize_integrity_stream(integ, header))
    return w.to_bytes()


def write(path, blob: bytes) -> None:
    """Atomically publish container bytes at ``path``.

    The checkpoint-writer idiom: write to a temp file in the same
    directory, flush + fsync, then ``os.replace`` — so a crash at any
    point leaves either the previous file or the complete new one, never
    a half-blob that parses (v4's outer digest would catch one anyway;
    this makes the failure mode impossible rather than detectable).
    """
    path = os.fspath(path)
    blob = bytes(blob)
    d = os.path.dirname(os.path.abspath(path))
    fd, tmp = tempfile.mkstemp(
        dir=d, prefix=f".{os.path.basename(path)}.tmp"
    )
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(blob)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    # fsync the directory so the rename itself is durable
    try:
        dfd = os.open(d, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(dfd)
    finally:
        os.close(dfd)


def read(path, *, verify: bool = True) -> bytes:
    """Read container bytes from ``path``; ``verify=True`` (default)
    digest-checks every payload byte on v4 blobs (structural parse only
    below v4) before returning, raising
    :class:`~repro.core.container.ContainerFormatError` on corruption."""
    with open(os.fspath(path), "rb") as f:
        blob = f.read()
    if verify:
        from repro.codec.integrity import verify_blob

        verify_blob(blob)
    return blob


class GBATCCodec:
    """Bytes-in/bytes-out GBATC (or GBA, via ``cfg.use_correction=False``).

    Usage::

        codec = GBATCCodec(PipelineConfig(...))
        codec.fit(data)                       # train AE (+ correction) once
        blob = codec.compress(target_nrmse=1e-3)   # -> container bytes
        field = repro.codec.decompress(blob)       # anywhere, no codec

    ``compress(data=...)`` fits on the given data first (refitting if the
    codec was already fitted), so one-shot compression is a single call;
    ``fit_stream(loader)`` consumes time-chunked input without ever
    materializing the full field (see
    :meth:`repro.core.pipeline.GBATCPipeline.fit_stream`). Error-bound
    sweeps against one fitted model reuse the pipeline's cached
    tau-independent guarantee state.
    """

    def __init__(self, cfg: Optional[PipelineConfig] = None,
                 n_species: Optional[int] = None):
        self.cfg = cfg if cfg is not None else PipelineConfig()
        self._pipe: Optional[GBATCPipeline] = (
            GBATCPipeline(self.cfg, n_species) if n_species is not None else None
        )

    @property
    def pipeline(self) -> Optional[GBATCPipeline]:
        """The underlying fit/orchestration layer (None before first fit)."""
        return self._pipe

    @property
    def fitted(self) -> bool:
        return self._pipe is not None and self._pipe._latents is not None

    def fit(self, data: np.ndarray, verbose: bool = False) -> "GBATCCodec":
        data = np.asarray(data)
        if data.ndim != 4:
            raise ValueError(
                f"expected (S, T, H, W) species data, got "
                f"{data.ndim}-d {type(data).__name__} of shape {data.shape}"
                " (note: compress(target_nrmse=...) is keyword-only via the"
                " data-first signature)"
            )
        if self._pipe is None or self._pipe.n_species != data.shape[0]:
            self._pipe = GBATCPipeline(self.cfg, n_species=data.shape[0])
        self._pipe.fit(data, verbose=verbose)
        return self

    def fit_stream(self, loader, verbose: bool = False, *,
                   loader_retries: int = 2, retry_backoff: float = 0.1,
                   _sleep=None) -> "GBATCCodec":
        """Fit from time-chunked input without materializing the field.

        ``loader`` must expose ``shape`` — the full (S, T, H, W) — and a
        re-iterable ``chunks()`` yielding consecutive (S, Tc, H, W) time
        chunks (each Tc divisible by the block geometry's ``bt``), e.g.
        :class:`repro.data.s3d.S3DChunkLoader`. The fit is bit-identical
        to ``fit(concatenate(chunks, axis=1))``.

        Transient loader faults (I/O errors mid-iteration) restart the
        failing pass from its beginning with exponential backoff — up to
        ``loader_retries`` restarts per pass, ``retry_backoff`` seconds
        doubling per attempt — and the result stays bit-identical to a
        clean run (each pass is a pure function of the re-iterated
        chunks). Shape/validation errors are never retried.
        """
        s = int(loader.shape[0])
        if self._pipe is None or self._pipe.n_species != s:
            self._pipe = GBATCPipeline(self.cfg, n_species=s)
        self._pipe.fit_stream(
            loader, verbose=verbose, loader_retries=loader_retries,
            retry_backoff=retry_backoff, _sleep=_sleep,
        )
        return self

    def compress(self, data: Optional[np.ndarray] = None,
                 target_nrmse: float = 1e-3, **kw) -> bytes:
        """Compress to container bytes; pass ``data`` to (re)fit first."""
        blob, _ = self.compress_report(data, target_nrmse=target_nrmse, **kw)
        return blob

    def compress_report(
        self, data: Optional[np.ndarray] = None,
        target_nrmse: float = 1e-3, **kw,
    ) -> tuple[bytes, CompressionReport]:
        """Like :meth:`compress`, also returning the quality report."""
        if data is not None:
            self.fit(data)
        if not self.fitted:
            raise RuntimeError("codec not fitted: pass data or call fit() first")
        rep = self._pipe.compress(target_nrmse=target_nrmse, **kw)
        return rep.artifact.to_bytes(), rep

    def write(self, path, data: Optional[np.ndarray] = None,
              target_nrmse: float = 1e-3, **kw) -> bytes:
        """Compress and atomically publish the container at ``path``
        (tmp + fsync + rename — a crash can never leave a half-blob).
        Pass ``data`` to (re)fit first. Returns the written bytes."""
        blob = self.compress(data, target_nrmse=target_nrmse, **kw)
        write(path, blob)
        return blob

    @staticmethod
    def read(path, *, verify: bool = True) -> bytes:
        """Read (and by default digest-verify) a container file; see
        module :func:`read`."""
        return read(path, verify=verify)

    @staticmethod
    def decompress(blob: bytes, *, species=None, time_range=None,
                   on_error: str = "raise"):
        """Decode a container blob (stateless; see module :func:`decompress`).

        ``species``/``time_range`` select a slice to decode
        randomly-accessed, bitwise equal to slicing the full decode;
        ``on_error="salvage"`` quarantines corruption and returns
        ``(field, DecodeReport)``."""
        return _decompress(blob, species=species, time_range=time_range,
                           on_error=on_error)
