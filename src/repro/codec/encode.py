"""Encode planner + codec facade: pipeline artifacts -> container bytes.

:func:`encode` maps a fitted :class:`CompressedArtifact` onto the wire
streams of the requested container version — v3 (default) shards the
latent stream along time and packs the per-shard chains in parallel, v2
writes the single-chain selective layout, v1 the original per-species
nested guarantee containers. All three stay writable so round-trip and
back-compat gates can cover every version; a v3 full decode is bitwise
equal to the v2 decode of the same fit.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.codec import format as wire
from repro.codec.decode import decompress as _decompress
from repro.codec.params import pack_artifact_params
from repro.core import container as container_format
from repro.core.container import ContainerWriter
from repro.core.pipeline import (
    CompressedArtifact,
    CompressionReport,
    GBATCPipeline,
    PipelineConfig,
)


def encode(artifact: CompressedArtifact,
           version: int = container_format.FORMAT_VERSION_SHARDED,
           *, shard_tgroups: Optional[int] = None) -> bytes:
    """Serialize a :class:`CompressedArtifact` into a container blob.

    ``version`` selects the layout: 3 (default) writes the time-sharded
    latent stream + combined guarantee stream; 2 the single-chain latent +
    combined guarantee; 1 the original per-species nested containers
    (both retained byte-stable so back-compat round-trips stay testable).
    ``shard_tgroups`` (v3 only) sets the shard size in time block-groups
    (``bt`` frames each); the default of
    ``format.DEFAULT_SHARD_TGROUPS`` gives the finest window a block-row
    decode can address. Oversized values clamp to one shard.
    """
    cfg = artifact.cfg
    if version not in container_format.SUPPORTED_VERSIONS:
        raise ValueError(f"unknown container version {version}")
    if (shard_tgroups is not None
            and version != container_format.FORMAT_VERSION_SHARDED):
        raise ValueError(
            f"shard_tgroups applies to container v"
            f"{container_format.FORMAT_VERSION_SHARDED} only"
        )
    w = ContainerWriter(version=version)
    w.add("meta", wire._pack_meta(artifact))
    if version >= container_format.FORMAT_VERSION_SHARDED:
        geom = cfg.geometry
        _, _, h, wd = artifact.shape
        per_frame = (h // geom.ph) * (wd // geom.pw)
        tg = wire.DEFAULT_SHARD_TGROUPS if shard_tgroups is None \
            else int(shard_tgroups)
        if tg < 1:
            raise ValueError(f"shard_tgroups must be >= 1, got {tg}")
        # through the artifact so a sweep's blobs share one packed stream
        w.add("latent", artifact.sharded_latent_stream(tg * per_frame))
    else:
        w.add("latent", artifact.latent_blob())
    packed = artifact._param_streams
    if packed is None:
        packed = pack_artifact_params(
            artifact.ae_params, artifact.corr_params, cfg.param_dtype_bytes
        )
    w.add("decoder", packed[0])
    if artifact.corr_params is not None:
        w.add("correction", packed[1])
    if version >= container_format.FORMAT_VERSION_SELECTIVE:
        w.add("guarantee",
              wire.pack_guarantee_stream(artifact.species_guarantees))
    else:
        for sidx, g in enumerate(artifact.species_guarantees):
            w.add(f"guarantee{sidx}", g.to_bytes())
    return w.to_bytes()


class GBATCCodec:
    """Bytes-in/bytes-out GBATC (or GBA, via ``cfg.use_correction=False``).

    Usage::

        codec = GBATCCodec(PipelineConfig(...))
        codec.fit(data)                       # train AE (+ correction) once
        blob = codec.compress(target_nrmse=1e-3)   # -> container bytes
        field = repro.codec.decompress(blob)       # anywhere, no codec

    ``compress(data=...)`` fits on the given data first (refitting if the
    codec was already fitted), so one-shot compression is a single call;
    ``fit_stream(loader)`` consumes time-chunked input without ever
    materializing the full field (see
    :meth:`repro.core.pipeline.GBATCPipeline.fit_stream`). Error-bound
    sweeps against one fitted model reuse the pipeline's cached
    tau-independent guarantee state.
    """

    def __init__(self, cfg: Optional[PipelineConfig] = None,
                 n_species: Optional[int] = None):
        self.cfg = cfg if cfg is not None else PipelineConfig()
        self._pipe: Optional[GBATCPipeline] = (
            GBATCPipeline(self.cfg, n_species) if n_species is not None else None
        )

    @property
    def pipeline(self) -> Optional[GBATCPipeline]:
        """The underlying fit/orchestration layer (None before first fit)."""
        return self._pipe

    @property
    def fitted(self) -> bool:
        return self._pipe is not None and self._pipe._latents is not None

    def fit(self, data: np.ndarray, verbose: bool = False) -> "GBATCCodec":
        data = np.asarray(data)
        if data.ndim != 4:
            raise ValueError(
                f"expected (S, T, H, W) species data, got "
                f"{data.ndim}-d {type(data).__name__} of shape {data.shape}"
                " (note: compress(target_nrmse=...) is keyword-only via the"
                " data-first signature)"
            )
        if self._pipe is None or self._pipe.n_species != data.shape[0]:
            self._pipe = GBATCPipeline(self.cfg, n_species=data.shape[0])
        self._pipe.fit(data, verbose=verbose)
        return self

    def fit_stream(self, loader, verbose: bool = False) -> "GBATCCodec":
        """Fit from time-chunked input without materializing the field.

        ``loader`` must expose ``shape`` — the full (S, T, H, W) — and a
        re-iterable ``chunks()`` yielding consecutive (S, Tc, H, W) time
        chunks (each Tc divisible by the block geometry's ``bt``), e.g.
        :class:`repro.data.s3d.S3DChunkLoader`. The fit is bit-identical
        to ``fit(concatenate(chunks, axis=1))``.
        """
        s = int(loader.shape[0])
        if self._pipe is None or self._pipe.n_species != s:
            self._pipe = GBATCPipeline(self.cfg, n_species=s)
        self._pipe.fit_stream(loader, verbose=verbose)
        return self

    def compress(self, data: Optional[np.ndarray] = None,
                 target_nrmse: float = 1e-3, **kw) -> bytes:
        """Compress to container bytes; pass ``data`` to (re)fit first."""
        blob, _ = self.compress_report(data, target_nrmse=target_nrmse, **kw)
        return blob

    def compress_report(
        self, data: Optional[np.ndarray] = None,
        target_nrmse: float = 1e-3, **kw,
    ) -> tuple[bytes, CompressionReport]:
        """Like :meth:`compress`, also returning the quality report."""
        if data is not None:
            self.fit(data)
        if not self.fitted:
            raise RuntimeError("codec not fitted: pass data or call fit() first")
        rep = self._pipe.compress(target_nrmse=target_nrmse, **kw)
        return rep.artifact.to_bytes(), rep

    @staticmethod
    def decompress(blob: bytes, *, species=None, time_range=None) -> np.ndarray:
        """Decode a container blob (stateless; see module :func:`decompress`).

        ``species``/``time_range`` select a slice to decode
        randomly-accessed, bitwise equal to slicing the full decode."""
        return _decompress(blob, species=species, time_range=time_range)
