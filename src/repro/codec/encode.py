"""Encode planner: pipeline artifacts -> container bytes.

:func:`encode` maps a fitted :class:`CompressedArtifact` onto the wire
streams of the requested container version — v5 (default) is v4's
stream set with the ``meta`` stream prefixed by the encoder-family tag
(see :mod:`repro.codec.families`; a conv-family v5 blob differs from
the v4 encoding of the same fit by that one byte only), v4 is v3 plus
an ``integrity`` stream of CRC32 digests (per stream + per
random-access unit + the outer header), v3 shards the latent stream
along time and packs the per-shard chains in parallel, v2 writes the
single-chain selective layout, v1 the original per-species nested
guarantee containers. All five stay writable so round-trip and
back-compat gates can cover every version; non-conv families require
v5 (the legacy meta layout has no family field).

:func:`write`/:func:`read` are the file-level pair: an atomic
tmp+fsync+rename publish (the ``train/checkpoint.py`` idiom), so a
crash mid-write can never leave a half-blob that parses, and a
digest-verifying read. The :class:`GBATCCodec` fit/compress facade
lives with the orchestration layer in :mod:`repro.core.pipeline` —
this module is decode-purity scoped (nothing under ``codec/`` imports
the pipeline).
"""

from __future__ import annotations

import os
import tempfile
from typing import Optional

from repro.codec import families
from repro.codec import format as wire
from repro.codec.artifact import CompressedArtifact
from repro.codec.params import pack_artifact_params
from repro.core import container as container_format
from repro.core.container import ContainerWriter


def encode(artifact: CompressedArtifact,
           version: int = container_format.FORMAT_VERSION_FAMILY,
           *, shard_tgroups: Optional[int] = None) -> bytes:
    """Serialize a :class:`CompressedArtifact` into a container blob.

    ``version`` selects the layout: 5 (default) prefixes the meta
    stream with the encoder-family tag (required for non-conv
    families); 4 writes the time-sharded latent stream + combined
    guarantee stream + integrity digests; 3 the same without digests;
    2 the single-chain latent + combined guarantee; 1 the original
    per-species nested containers (all retained byte-stable so
    back-compat round-trips stay testable). ``shard_tgroups`` (v3+)
    sets the shard size in time block-groups (``bt`` frames each); the
    default of ``format.DEFAULT_SHARD_TGROUPS`` gives the finest
    window a block-row decode can address. Oversized values clamp to
    one shard.
    """
    cfg = families.structural(artifact.cfg)
    if version not in container_format.SUPPORTED_VERSIONS:
        raise ValueError(f"unknown container version {version}")
    if (shard_tgroups is not None
            and version < container_format.FORMAT_VERSION_SHARDED):
        raise ValueError(
            f"shard_tgroups applies to container v"
            f"{container_format.FORMAT_VERSION_SHARDED}+ only"
        )
    w = ContainerWriter(version=version)
    w.add("meta", wire._pack_meta(artifact, version))
    if version >= container_format.FORMAT_VERSION_SHARDED:
        geom = cfg.geometry
        _, _, h, wd = artifact.shape
        per_frame = (h // geom.ph) * (wd // geom.pw)
        tg = wire.DEFAULT_SHARD_TGROUPS if shard_tgroups is None \
            else int(shard_tgroups)
        if tg < 1:
            raise ValueError(f"shard_tgroups must be >= 1, got {tg}")
        # through the artifact so a sweep's blobs share one packed stream
        w.add("latent", artifact.sharded_latent_stream(tg * per_frame))
    else:
        w.add("latent", artifact.latent_blob())
    packed = artifact._param_streams
    if packed is None:
        packed = pack_artifact_params(
            artifact.ae_params, artifact.corr_params, cfg.param_dtype_bytes
        )
    w.add("decoder", packed[0])
    if artifact.corr_params is not None:
        w.add("correction", packed[1])
    if version >= container_format.FORMAT_VERSION_SELECTIVE:
        w.add("guarantee",
              wire.pack_guarantee_stream(artifact.species_guarantees))
    else:
        for sidx, g in enumerate(artifact.species_guarantees):
            w.add(f"guarantee{sidx}", g.to_bytes())
    if version >= container_format.FORMAT_VERSION_INTEGRITY:
        # two-pass outer digest: the integrity payload's LENGTH is fixed
        # before its content (it depends only on stream count/names and
        # unit counts), so the exact outer header+table bytes — integrity
        # entry included — are known before outer_crc is patched in
        streams = list(w._streams)
        integ = wire.pack_integrity_stream(streams)
        header = container_format.pack_header(
            version,
            [(n, len(p)) for n, p in streams] + [("integrity", len(integ))],
        )
        w.add("integrity", wire.finalize_integrity_stream(integ, header))
    return w.to_bytes()


def write(path, blob: bytes) -> None:
    """Atomically publish container bytes at ``path``.

    The checkpoint-writer idiom: write to a temp file in the same
    directory, flush + fsync, then ``os.replace`` — so a crash at any
    point leaves either the previous file or the complete new one, never
    a half-blob that parses (v4's outer digest would catch one anyway;
    this makes the failure mode impossible rather than detectable).
    """
    path = os.fspath(path)
    blob = bytes(blob)
    d = os.path.dirname(os.path.abspath(path))
    fd, tmp = tempfile.mkstemp(
        dir=d, prefix=f".{os.path.basename(path)}.tmp"
    )
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(blob)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    # fsync the directory so the rename itself is durable
    try:
        dfd = os.open(d, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(dfd)
    finally:
        os.close(dfd)


def read(path, *, verify: bool = True) -> bytes:
    """Read container bytes from ``path``; ``verify=True`` (default)
    digest-checks every payload byte on v4+ blobs (structural parse only
    below v4) before returning, raising
    :class:`~repro.core.container.ContainerFormatError` on corruption."""
    with open(os.fspath(path), "rb") as f:
        blob = f.read()
    if verify:
        from repro.codec.integrity import verify_blob

        verify_blob(blob)
    return blob

