"""Salvage decode + blob verification: degraded-but-honest reads.

Raise-mode decoding (the default everywhere) aborts on the first
corruption it can prove. This module is the other half of the v4
integrity contract: :func:`salvage_decompress` quarantines the corrupt
random-access units — latent shards, species' guarantee extents —
decodes everything that still verifies (bitwise equal to the clean
decode of the same selection), fills what it cannot decode with NaN,
and reports exactly what happened in a structured
:class:`DecodeReport`. Nothing is silently wrong: a value is either the
clean decode's value, or NaN with its cause listed in the report.

Fatal (non-quarantinable) corruption still raises even in salvage mode:
the outer container framing and the ``meta`` stream, without which no
output shape or denormalization can be trusted. Corruption of the
shared NN parameter streams (``decoder``/``correction``) or of the
latent stream's head poisons *every* value, so salvage returns an
all-NaN field with every species reported ``missing`` rather than
decoding garbage.

Salvage is cache-isolated by design: it never reads from or writes into
the decode head cache (``runtime._HEADS``), and it evicts the blob's
key on entry — a salvaged parse can never be served later as a clean
head, and a previously cached clean head can never mask corruption the
caller asked salvage to find.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from repro.codec import format as wire
from repro.codec import runtime
from repro.codec.latents import _ChainLatents
from repro.codec.partial import (
    _any_corrections,
    _normalize_species,
    _normalize_time_range,
    _window_rows,
)
from repro.core import blocking, gae
from repro.core import container as container_format
from repro.core.container import ContainerFormatError, ContainerReader


@dataclasses.dataclass(frozen=True)
class IntegrityFailure:
    """One detected corruption, in the same structured vocabulary as
    :class:`ContainerFormatError` (stream / unit / offset)."""

    reason: str
    stream: Optional[str] = None
    unit: Optional[int] = None
    offset: Optional[int] = None

    @classmethod
    def from_error(cls, e: ContainerFormatError) -> "IntegrityFailure":
        return cls(reason=str(e), stream=e.stream, unit=e.unit,
                   offset=e.offset)


@dataclasses.dataclass
class SpeciesReport:
    """Per-species outcome of a salvage decode.

    ``status`` is one of:

    * ``"verified"`` — every byte feeding this species digest-checked
      (v4); ``nrmse_bound`` carries the achieved error bound
      (``tau / sqrt(D)``, the per-block guarantee in NRMSE units);
    * ``"unverified"`` — decoded clean but the container carries no
      digests (v1–v3) or its integrity stream was itself corrupt;
    * ``"salvaged"`` — decoded, but some time block-groups were lost to
      corrupt latent shards: ``damaged_frames`` lists the NaN-filled
      half-open frame ranges (healthy frames are bitwise clean);
    * ``"missing"`` — nothing trustworthy could be decoded (the species'
      guarantee extent was corrupt, or a shared stream was): all-NaN.
    """

    status: str
    nrmse_bound: Optional[float] = None
    damaged_frames: list = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class DecodeReport:
    """Structured result of a salvage decode.

    ``integrity`` is True when the container carried v4 digests and they
    were usable (self-consistent), i.e. every non-quarantined value was
    positively verified rather than merely parseable. ``species`` maps
    each *selected* absolute species index to its
    :class:`SpeciesReport`; ``failures`` lists every digest/parse
    failure encountered, most specific context first.
    """

    version: int
    integrity: bool
    failures: list
    species: dict

    @property
    def ok(self) -> bool:
        """True iff nothing was corrupt (the field equals a clean decode)."""
        return not self.failures

    @property
    def quarantined(self) -> list:
        """Selected species that came back all-NaN (status ``missing``)."""
        return sorted(s for s, r in self.species.items()
                      if r.status == "missing")


def verify_blob(blob: bytes) -> int:
    """Structurally parse + (v4) digest-check every payload byte of a blob.

    One pass: outer framing, then on v4 the integrity stream's
    self-check, the outer-header digest, and every sibling stream's
    whole-payload digest — together these cover 100% of the blob's
    bytes. Raises :class:`ContainerFormatError` on any mismatch; returns
    the container version. v1–v3 blobs get the structural parse only
    (they carry no digests to check)."""
    r = ContainerReader(blob)
    if r.version >= container_format.FORMAT_VERSION_INTEGRITY:
        integ = wire.IntegrityDirectory(r["integrity"])
        integ.verify_outer(bytes(blob), r.header_bytes)
        for name in r.names:
            if name != "integrity":
                integ.verify_stream(name, r[name])
    return r.version


def _salvage_head(blob: bytes, failures: list):
    """Best-effort head parse for salvage: returns ``(head, fatal)``.

    A corrupt integrity stream downgrades to a structural (v3-style)
    parse — recorded in ``failures``, never fatal by itself. Corruption
    of the shared decoder/correction/latent head regions is *fatal for
    values* (head is None) but still reportable; anything the outer
    framing or meta stream is at fault for re-raises."""
    check = True
    while True:
        try:
            return runtime._decode_head(blob, check_integrity=check), None
        except ContainerFormatError as e:
            if check and e.stream == "integrity":
                # digests unusable: fall back to the structural parse the
                # same bytes would get as a v3 container
                failures.append(IntegrityFailure.from_error(e))
                check = False
                continue
            if e.stream in ("decoder", "correction", "latent"):
                failures.append(IntegrityFailure.from_error(e))
                return None, e
            raise


def salvage_decompress(blob: bytes, *, species=None, time_range=None):
    """Decode as much of a (possibly corrupt) blob as can be trusted.

    Returns ``(field, report)``: ``field`` shaped exactly like the
    corresponding raise-mode ``decompress(blob, species=...,
    time_range=...)`` output, with every value either bitwise equal to
    the clean decode or NaN; ``report`` a :class:`DecodeReport` saying
    which. On a clean blob the field is bitwise identical to the
    raise-mode decode and ``report.ok`` is True.

    Raises only when nothing honest can be produced at all: malformed
    outer framing, or a corrupt ``meta`` stream (v4 proves it; below v4
    an unparseable one), since shape and denormalization would be
    untrustworthy. See the module docstring for the quarantine rules.
    """
    blob = bytes(blob)
    # cache isolation: never serve salvage from (or leave state in) the
    # clean-head cache
    runtime._evict_head(blob)
    failures: list = []
    head, fatal = _salvage_head(blob, failures)

    if head is None:
        # shared NN/latent state is gone: report shape from the (already
        # validated) meta stream and return an all-NaN field
        r = ContainerReader(blob)
        cfg, shape, _, _, _ = wire._unpack_meta(r["meta"], version=r.version)
        s, t, h, w = shape
        idx, squeeze = _normalize_species(species, s)
        t0, t1 = _normalize_time_range(time_range, t)
        out = np.full((len(idx), t1 - t0, h, w), np.nan, np.float32)
        report = DecodeReport(
            version=r.version,
            integrity=(
                r.version >= container_format.FORMAT_VERSION_INTEGRITY
                and not any(f.stream == "integrity" for f in failures)
            ),
            failures=failures,
            species={i: SpeciesReport(status="missing") for i in idx},
        )
        return (out[0] if squeeze else out), report

    s, t, h, w = head.shape
    idx, squeeze = _normalize_species(species, s)
    t0, t1 = _normalize_time_range(time_range, t)
    geom = head.cfg.geometry
    tg0, tg1, b0, b1 = _window_rows(head, t0, t1)
    per_frame = (h // geom.ph) * (w // geom.pw)
    verified = head.integrity is not None

    # --- latents: decode healthy shards, quarantine the rest -------------
    rows, bad_shards = head.latents.salvage_rows(b0, b1)
    for k, _, _, e in bad_shards:
        failures.append(IntegrityFailure.from_error(e))
    lat32 = runtime._latents32(rows, head.latent_bin)
    vecs_dev = runtime._fused_vecs(
        head.runtime, head.ae_params, head.corr_params, lat32
    )

    # --- guarantees: per-species quarantine ------------------------------
    # the artifact-wide replay gate and the directory must parse for ANY
    # species' corrections to be locatable; if they don't, no species can
    # honestly replay -> everything selected is missing
    try:
        any_corr = _any_corrections(head)
    except ContainerFormatError as e:
        failures.append(IntegrityFailure.from_error(e))
        out = np.full((len(idx), t1 - t0, h, w), np.nan, np.float32)
        report = DecodeReport(
            version=head.version, integrity=verified, failures=failures,
            species={i: SpeciesReport(status="missing") for i in idx},
        )
        return (out[0] if squeeze else out), report

    arts = []
    quarantined = set()
    for i in idx:
        try:
            arts.append(runtime._species_guarantee(head, i))
        except ContainerFormatError as e:
            failures.append(IntegrityFailure.from_error(e))
            quarantined.add(i)
            # a shape-compatible stand-in so the batched replay runs; its
            # output rows are overwritten with NaN below
            arts.append(gae.GuaranteeArtifact.empty(
                nb=head.nb, d=geom.block_size, tau=0.0
            ))

    # --- replay + finalize: the exact PartialDecoder pipeline ------------
    import jax.numpy as jnp

    vecs_sel = jnp.asarray(vecs_dev)[np.asarray(idx)]
    if any_corr:
        engine = gae.default_engine()
        dense, basis = engine.dense_corrections(
            arts, (len(idx), b1 - b0, geom.block_size),
            block_range=(b0, b1),
        )
        vecs_sel = engine.apply_device(
            vecs_sel, jnp.asarray(dense), jnp.asarray(basis)
        )
    vecs_np = np.asarray(vecs_sel)
    # quarantined latent shards: NaN exactly the damaged block rows (the
    # AE decodes all species jointly per block, so damage is species-wide)
    if bad_shards:
        vecs_np = vecs_np.copy()
        for _, r_lo, r_hi, _ in bad_shards:
            vecs_np[:, r_lo - b0 : r_hi - b0] = np.nan
    rec_blocks = blocking.vectors_as_blocks(vecs_np, geom)
    sub_shape = (len(idx), (tg1 - tg0) * geom.bt, h, w)
    rec_normed = blocking.from_blocks(rec_blocks, sub_shape, geom)
    out = (
        rec_normed * head.norm_range[idx][:, None, None, None]
        + head.norm_min[idx][:, None, None, None]
    ).astype(np.float32)
    out = out[:, t0 - tg0 * geom.bt : t1 - tg0 * geom.bt]

    # --- per-species verdicts --------------------------------------------
    damaged_frames = _merge_frame_ranges(
        bad_shards, per_frame, geom.bt, t0, t1
    )
    species_reports: dict = {}
    for pos, i in enumerate(idx):
        if i in quarantined:
            out[pos] = np.nan
            species_reports[i] = SpeciesReport(status="missing")
        elif damaged_frames:
            species_reports[i] = SpeciesReport(
                status="salvaged", damaged_frames=list(damaged_frames)
            )
        elif verified:
            species_reports[i] = SpeciesReport(
                status="verified",
                nrmse_bound=arts[pos].tau / math.sqrt(geom.block_size),
            )
        else:
            species_reports[i] = SpeciesReport(status="unverified")

    report = DecodeReport(
        version=head.version, integrity=verified, failures=failures,
        species=species_reports,
    )
    return (out[0] if squeeze else out), report


def _merge_frame_ranges(bad_shards, per_frame: int, bt: int,
                        t0: int, t1: int) -> list:
    """Quarantined block rows -> merged half-open damaged frame ranges
    (clipped to the requested window). Block rows are time-major, so a
    damaged row maps to the time block-group ``row // per_frame`` and
    from there to ``bt`` frames."""
    frames = set()
    for _, r_lo, r_hi, _ in bad_shards:
        for tg in range(r_lo // per_frame, -(-r_hi // per_frame)):
            for f in range(max(tg * bt, t0), min((tg + 1) * bt, t1)):
                frames.add(f)
    if not frames:
        return []
    ordered = sorted(frames)
    ranges = []
    lo = prev = ordered[0]
    for f in ordered[1:]:
        if f != prev + 1:
            ranges.append((lo, prev + 1))
            lo = f
        prev = f
    ranges.append((lo, prev + 1))
    return ranges
