"""Public codec API: GBATC as *bytes in, bytes out* (the paper's claim, made
literal).

The paper reports two-orders-of-magnitude reduction; this module is where
the repo actually produces those bytes. :class:`GBATCCodec` wraps the
fit/compress orchestration and returns a **self-describing container blob**;
module-level :func:`decompress` reconstructs the field from the blob alone —
no fitted pipeline, no original data, no config object. A fresh process can
decode a container because everything the decoder needs travels in it:

==============  ====================================================
stream          payload
==============  ====================================================
``meta``        geometry, AE structure, shape, latent bin, per-species
                normalization (min/range) — fixed-layout struct
``latent``      Huffman-coded quantized latents
``decoder``     AE decoder parameters, packed fp32/fp16 little-endian
                in deterministic (sorted-path) leaf order
``correction``  tensor-correction network parameters (GBATC only)
``guarantee``   (container v2) ONE combined CSR-of-CSR stream for all
                species: a fixed-layout directory (per species: tau,
                coeff bin, basis dims, and the byte lengths of its
                coeff/index/basis payloads) followed by the
                type-grouped payloads. Every species' byte extent is
                addressable from the directory alone — the basis of
                the random-access decode path.
``guarantee<s>``  (container v1, still read) per-species
                :class:`~repro.core.gae.GuaranteeArtifact` as a nested
                container: Huffman'd quantized coefficients, Fig. 2
                CSR index bitmap, trimmed fp32 PCA basis, tau/bin
==============  ====================================================

Selective decode: ``decompress(blob, species=..., time_range=...)`` (or a
reusable :class:`PartialDecoder`) parses only the header plus the
requested streams — the selected species' coefficient streams
entropy-decode in one lockstep walk, the fused jit decode runs on only
the block rows covering the time window, and only the selected species'
corrections replay through the Pallas kernel. The selective output is
bitwise equal to slicing the full decode, v1 blobs decode through the
same entry points unchanged, and a full-field v2 decode equals the v1
decode byte for byte.

Byte accounting is a *view over the container's stream table*
(:func:`stream_breakdown`), so ``breakdown["total"] == len(blob)`` holds
exactly — the seed's ``8*S + 64`` metadata guess is gone. Decoding state
(model instances, jitted callables, Huffman decode tables) is cached per
structural signature, so repeated ``decompress`` calls never re-trace.

Decode is organized as a device-resident hot path: the container head
(meta, latents, parameters) parses first and one fused jit — dequantized
latents through the AE decoder, pointwise correction, and the
blocks→vectors layout change — is dispatched asynchronously; the
per-species guarantee streams entropy-decode (batched lockstep chain
walks, memoized tables) while it runs, and a single batched Pallas replay
applies the corrections. The seed's staged orchestration is retained as
``reconstruct_reference`` / ``decompress_reference`` — the fused path must
match it **bit for bit** (asserted in tests and gating
``benchmarks/bench_throughput.py``).

``GBATCPipeline.compress/decompress`` remain as thin compatibility wrappers
over this module (see :mod:`repro.core.pipeline`).
"""

from __future__ import annotations

import dataclasses
import struct
from typing import Any, Optional

import numpy as np

from repro.core import autoencoder as ae
from repro.core import blocking, correction, entropy, gae
from repro.core import container as container_format
from repro.core.container import (
    ContainerFormatError,
    ContainerReader,
    ContainerWriter,
)
from repro.core.pipeline import (
    CompressedArtifact,
    CompressionReport,
    GBATCPipeline,
    PipelineConfig,
    _batched,
)
from repro.core.quantization import dequantize, param_storage_dtype
from repro.nn import module as nn_module

__all__ = [
    "GBATCCodec",
    "ContainerFormatError",
    "GuaranteeDirectory",
    "PartialDecoder",
    "encode",
    "pack_guarantee_stream",
    "decode_artifact",
    "decode_artifact_reference",
    "decompress",
    "decompress_reference",
    "reconstruct",
    "reconstruct_reference",
    "make_fused_decode",
    "stream_breakdown",
]

_FLAG_CORRECTION = 1

# flags, param_dtype_bytes, latent, bt, ph, pw, n_conv
_META_HEAD = struct.Struct("<BBHHHHH")
_META_SHAPE = struct.Struct("<IIIId")  # S, T, H, W, latent_bin


# ---------------------------------------------------------------------------
# parameter-tree packing: raw little-endian leaves, deterministic order
# ---------------------------------------------------------------------------
def _sorted_leaves(tree):
    """Depth-first leaves of a nested-dict pytree, keys sorted at every level
    (the same order as :func:`repro.nn.module._walk` over the defs tree)."""
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _sorted_leaves(tree[k])
    else:
        yield tree


def pack_params(tree, param_dtype_bytes: int) -> bytes:
    """Concatenate pytree leaves as raw storage-dtype bytes, no framing.

    The tree structure is fully derivable from the pipeline config, so the
    stream carries *only* parameter values — its length is exactly the
    byte count the paper's accounting charges for the decoder/correction
    networks.
    """
    dtype = param_storage_dtype(param_dtype_bytes)
    return b"".join(
        np.ascontiguousarray(np.asarray(leaf)).astype(dtype).tobytes()
        for leaf in _sorted_leaves(tree)
    )


def unpack_params(buf: bytes, defs, param_dtype_bytes: int):
    """Inverse of :func:`pack_params` given the matching definition tree."""
    dtype = param_storage_dtype(param_dtype_bytes)
    walk = list(nn_module._walk(defs))
    expected = sum(
        int(np.prod(p.shape)) * dtype.itemsize for _, p in walk
    )
    if len(buf) != expected:
        raise ContainerFormatError(
            f"parameter stream is {len(buf)} bytes, expected {expected}"
        )
    out: dict = {}
    off = 0
    for path, p in walk:
        n = int(np.prod(p.shape))
        leaf = (
            np.frombuffer(buf, dtype=dtype, count=n, offset=off)
            .astype(np.float32)
            .reshape(p.shape)
        )
        off += n * dtype.itemsize
        node = out
        for key in path[:-1]:
            node = node.setdefault(key, {})
        node[path[-1]] = leaf
    return out


def _decoder_defs(model: ae.BlockAutoencoder):
    return {k: v for k, v in model.defs.items() if k.startswith("dec")}


def pack_artifact_params(
    ae_params, corr_params, param_dtype_bytes: int
) -> tuple[bytes, Optional[bytes]]:
    """Packed (decoder, correction) wire streams — the single source for
    the decoder-key filter and tuple layout (correction is None when the
    artifact carries no correction network)."""
    dec = {k: v for k, v in ae_params.items() if k.startswith("dec")}
    return (
        pack_params(dec, param_dtype_bytes),
        pack_params(corr_params, param_dtype_bytes)
        if corr_params is not None
        else None,
    )


# ---------------------------------------------------------------------------
# meta stream
# ---------------------------------------------------------------------------
def _pack_meta(artifact: CompressedArtifact) -> bytes:
    cfg = artifact.cfg
    geom = cfg.geometry
    flags = _FLAG_CORRECTION if artifact.corr_params is not None else 0
    u16_fields = {
        "latent": cfg.latent,
        "bt": geom.bt,
        "ph": geom.ph,
        "pw": geom.pw,
        **{f"conv_channels[{i}]": c for i, c in enumerate(cfg.conv_channels)},
    }
    bad = {k: v for k, v in u16_fields.items() if not 0 < v <= 0xFFFF}
    if bad:
        raise ValueError(f"meta fields not representable as u16: {bad}")
    parts = [
        _META_HEAD.pack(
            flags,
            cfg.param_dtype_bytes,
            cfg.latent,
            geom.bt,
            geom.ph,
            geom.pw,
            len(cfg.conv_channels),
        ),
        np.asarray(cfg.conv_channels, dtype="<u2").tobytes(),
        _META_SHAPE.pack(*artifact.shape, artifact.latent_bin),
        np.ascontiguousarray(artifact.norm_min.astype("<f4")).tobytes(),
        np.ascontiguousarray(artifact.norm_range.astype("<f4")).tobytes(),
    ]
    return b"".join(parts)


def _unpack_meta(buf: bytes):
    if len(buf) < _META_HEAD.size:
        raise ContainerFormatError("meta stream truncated")
    flags, pdb, latent, bt, ph, pw, n_conv = _META_HEAD.unpack_from(buf, 0)
    if flags & ~_FLAG_CORRECTION:
        # unknown flag bits mean a newer writer (or corruption) — refuse
        # rather than decode under old-flag semantics
        raise ContainerFormatError(f"unknown meta flags 0x{flags:02x}")
    off = _META_HEAD.size
    if len(buf) < off + 2 * n_conv + _META_SHAPE.size:
        raise ContainerFormatError("meta stream truncated")
    conv = tuple(
        int(c) for c in np.frombuffer(buf, dtype="<u2", count=n_conv, offset=off)
    )
    off += 2 * n_conv
    s, t, h, w, latent_bin = _META_SHAPE.unpack_from(buf, off)
    off += _META_SHAPE.size
    if len(buf) != off + 8 * s:
        raise ContainerFormatError(
            f"meta stream is {len(buf)} bytes, expected {off + 8 * s} "
            f"for {s} species"
        )
    if pdb not in (2, 4):
        raise ContainerFormatError(f"bad param dtype byte {pdb} (expected 2 or 4)")
    if min(bt, ph, pw, latent, n_conv, s, t, h, w) < 1 or min(conv) < 1:
        raise ContainerFormatError(
            f"meta stream carries degenerate structure: geometry "
            f"({bt},{ph},{pw}), latent {latent}, conv {conv}, shape "
            f"({s},{t},{h},{w})"
        )
    norm_min = np.frombuffer(buf, dtype="<f4", count=s, offset=off).copy()
    norm_range = np.frombuffer(buf, dtype="<f4", count=s, offset=off + 4 * s).copy()
    if not (np.isfinite(latent_bin) and latent_bin > 0):
        raise ContainerFormatError(f"bad latent bin {latent_bin!r}")
    if not (
        np.isfinite(norm_min).all()
        and np.isfinite(norm_range).all()
        and (norm_range > 0).all()
    ):
        raise ContainerFormatError("non-finite or non-positive normalization")
    cfg = PipelineConfig(
        geometry=blocking.BlockGeometry(bt=bt, ph=ph, pw=pw),
        latent=latent,
        conv_channels=conv,
        use_correction=bool(flags & _FLAG_CORRECTION),
        param_dtype_bytes=pdb,
    )
    return cfg, (s, t, h, w), float(latent_bin), norm_min, norm_range


# ---------------------------------------------------------------------------
# combined guarantee stream (container v2): CSR-of-CSR over species
# ---------------------------------------------------------------------------
_GDIR_HEAD = struct.Struct("<I")  # species count
# per species: tau f64, coeff_bin f64, D u32, n_store u32,
#              coeff_len u64, index_len u64, basis_len u64
_GDIR_REC = struct.Struct("<ddIIQQQ")


def pack_guarantee_stream(arts) -> bytes:
    """Pack all species' guarantee artifacts into ONE combined stream.

    Layout: ``S u32 | S x directory record | coeff payloads | index
    payloads | basis payloads`` — the outer offset table (directory) over
    species plus type-grouped sub-streams. Per-species framing collapses
    from a nested container (~60 bytes of magic/table per species) to one
    fixed 48-byte record, and every species' byte extents follow from the
    directory by prefix sums, so a reader can slice one species without
    parsing any sibling payload.
    """
    parts = [_GDIR_HEAD.pack(len(arts))]
    coeffs: list[bytes] = []
    indexes: list[bytes] = []
    bases: list[bytes] = []
    for g in arts:
        c, i, b = g.wire_parts()
        parts.append(
            _GDIR_REC.pack(g.tau, g.coeff_bin, *g.basis.shape,
                           len(c), len(i), len(b))
        )
        coeffs.append(c)
        indexes.append(i)
        bases.append(b)
    return b"".join(parts + coeffs + indexes + bases)


class GuaranteeDirectory:
    """Parsed directory of a combined v2 ``guarantee`` stream.

    Holds the per-species metadata and byte extents; payload access is
    pure slicing — no sibling species' stream is ever parsed to reach
    another's. Raises :class:`ContainerFormatError` when the directory
    and the payload bytes disagree.
    """

    def __init__(self, payload: bytes):
        payload = bytes(payload)
        if len(payload) < _GDIR_HEAD.size:
            raise ContainerFormatError(
                "guarantee stream truncated: no species directory"
            )
        (s,) = _GDIR_HEAD.unpack_from(payload, 0)
        dir_end = _GDIR_HEAD.size + s * _GDIR_REC.size
        if len(payload) < dir_end:
            raise ContainerFormatError(
                f"guarantee directory truncated: {len(payload)} bytes "
                f"cannot hold {s} species records"
            )
        recs = list(_GDIR_REC.iter_unpack(payload[_GDIR_HEAD.size:dir_end]))
        self._meta = [(r[0], r[1], r[2], r[3]) for r in recs]
        coeff_lens = [r[4] for r in recs]
        index_lens = [r[5] for r in recs]
        basis_lens = [r[6] for r in recs]
        # per-type payload offsets by prefix sum (python ints: a corrupt
        # u64 length must overflow into a clean mismatch, not wrap)
        off = dir_end
        self._extents: list[list[tuple[int, int]]] = []
        for lens in (coeff_lens, index_lens, basis_lens):
            spans = []
            for ln in lens:
                spans.append((off, off + ln))
                off += ln
            self._extents.append(spans)
        if off != len(payload):
            raise ContainerFormatError(
                f"guarantee stream is {len(payload)} bytes but its "
                f"directory declares {off}"
            )
        self.dir_bytes = dir_end
        self.coeff_total = sum(coeff_lens)
        self.index_total = sum(index_lens)
        self.basis_total = sum(basis_lens)
        self._payload = payload

    @property
    def n_species(self) -> int:
        return len(self._meta)

    def _slice(self, kind: int, sidx: int) -> bytes:
        lo, hi = self._extents[kind][sidx]
        return self._payload[lo:hi]

    def coeff_stream(self, sidx: int) -> bytes:
        return self._slice(0, sidx)

    def coeff_len(self, sidx: int) -> int:
        lo, hi = self._extents[0][sidx]
        return hi - lo

    def species_parts(self, sidx: int):
        """(tau, coeff_bin, d, n_store, coeff, index, basis) for one species."""
        return (*self._meta[sidx], self._slice(0, sidx),
                self._slice(1, sidx), self._slice(2, sidx))

    def species_extent_bytes(self, sidx: int) -> int:
        """Payload bytes one species' decode touches (coeff+index+basis)."""
        return sum(hi - lo for lo, hi in
                   (self._extents[k][sidx] for k in range(3)))


# ---------------------------------------------------------------------------
# encode / decode
# ---------------------------------------------------------------------------
def encode(artifact: CompressedArtifact,
           version: int = container_format.FORMAT_VERSION_SELECTIVE) -> bytes:
    """Serialize a :class:`CompressedArtifact` into a container blob.

    ``version`` selects the guarantee layout: 2 (default) writes the
    combined CSR-of-CSR ``guarantee`` stream; 1 writes the original
    per-species nested containers (byte-stable with earlier releases —
    kept so back-compat round-trips stay testable).
    """
    cfg = artifact.cfg
    if version not in container_format.SUPPORTED_VERSIONS:
        raise ValueError(f"unknown container version {version}")
    w = ContainerWriter(version=version)
    w.add("meta", _pack_meta(artifact))
    w.add("latent", artifact.latent_blob())
    packed = artifact._param_streams
    if packed is None:
        packed = pack_artifact_params(
            artifact.ae_params, artifact.corr_params, cfg.param_dtype_bytes
        )
    w.add("decoder", packed[0])
    if artifact.corr_params is not None:
        w.add("correction", packed[1])
    if version == container_format.FORMAT_VERSION_SELECTIVE:
        w.add("guarantee", pack_guarantee_stream(artifact.species_guarantees))
    else:
        for sidx, g in enumerate(artifact.species_guarantees):
            w.add(f"guarantee{sidx}", g.to_bytes())
    return w.to_bytes()


@dataclasses.dataclass
class _DecodedHead:
    """Everything the NN decode needs, parsed before guarantee streams."""

    reader: ContainerReader
    blob: bytes
    cfg: PipelineConfig
    shape: tuple[int, int, int, int]
    nb: int
    latent_bin: float
    norm_min: np.ndarray
    norm_range: np.ndarray
    latent_q: np.ndarray
    latent_stream: bytes
    ae_params: Any
    corr_params: Any
    runtime: _DecodeRuntime
    version: int = container_format.FORMAT_VERSION
    # lazily parsed v2 guarantee directory (see _gdir)
    gdir: Optional[GuaranteeDirectory] = None
    # memoized artifact-wide "any species has corrections" bit (see
    # _any_corrections; a pure function of the blob, v1 recompute copies
    # every species' payload)
    any_corrections: Optional[bool] = None


def _decode_head(blob: bytes, *, huffman=None) -> _DecodedHead:
    """Parse/validate the container head: meta, stream set, latents,
    network parameters — everything except the guarantee streams, so the
    fused NN decode can be dispatched while those entropy-decode.
    ``huffman`` overrides the latent decoder (reference path)."""
    r = ContainerReader(blob)
    cfg, shape, latent_bin, norm_min, norm_range = _unpack_meta(r["meta"])
    if cfg.use_correction != ("correction" in r):
        # a flipped correction flag must not silently decode without the
        # shipped network (or with a phantom one)
        raise ContainerFormatError(
            f"meta correction flag is {cfg.use_correction} but the "
            f"container {'carries' if 'correction' in r else 'lacks'} a "
            f"correction stream"
        )
    s, t, h, w = shape
    geom = cfg.geometry
    if t % geom.bt or h % geom.ph or w % geom.pw:
        raise ContainerFormatError(
            f"shape {shape} not divisible by block geometry "
            f"({geom.bt}, {geom.ph}, {geom.pw})"
        )
    nb = (t // geom.bt) * (h // geom.ph) * (w // geom.pw)

    expected_streams = {"meta", "latent", "decoder"}
    if cfg.use_correction:
        expected_streams.add("correction")
    if r.version == container_format.FORMAT_VERSION_SELECTIVE:
        expected_streams.add("guarantee")
    else:
        expected_streams.update(f"guarantee{sidx}" for sidx in range(s))
    if set(r.names) != expected_streams:
        # strictness: every stream must be accounted for by purpose — no
        # stray payloads hiding in the blob, no silently absent streams
        raise ContainerFormatError(
            f"unexpected stream set {sorted(r.names)} "
            f"(expected {sorted(expected_streams)})"
        )

    # the runtime cache is the single construction site for the decode
    # models — decode_artifact and reconstruct cannot drift apart
    rt = _runtime(cfg, s, cfg.use_correction)
    latent_stream = r["latent"]
    try:
        if huffman is None:
            latent_q = entropy.huffman_decode(
                latent_stream, table_cache=rt.table_cache
            )
        else:
            latent_q = huffman(latent_stream)
    except (ValueError, struct.error) as e:
        # struct.error: a truncated Huffman header (not a ValueError)
        raise ContainerFormatError(f"corrupt latent stream: {e}") from e
    if latent_q.size != nb * cfg.latent:
        raise ContainerFormatError(
            f"latent stream decodes to {latent_q.size} symbols, "
            f"expected {nb * cfg.latent}"
        )
    latent_q = latent_q.reshape(nb, cfg.latent)

    ae_params = unpack_params(r["decoder"], _decoder_defs(rt.model),
                              cfg.param_dtype_bytes)
    corr_params = None
    if cfg.use_correction:
        corr_params = unpack_params(r["correction"], rt.corr_net.defs,
                                    cfg.param_dtype_bytes)
    return _DecodedHead(
        reader=r, blob=bytes(blob), cfg=cfg, shape=shape, nb=nb,
        latent_bin=latent_bin, norm_min=norm_min, norm_range=norm_range,
        latent_q=latent_q, latent_stream=latent_stream,
        ae_params=ae_params, corr_params=corr_params, runtime=rt,
        version=r.version,
    )


def _gdir(head: _DecodedHead) -> GuaranteeDirectory:
    """Parse (once) the combined v2 guarantee stream's directory."""
    if head.gdir is None:
        gdir = GuaranteeDirectory(head.reader["guarantee"])
        if gdir.n_species != head.shape[0]:
            raise ContainerFormatError(
                f"guarantee directory covers {gdir.n_species} species, "
                f"meta stream declares {head.shape[0]}"
            )
        head.gdir = gdir
    return head.gdir


def _coeff_streams(head: _DecodedHead, indices) -> "Optional[list[bytes]]":
    """Selected species' coefficient payloads, sliced without parsing any
    sibling payload; ``None`` when the per-species framing cannot be
    pre-parsed (the per-species path then surfaces the canonical error)."""
    if head.version == container_format.FORMAT_VERSION_SELECTIVE:
        gdir = _gdir(head)
        return [gdir.coeff_stream(sidx) for sidx in indices]
    try:
        return [
            ContainerReader(head.reader[f"guarantee{sidx}"])["coeff"]
            for sidx in indices
        ]
    except (ContainerFormatError, KeyError):
        return None


def _species_guarantee(
    head: _DecodedHead, sidx: int, *, huffman=None, coeff_q=None
) -> gae.GuaranteeArtifact:
    """Parse + validate ONE species' guarantee artifact (either layout).

    Touches only that species' streams, so a corrupt sibling cannot poison
    it; errors carry the species index. ``coeff_q`` injects pre-decoded
    coefficient symbols from the batched lockstep walk."""
    cache = head.runtime.table_cache
    try:
        if head.version == container_format.FORMAT_VERSION_SELECTIVE:
            tau, coeff_bin, d, n_store, coeff, index, basis = \
                _gdir(head).species_parts(sidx)
            g = gae.GuaranteeArtifact.from_parts(
                tau, coeff_bin, d, n_store, coeff, index, basis,
                table_cache=cache, huffman=huffman, coeff_q=coeff_q,
            )
        else:
            if coeff_q is not None:
                huffman = lambda _blob, _out=coeff_q: _out  # noqa: E731
            g = gae.GuaranteeArtifact.from_bytes(
                head.reader[f"guarantee{sidx}"],
                table_cache=cache, huffman=huffman,
            )
    except ContainerFormatError as e:
        raise ContainerFormatError(f"guarantee stream {sidx}: {e}") from e
    if g.n_blocks != head.nb:
        raise ContainerFormatError(
            f"guarantee stream {sidx} covers {g.n_blocks} blocks, "
            f"expected {head.nb}"
        )
    if g.basis.shape[0] != head.cfg.geometry.block_size:
        raise ContainerFormatError(
            f"guarantee stream {sidx} basis has dimension "
            f"{g.basis.shape[0]}, expected block size "
            f"{head.cfg.geometry.block_size}"
        )
    return g


def _decode_species_guarantees(
    head: _DecodedHead, indices: "list[int]", *, huffman=None
) -> list:
    """Entropy-decode the guarantee streams of ``indices`` only.

    The selected coefficient streams decode in one lockstep chunk-parallel
    chain walk (:func:`entropy.huffman_decode_many`) with codebook tables
    served from the runtime cache; per-species parsing/validation then
    consumes the pre-decoded symbols. When the batch walk cannot read a
    stream, every species re-parses individually so the canonical
    per-species ContainerFormatError surfaces (and healthy siblings are
    still decodable)."""
    coeffs: "Optional[list]" = None
    if huffman is None and len(indices) > 1:
        streams = _coeff_streams(head, indices)
        if streams is not None:
            try:
                coeffs = entropy.huffman_decode_many(
                    streams, table_cache=head.runtime.table_cache
                )
            except (ValueError, struct.error):
                coeffs = None  # per-species path raises the canonical error
    return [
        _species_guarantee(
            head, sidx, huffman=huffman,
            coeff_q=None if coeffs is None else coeffs[k],
        )
        for k, sidx in enumerate(indices)
    ]


def _decode_guarantees(head: _DecodedHead, *, huffman=None) -> list:
    """Entropy-decode every species' guarantee stream (full decode)."""
    return _decode_species_guarantees(
        head, list(range(head.shape[0])), huffman=huffman
    )


def _finish_artifact(head: _DecodedHead, *,
                     huffman=None) -> CompressedArtifact:
    return CompressedArtifact(
        latent_q=head.latent_q,
        latent_bin=head.latent_bin,
        ae_params=head.ae_params,
        corr_params=head.corr_params,
        species_guarantees=_decode_guarantees(head, huffman=huffman),
        norm_min=head.norm_min,
        norm_range=head.norm_range,
        shape=head.shape,
        cfg=head.cfg,
        _latent_blob=head.latent_stream,
        _wire=head.blob,
    )


def decode_artifact(blob: bytes) -> CompressedArtifact:
    """Rebuild a :class:`CompressedArtifact` from a container blob alone.

    The returned artifact carries only what the wire format does: the AE
    *decoder* parameters (the encoder never ships), the correction network
    if present, and the per-species guarantee streams (entropy-decoded
    species-parallel, decode tables memoized per codebook).
    """
    return _finish_artifact(_decode_head(blob))


def decode_artifact_reference(blob: bytes) -> CompressedArtifact:
    """Pre-change deserialize, retained as the throughput baseline:
    sequential per-species guarantee decode with per-call table builds and
    the reference per-code-bit window pass. Bitwise the same artifact as
    :func:`decode_artifact`."""
    return _finish_artifact(
        _decode_head(blob, huffman=entropy.huffman_decode_ref),
        huffman=entropy.huffman_decode_ref,
    )


def stream_breakdown(blob: bytes) -> dict:
    """Byte breakdown as a view over the container's measured stream lengths.

    ``latent/decoder/correction/coeff/index/basis`` are payload bytes;
    ``meta`` is everything else that is really on the wire — the outer
    header + stream table, the meta stream, and the nested guarantee
    containers' framing — so the parts always sum to ``len(blob)`` exactly.
    """
    r = ContainerReader(blob)
    sizes = r.stream_sizes()
    coeff = index = basis = 0
    if r.version == container_format.FORMAT_VERSION_SELECTIVE:
        if "guarantee" in r:
            gdir = GuaranteeDirectory(r["guarantee"])
            coeff, index, basis = (
                gdir.coeff_total, gdir.index_total, gdir.basis_total
            )
    else:
        for name in sizes:
            if name.startswith("guarantee"):
                sub = ContainerReader(r[name]).stream_sizes()
                coeff += sub.get("coeff", 0)
                index += sub.get("index", 0)
                basis += sub.get("basis", 0)
    out = {
        "latent": sizes.get("latent", 0),
        "decoder": sizes.get("decoder", 0),
        "correction": sizes.get("correction", 0),
        "coeff": coeff,
        "index": index,
        "basis": basis,
    }
    out["meta"] = r.total_bytes - sum(out.values())
    out["total"] = r.total_bytes
    return out


# ---------------------------------------------------------------------------
# decode runtime (cached per structural signature; never re-traces)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _DecodeRuntime:
    model: ae.BlockAutoencoder
    corr_net: Optional[correction.TensorCorrectionNetwork]
    jit_decode: Any
    jit_corr: Any
    # fused device-resident hot path: dequantized latents -> AE decode ->
    # pointwise correction -> (S, NB, D) block vectors, one dispatch
    jit_fused: Any
    # per-runtime Huffman decode-table memo (codebooks repeat across calls)
    table_cache: entropy.DecodeTableCache


_RUNTIMES: dict[tuple, _DecodeRuntime] = {}
_RUNTIMES_REF: dict[tuple, _DecodeRuntime] = {}
_RUNTIMES_MAX = 8


def _runtime_key(cfg: PipelineConfig, n_species: int, has_corr: bool) -> tuple:
    geom = cfg.geometry
    return (
        n_species,
        (geom.bt, geom.ph, geom.pw),
        cfg.latent,
        tuple(cfg.conv_channels),
        has_corr,
    )


def make_fused_decode(model: ae.BlockAutoencoder,
                      corr_net: Optional[correction.TensorCorrectionNetwork]):
    """Traceable latents -> corrected (S, NB, D) block vectors.

    The whole NN decode — AE decoder, pointwise tensor correction, and the
    blocks->vectors layout change — as one function of device arrays, so a
    single jit dispatch replaces the seed's chunked host round-trips. All
    reshuffles are pure transposes; per-element arithmetic is identical to
    the staged path (bit-identity asserted in tests and the benchmark).
    """
    s = model.cfg.n_species

    def fused(dec_params, corr_params, lat):
        x = model.decode(dec_params, lat)  # (NB, S, bt, ph, pw)
        nb = x.shape[0]
        if corr_net is not None:
            vec = x.reshape(nb, s, -1).transpose(0, 2, 1).reshape(-1, s)
            vec = corr_net(corr_params, vec)
            x = vec.reshape(nb, -1, s).transpose(0, 2, 1).reshape(x.shape)
        return x.reshape(nb, s, -1).transpose(1, 0, 2)  # (S, NB, D)

    return fused


def _build_runtime(cfg: PipelineConfig, n_species: int, has_corr: bool,
                   conv_impl: str) -> _DecodeRuntime:
    import jax

    geom = cfg.geometry
    model = ae.BlockAutoencoder(
        ae.AEConfig(
            n_species=n_species,
            block=(geom.bt, geom.ph, geom.pw),
            latent=cfg.latent,
            conv_channels=cfg.conv_channels,
            conv_impl=conv_impl,
        )
    )
    corr_net = (
        correction.TensorCorrectionNetwork(
            correction.CorrectionConfig(n_species=n_species)
        )
        if has_corr
        else None
    )
    return _DecodeRuntime(
        model=model,
        corr_net=corr_net,
        jit_decode=jax.jit(model.decode),
        jit_corr=jax.jit(corr_net.__call__) if corr_net is not None else None,
        jit_fused=jax.jit(make_fused_decode(model, corr_net)),
        table_cache=entropy.DecodeTableCache(),
    )


def _cached_runtime(cache: dict, cfg: PipelineConfig, n_species: int,
                    has_corr: bool, conv_impl: str) -> _DecodeRuntime:
    key = _runtime_key(cfg, n_species, has_corr)
    hit = cache.get(key)
    if hit is not None:
        return hit
    rt = _build_runtime(cfg, n_species, has_corr, conv_impl)
    while len(cache) >= _RUNTIMES_MAX:
        cache.pop(next(iter(cache)))
    cache[key] = rt
    return rt


def _runtime(cfg: PipelineConfig, n_species: int,
             has_corr: bool) -> _DecodeRuntime:
    return _cached_runtime(_RUNTIMES, cfg, n_species, has_corr, "2d")


def _runtime_reference(cfg: PipelineConfig, n_species: int,
                       has_corr: bool) -> _DecodeRuntime:
    """Runtime for the retained pre-change decode path: XLA conv impl,
    staged host-chunked orchestration (see :func:`reconstruct_reference`)."""
    return _cached_runtime(_RUNTIMES_REF, cfg, n_species, has_corr, "xla")


def _finalize_field(corrected: np.ndarray, artifact: CompressedArtifact
                    ) -> np.ndarray:
    """(S, NB, D) corrected vectors -> denormalized (S, T, H, W) field.

    Host numpy in both the fused and the reference path: the multiply/add
    stays un-fused (no FMA contraction), keeping the two paths bit-identical.
    """
    geom = artifact.cfg.geometry
    rec_blocks = blocking.vectors_as_blocks(corrected, geom)
    rec_normed = blocking.from_blocks(rec_blocks, artifact.shape, geom)
    return (
        rec_normed * artifact.norm_range[:, None, None, None]
        + artifact.norm_min[:, None, None, None]
    ).astype(np.float32)


def _latents32(artifact) -> np.ndarray:
    """f64 dequantize then one f32 round — exactly the cast the staged path
    performs when the f64 latents enter the jitted decoder. Accepts any
    object with ``latent_q``/``latent_bin`` (artifact or decoded head)."""
    return dequantize(artifact.latent_q, artifact.latent_bin).astype(np.float32)


_FUSED_CHUNK = 4096  # blocks per fused-decode dispatch: bounds peak
# activation memory at paper scale (the quick surrogates fit in one chunk)
# without re-tracing — the tail chunk is padded to the fixed shape


def _fused_vecs(rt: _DecodeRuntime, ae_params, corr_params,
                lat32: np.ndarray):
    """Run the fused NN decode over fixed-size block chunks.

    Dispatches are asynchronous, so callers can overlap host work with the
    whole chunk sequence; results are concatenated on device. Chunking is
    row-wise and therefore bit-transparent.
    """
    import jax.numpy as jnp

    n = lat32.shape[0]
    if n <= _FUSED_CHUNK:
        return rt.jit_fused(ae_params, corr_params, lat32)
    outs = []
    for i in range(0, n, _FUSED_CHUNK):
        chunk = lat32[i : i + _FUSED_CHUNK]
        pad = _FUSED_CHUNK - chunk.shape[0]
        if pad:
            chunk = np.concatenate(
                [chunk, np.repeat(chunk[-1:], pad, axis=0)]
            )
        out = rt.jit_fused(ae_params, corr_params, chunk)
        outs.append(out[:, : out.shape[1] - pad] if pad else out)
    return jnp.concatenate(outs, axis=1)  # (S, NB, D) along blocks


def _apply_guarantees_and_finalize(vecs_dev, artifact: CompressedArtifact
                                   ) -> np.ndarray:
    """Post-dispatch tail of the fused decode: batched guarantee replay on
    the (possibly still in-flight) NN-decoded vectors, then host
    finalization. The single implementation behind both ``reconstruct``
    and ``decompress``."""
    import jax.numpy as jnp

    engine = gae.default_engine()
    arts = artifact.species_guarantees
    if any(a.coeff_q.size for a in arts):
        s, nb, d = vecs_dev.shape
        # host-side CSR scatter overlaps the in-flight async NN decode
        dense, basis = engine.dense_corrections(arts, (s, nb, d))
        vecs_dev = engine.apply_device(
            vecs_dev, jnp.asarray(dense), jnp.asarray(basis)
        )
    return _finalize_field(np.asarray(vecs_dev), artifact)


def _fused_reconstruct(rt: _DecodeRuntime,
                       artifact: CompressedArtifact) -> np.ndarray:
    """The device-resident decode hot path (see :func:`decompress`)."""
    vecs_dev = _fused_vecs(
        rt, artifact.ae_params, artifact.corr_params, _latents32(artifact)
    )
    return _apply_guarantees_and_finalize(vecs_dev, artifact)


def reconstruct(artifact: CompressedArtifact) -> np.ndarray:
    """Decode an in-memory artifact to the full (S, T, H, W) field.

    Derives every structural decision — geometry, AE shape, whether the
    tensor-correction network runs — from the artifact itself, never from
    ambient pipeline state (the seed's config-shadowing hazard). Runs the
    fused device-resident hot path; :func:`reconstruct_reference` retains
    the staged pre-change orchestration as the bit-identity oracle.
    """
    cfg = artifact.cfg
    has_corr = artifact.corr_params is not None
    rt = _runtime(cfg, len(artifact.norm_min), has_corr)
    return _fused_reconstruct(rt, artifact)


def reconstruct_reference(artifact: CompressedArtifact,
                          conv_impl: str = "2d") -> np.ndarray:
    """The seed's decode *orchestration*, retained as baseline and oracle:
    host-chunked ``_batched`` stages with a numpy round-trip between
    dequantize, decoder, correction, and guarantee replay.

    With the default ``conv_impl="2d"`` the staged path shares the fused
    path's layer implementations, and ``reconstruct`` must match it **bit
    for bit** — the gate asserted by the test suite and by
    ``benchmarks/bench_throughput.py`` before any number is reported (it
    proves the hot-path reorganization is semantically transparent).
    ``conv_impl="xla"`` additionally retains the seed's convolution
    lowering — the true pre-change cost profile used as the benchmark's
    timing baseline; its output differs from the 2d formulation only by
    float-summation reassociation inside the convolutions (ulp-level,
    bound-checked in the benchmark)."""
    cfg = artifact.cfg
    has_corr = artifact.corr_params is not None
    builder = _runtime if conv_impl == "2d" else _runtime_reference
    rt = builder(cfg, len(artifact.norm_min), has_corr)
    lat = dequantize(artifact.latent_q, artifact.latent_bin)
    x_rec = _batched(rt.jit_decode, artifact.ae_params, lat)
    if has_corr:
        vecs = correction.blocks_to_pointwise(x_rec)
        fixed = _batched(rt.jit_corr, artifact.corr_params, vecs, batch=1 << 16)
        x_rec = correction.pointwise_to_blocks(fixed, x_rec)
    vecs_rec = blocking.blocks_as_vectors(x_rec)
    corrected = gae.apply_correction_batched(
        vecs_rec, artifact.species_guarantees
    )
    return _finalize_field(corrected, artifact)


def decompress(blob: bytes, *, species=None, time_range=None) -> np.ndarray:
    """Standalone decode: container bytes -> (S, T, H, W) float32 field.

    Needs no codec instance and no fitted model — everything is
    reconstructed from the blob (the acceptance contract for the wire
    format). Raises :class:`ContainerFormatError` on malformed input.

    ``species`` (an index or a sequence of indices) and/or ``time_range``
    (a half-open ``(t0, t1)`` frame window) select a slice to decode
    randomly-accessed: only the requested guarantee streams are parsed and
    entropy-decoded, the fused NN decode covers only the block rows of the
    window, and the result is bitwise equal to slicing a full decode —
    ``decompress(b, species=s, time_range=(t0, t1))
    == decompress(b)[s, t0:t1]``. An integer ``species`` drops the species
    axis, like numpy indexing. Repeated slicing of one blob is cheaper
    through a reused :class:`PartialDecoder`.

    Hot-path organization (full decode): the container head (meta,
    latents, parameters) is parsed first and the fused NN decode
    dispatched asynchronously; the per-species guarantee streams then
    entropy-decode species-parallel on the host while the decode runs, and
    one replay dispatch applies the corrections.
    """
    if species is not None or time_range is not None:
        return PartialDecoder(blob).decode(
            species=species, time_range=time_range
        )
    head = _decode_head(blob)
    vecs_dev = _fused_vecs(
        head.runtime, head.ae_params, head.corr_params, _latents32(head)
    )
    # the guarantee streams entropy-decode while the dispatched NN runs
    artifact = _finish_artifact(head)
    return _apply_guarantees_and_finalize(vecs_dev, artifact)


def decompress_reference(blob: bytes, conv_impl: str = "2d") -> np.ndarray:
    """Retained pre-change standalone decode: sequential per-species
    deserialize with per-call Huffman table builds, then the staged
    host-chunked reconstruct. With the default ``conv_impl="2d"`` this is
    the fused path's bit-identity oracle; with ``"xla"`` it is the seed's
    full cost profile (the throughput benchmark's timing baseline)."""
    return reconstruct_reference(decode_artifact_reference(blob), conv_impl)


# ---------------------------------------------------------------------------
# selective decode: random access by species / time window
# ---------------------------------------------------------------------------
def _normalize_species(species, s: int) -> tuple[list, bool]:
    """Selection -> (index list, squeeze-species-axis?)."""
    if species is None:
        return list(range(s)), False
    if isinstance(species, (int, np.integer)):
        species, squeeze = [int(species)], True
    else:
        species, squeeze = [int(x) for x in species], False
    if not species:
        raise ValueError("empty species selection")
    idx = []
    for x in species:
        if not -s <= x < s:
            raise ValueError(
                f"species index {x} out of range for {s} species"
            )
        idx.append(x % s)
    if len(set(idx)) != len(idx):
        raise ValueError(f"duplicate species in selection {species}")
    return idx, squeeze


def _normalize_time_range(time_range, t: int) -> tuple[int, int]:
    if time_range is None:
        return 0, t
    t0, t1 = (int(time_range[0]), int(time_range[1]))
    if not 0 <= t0 < t1 <= t:
        raise ValueError(
            f"time_range {time_range!r} is not a half-open window "
            f"inside [0, {t})"
        )
    return t0, t1


# an empty coefficient stream is exactly the self-describing Huffman
# header; any stream with >= 1 symbol is strictly longer (header grows by
# 9 bytes per codebook symbol before any payload bit)
_EMPTY_HUFFMAN_LEN = len(entropy.huffman_encode(np.zeros(0, np.int64)))


def _any_corrections(head: _DecodedHead) -> bool:
    """Does ANY species of the artifact carry stored corrections?

    The full decode runs the correction-replay kernel over all species
    whenever any one of them has corrections — so the selective path must
    gate its replay on the same artifact-wide bit (not just the selected
    species') to stay byte-identical to slicing the full decode. Decided
    at the wire level without entropy-decoding anything: a species is
    empty iff its coefficient stream is the bare Huffman header. Memoized
    on the head — the v1 recompute would copy every species' payload per
    query.
    """
    if head.any_corrections is not None:
        return head.any_corrections
    if head.version == container_format.FORMAT_VERSION_SELECTIVE:
        gdir = _gdir(head)
        result = any(
            gdir.coeff_len(sidx) > _EMPTY_HUFFMAN_LEN
            for sidx in range(gdir.n_species)
        )
    else:
        result = False
        for sidx in range(head.shape[0]):
            try:
                sizes = ContainerReader(
                    head.reader[f"guarantee{sidx}"]
                ).stream_sizes()
            except ContainerFormatError:
                # corrupt sibling: the full decode raises on this blob, so
                # there is no full-decode output to match — skip it here
                # and let the selected species' own parse decide
                continue
            if sizes.get("coeff", 0) > _EMPTY_HUFFMAN_LEN:
                result = True
                break
    head.any_corrections = result
    return result


class PartialDecoder:
    """Random-access decoder over one GBATC container blob.

    Parses the container head exactly once (meta, latent stream, network
    parameters — everything selection-independent), then serves
    species/time-window slices on demand:

    * only the **requested species'** guarantee streams are parsed and
      entropy-decoded (lockstep-batched when several are requested at
      once, memoized across ``decode`` calls);
    * the fused NN decode runs on only the **block rows covering the
      requested time window** (species cannot shrink this stage — the AE
      decodes the species stack jointly per block);
    * only the requested species' corrections replay through the batched
      Pallas kernel, scattered from the CSR extents of the window alone.

    Every slice is bitwise equal to slicing the corresponding full
    decode. Works on v1 and v2 containers; the v2 combined guarantee
    stream makes each species' byte extent addressable from its directory
    alone, which is what makes :meth:`bytes_parsed` shrink with the
    selection. A corrupt species stream raises
    :class:`ContainerFormatError` naming it, and does not poison sibling
    species requested in later calls.
    """

    def __init__(self, blob: bytes):
        self._head = _decode_head(blob)
        self._arts: dict[int, gae.GuaranteeArtifact] = {}

    @property
    def shape(self) -> tuple[int, int, int, int]:
        """(S, T, H, W) of the encoded field."""
        return self._head.shape

    @property
    def n_species(self) -> int:
        return self._head.shape[0]

    @property
    def version(self) -> int:
        return self._head.version

    def _artifacts(self, idx: "list[int]") -> list:
        missing = [s for s in idx if s not in self._arts]
        if missing:
            arts = _decode_species_guarantees(self._head, missing)
            self._arts.update(zip(missing, arts))
        return [self._arts[s] for s in idx]

    def bytes_parsed(self, species=None) -> int:
        """Container bytes a ``decode(species=...)`` call touches.

        Counts the outer header/table, the selection-independent head
        streams (meta, latent, decoder, correction), the guarantee
        directory, and the selected species' coeff/index/basis extents.
        With ``species=None`` this equals ``len(blob)`` on a v2 container
        — every byte is then accounted to a purpose. Time windows reduce
        compute, not bytes: the latent stream is a single sequential
        entropy stream shared by all blocks.
        """
        head = self._head
        idx, _ = _normalize_species(species, head.shape[0])
        sizes = head.reader.stream_sizes()
        n = (
            head.reader.header_bytes
            + sizes["meta"]
            + sizes["latent"]
            + sizes["decoder"]
            + sizes.get("correction", 0)
        )
        if head.version == container_format.FORMAT_VERSION_SELECTIVE:
            gdir = _gdir(head)
            n += gdir.dir_bytes
            n += sum(gdir.species_extent_bytes(s) for s in idx)
        else:
            n += sum(sizes[f"guarantee{s}"] for s in idx)
        return n

    def decode(self, species=None, time_range=None) -> np.ndarray:
        """Decode a (species, time-window) slice of the stored field.

        Returns ``(len(species), t1 - t0, H, W)`` float32 (the species
        axis squeezed when ``species`` is a single integer), bitwise equal
        to the same slice of the full decode.
        """
        head = self._head
        s, t, h, w = head.shape
        idx, squeeze = _normalize_species(species, s)
        t0, t1 = _normalize_time_range(time_range, t)
        geom = head.cfg.geometry
        per_frame = (h // geom.ph) * (w // geom.pw)
        tg0, tg1 = t0 // geom.bt, -(-t1 // geom.bt)
        b0, b1 = tg0 * per_frame, tg1 * per_frame

        # fused NN decode over the window's block rows only (async
        # dispatch; rows are independent, so the slice is bit-transparent)
        lat32 = dequantize(
            head.latent_q[b0:b1], head.latent_bin
        ).astype(np.float32)
        vecs_dev = _fused_vecs(
            head.runtime, head.ae_params, head.corr_params, lat32
        )
        # requested species' guarantee streams entropy-decode while the
        # dispatched NN decode runs
        arts = self._artifacts(idx)

        import jax.numpy as jnp

        vecs_sel = jnp.asarray(vecs_dev)[np.asarray(idx)]
        # gate on the artifact-wide corrections bit, not the selection's:
        # the full decode replays (x + C@U^T, C possibly all-zero) over
        # every species whenever any species has corrections, and the
        # selective output must be byte-identical to its slice
        if _any_corrections(head):
            engine = gae.default_engine()
            dense, basis = engine.dense_corrections(
                arts, (len(idx), b1 - b0, geom.block_size),
                block_range=(b0, b1),
            )
            vecs_sel = engine.apply_device(
                vecs_sel, jnp.asarray(dense), jnp.asarray(basis)
            )
        rec_blocks = blocking.vectors_as_blocks(np.asarray(vecs_sel), geom)
        sub_shape = (len(idx), (tg1 - tg0) * geom.bt, h, w)
        rec_normed = blocking.from_blocks(rec_blocks, sub_shape, geom)
        out = (
            rec_normed * head.norm_range[idx][:, None, None, None]
            + head.norm_min[idx][:, None, None, None]
        ).astype(np.float32)
        out = out[:, t0 - tg0 * geom.bt : t1 - tg0 * geom.bt]
        return out[0] if squeeze else out


# ---------------------------------------------------------------------------
# the codec facade
# ---------------------------------------------------------------------------
class GBATCCodec:
    """Bytes-in/bytes-out GBATC (or GBA, via ``cfg.use_correction=False``).

    Usage::

        codec = GBATCCodec(PipelineConfig(...))
        codec.fit(data)                       # train AE (+ correction) once
        blob = codec.compress(target_nrmse=1e-3)   # -> container bytes
        field = repro.codec.decompress(blob)       # anywhere, no codec

    ``compress(data=...)`` fits on the given data first (refitting if the
    codec was already fitted), so one-shot compression is a single call.
    Error-bound sweeps against one fitted model reuse the pipeline's cached
    tau-independent guarantee state.
    """

    def __init__(self, cfg: Optional[PipelineConfig] = None,
                 n_species: Optional[int] = None):
        self.cfg = cfg if cfg is not None else PipelineConfig()
        self._pipe: Optional[GBATCPipeline] = (
            GBATCPipeline(self.cfg, n_species) if n_species is not None else None
        )

    @property
    def pipeline(self) -> Optional[GBATCPipeline]:
        """The underlying fit/orchestration layer (None before first fit)."""
        return self._pipe

    @property
    def fitted(self) -> bool:
        return self._pipe is not None and self._pipe._latents is not None

    def fit(self, data: np.ndarray, verbose: bool = False) -> "GBATCCodec":
        data = np.asarray(data)
        if data.ndim != 4:
            raise ValueError(
                f"expected (S, T, H, W) species data, got "
                f"{data.ndim}-d {type(data).__name__} of shape {data.shape}"
                " (note: compress(target_nrmse=...) is keyword-only via the"
                " data-first signature)"
            )
        if self._pipe is None or self._pipe.n_species != data.shape[0]:
            self._pipe = GBATCPipeline(self.cfg, n_species=data.shape[0])
        self._pipe.fit(data, verbose=verbose)
        return self

    def compress(self, data: Optional[np.ndarray] = None,
                 target_nrmse: float = 1e-3, **kw) -> bytes:
        """Compress to container bytes; pass ``data`` to (re)fit first."""
        blob, _ = self.compress_report(data, target_nrmse=target_nrmse, **kw)
        return blob

    def compress_report(
        self, data: Optional[np.ndarray] = None,
        target_nrmse: float = 1e-3, **kw,
    ) -> tuple[bytes, CompressionReport]:
        """Like :meth:`compress`, also returning the quality report."""
        if data is not None:
            self.fit(data)
        if not self.fitted:
            raise RuntimeError("codec not fitted: pass data or call fit() first")
        rep = self._pipe.compress(target_nrmse=target_nrmse, **kw)
        return rep.artifact.to_bytes(), rep

    @staticmethod
    def decompress(blob: bytes, *, species=None, time_range=None) -> np.ndarray:
        """Decode a container blob (stateless; see module :func:`decompress`).

        ``species``/``time_range`` select a slice to decode
        randomly-accessed, bitwise equal to slicing the full decode."""
        return decompress(blob, species=species, time_range=time_range)
