"""Tensor correction network (paper §II-C).

A *pointwise* (per temporal/spatial sample) over-complete MLP that maps the S
reconstructed species values back toward the originals:
S -> 4S -> 8S -> 4S -> S with LeakyReLU (paper: 58->232->464->232->58).

No new latents are stored — only the network parameters, which is why the
layer improves NRMSE "for free" at high compression ratios. We parameterize
the map residually (out = x_rec + mlp(x_rec)); this spans the same function
class and trains markedly more stably when the AE reconstruction is already
close (the paper's "adjusts the reconstructed data" reading).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn import layers as L
from repro.nn.module import init_tree
from repro.train import optimizer as opt
from repro.train import train_loop


@dataclasses.dataclass(frozen=True)
class CorrectionConfig:
    n_species: int
    widths: tuple[int, int, int] = (4, 8, 4)  # multiples of S
    negative_slope: float = 0.2
    dtype: Any = jnp.float32


class TensorCorrectionNetwork:
    def __init__(self, cfg: CorrectionConfig):
        self.cfg = cfg
        s = cfg.n_species
        dims = (s,) + tuple(w * s for w in cfg.widths) + (s,)
        self.fcs = [
            L.dense(dims[i], dims[i + 1], dtype=cfg.dtype)
            for i in range(len(dims) - 1)
        ]

    @property
    def defs(self):
        return {f"fc{i}": fc.defs for i, fc in enumerate(self.fcs)}

    def init(self, key):
        return init_tree(self.defs, key)

    def __call__(self, params, x_rec):
        """x_rec: (..., S) pointwise species vectors; returns corrected (..., S)."""
        h = x_rec
        for i, fc in enumerate(self.fcs):
            h = fc.apply(params[f"fc{i}"], h)
            if i < len(self.fcs) - 1:
                h = L.leaky_relu(h, self.cfg.negative_slope)
        return x_rec + h

    def param_bytes(self, params) -> int:
        return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(params))


def blocks_to_pointwise(blocks: np.ndarray) -> np.ndarray:
    """(NB, S, bt, ph, pw) -> (NB*bt*ph*pw, S) species vectors."""
    nb, s = blocks.shape[:2]
    return np.ascontiguousarray(
        blocks.reshape(nb, s, -1).transpose(0, 2, 1).reshape(-1, s)
    )


def pointwise_to_blocks(vecs: np.ndarray, like: np.ndarray) -> np.ndarray:
    nb, s, bt, ph, pw = like.shape
    return np.ascontiguousarray(
        vecs.reshape(nb, bt * ph * pw, s).transpose(0, 2, 1).reshape(nb, s, bt, ph, pw)
    )


def _corr_loss(net: TensorCorrectionNetwork):
    def loss_fn(p, a, b):
        return jnp.mean(jnp.square(net(p, a) - b))

    return loss_fn


def fit(
    net: TensorCorrectionNetwork,
    x_rec: np.ndarray,
    x_orig: np.ndarray,
    *,
    steps: int = 300,
    batch_size: int = 4096,
    lr: float = 1e-3,
    seed: int = 1,
    log_every: int = 0,
    mode: Optional[str] = None,
    mesh=None,
) -> tuple[Any, np.ndarray]:
    """Train the correction net on (reconstructed -> original) species
    vectors through the compiled mini-batch engine. Returns
    (params, loss_history); the trainer is cached on the network, so
    refitting never re-traces. ``mesh`` runs the data-parallel mesh
    program (vector rows sharded over the data axis)."""
    params = net.init(jax.random.PRNGKey(seed))
    cache = net.__dict__.setdefault("_trainers", {})
    key = (lr, steps, mode)
    trainer = cache.get(key)
    if trainer is None:
        trainer = train_loop.MiniBatchTrainer(
            _corr_loss(net),
            train_loop.adamw_cfg(lr, steps),
            mode=mode,
            log_fn=lambda t, loss: print(f"[corr] step {t} loss {loss:.3e}"),
        )
        cache[key] = trainer
    return trainer.fit(
        params, (x_rec, x_orig), steps=steps, batch_size=batch_size,
        seed=seed, log_every=log_every, mesh=mesh,
    )


def fit_reference(
    net: TensorCorrectionNetwork,
    x_rec: np.ndarray,
    x_orig: np.ndarray,
    *,
    steps: int = 300,
    batch_size: int = 4096,
    lr: float = 1e-3,
    seed: int = 1,
) -> tuple[Any, np.ndarray]:
    """The seed's correction trainer (per-fit jit, host loop, per-step
    sync), retained as baseline/oracle; batch indices follow the engine's
    law so trajectories are comparable."""
    key = jax.random.PRNGKey(seed)
    params = net.init(key)
    cfg = train_loop.adamw_cfg(lr, steps)
    state = opt.init_state(params)
    xr = jnp.asarray(x_rec)
    xo = jnp.asarray(x_orig)
    n = xr.shape[0]
    loss_fn = _corr_loss(net)

    @jax.jit
    def step_fn(p, s, a, b):
        loss, grads = jax.value_and_grad(loss_fn)(p, a, b)
        p, s, _ = opt.update(cfg, grads, s, p)
        return p, s, loss

    losses = []
    idxs = train_loop.all_batch_indices(seed, steps, n, min(batch_size, n))
    for i in range(steps):
        params, state, loss = step_fn(params, state, xr[idxs[i]], xo[idxs[i]])
        losses.append(float(loss))
    return params, np.asarray(losses, dtype=np.float32)
