"""Tensor correction network (paper §II-C).

A *pointwise* (per temporal/spatial sample) over-complete MLP that maps the S
reconstructed species values back toward the originals:
S -> 4S -> 8S -> 4S -> S with LeakyReLU (paper: 58->232->464->232->58).

No new latents are stored — only the network parameters, which is why the
layer improves NRMSE "for free" at high compression ratios. We parameterize
the map residually (out = x_rec + mlp(x_rec)); this spans the same function
class and trains markedly more stably when the AE reconstruction is already
close (the paper's "adjusts the reconstructed data" reading).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn import layers as L
from repro.nn.module import init_tree
from repro.train import optimizer as opt


@dataclasses.dataclass(frozen=True)
class CorrectionConfig:
    n_species: int
    widths: tuple[int, int, int] = (4, 8, 4)  # multiples of S
    negative_slope: float = 0.2
    dtype: Any = jnp.float32


class TensorCorrectionNetwork:
    def __init__(self, cfg: CorrectionConfig):
        self.cfg = cfg
        s = cfg.n_species
        dims = (s,) + tuple(w * s for w in cfg.widths) + (s,)
        self.fcs = [
            L.dense(dims[i], dims[i + 1], dtype=cfg.dtype)
            for i in range(len(dims) - 1)
        ]

    @property
    def defs(self):
        return {f"fc{i}": fc.defs for i, fc in enumerate(self.fcs)}

    def init(self, key):
        return init_tree(self.defs, key)

    def __call__(self, params, x_rec):
        """x_rec: (..., S) pointwise species vectors; returns corrected (..., S)."""
        h = x_rec
        for i, fc in enumerate(self.fcs):
            h = fc.apply(params[f"fc{i}"], h)
            if i < len(self.fcs) - 1:
                h = L.leaky_relu(h, self.cfg.negative_slope)
        return x_rec + h

    def param_bytes(self, params) -> int:
        return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(params))


def blocks_to_pointwise(blocks: np.ndarray) -> np.ndarray:
    """(NB, S, bt, ph, pw) -> (NB*bt*ph*pw, S) species vectors."""
    nb, s = blocks.shape[:2]
    return np.ascontiguousarray(
        blocks.reshape(nb, s, -1).transpose(0, 2, 1).reshape(-1, s)
    )


def pointwise_to_blocks(vecs: np.ndarray, like: np.ndarray) -> np.ndarray:
    nb, s, bt, ph, pw = like.shape
    return np.ascontiguousarray(
        vecs.reshape(nb, bt * ph * pw, s).transpose(0, 2, 1).reshape(nb, s, bt, ph, pw)
    )


def fit(
    net: TensorCorrectionNetwork,
    x_rec: np.ndarray,
    x_orig: np.ndarray,
    *,
    steps: int = 300,
    batch_size: int = 4096,
    lr: float = 1e-3,
    seed: int = 1,
) -> Any:
    """Train the correction net on (reconstructed -> original) species vectors."""
    key = jax.random.PRNGKey(seed)
    params = net.init(key)
    cfg = opt.AdamWConfig(lr=lr, total_steps=steps, warmup_steps=min(20, steps // 10))
    state = opt.init_state(params)
    xr = jnp.asarray(x_rec)
    xo = jnp.asarray(x_orig)
    n = xr.shape[0]

    def loss_fn(p, a, b):
        return jnp.mean(jnp.square(net(p, a) - b))

    @jax.jit
    def step_fn(p, s, a, b):
        loss, grads = jax.value_and_grad(loss_fn)(p, a, b)
        p, s, _ = opt.update(cfg, grads, s, p)
        return p, s, loss

    rng = np.random.default_rng(seed)
    for _ in range(steps):
        idx = rng.integers(0, n, size=min(batch_size, n))
        params, state, _ = step_fn(params, state, xr[idx], xo[idx])
    return params
