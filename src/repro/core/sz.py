"""SZ3-style error-bounded lossy compressor (paper §II-D baseline).

Faithful to the SZ3 design [Liang et al., IEEE TBD 2023]: a multilevel
interpolation predictor (cubic spline with linear fallback at borders),
linear-scale residual quantization with bin = 2*eb (so every point's absolute
error is <= eb by construction), Huffman coding of the quantizer stream, and
a zstd lossless backend — the same four stages as SZ.

The predictor sweeps levels coarse->fine; at each level, points on the
half-stride grid are predicted *from already-reconstructed* coarser points
(decompressor-consistent, as SZ requires). Everything is vectorized per
(level, axis) pass.
"""

from __future__ import annotations

import dataclasses
import struct
from typing import Optional

import numpy as np

from repro.core import entropy

_QUANT_RADIUS = 1 << 20  # outliers beyond this are stored raw


@dataclasses.dataclass
class SZArtifact:
    recon: Optional[np.ndarray]  # encoder-side reconstruction (not on the wire)
    quant_stream: np.ndarray  # concatenated per-pass quantizer indices
    outlier_values: np.ndarray
    anchor_values: np.ndarray
    abs_eb: float
    shape: tuple[int, ...]

    # header: shape (3 x u32), abs_eb f64, n_quant u64, n_outliers u32 —
    # the SZ baseline artifact is self-contained, independent of the
    # GBATC container, hence its own wire site:
    _WIRE_HEAD = struct.Struct("<IIIdQI")  # repro: allow[wire-centralization]

    def wire_streams(self) -> dict[str, bytes]:
        """The exact byte streams a standalone decoder replays.

        Outlier *positions* are not stored: the decoder recovers them from
        the quantizer stream (``q == radius + 1`` marks an outlier), so the
        outlier stream carries only the values — lossless float64, because
        the decode path replays them verbatim into the reconstruction.
        """
        huff = entropy.huffman_encode(self.quant_stream)
        return {
            "header": self._WIRE_HEAD.pack(
                *self.shape, self.abs_eb, self.quant_stream.size,
                self.outlier_values.size,
            ),
            "quant": entropy.zstd_bytes(huff),
            "outliers": np.ascontiguousarray(
                self.outlier_values.astype("<f8", copy=False)).tobytes(),
            "anchors": np.ascontiguousarray(
                self.anchor_values.astype("<f8", copy=False)).tobytes(),
        }

    def to_bytes(self) -> bytes:
        """Serialize the replayable streams (``payload_bytes`` == length)."""
        return b"".join(self.wire_streams().values())

    @classmethod
    def from_bytes(cls, blob: bytes) -> "SZArtifact":
        """Inverse of :func:`to_bytes` (``recon`` is decode-side ``None``)."""
        head = cls._WIRE_HEAD
        if len(blob) < head.size:
            raise ValueError(f"SZ blob truncated: {len(blob)} bytes")
        t, h, w, abs_eb, n_quant, n_out = head.unpack_from(blob, 0)
        shape = (t, h, w)
        n_anchor = int(np.prod([-(-dim // _anchor_stride(shape))
                                for dim in shape]))
        tail = 8 * (n_out + n_anchor)
        if len(blob) < head.size + tail:
            raise ValueError("SZ blob truncated: outlier/anchor streams")
        try:
            quant = entropy.huffman_decode(
                entropy.zstd_unbytes(blob[head.size : len(blob) - tail])
            )
        except ValueError:
            raise
        except Exception as e:  # zlib.error / zstd errors are backend types
            raise ValueError(f"corrupt SZ quantizer stream: {e}") from e
        if quant.size != n_quant:
            raise ValueError(
                f"SZ quantizer stream decodes to {quant.size} symbols, "
                f"expected {n_quant}"
            )
        off = len(blob) - tail
        outliers = np.frombuffer(blob, dtype="<f8", count=n_out, offset=off)
        anchors = np.frombuffer(
            blob, dtype="<f8", count=n_anchor, offset=off + 8 * n_out
        )
        return cls(
            recon=None,
            quant_stream=quant,
            outlier_values=outliers.copy(),
            anchor_values=anchors.copy(),
            abs_eb=float(abs_eb),
            shape=shape,
        )

    def payload_bytes(self) -> int:
        """Measured size of the replayable wire streams (== ``len(to_bytes())``).

        Each outlier costs its lossless float64 value (8 bytes); positions
        are derived from the quantizer stream at decode time, so charging
        them here would double-count bytes the decoder never reads.
        """
        return sum(len(s) for s in self.wire_streams().values())


def _interp_pass(
    recon: np.ndarray,
    known: np.ndarray,
    orig: np.ndarray,
    axis: int,
    h: int,
    step_other: tuple[int, int, int],
    twice_eb: float,
    quant_chunks: list[np.ndarray],
    outliers: list[np.ndarray],
    decode_stream: "_StreamReader | None" = None,
):
    """Predict points at odd multiples of h along `axis`, on the sub-grid
    where the other axes run at their current strides. Cubic where four
    neighbours exist, linear otherwise."""
    n = recon.shape[axis]
    pos = np.arange(h, n, 2 * h)
    if pos.size == 0:
        return
    idx = [np.arange(0, recon.shape[d], step_other[d]) for d in range(3)]
    idx[axis] = pos
    grid = np.ix_(*idx)

    def take(offset_positions):
        g = [np.arange(0, recon.shape[d], step_other[d]) for d in range(3)]
        g[axis] = offset_positions
        return recon[np.ix_(*g)]

    left = take(pos - h)
    right_valid = pos + h < n
    right_pos = np.where(right_valid, pos + h, pos - h)
    right = take(right_pos)
    lin = np.where(
        _expand(right_valid, axis, left.shape), 0.5 * (left + right), left
    )

    cubic_valid = (pos - 3 * h >= 0) & (pos + 3 * h < n)
    if cubic_valid.any():
        l2 = take(np.maximum(pos - 3 * h, 0))
        r2 = take(np.minimum(pos + 3 * h, n - 1))
        cubic = (-l2 + 9.0 * left + 9.0 * right - r2) / 16.0
        pred = np.where(_expand(cubic_valid, axis, left.shape), cubic, lin)
    else:
        pred = lin

    if decode_stream is None:
        true = orig[grid]
        q = np.rint((true - pred) / twice_eb)
        out_mask = np.abs(q) > _QUANT_RADIUS
        q = np.where(out_mask, _QUANT_RADIUS + 1, q).astype(np.int64)
        rec = pred + q * twice_eb
        if out_mask.any():
            vals = true[out_mask]
            rec[out_mask] = vals  # raw lossless storage
            outliers.append(vals)
        quant_chunks.append(q.ravel())
        recon[grid] = rec
    else:
        q = decode_stream.read(pred.size).reshape(pred.shape)
        rec = pred + q * twice_eb
        out_mask = q == _QUANT_RADIUS + 1
        if out_mask.any():
            rec[out_mask] = decode_stream.read_outliers(int(out_mask.sum()))
        recon[grid] = rec


def _expand(mask_1d: np.ndarray, axis: int, shape: tuple[int, ...]) -> np.ndarray:
    view = [1, 1, 1]
    view[axis] = mask_1d.size
    return np.broadcast_to(mask_1d.reshape(view), shape)


class _StreamReader:
    def __init__(self, quant_stream: np.ndarray, outlier_values: np.ndarray):
        self.q = quant_stream
        self.o = outlier_values
        self.qi = 0
        self.oi = 0

    def read(self, n: int) -> np.ndarray:
        out = self.q[self.qi : self.qi + n]
        self.qi += n
        return out

    def read_outliers(self, n: int) -> np.ndarray:
        out = self.o[self.oi : self.oi + n]
        self.oi += n
        return out


def _anchor_stride(shape: tuple[int, ...]) -> int:
    """Anchor-grid stride, shared by compress/decompress/deserialize."""
    return 1 << max(1, int(np.floor(np.log2(max(2, min(shape))))))


def _sweep(recon, orig, abs_eb, decode_stream=None):
    """Shared compress/decompress level sweep (decompressor-consistent)."""
    shape = recon.shape
    max_level = max(1, int(np.floor(np.log2(max(2, min(shape))))))
    twice_eb = 2.0 * abs_eb
    quant_chunks: list[np.ndarray] = []
    outliers: list[np.ndarray] = []
    for level in range(max_level - 1, -1, -1):
        h = 1 << level
        s = 2 * h
        # pass order mirrors SZ3: axis 0 first (others at coarse stride),
        # then axis 1 (axis 0 now fine), then axis 2.
        _interp_pass(recon, None, orig, 0, h, (s, s, s), twice_eb,
                     quant_chunks, outliers, decode_stream)
        _interp_pass(recon, None, orig, 1, h, (h, s, s), twice_eb,
                     quant_chunks, outliers, decode_stream)
        _interp_pass(recon, None, orig, 2, h, (h, h, s), twice_eb,
                     quant_chunks, outliers, decode_stream)
    return quant_chunks, outliers, max_level


def compress(data: np.ndarray, abs_eb: float) -> SZArtifact:
    """Error-bounded compression of a 3D array; |x - recon| <= eb pointwise."""
    assert data.ndim == 3, "SZ baseline operates on (T, H, W) fields"
    orig = data.astype(np.float64)
    recon = np.zeros_like(orig)
    stride = _anchor_stride(orig.shape)
    anchors = orig[::stride, ::stride, ::stride].copy()
    recon[::stride, ::stride, ::stride] = anchors  # anchors stored lossless
    quant_chunks, outliers, _ = _sweep(recon, orig, abs_eb)
    return SZArtifact(
        recon=recon,
        quant_stream=(
            np.concatenate(quant_chunks) if quant_chunks else np.zeros(0, np.int64)
        ),
        outlier_values=(
            np.concatenate(outliers) if outliers else np.zeros(0, np.float64)
        ),
        anchor_values=anchors.ravel(),
        abs_eb=float(abs_eb),
        shape=tuple(orig.shape),
    )


def decompress(art: SZArtifact) -> np.ndarray:
    recon = np.zeros(art.shape, dtype=np.float64)
    stride = _anchor_stride(art.shape)
    anchor_shape = recon[::stride, ::stride, ::stride].shape
    recon[::stride, ::stride, ::stride] = art.anchor_values.reshape(anchor_shape)
    reader = _StreamReader(art.quant_stream, art.outlier_values)
    _sweep(recon, None, art.abs_eb, decode_stream=reader)
    return recon


def compress_species(
    data: np.ndarray, abs_eb_per_species: np.ndarray
) -> tuple[np.ndarray, int]:
    """Compress (S, T, H, W) per species; returns (recon, total_bytes).

    The reconstruction stays float64: the per-point |x - recon| <= eb
    guarantee is established in float64, and a float32 cast adds up to half
    a float32 ulp of the field's magnitude — on large-offset fields that
    alone exceeds a tight bound (measured: max err 1.14e-3 > eb 6.97e-4),
    which would make the SZ baseline report bounds it does not honor.
    """
    recon = np.empty(data.shape, dtype=np.float64)
    total = 0
    for sidx in range(data.shape[0]):
        art = compress(data[sidx], float(abs_eb_per_species[sidx]))
        recon[sidx] = art.recon
        total += art.payload_bytes()
    return recon, total
