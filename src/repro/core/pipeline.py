"""End-to-end GBA / GBATC compression pipeline (paper §II, Fig. 3).

Workflow (matches the paper's):

  pipe = GBATCPipeline(cfg, n_species=S)
  pipe.fit(data)                       # train AE (+ correction net) ONCE
  rep = pipe.compress(target_nrmse=1e-3, latent_bin_rel=0.05)   # cheap sweep
  rec = pipe.decompress(rep.artifact)  # streams-only replay

Stages:
  1. per-species min/max normalization (species span ~7 decades; the NRMSE
     metric is range-normalized, so the guarantee runs in normalized units);
  2. spatiotemporal blocking (paper geometry 4 x 5 x 4);
  3. 3D-conv block AE; latents quantized + Huffman'd (the decoder consumes
     the *quantized* latents so encode/decode stay consistent);
  4. (GBATC) pointwise tensor-correction network on reconstructed->original
     species vectors;
  5. device-resident guarantee engine (Algorithm 1): one batched (S, NB, D)
     dispatch through ``gae.GuaranteeEngine`` — Pallas projection and
     masked select-and-accumulate kernels plus jitted fp64 selection — with
     tau_s = target_nrmse * sqrt(D) (normalized range = 1). The engine's
     tau-independent state (residual PCA, projections, energy ordering) is
     cached per (latent_bin, correction) so sweeping error bounds against
     one fitted model pays it once; decompress replays corrections through
     the same batched kernel path;
  6. serialization through :mod:`repro.codec`: ``artifact.to_bytes()`` emits
     the versioned container (container v3 by default: a time-sharded
     latent stream — per-shard Huffman chains under one shared codebook —
     plus decoder/correction params and ONE combined guarantee stream, a
     CSR-of-CSR directory over species fronting the {coeff, CSR index
     bitmap, basis} sub-streams; v2's single-chain latent and v1's
     per-species nested containers still encode/decode) and
     ``byte_breakdown`` is a view over the container's *measured* stream
     lengths — ``breakdown["total"] == len(blob)`` exactly, no estimates.
     Consumers that want one species or a time window decode the blob
     randomly-accessed via ``repro.codec.decompress(blob, species=...,
     time_range=...)`` / ``repro.codec.PartialDecoder`` — bitwise equal to
     slicing the full decode, without parsing unselected streams (and, on
     v3, entropy-decoding only the latent shards covering the window).

This class is the fit/orchestration layer; the wire format and the
standalone decode path live in :mod:`repro.codec` (``compress`` returns an
in-memory report whose artifact serializes via the codec, and
``decompress`` is a compatibility wrapper over ``codec.reconstruct`` that
derives decode structure from the *artifact*, not from this pipeline's
config). Training runs on the compiled mini-batch engine
(:mod:`repro.train.train_loop` — device-resident data, cached programs, no
per-step host sync), and every decode — including the one feeding the
guarantee prep — goes through the codec's shared fused runtime, so the
reconstruction the guarantee is computed against is bit-identical to the
one ``codec.decompress`` replays. Nothing re-traces across fit/compress/
decompress calls.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import numpy as np

from repro.codec.artifact import (  # noqa: F401  (canonical home moved;
    CompressedArtifact,  # re-exported so pipeline-layer imports keep working)
    _batched,
)
from repro.codec.families import get as _family, structural as _structural
from repro.core import blocking, correction, gae, metrics
from repro.core.quantization import dequantize, quantize, quantize_params


def _host_alloc(shape, dtype):
    """Host allocation seam for the streaming ingest buffer. The mesh
    fit_stream path must never call this at full-field size (blocks land
    sharded on device instead) — the allocation-tracking test hooks this
    function to assert exactly that."""
    return np.empty(shape, dtype)


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    geometry: blocking.BlockGeometry = blocking.PAPER_GEOMETRY
    latent: int = 36
    conv_channels: tuple[int, ...] = (32, 64)
    use_correction: bool = True  # GBATC if True, GBA if False
    ae_steps: int = 600
    corr_steps: int = 300
    batch_size: int = 64
    lr: float = 2e-3
    seed: int = 0
    # paper stores networks fp32; fp16 halves the fixed overhead with
    # negligible NRMSE impact (beyond-paper option, default off)
    param_dtype_bytes: int = 4
    # encoder family (see repro.codec.families): "conv" is the paper's
    # block autoencoder; "attention" the patch-token block attention
    # pair. ``arch`` carries the family's wire arch words — for conv it
    # defaults to ``conv_channels`` (kept as the historical spelling),
    # for attention to families.DEFAULT_ATTENTION_ARCH
    family: str = "conv"
    arch: Optional[tuple[int, ...]] = None


@dataclasses.dataclass
class CompressionReport:
    recon: np.ndarray
    compression_ratio: float
    mean_nrmse: float
    per_species_nrmse: np.ndarray
    bytes_breakdown: dict
    artifact: CompressedArtifact


class GBATCPipeline:
    """GBATC when cfg.use_correction else GBA.

    Model-shaped decisions dispatch through the encoder-family registry
    (:mod:`repro.codec.families`): ``cfg.family`` picks the handle, the
    normalized :class:`~repro.codec.families.StructuralConfig` builds the
    model, and ``family.fit`` trains it — conv by default, so existing
    configs behave exactly as before.
    """

    def __init__(self, cfg: PipelineConfig, n_species: int, mesh=None):
        self.cfg = cfg
        self.n_species = n_species
        self.mesh = mesh
        self.family = _family(cfg.family)
        self.scfg = _structural(cfg)
        self.model = self.family.build_model(self.scfg, n_species, "2d")
        self.corr_net = (
            correction.TensorCorrectionNetwork(
                correction.CorrectionConfig(n_species=n_species)
            )
            if cfg.use_correction
            else None
        )
        # jitted once per instance: rebuilding jax.jit(...) per call would
        # re-trace (and re-compile) on every compress/decompress
        self._jit_encode = jax.jit(self.model.encode)
        if mesh is not None:
            # mesh-sharded orchestration: DP trainer programs, a
            # species/row-sharded guarantee engine, and sharded streaming
            # ingest (fit_stream) — artifacts stay byte-identical to the
            # single-device path (see repro.parallel.mesh_fit)
            from repro.parallel.mesh_fit import ShardedGuaranteeEngine

            self._gengine = ShardedGuaranteeEngine(mesh=mesh)
        else:
            self._gengine = gae.default_engine()
        # populated by fit()
        self._ae_params: Any = None
        self._corr_params: Any = None
        self._latents: Optional[np.ndarray] = None
        self._blocks: Optional[np.ndarray] = None
        self._vecs_orig: Optional[np.ndarray] = None
        self._data: Optional[np.ndarray] = None
        self._shape: Optional[tuple[int, int, int, int]] = None
        self._data_nbytes: int = 0
        self._norm: Optional[tuple[np.ndarray, np.ndarray]] = None
        # tau-independent guarantee state per (latent_bin, skip_correction)
        self._prepared: dict[tuple, tuple] = {}
        # most recent PreparedGuarantee — seed for the engine's
        # shared-residual incremental prepare on the next sweep key
        self._last_prepared: Optional[gae.PreparedGuarantee] = None
        # packed (decoder, correction) wire streams, constant per fit
        self._packed_params: Optional[tuple] = None

    _PREPARED_CACHE_MAX = 4  # GBATC + GBA at a couple of latent bins

    def set_guarantee_engine(self, engine) -> None:
        """Swap the guarantee engine (e.g. a mesh-sharded one). Clears the
        tau-independent prepared cache: PreparedGuarantee tensors are
        staged per engine (device-resident vs host-chunked), so prepared
        state never crosses engines."""
        self._gengine = engine
        self._prepared.clear()
        self._last_prepared = None

    # ------------------------------------------------------------------
    @staticmethod
    def _normalize(data: np.ndarray):
        mn = data.min(axis=(1, 2, 3))
        mx = data.max(axis=(1, 2, 3))
        rng = np.maximum(mx - mn, 1e-30)
        normed = (data - mn[:, None, None, None]) / rng[:, None, None, None]
        return normed.astype(np.float32), mn.astype(np.float32), rng.astype(np.float32)

    def fit(self, data: np.ndarray, verbose: bool = False) -> dict:
        """Train the AE (and correction net) once; returns training stats."""
        assert data.shape[0] == self.n_species
        normed, mn, rngs = self._normalize(data)
        blocks = blocking.to_blocks(normed, self.cfg.geometry)
        return self._fit_blocks(
            blocks, mn, rngs, shape=tuple(data.shape),
            data_nbytes=data.nbytes, data=data, verbose=verbose,
        )

    def fit_stream(self, loader, verbose: bool = False, *,
                   loader_retries: int = 2, retry_backoff: float = 0.1,
                   _sleep=None) -> dict:
        """Train from time-chunked input without materializing the field.

        ``loader`` exposes a re-iterable ``chunks()`` yielding consecutive
        (S, Tc, H, W) time chunks, each Tc divisible by the geometry's
        ``bt`` so per-chunk blocks concatenate into the canonical
        time-major block order. Two passes: per-species running min/max
        (exact — min/max commute with chunking), then normalize+block each
        chunk. The training inputs — and therefore the fitted artifact —
        are **bit-identical** to ``fit(concatenate(chunks, axis=1))``; only
        the peak memory differs (one chunk plus the block array instead of
        the full field plus its normalized copy).

        Transient loader faults — ``OSError``/``IOError`` raised during
        chunk iteration — restart the *failing pass* from its beginning
        (both passes are pure functions of the re-iterable loader, so a
        restart is equivalent to a clean first run and the fitted
        artifact stays bit-identical): up to ``loader_retries`` restarts
        per pass with exponential backoff starting at ``retry_backoff``
        seconds. Validation errors (wrong shapes, misaligned chunks)
        propagate immediately. ``_sleep`` overrides the backoff sleep
        (tests).

        The original field is not retained, so ``compress`` reports
        per-species NRMSE from the normalized block vectors (equal to the
        data-space NRMSE up to float rounding: per-species min/max
        normalization makes the range exactly 1).
        """
        from repro.train.fault_tolerance import retry_with_backoff

        cfg = self.cfg
        geom = cfg.geometry
        retry = dict(
            max_retries=loader_retries, backoff=retry_backoff,
            retry_on=(OSError, IOError),
            **({} if _sleep is None else {"sleep": _sleep}),
        )

        def pass_ranges():
            # accumulators local to the pass: a mid-iteration fault
            # restarts with a clean slate, never double-counts a chunk
            mn = mx = None
            t_total = 0
            nbytes = 0
            spatial = None
            for chunk in loader.chunks():
                chunk = np.asarray(chunk)
                if chunk.ndim != 4 or chunk.shape[0] != self.n_species:
                    raise ValueError(
                        f"chunk shape {chunk.shape} does not match "
                        f"(S={self.n_species}, Tc, H, W)"
                    )
                if chunk.shape[1] == 0 or chunk.shape[1] % geom.bt:
                    raise ValueError(
                        f"chunk spans {chunk.shape[1]} frames, not a positive "
                        f"multiple of block depth bt={geom.bt}"
                    )
                if spatial is None:
                    spatial = chunk.shape[2:]
                elif chunk.shape[2:] != spatial:
                    raise ValueError(
                        f"chunk grid {chunk.shape[2:]} != first chunk {spatial}"
                    )
                cmn = chunk.min(axis=(1, 2, 3))
                cmx = chunk.max(axis=(1, 2, 3))
                mn = cmn if mn is None else np.minimum(mn, cmn)
                mx = cmx if mx is None else np.maximum(mx, cmx)
                t_total += chunk.shape[1]
                nbytes += chunk.nbytes
            if mn is None:
                raise ValueError("loader yielded no chunks")
            return mn, mx, t_total, nbytes, spatial

        mn, mx, t_total, nbytes, spatial = retry_with_backoff(
            pass_ranges, **retry
        )
        rngs = np.maximum(mx - mn, 1e-30)
        shape = (self.n_species, t_total, *spatial)
        blocking.check_divisible(shape, geom)
        h, w = spatial
        per_frame = (h // geom.ph) * (w // geom.pw)
        nb = (t_total // geom.bt) * per_frame

        def normed_parts():
            for chunk in loader.chunks():
                chunk = np.asarray(chunk)
                normed = (
                    (chunk - mn[:, None, None, None])
                    / rngs[:, None, None, None]
                ).astype(np.float32)
                yield blocking.to_blocks(normed, geom)

        if self.mesh is not None:
            from repro.parallel.mesh_fit import ShardedBlockStore

            def pass_blocks():
                # mesh ingest: each chunk's blocks land straight in the
                # row-sharded device buffer — the host holds one chunk at
                # a time and the full normalized field only ever exists
                # sharded across the mesh. A restart refills a fresh store.
                store = ShardedBlockStore(
                    nb, (self.n_species, geom.bt, geom.ph, geom.pw),
                    self.mesh,
                )
                for part in normed_parts():
                    store.append(part)
                return store.finish()
        else:
            def pass_blocks():
                # preallocate and fill per chunk: peak memory stays one full
                # block array plus one chunk, never the transient 2x a
                # concat would cost. Allocated inside the pass so a restart
                # refills from row 0 of a fresh array.
                blocks = _host_alloc(
                    (nb, self.n_species, geom.bt, geom.ph, geom.pw),
                    np.float32,
                )
                row = 0
                for part in normed_parts():
                    blocks[row : row + part.shape[0]] = part
                    row += part.shape[0]
                return blocks

        blocks = retry_with_backoff(pass_blocks, **retry)
        return self._fit_blocks(
            blocks, mn.astype(np.float32), rngs.astype(np.float32),
            shape=shape, data_nbytes=nbytes, data=None, verbose=verbose,
        )

    def _fit_blocks(self, blocks: np.ndarray, mn: np.ndarray,
                    rngs: np.ndarray, *, shape, data_nbytes: int,
                    data: Optional[np.ndarray], verbose: bool) -> dict:
        """Shared fit body over normalized blocks (full or streamed input).

        ``blocks`` is a host array, or — on the mesh fit_stream path — a
        row-sharded device array: then the trainers run their DP mesh
        programs over it and the correction/guarantee feed tensors stay
        device-resident transposed views (bitwise the host layouts, the
        values being pure data movement away), so the full normalized
        field is never materialized on host during fit.
        """
        cfg = self.cfg
        on_device = not isinstance(blocks, np.ndarray)
        fit_kw = {} if self.mesh is None else {"mesh": self.mesh}
        params, losses = self.family.fit(
            self.model,
            blocks,
            steps=cfg.ae_steps,
            batch_size=cfg.batch_size,
            lr=cfg.lr,
            seed=cfg.seed,
            log_every=200 if verbose else 0,
            **fit_kw,
        )
        # honest sub-fp32 storage: round params through the container's
        # storage dtype *before* any of them are used, so the latents,
        # correction fit, and guarantee all see exactly the values the
        # serialized decoder will replay (fp32 is the identity)
        params = quantize_params(params, cfg.param_dtype_bytes)
        latents = np.asarray(_batched(self._jit_encode, params, blocks))

        corr_params = None
        if self.corr_net is not None:
            # decode through the shared fused runtime (one dispatch, no
            # chunked host round-trips); pointwise vecs are a transpose away
            ae_vecs = self._decode_vecs(params, latents, None,
                                        device=on_device)
            nb, s = blocks.shape[:2]
            if on_device:
                vec_rec = ae_vecs.transpose(1, 2, 0).reshape(-1, s)
                vec_orig = (
                    blocks.reshape(nb, s, -1).transpose(0, 2, 1)
                    .reshape(-1, s)
                )  # blocks_to_pointwise, device-resident
            else:
                vec_rec = np.ascontiguousarray(
                    ae_vecs.transpose(1, 2, 0).reshape(-1, self.n_species)
                )
                vec_orig = correction.blocks_to_pointwise(blocks)
            corr_params, _ = correction.fit(
                self.corr_net, vec_rec, vec_orig,
                steps=cfg.corr_steps, seed=cfg.seed + 1,
                **fit_kw,
            )
            corr_params = quantize_params(corr_params, cfg.param_dtype_bytes)

        self._ae_params = params
        self._corr_params = corr_params
        self._latents = latents
        self._blocks = blocks
        if on_device:
            nb, s = blocks.shape[:2]
            # blocks_as_vectors, device-resident; gae.prepare converts at
            # compress time (compress-stage host mirrors are by design —
            # the out-of-core constraint is ingest/fit)
            self._vecs_orig = blocks.reshape(nb, s, -1).transpose(1, 0, 2)
        else:
            self._vecs_orig = blocking.blocks_as_vectors(blocks)
        self._data = data
        self._shape = tuple(shape)
        self._data_nbytes = int(data_nbytes)
        self._norm = (mn, rngs)
        self._prepared.clear()
        self._last_prepared = None
        self._packed_params = None
        return {"final_ae_loss": losses[-1] if len(losses) else float("nan")}

    # ------------------------------------------------------------------
    def _decode_vecs(self, ae_params, latents: np.ndarray,
                     corr_params=None, device: bool = False) -> np.ndarray:
        """Latents -> corrected (S, NB, D) vectors via the shared fused
        decode runtime (the same compiled program ``codec.decompress``
        replays, so encode-side guarantees see bit-identical x_rec).
        ``device=True`` skips the host fetch (mesh fit keeps the
        correction feed device-resident)."""
        from repro import codec

        rt = codec._runtime(self.cfg, self.n_species,
                            corr_params is not None)
        lat32 = np.ascontiguousarray(np.asarray(latents, dtype=np.float32))
        out = codec._fused_vecs(rt, ae_params, corr_params, lat32)
        return out if device else np.asarray(out)

    def _prepare_guarantee(self, latent_bin_rel: float, skip_correction: bool):
        """Decode + tau-independent guarantee prep, cached per sweep key.

        Cold keys seed the engine's shared-residual incremental prepare
        with the most recent prepared state: species whose reconstruction
        is unchanged (e.g. toggling ``skip_correction`` on a pipeline with
        no correction net) reuse their PCA/projection/energy-ordering."""
        lat_bin = float(latent_bin_rel * max(self._latents.std(), 1e-12))
        key = (lat_bin, bool(skip_correction))
        hit = self._prepared.get(key)
        if hit is not None:
            return hit
        lat_q = quantize(self._latents, lat_bin)
        corr_params = None if skip_correction else self._corr_params
        vecs_rec = self._decode_vecs(
            self._ae_params, dequantize(lat_q, lat_bin), corr_params
        )
        prepared = self._gengine.prepare(
            self._vecs_orig, vecs_rec, reuse=self._last_prepared
        )
        self._last_prepared = prepared
        # latent wire streams are NOT packed here — the artifact packs
        # lazily per requested layout (sharded v3 by default, the single
        # chain only if a legacy version asks) into this shared memo, so
        # a sweep pays each pack once and a pure-report sweep pays none
        entry = (prepared, lat_q, lat_bin, corr_params, {})
        # bounded FIFO: each entry pins several (S, NB, D) fp64 tensors, and
        # a latent_bin_rel sweep would otherwise accumulate one per value
        while len(self._prepared) >= self._PREPARED_CACHE_MAX:
            self._prepared.pop(next(iter(self._prepared)))
        self._prepared[key] = entry
        return entry

    def _packed_param_streams(self) -> tuple:
        """Pre-packed decoder/correction wire streams, cached per fit —
        a target_nrmse sweep serializes many artifacts off one fitted
        model, and the parameter streams are identical in all of them."""
        if self._packed_params is None:
            from repro import codec

            self._packed_params = codec.pack_artifact_params(
                self._ae_params, self._corr_params, self.cfg.param_dtype_bytes
            )
        return self._packed_params

    def compress(
        self,
        target_nrmse: float = 1e-3,
        latent_bin_rel: float = 0.05,
        coeff_bin: float = 0.0,
        skip_correction: bool = False,
    ) -> CompressionReport:
        """Cheap per-error-bound pass reusing the fitted networks.

        ``skip_correction=True`` reports the GBA variant off the same fitted
        AE (the correction net is trained after the AE, so GBA and GBATC
        legitimately share the encoder — paper §II-C). Sweeping
        ``target_nrmse`` reuses the cached tau-independent guarantee state,
        so each additional error bound costs only the engine's select pass."""
        if self._latents is None:
            raise RuntimeError("call fit() first")
        cfg = self.cfg
        geom = cfg.geometry
        shape = self._shape
        mn, rngs = self._norm

        prepared, lat_q, lat_bin, corr_params, latent_memo = \
            self._prepare_guarantee(latent_bin_rel, skip_correction)

        d = geom.block_size
        tau = target_nrmse * np.sqrt(d)  # normalized range == 1
        corrected, arts = self._gengine.select(prepared, tau, coeff_bin)

        artifact = CompressedArtifact(
            latent_q=lat_q,
            latent_bin=lat_bin,
            ae_params=self._ae_params,
            corr_params=corr_params,
            species_guarantees=arts,
            norm_min=mn,
            norm_range=rngs,
            shape=shape,
            cfg=cfg,
            _param_streams=self._packed_param_streams(),
            _latent_memo=latent_memo,
        )

        rec_blocks = blocking.vectors_as_blocks(corrected, geom)
        rec_normed = blocking.from_blocks(rec_blocks, shape, geom)
        recon = rec_normed * rngs[:, None, None, None] + mn[:, None, None, None]

        bb = artifact.byte_breakdown()
        if self._data is not None:
            per_species = np.array(
                [metrics.nrmse(self._data[s], recon[s])
                 for s in range(self.n_species)]
            )
        else:
            # streamed fit: the original field was never materialized.
            # NRMSE is range-normalized and per-species min/max
            # normalization makes the range exactly 1, so the normalized
            # block-vector RMS *is* the NRMSE (up to float rounding; the
            # guarantee itself is enforced in normalized units either way)
            err = corrected - np.asarray(self._vecs_orig)
            per_species = np.sqrt(np.mean(np.square(err), axis=(1, 2)))
        return CompressionReport(
            recon=recon.astype(np.float32),
            compression_ratio=self._data_nbytes / bb["total"],
            mean_nrmse=float(per_species.mean()),
            per_species_nrmse=per_species,
            bytes_breakdown=bb,
            artifact=artifact,
        )

    def fit_compress(self, data: np.ndarray, verbose: bool = False,
                     target_nrmse: float = 1e-3, **kw) -> CompressionReport:
        self.fit(data, verbose=verbose)
        return self.compress(target_nrmse=target_nrmse, **kw)

    # ------------------------------------------------------------------
    def decompress(self, artifact: CompressedArtifact) -> np.ndarray:
        """Replay stored streams only (no access to the original data).

        Compatibility wrapper over ``repro.codec.reconstruct``: the decode
        structure — geometry, AE shape, whether correction runs — comes
        from the *artifact*, never from this pipeline's config. An artifact
        whose structure disagrees with this pipeline raises rather than
        silently decoding with the wrong networks (the seed would e.g. let
        a GBA-configured pipeline skip a GBATC artifact's correction); an
        artifact that only differs in correction presence decodes fine, so
        GBA reports off a shared encoder keep working.
        """
        # family-aware structural identity; correction presence and param
        # storage width may legitimately differ (GBA reports off a shared
        # encoder, fp16-stored params), so neutralize those fields
        a = dataclasses.replace(
            _structural(artifact.cfg), use_correction=False,
            param_dtype_bytes=4,
        )
        p = dataclasses.replace(
            self.scfg, use_correction=False, param_dtype_bytes=4
        )
        if a != p or len(artifact.norm_min) != self.n_species:
            raise ValueError(
                f"artifact structure (family={a.family}, geometry={a.geometry}, "
                f"latent={a.latent}, arch={a.arch}, S={len(artifact.norm_min)}) "
                f"does not match this pipeline (family={p.family}, "
                f"geometry={p.geometry}, latent={p.latent}, arch={p.arch}, "
                f"S={self.n_species}); use repro.codec.decompress / "
                f"codec.reconstruct, which derive everything from the artifact"
            )
        from repro import codec

        return codec.reconstruct(artifact)



class GBATCCodec:
    """Bytes-in/bytes-out GBATC (or GBA, via ``cfg.use_correction=False``).

    Usage::

        codec = GBATCCodec(PipelineConfig(...))
        codec.fit(data)                       # train AE (+ correction) once
        blob = codec.compress(target_nrmse=1e-3)   # -> container bytes
        field = repro.codec.decompress(blob)       # anywhere, no codec

    ``compress(data=...)`` fits on the given data first (refitting if the
    codec was already fitted), so one-shot compression is a single call;
    ``fit_stream(loader)`` consumes time-chunked input without ever
    materializing the full field (see :meth:`GBATCPipeline.fit_stream`).
    Error-bound sweeps against one fitted model reuse the pipeline's
    cached tau-independent guarantee state. ``PipelineConfig(family=
    "attention")`` compresses through the block attention family instead
    of the conv AE — same container, same guarantee engine (see
    :mod:`repro.codec.families`).

    The class lives with the orchestration layer (it owns a fit), and
    ``repro.codec.GBATCCodec`` re-exports it; the decode side of the
    codec package never imports this module.
    """

    def __init__(self, cfg: Optional[PipelineConfig] = None,
                 n_species: Optional[int] = None, mesh=None):
        self.cfg = cfg if cfg is not None else PipelineConfig()
        self.mesh = mesh
        self._pipe: Optional[GBATCPipeline] = (
            GBATCPipeline(self.cfg, n_species, mesh=mesh)
            if n_species is not None else None
        )

    @property
    def pipeline(self) -> Optional[GBATCPipeline]:
        """The underlying fit/orchestration layer (None before first fit)."""
        return self._pipe

    @property
    def fitted(self) -> bool:
        return self._pipe is not None and self._pipe._latents is not None

    def fit(self, data: np.ndarray, verbose: bool = False) -> "GBATCCodec":
        data = np.asarray(data)
        if data.ndim != 4:
            raise ValueError(
                f"expected (S, T, H, W) species data, got "
                f"{data.ndim}-d {type(data).__name__} of shape {data.shape}"
                " (note: compress(target_nrmse=...) is keyword-only via the"
                " data-first signature)"
            )
        if self._pipe is None or self._pipe.n_species != data.shape[0]:
            self._pipe = GBATCPipeline(self.cfg, n_species=data.shape[0],
                                       mesh=self.mesh)
        self._pipe.fit(data, verbose=verbose)
        return self

    def fit_stream(self, loader, verbose: bool = False, *,
                   loader_retries: int = 2, retry_backoff: float = 0.1,
                   _sleep=None) -> "GBATCCodec":
        """Fit from time-chunked input without materializing the field.

        ``loader`` must expose ``shape`` — the full (S, T, H, W) — and a
        re-iterable ``chunks()`` yielding consecutive (S, Tc, H, W) time
        chunks (each Tc divisible by the block geometry's ``bt``), e.g.
        :class:`repro.data.s3d.S3DChunkLoader`. The fit is bit-identical
        to ``fit(concatenate(chunks, axis=1))``.

        Transient loader faults (I/O errors mid-iteration) restart the
        failing pass from its beginning with exponential backoff — up to
        ``loader_retries`` restarts per pass, ``retry_backoff`` seconds
        doubling per attempt — and the result stays bit-identical to a
        clean run (each pass is a pure function of the re-iterated
        chunks). Shape/validation errors are never retried.
        """
        s = int(loader.shape[0])
        if self._pipe is None or self._pipe.n_species != s:
            self._pipe = GBATCPipeline(self.cfg, n_species=s,
                                       mesh=self.mesh)
        self._pipe.fit_stream(
            loader, verbose=verbose, loader_retries=loader_retries,
            retry_backoff=retry_backoff, _sleep=_sleep,
        )
        return self

    def compress(self, data: Optional[np.ndarray] = None,
                 target_nrmse: float = 1e-3, **kw) -> bytes:
        """Compress to container bytes; pass ``data`` to (re)fit first."""
        blob, _ = self.compress_report(data, target_nrmse=target_nrmse, **kw)
        return blob

    def compress_report(
        self, data: Optional[np.ndarray] = None,
        target_nrmse: float = 1e-3, **kw,
    ) -> tuple[bytes, CompressionReport]:
        """Like :meth:`compress`, also returning the quality report."""
        if data is not None:
            self.fit(data)
        if not self.fitted:
            raise RuntimeError("codec not fitted: pass data or call fit() first")
        rep = self._pipe.compress(target_nrmse=target_nrmse, **kw)
        return rep.artifact.to_bytes(), rep

    def write(self, path, data: Optional[np.ndarray] = None,
              target_nrmse: float = 1e-3, **kw) -> bytes:
        """Compress and atomically publish the container at ``path``
        (tmp + fsync + rename — a crash can never leave a half-blob).
        Pass ``data`` to (re)fit first. Returns the written bytes."""
        from repro.codec.encode import write as write_file

        blob = self.compress(data, target_nrmse=target_nrmse, **kw)
        write_file(path, blob)
        return blob

    @staticmethod
    def read(path, *, verify: bool = True) -> bytes:
        """Read (and by default digest-verify) a container file; see
        :func:`repro.codec.read`."""
        from repro.codec.encode import read as read_file

        return read_file(path, verify=verify)

    @staticmethod
    def decompress(blob: bytes, *, species=None, time_range=None,
                   on_error: str = "raise"):
        """Decode a container blob (stateless; see
        :func:`repro.codec.decompress`).

        ``species``/``time_range`` select a slice to decode
        randomly-accessed, bitwise equal to slicing the full decode;
        ``on_error="salvage"`` quarantines corruption and returns
        ``(field, DecodeReport)``."""
        from repro.codec.decode import decompress

        return decompress(blob, species=species, time_range=time_range,
                          on_error=on_error)
