"""End-to-end GBA / GBATC compression pipeline (paper §II, Fig. 3).

Workflow (matches the paper's):

  pipe = GBATCPipeline(cfg, n_species=S)
  pipe.fit(data)                       # train AE (+ correction net) ONCE
  rep = pipe.compress(target_nrmse=1e-3, latent_bin_rel=0.05)   # cheap sweep
  rec = pipe.decompress(rep.artifact)  # streams-only replay

Stages:
  1. per-species min/max normalization (species span ~7 decades; the NRMSE
     metric is range-normalized, so the guarantee runs in normalized units);
  2. spatiotemporal blocking (paper geometry 4 x 5 x 4);
  3. 3D-conv block AE; latents quantized + Huffman'd (the decoder consumes
     the *quantized* latents so encode/decode stay consistent);
  4. (GBATC) pointwise tensor-correction network on reconstructed->original
     species vectors;
  5. device-resident guarantee engine (Algorithm 1): one batched (S, NB, D)
     dispatch through ``gae.GuaranteeEngine`` — Pallas projection and
     masked select-and-accumulate kernels plus jitted fp64 selection — with
     tau_s = target_nrmse * sqrt(D) (normalized range = 1). The engine's
     tau-independent state (residual PCA, projections, energy ordering) is
     cached per (latent_bin, correction) so sweeping error bounds against
     one fitted model pays it once; decompress replays corrections through
     the same batched kernel path;
  6. serialization through :mod:`repro.codec`: ``artifact.to_bytes()`` emits
     the versioned container (latent stream + decoder params + correction
     params + ONE combined guarantee stream — a CSR-of-CSR directory over
     species fronting the {coeff, CSR index bitmap, basis} sub-streams,
     container v2; v1's per-species nested containers still decode) and
     ``byte_breakdown`` is a view over the container's *measured* stream
     lengths — ``breakdown["total"] == len(blob)`` exactly, no estimates.
     Consumers that want one species or a time window decode the blob
     randomly-accessed via ``repro.codec.decompress(blob, species=...,
     time_range=...)`` / ``repro.codec.PartialDecoder`` — bitwise equal to
     slicing the full decode, without parsing unselected streams.

This class is the fit/orchestration layer; the wire format and the
standalone decode path live in :mod:`repro.codec` (``compress`` returns an
in-memory report whose artifact serializes via the codec, and
``decompress`` is a compatibility wrapper over ``codec.reconstruct`` that
derives decode structure from the *artifact*, not from this pipeline's
config). Training runs on the compiled mini-batch engine
(:mod:`repro.train.train_loop` — device-resident data, cached programs, no
per-step host sync), and every decode — including the one feeding the
guarantee prep — goes through the codec's shared fused runtime, so the
reconstruction the guarantee is computed against is bit-identical to the
one ``codec.decompress`` replays. Nothing re-traces across fit/compress/
decompress calls.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import autoencoder as ae
from repro.core import blocking, correction, entropy, gae, metrics
from repro.core.quantization import dequantize, quantize, quantize_params


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    geometry: blocking.BlockGeometry = blocking.PAPER_GEOMETRY
    latent: int = 36
    conv_channels: tuple[int, ...] = (32, 64)
    use_correction: bool = True  # GBATC if True, GBA if False
    ae_steps: int = 600
    corr_steps: int = 300
    batch_size: int = 64
    lr: float = 2e-3
    seed: int = 0
    # paper stores networks fp32; fp16 halves the fixed overhead with
    # negligible NRMSE impact (beyond-paper option, default off)
    param_dtype_bytes: int = 4


@dataclasses.dataclass
class CompressedArtifact:
    latent_q: np.ndarray  # (NB, latent) int64
    latent_bin: float
    ae_params: Any
    corr_params: Optional[Any]
    species_guarantees: list[gae.GuaranteeArtifact]
    norm_min: np.ndarray  # (S,)
    norm_range: np.ndarray  # (S,)
    shape: tuple[int, int, int, int]
    cfg: PipelineConfig
    # memoized wire streams (immutable once built): the Huffman'd latent
    # payload, pre-packed (decoder, correction) parameter streams shared
    # across a sweep's artifacts, and the full serialized container
    _latent_blob: Optional[bytes] = dataclasses.field(
        default=None, repr=False, compare=False
    )
    _param_streams: Optional[tuple] = dataclasses.field(
        default=None, repr=False, compare=False
    )
    _wire: Optional[bytes] = dataclasses.field(
        default=None, repr=False, compare=False
    )

    def latent_blob(self) -> bytes:
        if self._latent_blob is None:
            self._latent_blob = entropy.huffman_encode(self.latent_q)
        return self._latent_blob

    def latent_bytes(self) -> int:
        return len(self.latent_blob())

    def to_bytes(self) -> bytes:
        """Serialize to the self-describing container (see repro.codec)."""
        if self._wire is None:
            from repro import codec

            self._wire = codec.encode(self)
        return self._wire

    @classmethod
    def from_bytes(cls, blob: bytes) -> "CompressedArtifact":
        """Rebuild an artifact from container bytes (repro.codec wire format)."""
        from repro import codec

        return codec.decode_artifact(blob)

    def byte_breakdown(
        self,
        model: Optional[ae.BlockAutoencoder] = None,
        corr_net: Optional[correction.TensorCorrectionNetwork] = None,
    ) -> dict:
        """Measured per-stream byte accounting of the serialized container.

        A view over the container's stream table — every entry is the real
        on-wire length and ``breakdown["total"] == len(self.to_bytes())``
        exactly. ``model``/``corr_net`` are accepted for backward
        compatibility but unused: the container carries the parameter
        streams itself.
        """
        del model, corr_net
        from repro import codec

        return codec.stream_breakdown(self.to_bytes())


@dataclasses.dataclass
class CompressionReport:
    recon: np.ndarray
    compression_ratio: float
    mean_nrmse: float
    per_species_nrmse: np.ndarray
    bytes_breakdown: dict
    artifact: CompressedArtifact


class GBATCPipeline:
    """GBATC when cfg.use_correction else GBA."""

    def __init__(self, cfg: PipelineConfig, n_species: int):
        self.cfg = cfg
        self.n_species = n_species
        block = (cfg.geometry.bt, cfg.geometry.ph, cfg.geometry.pw)
        self.model = ae.BlockAutoencoder(
            ae.AEConfig(
                n_species=n_species,
                block=block,
                latent=cfg.latent,
                conv_channels=cfg.conv_channels,
            )
        )
        self.corr_net = (
            correction.TensorCorrectionNetwork(
                correction.CorrectionConfig(n_species=n_species)
            )
            if cfg.use_correction
            else None
        )
        # jitted once per instance: rebuilding jax.jit(...) per call would
        # re-trace (and re-compile) on every compress/decompress
        self._jit_encode = jax.jit(self.model.encode)
        self._gengine = gae.default_engine()
        # populated by fit()
        self._ae_params: Any = None
        self._corr_params: Any = None
        self._latents: Optional[np.ndarray] = None
        self._blocks: Optional[np.ndarray] = None
        self._vecs_orig: Optional[np.ndarray] = None
        self._data: Optional[np.ndarray] = None
        self._norm: Optional[tuple[np.ndarray, np.ndarray]] = None
        # tau-independent guarantee state per (latent_bin, skip_correction)
        self._prepared: dict[tuple, tuple] = {}
        # most recent PreparedGuarantee — seed for the engine's
        # shared-residual incremental prepare on the next sweep key
        self._last_prepared: Optional[gae.PreparedGuarantee] = None
        # packed (decoder, correction) wire streams, constant per fit
        self._packed_params: Optional[tuple] = None

    _PREPARED_CACHE_MAX = 4  # GBATC + GBA at a couple of latent bins

    # ------------------------------------------------------------------
    @staticmethod
    def _normalize(data: np.ndarray):
        mn = data.min(axis=(1, 2, 3))
        mx = data.max(axis=(1, 2, 3))
        rng = np.maximum(mx - mn, 1e-30)
        normed = (data - mn[:, None, None, None]) / rng[:, None, None, None]
        return normed.astype(np.float32), mn.astype(np.float32), rng.astype(np.float32)

    def fit(self, data: np.ndarray, verbose: bool = False) -> dict:
        """Train the AE (and correction net) once; returns training stats."""
        cfg = self.cfg
        assert data.shape[0] == self.n_species
        normed, mn, rngs = self._normalize(data)
        blocks = blocking.to_blocks(normed, cfg.geometry)

        params, losses = ae.fit(
            self.model,
            blocks,
            steps=cfg.ae_steps,
            batch_size=cfg.batch_size,
            lr=cfg.lr,
            seed=cfg.seed,
            log_every=200 if verbose else 0,
        )
        # honest sub-fp32 storage: round params through the container's
        # storage dtype *before* any of them are used, so the latents,
        # correction fit, and guarantee all see exactly the values the
        # serialized decoder will replay (fp32 is the identity)
        params = quantize_params(params, cfg.param_dtype_bytes)
        latents = np.asarray(_batched(self._jit_encode, params, blocks))

        corr_params = None
        if self.corr_net is not None:
            # decode through the shared fused runtime (one dispatch, no
            # chunked host round-trips); pointwise vecs are a transpose away
            ae_vecs = self._decode_vecs(params, latents, None)
            vec_rec = np.ascontiguousarray(
                ae_vecs.transpose(1, 2, 0).reshape(-1, self.n_species)
            )
            vec_orig = correction.blocks_to_pointwise(blocks)
            corr_params, _ = correction.fit(
                self.corr_net, vec_rec, vec_orig,
                steps=cfg.corr_steps, seed=cfg.seed + 1,
            )
            corr_params = quantize_params(corr_params, cfg.param_dtype_bytes)

        self._ae_params = params
        self._corr_params = corr_params
        self._latents = latents
        self._blocks = blocks
        self._vecs_orig = blocking.blocks_as_vectors(blocks)
        self._data = data
        self._norm = (mn, rngs)
        self._prepared.clear()
        self._last_prepared = None
        self._packed_params = None
        return {"final_ae_loss": losses[-1] if len(losses) else float("nan")}

    # ------------------------------------------------------------------
    def _decode_vecs(self, ae_params, latents: np.ndarray,
                     corr_params=None) -> np.ndarray:
        """Latents -> corrected (S, NB, D) vectors via the shared fused
        decode runtime (the same compiled program ``codec.decompress``
        replays, so encode-side guarantees see bit-identical x_rec)."""
        from repro import codec

        rt = codec._runtime(self.cfg, self.n_species,
                            corr_params is not None)
        lat32 = np.ascontiguousarray(np.asarray(latents, dtype=np.float32))
        return np.asarray(codec._fused_vecs(rt, ae_params, corr_params, lat32))

    def _prepare_guarantee(self, latent_bin_rel: float, skip_correction: bool):
        """Decode + tau-independent guarantee prep, cached per sweep key.

        Cold keys seed the engine's shared-residual incremental prepare
        with the most recent prepared state: species whose reconstruction
        is unchanged (e.g. toggling ``skip_correction`` on a pipeline with
        no correction net) reuse their PCA/projection/energy-ordering."""
        lat_bin = float(latent_bin_rel * max(self._latents.std(), 1e-12))
        key = (lat_bin, bool(skip_correction))
        hit = self._prepared.get(key)
        if hit is not None:
            return hit
        lat_q = quantize(self._latents, lat_bin)
        corr_params = None if skip_correction else self._corr_params
        vecs_rec = self._decode_vecs(
            self._ae_params, dequantize(lat_q, lat_bin), corr_params
        )
        prepared = self._gengine.prepare(
            self._vecs_orig, vecs_rec, reuse=self._last_prepared
        )
        self._last_prepared = prepared
        latent_blob = entropy.huffman_encode(lat_q)
        entry = (prepared, lat_q, lat_bin, corr_params, latent_blob)
        # bounded FIFO: each entry pins several (S, NB, D) fp64 tensors, and
        # a latent_bin_rel sweep would otherwise accumulate one per value
        while len(self._prepared) >= self._PREPARED_CACHE_MAX:
            self._prepared.pop(next(iter(self._prepared)))
        self._prepared[key] = entry
        return entry

    def _packed_param_streams(self) -> tuple:
        """Pre-packed decoder/correction wire streams, cached per fit —
        a target_nrmse sweep serializes many artifacts off one fitted
        model, and the parameter streams are identical in all of them."""
        if self._packed_params is None:
            from repro import codec

            self._packed_params = codec.pack_artifact_params(
                self._ae_params, self._corr_params, self.cfg.param_dtype_bytes
            )
        return self._packed_params

    def compress(
        self,
        target_nrmse: float = 1e-3,
        latent_bin_rel: float = 0.05,
        coeff_bin: float = 0.0,
        skip_correction: bool = False,
    ) -> CompressionReport:
        """Cheap per-error-bound pass reusing the fitted networks.

        ``skip_correction=True`` reports the GBA variant off the same fitted
        AE (the correction net is trained after the AE, so GBA and GBATC
        legitimately share the encoder — paper §II-C). Sweeping
        ``target_nrmse`` reuses the cached tau-independent guarantee state,
        so each additional error bound costs only the engine's select pass."""
        if self._latents is None:
            raise RuntimeError("call fit() first")
        cfg = self.cfg
        geom = cfg.geometry
        data = self._data
        mn, rngs = self._norm

        prepared, lat_q, lat_bin, corr_params, latent_blob = \
            self._prepare_guarantee(latent_bin_rel, skip_correction)

        d = geom.block_size
        tau = target_nrmse * np.sqrt(d)  # normalized range == 1
        corrected, arts = self._gengine.select(prepared, tau, coeff_bin)

        artifact = CompressedArtifact(
            latent_q=lat_q,
            latent_bin=lat_bin,
            ae_params=self._ae_params,
            corr_params=corr_params,
            species_guarantees=arts,
            norm_min=mn,
            norm_range=rngs,
            shape=tuple(data.shape),
            cfg=cfg,
            _latent_blob=latent_blob,
            _param_streams=self._packed_param_streams(),
        )

        rec_blocks = blocking.vectors_as_blocks(corrected, geom)
        rec_normed = blocking.from_blocks(rec_blocks, data.shape, geom)
        recon = rec_normed * rngs[:, None, None, None] + mn[:, None, None, None]

        bb = artifact.byte_breakdown()
        per_species = np.array(
            [metrics.nrmse(data[s], recon[s]) for s in range(self.n_species)]
        )
        return CompressionReport(
            recon=recon.astype(np.float32),
            compression_ratio=data.nbytes / bb["total"],
            mean_nrmse=float(per_species.mean()),
            per_species_nrmse=per_species,
            bytes_breakdown=bb,
            artifact=artifact,
        )

    def fit_compress(self, data: np.ndarray, verbose: bool = False,
                     target_nrmse: float = 1e-3, **kw) -> CompressionReport:
        self.fit(data, verbose=verbose)
        return self.compress(target_nrmse=target_nrmse, **kw)

    # ------------------------------------------------------------------
    def decompress(self, artifact: CompressedArtifact) -> np.ndarray:
        """Replay stored streams only (no access to the original data).

        Compatibility wrapper over ``repro.codec.reconstruct``: the decode
        structure — geometry, AE shape, whether correction runs — comes
        from the *artifact*, never from this pipeline's config. An artifact
        whose structure disagrees with this pipeline raises rather than
        silently decoding with the wrong networks (the seed would e.g. let
        a GBA-configured pipeline skip a GBATC artifact's correction); an
        artifact that only differs in correction presence decodes fine, so
        GBA reports off a shared encoder keep working.
        """
        a, p = artifact.cfg, self.cfg
        if (
            a.geometry != p.geometry
            or a.latent != p.latent
            or tuple(a.conv_channels) != tuple(p.conv_channels)
            or len(artifact.norm_min) != self.n_species
        ):
            raise ValueError(
                f"artifact structure (geometry={a.geometry}, latent={a.latent}, "
                f"conv={tuple(a.conv_channels)}, S={len(artifact.norm_min)}) does "
                f"not match this pipeline (geometry={p.geometry}, "
                f"latent={p.latent}, conv={tuple(p.conv_channels)}, "
                f"S={self.n_species}); use repro.codec.decompress / "
                f"codec.reconstruct, which derive everything from the artifact"
            )
        from repro import codec

        return codec.reconstruct(artifact)


def _batched(fn, params, arrays, batch: int = 512):
    """Apply an already-jitted (params, x) callable over leading-axis chunks.

    Chunk shapes are kept fixed: a ragged last chunk is padded (edge-row
    repeat) to the full batch size and the padding sliced off the result.
    The seed dispatched the remainder at its own shape, re-tracing and
    re-compiling the callable once per distinct tail length — the
    trace-count regression test pins this to one trace per leading shape.
    """
    n = arrays.shape[0]
    if n <= batch:
        return np.asarray(fn(params, jnp.asarray(arrays)))
    outs = []
    for i in range(0, n, batch):
        chunk = arrays[i : i + batch]
        pad = batch - chunk.shape[0]
        if pad:
            chunk = np.concatenate(
                [np.asarray(chunk),
                 np.repeat(np.asarray(chunk[-1:]), pad, axis=0)]
            )
        out = np.asarray(fn(params, jnp.asarray(chunk)))
        outs.append(out[: batch - pad] if pad else out)
    return np.concatenate(outs, axis=0)
