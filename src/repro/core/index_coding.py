"""Basis-index encoding (paper Fig. 2).

Per block, the set of selected PCA basis indices is a binary membership
sequence over basis positions. Because leading (large-eigenvalue) vectors are
selected far more often, the sequence typically ends in a run of zeros: we
store only the shortest prefix containing all ones, preceded by a 16-bit
length field. Blocks with no selected coefficients cost just the length field.
"""

from __future__ import annotations

import numpy as np


def encode_indices(index_sets: list[np.ndarray]) -> bytes:
    """Pack per-block index sets into the Fig. 2 bitstream."""
    lengths = np.array(
        [0 if ids.size == 0 else int(ids.max()) + 1 for ids in index_sets],
        dtype=np.uint16,
    )
    total_bits = int(lengths.sum())
    bits = np.zeros(total_bits, dtype=np.uint8)
    cursor = 0
    for ids, ln in zip(index_sets, lengths):
        if ln:
            bits[cursor + np.asarray(ids, dtype=np.int64)] = 1
            cursor += int(ln)
    header = np.asarray(len(index_sets), dtype="<u4").tobytes()
    return header + lengths.astype("<u2").tobytes() + np.packbits(bits).tobytes()


def decode_indices(blob: bytes) -> list[np.ndarray]:
    n = int(np.frombuffer(blob, dtype="<u4", count=1)[0])
    lengths = np.frombuffer(blob, dtype="<u2", count=n, offset=4).astype(np.int64)
    bit_payload = np.frombuffer(blob, dtype=np.uint8, offset=4 + 2 * n)
    bits = np.unpackbits(bit_payload)
    out: list[np.ndarray] = []
    cursor = 0
    for ln in lengths:
        out.append(np.nonzero(bits[cursor : cursor + ln])[0].astype(np.int64))
        cursor += int(ln)
    return out


def encoded_size_bytes(index_sets: list[np.ndarray]) -> int:
    total_bits = sum(0 if ids.size == 0 else int(ids.max()) + 1 for ids in index_sets)
    return 4 + 2 * len(index_sets) + (total_bits + 7) // 8
