"""Basis-index encoding (paper Fig. 2), CSR layout, loop-free.

Per block, the set of selected PCA basis indices is a binary membership
sequence over basis positions. Because leading (large-eigenvalue) vectors are
selected far more often, the sequence typically ends in a run of zeros: we
store only the shortest prefix containing all ones, preceded by a 16-bit
length field. Blocks with no selected coefficients cost just the length field.

The in-memory representation is CSR: ``offsets`` (NB+1, int64) and ``flat``
(nnz, int64) with each block's indices ascending. Encode/decode are pure
``cumsum``/``repeat``/``searchsorted``/``packbits`` passes — no per-block
Python loop — which is what lets the guarantee engine stream millions of
blocks through this stage. The wire format is unchanged from the seed
(list-of-sets) implementation, so old blobs decode bit-identically.
"""

from __future__ import annotations

import numpy as np


def sets_to_csr(index_sets: list[np.ndarray]) -> tuple[np.ndarray, np.ndarray]:
    """List-of-ascending-index-arrays -> (offsets, flat)."""
    counts = np.array([len(ids) for ids in index_sets], dtype=np.int64)
    offsets = np.zeros(len(index_sets) + 1, dtype=np.int64)
    np.cumsum(counts, out=offsets[1:])
    flat = (
        np.concatenate([np.asarray(ids, dtype=np.int64) for ids in index_sets])
        if offsets[-1]
        else np.zeros(0, np.int64)
    )
    return offsets, flat


def csr_to_sets(offsets: np.ndarray, flat: np.ndarray) -> list[np.ndarray]:
    """(offsets, flat) -> list of per-block index arrays (views where possible)."""
    return np.split(np.asarray(flat, dtype=np.int64), offsets[1:-1])


def _block_lengths(offsets: np.ndarray, flat: np.ndarray) -> np.ndarray:
    """Shortest prefix containing all ones, per block: last index + 1.

    Indices are ascending within a block, so the block max is the element
    just before the next offset — a single gather, no reduction loop.
    """
    counts = np.diff(offsets)
    last = flat[np.maximum(offsets[1:] - 1, 0)] if flat.size else np.zeros_like(counts)
    return np.where(counts > 0, last + 1, 0)


def encode_indices(offsets: np.ndarray, flat: np.ndarray) -> bytes:
    """Pack CSR index sets into the Fig. 2 bitstream."""
    offsets = np.asarray(offsets, dtype=np.int64)
    flat = np.asarray(flat, dtype=np.int64)
    n = len(offsets) - 1
    lengths = _block_lengths(offsets, flat)
    starts = np.zeros(n, dtype=np.int64)
    np.cumsum(lengths[:-1], out=starts[1:])
    bits = np.zeros(int(lengths.sum()), dtype=np.uint8)
    bits[flat + np.repeat(starts, np.diff(offsets))] = 1
    header = np.asarray(n, dtype="<u4").tobytes()
    return header + lengths.astype("<u2").tobytes() + np.packbits(bits).tobytes()


def decode_indices(blob: bytes) -> tuple[np.ndarray, np.ndarray]:
    """Inverse of :func:`encode_indices`; returns (offsets, flat).

    The payload must be exactly the ``ceil(total_bits / 8)`` bytes the
    encoder emits — a length-framed slice that is short or long means the
    framing (not just the content) is corrupt, and raises.
    """
    n = int(np.frombuffer(blob, dtype="<u4", count=1)[0])
    lengths = np.frombuffer(blob, dtype="<u2", count=n, offset=4).astype(np.int64)
    payload = np.frombuffer(blob, dtype=np.uint8, offset=4 + 2 * n)
    total = int(lengths.sum())
    if payload.size != (total + 7) // 8:
        raise ValueError(
            f"corrupt index stream: bitmap is {payload.size} bytes, "
            f"lengths declare {(total + 7) // 8}"
        )
    bits = np.unpackbits(payload, count=total) if total else np.zeros(0, np.uint8)
    ends = np.cumsum(lengths)
    starts = ends - lengths
    pos = np.flatnonzero(bits)
    block = np.searchsorted(ends, pos, side="right")
    flat = (pos - starts[block]).astype(np.int64)
    counts = np.bincount(block, minlength=n)
    offsets = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=offsets[1:])
    return offsets, flat


def encoded_size_bytes(offsets: np.ndarray, flat: np.ndarray) -> int:
    offsets = np.asarray(offsets, dtype=np.int64)
    flat = np.asarray(flat, dtype=np.int64)
    total_bits = int(_block_lengths(offsets, flat).sum())
    return 4 + 2 * (len(offsets) - 1) + (total_bits + 7) // 8
