"""Guaranteed autoencoder post-process (paper Algorithm 1), vectorized.

Given original blocks ``x`` and AE reconstructions ``x_rec`` (per species,
shape (NB, D)), we bound each block's residual l2 norm by tau:

  1. PCA on the full residual matrix -> orthonormal basis U (D x D).
  2. For every block whose residual norm exceeds tau: project c = U^T r,
     sort coefficients by energy c_k^2, and keep the smallest M quantized
     coefficients such that the *corrected* residual satisfies
     ||x - (x_rec + U_s c_q)||_2 <= tau.

Because U is orthonormal, the corrected residual energy after keeping a
coefficient set S with quantized values c_q is exactly

  ||r||^2 - sum_{k in S} (2 c_k c_qk - c_qk^2),

so the greedy loop of Algorithm 1 collapses to a cumulative sum over the
energy-sorted coefficients plus a searchsorted — no per-block Python loop.

The coefficient quantization bin is clamped to 1.8*tau/sqrt(D) so that even
the degenerate all-D correction meets the bound (worst-case quantization
residual sqrt(D)*bin/2 <= 0.9*tau): the guarantee is *unconditional*.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import entropy, index_coding, pca
from repro.core.quantization import dequantize, quantize


@dataclasses.dataclass
class GuaranteeArtifact:
    """Everything needed to replay the correction at decode time."""

    basis: np.ndarray  # (D, n_basis_stored) float32, leading columns of U
    coeff_q: np.ndarray  # flat int64 quantized coefficients (ascending index per block)
    index_sets: list[np.ndarray]  # per-block selected basis indices (ascending)
    coeff_bin: float
    tau: float

    # --- exact storage accounting -------------------------------------
    def coeff_bytes(self) -> int:
        return entropy.huffman_size_bytes(self.coeff_q)

    def index_bytes(self) -> int:
        return index_coding.encoded_size_bytes(self.index_sets)

    def basis_bytes(self) -> int:
        return self.basis.size * 4

    def total_bytes(self) -> int:
        # 16 bytes of per-species metadata (tau, bin as float64)
        return self.coeff_bytes() + self.index_bytes() + self.basis_bytes() + 16


def _effective_bin(coeff_bin: float, tau: float, d: int) -> float:
    cap = 1.8 * tau / np.sqrt(d)
    return float(min(coeff_bin, cap)) if coeff_bin > 0 else float(cap)


def guarantee(
    x: np.ndarray,
    x_rec: np.ndarray,
    tau: float,
    coeff_bin: float = 0.0,
) -> tuple[np.ndarray, GuaranteeArtifact]:
    """Correct ``x_rec`` so every block satisfies ||x - out||_2 <= tau.

    x, x_rec: (NB, D). Returns (corrected, artifact).
    """
    x = np.asarray(x, dtype=np.float64)
    x_rec = np.asarray(x_rec, dtype=np.float64)
    nb, d = x.shape
    residual = x - x_rec
    norms2 = np.sum(residual**2, axis=1)
    tau2 = float(tau) ** 2
    needs = norms2 > tau2

    if not needs.any():
        art = GuaranteeArtifact(
            basis=np.zeros((d, 0), np.float32),
            coeff_q=np.zeros(0, np.int64),
            index_sets=[np.zeros(0, np.int64) for _ in range(nb)],
            coeff_bin=0.0,
            tau=float(tau),
        )
        return x_rec.astype(np.float32), art

    basis, _ = pca.pca_basis(residual)  # PCA over the *entire* residual set
    bin_size = _effective_bin(coeff_bin, float(tau), d)

    coeffs = pca.project(residual[needs], basis)  # (nf, d)
    cq_int = quantize(coeffs, bin_size)
    cq = cq_int.astype(np.float64) * bin_size
    gain = 2.0 * coeffs * cq - cq**2  # energy removed per kept coefficient

    order = np.argsort(-(coeffs**2), axis=1, kind="stable")
    sorted_gain = np.take_along_axis(gain, order, axis=1)
    cum = np.cumsum(sorted_gain, axis=1)
    target = norms2[needs][:, None] - tau2
    # smallest M with cum[M-1] >= target; quantization can make `cum`
    # non-monotone by epsilon, so use a running max before the search.
    cum_monotone = np.maximum.accumulate(cum, axis=1)
    m = 1 + np.argmax(cum_monotone >= target, axis=1)
    satisfied_at_m = np.take_along_axis(cum_monotone, (m - 1)[:, None], axis=1)[:, 0]
    # Guaranteed by bin clamp, but assert rather than assume:
    slack = 1e-9 * np.maximum(norms2[needs], 1.0)
    if not np.all(satisfied_at_m >= target[:, 0] - slack):
        raise AssertionError("guarantee violated — coefficient bin clamp failed")

    # Build per-block index sets + coefficient stream (ascending index order)
    keep_mask = np.zeros_like(coeffs, dtype=bool)
    cols = np.arange(d)[None, :]
    keep_sorted = cols < m[:, None]
    np.put_along_axis(keep_mask, order, keep_sorted, axis=1)

    corrected = x_rec.copy()
    corrected[needs] += (cq * keep_mask) @ basis.T

    fix_rows = np.nonzero(needs)[0]
    index_sets: list[np.ndarray] = [np.zeros(0, np.int64) for _ in range(nb)]
    coeff_chunks: list[np.ndarray] = []
    for local, row in enumerate(fix_rows):
        ids = np.nonzero(keep_mask[local])[0].astype(np.int64)
        index_sets[row] = ids
        coeff_chunks.append(cq_int[local, ids])
    coeff_stream = (
        np.concatenate(coeff_chunks) if coeff_chunks else np.zeros(0, np.int64)
    )

    max_idx = max((int(ids.max()) for ids in index_sets if ids.size), default=-1)
    art = GuaranteeArtifact(
        basis=basis[:, : max_idx + 1].astype(np.float32),
        coeff_q=coeff_stream,
        index_sets=index_sets,
        coeff_bin=bin_size,
        tau=float(tau),
    )
    return corrected.astype(np.float32), art


def apply_correction(x_rec: np.ndarray, art: GuaranteeArtifact) -> np.ndarray:
    """Decode path: replay the stored correction on AE reconstructions."""
    out = np.asarray(x_rec, dtype=np.float64).copy()
    basis = art.basis.astype(np.float64)
    cursor = 0
    for row, ids in enumerate(art.index_sets):
        if ids.size == 0:
            continue
        c = dequantize(art.coeff_q[cursor : cursor + ids.size], art.coeff_bin)
        cursor += ids.size
        out[row] += basis[:, ids] @ c.astype(np.float64)
    return out.astype(np.float32)


def verify_guarantee(x: np.ndarray, corrected: np.ndarray, tau: float) -> bool:
    """True iff every block meets the l2 bound (with fp32 round-off slack)."""
    r = np.asarray(x, np.float64) - np.asarray(corrected, np.float64)
    norms = np.sqrt(np.sum(r**2, axis=1))
    scale = np.sqrt(np.sum(np.asarray(x, np.float64) ** 2, axis=1))
    slack = 1e-5 * np.maximum(scale, 1.0)  # fp32 storage round-off
    return bool(np.all(norms <= tau + slack))
