"""Guaranteed autoencoder post-process (paper Algorithm 1), device-resident.

Given original blocks ``x`` and AE reconstructions ``x_rec`` (per species,
shape (NB, D)), we bound each block's residual l2 norm by tau:

  1. PCA on the full residual matrix -> orthonormal basis U (D x D).
  2. For every block whose residual norm exceeds tau: project c = U^T r,
     sort coefficients by energy c_k^2, and keep the smallest M quantized
     coefficients such that the *corrected* residual satisfies
     ||x - (x_rec + U_s c_q)||_2 <= tau.

Because U is orthonormal, the corrected residual energy after keeping a
coefficient set S with quantized values c_q is exactly

  ||r||^2 - sum_{k in S} (2 c_k c_qk - c_qk^2),

so the greedy loop of Algorithm 1 collapses to a cumulative sum over the
energy-sorted coefficients plus a searchsorted — no per-block Python loop.

The coefficient quantization bin is clamped to 1.8*tau/sqrt(D) so that even
the degenerate all-D correction meets the bound (worst-case quantization
residual sqrt(D)*bin/2 <= 0.9*tau): the guarantee is *unconditional*.

Engine architecture
-------------------
:class:`GuaranteeEngine` splits the stage by what depends on the error
bound:

* ``prepare(x, x_rec)`` — everything tau-INDEPENDENT: the fp64 residual,
  per-block norms, the per-species PCA factorization (host numpy, so the
  basis is bit-identical to the :mod:`repro.core.gae_ref` oracle's), the
  projection c = R @ U as a single batched fp64 Pallas dispatch
  (``gbatc_project_batched``), and the per-block energy ordering. The
  projection, ordering, and reconstruction tensors stay device-resident.
* ``select(prepared, tau, coeff_bin)`` — the cheap per-error-bound pass:
  one jitted dispatch fuses quantization, the gain cumsum/cut (jnp ops
  under ``enable_x64``), and the masked select-and-accumulate correction
  GEMM (``gbatc_select_accumulate``); the host then assembles the CSR
  artifact with vectorized ``nonzero``/``cumsum`` passes.

``pipeline.compress`` sweeps error bounds against one fitted model, so the
prepare cost amortizes across the sweep — that, plus the loop-free artifact
assembly, is where the order-of-magnitude win over the per-species numpy
oracle comes from (see ``benchmarks/bench_guarantee.py``).

Numerical contract: quantized coefficients, index sets, and the trimmed
basis are bit-identical to the oracle's. The only reordering risk is fp64
summation-order differences (~1e-16 relative) landing exactly on a
quantization or cut boundary — probability ~1e-9 per full sweep.
"""

from __future__ import annotations

import dataclasses
import os
import struct
from concurrent.futures import ThreadPoolExecutor
from typing import Optional

import numpy as np

from repro.core import container, entropy, index_coding, pca
from repro.core.quantization import dequantize


@dataclasses.dataclass
class GuaranteeArtifact:
    """Everything needed to replay the correction at decode time.

    Index sets use a CSR layout — ``index_offsets`` (NB+1,) into
    ``index_flat`` (nnz,), ascending within each block — so encode/decode
    and correction replay are loop-free vectorized passes.
    """

    basis: np.ndarray  # (D, n_basis_stored) float32, leading columns of U
    coeff_q: np.ndarray  # flat int64 quantized coefficients (ascending index per block)
    index_offsets: np.ndarray  # (NB+1,) int64 CSR offsets
    index_flat: np.ndarray  # (nnz,) int64 selected basis indices
    coeff_bin: float
    tau: float
    # memoized stream sizes: byte accounting sweeps (bench_compression's
    # TARGETS loop) would otherwise recount identical Huffman streams
    _coeff_bytes: Optional[int] = dataclasses.field(
        default=None, repr=False, compare=False
    )
    _index_bytes: Optional[int] = dataclasses.field(
        default=None, repr=False, compare=False
    )

    @classmethod
    def empty(cls, nb: int, d: int, tau: float) -> "GuaranteeArtifact":
        return cls(
            basis=np.zeros((d, 0), np.float32),
            coeff_q=np.zeros(0, np.int64),
            index_offsets=np.zeros(nb + 1, np.int64),
            index_flat=np.zeros(0, np.int64),
            coeff_bin=0.0,
            tau=float(tau),
        )

    @property
    def n_blocks(self) -> int:
        return len(self.index_offsets) - 1

    @property
    def index_sets(self) -> list[np.ndarray]:
        """Per-block index arrays (list view of the CSR layout)."""
        return index_coding.csr_to_sets(self.index_offsets, self.index_flat)

    # --- exact storage accounting -------------------------------------
    def coeff_bytes(self) -> int:
        if self._coeff_bytes is None:
            self._coeff_bytes = entropy.huffman_size_bytes(self.coeff_q)
        return self._coeff_bytes

    def index_bytes(self) -> int:
        if self._index_bytes is None:
            self._index_bytes = index_coding.encoded_size_bytes(
                self.index_offsets, self.index_flat
            )
        return self._index_bytes

    def basis_bytes(self) -> int:
        return self.basis.size * 4

    def total_bytes(self) -> int:
        # 16 bytes of per-species metadata (tau, bin as float64)
        return self.coeff_bytes() + self.index_bytes() + self.basis_bytes() + 16

    # --- wire format ---------------------------------------------------
    # the per-species guarantee artifact header predates the container
    # and is parsed by from_bytes round-trips in tier-1; the container
    # only frames its bytes.
    _META = struct.Struct("<ddII")  # repro: allow[wire-centralization]

    def wire_parts(self) -> tuple[bytes, bytes, bytes]:
        """The (coeff, index, basis) payload streams — the single encode
        site shared by the v1 nested container (:meth:`to_bytes`) and the
        v2 combined guarantee stream (``repro.codec``)."""
        return (
            entropy.huffman_encode(self.coeff_q),
            index_coding.encode_indices(self.index_offsets, self.index_flat),
            np.ascontiguousarray(
                self.basis.astype("<f4", copy=False)).tobytes(),
        )

    def to_bytes(self) -> bytes:
        """Serialize to a nested container: coeff (Huffman), index (Fig. 2
        bitmap), basis (raw little-endian float32), meta (tau/bin/dims) —
        the container-v1 per-species layout, byte-stable across PRs."""
        coeff, index, basis = self.wire_parts()
        w = container.ContainerWriter()
        w.add("coeff", coeff)
        w.add("index", index)
        w.add("basis", basis)
        w.add("meta", self._META.pack(self.tau, self.coeff_bin,
                                      *self.basis.shape))
        return w.to_bytes()

    @classmethod
    def from_bytes(
        cls,
        blob: bytes,
        *,
        table_cache: Optional[entropy.DecodeTableCache] = None,
        huffman=None,
    ) -> "GuaranteeArtifact":
        """Inverse of :func:`to_bytes`; raises ContainerFormatError on a
        malformed blob. Stream-size memos are seeded from the measured
        payload lengths (they are exact by construction).

        ``table_cache`` memoizes Huffman decode tables across calls sharing
        a codebook; ``huffman`` overrides the coefficient decoder (the
        codec benchmark passes :func:`entropy.huffman_decode_ref` to time
        the retained pre-change deserialize path)."""
        r = container.ContainerReader(blob)
        meta = r["meta"]
        if len(meta) != cls._META.size:
            raise container.ContainerFormatError(
                f"guarantee meta stream is {len(meta)} bytes, "
                f"expected {cls._META.size}"
            )
        tau, coeff_bin, d, n_store = cls._META.unpack(meta)
        return cls.from_parts(
            tau, coeff_bin, d, n_store, r["coeff"], r["index"], r["basis"],
            table_cache=table_cache, huffman=huffman,
        )

    @classmethod
    def from_parts(
        cls,
        tau: float,
        coeff_bin: float,
        d: int,
        n_store: int,
        coeff_stream: bytes,
        index_stream: bytes,
        raw_basis: bytes,
        *,
        table_cache: Optional[entropy.DecodeTableCache] = None,
        huffman=None,
        coeff_q: Optional[np.ndarray] = None,
    ) -> "GuaranteeArtifact":
        """Assemble + validate an artifact from its wire streams.

        The single decode/validation site behind :meth:`from_bytes` (v1
        nested containers) and the codec's v2 combined guarantee stream —
        a malformed stream raises :class:`ContainerFormatError` here no
        matter which framing delivered it. ``coeff_q`` supplies
        pre-decoded coefficient symbols (the batched lockstep decode path)
        and skips the per-stream Huffman walk."""
        if huffman is None:
            huffman = entropy.huffman_decode
        if not (np.isfinite(tau) and tau >= 0):
            raise container.ContainerFormatError(f"bad tau {tau!r}")
        if not (np.isfinite(coeff_bin) and coeff_bin >= 0):
            raise container.ContainerFormatError(f"bad coeff bin {coeff_bin!r}")
        if len(raw_basis) != 4 * d * n_store:
            raise container.ContainerFormatError(
                f"basis stream is {len(raw_basis)} bytes, "
                f"expected {4 * d * n_store} for shape ({d}, {n_store})"
            )
        basis = np.frombuffer(raw_basis, dtype="<f4").reshape(d, n_store)
        try:
            if coeff_q is None:
                if huffman is entropy.huffman_decode:
                    coeff_q = huffman(coeff_stream, table_cache=table_cache)
                else:
                    coeff_q = huffman(coeff_stream)
            offsets, flat = index_coding.decode_indices(index_stream)
        except (ValueError, struct.error) as e:
            # struct.error: truncated Huffman/index headers (not a ValueError)
            raise container.ContainerFormatError(
                f"corrupt guarantee stream: {e}"
            ) from e
        if coeff_q.size != flat.size:
            raise container.ContainerFormatError(
                f"coefficient stream ({coeff_q.size}) and index stream "
                f"({flat.size}) disagree on selection count"
            )
        if coeff_q.size and coeff_bin == 0.0:
            raise container.ContainerFormatError(
                "zero coefficient bin with a non-empty coefficient stream"
            )
        if n_store > d:
            raise container.ContainerFormatError(
                f"basis claims {n_store} stored columns for dimension {d}"
            )
        if flat.size and (flat.min() < 0 or flat.max() >= n_store):
            # a well-framed but bit-flipped index payload must not scatter
            # coefficients into absent basis columns at replay time
            raise container.ContainerFormatError(
                f"index stream selects basis column "
                f"{int(flat.max() if flat.size else 0)} but only "
                f"{n_store} columns are stored"
            )
        return cls(
            basis=basis.astype(np.float32),
            coeff_q=coeff_q,
            index_offsets=offsets,
            index_flat=flat,
            coeff_bin=float(coeff_bin),
            tau=float(tau),
            _coeff_bytes=len(coeff_stream),
            _index_bytes=len(index_stream),
        )


def _effective_bin(coeff_bin: float, tau: float, d: int) -> float:
    cap = 1.8 * tau / np.sqrt(d)
    return float(min(coeff_bin, cap)) if coeff_bin > 0 else float(cap)


_POOL: Optional[ThreadPoolExecutor] = None


def _pool() -> ThreadPoolExecutor:
    """Shared worker pool for per-species numpy stages.

    Every parallelized stage writes disjoint per-species slices with pure
    per-slice arithmetic, so results are bitwise independent of scheduling.
    numpy releases the GIL, and on memory-bound elementwise chains the
    per-species split also improves cache residency.
    """
    global _POOL
    if _POOL is None:
        _POOL = ThreadPoolExecutor(max_workers=min(os.cpu_count() or 1, 8))
    return _POOL


def _stable_desc_order(energy: np.ndarray) -> np.ndarray:
    """Stable argsort of ``-energy`` along the last axis, introsort-fast.

    ``np.argsort(kind="stable")`` on fp64 is a mergesort and ~2x slower than
    introsort. Rows without duplicate keys sort identically under any
    correct comparison sort, so run the fast unstable sort everywhere and
    re-sort only the (rare) rows that actually contain ties.
    """
    neg = -energy
    order = np.argsort(neg, axis=-1)
    sorted_vals = np.take_along_axis(neg, order, axis=-1)
    ties = (sorted_vals[..., 1:] == sorted_vals[..., :-1]).any(axis=-1)
    if ties.any():
        rows = np.nonzero(ties)
        order[rows] = np.argsort(neg[rows], axis=-1, kind="stable")
    return order.astype(np.int32)


@dataclasses.dataclass
class PreparedGuarantee:
    """Tau-independent guarantee state (see GuaranteeEngine.prepare)."""

    shape: tuple[int, int, int]  # (S, NB, D)
    x_ref: np.ndarray  # the originals this state was computed against
    x_rec32: np.ndarray  # (S, NB, D) float32 host copy (fast no-fix path)
    norms2: np.ndarray  # (S, NB) float64 residual energies (host)
    basis: np.ndarray  # (S, D, D) float64 PCA bases (host, oracle-bitwise)
    inv_rank: np.ndarray  # (S, NB, D) int32 energy rank of each element (host)
    coeffs: np.ndarray  # (S, NB, D) float64 projections (host mirror)
    coeffs_sorted: np.ndarray  # (S, NB, D) float64, energy-descending per block
    # device-resident tensors (jax arrays; None when a backend never reads them)
    coeffs_dev: object  # (S, NB, D) float64 projections (jit selection backend)
    coeffs_sorted_dev: object  # (S, NB, D) float64 (jit selection backend)
    inv_rank_dev: object  # (S, NB, D) int32 rank of each element
    norms2_dev: object  # (S, NB) float64
    x_rec_dev: object  # (S, NB, D) float32
    basis32_dev: object  # (S, D, D) float32


class GuaranteeEngine:
    """Batched-over-species, device-resident Algorithm 1.

    ``interpret`` defaults to True off-TPU (Pallas interpret mode); tile
    sizes default to one grid step per dispatch under interpret mode and to
    TPU-friendly (1 species, 512 rows) tiles otherwise.

    ``select_backend`` picks where the coefficient-selection math (the
    quantized-gain cumsum and its first crossing) runs:

    * ``"jit"`` — jittable jnp ops, fused with the select-and-accumulate
      kernel in one dispatch (the accelerator path);
    * ``"host"`` — the same arithmetic in numpy; on CPU backends numpy's
      sequential cumsum beats XLA's log-depth scan ~3x, and it makes the
      cumulative gains bit-identical to the numpy oracle rather than
      identical-up-to-scan-order.

    Both backends call the Pallas kernels for the projection and the
    masked-correction GEMMs, and both produce oracle-bit-identical
    artifacts; the default follows ``interpret``.
    """

    def __init__(
        self,
        interpret: Optional[bool] = None,
        species_per_tile: Optional[int] = None,
        rows_per_tile: Optional[int] = None,
        lane: Optional[int] = None,
        select_backend: Optional[str] = None,
    ):
        import jax

        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        self.interpret = interpret
        if not interpret:
            species_per_tile = species_per_tile or 1
            rows_per_tile = rows_per_tile or 512
        self.species_per_tile = species_per_tile
        self.rows_per_tile = rows_per_tile
        self.lane = lane
        if select_backend is None:
            select_backend = "host" if interpret else "jit"
        if select_backend not in ("host", "jit"):
            raise ValueError(f"unknown select_backend {select_backend!r}")
        self.select_backend = select_backend
        self._project_jit = None
        self._select_jit = None
        self._correct_jit = None
        self._apply_jit = None

    # -- jitted stages -------------------------------------------------
    def _kernel_opts(self):
        return dict(
            species_per_tile=self.species_per_tile,
            rows_per_tile=self.rows_per_tile,
            interpret=self.interpret,
            lane=self.lane,
        )

    def _build_jits(self):
        import jax
        import jax.numpy as jnp

        from repro.kernels.gbatc_project import (
            gbatc_correct_batched,
            gbatc_project_batched,
            gbatc_select_accumulate,
        )

        opts = self._kernel_opts()

        def project_fn(residual, basis):
            return gbatc_project_batched(residual, basis, **opts)

        def apply_fn(x_rec, dense, basis):
            return gbatc_correct_batched(x_rec, dense, basis, **opts)

        def correct_fn(x_rec, cqv32, inv_rank, m_eff, basis32):
            return gbatc_select_accumulate(
                x_rec, cqv32, inv_rank, m_eff, basis32, **opts
            )

        def select_fn(
            coeffs, coeffs_sorted, inv_rank, norms2, x_rec, basis32, tau2, bin_size
        ):
            # gains in energy-descending order (the sort itself is
            # tau-independent and lives in prepare); gains are >= 0, so the
            # first cumsum crossing IS the oracle's running-max crossing
            cq_s = jnp.rint(coeffs_sorted / bin_size)
            cqv_s = cq_s * bin_size
            gain = 2.0 * coeffs_sorted * cqv_s - cqv_s * cqv_s
            cum = jnp.cumsum(gain, axis=2)
            target = norms2 - tau2
            needs = norms2 > tau2
            m = 1 + jnp.argmax(cum >= target[..., None], axis=2)
            achieved = jnp.take_along_axis(cum, (m - 1)[..., None], axis=2)[..., 0]
            m_eff = jnp.where(needs, m, 0).astype(jnp.int32)
            cq = jnp.rint(coeffs / bin_size)  # index-ordered ints (as f64)
            corrected = gbatc_select_accumulate(
                x_rec, (cq * bin_size).astype(jnp.float32), inv_rank, m_eff,
                basis32, **opts
            )
            return corrected, cq, m_eff, achieved

        self._project_jit = jax.jit(project_fn)
        self._select_jit = jax.jit(select_fn)
        self._correct_jit = jax.jit(correct_fn)
        self._apply_jit = jax.jit(apply_fn)

    # -- dispatch/staging seams (subclass points for sharded engines) ----
    def _stage(self, arr):
        """Stage a prepared tensor for kernel dispatch. The default engine
        keeps prepared tensors device-resident; a sharded engine
        (``repro.parallel.mesh_fit.ShardedGuaranteeEngine``) keeps them on
        host and chunk-uploads per dispatch instead."""
        import jax.numpy as jnp

        return jnp.asarray(arr)

    def _dispatch(self, kernel: str, *args):
        """Run one batched kernel program (``project`` / ``select`` /
        ``correct`` / ``apply``). The default engine issues the single
        batched jit; a sharded engine splits the batch over species and
        block rows into per-shard programs — the kernels are per-species
        and per-block-row pure, so the concatenated results are bitwise
        the batched ones."""
        return getattr(self, f"_{kernel}_jit")(*args)

    # -- tau-independent stage -----------------------------------------
    def prepare(
        self,
        x: np.ndarray,
        x_rec: np.ndarray,
        reuse: Optional[PreparedGuarantee] = None,
    ) -> PreparedGuarantee:
        """Factor out everything that does not depend on the error bound.

        ``reuse`` starts the ROADMAP's shared-residual incremental prepare:
        given a previous :class:`PreparedGuarantee` over the *same original
        vectors* ``x``, any species whose reconstruction is bitwise
        unchanged reuses its residual norms, PCA basis, projection, and
        energy ordering wholesale; only changed species recompute. The
        recomputed slices go through the same batched gram/eigh/projection
        /sort path as a cold prepare (per-species arithmetic is slice-pure),
        so the result is bit-identical to a cold ``prepare(x, x_rec)`` —
        asserted by the parity suite. Reuse is keyed on values, not
        provenance: a stale ``reuse`` from different ``x`` is rejected by
        the caller contract (pipeline passes its one fitted ``vecs_orig``).
        """
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        if self._project_jit is None:
            self._build_jits()

        x = np.asarray(x)
        x_rec32 = np.asarray(x_rec, dtype=np.float32)
        s, nb, d = x.shape

        stale = np.arange(s)
        # staleness is judged on the f32 mirror, which is only sound when
        # the reconstruction IS f32 (the pipeline's case); a float64 x_rec
        # could differ below f32 precision, so it never reuses. The
        # originals must also be the ones the reuse state was computed
        # against — identity for the common case, value equality otherwise
        can_reuse = (
            reuse is not None
            and reuse.shape == (s, nb, d)
            and np.asarray(x_rec).dtype == np.float32
            and (reuse.x_ref is x or np.array_equal(reuse.x_ref, x))
        )
        if can_reuse:
            stale = np.array(
                [
                    sidx
                    for sidx in range(s)
                    if not np.array_equal(x_rec32[sidx], reuse.x_rec32[sidx])
                ],
                dtype=np.int64,
            )
            if len(stale) == 0:
                return reuse

        # residual in the caller's precision (matches the oracle's
        # float64 contract even for float64 reconstructions); only the
        # correction kernel input and fast-path output are float32
        full = len(stale) == s
        x_rec_arr = np.asarray(x_rec)
        residual = (x if full else x[stale]).astype(np.float64)
        residual -= (x_rec_arr if full else x_rec_arr[stale]).astype(np.float64)
        norms2_stale = np.sum(residual**2, axis=2)
        # PCA on host numpy: the D x D eigh is tiny, and sharing the exact
        # gram/eigh path with the numpy oracle is what makes the engine's
        # byte accounting bit-identical to it.
        basis_stale, _ = pca.pca_basis_stack(residual, executor=_pool())

        with enable_x64():
            residual_dev = self._stage(residual)
            basis_dev = self._stage(basis_stale)
            coeffs_stale_dev = self._dispatch("project", residual_dev,
                                              basis_dev)
            # np.array, not asarray: a zero-copy view of the jax buffer has
            # pathological ufunc throughput (unaligned); copy once here
            coeffs_stale = np.array(coeffs_stale_dev)

        if not can_reuse or len(stale) == s:
            norms2, basis, coeffs = norms2_stale, basis_stale, coeffs_stale
            coeffs_sorted = np.empty_like(coeffs)
            inv_rank = np.empty((s, nb, d), np.int32)
            fresh = range(s)
        else:
            norms2 = reuse.norms2.copy()
            norms2[stale] = norms2_stale
            basis = reuse.basis.copy()
            basis[stale] = basis_stale
            coeffs = reuse.coeffs.copy()
            coeffs[stale] = coeffs_stale
            coeffs_sorted = reuse.coeffs_sorted.copy()
            inv_rank = reuse.inv_rank.copy()
            fresh = stale.tolist()

        iota = np.arange(d, dtype=np.int32)

        def order_work(sidx):
            order = _stable_desc_order(coeffs[sidx] ** 2)
            coeffs_sorted[sidx] = np.take_along_axis(coeffs[sidx], order, axis=-1)
            np.put_along_axis(
                inv_rank[sidx], order, np.broadcast_to(iota, order.shape), axis=-1
            )

        list(_pool().map(order_work, fresh))
        jit_backend = self.select_backend == "jit"
        full_recompute = coeffs is coeffs_stale
        with enable_x64():
            prepared = PreparedGuarantee(
                shape=(s, nb, d),
                x_ref=x,
                x_rec32=x_rec32,
                norms2=norms2,
                basis=basis,
                inv_rank=inv_rank,
                coeffs=coeffs,
                coeffs_sorted=coeffs_sorted,
                # the host backend reads the host mirror only; keeping the
                # device projection alive would pin S*NB*D fp64 for nothing.
                # On a full recompute the projection is already device
                # resident — re-uploading the host copy would waste a
                # S*NB*D fp64 transfer on the accelerator path
                coeffs_dev=(
                    (coeffs_stale_dev if full_recompute
                     else self._stage(coeffs))
                    if jit_backend else None
                ),
                coeffs_sorted_dev=(
                    self._stage(coeffs_sorted) if jit_backend else None
                ),
                inv_rank_dev=self._stage(inv_rank),
                norms2_dev=self._stage(norms2) if jit_backend else None,
                x_rec_dev=self._stage(x_rec32),
                basis32_dev=self._stage(basis.astype(np.float32)),
            )
        return prepared

    # -- per-error-bound stage -----------------------------------------
    def select(
        self,
        prep: PreparedGuarantee,
        tau: float,
        coeff_bin: float = 0.0,
    ) -> tuple[np.ndarray, list[GuaranteeArtifact]]:
        """Apply Algorithm 1 at one error bound; returns (corrected, artifacts)."""
        from jax.experimental import enable_x64

        if self._select_jit is None:
            self._build_jits()  # prep may come from a different engine
        s, nb, d = prep.shape
        tau = float(tau)
        tau2 = tau * tau
        needs = prep.norms2 > tau2
        if not needs.any():
            arts = [GuaranteeArtifact.empty(nb, d, tau) for _ in range(s)]
            return prep.x_rec32.astype(np.float32), arts

        bin_size = _effective_bin(coeff_bin, tau, d)
        if self.select_backend == "host":
            corrected, cq, m_eff, achieved = self._select_host(
                prep, needs, tau2, bin_size
            )
        else:
            with enable_x64():
                corrected, cq, m_eff, achieved = self._dispatch(
                    "select",
                    prep.coeffs_dev,
                    prep.coeffs_sorted_dev,
                    prep.inv_rank_dev,
                    prep.norms2_dev,
                    prep.x_rec_dev,
                    prep.basis32_dev,
                    np.float64(tau2),
                    np.float64(bin_size),
                )
                corrected = np.asarray(corrected)
                cq = np.asarray(cq)
                m_eff = np.asarray(m_eff)
                achieved = np.asarray(achieved)

        # Guaranteed by bin clamp, but assert rather than assume:
        target = prep.norms2 - tau2
        slack = 1e-9 * np.maximum(prep.norms2, 1.0)
        if not np.all(achieved[needs] >= (target - slack)[needs]):
            raise AssertionError("guarantee violated — coefficient bin clamp failed")

        arts = self._build_artifacts(prep, m_eff, cq, needs, bin_size, tau)
        return corrected, arts

    def _select_host(self, prep, needs, tau2, bin_size):
        """Host-numpy selection math + Pallas masked-correction dispatch.

        Arithmetic mirrors the oracle expression for expression, so the
        cumulative gains — and therefore the cut — are bit-identical to it,
        not merely scan-order-close. Species are processed by the shared
        thread pool (disjoint slices, pure per-slice ops).
        """
        s, nb, d = prep.shape
        m_eff = np.empty((s, nb), np.int32)
        achieved = np.empty((s, nb), np.float64)
        cq = np.empty((s, nb, d), np.float64)
        cqv32 = np.empty((s, nb, d), np.float32)
        # row-chunked tasks: every op is row-independent, and ~1k-row
        # slices keep the ~10-pass working set L2-resident
        chunk = max(256, min(nb, 1024))

        def work(task):
            sidx, r0 = task
            r1 = min(r0 + chunk, nb)
            rows = slice(r0, r1)
            cs = prep.coeffs_sorted[sidx, rows]
            # in-place where bit-exactness allows: 2*(c*cqv) == (2*c)*cqv
            # exactly (scaling by 2 is exponent-only), so the gains match
            # the oracle's `2.0 * coeffs * cq - cq**2` bit for bit
            cqv = cs / bin_size
            np.rint(cqv, out=cqv)
            cqv *= bin_size  # the dequantized values, exactly oracle's cq
            gain = cs * cqv
            gain *= 2.0
            cqv *= cqv
            gain -= cqv
            cum = np.cumsum(gain, axis=-1, out=gain)
            target = prep.norms2[sidx, rows] - tau2
            # gains are >= 0: the first plain-cumsum crossing IS the
            # oracle's running-max crossing (the max is redundant there)
            m = 1 + np.argmax(cum >= target[:, None], axis=-1)
            achieved[sidx, rows] = np.take_along_axis(
                cum, (m - 1)[:, None], axis=-1
            )[:, 0]
            m_eff[sidx, rows] = np.where(needs[sidx, rows], m, 0)
            np.divide(prep.coeffs[sidx, rows], bin_size, out=cq[sidx, rows])
            np.rint(cq[sidx, rows], out=cq[sidx, rows])
            # (int * bin) in f64, then cast on store — must match the
            # decode path's dequantize(...).astype(f32) bit for bit
            np.multiply(cq[sidx, rows], bin_size, out=cum)
            cqv32[sidx, rows] = cum

        tasks = [(sidx, r0) for sidx in range(s) for r0 in range(0, nb, chunk)]
        list(_pool().map(work, tasks))
        corrected = np.asarray(
            self._dispatch(
                "correct",
                prep.x_rec_dev, cqv32, prep.inv_rank_dev, m_eff,
                prep.basis32_dev,
            )
        )
        return corrected, cq, m_eff, achieved

    @staticmethod
    def _build_artifacts(prep, m_eff, cq, needs, bin_size, tau):
        """CSR artifact assembly: one flatnonzero pass per species, no
        per-block loops; species run on the shared thread pool."""
        s, nb, d = prep.shape

        def work(sidx):
            if not needs[sidx].any():
                return GuaranteeArtifact.empty(nb, d, tau)
            keep = prep.inv_rank[sidx] < m_eff[sidx][:, None]
            flat_idx = np.flatnonzero(keep)
            flat = flat_idx % d
            # cq holds exact integers as float64 (rint output) — exact cast
            coeff_q = cq[sidx].reshape(-1)[flat_idx].astype(np.int64)
            offsets = np.zeros(nb + 1, np.int64)
            np.cumsum(keep.sum(axis=1, dtype=np.int64), out=offsets[1:])
            n_store = int(flat.max()) + 1 if flat.size else 0
            return GuaranteeArtifact(
                basis=prep.basis[sidx][:, :n_store].astype(np.float32),
                coeff_q=coeff_q,
                index_offsets=offsets,
                index_flat=flat,
                coeff_bin=bin_size,
                tau=tau,
            )

        return list(_pool().map(work, range(s)))

    # -- decode path ----------------------------------------------------
    def dense_corrections(
        self,
        arts: list[GuaranteeArtifact],
        shape: tuple[int, int, int],
        block_range: Optional[tuple[int, int]] = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        """Scatter CSR artifacts into the kernel inputs (dense, basis_pad).

        Per-species flat scatter: CSR row ids come from one repeat over the
        per-block counts; species slices are disjoint (thread pool). Host
        work only — callers overlap it with in-flight device decode.

        ``block_range=(b0, b1)`` scatters only that window of block rows
        (``shape[1] == b1 - b0``): the CSR offsets address the window's
        coefficient/index spans directly, so the cost scales with the
        window's selection count, not the artifact's. Values are sliced
        from the same streams the full scatter reads — per-element
        arithmetic, hence bitwise equal to slicing a full scatter.
        """
        s, nb, d = shape
        b0, b1 = (0, nb) if block_range is None else block_range
        dense = np.zeros((s, nb, d), np.float32)
        basis_pad = np.zeros((s, d, d), np.float32)

        def work(sidx):
            art = arts[sidx]
            if art.coeff_q.size == 0:
                return
            off = art.index_offsets
            lo, hi = int(off[b0]), int(off[b1])
            if hi > lo:
                rows = np.repeat(
                    np.arange(nb, dtype=np.int64), np.diff(off[b0 : b1 + 1])
                )
                dense[sidx].reshape(-1)[
                    rows * d + art.index_flat[lo:hi]
                ] = dequantize(
                    art.coeff_q[lo:hi], art.coeff_bin
                ).astype(np.float32)
            basis_pad[sidx, :, : art.basis.shape[1]] = art.basis

        list(_pool().map(work, range(s)))
        return dense, basis_pad

    def apply_device(self, x_rec_dev, dense, basis):
        """Replay on device-resident reconstructions without a host sync."""
        if self._apply_jit is None:
            self._build_jits()
        return self._apply_jit(x_rec_dev, dense, basis)

    def apply_batched(
        self, x_rec: np.ndarray, arts: list[GuaranteeArtifact]
    ) -> np.ndarray:
        """Replay stored corrections for all species in one dispatch."""
        import jax.numpy as jnp

        if self._apply_jit is None:
            self._build_jits()
        x_rec = np.asarray(x_rec, dtype=np.float32)
        if all(art.coeff_q.size == 0 for art in arts):
            return x_rec.copy()
        dense, basis_pad = self.dense_corrections(arts, x_rec.shape)
        out = self._dispatch(
            "apply",
            self._stage(x_rec), self._stage(dense), self._stage(basis_pad),
        )
        return np.asarray(out)


_DEFAULT_ENGINE: Optional[GuaranteeEngine] = None


def default_engine() -> GuaranteeEngine:
    global _DEFAULT_ENGINE
    if _DEFAULT_ENGINE is None:
        _DEFAULT_ENGINE = GuaranteeEngine()
    return _DEFAULT_ENGINE


def guarantee_batched(
    x: np.ndarray,
    x_rec: np.ndarray,
    tau: float,
    coeff_bin: float = 0.0,
    engine: Optional[GuaranteeEngine] = None,
    prepared: Optional[PreparedGuarantee] = None,
) -> tuple[np.ndarray, list[GuaranteeArtifact]]:
    """Batched-over-species guarantee: x, x_rec are (S, NB, D)."""
    engine = engine or default_engine()
    if prepared is None:
        prepared = engine.prepare(x, x_rec)
    return engine.select(prepared, tau, coeff_bin)


def guarantee(
    x: np.ndarray,
    x_rec: np.ndarray,
    tau: float,
    coeff_bin: float = 0.0,
) -> tuple[np.ndarray, GuaranteeArtifact]:
    """Correct ``x_rec`` so every block satisfies ||x - out||_2 <= tau.

    x, x_rec: (NB, D). Returns (corrected, artifact). Single-species
    convenience over :func:`guarantee_batched`.
    """
    corrected, arts = guarantee_batched(
        np.asarray(x)[None], np.asarray(x_rec)[None], tau, coeff_bin
    )
    return corrected[0], arts[0]


def apply_correction(x_rec: np.ndarray, art: GuaranteeArtifact) -> np.ndarray:
    """Decode path: replay the stored correction, loop-free.

    Scatters the dequantized coefficient stream into a dense (NB, n_store)
    matrix (CSR row ids come from one ``repeat`` over the offsets) and
    applies the correction as a single GEMM.
    """
    out = np.asarray(x_rec, dtype=np.float64).copy()
    if art.coeff_q.size:
        nb = out.shape[0]
        n_store = art.basis.shape[1]
        dense = np.zeros((nb, n_store), np.float64)
        rows = np.repeat(np.arange(nb), np.diff(art.index_offsets))
        dense[rows, art.index_flat] = dequantize(art.coeff_q, art.coeff_bin)
        out += dense @ art.basis.astype(np.float64).T
    return out.astype(np.float32)


def apply_correction_batched(
    x_rec: np.ndarray,
    arts: list[GuaranteeArtifact],
    engine: Optional[GuaranteeEngine] = None,
) -> np.ndarray:
    """Batched decode replay via the Pallas correction kernel."""
    engine = engine or default_engine()
    return engine.apply_batched(x_rec, arts)


def verify_guarantee(x: np.ndarray, corrected: np.ndarray, tau: float) -> bool:
    """True iff every block meets the l2 bound (with fp32 round-off slack)."""
    r = np.asarray(x, np.float64) - np.asarray(corrected, np.float64)
    norms = np.sqrt(np.sum(r**2, axis=1))
    scale = np.sqrt(np.sum(np.asarray(x, np.float64) ** 2, axis=1))
    slack = 1e-5 * np.maximum(scale, 1.0)  # fp32 storage round-off
    return bool(np.all(norms <= tau + slack))
