"""Residual PCA (paper §II-A).

The guarantee post-process runs PCA on the residual matrix R (NB x D, blocks
as instances). D is small (paper: 80) while NB is large, so we form the D x D
Gram matrix in float64 and eigendecompose — O(NB*D^2) flops, numerically
comfortable, and exactly orthonormal basis vectors (required for the
cumulative-energy argument that makes Algorithm 1 vectorizable).
"""

from __future__ import annotations

import numpy as np


def pca_basis(residual: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Return (U, eigvals) with columns of U sorted by descending eigenvalue.

    residual: (NB, D). The paper does not center the residual before PCA
    (Algorithm 1 projects raw residuals), so neither do we — U must span the
    residuals themselves for ``x^R + U c`` to reconstruct exactly.
    """
    r = residual.astype(np.float64)
    gram = r.T @ r
    eigvals, eigvecs = np.linalg.eigh(gram)
    order = np.argsort(eigvals)[::-1]
    return eigvecs[:, order], np.maximum(eigvals[order], 0.0)


def project(residual: np.ndarray, basis: np.ndarray) -> np.ndarray:
    """c = U^T r for each block row: (NB, D) @ (D, D) -> (NB, D)."""
    return residual.astype(np.float64) @ basis
