"""Residual PCA (paper §II-A).

The guarantee post-process runs PCA on the residual matrix R (NB x D, blocks
as instances). D is small (paper: 80) while NB is large, so we form the D x D
Gram matrix in float64 and eigendecompose — O(NB*D^2) flops, numerically
comfortable, and exactly orthonormal basis vectors (required for the
cumulative-energy argument that makes Algorithm 1 vectorizable).
"""

from __future__ import annotations

import numpy as np


def pca_basis(residual: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Return (U, eigvals) with columns of U sorted by descending eigenvalue.

    residual: (NB, D). The paper does not center the residual before PCA
    (Algorithm 1 projects raw residuals), so neither do we — U must span the
    residuals themselves for ``x^R + U c`` to reconstruct exactly.
    """
    r = residual.astype(np.float64)
    gram = r.T @ r
    eigvals, eigvecs = np.linalg.eigh(gram)
    order = np.argsort(eigvals)[::-1]
    return eigvecs[:, order], np.maximum(eigvals[order], 0.0)


def pca_basis_stack(
    residuals: np.ndarray, executor=None
) -> tuple[np.ndarray, np.ndarray]:
    """Per-species bases for a (S, NB, D) residual stack.

    The grams are computed as one batched matmul — BLAS runs the same GEMM
    per slice, so the result is bit-identical to :func:`pca_basis`'s
    ``r.T @ r`` (asserted by the engine/oracle parity suite); each slice
    then goes through exactly the same eigh/ordering as a standalone call.
    The guarantee engine's byte-accounting parity with the numpy oracle
    depends on these bases matching bit for bit. ``executor`` optionally
    parallelizes the per-slice eigh (LAPACK releases the GIL; slices are
    independent, so results do not depend on scheduling).
    """
    s, _, d = residuals.shape
    r = residuals.astype(np.float64)
    grams = np.matmul(r.transpose(0, 2, 1), r)
    bases = np.empty((s, d, d), np.float64)
    eigvals = np.empty((s, d), np.float64)

    def work(sidx):
        ev, evec = np.linalg.eigh(grams[sidx])
        order = np.argsort(ev)[::-1]
        bases[sidx] = evec[:, order]
        eigvals[sidx] = np.maximum(ev[order], 0.0)

    if executor is None:
        for sidx in range(s):
            work(sidx)
    else:
        list(executor.map(work, range(s)))
    return bases, eigvals


def project(residual: np.ndarray, basis: np.ndarray) -> np.ndarray:
    """c = U^T r for each block row: (NB, D) @ (D, D) -> (NB, D)."""
    return residual.astype(np.float64) @ basis
