"""Spatiotemporal blocking (paper §II-B).

The S3D field is a (S, T, H, W) array: S species (tensor axis), T time steps,
H x W spatial grid. Per species we partition each frame into non-overlapping
``ph x pw`` patches and group ``bt`` consecutive time steps of the same patch
location into one block. Paper geometry: bt=4 timesteps, 5x4 patches -> 80
scalars per species per block; an AE instance is the (S, bt, ph, pw) stack
across all species at one (time-group, location).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class BlockGeometry:
    bt: int  # time steps per block
    ph: int  # patch height
    pw: int  # patch width

    @property
    def block_size(self) -> int:
        return self.bt * self.ph * self.pw


PAPER_GEOMETRY = BlockGeometry(bt=4, ph=5, pw=4)


def check_divisible(shape: tuple[int, int, int, int], geom: BlockGeometry) -> None:
    s, t, h, w = shape
    if t % geom.bt or h % geom.ph or w % geom.pw:
        raise ValueError(
            f"data shape {shape} not divisible by block geometry "
            f"(bt={geom.bt}, ph={geom.ph}, pw={geom.pw})"
        )


def to_blocks(data: np.ndarray, geom: BlockGeometry) -> np.ndarray:
    """(S, T, H, W) -> (NB, S, bt, ph, pw) with NB = (T/bt)(H/ph)(W/pw).

    Block index runs (time-group, patch-row, patch-col) row-major, so the
    inverse is a pure reshape/transpose — bit-exact round trip.
    """
    check_divisible(data.shape, geom)
    s, t, h, w = data.shape
    nt, nh, nw = t // geom.bt, h // geom.ph, w // geom.pw
    x = data.reshape(s, nt, geom.bt, nh, geom.ph, nw, geom.pw)
    # -> (nt, nh, nw, s, bt, ph, pw)
    x = x.transpose(1, 3, 5, 0, 2, 4, 6)
    return np.ascontiguousarray(x.reshape(nt * nh * nw, s, geom.bt, geom.ph, geom.pw))


def from_blocks(
    blocks: np.ndarray, shape: tuple[int, int, int, int], geom: BlockGeometry
) -> np.ndarray:
    """Inverse of :func:`to_blocks`."""
    s, t, h, w = shape
    nt, nh, nw = t // geom.bt, h // geom.ph, w // geom.pw
    x = blocks.reshape(nt, nh, nw, s, geom.bt, geom.ph, geom.pw)
    x = x.transpose(3, 0, 4, 1, 5, 2, 6)  # (s, nt, bt, nh, ph, nw, pw)
    return np.ascontiguousarray(x.reshape(s, t, h, w))


def blocks_as_vectors(blocks: np.ndarray) -> np.ndarray:
    """(NB, S, bt, ph, pw) -> per-species block vectors (S, NB, D)."""
    nb, s = blocks.shape[:2]
    return np.ascontiguousarray(
        blocks.reshape(nb, s, -1).transpose(1, 0, 2)
    )


def vectors_as_blocks(vecs: np.ndarray, geom: BlockGeometry) -> np.ndarray:
    """(S, NB, D) -> (NB, S, bt, ph, pw)."""
    s, nb, d = vecs.shape
    assert d == geom.block_size
    return np.ascontiguousarray(
        vecs.transpose(1, 0, 2).reshape(nb, s, geom.bt, geom.ph, geom.pw)
    )
