"""3D convolutional block autoencoder (paper Fig. 1).

Input instances are (NB, S, bt, ph, pw) spatiotemporal blocks; species are the
conv channel axis. Encoder: Conv3D stack (LeakyReLU) -> single FC to a 36-dim
latent (the paper found extra FC layers do not help). Decoder mirrors with a
FC + Conv3DTranspose stack back to S channels.

The module is pure-JAX (see repro.nn); `fit` trains with AdamW on MSE through
the compiled mini-batch engine (:class:`repro.train.train_loop.MiniBatchTrainer`
— device-resident data, jax.random batch draws, donated carries, scan- or
stream-compiled by backend). `fit_reference` retains the seed's per-step
dispatch loop as the trajectory/throughput baseline.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn import layers as L
from repro.nn.module import init_tree
from repro.train import optimizer as opt
from repro.train import train_loop


@dataclasses.dataclass(frozen=True)
class AEConfig:
    n_species: int
    block: tuple[int, int, int]  # (bt, ph, pw)
    latent: int = 36
    conv_channels: tuple[int, ...] = (64, 128)
    negative_slope: float = 0.2
    dtype: Any = jnp.float32
    # "2d" = depth-decomposed 2D-conv formulation (default; equals the lax
    # 3D conv up to depth-sum reassociation — ulp-level — and ~3x faster
    # on CPU); "xla" = lax 3D conv ops (retained perf/numerics reference)
    conv_impl: str = "2d"


class BlockAutoencoder:
    def __init__(self, cfg: AEConfig):
        self.cfg = cfg
        s = cfg.n_species
        bt, ph, pw = cfg.block
        chans = (s,) + cfg.conv_channels
        self.enc_convs = [
            L.conv3d(chans[i], chans[i + 1], (3, 3, 3), dtype=cfg.dtype,
                     impl=cfg.conv_impl)
            for i in range(len(cfg.conv_channels))
        ]
        flat = cfg.conv_channels[-1] * bt * ph * pw
        self.flat = flat
        self.enc_fc = L.dense(flat, cfg.latent, dtype=cfg.dtype)
        self.dec_fc = L.dense(cfg.latent, flat, dtype=cfg.dtype)
        rev = tuple(reversed(chans))
        self.dec_convs = [
            L.conv3d_transpose(rev[i], rev[i + 1], (3, 3, 3), dtype=cfg.dtype,
                               impl=cfg.conv_impl)
            for i in range(len(cfg.conv_channels))
        ]
        # MiniBatchTrainer per optimizer config, built lazily by fit():
        # refitting the same model never re-traces the training program
        self._trainers: dict[tuple, train_loop.MiniBatchTrainer] = {}

    # ---- definition tree ------------------------------------------------
    @property
    def defs(self):
        d = {"enc_fc": self.enc_fc.defs, "dec_fc": self.dec_fc.defs}
        for i, c in enumerate(self.enc_convs):
            d[f"enc_conv{i}"] = c.defs
        for i, c in enumerate(self.dec_convs):
            d[f"dec_conv{i}"] = c.defs
        return d

    def init(self, key):
        return init_tree(self.defs, key)

    # ---- forward ---------------------------------------------------------
    def _to_ndhwc(self, x):
        # (NB, S, bt, ph, pw) -> (NB, bt, ph, pw, S)
        return jnp.transpose(x, (0, 2, 3, 4, 1))

    def _from_ndhwc(self, x):
        return jnp.transpose(x, (0, 4, 1, 2, 3))

    def encode(self, params, x):
        h = self._to_ndhwc(x)
        for i, conv in enumerate(self.enc_convs):
            h = L.leaky_relu(
                conv.apply(params[f"enc_conv{i}"], h), self.cfg.negative_slope
            )
        h = h.reshape(h.shape[0], -1)
        return self.enc_fc.apply(params["enc_fc"], h)

    def decode(self, params, z):
        bt, ph, pw = self.cfg.block
        c_last = self.cfg.conv_channels[-1]
        h = L.leaky_relu(self.dec_fc.apply(params["dec_fc"], z), self.cfg.negative_slope)
        h = h.reshape(-1, bt, ph, pw, c_last)
        for i, conv in enumerate(self.dec_convs):
            h = conv.apply(params[f"dec_conv{i}"], h)
            if i < len(self.dec_convs) - 1:
                h = L.leaky_relu(h, self.cfg.negative_slope)
        return self._from_ndhwc(h)

    def __call__(self, params, x):
        return self.decode(params, self.encode(params, x))

    def decoder_param_bytes(self, params) -> int:
        """Bytes of everything stored with the compressed artifact (decoder only)."""
        dec = {k: v for k, v in params.items() if k.startswith("dec")}
        return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(dec))


def _ae_loss(model: BlockAutoencoder):
    def loss_fn(p, batch):
        rec = model(p, batch)
        return jnp.mean(jnp.square(rec - batch))

    return loss_fn


def fit(
    model: BlockAutoencoder,
    blocks: np.ndarray,
    *,
    steps: int = 400,
    batch_size: int = 64,
    lr: float = 1e-3,
    seed: int = 0,
    log_every: int = 0,
    mode: Optional[str] = None,
    mesh=None,
) -> tuple[Any, np.ndarray]:
    """Train the AE with AdamW on MSE. Returns (params, loss_history).

    Runs on the compiled mini-batch engine; ``mode`` picks "scan" / "stream"
    explicitly (default: by backend). The engine (and its compiled programs)
    is cached on the model, so refitting is warm-start fast. ``mesh``
    switches to the data-parallel mesh program (blocks sharded over the
    mesh's data axis; bit-identical to ``mode="scan"`` on one device).
    """
    params = model.init(jax.random.PRNGKey(seed))
    key = (lr, steps, mode)
    trainer = model._trainers.get(key)
    if trainer is None:
        trainer = train_loop.MiniBatchTrainer(
            _ae_loss(model),
            train_loop.adamw_cfg(lr, steps),
            mode=mode,
            log_fn=lambda t, loss: print(f"[ae] step {t} loss {loss:.3e}"),
        )
        model._trainers[key] = trainer
    return trainer.fit(
        params, (blocks,), steps=steps, batch_size=batch_size, seed=seed,
        log_every=log_every, mesh=mesh,
    )


def fit_reference(
    model: BlockAutoencoder,
    blocks: np.ndarray,
    *,
    steps: int = 400,
    batch_size: int = 64,
    lr: float = 1e-3,
    seed: int = 0,
    log_every: int = 0,
) -> tuple[Any, np.ndarray]:
    """The seed's training loop, retained as the engine's baseline/oracle.

    Per-fit ``jax.jit`` of a fresh step closure (recompiles every call),
    host-looped steps with a blocking ``float(loss)`` sync each iteration,
    host-side batch gather dispatch. Batch indices come from the engine's
    :func:`~repro.train.train_loop.batch_indices` law so the loss
    trajectory is directly comparable with the scan/stream engines.
    """
    key = jax.random.PRNGKey(seed)
    params = model.init(key)
    cfg = train_loop.adamw_cfg(lr, steps)
    state = opt.init_state(params)
    data = jnp.asarray(blocks)
    n = data.shape[0]
    loss_fn = _ae_loss(model)

    @jax.jit
    def step_fn(p, s, batch):
        loss, grads = jax.value_and_grad(loss_fn)(p, batch)
        p, s, _ = opt.update(cfg, grads, s, p)
        return p, s, loss

    losses: list[float] = []
    idxs = train_loop.all_batch_indices(seed, steps, n, min(batch_size, n))
    for i in range(steps):
        params, state, loss = step_fn(params, state, data[idxs[i]])
        losses.append(float(loss))
        if log_every and i % log_every == 0:
            print(f"[ae] step {i} loss {float(loss):.3e}")
    return params, np.asarray(losses, dtype=np.float32)
