"""3D convolutional block autoencoder (paper Fig. 1).

Input instances are (NB, S, bt, ph, pw) spatiotemporal blocks; species are the
conv channel axis. Encoder: Conv3D stack (LeakyReLU) -> single FC to a 36-dim
latent (the paper found extra FC layers do not help). Decoder mirrors with a
FC + Conv3DTranspose stack back to S channels.

The module is pure-JAX (see repro.nn); `fit` provides a jit'd Adam training
loop used by the reproduction pipeline and the examples.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn import layers as L
from repro.nn.module import init_tree
from repro.train import optimizer as opt


@dataclasses.dataclass(frozen=True)
class AEConfig:
    n_species: int
    block: tuple[int, int, int]  # (bt, ph, pw)
    latent: int = 36
    conv_channels: tuple[int, ...] = (64, 128)
    negative_slope: float = 0.2
    dtype: Any = jnp.float32


class BlockAutoencoder:
    def __init__(self, cfg: AEConfig):
        self.cfg = cfg
        s = cfg.n_species
        bt, ph, pw = cfg.block
        chans = (s,) + cfg.conv_channels
        self.enc_convs = [
            L.conv3d(chans[i], chans[i + 1], (3, 3, 3), dtype=cfg.dtype)
            for i in range(len(cfg.conv_channels))
        ]
        flat = cfg.conv_channels[-1] * bt * ph * pw
        self.flat = flat
        self.enc_fc = L.dense(flat, cfg.latent, dtype=cfg.dtype)
        self.dec_fc = L.dense(cfg.latent, flat, dtype=cfg.dtype)
        rev = tuple(reversed(chans))
        self.dec_convs = [
            L.conv3d_transpose(rev[i], rev[i + 1], (3, 3, 3), dtype=cfg.dtype)
            for i in range(len(cfg.conv_channels))
        ]

    # ---- definition tree ------------------------------------------------
    @property
    def defs(self):
        d = {"enc_fc": self.enc_fc.defs, "dec_fc": self.dec_fc.defs}
        for i, c in enumerate(self.enc_convs):
            d[f"enc_conv{i}"] = c.defs
        for i, c in enumerate(self.dec_convs):
            d[f"dec_conv{i}"] = c.defs
        return d

    def init(self, key):
        return init_tree(self.defs, key)

    # ---- forward ---------------------------------------------------------
    def _to_ndhwc(self, x):
        # (NB, S, bt, ph, pw) -> (NB, bt, ph, pw, S)
        return jnp.transpose(x, (0, 2, 3, 4, 1))

    def _from_ndhwc(self, x):
        return jnp.transpose(x, (0, 4, 1, 2, 3))

    def encode(self, params, x):
        h = self._to_ndhwc(x)
        for i, conv in enumerate(self.enc_convs):
            h = L.leaky_relu(
                conv.apply(params[f"enc_conv{i}"], h), self.cfg.negative_slope
            )
        h = h.reshape(h.shape[0], -1)
        return self.enc_fc.apply(params["enc_fc"], h)

    def decode(self, params, z):
        bt, ph, pw = self.cfg.block
        c_last = self.cfg.conv_channels[-1]
        h = L.leaky_relu(self.dec_fc.apply(params["dec_fc"], z), self.cfg.negative_slope)
        h = h.reshape(-1, bt, ph, pw, c_last)
        for i, conv in enumerate(self.dec_convs):
            h = conv.apply(params[f"dec_conv{i}"], h)
            if i < len(self.dec_convs) - 1:
                h = L.leaky_relu(h, self.cfg.negative_slope)
        return self._from_ndhwc(h)

    def __call__(self, params, x):
        return self.decode(params, self.encode(params, x))

    def decoder_param_bytes(self, params) -> int:
        """Bytes of everything stored with the compressed artifact (decoder only)."""
        dec = {k: v for k, v in params.items() if k.startswith("dec")}
        return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(dec))


def fit(
    model: BlockAutoencoder,
    blocks: np.ndarray,
    *,
    steps: int = 400,
    batch_size: int = 64,
    lr: float = 1e-3,
    seed: int = 0,
    log_every: int = 0,
) -> tuple[Any, list[float]]:
    """Train the AE with Adam on MSE. Returns (params, loss_history)."""
    key = jax.random.PRNGKey(seed)
    params = model.init(key)
    cfg = opt.AdamWConfig(lr=lr, total_steps=steps, warmup_steps=min(20, steps // 10))
    state = opt.init_state(params)
    data = jnp.asarray(blocks)
    n = data.shape[0]

    def loss_fn(p, batch):
        rec = model(p, batch)
        return jnp.mean(jnp.square(rec - batch))

    @jax.jit
    def step_fn(p, s, batch):
        loss, grads = jax.value_and_grad(loss_fn)(p, batch)
        p, s, _ = opt.update(cfg, grads, s, p)
        return p, s, loss

    losses: list[float] = []
    rng = np.random.default_rng(seed)
    for i in range(steps):
        idx = rng.integers(0, n, size=min(batch_size, n))
        params, state, loss = step_fn(params, state, data[idx])
        losses.append(float(loss))
        if log_every and i % log_every == 0:
            print(f"[ae] step {i} loss {float(loss):.3e}")
    return params, losses
