# The paper's primary contribution: GBA / GBATC / GAE compression with
# guaranteed error bounds, plus the SZ3-style baseline it is compared to.
from repro.core.blocking import BlockGeometry, PAPER_GEOMETRY  # noqa: F401
from repro.core.pipeline import (  # noqa: F401
    GBATCPipeline,
    PipelineConfig,
    CompressionReport,
)
