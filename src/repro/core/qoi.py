"""Quantity-of-interest surrogate: Arrhenius net production rates.

The paper's QoI is the per-species net production rate computed by Cantera
from the reconstructed mass fractions — an O(N) nonlinear map through
forward/reverse Arrhenius rate constants. Cantera is unavailable offline, so
we implement the same mathematical structure directly in JAX:

  k_f,r = A_r * T^b_r * exp(-Ea_r / (R T))
  k_r,r = k_f,r / Keq_r,  Keq_r = exp(dS_r/R - dH_r/(R T))
  rate_r = k_f,r * prod_i [X_i]^nu'_ir  -  k_r,r * prod_j [X_j]^nu''_jr
  wdot_s = sum_r (nu''_sr - nu'_sr) * rate_r,   [X_i] = rho Y_i / W_i

with a randomly generated (but fixed-seed) elementary mechanism over the S
species. This preserves exactly the error-amplification behaviour the paper
studies: minor-species PD errors blow up through the exponentials and
high-order concentration products.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

R_GAS = 8.314462618  # J/(mol K)


@dataclasses.dataclass(frozen=True)
class Mechanism:
    nu_fwd: np.ndarray  # (S, NR) reactant stoichiometry
    nu_rev: np.ndarray  # (S, NR) product stoichiometry
    log_a: np.ndarray  # (NR,)
    beta: np.ndarray  # (NR,)
    ea: np.ndarray  # (NR,) J/mol
    ds: np.ndarray  # (NR,) J/(mol K)
    dh: np.ndarray  # (NR,) J/mol
    mol_weight: np.ndarray  # (S,) kg/mol
    density: float = 1.0  # kg/m^3 (constant-volume surrogate)


def make_mechanism(n_species: int, n_reactions: int | None = None, seed: int = 7) -> Mechanism:
    rng = np.random.default_rng(seed)
    nr = n_reactions or 4 * n_species
    nu_f = np.zeros((n_species, nr))
    nu_r = np.zeros((n_species, nr))
    for r in range(nr):
        reactants = rng.choice(n_species, size=rng.integers(1, 3), replace=False)
        products = rng.choice(
            [s for s in range(n_species) if s not in reactants],
            size=rng.integers(1, 3),
            replace=False,
        )
        nu_f[reactants, r] = rng.integers(1, 3, size=len(reactants))
        nu_r[products, r] = rng.integers(1, 3, size=len(products))
    return Mechanism(
        nu_fwd=nu_f,
        nu_rev=nu_r,
        log_a=rng.uniform(2.0, 10.0, nr),  # log10 pre-exponential
        beta=rng.uniform(-0.5, 1.5, nr),
        ea=rng.uniform(2.0e4, 1.6e5, nr),
        ds=rng.uniform(-40.0, 40.0, nr),
        dh=rng.uniform(-2.0e5, 2.0e5, nr),
        mol_weight=rng.uniform(0.002, 0.12, n_species),
    )


def production_rates(mech: Mechanism, y: jax.Array, temperature: jax.Array) -> jax.Array:
    """wdot for each species. y: (..., S) mass fractions; T: (...)."""
    conc = mech.density * y / jnp.asarray(mech.mol_weight)  # (..., S)
    log_conc = jnp.log(jnp.clip(conc, 1e-30))  # fp32-safe floor
    t = temperature[..., None]  # (..., 1) broadcast over reactions
    log_kf = (
        jnp.asarray(mech.log_a) * jnp.log(10.0)
        + jnp.asarray(mech.beta) * jnp.log(t)
        - jnp.asarray(mech.ea) / (R_GAS * t)
    )
    log_keq = jnp.asarray(mech.ds) / R_GAS - jnp.asarray(mech.dh) / (R_GAS * t)
    log_kr = log_kf - log_keq
    # product over species of [X]^nu  ->  exp(nu^T log[X]); clamped (see
    # _rates_jit)
    fwd = jnp.exp(jnp.clip(log_kf + log_conc @ jnp.asarray(mech.nu_fwd),
                           -700.0, 700.0))
    rev = jnp.exp(jnp.clip(log_kr + log_conc @ jnp.asarray(mech.nu_rev),
                           -700.0, 700.0))
    rate = fwd - rev  # (..., NR)
    return rate @ jnp.asarray((mech.nu_rev - mech.nu_fwd).T)  # (..., S)


@jax.jit
def _rates_jit(nu_f, nu_r, log_a, beta, ea, ds, dh, inv_w, rho, y, t):
    conc = rho * y * inv_w
    log_conc = jnp.log(jnp.clip(conc, 1e-30))  # fp32-safe floor
    tt = t[..., None]
    log_kf = log_a * jnp.log(10.0) + beta * jnp.log(tt) - ea / (R_GAS * tt)
    log_kr = log_kf - (ds / R_GAS - dh / (R_GAS * tt))
    # clamp exponents: physically k*prod[X] stays finite; random mechanisms
    # can otherwise overflow fp64 (exp(>709)) and poison the NRMSE metric
    fwd = jnp.exp(jnp.clip(log_kf + log_conc @ nu_f, -700.0, 700.0))
    rev = jnp.exp(jnp.clip(log_kr + log_conc @ nu_r, -700.0, 700.0))
    return (fwd - rev) @ (nu_r - nu_f).T


def production_rates_np(mech: Mechanism, y: np.ndarray, temperature: np.ndarray) -> np.ndarray:
    """Batched host entry point: y (S, T, H, W), temperature (T, H, W)."""
    s = y.shape[0]
    yy = np.moveaxis(y, 0, -1).reshape(-1, s).astype(np.float64)
    tt = temperature.reshape(-1).astype(np.float64)
    out = _rates_jit(
        jnp.asarray(mech.nu_fwd),
        jnp.asarray(mech.nu_rev),
        jnp.asarray(mech.log_a),
        jnp.asarray(mech.beta),
        jnp.asarray(mech.ea),
        jnp.asarray(mech.ds),
        jnp.asarray(mech.dh),
        jnp.asarray(1.0 / mech.mol_weight),
        mech.density,
        jnp.asarray(yy),
        jnp.asarray(tt),
    )
    out = np.asarray(out)
    return np.moveaxis(out.reshape(temperature.shape + (s,)), -1, 0)
