"""Entropy coding: canonical Huffman (bit-exact) + zstd backend.

The Huffman path is the paper's coder: quantized integer streams are
frequency-counted, a canonical Huffman code is built, and the stream is
bit-packed with a self-describing header (symbol table + code lengths).
Encoding is vectorized in numpy (loop over code-bit position, not symbols);
decoding uses a k-bit lookup table.

``zstd_bytes`` exposes the zstandard backend used as the final lossless
stage of the SZ baseline (matching SZ3's use of zstd).
"""

from __future__ import annotations

import heapq
import io
import struct

import numpy as np
import zstandard

_MAGIC = b"HUF1"
_MAX_CODE_LEN = 32


def _code_lengths(freqs: np.ndarray) -> np.ndarray:
    """Huffman code lengths via heap merge. freqs: (K,) positive counts."""
    k = len(freqs)
    if k == 1:
        return np.array([1], dtype=np.int64)
    heap = [(int(f), i) for i, f in enumerate(freqs)]
    heapq.heapify(heap)
    parent = np.full(2 * k - 1, -1, dtype=np.int64)
    next_id = k
    while len(heap) > 1:
        fa, a = heapq.heappop(heap)
        fb, b = heapq.heappop(heap)
        parent[a] = next_id
        parent[b] = next_id
        heapq.heappush(heap, (fa + fb, next_id))
        next_id += 1
    depth = np.zeros(2 * k - 1, dtype=np.int64)
    for node in range(next_id - 2, -1, -1):
        depth[node] = depth[parent[node]] + 1
    lengths = depth[:k]
    if lengths.max() > _MAX_CODE_LEN:
        raise ValueError("Huffman code exceeds 32 bits; alphabet too skewed")
    return lengths


def _canonical_codes(lengths: np.ndarray) -> np.ndarray:
    """Canonical code values: symbols sorted by (length, symbol index)."""
    order = np.lexsort((np.arange(len(lengths)), lengths))
    codes = np.zeros(len(lengths), dtype=np.uint64)
    code = 0
    prev_len = int(lengths[order[0]])
    for idx in order:
        ln = int(lengths[idx])
        code <<= ln - prev_len
        codes[idx] = code
        code += 1
        prev_len = ln
    return codes


def huffman_encode(values: np.ndarray) -> bytes:
    """Encode an int array. Self-describing: header + packed bits."""
    values = np.asarray(values).ravel()
    if values.size == 0:
        return _MAGIC + struct.pack("<QI", 0, 0)
    symbols, inverse = np.unique(values, return_inverse=True)
    freqs = np.bincount(inverse)
    lengths = _code_lengths(freqs)
    codes = _canonical_codes(lengths)

    sym_lengths = lengths[inverse]
    sym_codes = codes[inverse]
    offsets = np.concatenate(([0], np.cumsum(sym_lengths)[:-1]))
    total_bits = int(sym_lengths.sum())

    bits = np.zeros(total_bits, dtype=np.uint8)
    max_len = int(lengths.max())
    for j in range(max_len):
        mask = sym_lengths > j
        pos = offsets[mask] + j
        shift = (sym_lengths[mask] - 1 - j).astype(np.uint64)
        bits[pos] = ((sym_codes[mask] >> shift) & np.uint64(1)).astype(np.uint8)
    payload = np.packbits(bits).tobytes()

    header = io.BytesIO()
    header.write(_MAGIC)
    header.write(struct.pack("<QI", values.size, len(symbols)))
    header.write(symbols.astype("<i8").tobytes())
    header.write(lengths.astype("<u1").tobytes())
    return header.getvalue() + payload


def huffman_decode(blob: bytes) -> np.ndarray:
    if blob[:4] != _MAGIC:
        raise ValueError("bad magic")
    n, k = struct.unpack_from("<QI", blob, 4)
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    off = 4 + 12
    symbols = np.frombuffer(blob, dtype="<i8", count=k, offset=off).copy()
    off += 8 * k
    lengths = np.frombuffer(blob, dtype="<u1", count=k, offset=off).astype(np.int64)
    off += k
    codes = _canonical_codes(lengths)

    bit_arr = np.unpackbits(np.frombuffer(blob, dtype=np.uint8, offset=off))
    # k-bit table decode
    table_bits = min(int(lengths.max()), 16)
    table_sym = np.full(1 << table_bits, -1, dtype=np.int64)
    table_len = np.zeros(1 << table_bits, dtype=np.int64)
    long_codes: dict[tuple[int, int], int] = {}
    for i in range(k):
        ln, cd = int(lengths[i]), int(codes[i])
        if ln <= table_bits:
            base = cd << (table_bits - ln)
            table_sym[base : base + (1 << (table_bits - ln))] = i
            table_len[base : base + (1 << (table_bits - ln))] = ln
        else:
            long_codes[(ln, cd)] = i

    out = np.empty(n, dtype=np.int64)
    # pad bit array so windowed reads never go OOB
    bit_arr = np.concatenate([bit_arr, np.zeros(_MAX_CODE_LEN + table_bits, np.uint8)])
    weights = (1 << np.arange(table_bits - 1, -1, -1)).astype(np.int64)
    pos = 0
    max_len = int(lengths.max())
    for i in range(n):
        window = int(bit_arr[pos : pos + table_bits] @ weights)
        sym_idx = table_sym[window]
        if sym_idx >= 0:
            out[i] = symbols[sym_idx]
            pos += int(table_len[window])
        else:
            # rare long code: extend bit by bit
            code = window
            ln = table_bits
            while True:
                ln += 1
                code = (code << 1) | int(bit_arr[pos + ln - 1])
                if (ln, code) in long_codes:
                    out[i] = symbols[long_codes[(ln, code)]]
                    pos += ln
                    break
                if ln > max_len:
                    raise ValueError("corrupt Huffman stream")
    return out


def huffman_size_bytes(values: np.ndarray) -> int:
    """Exact coded size without materializing the payload bit array."""
    values = np.asarray(values).ravel()
    if values.size == 0:
        return 4 + 12
    symbols, inverse = np.unique(values, return_inverse=True)
    freqs = np.bincount(inverse)
    lengths = _code_lengths(freqs)
    total_bits = int((freqs * lengths).sum())
    header = 4 + 12 + 9 * len(symbols)
    return header + (total_bits + 7) // 8


def zstd_bytes(data: bytes, level: int = 19) -> bytes:
    return zstandard.ZstdCompressor(level=level).compress(data)


def zstd_unbytes(blob: bytes) -> bytes:
    return zstandard.ZstdDecompressor().decompress(blob)
