"""Entropy coding: canonical Huffman (bit-exact) + zstd backend.

The Huffman path is the paper's coder: quantized integer streams are
frequency-counted, a canonical Huffman code is built, and the stream is
bit-packed with a self-describing header (symbol table + code lengths).
Encoding is vectorized in numpy (loop over code-bit position, not symbols);
decoding batches the k-bit table lookups over every bit position (a
byte-parallel window pass — constant sweeps, not one per code bit) and
walks the sequential codeword chain speculatively chunk-by-chunk (exact,
with a scalar fallback only for chunks that never self-synchronize); codes
longer than the table are resolved by a vectorized prefix match. Decode
tables memoize per codebook signature (:class:`DecodeTableCache`),
independent streams decode in one lockstep multi-stream chain walk
(:func:`huffman_decode_many`), and the pre-throughput-engine path is
retained as :func:`huffman_decode_ref` (parity-asserted baseline).

Segmented layouts — many independently decodable chains under ONE shared
codebook, e.g. the codec's time-sharded (container v3) latent stream —
use the headerless primitives: :func:`huffman_codebook` builds the table
once, :func:`huffman_payload` packs each segment's chain, and
:func:`huffman_decode_payloads` walks any subset of segments lockstep,
enforcing that every chain consumes its byte extent exactly.

``zstd_bytes`` exposes the zstandard backend used as the final lossless
stage of the SZ baseline (matching SZ3's use of zstd). When the
``zstandard`` wheel is absent (hermetic CI images), stdlib ``zlib`` stands
in — same role in the pipeline, slightly worse ratio, self-describing via a
one-byte backend tag so streams decode with either backend present.
"""

from __future__ import annotations

import heapq
import io
import struct
import threading
import zlib
from typing import Optional

import numpy as np

# repro: allow-file[wire-centralization] — entropy owns the Huffman
# stream wire format (magic "HUF1" + codebook framing); it is the one
# sanctioned secondary wire site, round-trip-tested in tier-1.

try:  # optional: not all images carry the zstandard wheel
    import zstandard
except ImportError:  # pragma: no cover - depends on environment
    zstandard = None

_MAGIC = b"HUF1"
_MAX_CODE_LEN = 32
_CHAIN_BPC = 128  # chain-walk chunk bits: best vector-width/round-count balance


def _code_lengths(freqs: np.ndarray) -> np.ndarray:
    """Huffman code lengths via heap merge. freqs: (K,) positive counts."""
    k = len(freqs)
    if k == 1:
        return np.array([1], dtype=np.int64)
    heap = [(int(f), i) for i, f in enumerate(freqs)]
    heapq.heapify(heap)
    parent = np.full(2 * k - 1, -1, dtype=np.int64)
    next_id = k
    while len(heap) > 1:
        fa, a = heapq.heappop(heap)
        fb, b = heapq.heappop(heap)
        parent[a] = next_id
        parent[b] = next_id
        heapq.heappush(heap, (fa + fb, next_id))
        next_id += 1
    depth = np.zeros(2 * k - 1, dtype=np.int64)
    for node in range(next_id - 2, -1, -1):
        depth[node] = depth[parent[node]] + 1
    lengths = depth[:k]
    if lengths.max() > _MAX_CODE_LEN:
        raise ValueError("Huffman code exceeds 32 bits; alphabet too skewed")
    return lengths


def _canonical_codes(lengths: np.ndarray) -> np.ndarray:
    """Canonical code values: symbols sorted by (length, symbol index)."""
    if len(lengths) and (lengths.min() < 1 or lengths.max() > _MAX_CODE_LEN):
        # a corrupt stored codebook (writers never emit these) must fail
        # typed here, not overflow/misbehave in the table build below
        raise ValueError(
            f"corrupt Huffman codebook: code lengths span "
            f"[{lengths.min()}, {lengths.max()}], legal range is "
            f"[1, {_MAX_CODE_LEN}]"
        )
    order = np.lexsort((np.arange(len(lengths)), lengths))
    codes = np.zeros(len(lengths), dtype=np.uint64)
    code = 0
    prev_len = int(lengths[order[0]])
    for idx in order:
        ln = int(lengths[idx])
        code <<= ln - prev_len
        codes[idx] = code
        code += 1
        prev_len = ln
    return codes


def _pack_payload_bitloop(sym_codes, sym_lengths, offsets, total_bits) -> bytes:
    """Reference payload packer: one masked pass per code-bit position.

    Retained as the parity oracle for :func:`_pack_payload` (and for the
    long-code edge cases the tests pin); ``huffman_encode`` no longer calls
    it on the hot path.
    """
    bits = np.zeros(total_bits, dtype=np.uint8)
    max_len = int(sym_lengths.max())
    for j in range(max_len):
        mask = sym_lengths > j
        pos = offsets[mask] + j
        shift = (sym_lengths[mask] - 1 - j).astype(np.uint64)
        bits[pos] = ((sym_codes[mask] >> shift) & np.uint64(1)).astype(np.uint8)
    return np.packbits(bits).tobytes()


def _or_runs(out: np.ndarray, targets: np.ndarray, values: np.ndarray) -> None:
    """``out[t] |= OR of values at t`` for *sorted* targets, loop-free.

    Consecutive equal targets form runs; ``bitwise_or.reduceat`` collapses
    each run in one pass, then a single fancy-index OR lands the results.
    """
    if targets.size == 0:
        return
    starts = np.flatnonzero(np.r_[True, targets[1:] != targets[:-1]])
    out[targets[starts]] |= np.bitwise_or.reduceat(values, starts)


def _pack_payload(sym_codes, sym_lengths, offsets, total_bits) -> bytes:
    """Table-driven batched bit pack — no per-code-bit host loop.

    The bitstream is built as big-endian 64-bit words. A code of length
    ``l`` at bit offset ``o`` lands in word ``o // 64`` (left-aligned at
    phase ``o % 64``) and, when it straddles the boundary (phase + l > 64),
    spills its low bits into the next word. Codes are <= 32 bits, so no code
    touches more than two words. Bit offsets are monotone, hence both the
    primary and the spill word-index streams arrive sorted and the
    per-word OR-accumulate collapses to two ``reduceat`` passes — every
    step is a full-width vector op over the symbol stream. Bit-identical to
    :func:`_pack_payload_bitloop` (asserted in the unit suite).
    """
    nbytes = (total_bits + 7) // 8
    nwords = (total_bits + 63) // 64
    w = (offsets >> 6).astype(np.int64)
    phase = offsets & 63
    spill_bits = sym_lengths + phase - 64  # > 0: code straddles the boundary
    codes = sym_codes.astype(np.uint64)
    lsh = np.where(spill_bits <= 0, -spill_bits, 0).astype(np.uint64)
    rsh = np.where(spill_bits > 0, spill_bits, 0).astype(np.uint64)
    hi = np.where(spill_bits <= 0, codes << lsh, codes >> rsh)
    out = np.zeros(nwords + 1, dtype=np.uint64)  # +1: spill off the last word
    _or_runs(out, w, hi)
    straddle = spill_bits > 0
    if straddle.any():
        lo = codes[straddle] << (64 - rsh[straddle])
        _or_runs(out, w[straddle] + 1, lo)
    return out.astype(">u8").tobytes()[:nbytes]


def huffman_encode(values: np.ndarray) -> bytes:
    """Encode an int array. Self-describing: header + packed bits."""
    values = np.asarray(values).ravel()
    if values.size == 0:
        return _MAGIC + struct.pack("<QI", 0, 0)
    symbols, inverse = np.unique(values, return_inverse=True)
    freqs = np.bincount(inverse)
    lengths = _code_lengths(freqs)
    codes = _canonical_codes(lengths)

    sym_lengths = lengths[inverse]
    sym_codes = codes[inverse]
    offsets = np.concatenate(([0], np.cumsum(sym_lengths)[:-1]))
    total_bits = int(sym_lengths.sum())
    payload = _pack_payload(sym_codes, sym_lengths, offsets, total_bits)

    header = io.BytesIO()
    header.write(_MAGIC)
    header.write(struct.pack("<QI", values.size, len(symbols)))
    header.write(symbols.astype("<i8").tobytes())
    header.write(lengths.astype("<u1").tobytes())
    return header.getvalue() + payload


# ---------------------------------------------------------------------------
# shared-codebook (segmented) coding: one codebook, many independent chains
# ---------------------------------------------------------------------------
def huffman_codebook(values: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Canonical codebook ``(symbols, code lengths)`` for ``values``.

    The codebook half of :func:`huffman_encode`, exposed standalone so
    segmented layouts — many independently decodable chains sharing ONE
    codebook, e.g. the codec's time-sharded latent stream — can store the
    table once and pack each segment with :func:`huffman_payload`.
    """
    values = np.asarray(values).ravel()
    if values.size == 0:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    symbols, inverse = np.unique(values, return_inverse=True)
    freqs = np.bincount(inverse)
    return symbols.astype(np.int64), _code_lengths(freqs)


def huffman_codebook_parts(parts) -> tuple[np.ndarray, np.ndarray]:
    """:func:`huffman_codebook` over a sequence of array parts without
    concatenating them: per-part sorted-unique symbol counts merge into
    the global (symbol, count) table, and Huffman tie-breaking orders by
    (count, sorted-symbol index) either way — so the codebook is bitwise
    the one ``huffman_codebook(concatenate(parts))`` builds. This is how
    sharded fits feed the v3 latent stream: each shard's latent block
    contributes counts, the full latent matrix never lands in one host
    array."""
    merged: dict[int, int] = {}
    for part in parts:
        values = np.asarray(part).ravel()
        if values.size == 0:
            continue
        syms, counts = np.unique(values, return_counts=True)
        for s, c in zip(syms.astype(np.int64), counts):
            merged[int(s)] = merged.get(int(s), 0) + int(c)
    if not merged:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    symbols = np.array(sorted(merged), dtype=np.int64)
    freqs = np.array([merged[int(s)] for s in symbols], dtype=np.int64)
    return symbols, _code_lengths(freqs)


def huffman_payload(
    values: np.ndarray, symbols: np.ndarray, lengths: np.ndarray,
    codes: Optional[np.ndarray] = None,
) -> bytes:
    """Pack ``values`` as one headerless Huffman bit chain under a shared
    codebook (the payload :func:`huffman_encode` would emit for the same
    values if the codebook matches). Raises ``ValueError`` when a value is
    not in ``symbols`` — a segment may never silently extend the codebook.
    ``codes`` passes pre-computed :func:`_canonical_codes` so a caller
    packing many segments (one per shard) pays the python-loop code build
    once, not per segment.
    """
    values = np.asarray(values).ravel()
    if values.size == 0:
        return b""
    symbols = np.asarray(symbols, dtype=np.int64)
    lengths = np.asarray(lengths, dtype=np.int64)
    idx = np.searchsorted(symbols, values)
    idx_c = np.minimum(idx, max(len(symbols) - 1, 0))
    if len(symbols) == 0 or not np.array_equal(symbols[idx_c], values):
        raise ValueError("value outside the shared Huffman codebook")
    if codes is None:
        codes = _canonical_codes(lengths)
    sym_lengths = lengths[idx_c]
    sym_codes = codes[idx_c]
    offsets = np.concatenate(([0], np.cumsum(sym_lengths)[:-1]))
    return _pack_payload(sym_codes, sym_lengths, offsets,
                         int(sym_lengths.sum()))


def _decode_table(lengths: np.ndarray, codes: np.ndarray):
    """k-bit lookup table + dict of codes too long for the table."""
    k = len(lengths)
    table_bits = min(int(lengths.max()), 16)
    table_sym = np.full(1 << table_bits, -1, dtype=np.int32)
    table_len = np.zeros(1 << table_bits, dtype=np.int32)
    long_codes: dict[tuple[int, int], int] = {}
    for i in range(k):
        ln, cd = int(lengths[i]), int(codes[i])
        if ln <= table_bits:
            base = cd << (table_bits - ln)
            table_sym[base : base + (1 << (table_bits - ln))] = i
            table_len[base : base + (1 << (table_bits - ln))] = ln
        else:
            long_codes[(ln, cd)] = i
    return table_bits, table_sym, table_len, long_codes


class DecodeTableCache:
    """Bounded memo of canonical decode tables keyed by codebook signature.

    The lookup table (and the long-code map) depend only on the code-length
    vector — canonical codes are a pure function of it, and table entries
    are symbol *indices* — so the key is ``lengths.tobytes()``. Deserialize
    previously rebuilt the table per species per call; a decode runtime
    holding one of these pays table construction once per codebook.
    Thread-safe (coeff streams decode species-parallel).
    """

    def __init__(self, max_entries: int = 64):
        self._max = max_entries
        self._tables: dict[bytes, tuple] = {}
        self._lock = threading.Lock()
        self._hits = 0
        self._misses = 0

    def get(self, lengths: np.ndarray):
        key = lengths.tobytes()
        with self._lock:
            hit = self._tables.get(key)
            if hit is not None:
                self._hits += 1
                return hit
            self._misses += 1
        table = _decode_table(lengths, _canonical_codes(lengths))
        with self._lock:
            while len(self._tables) >= self._max:
                self._tables.pop(next(iter(self._tables)))
            self._tables[key] = table
        return table

    def clear(self) -> None:
        """Drop every memoized table (counters are cumulative and stay)."""
        with self._lock:
            self._tables.clear()

    def stats(self) -> dict:
        """Hit/miss counters + occupancy (schema mirrors the decode-cache
        tiers so codec.cache_stats() can aggregate across runtimes)."""
        with self._lock:
            hits, misses, entries = self._hits, self._misses, \
                len(self._tables)
        total = hits + misses
        return {
            "hits": hits,
            "misses": misses,
            "hit_rate": (hits / total) if total else 0.0,
            "entries": entries,
        }


def _window_values_ref(bit_arr: np.ndarray, width: int) -> np.ndarray:
    """Reference window extractor: one shift-or pass per code bit.

    Retained as the parity oracle for :func:`_window_values` (and as part
    of the pre-change deserialize baseline, :func:`huffman_decode_ref`).
    """
    w = len(bit_arr) - width
    vals = np.zeros(w, dtype=np.int32)
    for j in range(width):
        np.left_shift(vals, 1, out=vals)
        np.bitwise_or(vals, bit_arr[j : j + w], out=vals)
    return vals


def _window_values(bit_arr: np.ndarray, width: int) -> np.ndarray:
    """Big-endian integer value of ``bit_arr[p : p + width]`` for every p.

    Byte-parallel: repack the (zero-padded) bits into bytes, build one
    32-bit big-endian window per *byte* position, then every bit position p
    reads word ``p // 8`` shifted by its phase — a constant number of
    full-width passes instead of one per code bit (``width`` is up to 16).
    Bit-identical to :func:`_window_values_ref` (asserted in the suite).
    """
    n_out = len(bit_arr) - width
    if n_out <= 0:
        return np.zeros(max(n_out, 0), dtype=np.int32)
    b = np.packbits(bit_arr)
    n_bytes = (n_out + 7) >> 3
    bp = np.zeros(n_bytes + 3, dtype=np.uint32)
    m = min(len(b), n_bytes + 3)
    bp[:m] = b[:m]
    words = (bp[:n_bytes] << 24) | (bp[1 : n_bytes + 1] << 16) \
        | (bp[2 : n_bytes + 2] << 8) | bp[3 : n_bytes + 3]
    rep = np.repeat(words, 8)[:n_out]
    phase = np.tile(np.arange(8, dtype=np.uint32), n_bytes)[:n_out]
    rep >>= np.uint32(32 - width) - phase
    rep &= np.uint32((1 << width) - 1)
    return rep.astype(np.int32)


def _resolve_long_codes(bit_arr, sym_at, len_at, long_codes):
    """Fix (sym, len) at positions whose code exceeds the table width.

    No short code is a prefix of a long one, so long-code positions are
    exactly the table misses, and at most one long code matches each.
    """
    miss = np.flatnonzero(sym_at < 0)
    if miss.size == 0:
        return
    by_len: dict[int, dict[int, int]] = {}
    for (ln, cd), i in long_codes.items():
        by_len.setdefault(ln, {})[cd] = i
    for ln in sorted(by_len):
        pairs = sorted(by_len[ln].items())
        cds = np.array([c for c, _ in pairs], dtype=np.int64)
        syms = np.array([i for _, i in pairs], dtype=np.int64)
        window = np.zeros(miss.size, dtype=np.int64)
        for j in range(ln):
            window = (window << 1) | bit_arr[miss + j].astype(np.int64)
        slot = np.searchsorted(cds, window)
        hit = (slot < len(cds)) & (cds[np.minimum(slot, len(cds) - 1)] == window)
        sym_at[miss[hit]] = syms[slot[hit]].astype(np.int32)
        len_at[miss[hit]] = ln
        miss = miss[~hit]
        if miss.size == 0:
            return


def _chain_positions(len_at: np.ndarray, n: int) -> np.ndarray:
    """Bit positions of the first ``n`` codewords of one stream
    (see :func:`_chain_positions_multi`)."""
    return _chain_positions_multi([(len_at, n)])[0]


def _chain_positions_multi(
    streams: "list[tuple[np.ndarray, int]]",
) -> "list[np.ndarray]":
    """Codeword bit positions, ``p_{i+1} = p_i + len[p_i]``, for one *or
    many independent streams* walked in lockstep.

    The position chain is inherently sequential, so it is decoded
    speculatively in three vectorized phases:

    1. cut each bitstream into small chunks and walk every chunk (across
       all streams at once) from its boundary in lockstep — one vectorized
       step per round, recording positions and each walk's exit into the
       next chunk;
    2. walk every chunk again in lockstep from its *candidate true entry* —
       the previous chunk's speculative exit (each stream's first chunk
       starts from its true origin) — until it joins that chunk's phase-1
       walk (Huffman streams self-synchronize, so this takes a few
       codewords at most);
    3. assemble prefix + joined tail per chunk with two ragged scatters
       and split the result back per stream.

    Chunks that never self-synchronize invalidate their successor's entry;
    those successors (rare) are re-walked scalar, cascading only until a
    walk re-joins the speculative chain — never across a stream boundary.
    The result is always exact. Batching streams multiplies the lockstep
    vector width instead of the (python-level) round count, which is what
    makes multi-species coefficient decode fast.
    """
    bpc = _CHAIN_BPC  # codewords (<=32 bits) never span a chunk
    sizes = [len(la) for la, _ in streams]
    bases = np.zeros(len(streams), dtype=np.int64)
    np.cumsum(sizes[:-1], out=bases[1:])
    len_at = (
        streams[0][0] if len(streams) == 1
        else np.concatenate([la for la, _ in streams])
    )
    b = len(len_at)
    chunk_counts = [-(-size // bpc) for size in sizes]
    starts = np.concatenate([
        base + np.arange(c, dtype=np.int64) * bpc
        for base, c in zip(bases, chunk_counts)
    ])
    ends = np.concatenate([
        np.minimum(base + np.arange(1, c + 1, dtype=np.int64) * bpc,
                   base + size)
        for base, c, size in zip(bases, chunk_counts, sizes)
    ])
    n_chunks = len(starts)
    if n_chunks == 0:
        if any(n for _, n in streams):
            raise ValueError("corrupt Huffman stream")
        return [np.zeros(0, np.int64) for _ in streams]
    first_chunk = np.zeros(len(streams) + 1, dtype=np.int64)
    np.cumsum(chunk_counts, out=first_chunk[1:])
    is_first = np.zeros(n_chunks, dtype=bool)
    is_first[first_chunk[:-1]] = True
    is_last = np.zeros(n_chunks, dtype=bool)
    is_last[first_chunk[1:] - 1] = True
    if not (len_at > 0).all():
        # only possible with unresolved long-code windows; the chain must
        # never step on one, so guard each round below
        def checked_step(cur, mask):
            step = len_at[cur]
            if not (step[mask] > 0).all():
                raise ValueError("corrupt Huffman stream")
            return step
    else:
        def checked_step(cur, mask):
            return len_at[cur]

    # -- phase 1: speculative boundary walks ---------------------------
    cur = starts.copy()
    active = cur < ends
    exits = ends.copy()
    records = []
    counts = np.zeros(n_chunks, dtype=np.int64)
    while active.any():
        records.append(cur.copy())
        counts += active
        nxt = cur + checked_step(cur, active)
        crossed = active & (nxt >= ends)
        if crossed.any():
            exits[crossed] = nxt[crossed]
        still = active & (nxt < ends)
        cur = np.where(still, nxt, cur)
        active = still
    rec = (
        np.stack(records, axis=0) if records else np.zeros((0, n_chunks), np.int64)
    )
    n_rounds = len(records)
    # O(1) membership: was p visited speculatively, and at which round of
    # its chunk? (walks never leave their chunk, so ranges are disjoint)
    valid = np.arange(n_rounds, dtype=np.int64)[:, None] < counts[None, :]
    spec_pos = rec[valid]
    visited = np.zeros(b + 1, dtype=bool)
    rank = np.zeros(b + 1, dtype=np.int64)
    visited[spec_pos] = True
    rank[spec_pos] = np.broadcast_to(
        np.arange(n_rounds, dtype=np.int64)[:, None], rec.shape
    )[valid]

    # -- phase 2: lockstep resync from candidate true entries ----------
    # each stream's first chunk enters at its true origin; later chunks at
    # the previous chunk's speculative exit
    entry0 = np.empty(n_chunks, dtype=np.int64)
    entry0[1:] = exits[:-1]
    entry0[is_first] = starts[is_first]
    walking = entry0 < ends
    cur = np.where(walking, entry0, 0)
    walk_end = entry0.copy()  # walk-off position per chunk (for repair)
    joined = np.zeros(n_chunks, dtype=bool)
    join_rank = np.zeros(n_chunks, dtype=np.int64)
    pre_records = []
    pre_counts = np.zeros(n_chunks, dtype=np.int64)
    while walking.any():
        hit = walking & visited[cur]
        if hit.any():
            join_rank[hit] = rank[cur[hit]]
            joined |= hit
            walking = walking & ~hit
            if not walking.any():
                break
        pre_records.append(cur.copy())
        pre_counts += walking
        nxt = cur + checked_step(cur, walking)
        off_chunk = walking & (nxt >= ends)
        if off_chunk.any():
            walk_end[off_chunk] = nxt[off_chunk]
        walking = walking & (nxt < ends)
        cur = np.where(walking, nxt, cur)
    pre = (
        np.stack(pre_records, axis=0)
        if pre_records
        else np.zeros((0, n_chunks), np.int64)
    )

    # -- repair: successors of chunks that never joined ----------------
    # a stream's last chunk has no successor — its walk-off never feeds
    # another chunk, and repair must not cascade across stream boundaries
    repaired: dict[int, np.ndarray] = {}
    if n_chunks > 1 and not joined[~is_last].all():
        repair_end: dict[int, int] = {}
        for c in np.flatnonzero(~joined & ~is_last).tolist():
            nxt_c = c + 1
            entry = repair_end.get(c, int(walk_end[c]))
            if nxt_c in repaired:
                continue
            while nxt_c < n_chunks and not is_first[nxt_c]:
                if nxt_c not in repaired and entry == int(entry0[nxt_c]):
                    break  # speculative entry was right after all
                prefix = []
                p = entry
                join = None
                while p < ends[nxt_c]:
                    if visited[p]:
                        join = int(rank[p])
                        break
                    step = int(len_at[p])
                    if step <= 0:
                        raise ValueError("corrupt Huffman stream")
                    prefix.append(p)
                    p += step
                repaired[nxt_c] = np.array(prefix, dtype=np.int64)
                joined[nxt_c] = join is not None
                join_rank[nxt_c] = join if join is not None else 0
                pre_counts[nxt_c] = len(prefix)
                # once joined, the true chain rides the speculative one to
                # its recorded exit; otherwise our walk-off is the exit
                repair_end[nxt_c] = int(exits[nxt_c]) if join is not None else p
                if join is not None:
                    break
                entry = p
                nxt_c += 1
                if nxt_c in repaired:
                    break

    # -- phase 3: ragged assembly --------------------------------------
    tail_counts = np.where(joined, counts - join_rank, 0)
    lengths = pre_counts + tail_counts
    off = np.zeros(n_chunks + 1, dtype=np.int64)
    np.cumsum(lengths, out=off[1:])
    out = np.empty(off[-1], dtype=np.int64)
    if pre.size:
        rows = np.arange(pre.shape[0], dtype=np.int64)[:, None]
        mask = rows < pre_counts[None, :]
        if repaired:
            mask[:, list(repaired)] = False
        out[(off[:-1][None, :] + rows)[mask]] = pre[mask]
    if rec.size:
        rows = np.arange(n_rounds, dtype=np.int64)[:, None]
        mask = joined[None, :] & (rows >= join_rank[None, :]) & valid
        dest = off[:-1][None, :] + pre_counts[None, :] + rows - join_rank[None, :]
        out[dest[mask]] = rec[mask]
    for c, prefix in repaired.items():
        out[off[c] : off[c] + len(prefix)] = prefix
    # split chunk-contiguous positions back per stream (rebased to 0)
    results: list[np.ndarray] = []
    for i, (_, n) in enumerate(streams):
        lo = off[first_chunk[i]]
        hi = off[first_chunk[i + 1]]
        if hi - lo < n:
            raise ValueError("corrupt Huffman stream")
        results.append(out[lo : lo + n] - bases[i])
    return results


def _parse_header(blob: bytes):
    if blob[:4] != _MAGIC:
        raise ValueError("bad magic")
    n, k = struct.unpack_from("<QI", blob, 4)
    off = 4 + 12
    symbols = np.frombuffer(blob, dtype="<i8", count=k, offset=off).copy()
    off += 8 * k
    lengths = np.frombuffer(blob, dtype="<u1", count=k, offset=off).astype(np.int64)
    off += k
    return n, symbols, lengths, off


def _check_payload_length(pos, len_at, payload_nbytes: int) -> None:
    """The decoded chain must consume the payload *exactly*.

    The encoder emits ``ceil(total_bits / 8)`` payload bytes; a stream
    sliced short decodes into the zero padding and a stream sliced long
    carries bytes no symbol accounts for. Both used to pass silently —
    with length-framed sub-streams (the selective-decode container) either
    one means the framing is corrupt, so fail here rather than hand back
    plausible-looking symbols.
    """
    end_bits = int(pos[-1] + len_at[pos[-1]])
    if (end_bits + 7) // 8 != payload_nbytes:
        raise ValueError(
            f"corrupt Huffman stream: {payload_nbytes} payload bytes on the "
            f"wire but the symbol chain spans {end_bits} bits"
        )


def _prepare_stream(blob: bytes, table_cache: Optional[DecodeTableCache]):
    """Header/table/window phase of decode: everything except the
    (sequential) codeword chain. Returns
    (n, symbols, sym_at, len_at, payload_nbytes). The payload phase is
    shared with the headerless (segmented) path — a self-describing
    stream is its inline codebook plus one :func:`_prepare_payload`."""
    n, symbols, lengths, off = _parse_header(blob)
    if n == 0:
        if len(blob) != off:
            raise ValueError(
                f"corrupt Huffman stream: empty stream carries "
                f"{len(blob) - off} trailing payload bytes"
            )
        return 0, symbols, None, None, 0
    sym_at, len_at = _prepare_payload(
        memoryview(blob)[off:], int(n), lengths, table_cache
    )
    return int(n), symbols, sym_at, len_at, len(blob) - off


def _prepare_payload(
    payload: bytes, n: int, lengths: np.ndarray,
    table_cache: Optional[DecodeTableCache],
):
    """Window/table phase for a headerless chain under a known codebook.

    Returns ``(sym_at, len_at)`` (``(None, None)`` for an empty chain);
    the caller supplies the symbol count and the codebook that a
    self-describing stream would carry inline.
    """
    if n == 0:
        if len(payload):
            raise ValueError(
                f"corrupt Huffman payload: empty chain carries "
                f"{len(payload)} bytes"
            )
        return None, None
    if len(lengths) == 0:
        raise ValueError(
            "corrupt Huffman payload: empty codebook with symbols to decode"
        )
    if table_cache is not None:
        table_bits, table_sym, table_len, long_codes = table_cache.get(lengths)
    else:
        table_bits, table_sym, table_len, long_codes = _decode_table(
            lengths, _canonical_codes(lengths)
        )
    bit_arr = np.unpackbits(np.frombuffer(payload, dtype=np.uint8))
    # pad so windowed reads never go OOB; stays uint8 — the window and
    # long-code passes upcast on the fly, so per-bit memory stays 1 byte
    bit_arr = np.concatenate(
        [bit_arr, np.zeros(_MAX_CODE_LEN + table_bits, np.uint8)]
    )
    win = _window_values(bit_arr, table_bits)
    sym_at = table_sym[win]
    len_at = table_len[win]
    if long_codes:
        _resolve_long_codes(bit_arr, sym_at, len_at, long_codes)
    return sym_at, len_at


def _grouped_positions(
    entries: "list[tuple[np.ndarray, int]]",
) -> "list[np.ndarray]":
    """Chain positions for many independent streams, lockstep-walked in
    adaptively sized groups: batching pays while the combined walk state
    stays cache-resident (many small streams — the high-compression
    regime); past that the walk goes bandwidth-bound and big streams run
    alone. The single scheduler behind :func:`huffman_decode_many` and
    :func:`huffman_decode_payloads`."""
    max_group_chunks = 4096  # ~bpc * 4096 bits of lockstep walk state
    groups: list[list[int]] = [[]]
    budget = max_group_chunks
    for j, (len_at, _) in enumerate(entries):
        chunks = -(-len(len_at) // _CHAIN_BPC)
        if groups[-1] and chunks > budget:
            groups.append([])
            budget = max_group_chunks
        groups[-1].append(j)
        budget -= chunks
    positions: list = [None] * len(entries)
    for group in groups:
        pos_list = _chain_positions_multi([entries[j] for j in group])
        for j, pos in zip(group, pos_list):
            positions[j] = pos
    return positions


def _finish_payload(symbols, sym_at, len_at, pos, payload_nbytes: int):
    """Symbol lookup + exact-consumption check shared by every decode path."""
    sym_idx = sym_at[pos]
    if (sym_idx < 0).any():
        raise ValueError("corrupt Huffman stream")
    _check_payload_length(pos, len_at, payload_nbytes)
    return symbols[sym_idx]


def huffman_decode_payloads(
    payloads: "list[bytes]",
    counts: "list[int]",
    symbols: np.ndarray,
    lengths: np.ndarray,
    *,
    table_cache: Optional[DecodeTableCache] = None,
) -> "list[np.ndarray]":
    """Decode independent headerless chains sharing ONE codebook.

    The segmented counterpart of :func:`huffman_decode_many`: the caller
    supplies the codebook (stored once on the wire) and each segment's
    symbol count; the sequential codeword chains run as lockstep
    multi-stream walks. Every chain must consume its (byte-padded) payload
    exactly — a mis-framed segment raises instead of decoding padding.
    """
    symbols = np.asarray(symbols, dtype=np.int64)
    lengths = np.asarray(lengths, dtype=np.int64)
    if len(payloads) != len(counts):
        raise ValueError("payloads and counts disagree in length")
    prepped = [
        _prepare_payload(p, int(n), lengths, table_cache)
        for p, n in zip(payloads, counts)
    ]
    out: list[np.ndarray] = [np.zeros(0, dtype=np.int64) for _ in payloads]
    live = [i for i, n in enumerate(counts) if n > 0]
    if not live:
        return out
    positions = _grouped_positions(
        [(prepped[i][1], int(counts[i])) for i in live]
    )
    for i, pos in zip(live, positions):
        sym_at, len_at = prepped[i]
        out[i] = _finish_payload(symbols, sym_at, len_at, pos,
                                 len(payloads[i]))
    return out


def huffman_decode_payload(
    payload: bytes, n: int, symbols: np.ndarray, lengths: np.ndarray,
    *, table_cache: Optional[DecodeTableCache] = None,
) -> np.ndarray:
    """Decode one headerless chain under a shared codebook."""
    return huffman_decode_payloads(
        [payload], [n], symbols, lengths, table_cache=table_cache
    )[0]


def huffman_decode_payload_ref(
    payload: bytes, n: int, symbols: np.ndarray, lengths: np.ndarray,
) -> np.ndarray:
    """Reference decode of one headerless chain: frame it as the
    self-describing stream :func:`huffman_encode` would emit (the payload
    bits are identical by construction) and run the retained pre-change
    decoder — per-call tables, per-code-bit window pass. The segmented
    counterpart of :func:`huffman_decode_ref`, so baselines that time the
    pre-change path stay honest on sharded streams."""
    symbols = np.asarray(symbols, dtype=np.int64)
    lengths = np.asarray(lengths, dtype=np.int64)
    framed = (
        _MAGIC + struct.pack("<QI", int(n), len(symbols))
        + symbols.astype("<i8").tobytes()
        + lengths.astype("<u1").tobytes()
        + payload
    )
    return huffman_decode_ref(framed)


def huffman_decode(
    blob: bytes, *, table_cache: Optional[DecodeTableCache] = None
) -> np.ndarray:
    """Decode a self-describing Huffman stream.

    ``table_cache`` memoizes decode-table construction across calls that
    share a codebook (a decode runtime's steady state); ``None`` builds the
    table per call. The symbol chain must account for the payload length
    exactly — truncated or over-long payloads raise rather than decode.
    """
    n, symbols, sym_at, len_at, payload_nbytes = _prepare_stream(
        blob, table_cache
    )
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    pos = _chain_positions(len_at, n)
    return _finish_payload(symbols, sym_at, len_at, pos, payload_nbytes)


def huffman_decode_many(
    blobs: "list[bytes]",
    *,
    table_cache: Optional[DecodeTableCache] = None,
) -> "list[np.ndarray]":
    """Decode independent Huffman streams together.

    The per-stream phases (header, tables, windows, symbol lookups) are
    vectorized already; the sequential codeword chains — the python-round
    bound part — run as lockstep multi-stream walks
    (:func:`_chain_positions_multi`), so decoding S species' coefficient
    streams costs ~the round count of the longest one, not the sum.
    Grouping is adaptive: batching pays while the combined walk state stays
    cache-resident (many small streams — the high-compression regime);
    past that the walk goes bandwidth-bound and big streams run alone.
    """
    prepped = [_prepare_stream(b, table_cache) for b in blobs]
    live = [i for i, (n, _, _, _, _) in enumerate(prepped) if n > 0]
    out: list[np.ndarray] = [
        np.zeros(0, dtype=np.int64) for _ in blobs
    ]
    if not live:
        return out
    positions = _grouped_positions(
        [(prepped[i][3], prepped[i][0]) for i in live]
    )
    for i, pos in zip(live, positions):
        n, symbols, sym_at, len_at, payload_nbytes = prepped[i]
        out[i] = _finish_payload(symbols, sym_at, len_at, pos,
                                 payload_nbytes)
    return out


def huffman_decode_ref(blob: bytes) -> np.ndarray:
    """The pre-throughput-engine decode path, retained as baseline/oracle:
    decode tables rebuilt per call, reference per-code-bit window pass.
    Output is bit-identical to :func:`huffman_decode`."""
    n, symbols, lengths, off = _parse_header(blob)
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    table_bits, table_sym, table_len, long_codes = _decode_table(
        lengths, _canonical_codes(lengths)
    )
    bit_arr = np.unpackbits(np.frombuffer(blob, dtype=np.uint8, offset=off))
    bit_arr = np.concatenate(
        [bit_arr, np.zeros(_MAX_CODE_LEN + table_bits, np.uint8)]
    )
    win = _window_values_ref(bit_arr, table_bits)
    sym_at = table_sym[win]
    len_at = table_len[win]
    if long_codes:
        _resolve_long_codes(bit_arr, sym_at, len_at, long_codes)
    pos = _chain_positions(len_at, int(n))
    sym_idx = sym_at[pos]
    if (sym_idx < 0).any():
        raise ValueError("corrupt Huffman stream")
    return symbols[sym_idx]


def huffman_size_bytes(values: np.ndarray) -> int:
    """Exact coded size without materializing the payload bit array."""
    values = np.asarray(values).ravel()
    if values.size == 0:
        return 4 + 12
    symbols, inverse = np.unique(values, return_inverse=True)
    freqs = np.bincount(inverse)
    lengths = _code_lengths(freqs)
    total_bits = int((freqs * lengths).sum())
    header = 4 + 12 + 9 * len(symbols)
    return header + (total_bits + 7) // 8


_ZSTD_TAG = b"\x01"
_ZLIB_TAG = b"\x02"


def zstd_bytes(data: bytes, level: int = 19) -> bytes:
    if zstandard is not None:
        return _ZSTD_TAG + zstandard.ZstdCompressor(level=level).compress(data)
    return _ZLIB_TAG + zlib.compress(data, level=min(level, 9))


def zstd_unbytes(blob: bytes) -> bytes:
    tag, payload = blob[:1], blob[1:]
    if tag == _ZSTD_TAG:
        if zstandard is None:
            raise RuntimeError("stream was zstd-coded but zstandard is absent")
        return zstandard.ZstdDecompressor().decompress(payload)
    if tag == _ZLIB_TAG:
        return zlib.decompress(payload)
    raise ValueError("unknown lossless-backend tag")
