"""Versioned, tagged-stream container format (the GBATC wire layout).

A container is a self-describing byte blob::

    magic "GBTC" (4) | version u16 | n_streams u16
    stream table: n_streams x { name_len u8 | name (ascii) | length u64 }
    payloads, concatenated in table order

Every stream is an opaque byte string addressed by name; nothing about the
layout is implicit, so a fresh process can enumerate and slice a container
without any codec state. :class:`ContainerReader` enforces the format
strictly — bad magic, unknown version, a truncated table, truncated
payloads, *and trailing garbage* all raise :class:`ContainerFormatError` —
which is what lets the codec assert ``len(blob)`` equals the sum of the
header and the stream table's lengths exactly (the byte accounting is a
view over this table, not an estimate).

Containers nest: a stream's payload may itself be a container (the codec
stores each species' guarantee artifact that way), and the framing overhead
of every level is measurable, so "metadata bytes" in the breakdown is a
real number rather than a ``8*S + 64`` guess.

Five versions share this byte layout; the version field declares the
*schema of the stream set* so readers pick the right interpretation:

* version 1 — the original GBATC layout: one nested ``guarantee<s>``
  container per species;
* version 2 — the selective-decode layout: a single combined ``guarantee``
  stream (CSR-of-CSR directory over species; see ``repro.codec``) whose
  per-species byte extents are addressable from the directory alone;
* version 3 — the time-sharded layout: v2's guarantee stream plus a
  segmented ``latent`` stream — the time axis partitioned into block-row
  shards, each an independently decodable Huffman chain under one shared
  codebook, fronted by a byte-extent directory — so a time-window decode
  entropy-decodes only the shards covering the window;
* version 4 — the integrity layout: v3's stream set plus an ``integrity``
  stream of CRC32 digests — one per sibling stream, plus fine-grained
  digests matching the random-access units (one per latent shard, one per
  species' guarantee byte-extent), plus a digest of this outer header —
  so a decoder verifies exactly the bytes it reads and no more (see
  ``repro.codec.format`` for the wire layout);
* version 5 — the encoder-family layout: v4's stream set, with the
  ``meta`` stream prefixed by a one-byte family tag (see
  ``repro.codec.families``) selecting which encoder family's decoder the
  remaining meta bytes configure. Below v5 the family is implicitly the
  conv block autoencoder; a conv-family v5 blob's payload streams are
  byte-identical to the v4 encoding of the same fit apart from that tag.

:class:`ContainerReader` accepts all five and exposes ``.version``;
anything else raises :class:`ContainerFormatError`.
"""

from __future__ import annotations

import struct

MAGIC = b"GBTC"
FORMAT_VERSION = 1
FORMAT_VERSION_SELECTIVE = 2
FORMAT_VERSION_SHARDED = 3
FORMAT_VERSION_INTEGRITY = 4
FORMAT_VERSION_FAMILY = 5
SUPPORTED_VERSIONS = (
    FORMAT_VERSION, FORMAT_VERSION_SELECTIVE, FORMAT_VERSION_SHARDED,
    FORMAT_VERSION_INTEGRITY, FORMAT_VERSION_FAMILY,
)

_HEAD = struct.Struct("<4sHH")  # magic, version, n_streams
_LEN = struct.Struct("<Q")

_MAX_NAME = 255


class ContainerFormatError(ValueError):
    """Raised when a blob is not a well-formed container of a known version.

    Carries structured context alongside the message, so salvage decode
    and tests consume the same facts the message states:

    * ``stream`` — name of the stream the failure was localized to
      (``None`` when the outer framing itself is at fault);
    * ``offset`` — byte offset of the failing region *within that
      stream's payload* (blob-absolute when ``stream`` is ``None``), or
      ``None`` when the failure has no single position;
    * ``unit`` — random-access unit index inside the stream (latent
      shard index, species index), or ``None``.
    """

    def __init__(self, message: str, *, stream: "str | None" = None,
                 offset: "int | None" = None, unit: "int | None" = None):
        super().__init__(message)
        self.stream = stream
        self.offset = offset
        self.unit = unit


class ContainerWriter:
    """Accumulates named streams; ``to_bytes`` emits header + table + payloads."""

    def __init__(self, version: int = FORMAT_VERSION):
        self.version = version
        self._streams: list[tuple[str, bytes]] = []

    def add(self, name: str, payload: bytes) -> None:
        if any(n == name for n, _ in self._streams):
            raise ValueError(f"duplicate stream name {name!r}")
        encoded = name.encode("ascii")
        if not 0 < len(encoded) <= _MAX_NAME:
            raise ValueError(f"stream name {name!r} must be 1..{_MAX_NAME} ascii bytes")
        self._streams.append((name, bytes(payload)))

    def to_bytes(self) -> bytes:
        head = pack_header(
            self.version, [(n, len(p)) for n, p in self._streams]
        )
        return head + b"".join(payload for _, payload in self._streams)


def pack_header(version: int, entries: "list[tuple[str, int]]") -> bytes:
    """The exact header + stream-table bytes :class:`ContainerWriter`
    emits for ``entries`` of (name, payload length) — exposed so the v4
    integrity stream can digest the outer framing it will be framed by
    (the table depends on the integrity payload's *length* only, which is
    computable before its content)."""
    parts = [_HEAD.pack(MAGIC, version, len(entries))]
    for name, length in entries:
        encoded = name.encode("ascii")
        parts.append(struct.pack("<B", len(encoded)))
        parts.append(encoded)
        parts.append(_LEN.pack(length))
    return b"".join(parts)


class ContainerReader:
    """Parses and validates a container blob; streams accessed by name."""

    def __init__(self, blob: bytes):
        blob = bytes(blob)
        if len(blob) < _HEAD.size:
            raise ContainerFormatError(
                f"truncated container: {len(blob)} bytes, header needs {_HEAD.size}",
                offset=0,
            )
        magic, version, n_streams = _HEAD.unpack_from(blob, 0)
        if magic != MAGIC:
            raise ContainerFormatError(
                f"bad magic {magic!r} (expected {MAGIC!r})", offset=0
            )
        if version not in SUPPORTED_VERSIONS:
            raise ContainerFormatError(
                f"unsupported container version {version} "
                f"(this reader speaks versions {SUPPORTED_VERSIONS})",
                offset=4,
            )
        off = _HEAD.size
        names: list[str] = []
        lengths: list[int] = []
        for _ in range(n_streams):
            if off + 1 > len(blob):
                raise ContainerFormatError("truncated stream table", offset=off)
            (name_len,) = struct.unpack_from("<B", blob, off)
            off += 1
            if off + name_len + _LEN.size > len(blob):
                raise ContainerFormatError("truncated stream table", offset=off)
            try:
                name = blob[off : off + name_len].decode("ascii")
            except UnicodeDecodeError as e:
                raise ContainerFormatError(
                    "non-ascii stream name", offset=off
                ) from e
            off += name_len
            (length,) = _LEN.unpack_from(blob, off)
            off += _LEN.size
            if name in names:
                raise ContainerFormatError(
                    f"duplicate stream name {name!r}", offset=off
                )
            names.append(name)
            lengths.append(length)
        header_end = off
        expected = header_end + sum(lengths)
        if len(blob) != expected:
            kind = "truncated" if len(blob) < expected else "trailing bytes in"
            raise ContainerFormatError(
                f"{kind} container: stream table declares {expected} bytes, "
                f"blob has {len(blob)}",
                offset=min(expected, len(blob)),
            )
        self.version = version
        self.header_bytes = header_end
        self._blob = blob
        self._offsets: dict[str, tuple[int, int]] = {}
        for name, length in zip(names, lengths):
            self._offsets[name] = (off, length)
            off += length
        self.names = names

    def __contains__(self, name: str) -> bool:
        return name in self._offsets

    def __getitem__(self, name: str) -> bytes:
        try:
            off, length = self._offsets[name]
        except KeyError:
            raise ContainerFormatError(
                f"missing stream {name!r}", stream=name
            ) from None
        return self._blob[off : off + length]

    def stream_extent(self, name: str) -> tuple[int, int]:
        """Blob-absolute ``[lo, hi)`` byte extent of one stream's payload
        (the fault-injection harness addresses corruption through this)."""
        try:
            off, length = self._offsets[name]
        except KeyError:
            raise ContainerFormatError(
                f"missing stream {name!r}", stream=name
            ) from None
        return off, off + length

    def get(self, name: str, default: bytes | None = None) -> bytes | None:
        return self[name] if name in self._offsets else default

    def stream_sizes(self) -> dict[str, int]:
        """Name -> payload length, from the stream table (measured, not estimated)."""
        return {name: length for name, (_, length) in self._offsets.items()}

    @property
    def total_bytes(self) -> int:
        return len(self._blob)
