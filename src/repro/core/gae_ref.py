"""Numpy oracle for the guaranteed-autoencoder post-process (Algorithm 1).

This is the seed implementation, retained verbatim as the correctness
contract for the device-resident engine in :mod:`repro.core.gae`: float64
throughout, per-species invocation, and per-block Python loops for artifact
assembly and decode replay. The engine must reproduce this oracle's byte
accounting bit-for-bit (same quantized coefficients, same index sets, same
trimmed basis); ``benchmarks/bench_guarantee.py`` asserts exactly that while
timing the two side by side.

See ``gae.py``'s module docstring for the shared mathematical derivation.
"""

from __future__ import annotations

import numpy as np

from repro.core import index_coding, pca
from repro.core.gae import GuaranteeArtifact, _effective_bin
from repro.core.quantization import dequantize, quantize


def guarantee(
    x: np.ndarray,
    x_rec: np.ndarray,
    tau: float,
    coeff_bin: float = 0.0,
) -> tuple[np.ndarray, GuaranteeArtifact]:
    """Correct ``x_rec`` so every block satisfies ||x - out||_2 <= tau.

    x, x_rec: (NB, D). Returns (corrected, artifact).
    """
    x = np.asarray(x, dtype=np.float64)
    x_rec = np.asarray(x_rec, dtype=np.float64)
    nb, d = x.shape
    residual = x - x_rec
    norms2 = np.sum(residual**2, axis=1)
    tau2 = float(tau) ** 2
    needs = norms2 > tau2

    if not needs.any():
        return x_rec.astype(np.float32), GuaranteeArtifact.empty(nb, d, float(tau))

    basis, _ = pca.pca_basis(residual)  # PCA over the *entire* residual set
    bin_size = _effective_bin(coeff_bin, float(tau), d)

    coeffs = pca.project(residual[needs], basis)  # (nf, d)
    cq_int = quantize(coeffs, bin_size)
    cq = cq_int.astype(np.float64) * bin_size
    gain = 2.0 * coeffs * cq - cq**2  # energy removed per kept coefficient

    order = np.argsort(-(coeffs**2), axis=1, kind="stable")
    sorted_gain = np.take_along_axis(gain, order, axis=1)
    cum = np.cumsum(sorted_gain, axis=1)
    target = norms2[needs][:, None] - tau2
    # smallest M with cum[M-1] >= target; quantization can make `cum`
    # non-monotone by epsilon, so use a running max before the search.
    cum_monotone = np.maximum.accumulate(cum, axis=1)
    m = 1 + np.argmax(cum_monotone >= target, axis=1)
    satisfied_at_m = np.take_along_axis(cum_monotone, (m - 1)[:, None], axis=1)[:, 0]
    # Guaranteed by bin clamp, but assert rather than assume:
    slack = 1e-9 * np.maximum(norms2[needs], 1.0)
    if not np.all(satisfied_at_m >= target[:, 0] - slack):
        raise AssertionError("guarantee violated — coefficient bin clamp failed")

    # Build per-block index sets + coefficient stream (ascending index order)
    keep_mask = np.zeros_like(coeffs, dtype=bool)
    cols = np.arange(d)[None, :]
    keep_sorted = cols < m[:, None]
    np.put_along_axis(keep_mask, order, keep_sorted, axis=1)

    corrected = x_rec.copy()
    corrected[needs] += (cq * keep_mask) @ basis.T

    fix_rows = np.nonzero(needs)[0]
    index_sets: list[np.ndarray] = [np.zeros(0, np.int64) for _ in range(nb)]
    coeff_chunks: list[np.ndarray] = []
    for local, row in enumerate(fix_rows):
        ids = np.nonzero(keep_mask[local])[0].astype(np.int64)
        index_sets[row] = ids
        coeff_chunks.append(cq_int[local, ids])
    coeff_stream = (
        np.concatenate(coeff_chunks) if coeff_chunks else np.zeros(0, np.int64)
    )
    offsets, index_flat = index_coding.sets_to_csr(index_sets)

    max_idx = max((int(ids.max()) for ids in index_sets if ids.size), default=-1)
    art = GuaranteeArtifact(
        basis=basis[:, : max_idx + 1].astype(np.float32),
        coeff_q=coeff_stream,
        index_offsets=offsets,
        index_flat=index_flat,
        coeff_bin=bin_size,
        tau=float(tau),
    )
    return corrected.astype(np.float32), art


def apply_correction(x_rec: np.ndarray, art: GuaranteeArtifact) -> np.ndarray:
    """Decode path: replay the stored correction, one block at a time."""
    out = np.asarray(x_rec, dtype=np.float64).copy()
    basis = art.basis.astype(np.float64)
    for row in range(len(art.index_offsets) - 1):
        lo, hi = art.index_offsets[row], art.index_offsets[row + 1]
        if hi == lo:
            continue
        ids = art.index_flat[lo:hi]
        c = dequantize(art.coeff_q[lo:hi], art.coeff_bin)
        out[row] += basis[:, ids] @ c.astype(np.float64)
    return out.astype(np.float32)
