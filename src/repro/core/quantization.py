"""Uniform mid-tread quantization (paper §II-A).

Values are binned with bin size ``d`` and represented by the bin center:
``q = round(x / d)``; ``x_hat = q * d``; worst-case error d/2 per scalar.
The integer streams feed the entropy coder.
"""

from __future__ import annotations

import numpy as np


def quantize(x: np.ndarray, bin_size: float) -> np.ndarray:
    if bin_size <= 0:
        raise ValueError("bin_size must be positive")
    return np.rint(x / bin_size).astype(np.int64)


def dequantize(q: np.ndarray, bin_size: float) -> np.ndarray:
    # float64 so the bin/2 bound is exact; callers cast on storage.
    return q.astype(np.float64) * bin_size


def quantize_roundtrip(x: np.ndarray, bin_size: float) -> tuple[np.ndarray, np.ndarray]:
    q = quantize(x, bin_size)
    return q, dequantize(q, bin_size)


def param_storage_dtype(param_dtype_bytes: int) -> np.dtype:
    """Numpy dtype for stored network parameters (fp16 or fp32)."""
    try:
        return {2: np.dtype("<f2"), 4: np.dtype("<f4")}[int(param_dtype_bytes)]
    except KeyError:
        raise ValueError(
            f"param_dtype_bytes must be 2 or 4, got {param_dtype_bytes}"
        ) from None


def quantize_params(tree, param_dtype_bytes: int):
    """Round every leaf of a parameter pytree through its storage dtype.

    Run at fit time when parameters are stored below fp32 so the encoder
    computes latents/corrections/guarantees with *exactly* the values the
    container will carry — otherwise the serialized decoder drifts from the
    one the guarantee was computed against and the error bound is fiction.
    fp32 storage is the identity. Compute dtype stays float32.
    """
    import jax

    dtype = param_storage_dtype(param_dtype_bytes)
    if dtype.itemsize == 4:
        return tree
    return jax.tree.map(
        lambda leaf: np.asarray(leaf).astype(dtype).astype(np.float32), tree
    )


def per_channel_scale(x: np.ndarray, axis: int, n_bits: int = 8) -> np.ndarray:
    """Symmetric per-channel scale for int quantization (KV/grad compression)."""
    amax = np.max(np.abs(x), axis=axis, keepdims=True)
    qmax = float(2 ** (n_bits - 1) - 1)
    return np.maximum(amax, 1e-30) / qmax
