"""Uniform mid-tread quantization (paper §II-A).

Values are binned with bin size ``d`` and represented by the bin center:
``q = round(x / d)``; ``x_hat = q * d``; worst-case error d/2 per scalar.
The integer streams feed the entropy coder.
"""

from __future__ import annotations

import numpy as np


def quantize(x: np.ndarray, bin_size: float) -> np.ndarray:
    if bin_size <= 0:
        raise ValueError("bin_size must be positive")
    return np.rint(x / bin_size).astype(np.int64)


def dequantize(q: np.ndarray, bin_size: float) -> np.ndarray:
    # float64 so the bin/2 bound is exact; callers cast on storage.
    return q.astype(np.float64) * bin_size


def quantize_roundtrip(x: np.ndarray, bin_size: float) -> tuple[np.ndarray, np.ndarray]:
    q = quantize(x, bin_size)
    return q, dequantize(q, bin_size)


def per_channel_scale(x: np.ndarray, axis: int, n_bits: int = 8) -> np.ndarray:
    """Symmetric per-channel scale for int quantization (KV/grad compression)."""
    amax = np.max(np.abs(x), axis=axis, keepdims=True)
    qmax = float(2 ** (n_bits - 1) - 1)
    return np.maximum(amax, 1e-30) / qmax
