"""Reconstruction quality metrics: NRMSE (paper eq. 3), PSNR, SSIM."""

from __future__ import annotations

import numpy as np


def nrmse(x: np.ndarray, x_rec: np.ndarray) -> float:
    """Range-normalized RMSE for a single species (paper eq. 3)."""
    x = np.asarray(x, dtype=np.float64)
    x_rec = np.asarray(x_rec, dtype=np.float64)
    rng = float(x.max() - x.min())
    if rng == 0.0:
        return 0.0 if np.allclose(x, x_rec) else float("inf")
    rmse = float(np.sqrt(np.mean((x - x_rec) ** 2)))
    return rmse / rng


def mean_nrmse(x: np.ndarray, x_rec: np.ndarray, species_axis: int = 0) -> float:
    """Paper's headline metric: average per-species NRMSE."""
    x = np.moveaxis(x, species_axis, 0)
    x_rec = np.moveaxis(x_rec, species_axis, 0)
    return float(np.mean([nrmse(a, b) for a, b in zip(x, x_rec)]))


def psnr(x: np.ndarray, x_rec: np.ndarray) -> float:
    """Range-referenced PSNR; the zero-range and zero-error cases are
    handled explicitly (like :func:`nrmse`) instead of leaking a
    ``log10(0)`` RuntimeWarning and a surprise ``-inf``/``nan``."""
    x = np.asarray(x, dtype=np.float64)
    x_rec = np.asarray(x_rec, dtype=np.float64)
    rng = float(x.max() - x.min())
    mse = float(np.mean((x - x_rec) ** 2))
    if mse == 0.0:
        return float("inf")
    if rng == 0.0:
        # constant-range reference with nonzero error: no finite dB value
        # is meaningful, and log10(rng) would warn-and-return -inf
        return float("-inf")
    return 20.0 * np.log10(rng) - 10.0 * np.log10(mse)


def _gaussian_kernel(size: int = 11, sigma: float = 1.5) -> np.ndarray:
    ax = np.arange(size) - (size - 1) / 2.0
    g = np.exp(-0.5 * (ax / sigma) ** 2)
    k = np.outer(g, g)
    return k / k.sum()


def _filter2d_valid(img: np.ndarray, kernel: np.ndarray) -> np.ndarray:
    """Valid-mode 2D correlation via stride tricks (no scipy available)."""
    kh, kw = kernel.shape
    h, w = img.shape
    windows = np.lib.stride_tricks.sliding_window_view(img, (kh, kw))
    return np.einsum("ijkl,kl->ij", windows, kernel, optimize=True)


def ssim2d(x: np.ndarray, y: np.ndarray) -> float:
    """SSIM between two 2D fields, 11x11 gaussian window, standard constants."""
    x = np.asarray(x, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    rng = float(max(x.max() - x.min(), 1e-30))
    c1, c2 = (0.01 * rng) ** 2, (0.03 * rng) ** 2
    k = _gaussian_kernel()
    mu_x = _filter2d_valid(x, k)
    mu_y = _filter2d_valid(y, k)
    xx = _filter2d_valid(x * x, k) - mu_x**2
    yy = _filter2d_valid(y * y, k) - mu_y**2
    xy = _filter2d_valid(x * y, k) - mu_x * mu_y
    num = (2 * mu_x * mu_y + c1) * (2 * xy + c2)
    den = (mu_x**2 + mu_y**2 + c1) * (xx + yy + c2)
    return float(np.mean(num / den))
