"""Training driver: end-to-end single-controller loop with checkpointing,
fault tolerance, straggler watchdog, and optional gradient compression.

On real TPU pods this runs under the production mesh; on the dev box it runs
the reduced (.smoke()) configs — same code path, smaller shapes:

  PYTHONPATH=src python -m repro.launch.train --arch llama3_2_1b \
      --steps 200 --smoke --compress-grads --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.data.tokens import TokenPipeline, TokenPipelineConfig
from repro.models.registry import build_model
from repro.parallel.gradient_compression import CompressionConfig
from repro.train import optimizer as opt
from repro.train.checkpoint import CheckpointManager
from repro.train.fault_tolerance import Watchdog, run_with_recovery
from repro.train.train_loop import (TrainConfig, init_train_state,
                                    make_train_step)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    tcfg = TrainConfig(
        optimizer=opt.AdamWConfig(lr=args.lr, total_steps=args.steps,
                                  warmup_steps=max(1, args.steps // 20)),
        compression=(CompressionConfig() if args.compress_grads else None),
    )
    step_fn = jax.jit(make_train_step(model, tcfg))
    state = init_train_state(model, params, tcfg)

    pipe = TokenPipeline(TokenPipelineConfig(
        vocab=cfg.vocab, batch=args.batch, seq_len=args.seq, seed=0))

    ckpt = CheckpointManager(args.ckpt_dir)
    watchdog = Watchdog()
    losses = []

    def one_step(step, s):
        params, state = s
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}
        params, state, metrics = step_fn(params, state, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0:
            print(f"step {step:5d} loss {losses[-1]:.4f} "
                  f"lr {float(metrics['lr']):.2e}")
        return params, state

    t0 = time.time()
    (params, state), report = run_with_recovery(
        step_fn=one_step,
        init_state=(params, state),
        n_steps=args.steps,
        ckpt=ckpt,
        save_every=args.save_every,
        watchdog=watchdog,
        state_to_tree=lambda s: {"params": s[0], "opt": s[1]["opt"]},
        tree_to_state=lambda tmpl, t: (t["params"],
                                       {**tmpl[1], "opt": t["opt"]}),
    )
    dt = time.time() - t0
    print(f"done: {args.steps} steps in {dt:.1f}s "
          f"({args.steps / dt:.2f} steps/s, median {watchdog.median:.3f}s)")
    print(f"loss {losses[0]:.4f} -> {losses[-1]:.4f}; report={report}")
    return losses


if __name__ == "__main__":
    main()
