"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (required so smoke tests see 1 device while the dry-run sees
512 placeholder devices via XLA_FLAGS set before any jax import).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod single-pod; (2, 16, 16) = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh for tests/examples (e.g. (2, 4) on 8 CPU devices)."""
    return jax.make_mesh(shape, axes)
