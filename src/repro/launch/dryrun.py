import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell:
  * build the model from its full config (ShapeDtypeStruct params/inputs —
    zero device allocation);
  * jit the production step (train_step incl. optimizer | prefill |
    serve_step) with explicit in/out shardings from repro.parallel.sharding;
  * ``.lower(...).compile()`` against the 16x16 (single-pod) and 2x16x16
    (multi-pod) meshes;
  * record memory_analysis(), cost_analysis(), and the collective-op bytes
    parsed from the post-SPMD optimized HLO into results/dryrun/<cell>.json
    (consumed by benchmarks/roofline.py and EXPERIMENTS.md).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3_2_1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]
"""

import argparse
import json
import re
import time
import traceback

import jax
import numpy as np

from repro.configs.base import SHAPES, get_config, list_configs
from repro.launch.mesh import make_production_mesh
from repro.models.registry import build_model, input_specs
from repro.parallel import sharding as sh
from repro.train import optimizer as opt
from repro.train.train_loop import TrainConfig, make_train_step
from jax.sharding import NamedSharding, PartitionSpec as P

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def parse_collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum output-shape bytes of every collective op in the optimized HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    # matches: `= bf16[1,2,3]{...} all-gather(` and tuple forms
    shape_re = re.compile(r"(\w+)\[([\d,]*)\]")
    for line in hlo_text.splitlines():
        m = re.search(r"=\s+(.*?)\s+(" + "|".join(_COLLECTIVES) + r")[\.\(]",
                      line)
        if not m:
            continue
        shapes_str, op = m.group(1), m.group(2)
        nbytes = 0
        for dt, dims in shape_re.findall(shapes_str):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[op] += nbytes
        out["count"] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def depth_variants(cfg):
    """Reduced-depth override dicts for the affine cost fit.

    XLA's HloCostAnalysis counts a while-loop (lax.scan) body ONCE, so the
    scanned production artifact under-reports flops/bytes/collectives by the
    trip count. Costs are affine in stack depth, so we compile tiny unrolled
    variants (same widths, same remat, depth 1 and 2) and extrapolate:
        total(L) = f(1) + (L - 1) * (f(2) - f(1)).
    Whisper has two stacks (enc, dec) -> 3 points; recurrentgemma's depth
    unit is the (rec, rec, attn) period.
    """
    fam = cfg.family
    if fam == "audio":
        return (
            [dict(n_layers=1, n_encoder_layers=1, scan_layers=False),
             dict(n_layers=2, n_encoder_layers=1, scan_layers=False),
             dict(n_layers=1, n_encoder_layers=2, scan_layers=False)],
            ("dec", "enc"),
            (cfg.n_layers, cfg.n_encoder_layers),
        )
    if fam == "hybrid":
        tail = cfg.n_layers - 3 * (cfg.n_layers // 3)
        return (
            [dict(n_layers=3 + tail, scan_layers=False),
             dict(n_layers=6 + tail, scan_layers=False)],
            ("period",),
            (cfg.n_layers // 3,),
        )
    return (
        [dict(n_layers=1, scan_layers=False),
         dict(n_layers=2, scan_layers=False)],
        ("layer",),
        (cfg.n_layers,),
    )


def extrapolate(points: list[dict], depths: tuple[int, ...]) -> dict:
    """Affine extrapolation of every numeric metric to full depth.

    Slopes are clamped at 0: cost is non-decreasing in depth, and at tiny
    decode shapes compiler fusion noise can make f(2) < f(1) by epsilon."""
    keys = [k for k, v in points[0].items() if isinstance(v, (int, float))]
    out = {}
    for k in keys:
        base = points[0][k]
        total = base
        for i, full in enumerate(depths):
            slope = max(0.0, points[i + 1][k] - base)
            total += (full - 1) * slope
        out[k] = total
    return out


def train_state_specs(params_specs):
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jax.numpy.float32)
    return {
        "opt": {
            "m": jax.tree.map(f32, params_specs),
            "v": jax.tree.map(f32, params_specs),
            "step": jax.ShapeDtypeStruct((), jax.numpy.int32),
        }
    }


def _named(mesh, pspec_tree):
    return jax.tree.map(
        lambda ps: NamedSharding(mesh, ps), pspec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _lower_compile(cfg, shape, mesh, grad_accum: int = 1):
    """Lower + compile one step for one config; returns metrics dict."""
    model = build_model(cfg)
    params_specs = model.specs()
    p_shard = _named(mesh, sh.param_pspecs(model, cfg, mesh))
    batch_specs = input_specs(cfg, shape)
    b_shard = {k: NamedSharding(mesh, v)
               for k, v in sh.batch_pspecs(cfg, shape, mesh).items()}

    t0 = time.time()
    if shape.kind == "train":
        tcfg = TrainConfig(optimizer=opt.AdamWConfig(lr=1e-4),
                           grad_accum=grad_accum)
        step = make_train_step(model, tcfg)
        state_specs = train_state_specs(params_specs)
        state_shard = {"opt": _named(mesh, sh.optimizer_pspecs(model, cfg, mesh))}
        with mesh:
            lowered = jax.jit(
                step,
                in_shardings=(p_shard, state_shard, b_shard),
                out_shardings=(p_shard, state_shard, None),
            ).lower(params_specs, state_specs, batch_specs)
    elif shape.kind == "prefill":
        def prefill(params, batch):
            return model.prefill(params, batch, max_len=shape.seq_len)

        with mesh:
            lowered = jax.jit(
                prefill, in_shardings=(p_shard, b_shard), out_shardings=None,
            ).lower(params_specs, batch_specs)
    else:  # decode
        cache_specs = model.cache_specs(shape.global_batch, shape.seq_len)
        cache_shard = _named(mesh, sh.cache_pspecs(model, cfg, mesh, shape.global_batch))

        def serve_step(params, cache, tokens):
            return model.decode_step(params, cache, tokens)

        with mesh:
            lowered = jax.jit(
                serve_step,
                in_shardings=(p_shard, cache_shard,
                              NamedSharding(
                                  mesh,
                                  P(sh.dp_axes_for(mesh, shape.global_batch),
                                    None))),
                out_shardings=(None, cache_shard),
            ).lower(params_specs, cache_specs, batch_specs["tokens"])
    lower_s = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    mem_info = {}
    if mem is not None:
        for field in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
            v = getattr(mem, field, None)
            if v is not None:
                mem_info[field] = int(v)
    cost = compiled.cost_analysis() or {}
    cost_info = {k: float(v) for k, v in cost.items()
                 if isinstance(v, (int, float)) and not k.startswith("utilization")}
    coll = parse_collective_bytes(compiled.as_text())

    metrics = {
        "flops": cost_info.get("flops", 0.0),
        "bytes_accessed": cost_info.get("bytes accessed", 0.0),
        **{f"coll_{k}": float(v) for k, v in coll.items()},
    }
    return {
        "metrics": metrics,
        "memory": mem_info,
        "cost": cost_info,
        "lower_s": round(lower_s, 2),
        "compile_s": round(compile_s, 2),
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = "results/dryrun", overrides: dict | None = None,
             tag: str = "", grad_accum: int = 1) -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"

    # 1. the production (scanned) artifact: memory analysis + compile proof
    prod = _lower_compile(cfg, shape, mesh, grad_accum=grad_accum)

    # 2. affine depth fit for scan-corrected flops/bytes/collectives
    variants, depth_names, full_depths = depth_variants(cfg)
    points = [_lower_compile(cfg.replace(**ov), shape, mesh,
                             grad_accum=grad_accum)["metrics"]
              for ov in variants]
    corrected = extrapolate(points, full_depths)

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "kind": shape.kind,
        "ok": True,
        "lower_s": prod["lower_s"],
        "compile_s": prod["compile_s"],
        # scan-corrected totals (per device)
        "flops": corrected["flops"],
        "bytes_accessed": corrected["bytes_accessed"],
        "collectives": {k[5:]: v for k, v in corrected.items()
                        if k.startswith("coll_")},
        # raw production-artifact numbers (scan body counted once by XLA)
        "raw_scanned": prod["metrics"],
        "memory": prod["memory"],
        "cost_scanned": prod["cost"],
        "depth_fit": {"names": depth_names, "full": full_depths,
                      "points": points},
        "n_devices": int(np.prod(list(mesh.shape.values()))),
        "tag": tag,
    }
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"_{tag}" if tag else ""
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}{suffix}.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    print(f"[dryrun] OK {arch} {shape_name} {mesh_name} "
          f"flops={result['flops']:.3e} "
          f"coll={result['collectives']['total']:.3e}B "
          f"compile={prod['compile_s']:.0f}s")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", type=str, default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = list_configs() if (args.all or args.arch is None) else [args.arch]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        cfg = get_config(arch)
        shapes = [args.shape] if args.shape else cfg.shapes
        for shape_name in shapes:
            if shape_name not in cfg.shapes:
                print(f"[dryrun] SKIP {arch} {shape_name} (not applicable)")
                continue
            for mp in meshes:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                path = os.path.join(
                    args.out, f"{arch}__{shape_name}__{mesh_name}.json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[dryrun] cached {path}")
                    continue
                try:
                    run_cell(arch, shape_name, mp, args.out)
                except Exception as e:  # noqa: BLE001  # repro: allow[typed-errors] — record and continue
                    failures.append((arch, shape_name, mesh_name, repr(e)))
                    traceback.print_exc()
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES:")
        for f in failures:
            print("   ", f)
        raise SystemExit(1)
    print("[dryrun] all cells green")


if __name__ == "__main__":
    main()
