"""Serving driver: batched prefill + greedy decode (smoke-scale on CPU).

  PYTHONPATH=src python -m repro.launch.serve --arch llama3_2_1b \
      --batch 4 --prompt-len 32 --new-tokens 16
"""

from __future__ import annotations

import argparse
import time

from repro.configs.base import get_config
from repro.models.registry import build_model, make_batch
from repro.serve.serve_loop import Server

import jax


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    server = Server(model, params,
                    max_len=args.prompt_len + args.new_tokens + 8)

    batch = make_batch(cfg, batch=args.batch, seq=args.prompt_len,
                       kind="prefill")
    t0 = time.time()
    out = server.generate(batch, args.new_tokens)
    dt = time.time() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({server.stats.decode_tokens / dt:.1f} tok/s)")
    print("sample:", out[0].tolist())
    return out


if __name__ == "__main__":
    main()
