"""Root pytest conftest: opt-in forced host-device meshes.

Setting ``REPRO_HOST_DEVICES=N`` (N > 1) makes the whole tier-1 suite —
and the mesh-sharded fit/compress paths it exercises — run on an N-way
forced host-platform device mesh, the CPU stand-in for a real
accelerator pod. The flag must land in ``XLA_FLAGS`` before *any*
``import jax`` anywhere in the process, which is why this lives in the
repo-root conftest (imported by pytest before test collection) rather
than in a fixture. ``python -m repro.analysis`` honors the same variable
via the identical hook in ``repro/analysis/__main__.py``.
"""

import os


def _force_host_devices() -> None:
    n = os.environ.get("REPRO_HOST_DEVICES", "")
    if not n.isdigit() or int(n) <= 1:
        return
    flag = f"--xla_force_host_platform_device_count={int(n)}"
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (flags + " " + flag).strip()


_force_host_devices()
