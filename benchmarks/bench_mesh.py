"""Mesh-sharded fit/compress scaling: DP fit steps/s at 1/2/4/8 devices,
sharded-vs-default compress wall-clock, and gradient-exchange wire bytes.

All mesh work runs in ONE child subprocess with a forced 8-device host
platform (the device count is locked at first jax init, so the parent
process — which may already hold a 1-device runtime — cannot host it).
The child asserts the bit-identity gates FIRST (P=1 DP fit bitwise the
scan fit; sharded-engine container byte-identical to the default engine;
parts-mode latent packing byte-identical to full-array packing) and only
then measures — a broken invariant can never hide behind a throughput
number.

On this CI host the 8 "devices" are XLA host-platform slices of the same
CPUs, so DP steps/s saturates at the physical core count; the JSON
records the full per-device-count curve plus ``cpu_cores`` so the curve
reads as a saturation measurement, not a regression.

Writes BENCH_mesh.json (repo root) + results/bench/mesh.csv.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

_HERE = os.path.dirname(os.path.abspath(__file__))
_REPO = os.path.dirname(_HERE)
OUT_JSON = os.path.join(_REPO, "BENCH_mesh.json")
OUT_CSV = os.path.join(_REPO, "results", "bench", "mesh.csv")
_SENTINEL = "BENCH_MESH_JSON "

DEVICE_COUNTS = (1, 2, 4, 8)


# ---------------------------------------------------------------------------
# child: runs under the forced 8-device mesh
# ---------------------------------------------------------------------------
def _child(quick: bool) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.core import gae
    from repro.codec import format as fmt
    from repro.core.pipeline import GBATCPipeline, PipelineConfig
    from repro.data import s3d
    from repro.parallel import mesh_fit
    from repro.train import train_loop

    assert len(jax.devices()) == 8, "child must run on 8 forced devices"
    summary: dict = {
        "quick": quick,
        "cpu_cores": os.cpu_count(),
        "n_devices_forced": 8,
        "gates": {},
    }

    # ---- trainer problem (linear AE, large enough to give the loss and
    # grad work per step some substance) --------------------------------
    rows_n, dim, lat = (2048, 96, 12) if quick else (8192, 128, 16)
    steps, batch = (30, 256) if quick else (60, 512)
    rng = np.random.default_rng(0)
    x = rng.standard_normal((rows_n, dim)).astype(np.float32)
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    params = {"w_enc": jax.random.normal(k1, (dim, lat)) * 0.1,
              "w_dec": jax.random.normal(k2, (lat, dim)) * 0.1}

    def loss_fn(p, b):
        rec = b @ p["w_enc"] @ p["w_dec"]
        return jnp.mean(jnp.square(rec - b))

    tr = train_loop.MiniBatchTrainer(
        loss_fn, train_loop.adamw_cfg(1e-3, steps), mode="scan")
    kw = dict(steps=steps, batch_size=batch, seed=0)

    # ---- gate 1: P=1 DP fit bitwise the plain scan fit ----------------
    p_ref, l_ref = tr.fit(params, (x,), **kw)
    p_1, l_1 = tr.fit(params, (x,), mesh=mesh_fit.host_mesh(1), **kw)
    bitwise = bool(np.array_equal(l_ref, l_1)) and all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_1))
    )
    summary["gates"]["p1_fit_bitwise"] = bitwise
    assert bitwise, "P=1 mesh fit drifted from the scan fit"

    # ---- DP fit steps/s per device count ------------------------------
    curve = []
    for n_dev in DEVICE_COUNTS:
        mesh = mesh_fit.host_mesh(n_dev)
        tr.fit(params, (x,), mesh=mesh, **kw)  # compile + warm
        t0 = time.perf_counter()
        tr.fit(params, (x,), mesh=mesh, **kw)
        dt = time.perf_counter() - t0
        curve.append({"n_devices": n_dev, "fit_s": dt,
                      "steps_per_s": steps / dt})
    base = curve[0]["steps_per_s"]
    for c in curve:
        c["speedup_vs_1dev"] = c["steps_per_s"] / base
    best = max(curve, key=lambda c: c["steps_per_s"])
    summary["dp_fit"] = {
        "steps": steps, "rows": rows_n, "batch": batch,
        "per_device_count": curve,
        "best_n_devices": best["n_devices"],
        "saturation_note": (
            f"forced host devices share {os.cpu_count()} physical core(s); "
            f"steps/s saturates at n_devices={best['n_devices']} "
            f"({best['speedup_vs_1dev']:.2f}x vs 1 device) — on real "
            f"multi-chip meshes the per-device batch shrinks P-fold instead"
        ),
    }

    # ---- gate 2 + compress wall-clock: sharded engine ------------------
    data = s3d.generate(s3d.S3DConfig(
        n_species=8 if not quick else 4, n_time=8, height=20, width=16,
        seed=5))["species"]
    cfg = PipelineConfig(ae_steps=40, corr_steps=20, conv_channels=(8, 16))
    pipe = GBATCPipeline(cfg, n_species=data.shape[0])
    pipe.fit(data)

    def compress_cold():
        # clear the tau-independent prepared cache so each timing pays the
        # full prepare+select path on its engine
        pipe._prepared.clear()
        pipe._last_prepared = None
        return pipe.compress(target_nrmse=1e-3).artifact.to_bytes()

    ref_bytes = compress_cold()
    t0 = time.perf_counter()
    compress_cold()
    t_default = time.perf_counter() - t0

    pipe.set_guarantee_engine(
        mesh_fit.ShardedGuaranteeEngine(mesh=mesh_fit.host_mesh()))
    got_bytes = compress_cold()
    identical = got_bytes == ref_bytes
    summary["gates"]["sharded_compress_byte_identical"] = identical
    assert identical, "sharded compress container drifted"
    t0 = time.perf_counter()
    compress_cold()
    t_sharded = time.perf_counter() - t0
    pipe.set_guarantee_engine(gae.default_engine())
    summary["compress"] = {
        "default_engine_s": t_default,
        "sharded_engine_s": t_sharded,
        "byte_identical": identical,
    }

    # ---- wire accounting: quantized vs fp32 exchange -------------------
    wire = {}
    for n_dev in (2, 8):
        rep = mesh_fit.dp_wire_report(p_ref, n_dev)
        wire[f"p{n_dev}"] = rep
    summary["wire"] = wire

    # ---- gate 3 + parts-mode latent packing ----------------------------
    lat_q = rng.integers(-40, 40, size=(960, 36)).astype(np.int32)
    shard_rows = 32
    full = fmt.pack_latent_stream(lat_q, shard_rows, parallel=False)
    bounds = [0, 250, 480, 730, 960]  # misaligned with the 32-row shards
    parts = [lat_q[a:b] for a, b in zip(bounds, bounds[1:])]
    streamed = fmt.pack_latent_stream(parts, shard_rows, parallel=False)
    parity = streamed == full
    summary["gates"]["pack_parts_bitwise"] = parity
    assert parity, "parts-mode latent packing drifted"
    t0 = time.perf_counter()
    fmt.pack_latent_stream(lat_q, shard_rows, parallel=False)
    t_full = time.perf_counter() - t0
    t0 = time.perf_counter()
    fmt.pack_latent_stream(parts, shard_rows, parallel=False)
    t_parts = time.perf_counter() - t0
    summary["pack_parts"] = {"full_ms": t_full * 1e3,
                             "parts_ms": t_parts * 1e3,
                             "rows": int(lat_q.shape[0])}
    return summary


# ---------------------------------------------------------------------------
# parent: spawn the forced-mesh child, persist the summary
# ---------------------------------------------------------------------------
def run(quick: bool = True) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_REPO, "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    cmd = [sys.executable, "-m", "benchmarks.bench_mesh", "--child"]
    if not quick:
        cmd.append("--full")
    out = subprocess.run(cmd, capture_output=True, text=True, env=env,
                         cwd=_REPO, timeout=1800)
    payload = None
    for line in out.stdout.splitlines():
        if line.startswith(_SENTINEL):
            payload = json.loads(line[len(_SENTINEL):])
    assert out.returncode == 0 and payload is not None, (
        f"mesh benchmark child failed:\n{out.stdout}\n{out.stderr}"
    )
    assert all(payload["gates"].values()), f"gates failed: {payload['gates']}"

    with open(OUT_JSON, "w", encoding="utf-8") as f:
        json.dump(payload, f, indent=2)
    os.makedirs(os.path.dirname(OUT_CSV), exist_ok=True)
    with open(OUT_CSV, "w", encoding="utf-8") as f:
        f.write("n_devices,fit_s,steps_per_s,speedup_vs_1dev\n")
        for c in payload["dp_fit"]["per_device_count"]:
            f.write(f"{c['n_devices']},{c['fit_s']:.4f},"
                    f"{c['steps_per_s']:.2f},{c['speedup_vs_1dev']:.3f}\n")
    return payload


def main() -> None:
    if "--child" in sys.argv:
        summary = _child(quick="--full" not in sys.argv)
        print(_SENTINEL + json.dumps(summary))
        return
    summary = run(quick="--full" not in sys.argv)
    best = max(summary["dp_fit"]["per_device_count"],
               key=lambda c: c["steps_per_s"])
    print(f"bench_mesh: gates {summary['gates']}; best DP fit "
          f"{best['steps_per_s']:.1f} steps/s at {best['n_devices']} "
          f"device(s) ({best['speedup_vs_1dev']:.2f}x vs 1); sharded "
          f"compress {summary['compress']['sharded_engine_s']:.2f}s vs "
          f"default {summary['compress']['default_engine_s']:.2f}s")


if __name__ == "__main__":
    sys.path.insert(0, os.path.join(_REPO, "src"))
    main()
