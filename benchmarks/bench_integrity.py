"""Integrity container (v4) benchmark: what the digests cost and what
salvage delivers.

Container v4 digest-checks every byte a decode reads (CRC32 per stream +
per random-access unit). This benchmark measures the price of that
guarantee and the throughput of the degraded-but-honest salvage path:

* **encode overhead** — v4 vs v3 serialize time and container size (the
  digests are the only delta);
* **decode overhead** — cold and warm full-decode wall clock, v4 vs v3,
  plus the standalone whole-blob verification cost (``verify_blob``);
* **salvage throughput** — ``decompress(..., on_error="salvage")`` wall
  clock with k corrupt species, k in {1, 2, 4}, against the clean decode;
* **fault-sweep summary** — seeded single-bit flips across every
  addressable region; v4 must detect 100%.

Before any number is reported, the gates are asserted:

* a clean v4 decode — full and windowed — is **byte-identical** to the
  v3 decode of the same fit;
* whole-blob verification costs **< 3%** of a warm full decode;
* salvage on a k-corrupt blob quarantines exactly the corrupt species
  and returns every other species bitwise equal to the clean decode;
* the fault sweep finds zero undetected flips.

Writes BENCH_integrity.json (repo root) + results/bench/integrity.csv.

  PYTHONPATH=src python -m benchmarks.bench_integrity
"""

from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import codec  # noqa: E402
from repro.core.container import ContainerFormatError  # noqa: E402
from repro.core.pipeline import PipelineConfig  # noqa: E402
from repro.data import s3d  # noqa: E402
from repro.testing.faults import FaultInjector, blob_regions  # noqa: E402

TARGET = 3e-4
VERIFY_BUDGET = 0.03  # whole-blob verify must cost < 3% of a warm decode
SWEEP_FLIPS_PER_REGION = 20
OUT_JSON = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_integrity.json")
OUT_CSV = "results/bench/integrity.csv"


def _time(fn, repeat=5):
    """Best-of-N wall time: robust to CPU contention in shared runners."""
    fn()  # warmup (jit compile / caches)
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(quick: bool = True, seed: int = 5):
    scfg = (
        s3d.S3DConfig(n_species=12, n_time=16, height=80, width=80,
                      seed=seed)
        if quick
        else s3d.S3DConfig(n_species=16, n_time=24, height=120, width=120,
                           seed=seed)
    )
    data = s3d.generate(scfg)["species"]
    gbatc = codec.GBATCCodec(
        PipelineConfig(
            conv_channels=(16, 32),
            ae_steps=150 if quick else 800,
            corr_steps=80 if quick else 400,
        )
    )
    gbatc.fit(data)
    blob_v4, rep = gbatc.compress_report(target_nrmse=TARGET)
    art = rep.artifact
    blob_v3 = codec.encode(art, version=3)
    s, t = data.shape[:2]
    window = (t // 4, t // 4 + 4)

    # -- gate: clean v4 decode == v3 decode, full and windowed -----------
    full = codec.decompress(blob_v4)
    assert codec.decompress(blob_v3).tobytes() == full.tobytes(), \
        "v4 full decode != v3 decode byte-for-byte"
    win4 = codec.decompress(blob_v4, species=[1, 3], time_range=window)
    win3 = codec.decompress(blob_v3, species=[1, 3], time_range=window)
    assert win4.tobytes() == win3.tobytes(), \
        "v4 window decode != v3 window decode byte-for-byte"
    assert win4.tobytes() == np.ascontiguousarray(
        full[[1, 3], window[0]:window[1]]
    ).tobytes(), "v4 window decode != full slice"

    # -- encode overhead: the digests are the only serialize delta -------
    enc_v3_s = _time(lambda: codec.encode(art, version=3))
    enc_v4_s = _time(lambda: codec.encode(art, version=4))
    size_overhead = len(blob_v4) - len(blob_v3)

    # -- decode overhead + the verification budget gate ------------------
    def cold(b):
        codec.clear_decode_cache()
        codec.decompress(b)

    cold_v3_s = _time(lambda: cold(blob_v3), repeat=3)
    cold_v4_s = _time(lambda: cold(blob_v4), repeat=3)
    warm_v3_s = _time(lambda: codec.decompress(blob_v3))
    warm_v4_s = _time(lambda: codec.decompress(blob_v4))
    verify_s = _time(lambda: codec.verify_blob(blob_v4))
    assert verify_s < VERIFY_BUDGET * warm_v4_s, (
        f"whole-blob verification ({verify_s * 1e3:.2f}ms) exceeds "
        f"{VERIFY_BUDGET:.0%} of a warm full decode "
        f"({warm_v4_s * 1e3:.1f}ms)"
    )

    # -- salvage throughput with k corrupt species -----------------------
    regions = blob_regions(blob_v4)
    by_label = {r.label: r for r in regions}
    inj = FaultInjector(seed=seed)
    salvage_rows = []
    for k in (1, 2, 4):
        bad = blob_v4
        corrupt = list(range(k))
        for i in corrupt:
            bad, _ = inj.flip_bit(bad, by_label[f"guarantee:s{i}:coeff"])
        field, report = codec.decompress(bad, on_error="salvage")
        # gate: exactly the corrupt species quarantined, siblings bitwise
        assert report.quarantined == corrupt, \
            f"salvage quarantined {report.quarantined}, corrupted {corrupt}"
        for i in range(s):
            if i in corrupt:
                assert np.isnan(field[i]).all()
            else:
                assert field[i].tobytes() == full[i].tobytes(), \
                    f"salvaged species {i} != clean decode bitwise"
        salvage_s = _time(
            lambda b=bad: codec.decompress(b, on_error="salvage"), repeat=3
        )
        salvage_rows.append({
            "corrupt_species": k,
            "salvage_ms": salvage_s * 1e3,
            "salvage_MBps": field.nbytes / salvage_s / 1e6,
            "slowdown_vs_warm_decode": salvage_s / warm_v4_s,
        })

    # -- fault sweep: zero undetected single-bit flips on v4 -------------
    detected = total = 0
    for reg in regions:
        for _ in range(SWEEP_FLIPS_PER_REGION):
            flipped, _ = inj.flip_bit(blob_v4, reg)
            total += 1
            try:
                codec.verify_blob(flipped)
            except ContainerFormatError:
                detected += 1
    assert detected == total, \
        f"fault sweep: {total - detected}/{total} flips went undetected"

    summary = {
        "problem": {
            "shape": list(data.shape),
            "raw_bytes": int(data.nbytes),
            "target_nrmse": TARGET,
            "seed": seed,
            "quick": quick,
        },
        "blob_bytes_v3": len(blob_v3),
        "blob_bytes_v4": len(blob_v4),
        "digest_overhead_bytes": size_overhead,
        "digest_overhead_fraction": size_overhead / len(blob_v3),
        "encode_v3_ms": enc_v3_s * 1e3,
        "encode_v4_ms": enc_v4_s * 1e3,
        "encode_overhead_fraction": enc_v4_s / enc_v3_s - 1.0,
        "decode_cold_v3_ms": cold_v3_s * 1e3,
        "decode_cold_v4_ms": cold_v4_s * 1e3,
        "decode_warm_v3_ms": warm_v3_s * 1e3,
        "decode_warm_v4_ms": warm_v4_s * 1e3,
        "verify_blob_ms": verify_s * 1e3,
        "verify_fraction_of_warm_decode": verify_s / warm_v4_s,
        "verify_budget": VERIFY_BUDGET,
        "salvage": salvage_rows,
        "fault_sweep": {
            "flips": total,
            "detected": detected,
            "detection_rate": detected / total,
        },
        "gates_passed": True,
        "v4_equals_v3_byte_for_byte": True,
    }

    with open(OUT_JSON, "w") as f:
        json.dump(summary, f, indent=2)
    os.makedirs(os.path.dirname(OUT_CSV), exist_ok=True)
    with open(OUT_CSV, "w") as f:
        f.write("corrupt_species,salvage_ms,salvage_MBps,"
                "slowdown_vs_warm_decode\n")
        for row in salvage_rows:
            f.write(",".join(str(row[k]) for k in (
                "corrupt_species", "salvage_ms", "salvage_MBps",
                "slowdown_vs_warm_decode")) + "\n")
    print(
        f"[bench_integrity] digests add {size_overhead} bytes "
        f"({summary['digest_overhead_fraction']:.2%}) | verify "
        f"{verify_s * 1e3:.2f}ms = "
        f"{summary['verify_fraction_of_warm_decode']:.1%} of warm decode "
        f"({warm_v4_s * 1e3:.0f}ms) | salvage k=1 "
        f"{salvage_rows[0]['salvage_ms']:.0f}ms | sweep {detected}/{total} "
        f"detected -> {OUT_JSON}"
    )
    return summary


if __name__ == "__main__":
    run(quick="--full" not in sys.argv)
