"""Encoder-family benchmark: conv vs attention vs SZ, same container.

The family registry's pitch is that a second encoder architecture rides
the *same* guarantee engine, wire format, and selective-decode machinery
— so the comparison that matters is CR-vs-bound per family against the
SZ baseline, with fit and decode wall-clock alongside:

* **CR at 3 NRMSE bounds** per registered family (conv AE, block
  attention), each through the full GBATC pipeline (latent quantization,
  entropy coding, guarantee post-process), plus SZ at the same bounds
  (per-species bisection on the abs error bound);
* **fit wall-clock** per family (one fit, reused across bounds);
* **decode wall-clock** per family, cold (cache cleared) and warm.

Before any number is reported, the refactor gates are asserted:

* **v1–v4 back-compat** — every legacy container version of the conv fit
  decodes bitwise identical to the v5 decode;
* **conv-v5 ≡ v4** — the conv-family v5 blob is the v4 blob of the same
  fit plus exactly the one-byte family tag (every payload stream but
  ``meta``/``integrity`` byte-identical, the meta body byte-identical
  behind the tag), and their decodes are bitwise equal;
* every GBATC point satisfies its per-species NRMSE bound.

Writes BENCH_families.json (repo root) + results/bench/families.csv.

  PYTHONPATH=src python -m benchmarks.bench_families
"""

from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import codec  # noqa: E402
from repro.core import metrics, sz  # noqa: E402
from repro.core.container import ContainerReader  # noqa: E402
from repro.core.pipeline import PipelineConfig  # noqa: E402
from repro.data import s3d  # noqa: E402

BOUNDS = (1e-2, 5e-3, 1e-3)
OUT_JSON = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_families.json")
OUT_CSV = "results/bench/families.csv"


def _time(fn, repeat=3):
    fn()  # warmup
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _assert_gates(rep, data):
    """The refactor's correctness gates, on the fitted conv artifact."""
    blob5 = codec.encode(rep.artifact)  # v5 default
    blob4 = codec.encode(rep.artifact, version=4)
    r5, r4 = ContainerReader(blob5), ContainerReader(blob4)
    assert (r5.version, r4.version) == (5, 4)
    # conv-v5 == v4 + the one-byte family tag
    assert r5["meta"][:1] == b"\x01" and r5["meta"][1:] == r4["meta"], \
        "conv v5 meta is not the tagged v4 meta body"
    for name in r4.names:
        if name in ("meta", "integrity"):
            continue
        assert r5[name] == r4[name], f"stream {name} drifted v4 -> v5"
    ref = codec.decompress(blob5)
    assert codec.decompress(blob4).tobytes() == ref.tobytes(), \
        "conv v5 decode != v4 decode"
    # v1-v4 back-compat: every legacy version decodes bitwise identical
    for version in (1, 2, 3):
        b = codec.encode(rep.artifact, version=version)
        assert codec.decompress(b).tobytes() == ref.tobytes(), \
            f"v{version} decode drifted from v5"
    return blob5


def _sz_point(data, target_nrmse, iters=7):
    """Per-species bisection on the abs bound to hit the NRMSE target."""
    s = data.shape[0]
    rng = data.max(axis=(1, 2, 3)) - data.min(axis=(1, 2, 3))
    lo = np.full(s, 1e-12) * rng
    hi = 2.0 * target_nrmse * rng
    for _ in range(iters):
        mid = np.sqrt(lo * hi)
        recon, _ = sz.compress_species(data, mid)
        per = np.array([metrics.nrmse(data[i], recon[i]) for i in range(s)])
        lo = np.where(per <= target_nrmse, mid, lo)
        hi = np.where(per > target_nrmse, mid, hi)
    return sz.compress_species(data, lo)


def run(quick: bool = True, seed: int = 11):
    scfg = (
        s3d.S3DConfig(n_species=8, n_time=16, height=80, width=80,
                      seed=seed)
        if quick
        else s3d.S3DConfig(n_species=12, n_time=24, height=120, width=120,
                           seed=seed)
    )
    data = s3d.generate(scfg)["species"]

    families_cfg = {
        "conv": PipelineConfig(
            conv_channels=(16, 32),
            ae_steps=150 if quick else 800,
            corr_steps=80 if quick else 400,
            seed=0,
        ),
        "attention": PipelineConfig(
            family="attention",
            arch=(32, 2, 1, 64),
            ae_steps=300 if quick else 1200,
            corr_steps=80 if quick else 400,
            seed=0,
        ),
    }

    rows = []
    fits = {}
    gates_blob = None
    for fam, cfg in families_cfg.items():
        gbatc = codec.GBATCCodec(cfg)
        t0 = time.perf_counter()
        gbatc.fit(data)
        fit_s = time.perf_counter() - t0
        fits[fam] = {"fit_s": fit_s}
        for bound in BOUNDS:
            blob, rep = gbatc.compress_report(target_nrmse=bound)
            per = rep.per_species_nrmse
            assert per.max() <= bound * (1 + 1e-3), \
                f"{fam} at bound {bound:g}: max NRMSE {per.max():.3e}"
            if fam == "conv" and bound == BOUNDS[0]:
                gates_blob = _assert_gates(rep, data)
            codec.clear_decode_cache()
            cold_s = _time(lambda b=blob: codec.decompress(b), repeat=1)
            warm_s = _time(lambda b=blob: codec.decompress(b))
            rows.append({
                "method": fam,
                "target_nrmse": bound,
                "achieved_nrmse": float(per.mean()),
                "max_species_nrmse": float(per.max()),
                "compression_ratio": data.nbytes / len(blob),
                "blob_bytes": len(blob),
                "fit_s": fit_s,
                "decode_cold_ms": cold_s * 1e3,
                "decode_warm_ms": warm_s * 1e3,
            })
            print(f"[bench_families] {fam} bound={bound:.0e} "
                  f"CR={rows[-1]['compression_ratio']:.1f} "
                  f"nrmse={per.mean():.2e} "
                  f"decode_warm={warm_s * 1e3:.1f}ms")
    assert gates_blob is not None  # the gate ran before any report

    for bound in BOUNDS:
        recon, total = _sz_point(data, bound)
        per = np.array([metrics.nrmse(data[i], recon[i])
                        for i in range(data.shape[0])])
        rows.append({
            "method": "sz",
            "target_nrmse": bound,
            "achieved_nrmse": float(per.mean()),
            "max_species_nrmse": float(per.max()),
            "compression_ratio": data.nbytes / total,
            "blob_bytes": int(total),
            "fit_s": 0.0,
            "decode_cold_ms": 0.0,
            "decode_warm_ms": 0.0,
        })
        print(f"[bench_families] sz bound={bound:.0e} "
              f"CR={rows[-1]['compression_ratio']:.1f} "
              f"nrmse={per.mean():.2e}")

    summary = {
        "quick": quick,
        "data_shape": list(data.shape),
        "bounds": list(BOUNDS),
        "families": sorted(families_cfg),
        "points": rows,
        "gates_passed": True,
        "v1_v4_back_compat": True,
        "conv_v5_equals_v4_plus_tag": True,
    }
    with open(OUT_JSON, "w") as f:
        json.dump(summary, f, indent=2)
    os.makedirs(os.path.dirname(OUT_CSV), exist_ok=True)
    with open(OUT_CSV, "w") as f:
        keys = ["method", "target_nrmse", "achieved_nrmse",
                "max_species_nrmse", "compression_ratio", "blob_bytes",
                "fit_s", "decode_cold_ms", "decode_warm_ms"]
        f.write(",".join(keys) + "\n")
        for row in rows:
            f.write(",".join(str(row[k]) for k in keys) + "\n")
    return summary


if __name__ == "__main__":
    run(quick="--full" not in sys.argv)
