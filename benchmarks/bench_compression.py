"""Paper Fig. 4 analogue: PD error & QoI error vs compression ratio for
GBA / GBATC / SZ on the S3D surrogate, plus the guarantee audit.

The AE is trained ONCE; GBA and GBATC share it (GBATC adds the correction
network), matching the paper's setup where the tensor-correction network is
trained after the AE. Error-bound sweeps reuse the fitted networks.

Outputs results/bench/compression.csv with one row per (method, target).
"""

from __future__ import annotations

import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import metrics, qoi, sz  # noqa: E402
from repro.core.blocking import PAPER_GEOMETRY  # noqa: E402
from repro.core.pipeline import GBATCPipeline, PipelineConfig  # noqa: E402
from repro.data import s3d  # noqa: E402

TARGETS = (3e-3, 1e-3, 3e-4, 1e-4)


def bench_dataset(quick: bool):
    if quick:
        cfg = s3d.S3DConfig(n_species=12, n_time=16, height=80, width=80, seed=1)
    else:
        cfg = s3d.S3DConfig(n_species=16, n_time=24, height=120, width=120, seed=1)
    return s3d.generate(cfg)


def sz_point(data, target_nrmse, iters=7):
    """Per-species bisection on the abs error bound to hit the NRMSE target
    (nrmse is monotone in eb; `lo` always satisfies the target)."""
    s = data.shape[0]
    ranges = data.max(axis=(1, 2, 3)) - data.min(axis=(1, 2, 3))
    lo = 1e-8 * ranges
    hi = 0.3 * ranges
    for _ in range(iters):
        mid = np.sqrt(lo * hi)
        recon, _ = sz.compress_species(data, mid)
        per = np.array([metrics.nrmse(data[i], recon[i]) for i in range(s)])
        lo = np.where(per <= target_nrmse, mid, lo)
        hi = np.where(per > target_nrmse, mid, hi)
    return sz.compress_species(data, lo)


def run(quick: bool = False, out_csv: str = "results/bench/compression.csv"):
    ds = bench_dataset(quick)
    data = ds["species"]
    temp = ds["temperature"]
    mech = qoi.make_mechanism(data.shape[0])
    qoi_ref = qoi.production_rates_np(mech, data, temp)

    pcfg = PipelineConfig(
        geometry=PAPER_GEOMETRY,
        latent=36,
        conv_channels=(16, 32) if quick else (32, 64),
        ae_steps=250 if quick else 1200,
        corr_steps=150 if quick else 500,
        batch_size=96,
        use_correction=True,
    )
    pipe = GBATCPipeline(pcfg, n_species=data.shape[0])
    t0 = time.time()
    stats = pipe.fit(data)
    fit_s = time.time() - t0

    rows = []

    def qoi_err(recon):
        q = qoi.production_rates_np(mech, np.clip(recon, 0, None), temp)
        return metrics.mean_nrmse(qoi_ref, q)

    for target in TARGETS:
        for method, skip_corr in [("GBATC", False), ("GBA", True)]:
            rep = pipe.compress(target_nrmse=target, skip_correction=skip_corr)
            rows.append({
                "method": method,
                "target_nrmse": target,
                "achieved_nrmse": rep.mean_nrmse,
                "max_species_nrmse": float(rep.per_species_nrmse.max()),
                "compression_ratio": rep.compression_ratio,
                "qoi_nrmse": qoi_err(rep.recon),
                "bound_satisfied": bool(rep.per_species_nrmse.max()
                                        <= target * (1 + 1e-3)),
                **{f"bytes_{k}": v for k, v in rep.bytes_breakdown.items()},
            })
            print(f"[bench] {method} target={target:.0e} "
                  f"CR={rep.compression_ratio:.1f} "
                  f"nrmse={rep.mean_nrmse:.2e} qoi={rows[-1]['qoi_nrmse']:.2e}")
        recon_sz, total_sz = sz_point(data, target)
        per = np.array([metrics.nrmse(data[i], recon_sz[i])
                        for i in range(data.shape[0])])
        rows.append({
            "method": "SZ",
            "target_nrmse": target,
            "achieved_nrmse": float(per.mean()),
            "max_species_nrmse": float(per.max()),
            "compression_ratio": data.nbytes / total_sz,
            "qoi_nrmse": qoi_err(recon_sz),
            "bound_satisfied": bool(per.max() <= target * (1 + 1e-3)),
        })
        print(f"[bench] SZ    target={target:.0e} "
              f"CR={rows[-1]['compression_ratio']:.1f} "
              f"nrmse={rows[-1]['achieved_nrmse']:.2e} "
              f"qoi={rows[-1]['qoi_nrmse']:.2e}")

    os.makedirs(os.path.dirname(out_csv), exist_ok=True)
    keys = sorted({k for r in rows for k in r})
    with open(out_csv, "w") as f:
        f.write(",".join(keys) + "\n")
        for r in rows:
            f.write(",".join(str(r.get(k, "")) for k in keys) + "\n")
    print(f"[bench] fit {fit_s:.0f}s (final AE loss {stats['final_ae_loss']:.2e})"
          f" -> {out_csv}")
    return rows


if __name__ == "__main__":
    run(quick="--quick" in sys.argv)
