"""Benchmark entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (the harness contract) and
writes detailed CSVs under results/bench/.

  PYTHONPATH=src python -m benchmarks.run           # quick mode (default)
  PYTHONPATH=src python -m benchmarks.run --quick   # same, explicit
  PYTHONPATH=src python -m benchmarks.run --full    # paper-scale surrogate

Exits nonzero if any benchmark's internal assertion fails — in particular
the bit-identity gates (fused decompress vs the retained pre-change decode,
wire decode vs in-memory replay): a broken invariant can never hide behind
a pretty throughput number.
"""

from __future__ import annotations

import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def _timeit(fn, *args, repeat=3, **kw):
    fn(*args, **kw)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        fn(*args, **kw)
    return (time.perf_counter() - t0) / repeat * 1e6


def bench_kernels(rows):
    """Kernel micro-timings (CPU interpret mode — correctness path; TPU
    timings come from the roofline analysis, not wall clock here)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ref

    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 4, 512, 64), jnp.float32)
    f = jax.jit(lambda a: ref.flash_attention_ref(a, a, a))
    rows.append(("flash_attention_ref_512", _timeit(f, q), "oracle"))

    r = jax.random.normal(key, (1, 128, 2, 64), jnp.float32)
    w = jax.nn.sigmoid(r)
    u = jnp.zeros((2, 64))
    f = jax.jit(lambda a, b: ref.rwkv6_scan_ref(a, a, a, b, u)[0])
    rows.append(("rwkv6_scan_ref_T128", _timeit(f, r, w), "oracle"))

    x = jax.random.normal(key, (4096, 80), jnp.float32)
    basis = jnp.linalg.qr(jax.random.normal(key, (80, 80)))[0]
    f = jax.jit(ref.gbatc_project_ref)
    rows.append(("gbatc_project_4096x80", _timeit(f, x, basis), "oracle"))


def bench_gae(rows):
    """Table: guarantee post-process throughput + bytes at tau sweep."""
    from repro.core import gae

    rng = np.random.default_rng(0)
    x = rng.normal(size=(4096, 80)).astype(np.float32)
    xr = x + 0.05 * rng.normal(size=x.shape).astype(np.float32)
    for tau in (0.5, 0.2):
        us = _timeit(gae.guarantee, x, xr, tau, repeat=2)
        _, art = gae.guarantee(x, xr, tau)
        rows.append((f"gae_guarantee_tau{tau}", us,
                     f"bytes={art.total_bytes()}"))


def bench_guarantee_engine(rows):
    """Device-resident guarantee engine vs numpy oracle; emits the
    BENCH_guarantee.json perf trajectory for future PRs to regress
    against (harness CSV rows preserved alongside)."""
    from benchmarks import bench_guarantee

    summary = bench_guarantee.run()
    # steady-state = per-bound select cost with prepare amortized out,
    # matching the speedup_steady_state definition
    select_ms = [r["engine_select_ms"] for r in summary["sweep"]]
    rows.append((
        "guarantee_engine_steady_state",
        sum(select_ms) / len(select_ms) * 1e3,
        f"speedup={summary['speedup_steady_state']:.1f}x",
    ))
    rows.append((
        "guarantee_engine_sweep",
        summary["engine_sweep_ms"] * 1e3,
        f"speedup={summary['speedup_sweep']:.1f}x",
    ))


def bench_throughput_engine(rows, full=False):
    """Compiled trainer + fused decode vs the retained pre-change paths;
    emits BENCH_throughput.json. Bit-identity of the fused decode against
    the reference is asserted inside before any number is reported."""
    from benchmarks import bench_throughput

    summary = bench_throughput.run(quick=not full)
    rows.append((
        "throughput_fit_warm",
        summary["fit"]["engine_warm_s"] * 1e6,
        f"speedup={summary['fit']['speedup_warm']:.1f}x",
    ))
    rows.append((
        "throughput_decompress",
        summary["decompress"]["fused_ms"] * 1e3,
        f"MBps={summary['decompress']['fused_MBps']:.1f}"
        f" speedup={summary['decompress']['speedup']:.1f}x",
    ))


def bench_codec_wire(rows, full=False):
    """Container wire format: on-disk-verified ratios + codec throughput;
    emits BENCH_codec.json (harness CSV rows preserved alongside)."""
    from benchmarks import bench_codec

    summary = bench_codec.run(quick=not full)
    ser = [r["serialize_ms"] for r in summary["targets"]]
    deser = [r["deserialize_ms"] for r in summary["targets"]]
    crs = [r["on_disk_compression_ratio"] for r in summary["targets"]]
    rows.append((
        "codec_serialize",
        sum(ser) / len(ser) * 1e3,
        f"MBps={summary['serialize_MBps_mean']:.0f}",
    ))
    rows.append((
        "codec_deserialize",
        sum(deser) / len(deser) * 1e3,
        f"MBps={summary['deserialize_MBps_mean']:.0f}"
        " CR=" + "/".join(f"{c:.1f}" for c in crs),
    ))


def bench_partial_decode(rows, full=False):
    """Selective (per-species / time-window) decode vs full decode; emits
    BENCH_partial.json. Bitwise equivalence of every selective decode with
    the sliced full decode (and v1 container back-compat) is asserted
    inside before any number is reported."""
    from benchmarks import bench_partial

    summary = bench_partial.run(quick=not full)
    rows.append((
        "partial_decode_1_species",
        summary["decode_1_species_ms"] * 1e3,
        f"speedup={summary['speedup_1_species']:.1f}x"
        f" bytes={summary['bytes_parsed_fraction']:.0%}",
    ))
    rows.append((
        "partial_decode_1_species_window",
        summary["decode_1_species_window_ms"] * 1e3,
        f"speedup={summary['speedup_1_species_window']:.1f}x",
    ))


def bench_sharded_latents(rows, full=False):
    """Time-sharded (container v3) latent stream: O(window) latent entropy
    for window decodes + parallel shard encode; emits BENCH_shards.json.
    v2/v3 byte-identity and slice-equivalence gates are asserted inside
    before any number is reported."""
    from benchmarks import bench_shards

    summary = bench_shards.run(quick=not full)
    first = summary["per_shard_size"][0]
    rows.append((
        "shards_window_decode",
        first["window_decode_warm_ms"] * 1e3,
        f"latent_frac={summary['window_latent_fraction']:.0%}"
        f" v2_ms={summary['v2_window_decode_warm_ms']:.1f}",
    ))
    rows.append((
        "shards_parallel_encode",
        summary["shard_encode"]["parallel_ms"] * 1e3,
        f"MBps={summary['shard_encode']['parallel_MBps']:.0f}"
        f" speedup={summary['shard_encode']['parallel_speedup']:.1f}x",
    ))


def bench_integrity_v4(rows, full=False):
    """Integrity container (v4): digest overhead, verification budget,
    salvage throughput; emits BENCH_integrity.json. The clean-blob
    v4/v3 byte-identity gate, the verify-cost budget (< 3% of a warm
    full decode), salvage correctness, and the 100%-detection fault
    sweep are asserted inside before any number is reported."""
    from benchmarks import bench_integrity

    summary = bench_integrity.run(quick=not full)
    rows.append((
        "integrity_verify_blob",
        summary["verify_blob_ms"] * 1e3,
        f"frac_of_warm_decode="
        f"{summary['verify_fraction_of_warm_decode']:.1%}"
        f" digest_bytes={summary['digest_overhead_bytes']}",
    ))
    k1 = summary["salvage"][0]
    rows.append((
        "integrity_salvage_1_species",
        k1["salvage_ms"] * 1e3,
        f"MBps={k1['salvage_MBps']:.0f}"
        f" sweep_detect={summary['fault_sweep']['detection_rate']:.0%}",
    ))


def bench_serve_service(rows, full=False):
    """Continuous-batched decode service vs the naive serial
    PartialDecoder loop under synthetic traffic; emits BENCH_serve.json.
    Bitwise service-vs-serial equivalence for every distinct request and
    the >=2x-QPS-at-equal-p99 hot-mix gate are asserted inside before
    any number is reported."""
    from benchmarks import bench_serve

    summary = bench_serve.run(quick=not full)
    hot = summary["mixes"]["hot_zipf"]
    rows.append((
        "serve_hot_zipf_qps",
        1e6 / hot["service"]["qps"],
        f"speedup={hot['qps_ratio']:.1f}x"
        f" p99={hot['service']['p99_ms']:.0f}ms"
        f" shard_hits={hot['cache_hit_rates']['shard']:.0%}",
    ))
    churn = summary["mixes"]["churn"]
    rows.append((
        "serve_churn_qps",
        1e6 / churn["service"]["qps"],
        f"speedup={churn['qps_ratio']:.1f}x"
        f" p99={churn['service']['p99_ms']:.0f}ms",
    ))


def bench_encoder_families(rows, full=False):
    """Registered encoder families (conv AE, block attention) vs the SZ
    baseline: CR at 3 NRMSE bounds + fit/decode wall-clock; emits
    BENCH_families.json. The v1–v4 back-compat and conv-v5 ≡ v4 + tag
    byte-identity gates are asserted inside before any number is
    reported."""
    from benchmarks import bench_families

    summary = bench_families.run(quick=not full)
    by = {(r["method"], r["target_nrmse"]): r for r in summary["points"]}
    b0 = summary["bounds"][0]
    for fam in summary["families"]:
        r = by[(fam, b0)]
        crs = [by[(fam, b)]["compression_ratio"]
               for b in summary["bounds"]]
        rows.append((
            f"families_{fam}",
            r["decode_warm_ms"] * 1e3,
            f"fit_s={r['fit_s']:.1f}"
            " CR=" + "/".join(f"{c:.1f}" for c in crs),
        ))
    sz_crs = [by[("sz", b)]["compression_ratio"] for b in summary["bounds"]]
    rows.append((
        "families_sz_baseline",
        0.0,
        "CR=" + "/".join(f"{c:.1f}" for c in sz_crs),
    ))


def bench_mesh_scaling(rows, full=False):
    """Mesh-sharded fit/compress: DP fit steps/s at 1/2/4/8 forced host
    devices, sharded-vs-default compress wall-clock, quantized-vs-fp32
    wire bytes; emits BENCH_mesh.json. The P=1 fit bit-identity, the
    sharded-container byte-identity, and the parts-mode pack parity are
    asserted inside the child before any number is reported."""
    from benchmarks import bench_mesh

    summary = bench_mesh.run(quick=not full)
    best = max(summary["dp_fit"]["per_device_count"],
               key=lambda c: c["steps_per_s"])
    rows.append((
        "mesh_dp_fit",
        summary["dp_fit"]["per_device_count"][-1]["fit_s"] * 1e6,
        f"best={best['steps_per_s']:.0f}steps/s"
        f"@{best['n_devices']}dev cores={summary['cpu_cores']}",
    ))
    rows.append((
        "mesh_sharded_compress",
        summary["compress"]["sharded_engine_s"] * 1e6,
        f"default_s={summary['compress']['default_engine_s']:.3f}"
        f" byte_identical={summary['compress']['byte_identical']}",
    ))
    rows.append((
        "mesh_wire_quantized",
        0.0,
        f"ratio_p2={summary['wire']['p2']['wire_ratio']:.2f}"
        f" ratio_p8={summary['wire']['p8']['wire_ratio']:.2f}",
    ))


def bench_analysis_gate(rows):
    """Invariant checker (lint + wire schema + jaxpr audit) as a gate:
    zero non-baselined findings, or the whole run turns nonzero; emits
    BENCH_analysis.json with per-rule counts and tier wall-clocks."""
    from benchmarks import bench_analysis

    summary = bench_analysis.run()
    n_rules = sum(summary["rule_counts"].values())
    rows.append((
        "analysis_gate",
        (summary["lint_wall_clock_s"] + summary["schema_wall_clock_s"]
         + summary["audit_wall_clock_s"]) * 1e6,
        f"findings={n_rules} new={summary['new_findings']}"
        f" programs={len(summary['audited_programs'])}",
    ))


def bench_sz(rows):
    from repro.core import sz
    from repro.data import s3d

    ds = s3d.generate(s3d.S3DConfig(n_species=1, n_time=16, height=80,
                                    width=80, seed=0))
    field = ds["species"][0]
    for eb_rel in (1e-3, 1e-4):
        eb = eb_rel * float(field.max() - field.min())
        us = _timeit(sz.compress, field, eb, repeat=2)
        art = sz.compress(field, eb)
        rows.append((f"sz_compress_eb{eb_rel:g}", us,
                     f"CR={field.nbytes / art.payload_bytes():.1f}"))


def main() -> None:
    # --quick (the default) runs the small surrogates; --full paper-scale
    full = "--full" in sys.argv and "--quick" not in sys.argv
    rows: list[tuple] = []
    failures: list[str] = []

    def guarded(name, fn, *args, **kw):
        """Run one benchmark; a failed bit-identity (or any other)
        assertion is recorded and turns the whole run nonzero instead of
        silently dropping the benchmark."""
        try:
            fn(*args, **kw)
        except AssertionError as e:
            failures.append(f"{name}: {e}")
            rows.append((name, 0.0, f"ASSERTION FAILED: {e}"))

    guarded("bench_kernels", bench_kernels, rows)
    guarded("bench_gae", bench_gae, rows)
    guarded("guarantee_engine", bench_guarantee_engine, rows)
    guarded("throughput_engine", bench_throughput_engine, rows, full=full)
    guarded("codec_wire", bench_codec_wire, rows, full=full)
    guarded("partial_decode", bench_partial_decode, rows, full=full)
    guarded("sharded_latents", bench_sharded_latents, rows, full=full)
    guarded("integrity", bench_integrity_v4, rows, full=full)
    guarded("serve", bench_serve_service, rows, full=full)
    guarded("families", bench_encoder_families, rows, full=full)
    guarded("mesh", bench_mesh_scaling, rows, full=full)
    guarded("analysis", bench_analysis_gate, rows)
    guarded("bench_sz", bench_sz, rows)

    # paper-figure benchmarks (CR vs NRMSE + QoI + gradcomp)
    from benchmarks import bench_compression, bench_gradcomp, bench_qoi

    def timed(name, fn):
        t0 = time.time()
        out = fn(quick=not full)
        rows.append((f"{name}_total", (time.time() - t0) * 1e6,
                     f"rows={len(out)}"))

    guarded("bench_compression", timed, "bench_compression",
            bench_compression.run)
    guarded("bench_qoi", timed, "bench_qoi", bench_qoi.run)
    guarded("bench_gradcomp", timed, "bench_gradcomp", bench_gradcomp.run)

    # roofline summary if dry-run artifacts exist
    try:
        from benchmarks import roofline

        rrows = roofline.analyze()
        if rrows:
            worst = min(rrows, key=lambda r: r["roofline_frac"])
            rows.append(("roofline_cells", float(len(rrows)),
                         f"worst={worst['arch']}/{worst['shape']}"
                         f"@{worst['roofline_frac']:.3f}"))
    except Exception as e:  # noqa: BLE001
        rows.append(("roofline_cells", 0.0, f"unavailable:{e!r}"))

    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if failures:
        print("\nFAILED ASSERTIONS:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
