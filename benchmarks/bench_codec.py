"""Codec wire-format benchmark: serialize/deserialize throughput + verified
on-disk compression ratio.

Unlike ``bench_compression`` (which reports quality from in-memory byte
*accounting*), every ratio here is computed from a container actually
written to disk: CR = raw bytes / ``os.path.getsize``. The benchmark also
asserts the acceptance contract at every error bound — the standalone
``repro.codec.decompress`` of the on-disk blob must bit-match the
encoder-side replay, satisfy the NRMSE bound, and the reported byte total
must equal the file size exactly — so a throughput number from a broken
wire format cannot be reported.

Writes BENCH_codec.json (repo root) + results/bench/codec.csv.

  PYTHONPATH=src python -m benchmarks.bench_codec
"""

from __future__ import annotations

import dataclasses
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import codec  # noqa: E402
from repro.core import metrics  # noqa: E402
from repro.core.pipeline import PipelineConfig  # noqa: E402
from repro.data import s3d  # noqa: E402

TARGETS = (3e-3, 1e-3, 3e-4)
OUT_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_codec.json")
OUT_CSV = "results/bench/codec.csv"
BLOB_DIR = "results/bench"


def _time(fn, repeat=5):
    """Best-of-N wall time: robust to CPU contention in shared runners."""
    fn()  # warmup (jit compile / caches)
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(quick: bool = True, seed: int = 1):
    scfg = (
        s3d.S3DConfig(n_species=12, n_time=16, height=80, width=80, seed=seed)
        if quick
        else s3d.S3DConfig(n_species=16, n_time=24, height=120, width=120,
                           seed=seed)
    )
    data = s3d.generate(scfg)["species"]
    gbatc = codec.GBATCCodec(
        PipelineConfig(
            conv_channels=(16, 32),
            ae_steps=150 if quick else 800,
            corr_steps=80 if quick else 400,
        )
    )
    t0 = time.time()
    gbatc.fit(data)
    fit_s = time.time() - t0
    raw_mb = data.nbytes / 1e6

    os.makedirs(BLOB_DIR, exist_ok=True)
    rows = []
    for target in TARGETS:
        blob, rep = gbatc.compress_report(target_nrmse=target)
        path = os.path.join(BLOB_DIR, f"codec_target{target:g}.gbtc")
        with open(path, "wb") as f:
            f.write(blob)
        on_disk = os.path.getsize(path)

        # -- the acceptance contract, asserted before any number is kept --
        assert on_disk == len(blob)
        assert rep.bytes_breakdown["total"] == on_disk, "accounting != file size"
        with open(path, "rb") as f:
            decoded = codec.decompress(f.read())
        inmem = gbatc.pipeline.decompress(rep.artifact)
        assert np.array_equal(decoded, inmem), "wire decode != in-memory replay"
        per = np.array(
            [metrics.nrmse(data[s], decoded[s]) for s in range(data.shape[0])]
        )
        assert per.max() <= target * (1 + 1e-3), "bound violated on wire"

        # -- serialize: full container build incl. entropy coding ----------
        art = rep.artifact
        serialize_s = _time(
            lambda: codec.encode(
                dataclasses.replace(
                    art, _latent_blob=None, _param_streams=None, _wire=None
                )
            )
        )
        # -- deserialize: parse + entropy decode + NN decode + replay ------
        # (head memo cleared per call: this times the cold wire decode,
        # not the digest-cache-served steady state)
        deserialize_s = _time(
            lambda: (codec.clear_decode_cache(), codec.decompress(blob))
        )

        rows.append({
            "target_nrmse": target,
            "blob_bytes": on_disk,
            "on_disk_compression_ratio": data.nbytes / on_disk,
            "serialize_ms": serialize_s * 1e3,
            "deserialize_ms": deserialize_s * 1e3,
            "serialize_MBps": raw_mb / (serialize_s * 1e3) * 1e3,
            "deserialize_MBps": raw_mb / (deserialize_s * 1e3) * 1e3,
            "max_species_nrmse": float(per.max()),
            "decode_bit_identical": True,
            "total_equals_file_size": True,
            **{f"bytes_{k}": v for k, v in rep.bytes_breakdown.items()
               if k != "total"},
        })
        print(f"[bench_codec] target={target:.0e} CR={rows[-1]['on_disk_compression_ratio']:6.1f}x "
              f"({on_disk} B on disk) ser={serialize_s*1e3:6.1f}ms "
              f"deser={deserialize_s*1e3:6.1f}ms")

    summary = {
        "problem": {
            "shape": list(data.shape),
            "raw_bytes": int(data.nbytes),
            "seed": seed,
            "quick": quick,
        },
        "fit_s": fit_s,
        "targets": rows,
        "serialize_MBps_mean": float(np.mean([r["serialize_MBps"] for r in rows])),
        "deserialize_MBps_mean": float(
            np.mean([r["deserialize_MBps"] for r in rows])
        ),
    }
    with open(OUT_JSON, "w") as f:
        json.dump(summary, f, indent=2)
    keys = list(rows[0].keys())
    with open(OUT_CSV, "w") as f:
        f.write(",".join(keys) + "\n")
        for r in rows:
            f.write(",".join(str(r[k]) for k in keys) + "\n")
    print(f"[bench_codec] fit {fit_s:.0f}s | "
          f"ser {summary['serialize_MBps_mean']:.0f} MB/s, "
          f"deser {summary['deserialize_MBps_mean']:.0f} MB/s -> {OUT_JSON}")
    return summary


if __name__ == "__main__":
    run(quick="--full" not in sys.argv)
