"""Paper Figs. 5-8 analogue: species-level fidelity at a fixed compression
ratio — SSIM / PSNR of PD and QoI fields for a major and a minor species,
plus mean/std temporal tracking error.

Outputs results/bench/qoi.csv.
"""

from __future__ import annotations

import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import metrics, qoi  # noqa: E402
from repro.core.pipeline import GBATCPipeline, PipelineConfig  # noqa: E402
from repro.data import s3d  # noqa: E402
from benchmarks.bench_compression import sz_point  # noqa: E402


def run(quick: bool = False, out_csv: str = "results/bench/qoi.csv"):
    cfg = s3d.S3DConfig(n_species=12, n_time=16, height=80, width=80, seed=2)
    ds = s3d.generate(cfg)
    data, temp = ds["species"], ds["temperature"]
    mech = qoi.make_mechanism(data.shape[0])
    qoi_ref = qoi.production_rates_np(mech, data, temp)

    # majors are low indices (products/reactants); minors high (radicals)
    major, minor = 2, data.shape[0] - 1
    target = 1e-3

    pcfg = PipelineConfig(conv_channels=(16, 32),
                          ae_steps=200 if quick else 800,
                          corr_steps=120 if quick else 400)
    pipe = GBATCPipeline(pcfg, n_species=data.shape[0])
    pipe.fit(data)

    recons = {
        "GBATC": pipe.compress(target_nrmse=target).recon,
        "GBA": pipe.compress(target_nrmse=target, skip_correction=True).recon,
        "SZ": sz_point(data, target)[0],
    }

    rows = []
    mid_t = data.shape[1] // 2
    for method, rec in recons.items():
        q = qoi.production_rates_np(mech, np.clip(rec, 0, None), temp)
        for label, sidx in [("major", major), ("minor", minor)]:
            rows.append({
                "method": method,
                "species": label,
                "pd_ssim": metrics.ssim2d(data[sidx, mid_t], rec[sidx, mid_t]),
                "pd_psnr": metrics.psnr(data[sidx], rec[sidx]),
                "pd_nrmse": metrics.nrmse(data[sidx], rec[sidx]),
                "qoi_ssim": metrics.ssim2d(qoi_ref[sidx, mid_t], q[sidx, mid_t]),
                "qoi_psnr": metrics.psnr(qoi_ref[sidx], q[sidx]),
                "qoi_nrmse": metrics.nrmse(qoi_ref[sidx], q[sidx]),
                # Fig 7/8: mean/std temporal tracking (relative L2 over time)
                "mean_track_err": float(np.linalg.norm(
                    data[sidx].mean((1, 2)) - rec[sidx].mean((1, 2)))
                    / (np.linalg.norm(data[sidx].mean((1, 2))) + 1e-30)),
                "std_track_err": float(np.linalg.norm(
                    data[sidx].std((1, 2)) - rec[sidx].std((1, 2)))
                    / (np.linalg.norm(data[sidx].std((1, 2))) + 1e-30)),
            })
            print(f"[qoi] {method:6s} {label}: "
                  f"pd_ssim={rows[-1]['pd_ssim']:.4f} "
                  f"qoi_nrmse={rows[-1]['qoi_nrmse']:.2e} "
                  f"std_track={rows[-1]['std_track_err']:.2e}")

    os.makedirs(os.path.dirname(out_csv), exist_ok=True)
    keys = list(rows[0].keys())
    with open(out_csv, "w") as f:
        f.write(",".join(keys) + "\n")
        for r in rows:
            f.write(",".join(str(r[k]) for k in keys) + "\n")
    print(f"[qoi] -> {out_csv}")
    return rows


if __name__ == "__main__":
    run(quick="--quick" in sys.argv)
