"""Decode-service benchmark: continuous-batched serving under synthetic
traffic vs a naive serial ``PartialDecoder`` loop.

The serving scenario the paper's consumers imply: many analysts issue
small selective-decode queries — zipf-skewed species popularity, sliding
time windows — against a fleet of container blobs with one hot blob.
The load generator drives two closed-loop mixes with K client threads:

* ``hot_zipf`` — every request hits the hot blob; zipfian species
  (single + small subsets), sliding windows. The acceptance mix.
* ``churn`` — the hot blob gets most of the traffic, the rest spreads
  over cold sibling blobs (byte-different containers of the same
  artifact at other shard granularities), forcing head-cache churn.

Before any number is reported, the equivalence gates are asserted:
every distinct request in both traces, decoded through the service, is
**bitwise equal** to the serial ``PartialDecoder`` answer. Then the
acceptance gates: on ``hot_zipf`` the batched+cached service must beat
the serial loop by >= 2x QPS at equal-or-better p99 latency.

Writes BENCH_serve.json (repo root) + results/bench/serve.csv.

  PYTHONPATH=src python -m benchmarks.bench_serve
"""

from __future__ import annotations

import json
import os
import sys
import threading
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import codec  # noqa: E402
from repro.core.pipeline import PipelineConfig  # noqa: E402
from repro.data import s3d  # noqa: E402
from repro.serve import DecodeService  # noqa: E402

TARGET = 3e-4
OUT_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")
OUT_CSV = "results/bench/serve.csv"

N_CLIENTS = 6
ZIPF_A = 1.2


class SerialServer:
    """The baseline: a naive serial PartialDecoder loop. One request at a
    time, in submission order — exactly the pre-service serving story
    (clients contend for one decode loop; no batching, no coalescing)."""

    def __init__(self):
        self._blobs: dict[str, bytes] = {}
        self._lock = threading.Lock()

    def register(self, blob_id: str, blob: bytes) -> None:
        self._blobs[blob_id] = blob

    def decode(self, blob_id: str, species=None, time_range=None):
        with self._lock:  # serializes: the "loop"
            pd = codec.PartialDecoder(self._blobs[blob_id])
            return pd.decode(species=species, time_range=time_range)


def _zipf_weights(n: int) -> np.ndarray:
    w = 1.0 / np.arange(1, n + 1) ** ZIPF_A
    return w / w.sum()


def _make_trace(rng, blob_ids, hot_frac, s, t, n_requests):
    """Synthetic request trace: (blob_id, species, time_range) tuples.

    Species ranks are zipf-reweighted per trace (rank->species shuffled
    once so the hot species isn't always index 0); windows slide across
    the series with a mix of lengths; ``hot_frac`` of requests pin the
    first blob id, the rest spread uniformly over the others.
    """
    ranks = rng.permutation(s)
    sw = _zipf_weights(s)
    win = max(2, t // 4)
    trace = []
    for i in range(n_requests):
        if len(blob_ids) == 1 or rng.random() < hot_frac:
            bid = blob_ids[0]
        else:
            bid = blob_ids[1 + int(rng.integers(0, len(blob_ids) - 1))]
        if rng.random() < 0.7:
            species = int(ranks[rng.choice(s, p=sw)])
        else:
            k = int(rng.integers(2, 4))
            picks = rng.choice(s, p=sw, size=k * 3)  # oversample, dedup
            uniq = list(dict.fromkeys(int(ranks[p]) for p in picks))[:k]
            species = uniq
        t0 = (i * 2) % max(1, t - win)  # sliding window
        time_range = (t0, t0 + win) if rng.random() < 0.8 else None
        trace.append((bid, species, time_range))
    return trace


def _run_clients(decode_fn, trace):
    """Closed-loop K-client run: each client issues its share of the
    trace back to back; returns (wall_s, per-request latencies)."""
    shares = [trace[i::N_CLIENTS] for i in range(N_CLIENTS)]
    lats: "list[list[float]]" = [[] for _ in range(N_CLIENTS)]
    errors: list = []

    def client(i):
        try:
            for bid, sp, tr in shares[i]:
                t0 = time.perf_counter()
                decode_fn(bid, sp, tr)
                lats[i].append(time.perf_counter() - t0)
        except Exception as e:  # surfaced by the caller
            errors.append(e)

    threads = [threading.Thread(target=client, args=(i,))
               for i in range(N_CLIENTS)]
    t0 = time.perf_counter()
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    wall = time.perf_counter() - t0
    if errors:
        raise errors[0]
    return wall, [x for ls in lats for x in ls]


def _percentiles(lats):
    a = np.asarray(lats)
    return {
        "p50_ms": float(np.percentile(a, 50) * 1e3),
        "p99_ms": float(np.percentile(a, 99) * 1e3),
        "mean_ms": float(a.mean() * 1e3),
    }


def _measure(mix_name, trace, blobs):
    """Serial baseline then batched service on one trace (each from a
    cold decode cache); returns the mix's summary dict."""
    # -- serial baseline -------------------------------------------------
    codec.clear_decode_cache()
    serial = SerialServer()
    for bid, b in blobs.items():
        serial.register(bid, b)
    wall_serial, lats_serial = _run_clients(serial.decode, trace)

    # -- batched + cached service ----------------------------------------
    codec.clear_decode_cache()
    with DecodeService(max_batch=2 * N_CLIENTS) as svc:
        for bid, b in blobs.items():
            svc.register(bid, b)
        wall_svc, lats_svc = _run_clients(svc.decode, trace)
    cache = codec.cache_stats()

    n = len(trace)
    out = {
        "requests": n,
        "clients": N_CLIENTS,
        "serial": {"qps": n / wall_serial, "wall_s": wall_serial,
                   **_percentiles(lats_serial)},
        "service": {"qps": n / wall_svc, "wall_s": wall_svc,
                    **_percentiles(lats_svc),
                    "sched": svc.stats.as_dict()},
        "qps_ratio": wall_serial / wall_svc,
        "p99_ratio": (_percentiles(lats_svc)["p99_ms"]
                      / _percentiles(lats_serial)["p99_ms"]),
        "cache_hit_rates": {
            tier: cache[tier]["hit_rate"]
            for tier in ("head", "shard", "guarantee", "decode_table")
        },
    }
    print(
        f"[bench_serve] {mix_name}: serial {out['serial']['qps']:.1f} qps "
        f"(p99 {out['serial']['p99_ms']:.0f}ms) vs service "
        f"{out['service']['qps']:.1f} qps (p99 "
        f"{out['service']['p99_ms']:.0f}ms) -> "
        f"{out['qps_ratio']:.1f}x | dispatches "
        f"{svc.stats.dispatches}/{svc.stats.requests} reqs | shard hits "
        f"{cache['shard']['hit_rate']:.0%}"
    )
    return out


def run(quick: bool = True, seed: int = 3):
    scfg = (
        s3d.S3DConfig(n_species=12, n_time=16, height=80, width=80,
                      seed=seed)
        if quick
        else s3d.S3DConfig(n_species=16, n_time=24, height=120, width=120,
                           seed=seed)
    )
    data = s3d.generate(scfg)["species"]
    gbatc = codec.GBATCCodec(
        PipelineConfig(
            conv_channels=(16, 32),
            ae_steps=150 if quick else 800,
            corr_steps=80 if quick else 400,
        )
    )
    t0 = time.time()
    gbatc.fit(data)
    fit_s = time.time() - t0
    blob, rep = gbatc.compress_report(target_nrmse=TARGET)

    # a fleet of byte-different containers of the same artifact (other
    # shard granularities): cold siblings for the churn mix, free — no
    # refit — and all decoding to the identical field
    blobs = {"hot": blob}
    for k in (2, 4):  # default is 1 tgroup/shard; these are byte-different
        blobs[f"cold{k}"] = codec.encode(rep.artifact, version=4,
                                         shard_tgroups=k)
    assert len({bytes(b) for b in blobs.values()}) == len(blobs)

    s, t = data.shape[0], data.shape[1]
    rng = np.random.default_rng(seed)
    n_req = 180 if quick else 600
    trace_hot = _make_trace(rng, ["hot"], 1.0, s, t, n_req)
    trace_churn = _make_trace(rng, list(blobs), 0.6, s, t, n_req)

    # -- equivalence gates: asserted before any number is reported -------
    full = codec.decompress(blob)
    for name, b in blobs.items():
        assert np.array_equal(codec.decompress(b), full), \
            f"sibling blob {name} decode != hot decode"
    distinct = {}
    for bid, sp, tr in trace_hot + trace_churn:
        key = (bid, json.dumps(sp), tr)
        distinct.setdefault(key, (bid, sp, tr))
    with DecodeService() as svc:
        for bid, b in blobs.items():
            svc.register(bid, b)
        for bid, sp, tr in distinct.values():
            got = svc.decode(bid, sp, tr)
            want = codec.PartialDecoder(blobs[bid]).decode(
                species=sp, time_range=tr
            )
            assert np.array_equal(got, want), \
                f"service != serial for {(bid, sp, tr)}"
    n_gated = len(distinct)

    # -- measured mixes (also warmed by the gate pass above) -------------
    mixes = {
        "hot_zipf": _measure("hot_zipf", trace_hot, {"hot": blob}),
        "churn": _measure("churn", trace_churn, blobs),
    }

    summary = {
        "problem": {
            "shape": list(data.shape),
            "blob_bytes": len(blob),
            "n_blobs": len(blobs),
            "target_nrmse": TARGET,
            "seed": seed,
            "quick": quick,
            "zipf_a": ZIPF_A,
        },
        "fit_s": fit_s,
        "equivalence_gates_passed": True,
        "distinct_requests_gated": n_gated,
        "mixes": mixes,
    }

    # the acceptance contract: batched+cached serving beats the naive
    # serial PartialDecoder loop on the hot-blob zipfian mix by >= 2x
    # QPS at equal-or-better p99
    hot = mixes["hot_zipf"]
    assert hot["qps_ratio"] >= 2.0, (
        f"hot_zipf QPS ratio {hot['qps_ratio']:.2f}x < 2x over the serial "
        f"loop"
    )
    assert hot["service"]["p99_ms"] <= hot["serial"]["p99_ms"], (
        f"hot_zipf service p99 {hot['service']['p99_ms']:.1f}ms worse "
        f"than serial {hot['serial']['p99_ms']:.1f}ms"
    )

    with open(OUT_JSON, "w") as f:
        json.dump(summary, f, indent=2)
    os.makedirs(os.path.dirname(OUT_CSV), exist_ok=True)
    cols = []
    for mix, m in mixes.items():
        for side in ("serial", "service"):
            for k in ("qps", "p50_ms", "p99_ms"):
                cols.append((f"{mix}_{side}_{k}", m[side][k]))
        cols.append((f"{mix}_qps_ratio", m["qps_ratio"]))
    with open(OUT_CSV, "w") as f:
        f.write(",".join(k for k, _ in cols) + "\n")
        f.write(",".join(f"{v:.3f}" for _, v in cols) + "\n")
    print(
        f"[bench_serve] hot_zipf {hot['qps_ratio']:.1f}x QPS at p99 "
        f"{hot['service']['p99_ms']:.0f}ms vs serial "
        f"{hot['serial']['p99_ms']:.0f}ms | {n_gated} distinct requests "
        f"gated bitwise -> {OUT_JSON}"
    )
    return summary


if __name__ == "__main__":
    run(quick="--full" not in sys.argv)
