"""Time-sharded latent stream (container v3) benchmark.

The last random-access gap: through PR 4 every byte bucket was
random-access *except* the latent stream — one sequential Huffman chain,
so a time-window query still entropy-decoded all latents. Container v3
shards the chain along the time axis (shared codebook, per-shard chains,
byte-extent directory); this benchmark measures what that buys:

* **latent bytes entropy-decoded vs window size** — the O(window) claim:
  a 4-frame window must touch a ~window-sized fraction of the latent
  chain bytes, not O(T) (v2's single chain is the contrast row);
* **window-decode wall clock vs shard size** — warm PartialDecoder
  queries across shard granularities, plus the v2 baseline;
* **parallel vs serial shard encode throughput** — shard chains are
  independent, so the packer threads them.

Before any number is reported, the equivalence gates are asserted:

* full v3 decode is **byte-identical** to the v2 decode of the same fit,
  at every shard size measured;
* every windowed v3 decode is bitwise the slice of the full decode.

Writes BENCH_shards.json (repo root) + results/bench/shards.csv.

  PYTHONPATH=src python -m benchmarks.bench_shards
"""

from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import codec  # noqa: E402
from repro.core import container  # noqa: E402
from repro.core.container import ContainerReader  # noqa: E402
from repro.core.pipeline import PipelineConfig  # noqa: E402
from repro.data import s3d  # noqa: E402

TARGET = 3e-4  # tight bound: the serving configuration
WINDOW_FRAMES = 4
OUT_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_shards.json")
OUT_CSV = "results/bench/shards.csv"


def _time(fn, repeat=5):
    """Best-of-N wall time: robust to CPU contention in shared runners."""
    fn()  # warmup (jit compile / caches)
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(quick: bool = True, seed: int = 1):
    scfg = (
        s3d.S3DConfig(n_species=12, n_time=16, height=80, width=80, seed=seed)
        if quick
        else s3d.S3DConfig(n_species=16, n_time=24, height=120, width=120,
                           seed=seed)
    )
    data = s3d.generate(scfg)["species"]
    gbatc = codec.GBATCCodec(
        PipelineConfig(
            conv_channels=(16, 32),
            ae_steps=150 if quick else 800,
            corr_steps=80 if quick else 400,
        )
    )
    t0 = time.time()
    gbatc.fit(data)
    fit_s = time.time() - t0
    blob_default, rep = gbatc.compress_report(target_nrmse=TARGET)
    art = rep.artifact
    blob_v2 = codec.encode(art, version=2)
    blob_v3 = codec.encode(art, version=3)
    t = data.shape[1]
    bt = art.cfg.geometry.bt
    n_tgroups = t // bt
    shard_sizes = sorted({1, 2, n_tgroups})
    window = (t // 4, t // 4 + WINDOW_FRAMES)

    # -- equivalence gates: asserted before any number is reported -------
    full_v2 = codec.decompress(blob_v2)
    blobs = {tg: codec.encode(art, version=3, shard_tgroups=tg)
             for tg in shard_sizes}
    assert blobs[codec.DEFAULT_SHARD_TGROUPS] == blob_v3  # default shards
    # the default writer is now v5 = this v3 layout + integrity digests
    # (bench_integrity's subject) + the family tag (bench_families'),
    # still decoding bit-identically
    assert ContainerReader(blob_default).version == \
        container.FORMAT_VERSION_FAMILY
    assert codec.decompress(blob_default).tobytes() == full_v2.tobytes(), \
        "default-version full decode != v2 decode byte-for-byte"
    for tg, b in blobs.items():
        full_v3 = codec.decompress(b)
        assert full_v3.tobytes() == full_v2.tobytes(), \
            f"v3 (shard_tgroups={tg}) full decode != v2 decode byte-for-byte"
        win = codec.decompress(b, time_range=window)
        assert np.array_equal(win, full_v3[:, window[0]:window[1]]), \
            f"v3 (shard_tgroups={tg}) window decode != full slice"

    # -- latent bytes entropy-decoded vs window size (O(window) gate) ----
    pd1 = codec.PartialDecoder(blobs[1])
    latent_total = pd1.latent_bytes_parsed()
    windows = []
    frames = WINDOW_FRAMES
    while frames <= t:
        w = (0, frames)
        windows.append({
            "frames": frames,
            "latent_bytes": int(pd1.latent_bytes_parsed(w)),
            "fraction_of_total": pd1.latent_bytes_parsed(w) / latent_total,
        })
        frames *= 2
    if windows[-1]["frames"] != t:
        windows.append({
            "frames": t,
            "latent_bytes": int(latent_total),
            "fraction_of_total": 1.0,
        })
    v2_latent = ContainerReader(blob_v2).stream_sizes()["latent"]
    b4 = windows[0]["latent_bytes"]
    # the acceptance contract: a 4-frame window's latent entropy work
    # scales with the window, not with T (v2 walks the whole chain)
    assert b4 <= latent_total * (WINDOW_FRAMES / t + 0.2), (
        f"4-frame window entropy-decodes {b4} of {latent_total} latent "
        f"bytes — not O(window)"
    )
    bytes_monotone = all(
        a["latent_bytes"] <= b["latent_bytes"]
        for a, b in zip(windows, windows[1:])
    )
    assert bytes_monotone, "latent bytes not monotone in window size"

    # -- window-decode wall clock vs shard size --------------------------
    per_shard = []
    for tg in shard_sizes:
        pd = codec.PartialDecoder(blobs[tg])
        pd.decode(time_range=window)  # warm the shard memo + jit
        warm_s = _time(lambda pd=pd: pd.decode(time_range=window))
        cold_s = _time(lambda b=blobs[tg]: (
            codec.clear_decode_cache(),
            codec.decompress(b, time_range=window),
        ))
        per_shard.append({
            "shard_tgroups": tg,
            "blob_bytes": len(blobs[tg]),
            "latent_window_bytes": int(
                codec.PartialDecoder(blobs[tg]).latent_bytes_parsed(window)
            ),
            "window_decode_warm_ms": warm_s * 1e3,
            "window_decode_cold_ms": cold_s * 1e3,
        })
    pd_v2 = codec.PartialDecoder(blob_v2)
    pd_v2.decode(time_range=window)
    v2_warm_s = _time(lambda: pd_v2.decode(time_range=window))
    v2_cold_s = _time(lambda: (
        codec.clear_decode_cache(),
        codec.decompress(blob_v2, time_range=window),
    ))

    # -- parallel vs serial shard encode ---------------------------------
    # tile the fitted latents so the pack is long enough to time sanely
    reps = max(1, (1 << 21) // max(art.latent_q.size, 1))
    lat_big = np.tile(art.latent_q, (reps, 1))
    shard_rows = max(1, lat_big.shape[0] // (8 * max(1, os.cpu_count() or 1)))
    serial_s = _time(lambda: codec.pack_latent_stream(
        lat_big, shard_rows, parallel=False), repeat=3)
    parallel_s = _time(lambda: codec.pack_latent_stream(
        lat_big, shard_rows, parallel=True), repeat=3)
    assert codec.pack_latent_stream(lat_big, shard_rows, parallel=True) == \
        codec.pack_latent_stream(lat_big, shard_rows, parallel=False), \
        "parallel shard pack != serial shard pack"
    sym_mb = lat_big.nbytes / 1e6

    summary = {
        "problem": {
            "shape": list(data.shape),
            "raw_bytes": int(data.nbytes),
            "target_nrmse": TARGET,
            "window": list(window),
            "seed": seed,
            "quick": quick,
        },
        "fit_s": fit_s,
        "blob_bytes_v2": len(blob_v2),
        "blob_bytes_v3_default": len(blob_v3),
        "blob_bytes_v4_default": len(blob_default),
        "v3_framing_overhead_bytes": len(blob_v3) - len(blob_v2),
        "latent_bytes_total": int(latent_total),
        "latent_bytes_v2_stream": int(v2_latent),
        "latent_bytes_vs_window": windows,
        "window_frames": WINDOW_FRAMES,
        "window_latent_fraction": b4 / latent_total,
        "per_shard_size": per_shard,
        "v2_window_decode_warm_ms": v2_warm_s * 1e3,
        "v2_window_decode_cold_ms": v2_cold_s * 1e3,
        "shard_encode": {
            "symbol_mb": sym_mb,
            "shard_rows": int(shard_rows),
            "serial_ms": serial_s * 1e3,
            "parallel_ms": parallel_s * 1e3,
            "serial_MBps": sym_mb / serial_s,
            "parallel_MBps": sym_mb / parallel_s,
            "parallel_speedup": serial_s / parallel_s,
        },
        "equivalence_gates_passed": True,
        "v3_equals_v2_byte_for_byte": True,
    }

    with open(OUT_JSON, "w") as f:
        json.dump(summary, f, indent=2)
    os.makedirs(os.path.dirname(OUT_CSV), exist_ok=True)
    with open(OUT_CSV, "w") as f:
        f.write("shard_tgroups,blob_bytes,latent_window_bytes,"
                "window_decode_warm_ms,window_decode_cold_ms\n")
        for row in per_shard:
            f.write(",".join(str(row[k]) for k in (
                "shard_tgroups", "blob_bytes", "latent_window_bytes",
                "window_decode_warm_ms", "window_decode_cold_ms")) + "\n")
    print(
        f"[bench_shards] {WINDOW_FRAMES}-frame window entropy-decodes "
        f"{b4}/{latent_total} latent bytes "
        f"({summary['window_latent_fraction']:.0%}; v2 chain walks 100%) | "
        f"window decode warm {per_shard[0]['window_decode_warm_ms']:.0f}ms "
        f"(shard=1) vs v2 {v2_warm_s * 1e3:.0f}ms | shard encode "
        f"{summary['shard_encode']['serial_MBps']:.0f} -> "
        f"{summary['shard_encode']['parallel_MBps']:.0f} MB/s "
        f"({summary['shard_encode']['parallel_speedup']:.1f}x) "
        f"-> {OUT_JSON}"
    )
    return summary


if __name__ == "__main__":
    run(quick="--full" not in sys.argv)
