"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell:
  compute term    = HLO_FLOPs(per device)        / peak_FLOP/s
  memory term     = HLO_bytes(per device)        / HBM_bw
  collective term = collective_bytes(per device) / (links * link_bw)

Hardware constants (TPU v5e-class, per assignment): 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI (3 usable link-pairs per chip on a 2D torus
-> we charge the *sum* of collective payload against one link, a
conservative single-bottleneck-link model).

MODEL_FLOPS = 6*N*D (dense train) / 6*N_active*D (MoE) / 2*N*D (inference
fwd), compared against HLO_FLOPs to expose remat/padding waste.
"""

from __future__ import annotations

import glob
import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import SHAPES, get_config  # noqa: E402

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s
LINK_BW = 50e9  # B/s per ICI link


# ---------------------------------------------------------------------------
# analytic parameter / flop counts
# ---------------------------------------------------------------------------
def param_counts(cfg) -> tuple[float, float]:
    """(total_params, active_params) — matmul params only (no embed gather)."""
    hd = cfg.head_dim
    d = cfg.d_model
    attn = d * (cfg.n_heads * hd) * 2 + d * (cfg.n_kv_heads * hd) * 2
    if cfg.family == "ssm":
        # rwkv6: 5 square projections + lora + channel mix
        tm = 5 * d * d + d * (5 * cfg.rwkv_lora_mix) * 2 + d * cfg.rwkv_lora_decay * 2
        cm = 2 * d * cfg.d_ff + d * d
        per_layer, active_per_layer = tm + cm, tm + cm
    elif cfg.family == "hybrid":
        w = cfg.rglru_width or d
        rec = 2 * d * w + 2 * w * w + w * d
        mlp = 3 * d * cfg.d_ff
        # per 3-block period: 2 rec + 1 attn + 3 mlp
        per_period = 2 * rec + attn + 3 * mlp
        n_periods = cfg.n_layers // 3
        tail = cfg.n_layers - 3 * n_periods
        total = per_period * n_periods + tail * (rec + mlp)
        per_layer = total / cfg.n_layers
        active_per_layer = per_layer
    elif cfg.n_experts:
        ffn_total = 3 * d * cfg.d_ff * cfg.n_experts
        ffn_active = 3 * d * cfg.d_ff * cfg.moe_top_k
        per_layer = attn + ffn_total
        active_per_layer = attn + ffn_active
    else:
        ffn = 3 * d * cfg.d_ff
        per_layer = attn + ffn
        active_per_layer = per_layer
    if cfg.is_encdec:
        enc = (attn + 2 * d * cfg.d_ff) * cfg.n_encoder_layers
        dec = (2 * attn + 2 * d * cfg.d_ff) * cfg.n_layers
        total = enc + dec
        active = total
    else:
        total = per_layer * cfg.n_layers
        active = active_per_layer * cfg.n_layers
    head = d * cfg.vocab
    return total + head, active + head


def model_flops(cfg, shape) -> float:
    """Whole-cell analytic flops (all devices)."""
    total, active = param_counts(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 8.0 * active * tokens  # fwd+bwd+remat-fwd (full remat policy)
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens
    return 2.0 * active * shape.global_batch  # decode: one token per seq


# ---------------------------------------------------------------------------
def analyze(path_glob="results/dryrun/*.json"):
    rows = []
    for path in sorted(glob.glob(path_glob)):
        with open(path) as f:
            r = json.load(f)
        if r.get("tag"):
            continue  # perf-iteration variants reported separately
        cfg = get_config(r["arch"])
        shape = SHAPES[r["shape"]]
        n_dev = r["n_devices"]
        t_compute = r["flops"] / PEAK_FLOPS
        t_memory = r["bytes_accessed"] / HBM_BW
        t_coll = r["collectives"]["total"] / LINK_BW
        terms = {"compute": t_compute, "memory": t_memory,
                 "collective": t_coll}
        bottleneck = max(terms, key=terms.get)
        mf = model_flops(cfg, shape)
        hlo_total = r["flops"] * n_dev
        rows.append({
            "arch": r["arch"],
            "shape": r["shape"],
            "mesh": r["mesh"],
            "kind": r["kind"],
            "t_compute_s": t_compute,
            "t_memory_s": t_memory,
            "t_collective_s": t_coll,
            "bottleneck": bottleneck,
            "model_flops": mf,
            "hlo_flops_total": hlo_total,
            "useful_ratio": mf / hlo_total if hlo_total else 0.0,
            # roofline fraction: useful model flops per step over what the
            # chips could do in the step's critical-path time
            "roofline_frac": (
                mf / n_dev / PEAK_FLOPS / max(max(terms.values()), 1e-30)
            ),
            "temp_bytes": r["memory"].get("temp_size_in_bytes", 0),
            "arg_bytes": r["memory"].get("argument_size_in_bytes", 0),
            "compile_s": r["compile_s"],
        })
    return rows


def render_markdown(rows) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "bottleneck | MODEL/HLO | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    body = ""
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
        body += (
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} "
            f"| {r['t_collective_s']:.3e} | **{r['bottleneck']}** "
            f"| {r['useful_ratio']:.2f} | {r['roofline_frac']:.3f} |\n"
        )
    return hdr + body


def main():
    rows = analyze()
    print(render_markdown(rows))
    os.makedirs("results", exist_ok=True)
    with open("results/roofline.json", "w") as f:
        json.dump(rows, f, indent=1)
    print(f"\n{len(rows)} cells analyzed -> results/roofline.json")


if __name__ == "__main__":
    main()
