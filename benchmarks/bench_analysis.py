"""Benchmark + gate for the invariant checker (:mod:`repro.analysis`).

Runs all three analyzer tiers against the live repo, asserts the gate
(zero non-baselined findings — a broken invariant can never hide behind
timing numbers), and records wall-clocks + per-rule counts.

Writes BENCH_analysis.json (repo root) + results/bench/analysis.csv.

  PYTHONPATH=src python -m benchmarks.bench_analysis
"""

from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.analysis import jaxpr_audit, wire_schema  # noqa: E402
from repro.analysis.findings import apply_baseline, load_baseline  # noqa: E402
from repro.analysis.lint import lint_tree  # noqa: E402

OUT_JSON = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_analysis.json")
OUT_CSV = "results/bench/analysis.csv"

_SRC_ROOT = os.path.join(os.path.dirname(__file__), "..", "src", "repro")
_TESTS_ROOT = os.path.join(os.path.dirname(__file__), "..", "tests")
_BASELINE = os.path.join(_SRC_ROOT, "analysis", "baseline.json")


def run(quick: bool = True) -> dict:
    t0 = time.perf_counter()
    lint = lint_tree(_SRC_ROOT, _TESTS_ROOT)
    lint_s = time.perf_counter() - t0

    t1 = time.perf_counter()
    schema_findings = wire_schema.check_conformance()
    schema_s = time.perf_counter() - t1

    audit = jaxpr_audit.audit()

    findings = (list(lint.findings) + list(lint.parse_errors)
                + schema_findings + audit.findings)
    new, baselined, _stale = apply_baseline(
        findings, load_baseline(_BASELINE)
    )

    # the gate: every finding is either fixed, inline-tagged with a
    # reason, or deliberately baselined — never silently outstanding
    assert not new, (
        "repro.analysis found non-baselined invariant violations:\n"
        + "\n".join(str(f) for f in new)
    )

    rule_counts: dict[str, int] = {}
    for f in findings:
        rule_counts[f.rule] = rule_counts.get(f.rule, 0) + 1

    summary = {
        "files_scanned": lint.files_scanned,
        "rule_counts": rule_counts,
        "new_findings": len(new),
        "baselined_findings": len(baselined),
        "suppressed_inline": len(lint.suppressed),
        "lint_wall_clock_s": lint_s,
        "schema_wall_clock_s": schema_s,
        "audit_wall_clock_s": audit.wall_clock_s,
        "audited_programs": {
            name: {
                "n_eqns": st.n_eqns,
                "callbacks": st.callbacks,
                "transfers": st.transfers,
                "f64_eqns": st.f64_eqns,
                "const_bytes": st.const_bytes,
                "donated": st.donated,
            }
            for name, st in audit.programs.items()
        },
        "gates_passed": True,
    }

    with open(OUT_JSON, "w") as f:
        json.dump(summary, f, indent=2)
    os.makedirs(os.path.dirname(OUT_CSV), exist_ok=True)
    with open(OUT_CSV, "w") as f:
        f.write("tier,wall_clock_s,items\n")
        f.write(f"lint,{lint_s:.4f},{lint.files_scanned}\n")
        f.write(f"schema,{schema_s:.4f},"
                f"{len(wire_schema.OUTER_RECORDS + wire_schema.STREAM_RECORDS)}\n")
        f.write(f"audit,{audit.wall_clock_s:.4f},"
                f"{len(audit.programs)}\n")
    return summary


if __name__ == "__main__":
    s = run()
    print(json.dumps(s, indent=2))
