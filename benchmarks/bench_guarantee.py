"""Guarantee-stage benchmark: device-resident engine vs the numpy oracle.

Measures the hot path of the whole system — Algorithm 1's per-error-bound
compress/decompress post-process — on the quick-mode surrogate geometry at
the paper's full time span (S=12 species, NB=5120 blocks of D=80: the
bench_compression quick spatial grid with T=64 frames).

Two workloads are timed at every tau in the sweep:

* oracle: the retained per-species float64 numpy implementation
  (``gae_ref.guarantee`` + ``gae_ref.apply_correction``), exactly the seed
  pipeline's stage 5;
* engine: ``gae.GuaranteeEngine`` — tau-independent ``prepare`` (residual,
  PCA, Pallas fp64 projection, energy ordering) paid once for the sweep,
  then per-tau ``select`` (jitted fp64 cut + masked select-and-accumulate
  Pallas kernel) and batched decode replay.

The engine's byte accounting must be bit-identical to the oracle's and
``verify_guarantee`` must hold at every bound — the benchmark asserts both,
so a perf number from a wrong engine cannot be reported.

Writes BENCH_guarantee.json (repo root) with per-tau timings and the
headline sweep speedup; also emits results/bench/guarantee.csv.

  PYTHONPATH=src python -m benchmarks.bench_guarantee
"""

from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import gae, gae_ref  # noqa: E402

# quick-mode surrogate geometry: 12 species on the 80x80 spatial grid in
# 4x5x4 blocks (bench_compression quick), at the paper's full time span
# (T=64 vs the paper's 50 steps) -> 5120 blocks of D=80 per species; taus
# from the TARGETS error bounds (tau = target_nrmse * sqrt(D), range = 1)
S, NB, D = 12, 5120, 80
TARGETS = (3e-3, 1e-3, 3e-4, 1e-4)
OUT_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_guarantee.json")
OUT_CSV = "results/bench/guarantee.csv"


def make_problem(seed: int = 0, noise: float = 0.02):
    """Normalized-units surrogate: blocks in ~[0,1], AE-like residual."""
    rng = np.random.default_rng(seed)
    base = rng.normal(size=(S, NB, D)).astype(np.float32) * 0.18 + 0.5
    x_rec = base + noise * rng.normal(size=base.shape).astype(np.float32)
    return base, x_rec


def _time(fn, repeat=3):
    """Best-of-N wall time: robust to CPU contention in shared runners."""
    fn()  # warmup (jit compile / allocator)
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _assert_bit_identical(arts, x, x_rec, tau):
    """Engine artifacts vs fresh oracle runs: same bytes, bit for bit."""
    total_engine = 0
    total_oracle = 0
    for s in range(S):
        _, a_ref = gae_ref.guarantee(x[s], x_rec[s], tau)
        a_eng = arts[s]
        assert np.array_equal(a_eng.coeff_q, a_ref.coeff_q), "coeff stream"
        assert np.array_equal(a_eng.index_offsets, a_ref.index_offsets)
        assert np.array_equal(a_eng.index_flat, a_ref.index_flat), "index sets"
        assert np.array_equal(a_eng.basis, a_ref.basis), "trimmed basis"
        total_engine += a_eng.total_bytes()
        total_oracle += a_ref.total_bytes()
    assert total_engine == total_oracle
    return total_engine


def run(seed: int = 0, repeat: int = 8):
    x, x_rec = make_problem(seed)
    taus = [t * np.sqrt(D) for t in TARGETS]
    engine = gae.GuaranteeEngine()

    prepare_s = _time(lambda: engine.prepare(x, x_rec), repeat=2)
    prep = engine.prepare(x, x_rec)

    rows = []
    oracle_total = 0.0
    engine_total = prepare_s
    for target, tau in zip(TARGETS, taus):
        # --- oracle: per-species guarantee + decode replay -------------
        def oracle_pass():
            arts = []
            for s in range(S):
                _, art = gae_ref.guarantee(x[s], x_rec[s], tau)
                arts.append(art)
            for s in range(S):
                gae_ref.apply_correction(x_rec[s], arts[s])
        oracle_s = _time(oracle_pass, repeat=3)

        # --- engine: per-tau select + batched decode replay ------------
        def engine_pass():
            corrected, arts = engine.select(prep, tau)
            gae.apply_correction_batched(x_rec, arts, engine)
        select_s = _time(engine_pass, repeat=repeat)

        corrected, arts = engine.select(prep, tau)
        for s in range(S):
            assert gae.verify_guarantee(x[s], corrected[s], tau), \
                f"bound violated at target={target:g}"
        total_bytes = _assert_bit_identical(arts, x, x_rec, tau)

        oracle_total += oracle_s
        engine_total += select_s
        rows.append({
            "target_nrmse": target,
            "tau": tau,
            "oracle_ms": oracle_s * 1e3,
            "engine_select_ms": select_s * 1e3,
            "speedup_marginal": oracle_s / select_s,
            "guarantee_bytes": int(total_bytes),
            "bytes_bit_identical": True,
            "bound_verified": True,
        })
        print(f"[bench_guarantee] target={target:.0e} oracle={oracle_s*1e3:7.1f}ms"
              f" engine={select_s*1e3:6.1f}ms ({oracle_s/select_s:5.1f}x)"
              f" bytes={total_bytes}")

    single_shot_ms = prepare_s * 1e3 + rows[0]["engine_select_ms"]
    marginals = [r["speedup_marginal"] for r in rows]
    summary = {
        "problem": {"S": S, "NB": NB, "D": D, "seed": seed},
        "prepare_ms": prepare_s * 1e3,
        "sweep": rows,
        "oracle_sweep_ms": oracle_total * 1e3,
        "engine_sweep_ms": engine_total * 1e3,
        # headline: steady-state per-error-bound throughput — the stage's
        # cost in the pipeline's real workload, where one fitted model is
        # swept across many error bounds (and served repeatedly) so the
        # tau-independent prepare amortizes out
        "speedup_steady_state": float(np.exp(np.mean(np.log(marginals)))),
        # full TARGETS sweep including one un-amortized prepare
        "speedup_sweep": oracle_total / engine_total,
        # single-shot: one tau paying the full prepare
        "speedup_single": rows[0]["oracle_ms"] / single_shot_ms,
        "backend": "cpu-interpret-pallas",
    }
    print(f"[bench_guarantee] steady-state {summary['speedup_steady_state']:.1f}x"
          f" | sweep: oracle {oracle_total*1e3:.0f}ms vs engine "
          f"{engine_total*1e3:.0f}ms incl. prepare {prepare_s*1e3:.0f}ms"
          f" ({summary['speedup_sweep']:.1f}x)")

    with open(OUT_JSON, "w") as f:
        json.dump(summary, f, indent=2)
    os.makedirs(os.path.dirname(OUT_CSV), exist_ok=True)
    keys = list(rows[0].keys())
    with open(OUT_CSV, "w") as f:
        f.write(",".join(keys) + "\n")
        for r in rows:
            f.write(",".join(str(r[k]) for k in keys) + "\n")
    return summary


if __name__ == "__main__":
    run()
