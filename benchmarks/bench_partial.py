"""Selective-decode benchmark: bytes parsed + wall-clock for random-access
decode of a stored container vs the full-field decode.

The serving scenario: an analyst queries ONE species (optionally one time
window) out of an S-species container on disk. The selective path parses
only the header plus the requested streams — the v2 combined guarantee
stream makes each species' byte extent addressable from its directory —
so both bytes touched and wall-clock must come in materially below a full
decode.

Before any number is reported, the equivalence gates are asserted:

* every selective decode is **bitwise equal** to slicing the full decode;
* a v1 (per-species nested guarantee) container decodes bit-identically
  to the v2 container through the same entry point.

Writes BENCH_partial.json (repo root) + results/bench/partial.csv.

  PYTHONPATH=src python -m benchmarks.bench_partial
"""

from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import codec  # noqa: E402
from repro.core.pipeline import PipelineConfig  # noqa: E402
from repro.data import s3d  # noqa: E402

TARGET = 3e-4  # tight bound: guarantee streams dominate, the serving case
OUT_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_partial.json")
OUT_CSV = "results/bench/partial.csv"


def _time(fn, repeat=5):
    """Best-of-N wall time: robust to CPU contention in shared runners."""
    fn()  # warmup (jit compile / caches)
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(quick: bool = True, seed: int = 1):
    scfg = (
        s3d.S3DConfig(n_species=12, n_time=16, height=80, width=80, seed=seed)
        if quick
        else s3d.S3DConfig(n_species=16, n_time=24, height=120, width=120,
                           seed=seed)
    )
    data = s3d.generate(scfg)["species"]
    gbatc = codec.GBATCCodec(
        PipelineConfig(
            conv_channels=(16, 32),
            ae_steps=150 if quick else 800,
            corr_steps=80 if quick else 400,
        )
    )
    t0 = time.time()
    gbatc.fit(data)
    fit_s = time.time() - t0
    blob, _rep = gbatc.compress_report(target_nrmse=TARGET)
    t = data.shape[1]
    window = (t // 4, t // 2)  # a mid-series window

    # -- equivalence gates: asserted before any number is reported -------
    full = codec.decompress(blob)
    one = codec.decompress(blob, species=0)
    assert np.array_equal(one, full[0]), "1-species decode != full slice"
    win = codec.decompress(blob, species=0, time_range=window)
    assert np.array_equal(win, full[0, window[0] : window[1]]), \
        "windowed decode != full slice"
    sub = codec.decompress(blob, species=[2, 7], time_range=window)
    assert np.array_equal(sub, full[[2, 7]][:, window[0] : window[1]]), \
        "subset decode != full slice"
    blob_v1 = codec.encode(_rep.artifact, version=1)
    assert np.array_equal(codec.decompress(blob_v1), full), \
        "v1 container decode != v2 decode"

    # -- bytes touched ---------------------------------------------------
    pd = codec.PartialDecoder(blob)
    bytes_full = len(blob)
    bytes_one = pd.bytes_parsed(species=[0])
    assert pd.bytes_parsed() == bytes_full  # v2 accounts every byte

    # -- wall clock ------------------------------------------------------
    # cold paths clear the head memo per call: these time a fresh-blob
    # query (the PR-4 measurement), not the digest-cache steady state —
    # which the warm PartialDecoder row below reports explicitly
    full_s = _time(
        lambda: (codec.clear_decode_cache(), codec.decompress(blob))
    )
    one_cold_s = _time(
        lambda: (codec.clear_decode_cache(),
                 codec.decompress(blob, species=0))
    )
    one_window_cold_s = _time(
        lambda: (codec.clear_decode_cache(),
                 codec.decompress(blob, species=0, time_range=window))
    )
    # steady state: a reused PartialDecoder answering repeated queries —
    # head parse amortized, guarantee artifact served from the memo
    warm_pd = codec.PartialDecoder(blob)
    one_window_warm_s = _time(
        lambda: warm_pd.decode(species=0, time_range=window)
    )

    summary = {
        "problem": {
            "shape": list(data.shape),
            "raw_bytes": int(data.nbytes),
            "target_nrmse": TARGET,
            "window": list(window),
            "seed": seed,
            "quick": quick,
        },
        "fit_s": fit_s,
        "blob_bytes": bytes_full,
        "bytes_parsed_1_species": int(bytes_one),
        "bytes_parsed_fraction": bytes_one / bytes_full,
        "decode_full_ms": full_s * 1e3,
        "decode_1_species_ms": one_cold_s * 1e3,
        "decode_1_species_window_ms": one_window_cold_s * 1e3,
        "decode_1_species_window_warm_ms": one_window_warm_s * 1e3,
        "speedup_1_species": full_s / one_cold_s,
        "speedup_1_species_window": full_s / one_window_cold_s,
        "equivalence_gates_passed": True,
        "v1_back_compat_bit_identical": True,
    }

    # the acceptance contract: both bytes touched and wall clock must be
    # materially below the full decode for a 1-of-S species query
    assert summary["bytes_parsed_fraction"] < 0.6, (
        f"1-species decode touches {summary['bytes_parsed_fraction']:.0%} "
        f"of the blob — not materially below full"
    )
    assert summary["speedup_1_species"] > 1.15, (
        f"1-species decode speedup {summary['speedup_1_species']:.2f}x "
        f"not materially below full decode wall-clock"
    )

    with open(OUT_JSON, "w") as f:
        json.dump(summary, f, indent=2)
    os.makedirs(os.path.dirname(OUT_CSV), exist_ok=True)
    keys = [k for k in summary if k not in ("problem",)]
    with open(OUT_CSV, "w") as f:
        f.write(",".join(keys) + "\n")
        f.write(",".join(str(summary[k]) for k in keys) + "\n")
    print(
        f"[bench_partial] blob {bytes_full} B | 1-species parses "
        f"{bytes_one} B ({summary['bytes_parsed_fraction']:.0%}) | "
        f"decode full {full_s * 1e3:.0f}ms vs 1-species "
        f"{one_cold_s * 1e3:.0f}ms ({summary['speedup_1_species']:.1f}x) "
        f"vs 1-species+window {one_window_cold_s * 1e3:.0f}ms "
        f"({summary['speedup_1_species_window']:.1f}x; warm "
        f"{one_window_warm_s * 1e3:.0f}ms) -> {OUT_JSON}"
    )
    return summary


if __name__ == "__main__":
    run(quick="--full" not in sys.argv)
