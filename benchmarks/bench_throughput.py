"""Throughput engine benchmark: compiled training vs the retained reference
trainer, and the fused device-resident decode vs the retained pre-change
decompress path.

Both baselines are *measured in-run* from code retained in the repo — not
replayed from old JSON — so the ratios hold on whatever box runs this:

* **fit baseline** — ``autoencoder.fit_reference`` / ``correction
  .fit_reference`` on an XLA-conv model: a fresh step closure jitted per
  call (the seed recompiled every ``fit``), host-looped steps with a
  blocking per-step loss sync.
* **decode baseline** — ``codec.decompress_reference``: sequential
  per-species deserialize with per-call Huffman table builds and the
  reference window pass, then the chunked host-round-trip reconstruct.

Bit-identity is the reporting gate: the fused decode must equal the
reference decode byte for byte, and the engine's loss trajectory must match
the reference trainer's, before any throughput number is written.

Writes BENCH_throughput.json (repo root) + results/bench/throughput.csv.

  PYTHONPATH=src python -m benchmarks.bench_throughput
"""

from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import codec  # noqa: E402
from repro.core import autoencoder as ae  # noqa: E402
from repro.core import blocking, correction, metrics  # noqa: E402
from repro.core.pipeline import GBATCPipeline, PipelineConfig  # noqa: E402
from repro.data import s3d  # noqa: E402

OUT_JSON = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_throughput.json")
OUT_CSV = "results/bench/throughput.csv"

TARGET = 1e-3  # domain-expert error bound (same as bench_codec's middle row)


def _best_of(fn, repeat=5):
    fn()  # warmup (jit compile / caches)
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _fit_reference(data, cfg: PipelineConfig, seed: int):
    """The pre-change fit recipe: reference trainers over XLA-conv models.

    Mirrors ``GBATCPipeline.fit``'s trainer workload (same steps, batch
    size, learning rate, seeds, and batch-index stream) on the retained
    per-step-dispatch engines. Every call pays the seed's per-fit jit
    rebuild, exactly as the pre-change code did.
    """
    geom = cfg.geometry
    normed, _, _ = GBATCPipeline._normalize(data)
    blocks = blocking.to_blocks(normed, geom)
    model = ae.BlockAutoencoder(
        ae.AEConfig(
            n_species=data.shape[0],
            block=(geom.bt, geom.ph, geom.pw),
            latent=cfg.latent,
            conv_channels=cfg.conv_channels,
            conv_impl="xla",
        )
    )
    params, losses = ae.fit_reference(
        model, blocks, steps=cfg.ae_steps, batch_size=cfg.batch_size,
        lr=cfg.lr, seed=cfg.seed,
    )
    import jax

    from repro.core.pipeline import _batched

    jit_encode = jax.jit(model.encode)
    jit_decode = jax.jit(model.decode)
    latents = np.asarray(_batched(jit_encode, params, blocks))
    x_rec = np.asarray(_batched(jit_decode, params, latents))
    corr_net = correction.TensorCorrectionNetwork(
        correction.CorrectionConfig(n_species=data.shape[0])
    )
    vec_rec = correction.blocks_to_pointwise(x_rec)
    vec_orig = correction.blocks_to_pointwise(blocks)
    correction.fit_reference(
        corr_net, vec_rec, vec_orig, steps=cfg.corr_steps, seed=cfg.seed + 1,
    )
    return np.asarray(losses)


def run(quick: bool = True, seed: int = 1):
    scfg = (
        s3d.S3DConfig(n_species=12, n_time=16, height=80, width=80, seed=seed)
        if quick
        else s3d.S3DConfig(n_species=16, n_time=24, height=120, width=120,
                           seed=seed)
    )
    data = s3d.generate(scfg)["species"]
    cfg = PipelineConfig(
        conv_channels=(16, 32),
        ae_steps=150 if quick else 800,
        corr_steps=80 if quick else 400,
    )
    raw_mb = data.nbytes / 1e6

    # ---- fit: engine (cold + steady-state) vs pre-change reference -------
    pipe = GBATCPipeline(cfg, n_species=data.shape[0])
    t0 = time.time()
    pipe.fit(data)
    fit_cold_s = time.time() - t0
    t0 = time.time()
    pipe.fit(data)
    fit_warm_s = time.time() - t0

    t0 = time.time()
    ref_losses = _fit_reference(data, cfg, seed=cfg.seed)
    fit_ref_s = time.time() - t0

    # trajectory equivalence gate: engine vs the retained reference
    # trainer on the SAME model — identical batch streams and step math,
    # only the execution engine differs, so the loss curves must agree
    # tightly (the xla-conv reference above is the *timing* baseline; its
    # trajectory additionally carries conv-reassociation noise)
    geom = cfg.geometry
    normed, _, _ = GBATCPipeline._normalize(data)
    blocks = blocking.to_blocks(normed, geom)
    _, eng_losses = ae.fit(
        pipe.model, blocks, steps=cfg.ae_steps, batch_size=cfg.batch_size,
        lr=cfg.lr, seed=cfg.seed,
    )
    _, ref2d_losses = ae.fit_reference(
        pipe.model, blocks, steps=cfg.ae_steps, batch_size=cfg.batch_size,
        lr=cfg.lr, seed=cfg.seed,
    )
    traj_rel = float(
        np.max(np.abs(eng_losses - ref2d_losses)
               / np.maximum(np.abs(ref2d_losses), 1e-12))
    )
    assert traj_rel < 1e-3, (
        f"engine/reference loss trajectories diverged: max rel {traj_rel:.3e}"
    )
    del ref_losses  # timing baseline only (xla convs reassociate)

    steps_total = cfg.ae_steps + cfg.corr_steps
    fit_speedup = fit_ref_s / fit_warm_s

    # ---- decode: fused device-resident path vs pre-change path -----------
    rep = pipe.compress(target_nrmse=TARGET)
    blob = rep.artifact.to_bytes()

    decoded = codec.decompress(blob)
    decoded_oracle = codec.decompress_reference(blob)
    # THE reporting gate: the fused hot path must be bit-identical to the
    # retained staged decode before any number is written (proves the
    # reorganization — fused dispatch, parallel deserialize, cached
    # tables — is semantically transparent)
    assert np.array_equal(decoded, decoded_oracle), \
        "fused decompress != staged reference decompress"
    # the timing baseline additionally retains the seed's XLA convolution
    # lowering; it may differ from the 2d formulation only by float
    # summation order inside the convs — ulp-level, bound-checked here
    decoded_seed = codec.decompress_reference(blob, conv_impl="xla")
    scale = float(np.abs(decoded_seed).max())
    assert np.allclose(decoded_seed, decoded, atol=1e-4 * scale), \
        "xla/2d conv outputs diverged beyond reassociation noise"
    per = np.array(
        [metrics.nrmse(data[s], decoded[s]) for s in range(data.shape[0])]
    )
    assert per.max() <= TARGET * (1 + 1e-3), "bound violated on wire"

    # clear the head memo per call so the number keeps meaning "cold-blob
    # standalone decode" (parse + entropy + NN + replay), comparable with
    # the retained baseline rather than the cache-served steady state
    dec_new_s = _best_of(
        lambda: (codec.clear_decode_cache(), codec.decompress(blob))
    )
    dec_ref_s = _best_of(
        lambda: codec.decompress_reference(blob, conv_impl="xla"), repeat=3
    )
    dec_speedup = dec_ref_s / dec_new_s

    summary = {
        "problem": {
            "shape": list(data.shape),
            "raw_bytes": int(data.nbytes),
            "seed": seed,
            "quick": quick,
            "config": {
                "conv_channels": list(cfg.conv_channels),
                "ae_steps": cfg.ae_steps,
                "corr_steps": cfg.corr_steps,
                "batch_size": cfg.batch_size,
                "target_nrmse": TARGET,
            },
        },
        "fit": {
            "reference_s": fit_ref_s,
            "engine_cold_s": fit_cold_s,
            "engine_warm_s": fit_warm_s,
            "speedup_warm": fit_speedup,
            "speedup_cold": fit_ref_s / fit_cold_s,
            "engine_steps_per_s": steps_total / fit_warm_s,
            "reference_steps_per_s": steps_total / fit_ref_s,
            "loss_trajectory_max_rel_dev": traj_rel,
            "trainer_mode": "stream/scan by backend",
        },
        "decompress": {
            "blob_bytes": len(blob),
            "reference_ms": dec_ref_s * 1e3,
            "fused_ms": dec_new_s * 1e3,
            "reference_MBps": raw_mb / dec_ref_s,
            "fused_MBps": raw_mb / dec_new_s,
            "speedup": dec_speedup,
            "bit_identical_to_reference": True,
            "max_species_nrmse": float(per.max()),
        },
    }
    os.makedirs(os.path.dirname(OUT_CSV), exist_ok=True)
    with open(OUT_JSON, "w") as f:
        json.dump(summary, f, indent=2)
    flat = {
        "fit_reference_s": fit_ref_s,
        "fit_engine_warm_s": fit_warm_s,
        "fit_speedup_warm": fit_speedup,
        "decompress_reference_MBps": raw_mb / dec_ref_s,
        "decompress_fused_MBps": raw_mb / dec_new_s,
        "decompress_speedup": dec_speedup,
    }
    with open(OUT_CSV, "w") as f:
        f.write(",".join(flat) + "\n")
        f.write(",".join(str(v) for v in flat.values()) + "\n")
    print(f"[bench_throughput] fit {fit_ref_s:.1f}s -> {fit_warm_s:.1f}s "
          f"({fit_speedup:.1f}x warm, {fit_ref_s / fit_cold_s:.1f}x cold) | "
          f"decompress {raw_mb / dec_ref_s:.1f} -> {raw_mb / dec_new_s:.1f} "
          f"MB/s ({dec_speedup:.1f}x) -> {OUT_JSON}")
    return summary


if __name__ == "__main__":
    run(quick="--full" not in sys.argv)
