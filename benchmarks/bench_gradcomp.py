"""Gradient-compression ablation: convergence with/without error-bounded
compression, wire-volume accounting, and quantized-all-reduce fidelity.

Outputs results/bench/gradcomp.csv.
"""

from __future__ import annotations

import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

from repro.configs.base import get_config  # noqa: E402
from repro.data.tokens import TokenPipeline, TokenPipelineConfig  # noqa: E402
from repro.models.registry import build_model  # noqa: E402
from repro.parallel.gradient_compression import CompressionConfig  # noqa: E402
from repro.train import optimizer as opt  # noqa: E402
from repro.train.train_loop import (TrainConfig, init_train_state,  # noqa: E402
                                    make_train_step)


def run(quick: bool = False, out_csv: str = "results/bench/gradcomp.csv"):
    steps = 40 if quick else 150
    cfg = get_config("llama3_2_1b").smoke()
    model = build_model(cfg)
    pipe = TokenPipeline(TokenPipelineConfig(vocab=cfg.vocab, batch=8,
                                             seq_len=32, seed=0))
    rows = []
    for name, ccfg in [
        ("fp32", None),
        ("int8_ef", CompressionConfig(n_bits=8)),
        ("int4_ef", CompressionConfig(n_bits=4)),
    ]:
        params = model.init(jax.random.PRNGKey(0))
        tcfg = TrainConfig(optimizer=opt.AdamWConfig(lr=3e-3,
                                                     total_steps=steps),
                           compression=ccfg)
        step_fn = jax.jit(make_train_step(model, tcfg))
        state = init_train_state(model, params, tcfg)
        losses = []
        t0 = time.time()
        for s in range(steps):
            batch = {k: jax.numpy.asarray(v)
                     for k, v in pipe.batch_at(s).items()}
            params, state, metrics = step_fn(params, state, batch)
            losses.append(float(metrics["loss"]))
        n_param = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
        bits = 32 if ccfg is None else ccfg.n_bits
        wire = n_param * bits / 8 + (0 if ccfg is None
                                     else n_param / ccfg.block * 4)
        rows.append({
            "mode": name,
            "final_loss": losses[-1],
            "mean_last10": float(np.mean(losses[-10:])),
            "wire_bytes_per_step": wire,
            "wire_saving": rows[0]["wire_bytes_per_step"] / wire if rows else 1.0,
            "steps_per_s": steps / (time.time() - t0),
        })
        print(f"[gradcomp] {name}: loss {losses[0]:.3f}->{losses[-1]:.3f} "
              f"wire/step {wire/1e6:.2f}MB")

    os.makedirs(os.path.dirname(out_csv), exist_ok=True)
    keys = list(rows[0].keys())
    with open(out_csv, "w") as f:
        f.write(",".join(keys) + "\n")
        for r in rows:
            f.write(",".join(str(r[k]) for k in keys) + "\n")
    print(f"[gradcomp] -> {out_csv}")
    return rows


if __name__ == "__main__":
    run(quick="--quick" in sys.argv)
