"""End-to-end driver (deliverable b): train a small LM for a few hundred
steps with the full production loop — checkpointing, fault tolerance,
error-bounded gradient compression — and show the loss dropping.

  PYTHONPATH=src python examples/train_lm.py [--arch llama3_2_1b]
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main as train_main


if __name__ == "__main__":
    args = sys.argv[1:] or []
    losses = train_main(args + ["--steps", "200", "--compress-grads",
                                "--ckpt-dir", "/tmp/repro_example_ckpt"])
    import numpy as np

    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"\nmean(first 10)={first:.3f}  mean(last 10)={last:.3f}")
    assert last < first - 0.3, "training failed to reduce loss"
    print("training reduced loss as expected.")
