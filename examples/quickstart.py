"""Quickstart: compress a CFD snapshot series with GBATC and verify the
guarantee — the paper's pipeline end to end in ~2 minutes on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import metrics
from repro.core.pipeline import GBATCPipeline, PipelineConfig
from repro.data import s3d


def main():
    # 1. a small S3D-like dataset: 12 species, 16 frames, 80x80 grid
    #    (fixed overheads — decoder, PCA bases — amortize with data volume;
    #    benchmarks/bench_compression.py runs the paper-scale version)
    ds = s3d.generate(s3d.S3DConfig(n_species=12, n_time=16, height=80,
                                    width=80, seed=0))
    data = ds["species"]
    print(f"data: {data.shape} ({data.nbytes / 1e6:.1f} MB), "
          f"species peak range {data.max(axis=(1,2,3)).min():.1e} .. "
          f"{data.max(axis=(1,2,3)).max():.1e}")

    # 2. fit the block AE + tensor-correction network once
    pipe = GBATCPipeline(
        PipelineConfig(conv_channels=(16, 32), ae_steps=500, corr_steps=200),
        n_species=data.shape[0],
    )
    pipe.fit(data, verbose=True)

    # 3. compress at the domain-expert bound (NRMSE 1e-3), decompress, audit
    rep = pipe.compress(target_nrmse=1e-3)
    print(f"\ncompression ratio : {rep.compression_ratio:.1f}x")
    print(f"mean NRMSE        : {rep.mean_nrmse:.2e} (target 1e-3)")
    print(f"worst species     : {rep.per_species_nrmse.max():.2e}")
    print(f"bytes breakdown   : {rep.bytes_breakdown}")

    decoded = pipe.decompress(rep.artifact)
    assert np.allclose(decoded, rep.recon, atol=1e-6)
    assert rep.per_species_nrmse.max() <= 1e-3 * (1 + 1e-3), "bound violated!"
    print("\nguarantee verified: every species within the error bound; "
          "decompress(artifact) bit-matches the encoder-side reconstruction.")


if __name__ == "__main__":
    main()
