"""Quickstart: compress a CFD snapshot series to *bytes on disk* with the
GBATC codec, decompress it standalone, and verify the error-bound guarantee —
the paper's pipeline end to end in ~1 minute on CPU.

  PYTHONPATH=src python examples/quickstart.py

The codec API is bytes in, bytes out: ``GBATCCodec.compress`` returns a
self-describing container blob, and ``repro.codec.decompress(blob)``
reconstructs the field from the blob alone — a fresh process with no fitted
model can decode the file this script writes. Subset consumers decode
randomly-accessed: ``decompress(blob, species=..., time_range=...)`` parses
only the header plus the requested streams and is bitwise equal to slicing
the full decode (step 4 below). Containers are written in the time-sharded
layout, so a time-window query entropy-decodes only the latent shards
covering the window — O(window), not O(T) (step 5 below) — and carry v4
integrity digests: every byte a decode reads is CRC-checked, corruption
raises a structured error, and ``on_error="salvage"`` decodes everything
that still verifies while quarantining the rest (step 6 below). The
encoder architecture itself is pluggable: containers are written in the
v5 family layout, whose meta stream names the encoder family, so a
block-attention codec rides the same wire format, guarantee engine, and
selective decode as the conv default (step 8 below). For fields that
outgrow one device, the whole fit/compress path shards over a
``("data",)`` mesh — DP trainer, species-sharded guarantee engine,
streamed sharded ingest — with byte-identical containers (step 9 below).

Performance expectations (2-core CI-class CPU; see BENCH_throughput.json
for the currently measured numbers): the 500-step fit below runs on the
compiled mini-batch engine (device-resident data, no per-step host sync)
at roughly 20+ steps/s — most of a fit's wall clock is now SGD compute,
and *refitting* the same codec is warm-start fast because the compiled
training program is cached. Standalone ``decompress`` runs the fused
device-resident decode (one dispatch for decoder+correction, batched
guarantee replay). Benchmark both ends against the retained pre-change
paths with:

  PYTHONPATH=src python -m benchmarks.bench_throughput

The invariants this pipeline rests on (decode reads only the blob, wire
errors carry stream/unit coordinates, hot programs never retrace, the
container layout matches its declarative schema) are machine-checked —
run the invariant checker before trusting a modified tree:

  PYTHONPATH=src python -m repro.analysis
"""

import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro import codec
from repro.core import metrics
from repro.core.pipeline import PipelineConfig
from repro.data import s3d


def main():
    # 1. a small S3D-like dataset: 12 species, 16 frames, 80x80 grid
    #    (fixed overheads — decoder, PCA bases — amortize with data volume;
    #    benchmarks/bench_compression.py runs the paper-scale version)
    ds = s3d.generate(s3d.S3DConfig(n_species=12, n_time=16, height=80,
                                    width=80, seed=0))
    data = ds["species"]
    print(f"data: {data.shape} ({data.nbytes / 1e6:.1f} MB), "
          f"species peak range {data.max(axis=(1,2,3)).min():.1e} .. "
          f"{data.max(axis=(1,2,3)).max():.1e}")

    # 2. fit the block AE + tensor-correction network once, then compress
    #    at the domain-expert bound (NRMSE 1e-3) straight to a file
    gbatc = codec.GBATCCodec(
        PipelineConfig(conv_channels=(16, 32), ae_steps=500, corr_steps=200)
    )
    gbatc.fit(data, verbose=True)
    blob, rep = gbatc.compress_report(target_nrmse=1e-3)

    fd, path = tempfile.mkstemp(suffix=".gbtc", prefix="quickstart_field_")
    with os.fdopen(fd, "wb") as f:
        f.write(blob)
    on_disk = os.path.getsize(path)
    print(f"\nwrote {path}: {on_disk} bytes "
          f"(compression ratio {data.nbytes / on_disk:.1f}x)")
    print(f"mean NRMSE        : {rep.mean_nrmse:.2e} (target 1e-3)")
    print(f"worst species     : {rep.per_species_nrmse.max():.2e}")
    print(f"bytes breakdown   : {rep.bytes_breakdown}")
    assert rep.bytes_breakdown["total"] == on_disk  # measured, not estimated

    # 3. decompress FROM THE FILE with no fitted state — everything the
    #    decoder needs (geometry, decoder params, correction net, guarantee
    #    streams, normalization) travels in the container
    with open(path, "rb") as f:
        decoded = codec.decompress(f.read())

    per = np.array([metrics.nrmse(data[s], decoded[s])
                    for s in range(data.shape[0])])
    assert per.max() <= 1e-3 * (1 + 1e-3), "bound violated!"
    assert np.array_equal(decoded, gbatc.pipeline.decompress(rep.artifact))
    print("\nguarantee verified: every species within the error bound; "
          "the on-disk container decodes bit-identically to the "
          "encoder-side reconstruction, with no fitted pipeline.")

    # 4. selective decode: analysts rarely want all S x T at once — pull ONE
    #    species (or a time window) straight from the on-disk blob. Only the
    #    header and that species' guarantee streams are parsed/entropy-
    #    decoded, and the result is bitwise equal to slicing a full decode.
    with open(path, "rb") as f:
        blob_on_disk = f.read()
    species_5 = codec.decompress(blob_on_disk, species=5)
    assert np.array_equal(species_5, decoded[5])
    pd = codec.PartialDecoder(blob_on_disk)  # reusable: head parsed once
    window = pd.decode(species=[2, 5], time_range=(4, 12))
    assert np.array_equal(window, decoded[[2, 5]][:, 4:12])
    touched = pd.bytes_parsed(species=[5])
    print(f"\nselective decode: species 5 alone touched {touched} of "
          f"{on_disk} container bytes ({touched / on_disk:.0%}) and came "
          "back bitwise equal to the full decode's slice "
          "(see benchmarks/bench_partial.py for the measured speedups).")

    # 5. sharded encode + window query: the container above is already the
    #    time-sharded v3 layout — the latent stream is partitioned into
    #    per-time-group Huffman chains under one shared codebook, so a
    #    window query entropy-decodes ONLY the shards covering it
    #    (O(window), where v1/v2 walk the whole sequential chain).
    #    `shard_tgroups` picks the granularity explicitly:
    coarse = codec.encode(rep.artifact, version=3, shard_tgroups=2)
    assert np.array_equal(codec.decompress(coarse), decoded)  # bit-equal
    lat_full = pd.latent_bytes_parsed()
    lat_win = pd.latent_bytes_parsed(time_range=(4, 8))
    print(f"\nwindow query: a 4-of-16-frame window entropy-decodes "
          f"{lat_win} of {lat_full} latent chain bytes "
          f"({lat_win / lat_full:.0%} ~ the window fraction; see "
          "benchmarks/bench_shards.py for the full sweep). Fitting "
          "larger-than-memory series is the same API via time chunks: "
          "codec.GBATCCodec(cfg).fit_stream(s3d.S3DChunkLoader(...)).")

    # 6. integrity + salvage: the blob above is container v5 (the v4
    #    integrity layout + the meta family tag) — per-stream and
    #    per-random-access-unit CRC32 digests ride in an `integrity`
    #    stream (v1-v4 blobs still decode bit-identically). codec.write /
    #    codec.read are the atomic file path: tmp + fsync + rename on
    #    write, digest verification on read.
    codec.write(path, blob_on_disk)
    assert codec.read(path) == blob_on_disk  # verified round trip
    codec.verify_blob(blob_on_disk)  # every payload byte digest-checked
    # flip one bit in species 3's guarantee bytes: raise-mode decode
    # refuses with a structured error; salvage-mode quarantines species 3
    # and returns every other species bitwise clean, with a report
    from repro.core.container import ContainerFormatError
    from repro.testing.faults import FaultInjector, blob_regions

    regions = {r.label: r for r in blob_regions(blob_on_disk)}
    bad, _ = FaultInjector(seed=0).flip_bit(
        blob_on_disk, regions["guarantee:s3:coeff"]
    )
    try:
        codec.decompress(bad)
        raise SystemExit("corruption went undetected!")
    except ContainerFormatError as e:
        print(f"\ncorrupt blob refused: stream={e.stream} unit={e.unit}")
    field, report = codec.decompress(bad, on_error="salvage")
    assert report.quarantined == [3] and np.isnan(field[3]).all()
    healthy = [s for s in range(field.shape[0]) if s != 3]
    assert np.array_equal(field[healthy], decoded[healthy])  # bitwise clean
    print(f"salvage decode: quarantined species {report.quarantined}, "
          f"all {len(healthy)} healthy species bitwise equal to the clean "
          "decode (see benchmarks/bench_integrity.py for overhead + "
          "throughput numbers).")

    # 7. decode service: many analysts, small queries — a scheduler thread
    #    coalesces concurrent (species, window) requests on one blob into
    #    shared fused dispatches and answers each from the multi-tier
    #    decode cache; every served slice is bitwise the serial
    #    PartialDecoder answer. cache_stats() surfaces the tiers.
    from repro.serve import DecodeService

    with DecodeService() as svc:
        svc.register("quickstart", blob_on_disk)
        futs = [svc.submit("quickstart", species=s % 12,
                           time_range=(4 * (s % 3), 4 * (s % 3) + 6))
                for s in range(9)]
        for s, fut in enumerate(futs):
            t0 = 4 * (s % 3)
            assert np.array_equal(fut.result(),
                                  decoded[s % 12, t0:t0 + 6])
    stats = codec.cache_stats()
    print(f"\ndecode service: {svc.stats.requests} mixed window queries in "
          f"{svc.stats.dispatches} fused dispatches "
          f"({svc.stats.coalesced} coalesced, {svc.stats.deduped} deduped); "
          "cache hit rates "
          + ", ".join(f"{tier}={stats[tier]['hit_rate']:.0%}"
                      for tier in ("head", "shard", "guarantee"))
          + " (see benchmarks/bench_serve.py for QPS/p99 vs the serial "
          "loop).")
    os.remove(path)

    # 8. a second encoder family, same container: `family="attention"`
    #    swaps the conv block AE for a patch-token block-attention
    #    autoencoder — the guarantee engine, wire format, selective
    #    decode, and integrity layer are untouched, so the bound holds
    #    the same way. The blob's meta stream carries the family tag;
    #    decompress dispatches on it with no fitted state, as always.
    attn = codec.GBATCCodec(PipelineConfig(
        family="attention", arch=(32, 2, 1, 64),  # d_model, heads, depth, mlp
        ae_steps=300, corr_steps=100,
    ))
    attn_blob, attn_rep = attn.compress_report(data, target_nrmse=1e-3)
    attn_decoded = codec.decompress(attn_blob)
    attn_per = np.array([metrics.nrmse(data[s], attn_decoded[s])
                         for s in range(data.shape[0])])
    assert attn_per.max() <= 1e-3 * (1 + 1e-3), "bound violated!"
    assert np.array_equal(codec.decompress(attn_blob, species=5),
                          attn_decoded[5])  # selective decode, same machinery
    print(f"\nattention family: CR "
          f"{data.nbytes / len(attn_blob):.1f}x at bound 1e-3 "
          f"(conv above: {data.nbytes / on_disk:.1f}x), worst species "
          f"NRMSE {attn_per.max():.2e} — same container, same guarantee "
          "(see benchmarks/bench_families.py for the CR-vs-bound sweep "
          "against conv and SZ).")

    # 9. mesh-sharded fit: the same pipeline over a ("data",) device mesh
    #    — DP trainer programs, a species/row-sharded guarantee engine,
    #    and streaming ingest that lands each chunk straight in a
    #    row-sharded device buffer, so each device holds only NB/P block
    #    rows and the full normalized field never exists on host. The
    #    device count is locked at first jax init, so the demo runs in a
    #    subprocess with 8 forced host devices; it prints the per-device
    #    ingest memory high-water against the single-device total and the
    #    sharded-compress NRMSE (container byte-identity with the
    #    single-device engine is asserted in tier-1 and in
    #    benchmarks/bench_mesh.py before any perf number).
    import subprocess

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(__file__), "..", "src"
    ) + os.pathsep + env.get("PYTHONPATH", "")
    mesh_demo = subprocess.run(
        [sys.executable, "-m", "repro.parallel.mesh_fit"],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert mesh_demo.returncode == 0, mesh_demo.stderr
    print("\nmesh-sharded fit (8 forced host devices):")
    print(mesh_demo.stdout.strip())


if __name__ == "__main__":
    main()
