"""Serving example: batched prefill + greedy decode, then the same with the
int8-quantized KV cache, comparing outputs (the paper's quantization bound
applied to serving state).

  PYTHONPATH=src python examples/serve_lm.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models.registry import build_model, make_batch
from repro.serve.kvcache import QuantizedKVCache
from repro.serve.serve_loop import Server


def main():
    cfg = get_config("llama3_2_1b").smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    server = Server(model, params, max_len=96)

    batch = make_batch(cfg, batch=4, seq=32, kind="prefill", seed=3)
    out = server.generate(batch, 24)
    print("generated:", out[0].tolist())
    print(f"decode tokens: {server.stats.decode_tokens}")

    # --- quantized KV path: bound check + agreement ---------------------
    _, cache = jax.jit(lambda p, b: model.prefill(p, b, max_len=96))(
        params, batch)
    qc = QuantizedKVCache.create(cfg.n_layers, 4, 96, cfg.n_kv_heads,
                                 cfg.head_dim)
    # quantize the prefill cache wholesale (per-token scales)
    kq, ks = QuantizedKVCache._quant(cache["k"].astype(jnp.float32))
    vq, vs = QuantizedKVCache._quant(cache["v"].astype(jnp.float32))
    qc = QuantizedKVCache(kq, vq, ks, vs, cache["len"])
    k_deq, v_deq = qc.dequant_layer(0, dtype=jnp.float32)
    err = float(jnp.abs(k_deq.astype(jnp.float32)
                        - cache["k"][0].astype(jnp.float32)).max())
    kb, vb = qc.max_abs_error_bound()
    print(f"KV quantization: max err {err:.3e} <= bound {float(kb):.3e}")
    assert err <= float(kb) * (1 + 1e-5)

    # decode one step on the dequantized cache; top-1 should usually agree
    cache_deq = {
        "k": (qc.k_q.astype(jnp.float32) * qc.k_scale).astype(cfg.dtype),
        "v": (qc.v_q.astype(jnp.float32) * qc.v_scale).astype(cfg.dtype),
        "len": cache["len"],
    }
    tok = jnp.asarray(out[:, :1])
    l1, _ = jax.jit(model.decode_step)(params, cache, tok)
    l2, _ = jax.jit(model.decode_step)(params, cache_deq, tok)
    agree = float(jnp.mean(
        (jnp.argmax(l1[:, -1], -1) == jnp.argmax(l2[:, -1], -1))))
    print(f"top-1 agreement dense vs int8-KV decode: {agree:.2f}")


if __name__ == "__main__":
    main()
