"""Checkpoint compression example: the paper's guarantee machinery applied
to model weights — int8 block quantization + PCA-residual correction with a
hard per-block l2 bound, Huffman-coded streams.

  PYTHONPATH=src python examples/compress_checkpoint.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models.registry import build_model, make_batch
from repro.train.checkpoint import compress_state_bytes, flatten_tree


def main():
    cfg = get_config("llama3_2_1b").smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    flat = flatten_tree(params)
    raw = sum(v.nbytes for v in flat.values())
    print(f"model: {len(flat)} tensors, {raw / 1e6:.1f} MB fp32")

    print("note: random-init weights are incompressible gaussians — trained "
          "checkpoints (structured weights) compress substantially better; "
          "tight bounds on random data force dense PCA coefficient storage.")
    for tau_rel in (3e-2, 1e-2, 3e-3):
        rec, nbytes, report = compress_state_bytes(flat, tau_rel=tau_rel)
        # quality impact: loss delta on a fixed batch
        batch = make_batch(cfg, batch=4, seq=32, kind="train", seed=1)
        from repro.train.checkpoint import unflatten_to

        loss0 = float(jax.jit(model.loss)(params, batch))
        loss1 = float(jax.jit(model.loss)(
            unflatten_to(params, rec), batch))
        print(f"tau_rel={tau_rel:.0e}: ratio {report['ratio']:.2f}x "
              f"({nbytes / 1e6:.1f} MB), loss {loss0:.4f} -> {loss1:.4f} "
              f"(delta {abs(loss1 - loss0):.2e})")


if __name__ == "__main__":
    main()
