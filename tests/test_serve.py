"""Serving-layer tests: generation loop + quantized KV cache."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models.registry import build_model, make_batch
from repro.serve.kvcache import QuantizedKVCache
from repro.serve.serve_loop import Server


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("llama3_2_1b").smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


class TestServer:
    def test_generate_shapes_and_determinism(self, setup):
        cfg, model, params = setup
        server = Server(model, params, max_len=64)
        batch = make_batch(cfg, batch=3, seq=16, kind="prefill", seed=5)
        out1 = server.generate(batch, 8)
        out2 = Server(model, params, max_len=64).generate(batch, 8)
        assert out1.shape == (3, 8)
        np.testing.assert_array_equal(out1, out2)  # greedy => deterministic
        assert (out1 >= 0).all() and (out1 < cfg.vocab).all()

    def test_generate_matches_incremental_prefill(self, setup):
        """Greedy decode must equal re-prefilling with the grown sequence."""
        cfg, model, params = setup
        server = Server(model, params, max_len=64)
        batch = make_batch(cfg, batch=2, seq=12, kind="prefill", seed=6)
        out = server.generate(batch, 3)
        # replay: prefill(12 + 2 generated) -> argmax equals 3rd generated
        grown = {"tokens": jnp.concatenate(
            [batch["tokens"], jnp.asarray(out[:, :2])], axis=1)}
        logits, _ = jax.jit(lambda p, b: model.prefill(p, b, max_len=64))(
            params, grown)
        want = np.asarray(jnp.argmax(logits[:, -1], -1))
        np.testing.assert_array_equal(out[:, 2], want)


class TestQuantKVDecodePath:
    def test_int8_decode_close_to_dense(self, setup):
        """cfg.kv_quant decode_step must track the dense path closely (the
        paper's quantization bound propagated through one attention layer)."""
        cfg, model, params = setup
        from repro.models.registry import build_model, make_batch
        import jax.numpy as jnp

        batch = make_batch(cfg, batch=2, seq=10, kind="prefill", seed=9)
        logits_p, cache = jax.jit(
            lambda p, b: model.prefill(p, b, max_len=24))(params, batch)

        qcfg = cfg.replace(kv_quant=True)
        qmodel = build_model(qcfg)
        # quantize the dense cache into the quant layout
        from repro.models.transformer import _quant_kv
        kq, ks = _quant_kv(cache["k"])
        vq, vs = _quant_kv(cache["v"])
        qcache = {"k_q": kq, "v_q": vq, "k_s": ks, "v_s": vs,
                  "len": cache["len"]}

        tok = batch["tokens"][:, :1]
        l_dense, _ = jax.jit(model.decode_step)(params, cache, tok)
        l_quant, qc2 = jax.jit(qmodel.decode_step)(params, qcache, tok)
        assert int(qc2["len"]) == 11
        a = np.asarray(l_dense, np.float32)
        b = np.asarray(l_quant, np.float32)
        # int8 KV: logits agree to ~1e-2 relative scale
        assert np.abs(a - b).max() / (np.abs(a).max() + 1e-9) < 0.05
        # top-1 agreement on most positions
        agree = (a.argmax(-1) == b.argmax(-1)).mean()
        assert agree >= 0.5


class TestQuantizedKV:
    def test_append_and_bound(self):
        qc = QuantizedKVCache.create(2, 3, 16, 4, 8)
        rng = np.random.default_rng(0)
        for i in range(5):
            k = jnp.asarray(rng.normal(size=(2, 3, 1, 4, 8)).astype(np.float32))
            v = jnp.asarray(rng.normal(size=(2, 3, 1, 4, 8)).astype(np.float32))
            qc = qc.append(k, v)
        assert int(qc.length) == 5
        k_deq, _ = qc.dequant_layer(0, dtype=jnp.float32)
        err = np.abs(np.asarray(k_deq[:, 4]) - np.asarray(k[0][:, 0]))
        kb, _ = qc.max_abs_error_bound()
        assert err.max() <= float(kb) + 1e-7

    def test_pytree_registered(self):
        qc = QuantizedKVCache.create(1, 1, 4, 1, 8)
        leaves = jax.tree.leaves(qc)
        assert len(leaves) == 5
        qc2 = jax.tree.map(lambda x: x, qc)
        assert isinstance(qc2, QuantizedKVCache)
