"""Subprocess driver for multi-device tests (8 fake CPU devices).

Run as:  python tests/distributed_driver.py <scenario>
Prints "SCENARIO_OK <name>" on success; any exception exits non-zero.
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import get_config, SHAPES, ShapeSpec
from repro.launch.mesh import make_mesh
from repro.models.registry import build_model, make_batch
from repro.parallel import sharding as sh
from repro.parallel.gradient_compression import (
    CompressionConfig, quantized_all_reduce)
from repro.train import optimizer as opt
from repro.train.checkpoint import CheckpointManager
from repro.train.train_loop import (
    TrainConfig, init_train_state, make_train_step)


def _small_setup(arch="llama3_2_1b", mesh_shape=(4, 2)):
    cfg = get_config(arch).smoke()
    mesh = make_mesh(mesh_shape, ("data", "model"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    p_shard = jax.tree.map(lambda ps: NamedSharding(mesh, ps),
                           sh.param_pspecs(model, cfg, mesh))
    params = jax.tree.map(jax.device_put, params, p_shard)
    return cfg, mesh, model, params, p_shard


def scenario_sharded_train_step():
    """Sharded train step on a (4, 2) mesh must match single-device numerics."""
    cfg, mesh, model, params, p_shard = _small_setup()
    tcfg = TrainConfig(optimizer=opt.AdamWConfig(lr=1e-3))
    step = make_train_step(model, tcfg)
    state = init_train_state(model, params, tcfg)
    batch = make_batch(cfg, batch=8, seq=16, kind="train")
    shape = ShapeSpec("t", 16, 8, "train")
    b_shard = sh.batch_shardings(cfg, shape, mesh)
    batch_sharded = {k: jax.device_put(v, b_shard[k]) for k, v in batch.items()}

    with mesh:
        p2, s2, m2 = jax.jit(step)(params, state, batch_sharded)
    # reference: plain single-device execution
    params_host = jax.device_get(params)
    state_host = jax.device_get(state)
    p1, s1, m1 = jax.jit(step)(params_host, state_host, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-4, atol=1e-5)
    flat1 = jax.tree.leaves(p1)
    flat2 = jax.tree.leaves(jax.device_get(p2))
    for a, b in zip(flat1, flat2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-3, atol=5e-4)
    print("SCENARIO_OK sharded_train_step")


def scenario_quantized_all_reduce():
    mesh = make_mesh((8,), ("data",))
    x = jnp.asarray(np.random.default_rng(0).normal(size=(8, 64)).astype(np.float32))
    xs = jax.device_put(x, NamedSharding(mesh, P("data", None)))
    got = quantized_all_reduce(xs, mesh, axis="data")
    want = jnp.broadcast_to(x.sum(axis=0, keepdims=True) * 0 + x.sum(axis=0),
                            x.shape)  # full sum on every row? no:
    # quantized_all_reduce sums *shards* -> every shard holds the total
    total = np.asarray(x).sum(axis=0)
    got_host = jax.device_get(got)
    for row in got_host.reshape(8, 64):
        np.testing.assert_allclose(row, total, rtol=0.05, atol=0.05)
    print("SCENARIO_OK quantized_all_reduce")


def scenario_checkpoint_elastic():
    """Save under a (4,2) mesh, restore under (2,4) and (8,1) — elastic."""
    cfg, mesh, model, params, _ = _small_setup(mesh_shape=(4, 2))
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, async_write=False)
        mgr.save(3, params, wait=True)
        for new_shape in [(2, 4), (8, 1), (1, 8)]:
            mesh2 = make_mesh(new_shape, ("data", "model"))
            shard2 = jax.tree.map(lambda ps: NamedSharding(mesh2, ps),
                                  sh.param_pspecs(model, cfg, mesh2))
            restored, step = mgr.restore(model.specs(), shardings=shard2)
            assert step == 3
            for a, b in zip(jax.tree.leaves(jax.device_get(params)),
                            jax.tree.leaves(jax.device_get(restored))):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("SCENARIO_OK checkpoint_elastic")


def scenario_dryrun_small_mesh():
    """Full dry-run mechanics on an 8-device (4,2) mesh for one arch."""
    cfg = get_config("llama3_2_1b")
    mesh = make_mesh((4, 2), ("data", "model"))
    model = build_model(cfg)
    from repro.models.registry import input_specs
    from repro.train.train_loop import make_train_step
    shape = SHAPES["train_4k"]
    specs = model.specs()
    p_shard = jax.tree.map(lambda ps: NamedSharding(mesh, ps),
                           sh.param_pspecs(model, cfg, mesh))
    b_shard = {k: NamedSharding(mesh, v)
               for k, v in sh.batch_pspecs(cfg, shape, mesh).items()}
    tcfg = TrainConfig(optimizer=opt.AdamWConfig(lr=1e-4))
    step = make_train_step(model, tcfg)
    from repro.launch.dryrun import train_state_specs, parse_collective_bytes
    st_specs = train_state_specs(specs)
    st_shard = {"opt": jax.tree.map(
        lambda ps: NamedSharding(mesh, ps),
        sh.optimizer_pspecs(model, cfg, mesh),
        is_leaf=lambda x: isinstance(x, P))}
    with mesh:
        lowered = jax.jit(step, in_shardings=(p_shard, st_shard, b_shard),
                          out_shardings=(p_shard, st_shard, None)).lower(
            specs, st_specs, input_specs(cfg, shape))
        compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older JAX returns [dict]
        cost = cost[0]
    assert cost.get("flops", 0) > 0
    coll = parse_collective_bytes(compiled.as_text())
    assert coll["total"] > 0, "sharded train step must communicate"
    print("SCENARIO_OK dryrun_small_mesh")


def scenario_moe_ep_sharded():
    """MoE forward under EP sharding matches unsharded numerics."""
    cfg = get_config("qwen3_moe_30b_a3b").smoke().replace(n_experts=8,
                                                          moe_top_k=2)
    mesh = make_mesh((2, 4), ("data", "model"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    batch = make_batch(cfg, batch=4, seq=16, kind="train")
    loss1 = float(jax.jit(model.loss)(params, batch))
    p_shard = jax.tree.map(lambda ps: NamedSharding(mesh, ps),
                           sh.param_pspecs(model, cfg, mesh))
    params_s = jax.tree.map(jax.device_put, params, p_shard)
    with mesh:
        loss2 = float(jax.jit(model.loss)(params_s, batch))
    np.testing.assert_allclose(loss1, loss2, rtol=1e-4)
    print("SCENARIO_OK moe_ep_sharded")


SCENARIOS = {
    "sharded_train_step": scenario_sharded_train_step,
    "quantized_all_reduce": scenario_quantized_all_reduce,
    "checkpoint_elastic": scenario_checkpoint_elastic,
    "dryrun_small_mesh": scenario_dryrun_small_mesh,
    "moe_ep_sharded": scenario_moe_ep_sharded,
}

if __name__ == "__main__":
    SCENARIOS[sys.argv[1]]()
