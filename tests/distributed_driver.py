"""Subprocess driver for multi-device tests (8 fake CPU devices).

Run as:  python tests/distributed_driver.py <scenario>
Prints "SCENARIO_OK <name>" on success; any exception exits non-zero.
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import get_config, SHAPES, ShapeSpec
from repro.launch.mesh import make_mesh
from repro.models.registry import build_model, make_batch
from repro.parallel import sharding as sh
from repro.parallel.gradient_compression import (
    CompressionConfig, quantized_all_reduce)
from repro.train import optimizer as opt
from repro.train.checkpoint import CheckpointManager
from repro.train.train_loop import (
    TrainConfig, init_train_state, make_train_step)


def _small_setup(arch="llama3_2_1b", mesh_shape=(4, 2)):
    cfg = get_config(arch).smoke()
    mesh = make_mesh(mesh_shape, ("data", "model"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    p_shard = jax.tree.map(lambda ps: NamedSharding(mesh, ps),
                           sh.param_pspecs(model, cfg, mesh))
    params = jax.tree.map(jax.device_put, params, p_shard)
    return cfg, mesh, model, params, p_shard


def scenario_sharded_train_step():
    """Sharded train step on a (4, 2) mesh must match single-device numerics."""
    cfg, mesh, model, params, p_shard = _small_setup()
    tcfg = TrainConfig(optimizer=opt.AdamWConfig(lr=1e-3))
    step = make_train_step(model, tcfg)
    state = init_train_state(model, params, tcfg)
    batch = make_batch(cfg, batch=8, seq=16, kind="train")
    shape = ShapeSpec("t", 16, 8, "train")
    b_shard = sh.batch_shardings(cfg, shape, mesh)
    batch_sharded = {k: jax.device_put(v, b_shard[k]) for k, v in batch.items()}

    with mesh:
        p2, s2, m2 = jax.jit(step)(params, state, batch_sharded)
    # reference: plain single-device execution
    params_host = jax.device_get(params)
    state_host = jax.device_get(state)
    p1, s1, m1 = jax.jit(step)(params_host, state_host, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-4, atol=1e-5)
    flat1 = jax.tree.leaves(p1)
    flat2 = jax.tree.leaves(jax.device_get(p2))
    for a, b in zip(flat1, flat2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-3, atol=5e-4)
    print("SCENARIO_OK sharded_train_step")


def scenario_quantized_all_reduce():
    mesh = make_mesh((8,), ("data",))
    x = jnp.asarray(np.random.default_rng(0).normal(size=(8, 64)).astype(np.float32))
    xs = jax.device_put(x, NamedSharding(mesh, P("data", None)))
    got = quantized_all_reduce(xs, mesh, axis="data")
    want = jnp.broadcast_to(x.sum(axis=0, keepdims=True) * 0 + x.sum(axis=0),
                            x.shape)  # full sum on every row? no:
    # quantized_all_reduce sums *shards* -> every shard holds the total
    total = np.asarray(x).sum(axis=0)
    got_host = jax.device_get(got)
    for row in got_host.reshape(8, 64):
        np.testing.assert_allclose(row, total, rtol=0.05, atol=0.05)
    print("SCENARIO_OK quantized_all_reduce")


def scenario_checkpoint_elastic():
    """Save under a (4,2) mesh, restore under (2,4) and (8,1) — elastic."""
    cfg, mesh, model, params, _ = _small_setup(mesh_shape=(4, 2))
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, async_write=False)
        mgr.save(3, params, wait=True)
        for new_shape in [(2, 4), (8, 1), (1, 8)]:
            mesh2 = make_mesh(new_shape, ("data", "model"))
            shard2 = jax.tree.map(lambda ps: NamedSharding(mesh2, ps),
                                  sh.param_pspecs(model, cfg, mesh2))
            restored, step = mgr.restore(model.specs(), shardings=shard2)
            assert step == 3
            for a, b in zip(jax.tree.leaves(jax.device_get(params)),
                            jax.tree.leaves(jax.device_get(restored))):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("SCENARIO_OK checkpoint_elastic")


def scenario_dryrun_small_mesh():
    """Full dry-run mechanics on an 8-device (4,2) mesh for one arch."""
    cfg = get_config("llama3_2_1b")
    mesh = make_mesh((4, 2), ("data", "model"))
    model = build_model(cfg)
    from repro.models.registry import input_specs
    from repro.train.train_loop import make_train_step
    shape = SHAPES["train_4k"]
    specs = model.specs()
    p_shard = jax.tree.map(lambda ps: NamedSharding(mesh, ps),
                           sh.param_pspecs(model, cfg, mesh))
    b_shard = {k: NamedSharding(mesh, v)
               for k, v in sh.batch_pspecs(cfg, shape, mesh).items()}
    tcfg = TrainConfig(optimizer=opt.AdamWConfig(lr=1e-4))
    step = make_train_step(model, tcfg)
    from repro.launch.dryrun import train_state_specs, parse_collective_bytes
    st_specs = train_state_specs(specs)
    st_shard = {"opt": jax.tree.map(
        lambda ps: NamedSharding(mesh, ps),
        sh.optimizer_pspecs(model, cfg, mesh),
        is_leaf=lambda x: isinstance(x, P))}
    with mesh:
        lowered = jax.jit(step, in_shardings=(p_shard, st_shard, b_shard),
                          out_shardings=(p_shard, st_shard, None)).lower(
            specs, st_specs, input_specs(cfg, shape))
        compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older JAX returns [dict]
        cost = cost[0]
    assert cost.get("flops", 0) > 0
    coll = parse_collective_bytes(compiled.as_text())
    assert coll["total"] > 0, "sharded train step must communicate"
    print("SCENARIO_OK dryrun_small_mesh")


def scenario_moe_ep_sharded():
    """MoE forward under EP sharding matches unsharded numerics."""
    cfg = get_config("qwen3_moe_30b_a3b").smoke().replace(n_experts=8,
                                                          moe_top_k=2)
    mesh = make_mesh((2, 4), ("data", "model"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    batch = make_batch(cfg, batch=4, seq=16, kind="train")
    loss1 = float(jax.jit(model.loss)(params, batch))
    p_shard = jax.tree.map(lambda ps: NamedSharding(mesh, ps),
                           sh.param_pspecs(model, cfg, mesh))
    params_s = jax.tree.map(jax.device_put, params, p_shard)
    with mesh:
        loss2 = float(jax.jit(model.loss)(params_s, batch))
    np.testing.assert_allclose(loss1, loss2, rtol=1e-4)
    print("SCENARIO_OK moe_ep_sharded")


def _mesh_fit_problem():
    """Tiny linear-AE trainer problem shared by the mesh-fit scenarios."""
    from repro.train import train_loop

    rng = np.random.default_rng(0)
    x = rng.standard_normal((64, 12)).astype(np.float32)
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    params = {"w_enc": jax.random.normal(k1, (12, 4)) * 0.1,
              "w_dec": jax.random.normal(k2, (4, 12)) * 0.1}

    def loss_fn(p, b):
        rec = b @ p["w_enc"] @ p["w_dec"]
        return jnp.mean(jnp.square(rec - b))

    tr = train_loop.MiniBatchTrainer(
        loss_fn, train_loop.adamw_cfg(5e-3, 16), mode="scan")
    return tr, params, x


def scenario_mesh_dp_fit():
    """DP fit over all 8 devices trains; a 1-device sub-mesh fit stays
    bitwise the plain scan fit (the P=1 identity gate, on a real forced
    mesh rather than the suite's default single device)."""
    from repro.parallel import mesh_fit

    tr, params, x = _mesh_fit_problem()
    kw = dict(steps=16, batch_size=16, seed=0)
    mesh8 = mesh_fit.host_mesh()
    assert mesh_fit.mesh_size(mesh8) == 8
    _, l8 = tr.fit(params, (x,), mesh=mesh8, **kw)
    assert np.isfinite(l8).all() and l8[-1] < l8[0]
    p_ref, l_ref = tr.fit(params, (x,), **kw)
    p1, l1 = tr.fit(params, (x,), mesh=mesh_fit.host_mesh(1), **kw)
    np.testing.assert_array_equal(l_ref, l1)
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("SCENARIO_OK mesh_dp_fit")


def scenario_mesh_quantized_fit():
    """DP fit with the int8 quantized gradient exchange on 8 devices:
    trains to a finite decreasing loss, and the static wire accounting
    shows the exchange is the cheaper one for realistically-sized params."""
    from repro.parallel import mesh_fit

    tr, params, x = _mesh_fit_problem()
    mesh8 = mesh_fit.host_mesh()
    _, losses = tr.fit(params, (x,), steps=16, batch_size=16, seed=0,
                       mesh=mesh8, quantized_exchange=True)
    assert np.isfinite(losses).all() and losses[-1] < losses[0]
    big = {"w": np.zeros((256, 256), np.float32)}
    # the int8 all-gather beats the fp32 ring all-reduce at small P (the
    # ring moves ~2n bytes regardless of P, the gather P*n/4); at P=8 the
    # two are a wash — assert each regime where it actually holds, plus
    # the ~4x win over an fp32 all-gather of the same pattern
    rep2 = mesh_fit.dp_wire_report(big, 2)
    assert rep2["wire_ratio"] > 3.5
    rep8 = mesh_fit.dp_wire_report(big, 8)
    fp32_gather = 7 * rep8["grad_fp32_bytes"]
    assert rep8["quantized_bytes_per_step"] < fp32_gather / 3.5
    print("SCENARIO_OK mesh_quantized_fit")


def scenario_mesh_sharded_compress():
    """Sharded guarantee engine with chunks placed across all 8 devices:
    the serialized container is byte-identical to the default engine's."""
    from repro.core.pipeline import GBATCPipeline, PipelineConfig
    from repro.data import s3d
    from repro.parallel import mesh_fit

    data = s3d.generate(s3d.S3DConfig(
        n_species=4, n_time=8, height=20, width=16, seed=5))["species"]
    cfg = PipelineConfig(ae_steps=40, corr_steps=20, conv_channels=(8, 16))
    pipe = GBATCPipeline(cfg, n_species=4)
    pipe.fit(data)
    ref = pipe.compress(target_nrmse=1e-3).artifact.to_bytes()
    pipe.set_guarantee_engine(
        mesh_fit.ShardedGuaranteeEngine(mesh=mesh_fit.host_mesh()))
    got = pipe.compress(target_nrmse=1e-3).artifact.to_bytes()
    assert got == ref, "sharded compress drifted from the default engine"
    print("SCENARIO_OK mesh_sharded_compress")


def scenario_mesh_fit_stream():
    """Mesh fit_stream on 8 devices: ingest lands row-sharded across the
    full mesh, the compressed output meets the bound, and re-compressing
    the same fitted state on the default engine is byte-identical."""
    from repro.core import gae
    from repro.core.pipeline import GBATCPipeline, PipelineConfig
    from repro.data import s3d
    from repro.parallel import mesh_fit

    scfg = s3d.S3DConfig(n_species=4, n_time=8, height=20, width=16, seed=5)
    loader = s3d.S3DChunkLoader(scfg, chunk_frames=4)
    cfg = PipelineConfig(ae_steps=30, corr_steps=15, conv_channels=(8, 16))
    pipe = GBATCPipeline(cfg, n_species=4, mesh=mesh_fit.host_mesh())
    pipe.fit_stream(loader)
    devs = {int(s.device.id) for s in pipe._blocks.addressable_shards}
    assert len(devs) == 8, f"ingest store only spans devices {devs}"
    rep = pipe.compress(target_nrmse=1e-3)
    assert rep.mean_nrmse <= 1e-3 * (1 + 1e-3)
    ref = rep.artifact.to_bytes()
    pipe.set_guarantee_engine(gae.default_engine())
    assert pipe.compress(target_nrmse=1e-3).artifact.to_bytes() == ref
    print("SCENARIO_OK mesh_fit_stream")


SCENARIOS = {
    "sharded_train_step": scenario_sharded_train_step,
    "quantized_all_reduce": scenario_quantized_all_reduce,
    "checkpoint_elastic": scenario_checkpoint_elastic,
    "dryrun_small_mesh": scenario_dryrun_small_mesh,
    "moe_ep_sharded": scenario_moe_ep_sharded,
    "mesh_dp_fit": scenario_mesh_dp_fit,
    "mesh_quantized_fit": scenario_mesh_quantized_fit,
    "mesh_sharded_compress": scenario_mesh_sharded_compress,
    "mesh_fit_stream": scenario_mesh_fit_stream,
}

if __name__ == "__main__":
    SCENARIOS[sys.argv[1]]()
