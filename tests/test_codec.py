"""Codec / container-format tests: the wire contract.

The acceptance bar for the serialization layer:

* ``decompress(compress(...))`` matches the in-memory reconstruction
  **bitwise** (not just within tolerance);
* a container decodes through the standalone module path — no fitted
  pipeline, no codec instance state;
* ``len(blob)`` equals the reported byte total exactly (accounting is a
  view over the stream table, not an estimate);
* corrupted / truncated / wrong-version blobs raise
  :class:`ContainerFormatError` with a useful message.
"""

import numpy as np
import pytest

from repro import codec
from repro.core import container as container_format
from repro.core import gae, metrics
from repro.core.container import (
    ContainerFormatError,
    ContainerReader,
    ContainerWriter,
)
from repro.core.pipeline import CompressedArtifact, GBATCPipeline, PipelineConfig
from repro.data import s3d


@pytest.fixture(scope="module")
def small_data():
    cfg = s3d.S3DConfig(n_species=8, n_time=8, height=40, width=32, seed=3)
    return s3d.generate(cfg)["species"]


@pytest.fixture(scope="module")
def fitted_codec(small_data):
    cfg = PipelineConfig(ae_steps=60, corr_steps=30, conv_channels=(16, 32))
    return codec.GBATCCodec(cfg).fit(small_data)


@pytest.fixture(scope="module")
def blob_and_report(fitted_codec):
    return fitted_codec.compress_report(target_nrmse=1e-3)


def _truncate_species_coeff(payload: bytes, sidx: int, keep: int) -> bytes:
    """Rebuild a combined (v2) guarantee stream with species ``sidx``'s
    coeff payload cut to ``keep`` bytes, directory record updated to match
    — the framing stays valid, only that one stream is corrupt."""
    head, rec = codec._GDIR_HEAD, codec._GDIR_REC
    (s,) = head.unpack_from(payload, 0)
    recs = [
        list(r)
        for r in rec.iter_unpack(payload[head.size : head.size + s * rec.size])
    ]
    off = head.size + s * rec.size
    parts: dict[int, list[bytes]] = {0: [], 1: [], 2: []}
    for kind in range(3):
        for i in range(s):
            ln = recs[i][4 + kind]
            parts[kind].append(payload[off : off + ln])
            off += ln
    parts[0][sidx] = parts[0][sidx][:keep]
    recs[sidx][4] = keep
    return b"".join(
        [head.pack(s)] + [rec.pack(*r) for r in recs]
        + parts[0] + parts[1] + parts[2]
    )


class TestContainer:
    def test_round_trip(self):
        w = ContainerWriter()
        w.add("alpha", b"12345")
        w.add("beta", b"")
        w.add("gamma", bytes(range(256)))
        blob = w.to_bytes()
        r = ContainerReader(blob)
        assert r.names == ["alpha", "beta", "gamma"]
        assert r["alpha"] == b"12345"
        assert r["beta"] == b""
        assert r["gamma"] == bytes(range(256))
        assert r.total_bytes == len(blob)
        assert r.header_bytes + sum(r.stream_sizes().values()) == len(blob)

    def test_duplicate_stream_rejected(self):
        w = ContainerWriter()
        w.add("x", b"1")
        with pytest.raises(ValueError):
            w.add("x", b"2")

    def test_missing_stream_raises(self):
        w = ContainerWriter()
        w.add("x", b"1")
        with pytest.raises(ContainerFormatError):
            ContainerReader(w.to_bytes())["y"]

    @pytest.mark.parametrize("cut", [0, 3, 7, 12, -1])
    def test_truncation_raises(self, cut):
        w = ContainerWriter()
        w.add("stream", b"payload-bytes")
        blob = w.to_bytes()
        with pytest.raises(ContainerFormatError):
            ContainerReader(blob[:cut] if cut >= 0 else blob[: len(blob) - 1])

    def test_trailing_garbage_raises(self):
        w = ContainerWriter()
        w.add("stream", b"payload")
        with pytest.raises(ContainerFormatError, match="trailing"):
            ContainerReader(w.to_bytes() + b"x")

    def test_bad_magic_raises(self):
        w = ContainerWriter()
        w.add("stream", b"payload")
        blob = w.to_bytes()
        with pytest.raises(ContainerFormatError, match="magic"):
            ContainerReader(b"NOPE" + blob[4:])

    def test_unknown_version_raises(self):
        w = ContainerWriter(version=73)
        w.add("stream", b"payload")
        with pytest.raises(ContainerFormatError, match="version"):
            ContainerReader(w.to_bytes())


class TestCodecRoundTrip:
    def test_bitwise_matches_in_memory_reconstruction(
        self, fitted_codec, blob_and_report
    ):
        blob, rep = blob_and_report
        dec = codec.decompress(blob)
        inmem = fitted_codec.pipeline.decompress(rep.artifact)
        np.testing.assert_array_equal(dec, inmem)
        assert dec.dtype == np.float32

    def test_standalone_decode_meets_bound(self, small_data, blob_and_report):
        blob, _ = blob_and_report
        dec = codec.decompress(blob)
        per = np.array(
            [metrics.nrmse(small_data[s], dec[s]) for s in range(small_data.shape[0])]
        )
        assert per.max() <= 1e-3 * (1 + 1e-3)

    def test_fresh_codec_instance_decodes(self, blob_and_report):
        """Decoding needs zero fitted state — a brand-new codec (and the
        module-level function) must reconstruct the same field."""
        blob, _ = blob_and_report
        fresh = codec.GBATCCodec()
        np.testing.assert_array_equal(fresh.decompress(blob),
                                      codec.decompress(blob))

    def test_artifact_fields_survive_wire(self, blob_and_report):
        blob, rep = blob_and_report
        art = CompressedArtifact.from_bytes(blob)
        src = rep.artifact
        np.testing.assert_array_equal(art.latent_q, src.latent_q)
        assert art.latent_bin == src.latent_bin
        np.testing.assert_array_equal(art.norm_min, src.norm_min)
        np.testing.assert_array_equal(art.norm_range, src.norm_range)
        assert art.shape == src.shape
        assert art.cfg.geometry == src.cfg.geometry
        assert art.cfg.latent == src.cfg.latent
        assert tuple(art.cfg.conv_channels) == tuple(src.cfg.conv_channels)
        for g_dec, g_src in zip(art.species_guarantees, src.species_guarantees):
            np.testing.assert_array_equal(g_dec.coeff_q, g_src.coeff_q)
            np.testing.assert_array_equal(g_dec.index_offsets, g_src.index_offsets)
            np.testing.assert_array_equal(g_dec.index_flat, g_src.index_flat)
            np.testing.assert_array_equal(g_dec.basis, g_src.basis)
            assert g_dec.tau == g_src.tau
            assert g_dec.coeff_bin == g_src.coeff_bin
        # decoder params round-trip bitwise (fp32 storage is lossless)
        dec_keys = sorted(k for k in src.ae_params if k.startswith("dec"))
        assert sorted(art.ae_params) == dec_keys
        for k in dec_keys:
            for leaf_name in sorted(art.ae_params[k]):
                np.testing.assert_array_equal(
                    np.asarray(art.ae_params[k][leaf_name]),
                    np.asarray(src.ae_params[k][leaf_name]),
                )

    def test_target_sweep_round_trips(self, small_data, fitted_codec):
        """Property-style sweep: every error bound's container must decode
        standalone to a bound-satisfying field, bitwise-matching the
        in-memory replay."""
        for target in (5e-3, 1e-3, 3e-4):
            blob, rep = fitted_codec.compress_report(target_nrmse=target)
            dec = codec.decompress(blob)
            np.testing.assert_array_equal(
                dec, fitted_codec.pipeline.decompress(rep.artifact)
            )
            per = np.array(
                [metrics.nrmse(small_data[s], dec[s])
                 for s in range(small_data.shape[0])]
            )
            assert per.max() <= target * (1 + 1e-3)
            assert len(blob) == rep.bytes_breakdown["total"]

    def test_version_back_compat(self, blob_and_report):
        """v1 (per-species nested guarantee), v2 (single-chain latent),
        v3 (sharded, no digests), and v4 (integrity) containers must
        decode bit-identically to the default v5 family layout through
        the same entry point; all five versions stay writable so
        round-trips cover each, and a conv-family v5 blob's payload
        streams are byte-identical to the v4 encoding of the same fit
        apart from the one-byte family tag (and the digests it shifts)."""
        blob, rep = blob_and_report
        blob_v1 = codec.encode(rep.artifact, version=1)
        blob_v2 = codec.encode(rep.artifact, version=2)
        blob_v3 = codec.encode(rep.artifact, version=3)
        blob_v4 = codec.encode(rep.artifact, version=4)
        assert ContainerReader(blob_v1).version == 1
        assert ContainerReader(blob_v2).version == 2
        assert ContainerReader(blob_v3).version == 3
        assert ContainerReader(blob_v4).version == 4
        r5, r4 = ContainerReader(blob), ContainerReader(blob_v4)
        assert r5.version == 5
        # conv v5 meta = family tag (conv=1) + the exact v4 meta bytes;
        # every other payload stream except the digests is byte-identical
        assert r5["meta"][:1] == b"\x01"
        assert r5["meta"][1:] == r4["meta"]
        for name in r4.names:
            if name not in ("meta", "integrity"):
                assert r5[name] == r4[name]
        assert len(blob_v2) < len(blob_v1)  # combined layout shaves framing
        full = codec.decompress(blob)
        # v5 decode == v4 == v3 == v2 decode BYTE for byte on one fit
        assert codec.decompress(blob_v4).tobytes() == full.tobytes()
        assert codec.decompress(blob_v3).tobytes() == full.tobytes()
        assert codec.decompress(blob_v2).tobytes() == full.tobytes()
        np.testing.assert_array_equal(codec.decompress(blob_v1), full)
        bb1 = codec.stream_breakdown(blob_v1)
        bb2 = codec.stream_breakdown(blob_v2)
        bb3 = codec.stream_breakdown(blob_v3)
        bb4 = codec.stream_breakdown(blob_v4)
        bb5 = codec.stream_breakdown(blob)
        for key in ("decoder", "correction", "coeff", "index", "basis"):
            assert bb1[key] == bb2[key] == bb3[key] == bb4[key] == bb5[key]
        # v1/v2 count the latent stream whole (inline Huffman header); v3+
        # buckets only the shard chain payloads as latent, the shared
        # codebook + shard table land in meta — parts still sum exactly
        assert bb1["latent"] == bb2["latent"] >= bb3["latent"]
        assert bb3["latent"] == bb4["latent"] == bb5["latent"]
        # the v4 digests are the only delta vs v3 and land in meta; the
        # v5 family tag adds exactly one more byte there
        assert bb4["meta"] > bb3["meta"]
        assert bb5["meta"] == bb4["meta"] + 1
        assert bb1["total"] == len(blob_v1)
        assert bb2["total"] == len(blob_v2)
        assert bb3["total"] == len(blob_v3)
        assert bb4["total"] == len(blob_v4)
        assert bb5["total"] == len(blob)

    def test_compress_with_data_fits_first(self, small_data):
        c = codec.GBATCCodec(
            PipelineConfig(ae_steps=40, corr_steps=20, conv_channels=(16, 32))
        )
        assert not c.fitted
        blob = c.compress(small_data, target_nrmse=2e-3)
        assert c.fitted
        dec = codec.decompress(blob)
        assert dec.shape == small_data.shape

    def test_unfitted_compress_raises(self):
        with pytest.raises(RuntimeError):
            codec.GBATCCodec().compress(target_nrmse=1e-3)

    def test_non_4d_data_raises_clearly(self, fitted_codec):
        """compress(1e-3) — a float where data goes — must fail with a
        clear ValueError, not an AttributeError deep inside fit."""
        with pytest.raises(ValueError, match="expected \\(S, T, H, W\\)"):
            fitted_codec.compress(1e-3)

    def test_unrepresentable_conv_channels_raise_at_encode(
        self, blob_and_report
    ):
        import dataclasses

        _, rep = blob_and_report
        bad_cfg = dataclasses.replace(
            rep.artifact.cfg, conv_channels=(70000, 32)
        )
        bad_art = dataclasses.replace(
            rep.artifact, cfg=bad_cfg, _wire=None
        )
        with pytest.raises(ValueError, match="u16"):
            codec.encode(bad_art)
        bad_cfg = dataclasses.replace(rep.artifact.cfg, latent=70000)
        bad_art = dataclasses.replace(rep.artifact, cfg=bad_cfg, _wire=None)
        with pytest.raises(ValueError, match="u16"):
            codec.encode(bad_art)


class TestByteAccounting:
    def test_len_equals_reported_total_exactly(self, blob_and_report):
        blob, rep = blob_and_report
        bb = rep.bytes_breakdown
        assert bb["total"] == len(blob)
        parts = (bb["latent"] + bb["decoder"] + bb["correction"] + bb["coeff"]
                 + bb["index"] + bb["basis"] + bb["meta"])
        assert parts == bb["total"]

    def test_breakdown_matches_stream_table(self, blob_and_report):
        blob, rep = blob_and_report
        r = ContainerReader(blob)
        sizes = r.stream_sizes()
        bb = rep.bytes_breakdown
        # v3 buckets the shard chain payloads as latent; the shard head
        # (shared codebook + extents table) is framing and lands in meta
        ldir = codec.LatentShardDirectory(r["latent"])
        assert bb["latent"] == ldir.payload_total
        assert bb["latent"] + ldir.header_bytes == sizes["latent"]
        assert bb["decoder"] == sizes["decoder"]
        assert bb["correction"] == sizes["correction"]
        # meta is measured framing + metadata, not the seed's 8*S + 64 guess
        assert bb["meta"] >= r.header_bytes + sizes["meta"] + ldir.header_bytes

    def test_gba_container_has_no_correction_stream(self, fitted_codec):
        blob, rep = fitted_codec.compress_report(
            target_nrmse=2e-3, skip_correction=True
        )
        assert "correction" not in ContainerReader(blob)
        assert rep.bytes_breakdown["correction"] == 0
        assert rep.bytes_breakdown["total"] == len(blob)
        dec = codec.decompress(blob)
        art = CompressedArtifact.from_bytes(blob)
        assert art.corr_params is None
        np.testing.assert_array_equal(dec, codec.reconstruct(art))


class TestCorruption:
    def test_truncated_raises(self, blob_and_report):
        blob, _ = blob_and_report
        for cut in (0, 5, len(blob) // 2, len(blob) - 1):
            with pytest.raises(ContainerFormatError):
                codec.decompress(blob[:cut])

    def test_wrong_magic_raises(self, blob_and_report):
        blob, _ = blob_and_report
        with pytest.raises(ContainerFormatError, match="magic"):
            codec.decompress(b"ZSTD" + blob[4:])

    def test_wrong_version_raises(self, blob_and_report):
        blob, _ = blob_and_report
        bad = blob[:4] + (99).to_bytes(2, "little") + blob[6:]
        with pytest.raises(ContainerFormatError, match="version"):
            codec.decompress(bad)

    def test_trailing_garbage_raises(self, blob_and_report):
        blob, _ = blob_and_report
        with pytest.raises(ContainerFormatError, match="trailing"):
            codec.decompress(blob + b"\x00\x01\x02")

    @pytest.mark.parametrize(
        "offset,value",
        [
            (0, 0),    # cleared correction flag with a correction stream present
            (0, 0xFF), # unknown flag bits set (newer writer or bit flip)
            (1, 3),    # param_dtype_bytes neither 2 nor 4
            (4, 0),    # geometry bt == 0 (would ZeroDivide downstream)
            (10, 0),   # n_conv == 0 (mis-frames the rest of the meta stream)
            (12, 0),   # conv_channels[0] == 0
        ],
    )
    def test_corrupt_meta_fields_raise(self, blob_and_report, offset, value):
        """Bit-flipped meta fields must surface as ContainerFormatError, not
        ZeroDivisionError / model-construction crashes downstream."""
        blob, _ = blob_and_report

        def mutate(name, payload):
            if name == "meta":
                return (payload[:offset] + bytes([value])
                        + payload[offset + 1:])
            return payload

        with pytest.raises(ContainerFormatError) as ei:
            codec.decompress(self._rebuild(blob, mutate).to_bytes())
        # structured: meta parse errors name the stream; a cleared/forged
        # correction flag instead surfaces as a stream-set mismatch (the
        # whole-container check, attributed to no single stream)
        assert ei.value.stream in ("meta", None)

    def _rebuild(self, blob, mutate):
        """Re-emit the outer container with ``mutate(name, payload)``,
        downgraded to v3 (integrity stream dropped, v5 meta family tag
        stripped back to the legacy layout): these tests pin the
        *structural* validation layer that pre-digest containers rely on
        — on a v4+ blob the digests would (correctly) catch the same
        mutations first, which test_integrity.py covers."""
        r = ContainerReader(blob)
        w = ContainerWriter(version=min(r.version, 3))
        family_ver = container_format.FORMAT_VERSION_FAMILY
        for name in r.names:
            if name == "integrity":
                continue
            payload = r[name]
            if name == "meta" and r.version >= family_ver:
                payload = payload[1:]  # drop the tag; v3 meta is the body
            res = mutate(name, payload)
            if res is not None:
                w.add(name, res)
        return w

    def test_truncated_nested_coeff_raises_format_error(self, blob_and_report):
        """A coeff payload cut inside its Huffman header must raise
        ContainerFormatError, not leak struct.error (v2: the species'
        directory record is shrunk to match, so only that stream is bad)."""
        blob, _ = blob_and_report

        def mutate(name, payload):
            if name == "guarantee":
                return _truncate_species_coeff(payload, sidx=0, keep=8)
            return payload

        with pytest.raises(ContainerFormatError) as ei:
            codec.decompress(self._rebuild(blob, mutate).to_bytes())
        assert ei.value.stream == "guarantee"
        assert ei.value.unit == 0

    def test_stray_stream_raises(self, blob_and_report):
        """Unknown streams must be rejected — every byte on the wire is
        accounted for by purpose, nothing rides along silently."""
        blob, _ = blob_and_report
        w = self._rebuild(blob, lambda name, payload: payload)
        w.add("padding", b"\x00" * 1024)
        with pytest.raises(ContainerFormatError, match="unexpected stream"):
            codec.decompress(w.to_bytes())

    def test_nan_coeff_bin_raises(self, blob_and_report):
        """A NaN coefficient bin in a guarantee directory record must
        raise, not scatter NaN corrections into the decoded field."""
        import struct

        blob, _ = blob_and_report

        def mutate(name, payload):
            if name == "guarantee":
                # record 0 starts after the u32 species count: <ddII...>
                off = 4 + 8  # skip count + tau
                return (payload[:off] + struct.pack("<d", float("nan"))
                        + payload[off + 8:])
            return payload

        with pytest.raises(ContainerFormatError, match="coeff bin"):
            codec.decompress(self._rebuild(blob, mutate).to_bytes())

    def test_basis_dimension_mismatch_raises(self, blob_and_report):
        """A guarantee basis whose row dimension disagrees with the block
        size must fail validation, not crash in the decode replay."""
        blob, rep = blob_and_report
        arts = rep.artifact.species_guarantees
        nb = arts[0].n_blocks
        wrong_d = codec.pack_guarantee_stream(
            [gae.GuaranteeArtifact.empty(nb=nb, d=40, tau=1.0)
             for _ in arts]
        )
        w = self._rebuild(
            blob,
            lambda name, payload: wrong_d if name == "guarantee" else payload,
        )
        with pytest.raises(ContainerFormatError, match="block size"):
            codec.decompress(w.to_bytes())

    def test_corrupt_guarantee_directory_raises(self, blob_and_report):
        """A guarantee stream whose directory disagrees with its payload
        bytes must surface as ContainerFormatError, not a mis-slice."""
        blob, _ = blob_and_report

        def mutate(name, payload):
            if name == "guarantee":
                # inflate the species count: directory now overruns
                return (99).to_bytes(4, "little") + payload[4:]
            return payload

        with pytest.raises(ContainerFormatError) as ei:
            codec.decompress(self._rebuild(blob, mutate).to_bytes())
        assert ei.value.stream == "guarantee"

    def test_corrupt_nested_guarantee_raises_v1(self, blob_and_report):
        """v1 layout: corrupting a nested guarantee container's magic must
        surface as a ContainerFormatError through the same entry point."""
        _, rep = blob_and_report
        blob = codec.encode(rep.artifact, version=1)
        r = ContainerReader(blob)
        w = ContainerWriter(version=r.version)
        for name in r.names:
            payload = r[name]
            if name == "guarantee0":
                payload = b"NOPE" + payload[4:]
            w.add(name, payload)
        with pytest.raises(ContainerFormatError):
            codec.decompress(w.to_bytes())


class TestConfigShadowingFix:
    """decompress must derive structure from the artifact, not the pipeline."""

    def test_gba_pipeline_applies_gbatc_correction(
        self, small_data, fitted_codec, blob_and_report
    ):
        blob, rep = blob_and_report
        cfg_gba = PipelineConfig(
            ae_steps=60, corr_steps=30, conv_channels=(16, 32),
            use_correction=False,
        )
        pipe_gba = GBATCPipeline(cfg_gba, n_species=small_data.shape[0])
        out = pipe_gba.decompress(rep.artifact)  # seed silently skipped corr
        np.testing.assert_array_equal(out, codec.decompress(blob))

    def test_structural_mismatch_raises(self, small_data, blob_and_report):
        _, rep = blob_and_report
        for bad_cfg in (
            PipelineConfig(conv_channels=(16, 32), latent=20),
            PipelineConfig(conv_channels=(8, 16)),
        ):
            pipe = GBATCPipeline(bad_cfg, n_species=small_data.shape[0])
            with pytest.raises(ValueError, match="does not match"):
                pipe.decompress(rep.artifact)

    def test_species_count_mismatch_raises(self, blob_and_report):
        _, rep = blob_and_report
        pipe = GBATCPipeline(
            PipelineConfig(conv_channels=(16, 32)), n_species=3
        )
        with pytest.raises(ValueError, match="does not match"):
            pipe.decompress(rep.artifact)


class TestGuaranteeArtifactWire:
    @pytest.mark.parametrize("tau", [0.2, 0.8])
    def test_round_trip(self, tau):
        rng = np.random.default_rng(0)
        x = rng.normal(size=(200, 64)).astype(np.float32)
        x_rec = x + 0.1 * rng.normal(size=x.shape).astype(np.float32)
        _, art = gae.guarantee(x, x_rec, tau)
        back = gae.GuaranteeArtifact.from_bytes(art.to_bytes())
        np.testing.assert_array_equal(back.coeff_q, art.coeff_q)
        np.testing.assert_array_equal(back.index_offsets, art.index_offsets)
        np.testing.assert_array_equal(back.index_flat, art.index_flat)
        np.testing.assert_array_equal(back.basis, art.basis)
        assert back.tau == art.tau and back.coeff_bin == art.coeff_bin
        # the replayed correction is bit-identical through the wire
        np.testing.assert_array_equal(
            gae.apply_correction(x_rec, back), gae.apply_correction(x_rec, art)
        )

    def test_empty_artifact_round_trip(self):
        art = gae.GuaranteeArtifact.empty(nb=37, d=80, tau=1.5)
        back = gae.GuaranteeArtifact.from_bytes(art.to_bytes())
        assert back.n_blocks == 37
        assert back.coeff_q.size == 0 and back.basis.shape == (80, 0)
        assert back.tau == 1.5

    def test_out_of_range_index_raises(self):
        """A well-framed index stream whose flat indices exceed the stored
        basis columns must raise at decode, not silently scatter into
        zero/absent columns at replay time."""
        art = gae.GuaranteeArtifact(
            basis=np.zeros((8, 2), np.float32),
            coeff_q=np.array([5], np.int64),
            index_offsets=np.array([0, 1, 1], np.int64),
            index_flat=np.array([5], np.int64),  # >= n_store == 2
            coeff_bin=0.1,
            tau=0.5,
        )
        with pytest.raises(ContainerFormatError, match="basis column"):
            gae.GuaranteeArtifact.from_bytes(art.to_bytes())

    def test_stream_size_memos_match_measured(self):
        rng = np.random.default_rng(1)
        x = rng.normal(size=(150, 48)).astype(np.float32)
        x_rec = x + 0.2 * rng.normal(size=x.shape).astype(np.float32)
        _, art = gae.guarantee(x, x_rec, 0.3)
        back = gae.GuaranteeArtifact.from_bytes(art.to_bytes())
        assert back.coeff_bytes() == art.coeff_bytes()
        assert back.index_bytes() == art.index_bytes()


class TestFp16ParamStorage:
    def test_honest_fp16_container(self, small_data):
        """fp16 storage halves the parameter streams AND keeps the bound:
        fit() rounds params through the storage dtype before anything
        downstream uses them, so the serialized decoder is exactly the one
        the guarantee was computed against."""
        mk = lambda pdb: PipelineConfig(
            ae_steps=40, corr_steps=20, conv_channels=(16, 32),
            param_dtype_bytes=pdb,
        )
        target = 2e-3
        blob32, _ = codec.GBATCCodec(mk(4)).fit(small_data).compress_report(
            target_nrmse=target
        )
        blob16, rep16 = codec.GBATCCodec(mk(2)).fit(small_data).compress_report(
            target_nrmse=target
        )
        bb32 = codec.stream_breakdown(blob32)
        bb16 = codec.stream_breakdown(blob16)
        assert bb16["decoder"] * 2 == bb32["decoder"]
        assert bb16["correction"] * 2 == bb32["correction"]
        dec = codec.decompress(blob16)
        np.testing.assert_array_equal(dec, codec.reconstruct(rep16.artifact))
        per = np.array(
            [metrics.nrmse(small_data[s], dec[s])
             for s in range(small_data.shape[0])]
        )
        assert per.max() <= target * (1 + 1e-3)
