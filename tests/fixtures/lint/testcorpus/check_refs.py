"""Fixture test corpus for the reference-pairing rule.

Mentions ``paired_fixture_ref`` (so pairing stays quiet on it); the
orphaned twin planted in ``tree/core/suppressed.py`` is deliberately
absent from this corpus, so pairing must fire on it.
"""

from clean import paired_fixture_ref  # noqa: F401 — word match is the point
