"""Planted decode-purity violation: pipeline-module import (fixture)."""

from repro.core.pipeline import CompressedArtifact  # planted: module import


def _encode(artifact):
    return CompressedArtifact, artifact
