"""Planted typed-errors parse-path violations (fixture — never imported)."""


class ContainerFormatError(Exception):
    pass


def _decode_head(blob):
    if not blob:
        # planted: structured error without stream=/offset=/unit=
        raise ContainerFormatError("empty blob")
    if blob[:1] == b"?":
        raise ValueError("bad magic")  # planted: untyped raise on parse path
    return blob


def helper(blob):
    # not a parse scope: an untyped raise here must NOT fire the rule
    raise ValueError("helpers may use plain errors")
