"""Planted decode-purity violations (fixture — never imported)."""

import os

from repro.core.pipeline import default_config  # planted: ambient import


def _decode_head(blob):
    level = os.getenv("GBATC_LEVEL")  # planted: env read on decode path
    cfg = default_config()
    return blob, cfg, level
