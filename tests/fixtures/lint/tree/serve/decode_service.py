"""Planted decode-purity violations in the serving layer (fixture)."""

import os

from repro.core.pipeline import GBATCPipeline  # planted: ambient import


def _serve(blob_id):
    root = os.environ["GBATC_BLOB_ROOT"]  # planted: env read in serve/
    return GBATCPipeline, root, blob_id
