"""A clean module: no rule may fire anywhere in this file (fixture)."""

import numpy as np


def paired_fixture_ref(x):
    """Mentioned by the fixture test corpus — pairing must NOT fire."""
    return np.asarray(x)


def work(seed: int):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 10, size=4)
