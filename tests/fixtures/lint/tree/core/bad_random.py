"""Planted determinism violations (fixture — never imported)."""

import random  # planted: stdlib random import
import time

import numpy as np


def sample(n: int):
    x = np.random.rand(n)  # planted: legacy global-state numpy RNG
    rng = np.random.default_rng()  # planted: unseeded generator
    return x, rng, random.random()


def cache_key() -> float:
    return time.time()  # planted: wall-clock in core/


def seeded_ok(n: int, seed: int):
    # sanctioned forms: must NOT fire
    rng = np.random.default_rng(seed)
    return rng.normal(size=n)
