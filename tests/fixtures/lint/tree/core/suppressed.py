"""Planted-but-suppressed violations (fixture — never imported)."""

import struct

HDR = struct.Struct("<I")  # repro: allow[wire-centralization]


def orphan_fixture_ref(x):
    """A reference twin no fixture test mentions — pairing fires here."""
    return x
