"""Planted handler-discipline violations (fixture — never imported)."""


def swallow(fn):
    try:
        return fn()
    except Exception:  # planted: broad swallow, no re-raise
        return None


def bare(fn):
    try:
        return fn()
    except:  # noqa: E722 — planted: bare except
        return None


def convert_ok(fn):
    try:
        return fn()
    except Exception as e:
        # broad catch that re-raises is the sanctioned convert idiom
        raise RuntimeError("wrapped") from e
