"""Planted wire-centralization violations (fixture — never imported)."""

import struct

MAGIC = b"FIX1"  # planted: magic-shaped literal outside the wire modules


def pack_header(n: int) -> bytes:
    return MAGIC + struct.pack("<I", n)  # planted: struct call outside wire


def on_error(e):
    # referencing struct.error is NOT a wire operation and must not fire
    return isinstance(e, struct.error)
