"""Per-architecture smoke tests (assignment requirement).

Each of the 10 assigned architectures is instantiated at its reduced
``.smoke()`` config and runs: one loss forward, one gradient step, and a
prefill -> decode consistency check — on CPU, asserting output shapes and
finiteness. The FULL configs are exercised only by the dry-run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, list_configs
from repro.models.registry import build_model, input_specs, make_batch
from repro.train import optimizer as opt

ALL_ARCHS = list_configs()


@pytest.fixture(scope="module")
def smoke_setups():
    return {}


def _setup(name):
    cfg = get_config(name).smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.mark.parametrize("arch", ALL_ARCHS)
class TestArchSmoke:
    def test_loss_and_grad_step(self, arch, smoke_setups):
        cfg, model, params = smoke_setups.setdefault(arch, _setup(arch))
        batch = make_batch(cfg, batch=2, seq=16, kind="train")

        loss_fn = jax.jit(model.loss)
        loss = loss_fn(params, batch)
        assert loss.shape == ()
        assert bool(jnp.isfinite(loss)), f"{arch} loss not finite"
        # untrained CE should be near log(vocab)
        assert float(loss) < np.log(cfg.vocab) + 2.0

        grads = jax.jit(jax.grad(model.loss))(params, batch)
        gnorm = opt.global_norm(grads)
        assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0.0

        state = opt.init_state(params)
        new_params, state, metrics = opt.update(
            opt.AdamWConfig(lr=1e-3), grads, state, params
        )
        # params actually moved
        delta = opt.global_norm(
            jax.tree.map(lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
                         new_params, params)
        )
        assert float(delta) > 0.0
        loss2 = loss_fn(new_params, batch)
        assert bool(jnp.isfinite(loss2))

    def test_prefill_decode_consistency(self, arch, smoke_setups):
        """decode_step after prefill(T) must match prefill(T+1)'s last logits."""
        cfg, model, params = smoke_setups.setdefault(arch, _setup(arch))
        t = 12
        batch_full = make_batch(cfg, batch=2, seq=t + 1, kind="prefill", seed=7)
        batch_pre = {
            k: (v[:, :t] if k == "tokens" else v) for k, v in batch_full.items()
        }

        logits_pre, cache = jax.jit(model.prefill)(params, batch_pre)
        assert logits_pre.shape[:2] == (2, 1)
        assert bool(jnp.isfinite(logits_pre).all())

        next_tok = batch_full["tokens"][:, t : t + 1]
        logits_dec, cache2 = jax.jit(model.decode_step)(params, cache, next_tok)
        assert logits_dec.shape[:2] == (2, 1)
        assert bool(jnp.isfinite(logits_dec).all())
        prefix = cfg.n_patches if cfg.is_vlm else 0  # VLM caches patch KV too
        assert int(cache2["len"]) == t + 1 + prefix

        logits_full, _ = jax.jit(model.prefill)(params, batch_full)
        np.testing.assert_allclose(
            np.asarray(logits_dec, np.float32),
            np.asarray(logits_full, np.float32),
            rtol=2e-2, atol=2e-2,
        )

    def test_input_specs_cover_all_shapes(self, arch, smoke_setups):
        cfg = get_config(arch)
        for shape_name in cfg.shapes:
            specs = input_specs(cfg, shape_name)
            assert "tokens" in specs
            for leaf in jax.tree.leaves(specs):
                assert isinstance(leaf, jax.ShapeDtypeStruct)

    def test_full_config_matches_assignment(self, arch, smoke_setups):
        """Spot-check the exact assigned numbers."""
        cfg = get_config(arch)
        expected = {
            "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
            "whisper-base": (6, 512, 8, 8, 2048, 51865),
            "rwkv6-7b": (32, 4096, 64, 64, 14336, 65536),
            "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
            "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
            "yi-9b": (48, 4096, 32, 4, 11008, 64000),
            "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
            "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
            "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
            "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        }[cfg.name]
        got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
               cfg.d_ff, cfg.vocab)
        assert got == expected


class TestShapeAssignments:
    def test_long_500k_only_sub_quadratic(self):
        runs_long = {n for n in ALL_ARCHS if "long_500k" in get_config(n).shapes}
        assert runs_long == {"rwkv6_7b", "recurrentgemma_2b"}

    def test_moe_experts(self):
        q = get_config("qwen3-moe-30b-a3b")
        assert (q.n_experts, q.moe_top_k) == (128, 8)
        d = get_config("dbrx-132b")
        assert (d.n_experts, d.moe_top_k) == (16, 4)
